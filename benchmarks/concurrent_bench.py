"""Concurrent-group planning benchmark: joint fabric arbitration vs
sequential independent plans on 2-D meshes.

The workload is the paper's end-to-end scenario: a TP×DP mesh where the TP
all-reduce (rows) and the DP reduce-scatter (columns) are in flight at the
same time on one photonic fabric.  For each swept point the bench plans the
pair two ways:

* **sequential** — each collective planned alone (Algorithm 1, the fabric to
  itself) and executed back-to-back: the sum of solo plan costs, i.e. what a
  per-collective planner charges a real training step;
* **joint** — ``plan_concurrent``: rounds aligned, link-disjoint circuit
  allocations where feasible, per-link priced contention where not.

Both are *planned* costs from the same cost model, so the ratio is exactly
the step-cost improvement the arbiter buys.  Joint plans are verified
bit-reproducible (two fresh runs must agree on totals and state sequences)
and never worse than sequential (the arbiter's serialized fallback bounds
them by construction — the bench asserts the bound held).

Writes ``BENCH_concurrent.json``::

    {"points": [{n, tp, dp, tp_collective, dp_collective, tp_mb, dp_mb,
                 algorithms, sequential_s, joint_s, speedup, serialized,
                 joint_rounds, plan_s}, ...],
     "smoke": bool}

``--smoke`` (used by scripts/ci.sh) restricts to n = 16 and asserts the
acceptance bar (≥ 1.2X at some point) plus the never-worse guard; by default
it skips the JSON write so a CI run never clobbers the full numbers, but
``--json-out PATH`` writes the (possibly reduced) points anywhere — the CI
bench gate diffs such a fresh file against the committed baseline.
"""

from __future__ import annotations

import argparse
import json
import time
from pathlib import Path
from typing import Dict, List, Tuple

from repro.core import cost_model as cm
from repro.core import topology as T
from repro.core.pccl import ConcurrentCollectiveRequest, plan_concurrent_collectives
from repro.core.planner import clear_planner_caches
from repro.core.schedules import mesh_groups

MB = 1024.0 ** 2
SIZE_PAIRS_MB = ((4, 64), (64, 64), (64, 256))  # (TP act, DP grad) per point
HW = cm.H100_DGX


def _fingerprint(cp) -> Tuple:
    """Everything a re-run must reproduce bit-for-bit."""
    return (
        cp.algorithms,
        cp.joint_cost,
        cp.sequential_cost,
        cp.serialized,
        tuple(g.states for g in cp.plan.groups),
    )


def bench_point(n: int, tp_mb: float, dp_mb: float) -> Dict:
    tp, dp = T.square_dims2(n)
    tp_groups, dp_groups = mesh_groups(tp, dp)
    reqs = [
        ConcurrentCollectiveRequest("all_reduce", tp_mb * MB, groups=tp_groups),
        ConcurrentCollectiveRequest("reduce_scatter", dp_mb * MB, groups=dp_groups),
    ]
    g0 = T.ring(n)

    clear_planner_caches()
    t0 = time.perf_counter()
    cp = plan_concurrent_collectives(reqs, n, g0, HW)
    plan_s = time.perf_counter() - t0

    # bit-reproducibility: a fresh cold run must return the identical plan
    clear_planner_caches()
    cp2 = plan_concurrent_collectives(reqs, n, g0, HW)
    assert _fingerprint(cp) == _fingerprint(cp2), (
        f"concurrent plan not reproducible at n={n} "
        f"({tp_mb:g}/{dp_mb:g} MB)"
    )
    # never-worse guard: the serialized fallback bounds the joint plan
    assert cp.cost <= cp.sequential_cost * (1 + 1e-12), (
        f"joint plan worse than sequential at n={n}: "
        f"{cp.cost} vs {cp.sequential_cost}"
    )
    return {
        "n": n,
        "tp": tp,
        "dp": dp,
        "tp_collective": "all_reduce",
        "dp_collective": "reduce_scatter",
        "tp_mb": tp_mb,
        "dp_mb": dp_mb,
        "algorithms": list(cp.algorithms),
        "sequential_s": cp.sequential_cost,
        "joint_s": cp.cost,
        "speedup": cp.speedup,
        "serialized": cp.serialized,
        "joint_rounds": cp.plan.n_rounds,
        "plan_s": plan_s,
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="n=16 only, assert guards, no default JSON write (CI)")
    ap.add_argument("--out", default="BENCH_concurrent.json")
    ap.add_argument("--json-out", default=None, metavar="PATH",
                    help="also write the JSON here (even under --smoke); "
                    "used by the CI bench gate")
    args = ap.parse_args()

    ns = (16,) if args.smoke else (16, 64)
    points: List[Dict] = []
    for n in ns:
        for tp_mb, dp_mb in SIZE_PAIRS_MB:
            p = bench_point(n, tp_mb, dp_mb)
            points.append(p)
            print(
                f"n={p['n']:<4} {p['tp']}x{p['dp']} "
                f"TP {p['tp_mb']:>4g} MB + DP {p['dp_mb']:>4g} MB  "
                f"seq {p['sequential_s']*1e6:9.1f} us  "
                f"joint {p['joint_s']*1e6:9.1f} us  "
                f"{p['speedup']:5.2f}x"
                f"{'  (serialized)' if p['serialized'] else ''}"
            )

    result = {"points": points, "smoke": args.smoke}

    # acceptance: the arbiter must beat sequential planning by >= 1.2x at
    # one swept point per n (planned cost: deterministic, no noise excuse)
    for n in ns:
        best = max(p["speedup"] for p in points if p["n"] == n)
        assert best >= 1.2, (
            f"acceptance: joint planning only {best:.2f}x over sequential "
            f"at n={n} (need >= 1.2x at some point)"
        )

    if args.json_out:
        Path(args.json_out).write_text(json.dumps(result, indent=2) + "\n")
        print(f"wrote {args.json_out}")
    if args.smoke:
        print("smoke OK: joint plans reproducible, never worse than "
              "sequential, and >= 1.2x at some point")
        return
    Path(args.out).write_text(json.dumps(result, indent=2) + "\n")
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
