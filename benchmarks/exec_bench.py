"""Execution-engine benchmark: cold per-call interpreter vs cached engine.

Times repeated same-shape collectives two ways per (n, collective) point,
on forced CPU host devices:

* **interpreter (cold)** — the pre-PR dispatch: every call builds a fresh
  ``jit(shard_map(...))`` around the per-round reference interpreter
  (``execute_schedule_reference`` + dense all-to-all state), re-deriving
  every round table in Python and re-tracing/compiling — what a serving
  or eval loop paid whenever XLA's jit cache missed;
* **engine (warm)** — the compiled execution engine through the eager
  Communicator path: the first call traces once into the process-wide
  executable cache (fingerprint + shape + dtype + axis + groups key),
  every later call is a cache hit with **zero retraces** (asserted from
  ``exec_stats`` deltas, the deterministic regression guard).

Both legs are best-of-N so the minimum reflects deterministic work, and
the engine outputs are checked against the interpreter outputs before
timing (bit-identical).

**Fused points** additionally time the comm/compute fusion layer
(``repro.comm.fusion``): the tile-streaming matmul + reduce-scatter (and
all-reduce + rmsnorm-on-arrival) in one dispatch versus the sequential
kernel-then-collective composition — both legs warm, bit-identical
outputs asserted before timing.  At least one fused point must show a
>=1.3x wall-clock win (the PR's acceptance bar; asserted in smoke and
full runs).

Writes ``BENCH_exec.json``::

    {"points": [{n, collective, algorithm, rounds, round_groups,
                 interp_cold_s, engine_cold_s, engine_warm_s, speedup,
                 first_call_traces, second_call_retraces},
                ...,
                {n, collective: "fused_matmul_reduce_scatter"|
                    "fused_all_reduce_rmsnorm",
                 algorithm, shape, mode: "fused",
                 seq_warm_s, fused_warm_s, speedup, overlap_fraction,
                 chunks_streamed, bytes_hidden}, ...],
     "smoke": bool}

Fused rows carry ``mode: "fused"`` and a ``shape`` string so the bench
gate (``scripts/bench_gate.py``) identifies them distinctly from engine
rows; their ``speedup`` is gated with the exec tolerance (0.1) configured
in ``scripts/ci.sh``.

``--smoke`` (used by scripts/ci.sh) restricts to n = 8 plus one fused
point, asserts the retrace guard, a loose wall-clock bar and the fused
>=1.3x bar, and skips the default JSON write so a CI run never clobbers
the full numbers.
"""

import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=16 "
    + os.environ.get("XLA_FLAGS", "")
)

import argparse
import json
import time
from pathlib import Path
from typing import Dict, List

import jax
import numpy as np
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.api import PcclSession
from repro.comm import exec_engine
from repro.comm import primitives as prim
from repro.core import cost_model as cm

COLLECTIVES = ("reduce_scatter", "all_gather", "all_reduce", "all_to_all")
HW = cm.TPU_V5E_PHOTONIC


def _mesh(n):
    return compat.make_mesh((n,), ("x",), devices=jax.devices()[:n])


def _global_input(collective, n, rng):
    if collective == "all_gather":
        return rng.normal(size=(n, 64)).astype(np.float32)
    return rng.normal(size=(n, n * 64)).astype(np.float32)


def bench_point(n: int, collective: str, repeats: int = 3) -> Dict:
    rng = np.random.default_rng(n)
    X = _global_input(collective, n, rng)
    session = PcclSession(HW, thread_fabric=False)
    comm = session.communicator("x", n, backend="interp")
    mesh = _mesh(n)

    # resolve the exact schedule both legs will execute
    itemsize = X.dtype.itemsize
    local = X[0]
    if collective == "all_gather":
        nbytes = local.size * itemsize * n
    else:
        nbytes = local.size * itemsize
    sched = comm.axis_schedule(collective, nbytes)

    def fresh_interpreter():
        """One *cold* interpreter call: new jit wrapper, full retrace."""
        fn = jax.jit(
            compat.shard_map(
                lambda x: prim.run_reference(collective, x[0], sched, "x")[None],
                mesh=mesh, in_specs=P("x", None), out_specs=P("x", None),
                check_vma=False,
            )
        )
        t0 = time.perf_counter()
        out = jax.block_until_ready(fn(X))
        return time.perf_counter() - t0, np.asarray(out)

    # --- engine: first (cold) call populates the executable cache
    exec_engine.clear_exec_caches()
    t0 = time.perf_counter()
    engine_out = np.asarray(jax.block_until_ready(comm.__getattribute__(collective)(X)))
    engine_cold_s = time.perf_counter() - t0
    s1 = exec_engine.exec_stats()

    # --- engine: warm calls (cache hit, zero retraces)
    engine_warm_s = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = jax.block_until_ready(getattr(comm, collective)(X))
        engine_warm_s = min(engine_warm_s, time.perf_counter() - t0)
    s2 = exec_engine.exec_stats()
    second_call_retraces = s2.traces - s1.traces
    assert s2.executable_hits >= repeats, (s2, repeats)
    np.testing.assert_array_equal(np.asarray(out), engine_out)

    # --- interpreter: every call cold (best-of-N)
    interp_cold_s = float("inf")
    for _ in range(repeats):
        dt, interp_out = fresh_interpreter()
        interp_cold_s = min(interp_cold_s, dt)
    np.testing.assert_array_equal(engine_out, interp_out)  # bit-identical

    compiled = exec_engine.compile_schedule(sched)
    return {
        "n": n,
        "collective": collective,
        "algorithm": sched.algorithm,
        "rounds": compiled.num_rounds,
        "round_groups": len(compiled.groups),
        "interp_cold_s": interp_cold_s,
        "engine_cold_s": engine_cold_s,
        "engine_warm_s": engine_warm_s,
        "speedup": interp_cold_s / engine_warm_s if engine_warm_s > 0 else float("inf"),
        "first_call_traces": s1.traces,
        "second_call_retraces": second_call_retraces,
    }


def bench_fused_matmul_rs(n: int, M: int, K: int, N: int, repeats: int = 5) -> Dict:
    """Fused tile-streaming matmul+RS vs sequential kernel-then-collective.

    Sequential leg is the pre-fusion composition the repo actually ran:
    one warm jitted ``shard_map`` matmul dispatch (same kernel, same block
    sizes as the fused tiles — so the legs stay bit-identical) followed by
    the warm eager reduce-scatter dispatch.  Fused leg is one dispatch of
    ``fused_matmul_reduce_scatter``.  Both warm, best-of-N.
    """
    from repro.comm.fusion import fused_matmul_reduce_scatter
    from repro.kernels.matmul.kernel import matmul_pallas

    rng = np.random.default_rng(n)
    x = rng.normal(size=(n, M, K)).astype(np.float32)
    w = rng.normal(size=(K, N)).astype(np.float32)
    session = PcclSession(HW, thread_fabric=False)
    comm = session.communicator("x", n, backend="interp", algorithm="ring")
    mesh = _mesh(n)
    Mc = M // n
    interpret = jax.default_backend() == "cpu"

    mm = jax.jit(compat.shard_map(
        lambda xl, wl: matmul_pallas(
            xl[0], wl, block_m=Mc, block_n=N, block_k=K, interpret=interpret
        )[None],
        mesh=mesh, in_specs=(P("x", None, None), P(None, None)),
        out_specs=P("x", None, None), check_vma=False,
    ))

    def sequential():
        y = mm(x, w)
        return jax.block_until_ready(comm.reduce_scatter(y))

    def fused():
        return jax.block_until_ready(fused_matmul_reduce_scatter(
            comm, x, w, block_m=Mc, block_n=N, block_k=K
        ))

    exec_engine.clear_exec_caches()
    s0 = exec_engine.exec_stats()
    f_out, s_out = fused(), sequential()
    np.testing.assert_array_equal(np.asarray(f_out), np.asarray(s_out))
    s1 = exec_engine.exec_stats()
    assert s1.fused_dispatches - s0.fused_dispatches == 1, (s0, s1)

    fused_warm_s = seq_warm_s = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fused()
        fused_warm_s = min(fused_warm_s, time.perf_counter() - t0)
        t0 = time.perf_counter()
        sequential()
        seq_warm_s = min(seq_warm_s, time.perf_counter() - t0)
    s2 = exec_engine.exec_stats()
    return {
        "n": n,
        "collective": "fused_matmul_reduce_scatter",
        "algorithm": "ring",
        "shape": f"{M}x{K}x{N}",
        "mode": "fused",
        "seq_warm_s": seq_warm_s,
        "fused_warm_s": fused_warm_s,
        "speedup": seq_warm_s / fused_warm_s if fused_warm_s > 0 else float("inf"),
        "overlap_fraction": max(0.0, 1.0 - fused_warm_s / seq_warm_s),
        "chunks_streamed": (s2.chunks_streamed - s0.chunks_streamed)
        // max(1, s2.fused_dispatches - s0.fused_dispatches),
        "bytes_hidden": (s2.bytes_hidden - s0.bytes_hidden)
        // max(1, s2.fused_dispatches - s0.fused_dispatches),
    }


def bench_fused_ar_rmsnorm(n: int, rows: int, d: int, repeats: int = 5) -> Dict:
    """Consumer fusion: rmsnorm at all-reduce arrival vs two dispatches."""
    from repro.comm.fusion import fused_all_reduce_rmsnorm
    from repro.kernels.rmsnorm.ops import rmsnorm

    rng = np.random.default_rng(n)
    x = rng.normal(size=(n, rows, d)).astype(np.float32)
    g = rng.normal(size=(d,)).astype(np.float32)
    session = PcclSession(HW, thread_fabric=False)
    comm = session.communicator("x", n, backend="interp", algorithm="ring")
    interpret = jax.default_backend() == "cpu"

    def sequential():
        red = comm.all_reduce(x)
        return jax.block_until_ready(
            rmsnorm(red, g, use_pallas=True, interpret=interpret)
        )

    def fused():
        return jax.block_until_ready(fused_all_reduce_rmsnorm(comm, x, g))

    exec_engine.clear_exec_caches()
    f_out, s_out = fused(), sequential()
    np.testing.assert_array_equal(np.asarray(f_out), np.asarray(s_out))

    fused_warm_s = seq_warm_s = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fused()
        fused_warm_s = min(fused_warm_s, time.perf_counter() - t0)
        t0 = time.perf_counter()
        sequential()
        seq_warm_s = min(seq_warm_s, time.perf_counter() - t0)
    return {
        "n": n,
        "collective": "fused_all_reduce_rmsnorm",
        "algorithm": "ring",
        "shape": f"{rows}x{d}",
        "mode": "fused",
        "seq_warm_s": seq_warm_s,
        "fused_warm_s": fused_warm_s,
        "speedup": seq_warm_s / fused_warm_s if fused_warm_s > 0 else float("inf"),
        "overlap_fraction": max(0.0, 1.0 - fused_warm_s / seq_warm_s),
        "chunks_streamed": 0,
        "bytes_hidden": 0,
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="n=8 only, assert guards, no default JSON write (CI)")
    ap.add_argument("--out", default="BENCH_exec.json")
    ap.add_argument("--json-out", default=None, metavar="PATH",
                    help="also write the JSON here (even under --smoke); "
                    "used by the CI bench gate")
    args = ap.parse_args()

    ns = (8,) if args.smoke else (8, 16)
    points: List[Dict] = []
    for n in ns:
        for coll in COLLECTIVES:
            p = bench_point(n, coll)
            points.append(p)
            print(
                f"n={p['n']:<3} {p['collective']:<15} ({p['algorithm']:<7}) "
                f"interp-cold {p['interp_cold_s']*1e3:8.1f} ms  "
                f"engine-warm {p['engine_warm_s']*1e3:7.2f} ms  "
                f"{p['speedup']:7.1f}x  "
                f"retraces {p['first_call_traces']}->{p['second_call_retraces']}  "
                f"rounds {p['rounds']}->{p['round_groups']} groups"
            )

    # --- fused comm/compute points (tile-streaming mm+RS, AR+rmsnorm)
    if args.smoke:
        fused_points = [bench_fused_matmul_rs(8, 512, 128, 128)]
    else:
        fused_points = [
            bench_fused_matmul_rs(8, 256, 128, 128),
            bench_fused_matmul_rs(8, 512, 128, 128),
            bench_fused_matmul_rs(4, 128, 128, 128),
            bench_fused_ar_rmsnorm(8, 256, 512),
        ]
    for p in fused_points:
        points.append(p)
        print(
            f"n={p['n']:<3} {p['collective']:<26} {p['shape']:<12} "
            f"seq-warm {p['seq_warm_s']*1e3:7.2f} ms  "
            f"fused-warm {p['fused_warm_s']*1e3:7.2f} ms  "
            f"{p['speedup']:5.2f}x  overlap {p['overlap_fraction']:.0%}"
        )

    def write_json_out() -> None:
        # only after the guards: a failed smoke must not leave a fresh
        # artifact for the bench gate to score
        if args.json_out:
            Path(args.json_out).write_text(
                json.dumps({"points": points, "smoke": args.smoke}, indent=2) + "\n"
            )
            print(f"wrote {args.json_out}")

    engine_points = [p for p in points if p.get("mode") != "fused"]
    mm_rs_points = [p for p in points
                    if p["collective"] == "fused_matmul_reduce_scatter"]

    # deterministic guard at every scale: a repeated same-shape collective
    # must never retrace after its first call
    for p in engine_points:
        assert p["second_call_retraces"] == 0, (
            f"retrace regression at n={p['n']} {p['collective']}: "
            f"{p['second_call_retraces']} retraces on warm calls"
        )

    # acceptance: the tile-streaming fusion must beat the sequential
    # kernel-then-collective by >=1.3x at some (n, shape)
    best_fused = max(p["speedup"] for p in mm_rs_points)
    assert best_fused >= 1.3, (
        "fused matmul+reduce-scatter regression: best speedup "
        f"{best_fused:.2f}x < 1.3x",
        [(p["n"], p["shape"], round(p["speedup"], 2)) for p in mm_rs_points],
    )

    if args.smoke:
        # loose wall-clock bar (observed locally: 100-4000x); deliberately
        # far below the acceptance number so CI noise cannot flake it
        for p in engine_points:
            assert p["speedup"] >= 3.0, (
                f"engine speedup regression: only {p['speedup']:.2f}x at "
                f"n={p['n']} {p['collective']}"
            )
        write_json_out()
        print("smoke OK: warm engine calls never retrace and stay >=3x the "
              f"cold interpreter; fused mm+RS {best_fused:.2f}x >= 1.3x")
        return

    assert min(p["speedup"] for p in engine_points) >= 3.0, (
        "acceptance: >=3x warm-engine speedup at every point",
        [(p["n"], p["collective"], round(p["speedup"], 1))
         for p in engine_points],
    )
    write_json_out()
    Path(args.out).write_text(json.dumps({"points": points, "smoke": False}, indent=2) + "\n")
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
