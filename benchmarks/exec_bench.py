"""Execution-engine benchmark: cold per-call interpreter vs cached engine.

Times repeated same-shape collectives two ways per (n, collective) point,
on forced CPU host devices:

* **interpreter (cold)** — the pre-PR dispatch: every call builds a fresh
  ``jit(shard_map(...))`` around the per-round reference interpreter
  (``execute_schedule_reference`` + dense all-to-all state), re-deriving
  every round table in Python and re-tracing/compiling — what a serving
  or eval loop paid whenever XLA's jit cache missed;
* **engine (warm)** — the compiled execution engine through the eager
  Communicator path: the first call traces once into the process-wide
  executable cache (fingerprint + shape + dtype + axis + groups key),
  every later call is a cache hit with **zero retraces** (asserted from
  ``exec_stats`` deltas, the deterministic regression guard).

Both legs are best-of-N so the minimum reflects deterministic work, and
the engine outputs are checked against the interpreter outputs before
timing (bit-identical).

Writes ``BENCH_exec.json``::

    {"points": [{n, collective, algorithm, rounds, round_groups,
                 interp_cold_s, engine_cold_s, engine_warm_s, speedup,
                 first_call_traces, second_call_retraces}, ...],
     "smoke": bool}

``--smoke`` (used by scripts/ci.sh) restricts to n = 8, asserts the
retrace guard plus a loose wall-clock bar, and skips the JSON write so a
CI run never clobbers the full numbers.
"""

import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=16 "
    + os.environ.get("XLA_FLAGS", "")
)

import argparse
import json
import time
from pathlib import Path
from typing import Dict, List

import jax
import numpy as np
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.api import PcclSession
from repro.comm import exec_engine
from repro.comm import primitives as prim
from repro.core import cost_model as cm

COLLECTIVES = ("reduce_scatter", "all_gather", "all_reduce", "all_to_all")
HW = cm.TPU_V5E_PHOTONIC


def _mesh(n):
    return compat.make_mesh((n,), ("x",), devices=jax.devices()[:n])


def _global_input(collective, n, rng):
    if collective == "all_gather":
        return rng.normal(size=(n, 64)).astype(np.float32)
    return rng.normal(size=(n, n * 64)).astype(np.float32)


def bench_point(n: int, collective: str, repeats: int = 3) -> Dict:
    rng = np.random.default_rng(n)
    X = _global_input(collective, n, rng)
    session = PcclSession(HW, thread_fabric=False)
    comm = session.communicator("x", n, backend="interp")
    mesh = _mesh(n)

    # resolve the exact schedule both legs will execute
    itemsize = X.dtype.itemsize
    local = X[0]
    if collective == "all_gather":
        nbytes = local.size * itemsize * n
    else:
        nbytes = local.size * itemsize
    sched = comm.axis_schedule(collective, nbytes)

    def fresh_interpreter():
        """One *cold* interpreter call: new jit wrapper, full retrace."""
        fn = jax.jit(
            compat.shard_map(
                lambda x: prim.run_reference(collective, x[0], sched, "x")[None],
                mesh=mesh, in_specs=P("x", None), out_specs=P("x", None),
                check_vma=False,
            )
        )
        t0 = time.perf_counter()
        out = jax.block_until_ready(fn(X))
        return time.perf_counter() - t0, np.asarray(out)

    # --- engine: first (cold) call populates the executable cache
    exec_engine.clear_exec_caches()
    t0 = time.perf_counter()
    engine_out = np.asarray(jax.block_until_ready(comm.__getattribute__(collective)(X)))
    engine_cold_s = time.perf_counter() - t0
    s1 = exec_engine.exec_stats()

    # --- engine: warm calls (cache hit, zero retraces)
    engine_warm_s = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = jax.block_until_ready(getattr(comm, collective)(X))
        engine_warm_s = min(engine_warm_s, time.perf_counter() - t0)
    s2 = exec_engine.exec_stats()
    second_call_retraces = s2.traces - s1.traces
    assert s2.executable_hits >= repeats, (s2, repeats)
    np.testing.assert_array_equal(np.asarray(out), engine_out)

    # --- interpreter: every call cold (best-of-N)
    interp_cold_s = float("inf")
    for _ in range(repeats):
        dt, interp_out = fresh_interpreter()
        interp_cold_s = min(interp_cold_s, dt)
    np.testing.assert_array_equal(engine_out, interp_out)  # bit-identical

    compiled = exec_engine.compile_schedule(sched)
    return {
        "n": n,
        "collective": collective,
        "algorithm": sched.algorithm,
        "rounds": compiled.num_rounds,
        "round_groups": len(compiled.groups),
        "interp_cold_s": interp_cold_s,
        "engine_cold_s": engine_cold_s,
        "engine_warm_s": engine_warm_s,
        "speedup": interp_cold_s / engine_warm_s if engine_warm_s > 0 else float("inf"),
        "first_call_traces": s1.traces,
        "second_call_retraces": second_call_retraces,
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="n=8 only, assert guards, no default JSON write (CI)")
    ap.add_argument("--out", default="BENCH_exec.json")
    ap.add_argument("--json-out", default=None, metavar="PATH",
                    help="also write the JSON here (even under --smoke); "
                    "used by the CI bench gate")
    args = ap.parse_args()

    ns = (8,) if args.smoke else (8, 16)
    points: List[Dict] = []
    for n in ns:
        for coll in COLLECTIVES:
            p = bench_point(n, coll)
            points.append(p)
            print(
                f"n={p['n']:<3} {p['collective']:<15} ({p['algorithm']:<7}) "
                f"interp-cold {p['interp_cold_s']*1e3:8.1f} ms  "
                f"engine-warm {p['engine_warm_s']*1e3:7.2f} ms  "
                f"{p['speedup']:7.1f}x  "
                f"retraces {p['first_call_traces']}->{p['second_call_retraces']}  "
                f"rounds {p['rounds']}->{p['round_groups']} groups"
            )

    def write_json_out() -> None:
        # only after the guards: a failed smoke must not leave a fresh
        # artifact for the bench gate to score
        if args.json_out:
            Path(args.json_out).write_text(
                json.dumps({"points": points, "smoke": args.smoke}, indent=2) + "\n"
            )
            print(f"wrote {args.json_out}")

    # deterministic guard at every scale: a repeated same-shape collective
    # must never retrace after its first call
    for p in points:
        assert p["second_call_retraces"] == 0, (
            f"retrace regression at n={p['n']} {p['collective']}: "
            f"{p['second_call_retraces']} retraces on warm calls"
        )

    if args.smoke:
        # loose wall-clock bar (observed locally: 100-4000x); deliberately
        # far below the acceptance number so CI noise cannot flake it
        for p in points:
            assert p["speedup"] >= 3.0, (
                f"engine speedup regression: only {p['speedup']:.2f}x at "
                f"n={p['n']} {p['collective']}"
            )
        write_json_out()
        print("smoke OK: warm engine calls never retrace and stay >=3x the "
              "cold interpreter")
        return

    assert min(p["speedup"] for p in points) >= 3.0, (
        "acceptance: >=3x warm-engine speedup at every point",
        [(p["n"], p["collective"], round(p["speedup"], 1)) for p in points],
    )
    write_json_out()
    Path(args.out).write_text(json.dumps({"points": points, "smoke": False}, indent=2) + "\n")
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
