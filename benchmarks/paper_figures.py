"""One function per paper table/figure (assignment deliverable d).

Every function returns a list of CSV rows ``(name, value, derived)`` and is
invoked by ``benchmarks.run``.  Values are model-predicted times (µs) from
the extended α–β cost model / planner — the paper's own evaluation
methodology (§5: Eq. 1 with congestion & dilation; §6: FlexFlow-style graph
simulation).  Paper-claim checks are asserted where the text states numbers.

All planning goes through :class:`repro.api.PcclSession`.  The paper's
figures report *cold-start* collectives (each data point starts from the
named fabric G0), so sessions here disable fabric-state threading; the
end-to-end training figures (12–16, via ``taskgraph``) thread state across
the per-layer AllReduces like a real job would.
"""

from __future__ import annotations

import time
from typing import Dict, List, Tuple

import numpy as np

from repro.api import PcclSession
from repro.core import cost_model as cm
from repro.core import schedules as S
from repro.core import topology as T
from repro.core.circuits import MZIMesh, random_requests, route_circuits
from repro.core.fibers import random_demands, route_fibers, server_grid
from repro.core.planner import plan

from .taskgraph import CommScheme, Workload, simulate_training

HW = cm.H100_DGX  # α=3µs, β=1/450GB/s, r=5µs (§5)

Row = Tuple[str, float, str]

MB = 1024.0 ** 2
GB = 1024.0 ** 3


def _std(n: int) -> List[T.Topology]:
    return [T.ring(n), T.torus2d(*T.square_dims2(n))]


def _session(n: int, g0: T.Topology, hw: cm.HardwareParams = HW) -> PcclSession:
    """Cold-start session on fabric ``g0`` (figure data points are i.i.d.)."""
    return PcclSession(hw, g0=g0, standard_set=_std(n), thread_fabric=False)


def _topos(n: int) -> Dict[str, T.Topology]:
    t = T.standard_topologies(n)
    return {k: t[k] for k in ["ring", "torus2d", "torus3d", "grid2d", "grid3d"]}


def _baseline_algos(n: int, topo_name: str):
    a2, b2 = T.square_dims2(n)
    a3, b3, c3 = T.square_dims3(n)
    return {
        "ring": ("ring", None),
        "rhd": ("rhd", None),
        "swing": ("swing", None),
        "bucket2d": ("bucket2d", (a2, b2)),
        "bucket3d": ("bucket3d", (a3, b3, c3)),
    }


# ---------------------------------------------------------------- Figure 1
def fig1_alltoall_3d_torus() -> List[Row]:
    """AllToAll + AllReduce on a 4×4×4 torus: PCCL matches the torus-ideal
    bucket AllReduce and beats hypercube-DEX AllToAll ~7.5× (paper Fig. 1)."""
    n = 64
    topo = T.torus3d(4, 4, 4)
    # Fig. 1 does not state its buffer size; 16 MB (a typical MoE dispatch)
    # reproduces the paper's ~7.5× — the α·dilation cost of 63 direct-
    # exchange rounds on the torus vs PCCL's 6 contention-free DEX rounds.
    # The full size sweep is in fig7/fig10a.
    buf = 16 * MB
    rows: List[Row] = []
    session = _session(n, topo)

    direct_fixed = session.baseline("all_to_all", "direct", buf).total
    dex_fixed = session.baseline("all_to_all", "dex", buf).total
    pccl_a2a = session.plan("all_to_all", buf).cost
    rows.append(("fig1/alltoall_direct_on_3dtorus", direct_fixed * 1e6, "us"))
    rows.append(("fig1/alltoall_dex_on_3dtorus", dex_fixed * 1e6, "us"))
    rows.append(("fig1/alltoall_pccl", pccl_a2a * 1e6, "us"))
    speedup = direct_fixed / pccl_a2a
    rows.append(("fig1/alltoall_speedup", speedup, "x (paper: ~7.5x)"))
    assert 5.0 < speedup < 12.0, f"Fig.1 speedup out of band: {speedup}"
    assert pccl_a2a <= dex_fixed

    bucket = session.baseline("all_reduce", "bucket3d", buf, dims=(4, 4, 4)).total
    pccl_ar = session.plan("all_reduce", buf, algorithm="auto").cost
    rows.append(("fig1/allreduce_bucket3d", bucket * 1e6, "us"))
    rows.append(("fig1/allreduce_pccl", pccl_ar * 1e6, "us"))
    rows.append(("fig1/allreduce_ratio", bucket / pccl_ar, "x (paper: PCCL matches)"))
    assert pccl_ar <= bucket * 1.05
    return rows


# ---------------------------------------------------------------- Figure 7
def fig7_reduce_scatter_sweep(n: int = 128) -> List[Row]:
    """ReduceScatter across buffer sizes/topologies/algorithms @ r=5µs.
    Claims: PCCL ≤ every baseline on every topology (the only system optimal
    everywhere); up to 2.5× over the best baseline somewhere."""
    rows: List[Row] = []
    best_gain = 0.0
    bufs = [1 * MB, 32 * MB, 256 * MB, 1 * GB]
    for topo_name, topo in _topos(n).items():
        session = _session(n, topo)
        # one structure phase prices the whole buffer sweep (bit-identical
        # to per-size plan() calls; sessions don't thread fabric here)
        pccl_plans = session.plan_sweep("reduce_scatter", bufs, algorithm="auto")
        for buf, pccl_plan in zip(bufs, pccl_plans):
            pccl = pccl_plan.cost
            rows.append(
                (f"fig7/{topo_name}/{int(buf/MB)}MB/pccl", pccl * 1e6, "us")
            )
            for algo, (aname, dims) in _baseline_algos(n, topo_name).items():
                c = session.baseline("reduce_scatter", aname, buf, dims=dims).total
                rows.append(
                    (f"fig7/{topo_name}/{int(buf/MB)}MB/{algo}", c * 1e6, "us")
                )
                assert pccl <= c * 1.001, (
                    f"PCCL beaten by {algo} on {topo_name} @ {buf/MB}MB: {pccl} vs {c}"
                )  # optimal everywhere: auto mode may adopt the baseline schedule
                best_gain = max(best_gain, c / pccl)
    rows.append(("fig7/max_speedup_vs_best_known", best_gain, "x (paper: up to 2.5x+)"))
    return rows


# ----------------------------------------------------------- Figures 17/18
def fig17_18_smaller_domains() -> List[Row]:
    """Appendix Figs. 17/18: the Fig. 7 ReduceScatter sweep at 64 and 32
    GPUs — same trends, PCCL optimal everywhere."""
    rows: List[Row] = []
    for n, tag in [(64, "fig17"), (32, "fig18")]:
        for r in fig7_reduce_scatter_sweep(n):
            rows.append((r[0].replace("fig7", tag), r[1], r[2]))
    return rows


# -------------------------------------------------------------- Figures 8/9
def fig8_9_breakdown() -> List[Row]:
    """Cost breakdown @128 GPUs: 256 MB/r=5µs (reconfigures log2 N = 7×) and
    1 GB/r=1 ms (reconfigures < 7×, trades congestion for reconfig)."""
    n = 128
    rows: List[Row] = []
    for tag, buf, hw in [
        ("fig8_256MB_5us", 256 * MB, HW),
        ("fig9_1GB_1ms", 1 * GB, cm.H100_DGX_R1MS),
    ]:
        for topo_name, topo in _topos(n).items():
            session = _session(n, topo, hw)
            p = session.plan("reduce_scatter", buf)
            b = p.breakdown()
            for k in ("alpha", "beta", "dilation", "congestion", "reconfig"):
                rows.append((f"{tag}/{topo_name}/pccl/{k}", b[k] * 1e6, "us"))
            rows.append(
                (f"{tag}/{topo_name}/pccl/n_reconfigs", p.num_reconfigs, "count")
            )
            rs = session.baseline("reduce_scatter", "ring", buf)
            for k, v in rs.breakdown().items():
                if k != "total":
                    rows.append((f"{tag}/{topo_name}/ring/{k}", v * 1e6, "us"))
    # headline claims
    p5 = _session(n, T.ring(n)).plan("reduce_scatter", 256 * MB)
    assert p5.num_reconfigs == 7, p5.num_reconfigs
    p1ms = _session(n, T.ring(n), cm.H100_DGX_R1MS).plan("reduce_scatter", 1 * GB)
    assert p1ms.num_reconfigs < 7
    rows.append(("fig8/reconfigs_at_5us", p5.num_reconfigs, "count (paper: 7)"))
    rows.append(("fig9/reconfigs_at_1ms", p1ms.num_reconfigs, "count (paper: ~4)"))
    return rows


# --------------------------------------------------------------- Figure 10a
def fig10a_alltoall_32mb() -> List[Row]:
    """AllToAll 32 MB @128 GPUs, r=5µs: PCCL (DEX input schedule) beats DEX
    on every fixed topology."""
    n, buf = 128, 32 * MB
    rows: List[Row] = []
    for topo_name, topo in _topos(n).items():
        session = _session(n, topo)
        dex = session.baseline("all_to_all", "dex", buf).total
        pccl = session.plan("all_to_all", buf).cost
        rows.append((f"fig10a/{topo_name}/dex", dex * 1e6, "us"))
        rows.append((f"fig10a/{topo_name}/pccl", pccl * 1e6, "us"))
        assert pccl <= dex * 1.001, topo_name
    return rows


# --------------------------------------------------------------- Figure 10b
def fig10b_bert_allreduce_buffers() -> List[Row]:
    """AllReduce buffer-size histogram of the paper's transformer (§6):
    per-layer gradient buckets span latency-sensitive (~1 MB) to
    BW-sensitive (~64 MB)."""
    wl = Workload()
    rows: List[Row] = []
    # per-layer buckets: qkv+o (4d²), mlp (8d²), embeddings
    d = wl.d_model
    buckets = {
        "attn_grad": 4 * d * d * 4,
        "mlp_grad": 8 * d * d * 4,
        "embed_grad": wl.vocab * d * 4,
        "lnorm_grad": 2 * d * 4,
    }
    for k, v in buckets.items():
        rows.append((f"fig10b/{k}", v / MB, "MB"))
    lo, hi = min(buckets.values()) / MB, max(buckets.values()) / MB
    assert lo < 1.0 and hi > 30.0  # paper: 1 MB .. 64 MB span
    return rows


# ------------------------------------------------------------ Figures 12-16
def fig12_16_end_to_end(ns=(32, 64, 128)) -> List[Row]:
    """Training throughput of the §6 transformer across cluster sizes and
    reconfiguration delays.  Claims: PCCL ≥ ideal algorithm per topology;
    beats everything on grids; outperforms ring-on-ring (log α); ≥1.3×
    somewhere vs a deployed baseline algorithm."""
    wl = Workload()
    rows: List[Row] = []
    max_vs_baseline = 0.0
    for n in ns:
        topos = _topos(n)
        for r_us, tag in [(5, "fig12"), (10, "fig13"), (25, "fig14"),
                          (50, "fig15"), (500, "fig16")]:
            hw = HW.with_reconfig(r_us * 1e-6)
            for topo_name, topo in topos.items():
                pccl = simulate_training(wl, CommScheme("pccl", "pccl"), topo, hw)
                rows.append(
                    (f"{tag}/n{n}/{topo_name}/pccl", pccl.throughput, "samples_per_s")
                )
                for algo, (aname, dims) in _baseline_algos(n, topo_name).items():
                    base = simulate_training(
                        wl, CommScheme(algo, "fixed", aname, dims), topo, hw
                    )
                    rows.append(
                        (f"{tag}/n{n}/{topo_name}/{algo}", base.throughput, "samples_per_s")
                    )
                    if tag == "fig12":
                        assert pccl.throughput >= base.throughput * 0.999, (
                            n, topo_name, algo
                        )
                        max_vs_baseline = max(
                            max_vs_baseline, pccl.throughput / base.throughput
                        )
    rows.append(
        ("fig12/max_throughput_gain", max_vs_baseline, "x (paper: up to 1.3x e2e)")
    )
    assert max_vs_baseline >= 1.25, max_vs_baseline
    return rows


# --------------------------------------------------------------- Figure 19a
def fig19a_circuit_routing() -> List[Row]:
    """Algorithm 3 routing time on MZI meshes (paper: <2.5 s on 256×256)."""
    rows: List[Row] = []
    for size, k in [(64, 16), (128, 16), (256, 16)]:
        mesh = MZIMesh(size, size)
        reqs = random_requests(mesh, k, n_wavelengths=4, seed=0)
        res = route_circuits(mesh, reqs)
        rows.append((f"fig19a/{size}x{size}/{k}circuits", res.elapsed_s, "s"))
        assert not res.failed
        if size == 256:
            assert res.elapsed_s < 2.5
    return rows


# ------------------------------------------------------------- fibers table
def tab_fibers() -> List[Row]:
    """§4.2: 64-server grid needs ≤7 fibers for 100 circuits, ≤31 for 512."""
    topo = server_grid(64)
    rows: List[Row] = []
    for k, bound in [(100, 7), (512, 31)]:
        r = route_fibers(topo, random_demands(topo, k, seed=0))
        rows.append((f"fibers/64servers/{k}circuits", r.z, f"fibers (paper: <={bound})"))
        rows.append((f"fibers/64servers/{k}circuits_time", r.elapsed_s, "s (paper: <10s)"))
        assert r.z <= bound and r.elapsed_s < 10.0
    return rows


# ----------------------------------------------- overlap-aware reconfiguration
def sweep_overlap_reconfig() -> List[Row]:
    """Serial vs partial (per-link) vs overlapped reconfiguration planning:
    r ∈ {5 µs … 1 ms} × both topology families (ring / torus2d) × all four
    collectives × small and large buffers.

    ``r_link`` is scaled so a full-fabric swap (≈4n changed directed
    circuits: tear down one bidirectional fabric, set up another) costs the
    full ``r`` — partial reconfiguration only wins when link sets overlap.
    Model guarantee checked pointwise: overlap ≤ partial ≤ serial (same
    exact planner over pointwise-cheaper transition costs).  The MEMS-class
    regime (r ≥ 500 µs) must show a strict overlapped win somewhere — that's
    the SWOT headline this cost model exists to reproduce."""
    n = 16
    rows: List[Row] = []
    collectives = ["reduce_scatter", "all_gather", "all_reduce", "all_to_all"]
    topos = {"ring": T.ring(n), "torus2d": T.torus2d(*T.square_dims2(n))}
    best_mems_gain = 0.0
    for r_us in (5, 50, 500, 1000):
        r = r_us * 1e-6
        serial_hw = HW.with_reconfig(r)
        r_link = r / (4 * n)
        modes = {
            "serial": serial_hw,
            "partial": serial_hw.with_link_reconfig(r_link),
            "overlap": serial_hw.with_link_reconfig(r_link, overlap=True),
        }
        for topo_name, topo in topos.items():
            for coll in collectives:
                bufs = (1 * MB, 256 * MB)
                # per mode, both buffer sizes come out of one plan_sweep
                # (bit-identical to per-size plan() on these cold sessions)
                per_mode = {
                    mode: [
                        p.cost
                        for p in _session(n, topo, hw).plan_sweep(
                            coll, bufs, algorithm="auto"
                        )
                    ]
                    for mode, hw in modes.items()
                }
                for bi, buf in enumerate(bufs):
                    costs = {mode: per_mode[mode][bi] for mode in modes}
                    for mode in modes:
                        rows.append((
                            f"overlap/r{r_us}us/{topo_name}/{coll}/{int(buf/MB)}MB/{mode}",
                            costs[mode] * 1e6,
                            "us",
                        ))
                    assert costs["partial"] <= costs["serial"] * (1 + 1e-9), (
                        r_us, topo_name, coll, buf, costs
                    )
                    assert costs["overlap"] <= costs["partial"] * (1 + 1e-9), (
                        r_us, topo_name, coll, buf, costs
                    )
                    if r_us >= 500:
                        best_mems_gain = max(
                            best_mems_gain, costs["serial"] / costs["overlap"]
                        )
    rows.append((
        "overlap/max_speedup_mems", best_mems_gain, "x serial/overlap @ r>=500us"
    ))
    assert best_mems_gain > 1.001, (
        f"no strict overlapped win in the MEMS regime: {best_mems_gain}"
    )
    return rows


# ------------------------------------------------------------ planner speed
def tab_planner_runtime() -> List[Row]:
    """§4.1: planner solves the largest scale-up domains in <1 s."""
    rows: List[Row] = []
    for n in (32, 64, 128, 256, 512, 1024):
        topo = T.ring(n)
        sched = S.rhd_all_reduce(n, 256 * MB)
        t0 = time.perf_counter()
        plan(topo, _std(n), sched, HW)
        dt = time.perf_counter() - t0
        rows.append((f"planner/n{n}/rhd_allreduce", dt, "s (paper: <1s)"))
    assert dt < 1.0
    return rows


ALL_FIGURES = [
    ("fig1", fig1_alltoall_3d_torus),
    ("fig7", fig7_reduce_scatter_sweep),
    ("fig17_18", fig17_18_smaller_domains),
    ("fig8_9", fig8_9_breakdown),
    ("fig10a", fig10a_alltoall_32mb),
    ("fig10b", fig10b_bert_allreduce_buffers),
    ("fig12_16", fig12_16_end_to_end),
    ("fig19a", fig19a_circuit_routing),
    ("fibers", tab_fibers),
    ("overlap_sweep", sweep_overlap_reconfig),
    ("planner", tab_planner_runtime),
]
