"""Planner performance benchmark: cold per-size planning vs ``plan_sweep``.

Times an 8-point buffer sweep (1 MB → 1 GB, three orders of magnitude — the
span of the paper's Figs. 8–10) two ways per (n, collective) point:

* **naive loop** — one cold ``plan_collective`` per size: the caches this
  PR introduced (structure table, linear labels, transition memo) are
  cleared before each plan, reproducing the pre-split planner that
  re-derived routing factors at every ``plan()`` call.  The shortest-path
  cache (``_SP_CACHE``) predates the split and always persisted across
  ``plan()`` calls, so it stays warm — the baseline is not billed for work
  the old planner amortized;
* **sweep** — one ``plan_collective_sweep`` over all sizes under the same
  cache regime: a single size-independent structure phase prices every
  size in one batched numeric pass.

Both must return bit-identical plans (checked).  Also reports single-plan
cold latency for the planner's heaviest query — direct AllToAll at n = 128
(127 rounds × ~130 candidate states) — against the paper's §4.1 one-second
budget, after a warm-up plan so library/numpy initialisation is not billed
to the planner (the paper's claim is about a running system).

Also benchmarks the **hierarchical** path (this PR): cold two-level plans at
n = 256/512/1024 against the paper's §4.1 one-second budget, stitched-cost
quality (flat-vs-hier ratio) at n ≤ 128 where the flat exact DP is still
tractable, and the **warm replan** path — a single-link failure repriced
through ``PcclSession.replan`` must beat a cold plan of the degraded fabric
by ≥10×.

Writes ``BENCH_planner.json``:

    {"sweep_points": [{n, collective, sizes_mb, loop_s, sweep_s, speedup,
                       loop_routing_calls, sweep_routing_calls}, ...],
     "hier_points": [{n, collective, algorithm, pod_size, hier_cold_s,
                      cost_ratio?}, ...],
     "replan": {n, collective, algorithm, cold_s, replan_s, replan_speedup},
     "n128_direct_alltoall_plan_s": float,
     "smoke": bool}

``--smoke`` (used by scripts/ci.sh) restricts to n = 16 sweeps plus one
n = 256 hierarchical point, asserts the regression guards, and skips the
default JSON write so a CI run never clobbers the full numbers.
"""

from __future__ import annotations

import argparse
import gc
import json
import time
from dataclasses import replace
from pathlib import Path
from typing import Dict, List

from repro.api.session import PcclSession
from repro.core import cost_model as cm
from repro.core import topology as T
from repro.core.pccl import (
    CollectiveRequest,
    default_standard_set,
    plan_collective,
    plan_collective_hierarchical,
    plan_collective_sweep,
)
from repro.core.planner import clear_planner_caches

MB = 1024.0 ** 2
SIZES_MB = (1, 2, 8, 32, 64, 128, 512, 1024)  # 8 points, 1 MB → 1 GB
COLLECTIVES = ("reduce_scatter", "all_gather", "all_reduce", "all_to_all")
HW = cm.H100_DGX


def _plans_equal(a, b) -> bool:
    """Bit-identical: same algorithm, same totals, same step sequence."""
    return (
        a.algorithm == b.algorithm
        and a.cost == b.cost
        and [s.state_idx for s in a.plan.steps] == [s.state_idx for s in b.plan.steps]
        and [s.total for s in a.plan.steps] == [s.total for s in b.plan.steps]
    )


def bench_point(n: int, collective: str, repeats: int = 3) -> Dict:
    g0 = T.ring(n)
    sizes = [m * MB for m in SIZES_MB]
    req = CollectiveRequest(collective, n, sizes[0], algorithm="paper_default")

    # best-of-N: each leg is deterministic work, so the minimum is the true
    # cost and the comparison survives noisy-neighbor/GC interference
    loop_s = float("inf")
    loop_plans = None
    loop_routing = 0
    for _ in range(repeats):
        plans, total, routing = [], 0.0, 0
        for d in sizes:
            # pre-split behavior: every plan re-derives routing factors
            clear_planner_caches(keep_shortest_paths=True)
            t0 = time.perf_counter()
            plans.append(plan_collective(replace(req, buffer_bytes=d), g0, HW))
            total += time.perf_counter() - t0
            routing += cm.STRUCTURE_TABLE.stats.routing_calls
        if total < loop_s:
            loop_s = total
        loop_plans = plans
        loop_routing = routing

    sweep_s = float("inf")
    sweep_plans = None
    sweep_routing = 0
    for _ in range(repeats):
        clear_planner_caches(keep_shortest_paths=True)
        t0 = time.perf_counter()
        sweep_plans = plan_collective_sweep(req, sizes, g0, HW)
        sweep_s = min(sweep_s, time.perf_counter() - t0)
        sweep_routing = cm.STRUCTURE_TABLE.stats.routing_calls

    identical = all(_plans_equal(a, b) for a, b in zip(loop_plans, sweep_plans))
    assert identical, f"sweep != loop at n={n} {collective}"
    return {
        "n": n,
        "collective": collective,
        "sizes_mb": list(SIZES_MB),
        "loop_s": loop_s,
        "sweep_s": sweep_s,
        "speedup": loop_s / sweep_s if sweep_s > 0 else float("inf"),
        "loop_routing_calls": loop_routing,
        "sweep_routing_calls": sweep_routing,
    }


#: hierarchical bench matrix: the planner's cheapest and heaviest schedules
HIER_CASES = (("all_reduce", "ring"), ("all_to_all", "direct"))


def bench_hier_point(
    n: int,
    collective: str,
    algorithm: str,
    repeats: int = 3,
    with_ratio: bool = False,
) -> Dict:
    """Cold two-level plan wall-clock (best-of-N); optionally the stitched
    cost vs the flat exact DP (only tractable at n <= 128)."""
    g0 = T.ring(n)
    req = CollectiveRequest(collective, n, 32 * MB, algorithm=algorithm)
    pod_size = len(T.derive_pods(n)[0])

    best = float("inf")
    hier = None
    for _ in range(repeats):
        clear_planner_caches()
        t0 = time.perf_counter()
        hier = plan_collective_hierarchical(req, g0, HW)
        best = min(best, time.perf_counter() - t0)

    point: Dict = {
        "n": n,
        "collective": collective,
        "algorithm": algorithm,
        "pod_size": pod_size,
        "hier_cold_s": best,
    }
    if with_ratio:
        flat = plan_collective(req, g0, HW)
        point["cost_ratio"] = hier.cost / flat.cost
    return point


def bench_replan(repeats: int = 3) -> Dict:
    """Warm ``PcclSession.replan`` of a single dead link vs a cold plan of
    the degraded fabric — the planner's heaviest query (direct AllToAll at
    n = 128) so the structure phase dominates the cold side."""
    n, collective, algorithm = 128, "all_to_all", "direct"
    fe = ((0, 1), (1, 0))
    d_g0 = T.degrade_topology(T.ring(n), fe)
    d_std = [T.degrade_topology(t, fe) for t in default_standard_set(n)]
    req = CollectiveRequest(collective, n, 32 * MB, algorithm=algorithm)

    # cold and warm repeats interleave (load drift hits both legs alike) and
    # run GC-quiesced: the ~20 ms warm leg is small enough that a single
    # collection pause would dominate the ratio the acceptance gate asserts
    cold_s = float("inf")
    replan_s = float("inf")
    for _ in range(max(repeats, 5)):
        clear_planner_caches()
        gc.collect()
        gc.disable()
        try:
            t0 = time.perf_counter()
            plan_collective(req, d_g0, HW, standard=d_std)
            cold_s = min(cold_s, time.perf_counter() - t0)
        finally:
            gc.enable()

        clear_planner_caches()
        session = PcclSession(HW, g0=T.ring(n), thread_fabric=False)
        session.plan(collective, 32 * MB, algorithm=algorithm)  # warm structures
        gc.collect()
        gc.disable()
        try:
            t0 = time.perf_counter()
            session.replan(
                collective, 32 * MB, algorithm=algorithm, failed_edges=[(0, 1)]
            )
            replan_s = min(replan_s, time.perf_counter() - t0)
        finally:
            gc.enable()

    return {
        "n": n,
        "collective": collective,
        "algorithm": algorithm,
        "cold_s": cold_s,
        "replan_s": replan_s,
        "replan_speedup": cold_s / replan_s if replan_s > 0 else float("inf"),
    }


def bench_single_plan_latency(repeats: int = 3) -> float:
    """Cold direct-AllToAll plan at n = 128 (§4.1 <1 s budget); best-of-N."""
    req = CollectiveRequest("all_to_all", 128, 32 * MB, algorithm="direct")
    g0 = T.ring(128)
    plan_collective(req, g0, HW)  # warm numpy/scipy; planner caches cleared next
    best = float("inf")
    for _ in range(repeats):
        clear_planner_caches()
        t0 = time.perf_counter()
        plan_collective(req, g0, HW)
        best = min(best, time.perf_counter() - t0)
    return best


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="n=16 only, assert guards, no default JSON write (CI)")
    ap.add_argument("--out", default="BENCH_planner.json")
    ap.add_argument("--json-out", default=None, metavar="PATH",
                    help="also write the JSON here (even under --smoke); "
                    "used by the CI bench gate")
    args = ap.parse_args()

    # warm library imports (scipy, numpy ufunc setup) so neither side of the
    # comparison is billed for one-time process initialisation
    plan_collective(
        CollectiveRequest("reduce_scatter", 8, MB, algorithm="paper_default"),
        T.ring(8), HW,
    )

    ns = (16,) if args.smoke else (16, 64, 128)
    points: List[Dict] = []
    for n in ns:
        for coll in COLLECTIVES:
            p = bench_point(n, coll)
            points.append(p)
            print(
                f"n={p['n']:<4} {p['collective']:<15} "
                f"loop {p['loop_s']*1e3:8.1f} ms  sweep {p['sweep_s']*1e3:7.1f} ms  "
                f"{p['speedup']:5.1f}x   routing {p['loop_routing_calls']} -> "
                f"{p['sweep_routing_calls']}"
            )

    hier_points: List[Dict] = []
    if args.smoke:
        # one n=256 hierarchical point per case: proves the scaling path
        # stays alive in CI without paying the full 1024-rank matrix
        for coll, algo in HIER_CASES:
            hp = bench_hier_point(256, coll, algo)
            hier_points.append(hp)
            print(
                f"n={hp['n']:<4} {hp['collective']:<15} hier(pod={hp['pod_size']}) "
                f"cold {hp['hier_cold_s']*1e3:7.1f} ms"
            )
    else:
        for n in (64, 128):
            for coll, algo in HIER_CASES:
                hp = bench_hier_point(n, coll, algo, with_ratio=True)
                hier_points.append(hp)
                print(
                    f"n={hp['n']:<4} {hp['collective']:<15} "
                    f"hier(pod={hp['pod_size']}) cold "
                    f"{hp['hier_cold_s']*1e3:7.1f} ms  cost ratio "
                    f"{hp['cost_ratio']:.3f}"
                )
        for n in (256, 512, 1024):
            for coll, algo in HIER_CASES:
                hp = bench_hier_point(n, coll, algo)
                hier_points.append(hp)
                print(
                    f"n={hp['n']:<4} {hp['collective']:<15} "
                    f"hier(pod={hp['pod_size']}) cold "
                    f"{hp['hier_cold_s']*1e3:7.1f} ms"
                )

    result: Dict = {
        "sweep_points": points,
        "hier_points": hier_points,
        "smoke": args.smoke,
    }

    def write_json_out() -> None:
        # only after the guards: a failed smoke must not leave a fresh
        # artifact for the bench gate to score
        if args.json_out:
            Path(args.json_out).write_text(json.dumps(result, indent=2) + "\n")
            print(f"wrote {args.json_out}")

    if args.smoke:
        # regression guards.  The deterministic one is the routing-call
        # count (the sweep must reuse one structure phase); the wall-clock
        # bars are deliberately loose so a noisy CI runner can't flake them
        # (observed locally: 3.7–10x sweeps, 60–120 ms n=256 hier plans).
        for p in points:
            assert p["sweep_routing_calls"] * 2 <= p["loop_routing_calls"], (
                f"structure phase not amortized at n={p['n']} "
                f"{p['collective']}: {p['sweep_routing_calls']} vs "
                f"{p['loop_routing_calls']} routing calls"
            )
            assert p["speedup"] >= 1.3, (
                f"plan_sweep regression: only {p['speedup']:.2f}x at "
                f"n={p['n']} {p['collective']}"
            )
        for hp in hier_points:
            assert hp["hier_cold_s"] < 1.5, (
                f"n={hp['n']} {hp['collective']} hierarchical cold plan took "
                f"{hp['hier_cold_s']:.2f}s (smoke bar 1.5s)"
            )
        write_json_out()
        print("smoke OK: sweeps amortize routing, n=256 hierarchical plans "
              "stay inside the wall-clock bar")
        return

    latency = bench_single_plan_latency()
    result["n128_direct_alltoall_plan_s"] = latency
    print(f"n=128 direct all_to_all cold plan: {latency*1e3:.1f} ms")

    rp = bench_replan()
    result["replan"] = rp
    print(
        f"n={rp['n']} {rp['collective']} warm replan "
        f"{rp['replan_s']*1e3:.1f} ms vs cold {rp['cold_s']*1e3:.1f} ms "
        f"({rp['replan_speedup']:.1f}x)"
    )

    n64 = [p for p in points if p["n"] == 64]
    assert min(p["speedup"] for p in n64) >= 5.0, (
        "acceptance: >=5x sweep speedup at n=64",
        [(p["collective"], p["speedup"]) for p in n64],
    )
    assert latency < 1.0, f"n=128 direct a2a plan took {latency:.2f}s (budget 1s)"
    # acceptance: the scaling path holds the paper's 1 s budget at n=1024,
    # stays within 10% of the flat exact DP where that is still tractable,
    # and faults reprice an order of magnitude faster than cold planning
    for hp in hier_points:
        if hp["n"] == 1024:
            assert hp["hier_cold_s"] < 1.0, (
                f"n=1024 {hp['collective']} hierarchical cold plan took "
                f"{hp['hier_cold_s']:.2f}s (budget 1s)"
            )
        if "cost_ratio" in hp:
            assert hp["cost_ratio"] <= 1.1, (
                f"n={hp['n']} {hp['collective']} stitched cost is "
                f"{hp['cost_ratio']:.3f}x flat (bar 1.1x)"
            )
    assert rp["replan_speedup"] >= 10.0, (
        f"warm replan only {rp['replan_speedup']:.1f}x faster than cold "
        f"(acceptance 10x)"
    )

    write_json_out()
    Path(args.out).write_text(json.dumps(result, indent=2) + "\n")
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
