"""Planner performance benchmark: cold per-size planning vs ``plan_sweep``.

Times an 8-point buffer sweep (1 MB → 1 GB, three orders of magnitude — the
span of the paper's Figs. 8–10) two ways per (n, collective) point:

* **naive loop** — one cold ``plan_collective`` per size: the caches this
  PR introduced (structure table, linear labels, transition memo) are
  cleared before each plan, reproducing the pre-split planner that
  re-derived routing factors at every ``plan()`` call.  The shortest-path
  cache (``_SP_CACHE``) predates the split and always persisted across
  ``plan()`` calls, so it stays warm — the baseline is not billed for work
  the old planner amortized;
* **sweep** — one ``plan_collective_sweep`` over all sizes under the same
  cache regime: a single size-independent structure phase prices every
  size in one batched numeric pass.

Both must return bit-identical plans (checked).  Also reports single-plan
cold latency for the planner's heaviest query — direct AllToAll at n = 128
(127 rounds × ~130 candidate states) — against the paper's §4.1 one-second
budget, after a warm-up plan so library/numpy initialisation is not billed
to the planner (the paper's claim is about a running system).

Writes ``BENCH_planner.json``:

    {"sweep_points": [{n, collective, sizes_mb, loop_s, sweep_s, speedup,
                       loop_routing_calls, sweep_routing_calls}, ...],
     "n128_direct_alltoall_plan_s": float,
     "smoke": bool}

``--smoke`` (used by scripts/ci.sh) restricts to n = 16, asserts the
regression guards, and skips the JSON write so a CI run never clobbers the
full numbers.
"""

from __future__ import annotations

import argparse
import json
import time
from dataclasses import replace
from pathlib import Path
from typing import Dict, List

from repro.core import cost_model as cm
from repro.core import topology as T
from repro.core.pccl import CollectiveRequest, plan_collective, plan_collective_sweep
from repro.core.planner import clear_planner_caches

MB = 1024.0 ** 2
SIZES_MB = (1, 2, 8, 32, 64, 128, 512, 1024)  # 8 points, 1 MB → 1 GB
COLLECTIVES = ("reduce_scatter", "all_gather", "all_reduce", "all_to_all")
HW = cm.H100_DGX


def _plans_equal(a, b) -> bool:
    """Bit-identical: same algorithm, same totals, same step sequence."""
    return (
        a.algorithm == b.algorithm
        and a.cost == b.cost
        and [s.state_idx for s in a.plan.steps] == [s.state_idx for s in b.plan.steps]
        and [s.total for s in a.plan.steps] == [s.total for s in b.plan.steps]
    )


def bench_point(n: int, collective: str, repeats: int = 3) -> Dict:
    g0 = T.ring(n)
    sizes = [m * MB for m in SIZES_MB]
    req = CollectiveRequest(collective, n, sizes[0], algorithm="paper_default")

    # best-of-N: each leg is deterministic work, so the minimum is the true
    # cost and the comparison survives noisy-neighbor/GC interference
    loop_s = float("inf")
    loop_plans = None
    loop_routing = 0
    for _ in range(repeats):
        plans, total, routing = [], 0.0, 0
        for d in sizes:
            # pre-split behavior: every plan re-derives routing factors
            clear_planner_caches(keep_shortest_paths=True)
            t0 = time.perf_counter()
            plans.append(plan_collective(replace(req, buffer_bytes=d), g0, HW))
            total += time.perf_counter() - t0
            routing += cm.STRUCTURE_TABLE.stats.routing_calls
        if total < loop_s:
            loop_s = total
        loop_plans = plans
        loop_routing = routing

    sweep_s = float("inf")
    sweep_plans = None
    sweep_routing = 0
    for _ in range(repeats):
        clear_planner_caches(keep_shortest_paths=True)
        t0 = time.perf_counter()
        sweep_plans = plan_collective_sweep(req, sizes, g0, HW)
        sweep_s = min(sweep_s, time.perf_counter() - t0)
        sweep_routing = cm.STRUCTURE_TABLE.stats.routing_calls

    identical = all(_plans_equal(a, b) for a, b in zip(loop_plans, sweep_plans))
    assert identical, f"sweep != loop at n={n} {collective}"
    return {
        "n": n,
        "collective": collective,
        "sizes_mb": list(SIZES_MB),
        "loop_s": loop_s,
        "sweep_s": sweep_s,
        "speedup": loop_s / sweep_s if sweep_s > 0 else float("inf"),
        "loop_routing_calls": loop_routing,
        "sweep_routing_calls": sweep_routing,
    }


def bench_single_plan_latency(repeats: int = 3) -> float:
    """Cold direct-AllToAll plan at n = 128 (§4.1 <1 s budget); best-of-N."""
    req = CollectiveRequest("all_to_all", 128, 32 * MB, algorithm="direct")
    g0 = T.ring(128)
    plan_collective(req, g0, HW)  # warm numpy/scipy; planner caches cleared next
    best = float("inf")
    for _ in range(repeats):
        clear_planner_caches()
        t0 = time.perf_counter()
        plan_collective(req, g0, HW)
        best = min(best, time.perf_counter() - t0)
    return best


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="n=16 only, assert guards, no default JSON write (CI)")
    ap.add_argument("--out", default="BENCH_planner.json")
    ap.add_argument("--json-out", default=None, metavar="PATH",
                    help="also write the JSON here (even under --smoke); "
                    "used by the CI bench gate")
    args = ap.parse_args()

    # warm library imports (scipy, numpy ufunc setup) so neither side of the
    # comparison is billed for one-time process initialisation
    plan_collective(
        CollectiveRequest("reduce_scatter", 8, MB, algorithm="paper_default"),
        T.ring(8), HW,
    )

    ns = (16,) if args.smoke else (16, 64, 128)
    points: List[Dict] = []
    for n in ns:
        for coll in COLLECTIVES:
            p = bench_point(n, coll)
            points.append(p)
            print(
                f"n={p['n']:<4} {p['collective']:<15} "
                f"loop {p['loop_s']*1e3:8.1f} ms  sweep {p['sweep_s']*1e3:7.1f} ms  "
                f"{p['speedup']:5.1f}x   routing {p['loop_routing_calls']} -> "
                f"{p['sweep_routing_calls']}"
            )

    result: Dict = {"sweep_points": points, "smoke": args.smoke}

    def write_json_out() -> None:
        # only after the guards: a failed smoke must not leave a fresh
        # artifact for the bench gate to score
        if args.json_out:
            Path(args.json_out).write_text(json.dumps(result, indent=2) + "\n")
            print(f"wrote {args.json_out}")

    if args.smoke:
        # regression guards.  The deterministic one is the routing-call
        # count (the sweep must reuse one structure phase); the wall-clock
        # bar is deliberately loose so a noisy CI runner can't flake it
        # (observed locally: 3.7–10x).
        for p in points:
            assert p["sweep_routing_calls"] * 2 <= p["loop_routing_calls"], (
                f"structure phase not amortized at n={p['n']} "
                f"{p['collective']}: {p['sweep_routing_calls']} vs "
                f"{p['loop_routing_calls']} routing calls"
            )
            assert p["speedup"] >= 1.3, (
                f"plan_sweep regression: only {p['speedup']:.2f}x at "
                f"n={p['n']} {p['collective']}"
            )
        write_json_out()
        print("smoke OK: sweeps amortize routing and stay faster than the loop")
        return

    latency = bench_single_plan_latency()
    result["n128_direct_alltoall_plan_s"] = latency
    print(f"n=128 direct all_to_all cold plan: {latency*1e3:.1f} ms")

    n64 = [p for p in points if p["n"] == 64]
    assert min(p["speedup"] for p in n64) >= 5.0, (
        "acceptance: >=5x sweep speedup at n=64",
        [(p["collective"], p["speedup"]) for p in n64],
    )
    assert latency < 1.0, f"n=128 direct a2a plan took {latency:.2f}s (budget 1s)"

    write_json_out()
    Path(args.out).write_text(json.dumps(result, indent=2) + "\n")
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
