"""Render EXPERIMENTS.md tables from results/*.json.

  PYTHONPATH=src python -m benchmarks.report dryrun|roofline|perf [--dir ...]
"""

from __future__ import annotations

import argparse
import json
import pathlib

ROOT = pathlib.Path(__file__).resolve().parent.parent / "results"


def _load(d):
    return [json.loads(p.read_text()) for p in sorted(d.glob("*.json"))]


def dryrun_table(dirname="dryrun"):
    recs = _load(ROOT / dirname)
    print("| arch | shape | mesh | chips | compile_s | args GB/dev | "
          "collective ops (count) | status |")
    print("|---|---|---|---|---|---|---|---|")
    for r in recs:
        if r["status"] == "skipped":
            if r["mesh"] == "single":
                print(f"| {r['arch']} | {r['shape']} | both | — | — | — | — | "
                      f"SKIP: {r['reason'][:60]} |")
            continue
        if r["status"] == "error":
            print(f"| {r['arch']} | {r['shape']} | {r['mesh']} | — | — | — | — | ERROR |")
            continue
        mem = r.get("memory", {})
        args = mem.get("argument_size_in_bytes", 0) / 1e9
        cc = r["collectives"]["count_by_op"]
        ops = ", ".join(f"{k}:{v}" for k, v in sorted(cc.items()) if v)
        print(f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['chips']} | "
              f"{r['compile_s']:.1f} | {args:.2f} | {ops} | OK |")


def roofline_table(dirname="roofline"):
    recs = _load(ROOT / dirname)
    print("| arch | shape | compute (ms) | memory (ms) | collective (ms) | "
          "dominant | MODEL_FLOPS | HLO_FLOPS | useful |")
    print("|---|---|---|---|---|---|---|---|---|")
    for r in recs:
        if r["status"] != "ok":
            continue
        rl = r["roofline"]
        print(f"| {r['arch']} | {r['shape']} | {rl['compute_s']*1e3:.1f} | "
              f"{rl['memory_s']*1e3:.1f} | {rl['collective_s']*1e3:.1f} | "
              f"**{rl['dominant']}** | {r['model_flops']:.2e} | "
              f"{rl['flops']:.2e} | {r['useful_ratio']:.3f} |")


def perf_table():
    recs = _load(ROOT / "perf")
    print("| variant | compute (ms) | memory (ms) | collective (ms) | dominant | useful |")
    print("|---|---|---|---|---|---|")
    for r in recs:
        if r.get("status") == "error":
            print(f"| {r['variant']} | ERROR | | | | |")
            continue
        rl = r["roofline"]
        print(f"| {r['variant']} | {rl['compute_s']*1e3:.1f} | "
              f"{rl['memory_s']*1e3:.1f} | {rl['collective_s']*1e3:.1f} | "
              f"{rl['dominant']} | {r['useful_ratio']:.3f} |")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("which", choices=["dryrun", "roofline", "perf"])
    ap.add_argument("--dir", default=None)
    a = ap.parse_args()
    if a.which == "dryrun":
        dryrun_table(a.dir or "dryrun")
    elif a.which == "roofline":
        roofline_table(a.dir or "roofline")
    else:
        perf_table()
