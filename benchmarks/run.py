"""Benchmark harness entry point: ``python -m benchmarks.run [--only fig7]``.

One function per paper table/figure (see paper_figures.py); prints
``name,value,derived`` CSV and writes results/benchmarks.csv.  Paper-claim
assertions fire inside the figure functions — a passing run IS the
§Paper-validation evidence.
"""

from __future__ import annotations

import argparse
import pathlib
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, help="run a single figure, e.g. fig7")
    args = ap.parse_args()

    from .paper_figures import ALL_FIGURES

    rows = []
    failures = []
    for name, fn in ALL_FIGURES:
        if args.only and args.only != name:
            continue
        t0 = time.perf_counter()
        try:
            out = fn()
            rows.extend(out)
            print(f"# {name}: {len(out)} rows ({time.perf_counter()-t0:.1f}s)",
                  file=sys.stderr)
        except AssertionError as e:
            failures.append((name, repr(e)))
            print(f"# {name}: CLAIM FAILED: {e}", file=sys.stderr)

    print("name,value,derived")
    for name, value, derived in rows:
        print(f"{name},{value},{derived}")

    out_path = pathlib.Path(__file__).resolve().parent.parent / "results"
    out_path.mkdir(exist_ok=True)
    with open(out_path / "benchmarks.csv", "w") as f:
        f.write("name,value,derived\n")
        for name, value, derived in rows:
            f.write(f"{name},{value},{derived}\n")

    if failures:
        print(f"\n# {len(failures)} paper-claim failures", file=sys.stderr)
        sys.exit(1)
    print(f"# all paper-claim assertions passed ({len(rows)} rows)", file=sys.stderr)


if __name__ == "__main__":
    main()
