"""Serving control-plane benchmark: SLA-aware fabric arbiter vs a
FIFO-sequential scheduler on one photonic fabric.

The workload is an online serving stream over a ``tp × dp`` mesh: decode
waves (DP all-gathers, latency-bound), prefill bursts (TP all-reduces over
mixed context lengths, bandwidth-bound) and occasional KV-cache migrations
(full-domain all-to-alls).  Arrivals follow deterministic **Poisson** and
**bursty** traces at several load multiples of the fabric's measured
capacity.  Each operating point is served two ways through the *same*
virtual-time control loop (``repro.serve.arbiter``):

* **fifo** — arrival-order service, rounds charged at the sequential
  one-collective-at-a-time cost, no deadlines, no preemption: what a
  fabric-unaware scheduler delivers;
* **arbiter** — EDF admission with deadline shedding, joint
  ``plan_concurrent`` rounds with prefill arrival offsets, and decode
  preemption when a round would miss the earliest decode deadline.

All times are planned costs from one cost model, so every number is
deterministic and machine-independent.  Writes ``BENCH_serve.json``::

    {"points": [{trace, load, n, tp, dp, d_model, arrivals,
                 completed, shed_rate, utilization, preemptions,
                 p50_token_s, p99_token_s, fifo_p50_token_s,
                 fifo_p99_token_s, speedup, plan_cache_hit_rate}, ...],
     "sla": {...}, "smoke": bool}

``speedup`` is the p99 *token* (decode) latency ratio fifo/arbiter — the
gated metric (higher is better; see scripts/bench_gate.py, which matches
points on ``trace``/``load``).  Acceptance, asserted every run:

* the arbiter beats FIFO p99 by >= 1.2x at some operating point;
* it is never worse than FIFO (>= 0.95x) at any point;
* at 2x overload, shedding engages and the p99 latency of *admitted*
  decode work stays bounded by twice the slowest SLA target — overload
  degrades throughput (shed rate), not admitted-request tails.

``--smoke`` (used by scripts/ci.sh) shrinks the traces and skips the
default JSON write; ``--json-out PATH`` still writes the reduced points
for the CI bench gate.
"""

from __future__ import annotations

import argparse
import json
import random
import time
from pathlib import Path
from typing import Dict, List, Tuple

from repro.api import PcclSession
from repro.core import cost_model as cm
from repro.core import topology as T
from repro.serve.arbiter import (
    DECODE,
    KV_MIGRATION,
    PREFILL,
    ArbiterConfig,
    FabricArbiter,
    SlaTarget,
)

HW = cm.H100_DGX
TP, DP = 4, 4
N = TP * DP
D_MODEL = 1024
CONTEXTS = (128, 512, 2048)     # mixed prompt lengths (tokens)
QUEUE_BOUND = 64
MAX_BATCH = 8
LOADS = (0.6, 1.0, 2.0)        # arrival rate as a multiple of capacity
OVERLOAD = 2.0                 # the point where shedding must engage
SEED = 20260807

Event = Tuple[float, str, int]  # (arrival_s, kind, context_len)


def _fresh_session() -> PcclSession:
    return PcclSession(HW, g0=T.ring(N))


def _probe() -> Tuple[float, float]:
    """Measure the fabric's saturated service capacity on a scratch session.

    Feeds the benchmark's own request mix as an instantaneous backlog (no
    deadlines, no shedding) and drains it, returning ``(round_s,
    capacity_rps)``: the mean joint round cost and the peak throughput in
    requests/second.  Everything downstream — SLA targets, arrival rates —
    derives from this, so the bench tracks the cost model instead of
    hard-coding seconds, and "2x overload" genuinely exceeds what the
    fabric can serve.
    """
    rng = random.Random(SEED ^ 0xBEEF)
    arb = FabricArbiter(
        _fresh_session(), tp=TP, dp=DP, d_model=D_MODEL,
        cfg=ArbiterConfig(queue_bound=10_000, max_batch=MAX_BATCH,
                          sla=SlaTarget(1e6, 1e6, 1e6), preemption=False),
    )
    for _ in range(120):
        kind, ctx = _mix(rng)
        arb.submit(arb.make_request(kind, ctx))
    while arb.queue_depth:
        arb.tick()
    rep = arb.report()
    return rep["clock_s"] / rep["rounds"], rep["completed"] / rep["clock_s"]


def _sla(round_s: float) -> SlaTarget:
    """SLA targets scaled to the probed round cost: decode must land within
    a few rounds, prefill within a batch drain, KV moves are slack."""
    return SlaTarget(
        prefill_s=12.0 * round_s,
        decode_s=3.0 * round_s,
        kv_migration_s=40.0 * round_s,
    )


def _mix(rng: random.Random) -> Tuple[str, int]:
    r = rng.random()
    if r < 0.70:
        return DECODE, 1
    if r < 0.92:
        return PREFILL, rng.choice(CONTEXTS)
    return KV_MIGRATION, rng.choice(CONTEXTS)


def poisson_trace(n_events: int, rate: float, seed: int) -> List[Event]:
    rng = random.Random(seed)
    t, events = 0.0, []
    for _ in range(n_events):
        t += rng.expovariate(rate)
        kind, ctx = _mix(rng)
        events.append((t, kind, ctx))
    return events


def bursty_trace(n_events: int, rate: float, seed: int) -> List[Event]:
    """Alternating hot/cold phases at the same mean rate: bursts of 4x
    arrivals followed by lulls at 0.4x — the trace that separates deadline
    shedding from simple rate limits."""
    rng = random.Random(seed)
    t, events = 0.0, []
    phase_len = 20
    for i in range(n_events):
        hot = (i // phase_len) % 2 == 0
        t += rng.expovariate(rate * (4.0 if hot else 0.4))
        kind, ctx = _mix(rng)
        events.append((t, kind, ctx))
    return events


TRACES = {"poisson": poisson_trace, "bursty": bursty_trace}


def run_trace(events: List[Event], arb: FabricArbiter) -> FabricArbiter:
    """Replay arrivals through the virtual-time control loop: drain rounds
    due before each arrival, idle-advance across gaps, then drain fully."""
    for t, kind, ctx in events:
        while arb.queue_depth and arb.clock < t:
            arb.tick()
        if arb.clock < t:
            arb.tick(now=t)  # idle gap: clock advances, fabric idle
        arb.submit(arb.make_request(kind, ctx, arrival_s=t))
    while arb.queue_depth:
        arb.tick()
    return arb


def _pct(lats: List[float], p: float) -> float:
    lats = sorted(lats)
    return lats[min(len(lats) - 1, int(p * len(lats)))] if lats else float("nan")


def _token_latencies(arb: FabricArbiter) -> List[float]:
    return [o.latency_s for o in arb.outcomes
            if o.status == "completed" and o.kind == DECODE]


def bench_point(trace: str, load: float, n_events: int,
                sla: SlaTarget, capacity: float) -> Dict:
    events = TRACES[trace](n_events, load * capacity, SEED)

    def build(fifo: bool) -> FabricArbiter:
        if fifo:
            # equal far-out deadlines make EDF degenerate to arrival order;
            # sequential round pricing models the fabric-unaware scheduler
            cfg = ArbiterConfig(
                queue_bound=QUEUE_BOUND, max_batch=MAX_BATCH,
                sla=SlaTarget(1e6, 1e6, 1e6), preemption=False,
                prefill_lead_rounds=0, serialize_rounds=True,
            )
        else:
            cfg = ArbiterConfig(
                queue_bound=QUEUE_BOUND, max_batch=MAX_BATCH, sla=sla,
            )
        return FabricArbiter(
            _fresh_session(), tp=TP, dp=DP, d_model=D_MODEL, cfg=cfg
        )

    t0 = time.perf_counter()
    arb = run_trace(events, build(fifo=False))
    wall_s = time.perf_counter() - t0
    fifo = run_trace(events, build(fifo=True))

    rep = arb.report()
    lat, flat = _token_latencies(arb), _token_latencies(fifo)
    p99, fifo_p99 = _pct(lat, 0.99), _pct(flat, 0.99)
    cache = rep["plan_cache"]
    return {
        "trace": trace,
        "load": load,
        "n": N,
        "tp": TP,
        "dp": DP,
        "d_model": D_MODEL,
        "arrivals": len(events),
        "completed": rep["completed"],
        "shed_rate": rep["shed_rate"],
        "shed_reasons": rep["shed_reasons"],
        "utilization": rep["utilization"],
        "preemptions": rep["preemptions"],
        "p50_token_s": _pct(lat, 0.50),
        "p99_token_s": p99,
        "fifo_p50_token_s": _pct(flat, 0.50),
        "fifo_p99_token_s": fifo_p99,
        "fifo_completed": fifo.report()["completed"],
        "speedup": fifo_p99 / p99,
        "plan_cache_hit_rate": cache["hits"] / max(1, cache["hits"] + cache["misses"]),
        "wall_s": wall_s,
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="short traces, assert guards, no default JSON "
                    "write (CI)")
    ap.add_argument("--out", default="BENCH_serve.json")
    ap.add_argument("--json-out", default=None, metavar="PATH",
                    help="also write the JSON here (even under --smoke); "
                    "used by the CI bench gate")
    args = ap.parse_args()

    n_events = 150 if args.smoke else 400
    round_s, capacity = _probe()
    sla = _sla(round_s)
    print(f"probe: round {round_s*1e6:.1f} us, capacity {capacity:.0f} req/s; "
          f"SLA decode {sla.decode_s*1e6:.0f} us / prefill "
          f"{sla.prefill_s*1e6:.0f} us / kv {sla.kv_migration_s*1e6:.0f} us")

    points: List[Dict] = []
    for trace in TRACES:
        for load in LOADS:
            p = bench_point(trace, load, n_events, sla, capacity)
            points.append(p)
            print(
                f"{p['trace']:<8} load {p['load']:<4g} "
                f"p99 {p['p99_token_s']*1e6:9.1f} us vs fifo "
                f"{p['fifo_p99_token_s']*1e6:9.1f} us  "
                f"{p['speedup']:5.2f}x  shed {p['shed_rate']:5.1%}  "
                f"util {p['utilization']:5.1%}  "
                f"preempt {p['preemptions']}"
            )

    # reproducibility: the whole pipeline is planned cost + seeded traces,
    # so a re-run of any point must agree exactly
    p0 = points[0]
    again = bench_point(p0["trace"], p0["load"], n_events, sla, capacity)
    for k in ("p99_token_s", "fifo_p99_token_s", "shed_rate", "completed"):
        assert again[k] == p0[k], (
            f"serve bench not reproducible: {k} {again[k]} != {p0[k]}"
        )

    # acceptance bars (deterministic planned costs: no noise excuse)
    best = max(p["speedup"] for p in points)
    assert best >= 1.2, (
        f"acceptance: arbiter only {best:.2f}x over FIFO at its best point "
        f"(need >= 1.2x somewhere)"
    )
    worst = min(p["speedup"] for p in points)
    assert worst >= 0.95, (
        f"acceptance: arbiter worse than FIFO ({worst:.2f}x) at some point "
        f"(must never be worse)"
    )
    bound = 2.0 * max(sla.prefill_s, sla.decode_s, sla.kv_migration_s)
    for p in points:
        if p["load"] >= OVERLOAD:
            assert p["shed_rate"] > 0.0, (
                f"acceptance: no shedding at {p['load']}x overload "
                f"({p['trace']}) — admission control not engaging"
            )
            assert p["p99_token_s"] <= bound, (
                f"acceptance: admitted p99 {p['p99_token_s']:.2e}s exceeds "
                f"{bound:.2e}s at {p['load']}x overload ({p['trace']})"
            )

    result = {
        "points": points,
        "sla": {"prefill_s": sla.prefill_s, "decode_s": sla.decode_s,
                "kv_migration_s": sla.kv_migration_s},
        "probe_round_s": round_s,
        "capacity_rps": capacity,
        "smoke": args.smoke,
    }
    if args.json_out:
        Path(args.json_out).write_text(json.dumps(result, indent=2) + "\n")
        print(f"wrote {args.json_out}")
    if args.smoke:
        print("smoke OK: arbiter >= 1.2x FIFO p99 at some point, never "
              "worse, bounded admitted p99 + active shedding at overload")
        return
    Path(args.out).write_text(json.dumps(result, indent=2) + "\n")
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
