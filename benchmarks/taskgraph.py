"""FlexFlow-style task-graph simulator for end-to-end training (paper §6).

The paper evaluates PCCL by replacing the *communication node* costs in a
FlexFlow task graph with different algorithm/topology cost models, keeping
compute-node times fixed (they measure those on a real GPU; we derive them
analytically from layer FLOPs at a fixed achievable-FLOPs rate — the
comparison between communication schemes is unaffected since compute time is
identical across schemes, exactly as in the paper).

Graph shape (Fig. 11): per layer, forward compute → (pipeline P2P edges) →
backward compute → gradient AllReduce; data-parallel groups run the same
program.  ``simulate`` walks the DAG in topological order tracking per-GPU
ready times; AllReduce nodes synchronize their group.

Communication nodes are priced by:
* a baseline collective algorithm on the fixed topology (Eq. 1 with
  congestion/dilation), or
* PCCL (Algorithm 1 planner) with a reconfiguration delay.

PEER-TO-PEER nodes get direct circuits under PCCL and shortest-path α–β cost
on the fixed fabric otherwise; the §6 co-scheduling rule (P2P before
overlappable AllReduce) is applied by edge priority.
"""

from __future__ import annotations

import dataclasses
import json
from dataclasses import dataclass
from pathlib import Path
from typing import List, Optional, Sequence, Tuple, Union

from repro.api import PcclSession
from repro.core import cost_model as cm
from repro.core import topology as T

# Paper workload (§6): 12 layers, 16 heads, 2048 hidden; batch 16/GPU, seq 64.
@dataclass(frozen=True)
class Workload:
    n_layers: int = 12
    d_model: int = 2048
    n_heads: int = 16
    seq: int = 64
    batch_per_gpu: int = 16
    vocab: int = 30522
    achieved_flops: float = 120e12  # sustained per-GPU FLOP/s for compute nodes

    def layer_params(self) -> int:
        return 4 * self.d_model * self.d_model + 2 * self.d_model * 4 * self.d_model

    def layer_grad_bytes(self) -> float:
        return 4.0 * self.layer_params()  # fp32 grads

    def fwd_time(self) -> float:
        flops = 2 * self.batch_per_gpu * self.seq * self.layer_params()
        return flops / self.achieved_flops

    def bwd_time(self) -> float:
        return 2 * self.fwd_time()

    def p2p_bytes(self) -> float:
        return 2.0 * self.batch_per_gpu * self.seq * self.d_model  # bf16 acts


@dataclass
class CommScheme:
    """How communication nodes are priced."""

    name: str
    kind: str                      # 'fixed' or 'pccl'
    algorithm: str = "ring"        # fixed: which collective algorithm
    dims: Optional[Tuple[int, ...]] = None  # for bucket algorithms


def allreduce_time(
    scheme: CommScheme,
    session: PcclSession,
    n: int,
    nbytes: float,
) -> float:
    if scheme.kind == "pccl":
        return session.plan("all_reduce", nbytes, n=n, algorithm="auto").cost
    return session.baseline(
        "all_reduce", scheme.algorithm, nbytes, n=n, dims=scheme.dims
    ).total


def allreduce_times_sweep(
    scheme: CommScheme,
    session: PcclSession,
    n: int,
    sizes: Sequence[float],
) -> List[float]:
    """Price one AllReduce per bucket size, batched.

    PCCL schemes go through ``PcclSession.plan_sweep``: one size-independent
    structure phase prices every gradient bucket.  Equal to per-size
    ``plan`` calls from the same fabric state — bit-identical for
    power-of-two bucket ratios (so the default homogeneous per-layer
    pricing below matches the old one-plan-per-layer loop exactly), to the
    last ulp for arbitrary heterogeneous buckets."""
    if scheme.kind == "pccl":
        return [
            p.cost
            for p in session.plan_sweep("all_reduce", sizes, n=n, algorithm="auto")
        ]
    return [
        session.baseline(
            "all_reduce", scheme.algorithm, d, n=n, dims=scheme.dims
        ).total
        for d in sizes
    ]


def p2p_time(scheme: CommScheme, topo: T.Topology, src: int, dst: int,
             nbytes: float, hw: cm.HardwareParams) -> float:
    if scheme.kind == "pccl":
        # direct circuit: reconfigure + contention-free transfer (§6)
        return hw.reconfig_delay + hw.alpha + hw.beta * nbytes
    hops = topo.hop_count(src, dst)
    return hops * hw.alpha + hw.beta * nbytes


@dataclass
class SimResult:
    iteration_s: float
    comm_s: float
    compute_s: float
    throughput: float  # samples / s


def measured_overlap_fraction(
    bench_exec_json: Union[str, Path],
) -> Optional[float]:
    """Overlap fraction measured by the fused comm/compute bench.

    Reads the fused ``fused_matmul_reduce_scatter`` rows out of an
    ``BENCH_exec.json`` (``benchmarks/exec_bench.py``) and returns the best
    measured fraction of the sequential kernel-then-collective time that
    the tile-streaming fusion hid (``1 - fused_warm_s / seq_warm_s``), or
    ``None`` when the file has no fused rows.  Feed the result to
    :func:`simulate_training`'s ``overlap_fraction`` to price per-layer
    AllReduce overlap with the *measured* number instead of a guess.
    """
    doc = json.loads(Path(bench_exec_json).read_text())
    fracs = [
        max(0.0, 1.0 - p["fused_warm_s"] / p["seq_warm_s"])
        for p in doc.get("points", ())
        if p.get("collective") == "fused_matmul_reduce_scatter"
        and p.get("seq_warm_s", 0) > 0
    ]
    return max(fracs) if fracs else None


def simulate_training(
    wl: Workload,
    scheme: CommScheme,
    topo: T.Topology,
    hw: cm.HardwareParams,
    *,
    pipeline_stages: int = 1,
    grad_buckets: Optional[Sequence[float]] = None,
    overlap_fraction: Optional[float] = None,
) -> SimResult:
    """One data-parallel training iteration on n GPUs (paper Fig. 12 setup:
    the optimized strategy is data-parallel with per-layer gradient
    AllReduce; with pipeline_stages>1, stage boundaries add P2P transfers
    prioritized per §6).

    ``grad_buckets`` optionally gives each layer its own gradient bucket
    size (Fig. 10b-style heterogeneous buckets); default is one
    ``wl.layer_grad_bytes()`` bucket per layer.  Warm layers are priced in
    a single batched ``plan_sweep`` over the distinct bucket sizes, all
    from the post-layer-1 fabric state — the same steady-state
    approximation the homogeneous model always used (one warm cost × L−1),
    so alternating bucket sizes whose plans end on different topologies
    price each layer cold-from-steady-state rather than threading fabric
    layer to layer.

    ``overlap_fraction`` (flag-guarded; default ``None`` keeps the model
    unchanged) overlaps each layer's gradient AllReduce with the *next*
    layer's backward compute, the way the fused tile-streaming dispatch
    hides collective rounds behind producer tiles: of each warm layer's
    AllReduce, ``min(ar_s, overlap_fraction * bwd_s)`` is hidden under
    compute and only the remainder stays on the critical path.  Pass the
    measured number from :func:`measured_overlap_fraction` (the fused
    rows of ``BENCH_exec.json``), not a guess.  Layer 1's cold AllReduce
    never overlaps (it gates the fabric state the warm layers re-enter),
    and there is no backward left to hide the last layer's AllReduce
    behind, so one warm AllReduce also stays exposed."""
    n = topo.n
    std = [T.ring(n), T.torus2d(*T.square_dims2(n))]
    # One session per simulated job: PCCL plans thread fabric state across the
    # per-layer AllReduces (layer 1 pays any reconfiguration cold, layers 2..L
    # re-enter the fabric state layer 1 left behind), and the plan cache makes
    # the L-1 warm layers a single planning call.
    session = PcclSession(hw, g0=topo, standard_set=std, thread_fabric=True)

    layers_per_stage = max(wl.n_layers // pipeline_stages, 1)
    fwd, bwd = wl.fwd_time(), wl.bwd_time()

    compute = wl.n_layers * (fwd + bwd)
    comm = 0.0

    # pipeline P2P at stage boundaries (fwd + bwd), prioritized before AR
    for _ in range(max(pipeline_stages - 1, 0) * 2):
        comm += p2p_time(scheme, topo, 0, 1, wl.p2p_bytes(), hw)

    # per-layer gradient AllReduce (the paper buckets by layer; Fig. 10b
    # shows 1–64 MB buffers — one d_model² bucket per layer lands mid-range).
    # Layer 1 plans cold and threads the fabric; layers 2..L are then priced
    # warm in one batched sweep over the distinct bucket sizes.
    buckets = (
        list(grad_buckets)
        if grad_buckets is not None
        else [wl.layer_grad_bytes()] * wl.n_layers
    )
    if len(buckets) != wl.n_layers:
        raise ValueError(
            f"got {len(buckets)} grad buckets for {wl.n_layers} layers"
        )
    ar_cold = allreduce_time(scheme, session, n, buckets[0])
    warm_sizes = sorted(set(buckets[1:]))
    warm = dict(
        zip(warm_sizes, allreduce_times_sweep(scheme, session, n, warm_sizes))
    )
    warm_costs = [warm[b] for b in buckets[1:]]
    comm += ar_cold + sum(warm_costs)
    if overlap_fraction is not None and len(warm_costs) > 1:
        f = float(overlap_fraction)
        if not 0.0 <= f <= 1.0:
            raise ValueError(f"overlap_fraction must be in [0, 1], got {f}")
        # every warm AllReduce but the last hides under the next layer's
        # backward; what is hidden leaves the critical path (comm only —
        # compute still runs, now concurrently with the collective)
        comm -= sum(min(c, f * bwd) for c in warm_costs[:-1])

    it = compute + comm
    return SimResult(
        iteration_s=it,
        comm_s=comm,
        compute_s=compute,
        throughput=wl.batch_per_gpu * n / it,
    )
