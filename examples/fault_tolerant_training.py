"""Fault-tolerant training demo: checkpoints, an injected node failure, and
bit-exact resume (assignment large-scale-runnability features).

  PYTHONPATH=src python examples/fault_tolerant_training.py
"""

import tempfile

from repro.ckpt.checkpoint import CheckpointConfig
from repro.configs import get_config
from repro.data.pipeline import DataConfig
from repro.runtime.fault import FailureInjector
from repro.train.optimizer import OptimizerConfig
from repro.train.trainer import Trainer, TrainerConfig


def main():
    cfg = get_config("olmoe-1b-7b").reduced()  # tiny MoE, same code paths
    steps = 24
    with tempfile.TemporaryDirectory() as d:
        trainer = Trainer(
            model_cfg=cfg,
            data_cfg=DataConfig(global_batch=4, seq_len=32),
            opt_cfg=OptimizerConfig(lr=1e-3, total_steps=steps, warmup_steps=2),
            trainer_cfg=TrainerConfig(total_steps=steps, ckpt_every=6, log_every=6),
            ckpt_cfg=CheckpointConfig(d, keep=2, async_write=True),
            failure_injector=FailureInjector(fail_at_steps=(10, 17)),
        )
        out = trainer.run()
        print(f"\nsurvived 2 injected failures; final loss "
              f"{out['final_metrics']['loss']:.4f}")
        print(f"PCCL planned '{out['grad_allreduce_algorithm']}' for the "
              f"gradient all-reduce")
        print(f"straggler report: {out['stragglers'] or 'none flagged'}")


if __name__ == "__main__":
    main()
