"""End-to-end driver: data-parallel training where the gradient all-reduce is
executed by PCCL's schedule-driven collectives (ppermute rounds) instead of
XLA's built-in psum — the paper's library, actually moving the gradients.

Runs a ~100 M-parameter dense transformer for a few hundred steps on 8 host
devices (sets the device count itself; run as a standalone script):

  PYTHONPATH=src python examples/pccl_dp_training.py --steps 300

A single ``PcclSession`` plans everything; ``session.communicator("data", n)``
returns the executable collectives (backend="interp" → ppermute rounds,
backend="xla" → the native baseline for A/B runs), and reports which
algorithm the planner chose for the gradient buffer size (paper §2.2).
"""

import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=8 " + os.environ.get("XLA_FLAGS", "")
)

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import compat
from repro.api import PcclSession
from repro.configs import get_config
from repro.core import cost_model as cm
from repro.data.pipeline import DataConfig, SyntheticLMData
from repro.models import build_model, unbox
from repro.models.module import param_count
from repro.train.optimizer import OptimizerConfig, adamw_update, init_opt_state


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--d-model", type=int, default=512)
    ap.add_argument("--layers", type=int, default=8)
    ap.add_argument("--backend", default="interp", choices=["interp", "xla"],
                    help="interp = PCCL ppermute schedules; xla = native psum baseline")
    args = ap.parse_args()

    n_dev = len(jax.devices())
    mesh = compat.make_mesh((n_dev,), ("data",))

    # ~100M params: d=512, 8L, vocab 32k → ≈ 60M; bump ff for ~100M
    cfg = dataclasses.replace(
        get_config("chatglm3-6b").reduced(),
        n_layers=args.layers, d_model=args.d_model, n_heads=8, n_kv_heads=2,
        head_dim=64, d_ff=4 * args.d_model, vocab=32000, dtype="float32",
    )
    model = build_model(cfg)
    params = unbox(model.init(jax.random.PRNGKey(0)))
    n_params = param_count(params)
    print(f"model: {n_params/1e6:.1f} M params on {n_dev} devices (pure DP)")

    grad_bytes = 4.0 * n_params
    session = PcclSession(cm.TPU_V5E_PHOTONIC)
    comm = session.communicator("data", n_dev, backend=args.backend)
    print(f"PCCL chose '{comm.chosen_algorithm('all_reduce', grad_bytes)}' "
          f"for the {grad_bytes/1e6:.0f} MB gradient all-reduce "
          f"(backend={args.backend})")

    opt_cfg = OptimizerConfig(lr=1e-3, total_steps=args.steps, warmup_steps=10)
    opt_state = init_opt_state(params)
    data = SyntheticLMData(cfg, DataConfig(global_batch=args.batch, seq_len=args.seq))

    def per_shard_step(params, opt_state, batch):
        # per-device loss on the local batch shard; grads averaged via the
        # schedule-driven PCCL all-reduce (ppermute rounds)
        def loss_fn(p):
            loss, _ = model.loss(p, batch)
            return loss

        loss, grads = jax.value_and_grad(loss_fn)(params)
        grads = jax.tree.map(lambda g: comm.all_reduce(g) / n_dev, grads)
        loss = jax.lax.psum(loss, "data") / n_dev
        new_params, new_opt, _ = adamw_update(opt_cfg, grads, params, opt_state)
        return new_params, new_opt, loss

    step_fn = jax.jit(
        compat.shard_map(
            per_shard_step,
            mesh=mesh,
            in_specs=(P(), P(), {"tokens": P("data", None)}),
            out_specs=(P(), P(), P()),
            check_vma=False,
        ),
        donate_argnums=(0, 1),
    )

    t0 = time.perf_counter()
    for step in range(args.steps):
        batch = {k: jnp.asarray(v) for k, v in data.global_batch(step).items()}
        params, opt_state, loss = step_fn(params, opt_state, batch)
        if step % 20 == 0 or step == args.steps - 1:
            print(f"step {step:4d}  loss {float(loss):.4f}")
    dt = time.perf_counter() - t0
    toks = args.steps * args.batch * args.seq
    moved_by = ("PCCL schedule-driven ppermute rounds" if args.backend == "interp"
                else "native XLA psum (baseline)")
    print(f"trained {args.steps} steps in {dt:.1f}s ({toks/dt:.0f} tok/s) — "
          f"gradients moved by {moved_by}")


if __name__ == "__main__":
    main()
