"""Quickstart: plan collectives with PCCL and see why reconfiguration wins.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

from repro.core import cost_model as cm
from repro.core import schedules as S
from repro.core import topology as T
from repro.core.pccl import CollectiveRequest, baseline_cost, plan_collective

MB = 1024.0 ** 2


def main():
    n = 128
    hw = cm.H100_DGX  # α=3µs, β=1/450 GB/s, reconfig r=5µs (paper §5)

    print("=== PCCL quickstart: ReduceScatter of 256 MB on 128 GPUs ===\n")
    for topo_name in ["ring", "torus2d", "grid2d"]:
        g0 = T.standard_topologies(n)[topo_name]
        plan = plan_collective(
            CollectiveRequest("reduce_scatter", n, 256 * MB, algorithm="auto"), g0, hw
        )
        ring = baseline_cost("reduce_scatter", "ring", g0, n, 256 * MB, hw).total
        rhd = baseline_cost("reduce_scatter", "rhd", g0, n, 256 * MB, hw).total
        print(f"starting topology: {topo_name}")
        print(f"  ring  on fixed fabric : {ring*1e6:9.1f} us")
        print(f"  RHD   on fixed fabric : {rhd*1e6:9.1f} us")
        print(f"  PCCL ({plan.algorithm} schedule, {plan.num_reconfigs} reconfigs)"
              f" : {plan.cost*1e6:9.1f} us")
        b = plan.breakdown()
        print(f"    breakdown: alpha={b['alpha']*1e6:.1f}us beta={b['beta']*1e6:.1f}us "
              f"dilation={b['dilation']*1e6:.1f}us congestion={b['congestion']*1e6:.1f}us "
              f"reconfig={b['reconfig']*1e6:.1f}us\n")

    print("=== When NOT to reconfigure: 1 GB buffer, 1 ms (MEMS-class) switch ===\n")
    hw_slow = cm.H100_DGX_R1MS
    g0 = T.ring(n)
    plan = plan_collective(
        CollectiveRequest("reduce_scatter", n, 1024 * MB), g0, hw_slow
    )
    print(f"PCCL reconfigures only {plan.num_reconfigs}×/7 rounds "
          f"(trades congestion for reconfig delay, paper Fig. 9)\n")

    print("=== MoE AllToAll (paper Fig. 10a): DEX schedule, 32 MB, 128 GPUs ===\n")
    for topo_name in ["ring", "torus3d"]:
        g0 = T.standard_topologies(n)[topo_name]
        dex_fixed = cm.schedule_cost_fixed(g0, S.dex_all_to_all(n, 32 * MB), hw).total
        plan = plan_collective(CollectiveRequest("all_to_all", n, 32 * MB), g0, hw)
        print(f"  {topo_name}: DEX fixed {dex_fixed*1e6:.1f} us → PCCL "
              f"{plan.cost*1e6:.1f} us ({dex_fixed/plan.cost:.2f}x)")


if __name__ == "__main__":
    main()
