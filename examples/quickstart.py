"""Quickstart: the PcclSession front door — plan collectives, see why
reconfiguration wins, and watch the session amortize it.

``PcclSession`` is the library's single entry point: it owns the hardware
model, a plan cache, and the fabric state.  Every ``session.plan(...)`` call
starts from the topology the *previous* collective left programmed on the
photonic fabric, so back-to-back collectives stop re-paying reconfigurations
(something the stateless ``plan_collective`` facade could never express).

Run:  PYTHONPATH=src python examples/quickstart.py
"""

from repro.api import PcclSession
from repro.core import cost_model as cm
from repro.core import topology as T

MB = 1024.0 ** 2


def main():
    n = 128
    hw = cm.H100_DGX  # α=3µs, β=1/450 GB/s, reconfig r=5µs (paper §5)

    print("=== PCCL quickstart: ReduceScatter of 256 MB on 128 GPUs ===\n")
    for topo_name in ["ring", "torus2d", "grid2d"]:
        g0 = T.standard_topologies(n)[topo_name]
        session = PcclSession(hw, g0=g0, thread_fabric=False)
        plan = session.plan("reduce_scatter", 256 * MB, algorithm="auto")
        ring = session.baseline("reduce_scatter", "ring", 256 * MB).total
        rhd = session.baseline("reduce_scatter", "rhd", 256 * MB).total
        print(f"starting topology: {topo_name}")
        print(f"  ring  on fixed fabric : {ring*1e6:9.1f} us")
        print(f"  RHD   on fixed fabric : {rhd*1e6:9.1f} us")
        print(f"  PCCL ({plan.algorithm} schedule, {plan.num_reconfigs} reconfigs)"
              f" : {plan.cost*1e6:9.1f} us")
        b = plan.breakdown()
        print(f"    breakdown: alpha={b['alpha']*1e6:.1f}us beta={b['beta']*1e6:.1f}us "
              f"dilation={b['dilation']*1e6:.1f}us congestion={b['congestion']*1e6:.1f}us "
              f"reconfig={b['reconfig']*1e6:.1f}us\n")

    print("=== Sessions thread fabric state across collectives ===\n")
    session = PcclSession(hw, g0=T.grid2d(*T.square_dims2(n)))
    cold = session.plan("reduce_scatter", 256 * MB, algorithm="ring")
    warm = session.plan("reduce_scatter", 256 * MB, algorithm="ring")
    again = session.plan("reduce_scatter", 256 * MB, algorithm="ring")
    print(f"cold start : {cold.cost*1e6:9.1f} us ({cold.num_reconfigs} reconfigs)")
    print(f"warm start : {warm.cost*1e6:9.1f} us ({warm.num_reconfigs} reconfigs)"
          f" — fabric already holds the ring circuits")
    print(f"cached     : {again.cost*1e6:9.1f} us "
          f"(cache {session.stats.hits} hit / {session.stats.misses} miss)\n")

    print("=== When NOT to reconfigure: 1 GB buffer, 1 ms (MEMS-class) switch ===\n")
    slow = PcclSession(cm.H100_DGX_R1MS, g0=T.ring(n))
    plan = slow.plan("reduce_scatter", 1024 * MB)
    print(f"PCCL reconfigures only {plan.num_reconfigs}×/7 rounds "
          f"(trades congestion for reconfig delay, paper Fig. 9)\n")

    print("=== MoE AllToAll (paper Fig. 10a): DEX schedule, 32 MB, 128 GPUs ===\n")
    for topo_name in ["ring", "torus3d"]:
        g0 = T.standard_topologies(n)[topo_name]
        session = PcclSession(hw, g0=g0, thread_fabric=False)
        dex_fixed = session.baseline("all_to_all", "dex", 32 * MB).total
        plan = session.plan("all_to_all", 32 * MB)
        print(f"  {topo_name}: DEX fixed {dex_fixed*1e6:.1f} us → PCCL "
              f"{plan.cost*1e6:.1f} us ({dex_fixed/plan.cost:.2f}x)")

    print("\n=== Executable collectives hang off the same session ===\n")
    tpu = PcclSession(cm.TPU_V5E_PHOTONIC)
    comm = tpu.communicator("data", 8, backend="interp")
    print(f"comm.all_reduce inside shard_map runs "
          f"'{comm.chosen_algorithm('all_reduce', 4 * MB)}' ppermute rounds; "
          f"split([r % 2 ...]) gives DP×TP sub-groups "
          f"(see examples/pccl_dp_training.py)")


if __name__ == "__main__":
    main()
