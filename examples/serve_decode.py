"""Batched serving demo: prefill + KV-cache decode on any assigned arch.

  PYTHONPATH=src python examples/serve_decode.py --arch zamba2-2.7b
  PYTHONPATH=src python examples/serve_decode.py --arch deepseek-v2-lite-16b

(reduced configs — same code paths the decode_32k / long_500k dry-run cells
lower at full scale, including MLA absorbed decode and SSM state decode)
"""

import argparse
import time

import numpy as np

from repro.configs import get_config
from repro.serve.engine import EngineConfig, Request, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="zamba2-2.7b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--new-tokens", type=int, default=12)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    eng = ServeEngine(cfg, EngineConfig(batch_size=args.batch,
                                        max_len=args.prompt_len + args.new_tokens))
    rng = np.random.default_rng(0)
    reqs = [
        Request(prompt=rng.integers(0, cfg.vocab, size=args.prompt_len).astype(np.int32),
                max_new_tokens=args.new_tokens)
        for _ in range(args.batch)
    ]
    t0 = time.perf_counter()
    out = eng.generate(reqs)
    dt = time.perf_counter() - t0
    total = sum(len(r.generated) for r in out)
    print(f"[{args.arch}] generated {total} tokens in {dt:.2f}s "
          f"({total/dt:.1f} tok/s, batch={args.batch})")
    for i, r in enumerate(out[:2]):
        print(f"  seq {i}: {r.generated}")


if __name__ == "__main__":
    main()
