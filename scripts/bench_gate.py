#!/usr/bin/env python
"""Benchmark regression gate: compare fresh bench JSONs against committed
baselines.

Usage (one or more ``FRESH:BASELINE[:TOLERANCE]`` pairs)::

    python scripts/bench_gate.py \
        .ci-bench/BENCH_planner.json:BENCH_planner.json \
        .ci-bench/BENCH_exec.json:BENCH_exec.json:0.1 \
        .ci-bench/BENCH_concurrent.json:BENCH_concurrent.json

Each file is one of the repo's bench formats — a top-level ``points`` /
``sweep_points`` (plus ``hier_points``) list of dicts carrying gated
metrics plus identifying fields (``n``, ``collective``, ``pod_size``,
``tp_mb``, ...).  Points are matched on the identifying fields that appear
in both files, so a CI run may produce a reduced (``--smoke``) point set
and still gate against the full committed baseline: only the intersection
is compared, and at least one shared point is required per pair.

Gated metrics carry a direction: ``speedup`` is higher-is-better,
``cost_ratio`` (hierarchical stitched cost vs the flat exact DP) is
lower-is-better.  Absolute wall-clock fields (``hier_cold_s``, ``loop_s``,
...) are never gated — they don't transfer across machines; the benches'
own ``--smoke`` assertions carry the wall-clock bars.  A shared point with
no gated metric on both sides is skipped.

Tolerance
---------
``--tolerance R`` (default 0.3, overridable per pair with a third ``:R``
component) passes a point when::

    fresh_speedup >= R * baseline_speedup

The committed baselines were measured on a warm dev box; CI runners are
slower, noisier, and differently provisioned, so the gate is deliberately a
*regression* gate, not a performance test: it catches a speedup collapsing
by more than ~3x (an algorithmic regression — e.g. a cache key that stopped
hitting, a fast path that stopped firing), while single-digit-percent noise
never flakes it.  The exec bench gets a looser 0.1 in CI: its warm leg is a
best-of-3 of millisecond-scale timings whose denominator legitimately
swings several-fold under co-tenant load, and its hard failure modes
(retrace regressions collapse the speedup to ~1x) are still far below the
floor.  The benches' own ``--smoke`` assertions carry the absolute floors
(planner >= 1.3x, exec >= 3x, fused mm+RS >= 1.3x, concurrent >= 1.2x), so
a fresh file that exists at all has already cleared those.  The exec
bench's fused rows (``mode="fused"``) share that 0.1 tolerance: their
speedup is a ratio of two warm dispatch paths on the same machine, so it
transfers across hosts far better than absolute times, but 1.1-1.8x-scale
wins still halve under pathological co-tenancy — the smoke assertion, not
the gate, carries the 1.3x acceptance bar.

The serve bench (``BENCH_serve.json``, points keyed ``trace``/``load``)
gets a *tighter* 0.5 in CI: its ``speedup`` is the FIFO-vs-arbiter p99
token-latency ratio computed purely from planned costs on seeded traces —
fully machine-independent — but the committed baseline runs 400-event
traces while ``--smoke`` runs 150, so tail percentiles shift with trace
length; 0.5 absorbs that while still catching a control-plane regression
(EDF ordering lost, preemption dead, joint planning off) that collapses
the win toward 1x.  Its absolute bars (>= 1.2x somewhere, never worse,
bounded overload p99) live in the bench's own assertions, which run every
smoke.
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path
from typing import Dict, List, Tuple

# fields that identify a point (the metric fields are everything else);
# "shape"/"mode" distinguish the exec bench's fused comm/compute rows
# (mode="fused", shape="MxKxN") from its engine rows; "trace"/"load"
# identify the serve bench's operating points (arrival trace x load
# multiple — its "speedup" is the FIFO/arbiter p99 token-latency ratio)
ID_KEYS = (
    "n", "collective", "algorithm", "pod_size", "tp", "dp",
    "tp_collective", "dp_collective", "tp_mb", "dp_mb", "sizes_mb",
    "shape", "mode", "trace", "load",
)
# gated metric -> direction ("higher" or "lower" is better)
METRICS = {
    "speedup": "higher",
    "cost_ratio": "lower",
}


def load_points(path: Path) -> List[Dict]:
    doc = json.loads(path.read_text())
    points: List[Dict] = []
    for key in ("points", "sweep_points", "hier_points"):
        points += doc.get(key, ())
    if not points:
        raise SystemExit(
            f"{path}: no 'points'/'sweep_points'/'hier_points' list"
        )
    return points


def point_id(p: Dict) -> Tuple:
    return tuple((k, json.dumps(p[k])) for k in ID_KEYS if k in p)


def gate_pair(fresh_path: Path, base_path: Path, tolerance: float) -> List[str]:
    fresh = {point_id(p): p for p in load_points(fresh_path)}
    base = {point_id(p): p for p in load_points(base_path)}
    shared = [k for k in fresh if k in base]
    if not shared:
        return [
            f"{fresh_path} vs {base_path}: no shared points "
            f"({len(fresh)} fresh, {len(base)} baseline)"
        ]
    failures: List[str] = []
    for k in shared:
        label = " ".join(f"{key}={json.loads(v)}" for key, v in k)
        gated = [m for m in METRICS if m in fresh[k] and m in base[k]]
        if not gated:
            print(f"  skip {label}: no gated metric on both sides")
            continue
        for metric in gated:
            f, b = fresh[k][metric], base[k][metric]
            if METRICS[metric] == "higher":
                floor = tolerance * b
                ok = f >= floor
                bound = f"floor {floor:.2f}"
            else:
                ceil = b / tolerance
                ok = f <= ceil
                bound = f"ceiling {ceil:.2f}"
            print(
                f"  {'ok  ' if ok else 'FAIL'} {label} {metric}: "
                f"fresh {f:.2f} vs baseline {b:.2f} ({bound})"
            )
            if not ok:
                failures.append(
                    f"{fresh_path}: {label} {metric} regressed to {f:.2f} "
                    f"(baseline {b:.2f}, tolerance {tolerance:g})"
                )
    return failures


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("pairs", nargs="+", metavar="FRESH:BASELINE[:TOLERANCE]",
                    help="fresh-vs-committed JSON pairs to gate, each with "
                    "an optional per-pair tolerance override")
    ap.add_argument("--tolerance", type=float, default=0.3,
                    help="fresh speedup must be >= TOLERANCE * baseline "
                    "(default 0.3; see module docstring)")
    args = ap.parse_args()

    failures: List[str] = []
    for pair in args.pairs:
        parts = pair.split(":")
        if len(parts) not in (2, 3):
            raise SystemExit(
                f"malformed pair {pair!r} (want FRESH:BASELINE[:TOLERANCE])"
            )
        fresh_s, base_s = parts[0], parts[1]
        tol = float(parts[2]) if len(parts) == 3 else args.tolerance
        print(f"gate {fresh_s} vs {base_s} (tolerance {tol:g}):")
        failures += gate_pair(Path(fresh_s), Path(base_s), tol)

    if failures:
        print("\nbench gate FAILED:", file=sys.stderr)
        for f in failures:
            print(f"  {f}", file=sys.stderr)
        sys.exit(1)
    print("bench gate OK")


if __name__ == "__main__":
    main()
