#!/usr/bin/env bash
# Tier-1 verification in named stages (see ROADMAP.md).
#
#   scripts/ci.sh                    # all stages: lint verify smoke tests bench
#   scripts/ci.sh lint smoke         # just these stages, in order
#   scripts/ci.sh tests -- -k session  # stage args after -- go to pytest
#   scripts/ci.sh -k session         # back-compat: bare pytest args run all
#                                    # stages with those args forwarded
#
# Stages (the GitHub Actions workflow runs them as separate steps so a
# compileall or smoke failure fails fast before paying for the full suite):
#   lint   - byte-compile everything + refuse tracked bytecode +
#            concurrency lint (repro.analysis.lint_concurrency) + ruff
#            (style/import order; skipped gracefully where not installed)
#   verify - static analysis gate (python -m repro.analysis): chunk-dataflow
#            verification of every generator, round feasibility, circuit
#            realizability, plan/concurrent-plan accounting invariants,
#            plus the Pallas kernel analyzer (--kernels): coverage,
#            write-race, bounds and scratch-carry proofs per pallas_call
#   smoke  - planner/exec/concurrent bench smoke guards (deterministic
#            regression checks + loose wall-clock bars); writes fresh
#            point JSONs into .ci-bench/ for the bench stage
#   tests  - the full pytest suite (hypothesis property suites run when
#            requirements-dev.txt is installed; they auto-skip otherwise)
#   bench  - scripts/bench_gate.py: fresh .ci-bench/ speedups vs the
#            committed BENCH_*.json baselines (documented tolerance)
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

BENCH_DIR=".ci-bench"

stage_lint() {
  # fast lint: every module must at least byte-compile
  python -m compileall -q src benchmarks scripts tests
  # committed bytecode must never reappear (purged in PR 5; see .gitignore)
  if git ls-files | grep -E '(^|/)__pycache__/|\.pyc$'; then
    echo "lint: tracked bytecode detected — purge it and rely on .gitignore" >&2
    return 1
  fi
  # concurrency lint: shared caches mutated outside their owning lock,
  # function-attribute state, mutable defaults (see src/repro/analysis/)
  python -m repro.analysis.lint_concurrency src/repro
  # style/import-order lint; requirements-dev installs ruff in CI, but the
  # dev image may not have it — degrade to a notice rather than fail
  if command -v ruff >/dev/null 2>&1; then
    ruff check src benchmarks scripts tests
  else
    echo "lint: ruff not installed; skipping style checks (CI runs them)"
  fi
}

stage_verify() {
  # static analysis gate: dataflow-verify every generator, check round
  # feasibility + circuit realizability, replay plan accounting
  python -m repro.analysis
  # kernel analyzer over the shipped Pallas kernels (separate invocation:
  # it needs JAX for capture, the schedule passes above stay jax-free)
  python -m repro.analysis --kernels
}

stage_smoke() {
  mkdir -p "$BENCH_DIR"
  # planner perf smoke: plan_sweep must stay bit-identical to the per-size
  # plan() loop and meaningfully faster (n=16), and one n=256 hierarchical
  # point per case must plan cold inside its wall-clock bar (keeps the
  # scaling path alive in CI without the full n=1024 matrix)
  python -m benchmarks.planner_bench --smoke --json-out "$BENCH_DIR/BENCH_planner.json"
  # execution-engine smoke (n=8): warm engine calls must be 0-retrace
  # (deterministic guard) and beat the cold per-round interpreter; also
  # runs one fused comm/compute point (tile-streaming matmul+RS at n=8,
  # 512x128x128) asserting bit-identity to the sequential composition and
  # a >=1.3x warm-dispatch win — the fusion acceptance bar
  python -m benchmarks.exec_bench --smoke --json-out "$BENCH_DIR/BENCH_exec.json"
  # concurrent-group smoke (n=16): joint plans reproducible, never worse
  # than sequential, >= 1.2x at some swept point
  python -m benchmarks.concurrent_bench --smoke --json-out "$BENCH_DIR/BENCH_concurrent.json"
  # serving control-plane smoke (tp=4 x dp=4): arbiter >= 1.2x FIFO p99 at
  # some operating point, never worse anywhere, and at 2x overload shedding
  # engages with admitted-request p99 still bounded
  python -m benchmarks.serve_bench --smoke --json-out "$BENCH_DIR/BENCH_serve.json"
}

stage_tests() {
  # --durations keeps slow planner tests visible as the suite grows
  # ${arr[@]+...} keeps `set -u` happy on bash < 4.4 when no args were given
  python -m pytest -x -q --durations=10 ${PYTEST_ARGS[@]+"${PYTEST_ARGS[@]}"}
}

stage_bench() {
  # regenerate any fresh file the smoke stage did not leave behind
  mkdir -p "$BENCH_DIR"
  [ -f "$BENCH_DIR/BENCH_planner.json" ] || \
    python -m benchmarks.planner_bench --smoke --json-out "$BENCH_DIR/BENCH_planner.json"
  [ -f "$BENCH_DIR/BENCH_exec.json" ] || \
    python -m benchmarks.exec_bench --smoke --json-out "$BENCH_DIR/BENCH_exec.json"
  [ -f "$BENCH_DIR/BENCH_concurrent.json" ] || \
    python -m benchmarks.concurrent_bench --smoke --json-out "$BENCH_DIR/BENCH_concurrent.json"
  [ -f "$BENCH_DIR/BENCH_serve.json" ] || \
    python -m benchmarks.serve_bench --smoke --json-out "$BENCH_DIR/BENCH_serve.json"
  # exec gets a looser tolerance: its warm-leg denominator is milliseconds
  # and legitimately swings under co-tenant load (see bench_gate docstring);
  # serve gets a tighter 0.5: its speedups are ratios of planned costs on
  # seeded traces (machine-independent), only the smoke trace length differs
  python scripts/bench_gate.py \
    "$BENCH_DIR/BENCH_planner.json:BENCH_planner.json" \
    "$BENCH_DIR/BENCH_exec.json:BENCH_exec.json:0.1" \
    "$BENCH_DIR/BENCH_concurrent.json:BENCH_concurrent.json" \
    "$BENCH_DIR/BENCH_serve.json:BENCH_serve.json:0.5"
}

# ---- argument parsing: stage names, then optional -- pytest args ----------
STAGES=()
PYTEST_ARGS=()
seen_sep=0
for arg in "$@"; do
  if [ "$seen_sep" = 1 ] || [ "$arg" = "--" ]; then
    [ "$arg" = "--" ] && [ "$seen_sep" = 0 ] && { seen_sep=1; continue; }
    PYTEST_ARGS+=("$arg")
  else
    case "$arg" in
      lint|verify|smoke|tests|bench) STAGES+=("$arg") ;;
      *)
        # back-compat with the pre-stage interface: the first word that is
        # not a stage name (a pytest flag, test path, -k expression, ...)
        # and everything after it forwards to pytest
        seen_sep=1
        PYTEST_ARGS+=("$arg")
        ;;
    esac
  fi
done
if [ "${#STAGES[@]}" -eq 0 ]; then
  STAGES=(lint verify smoke tests bench)
fi

for stage in "${STAGES[@]}"; do
  echo "==> ci stage: $stage"
  "stage_$stage"
done
