#!/usr/bin/env bash
# Tier-1 verification in one reproducible step (see ROADMAP.md).
#
#   scripts/ci.sh             # full tier-1 suite
#   scripts/ci.sh -k session  # extra args forwarded to pytest
#
# Property suites (hypothesis) auto-skip unless `pip install -r
# requirements-dev.txt` has been run; multidevice checks run in their own
# subprocesses and need no flags here.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
# fast lint: every module must at least byte-compile
python -m compileall -q src
# planner perf smoke (n=16): plan_sweep must stay bit-identical to the
# per-size plan() loop and meaningfully faster; fails fast on regression
python -m benchmarks.planner_bench --smoke
# execution-engine smoke (n=8): warm engine calls must be 0-retrace
# (deterministic guard) and beat the cold per-round interpreter by the
# loose wall-clock bar; outputs are checked bit-identical inside
python -m benchmarks.exec_bench --smoke
# --durations keeps slow planner tests visible as the suite grows
exec python -m pytest -x -q --durations=10 "$@"
