"""PCCL reproduction: photonic circuit-switched collective communication.

``repro.api`` is the front door — :class:`~repro.api.PcclSession` plans
reconfiguration-aware collectives with a shared plan cache and fabric-state
threading; :class:`~repro.api.Communicator` executes them over a mesh axis
through pluggable backends (``interp`` / ``xla`` / ``sim``).
"""

from .api import (
    Backend,
    CacheStats,
    Communicator,
    ConcurrentCollectiveRequest,
    ConcurrentPcclPlan,
    PcclSession,
    PlanCache,
    get_backend,
)

__all__ = [
    "Backend",
    "CacheStats",
    "Communicator",
    "ConcurrentCollectiveRequest",
    "ConcurrentPcclPlan",
    "PcclSession",
    "PlanCache",
    "get_backend",
]
