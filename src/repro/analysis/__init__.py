"""Static analysis for schedules, plans, kernels, and the codebase itself.

Four passes, no device execution:

* :mod:`repro.analysis.verify` — chunk-dataflow verifier: abstract
  interpretation proving a schedule's collective postcondition.
* :mod:`repro.analysis.invariants` — plan/circuit invariant checker: round
  feasibility, Alg. 3/4 realizability, Alg. 1 plan accounting, reconfig-mode
  monotonicity, concurrent joint-plan accounting.
* :mod:`repro.analysis.kernel_lint` (+ :mod:`repro.analysis.pallas_model`) —
  Pallas kernel static analyzer: captures each ``pl.pallas_call``'s
  grid/BlockSpecs abstractly and proves output coverage, write-race
  freedom, bounds, and scratch-carry discipline, plus an AST
  precision/hygiene lint.
* :mod:`repro.analysis.lint_concurrency` — AST lint for the shared-state
  bug classes (unguarded cache mutation, function-attribute state, mutable
  defaults).

``python -m repro.analysis`` runs the schedule/plan passes over the built-in
generator zoo and ``python -m repro.analysis --kernels`` the kernel analyzer
over the shipped kernels (both in the CI ``verify`` stage); ``python -m
repro.analysis.lint_concurrency`` runs the lint (the CI ``lint`` stage).
Set ``PCCL_VERIFY=1`` to also verify every schedule at exec-engine compile
time (``comm/exec_engine.py``) and every Pallas kernel entry point at
dispatch time (``kernels/*/ops.py``).
"""

from .verify import (  # noqa: F401
    ScheduleVerificationError,
    UnverifiableScheduleError,
    VerificationResult,
    Violation,
    assert_verified,
    verify_schedule,
)
from .invariants import (  # noqa: F401
    InvariantViolation,
    PlanInvariantError,
    assert_invariants,
    check_circuit_realizability,
    check_concurrent_plan,
    check_mode_monotonicity,
    check_plan,
    check_round_feasibility,
    check_schedule,
)
_LINT_EXPORTS = ("Finding", "lint_module", "lint_paths")
_KERNEL_EXPORTS = (
    "KernelLintError",
    "KernelReport",
    "KernelSummary",
    "KernelViolation",
    "analyze_call_site",
    "analyze_callable",
    "assert_kernel_clean",
    "shipped_kernel_cases",
    "summarize_kernel",
    "verify_entry_point",
)
_MODEL_EXPORTS = ("BlockModel", "Box", "CallSite", "CaptureError",
                  "capture_call_sites", "whole_array_box")


def __getattr__(name):
    # lazy (PEP 562): an eager import here makes ``python -m
    # repro.analysis.lint_concurrency`` warn about double execution, and
    # kernel_lint/pallas_model stay out of the jax-free schedule passes
    if name in _LINT_EXPORTS:
        from . import lint_concurrency

        return getattr(lint_concurrency, name)
    if name in _KERNEL_EXPORTS:
        from . import kernel_lint

        return getattr(kernel_lint, name)
    if name in _MODEL_EXPORTS:
        from . import pallas_model

        return getattr(pallas_model, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
