"""Static analysis for schedules, plans, and the codebase itself.

Three passes, no device execution:

* :mod:`repro.analysis.verify` — chunk-dataflow verifier: abstract
  interpretation proving a schedule's collective postcondition.
* :mod:`repro.analysis.invariants` — plan/circuit invariant checker: round
  feasibility, Alg. 3/4 realizability, Alg. 1 plan accounting, reconfig-mode
  monotonicity, concurrent joint-plan accounting.
* :mod:`repro.analysis.lint_concurrency` — AST lint for the shared-state
  bug classes (unguarded cache mutation, function-attribute state, mutable
  defaults).

``python -m repro.analysis`` runs the schedule/plan passes over the built-in
generator zoo (the CI ``verify`` stage); ``python -m
repro.analysis.lint_concurrency`` runs the lint (the CI ``lint`` stage).
Set ``PCCL_VERIFY=1`` to also verify every schedule at exec-engine compile
time (``comm/exec_engine.py``).
"""

from .verify import (  # noqa: F401
    ScheduleVerificationError,
    UnverifiableScheduleError,
    VerificationResult,
    Violation,
    assert_verified,
    verify_schedule,
)
from .invariants import (  # noqa: F401
    InvariantViolation,
    PlanInvariantError,
    assert_invariants,
    check_circuit_realizability,
    check_concurrent_plan,
    check_mode_monotonicity,
    check_plan,
    check_round_feasibility,
    check_schedule,
)
_LINT_EXPORTS = ("Finding", "lint_module", "lint_paths")


def __getattr__(name):
    # lazy (PEP 562): an eager import here makes ``python -m
    # repro.analysis.lint_concurrency`` warn about double execution
    if name in _LINT_EXPORTS:
        from . import lint_concurrency

        return getattr(lint_concurrency, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
