"""CI ``verify`` stage driver: ``python -m repro.analysis [--quick|--kernels]``.

Runs the static passes over the built-in generator zoo and the planner:

1. chunk-dataflow verification of every schedule generator across the
   n-sweep (plus ``split_for_fanout`` / ``replicate_groups`` compositions);
2. round feasibility + Alg. 3/4 circuit realizability for representative
   schedules;
3. Alg. 1 plan accounting, reconfig-mode monotonicity, and concurrent
   joint-plan accounting on planner output.

Prints one line per section and exits non-zero on any violation.
``--quick`` caps the realizability sweep at n=8 (it dominates runtime).

``--kernels`` instead runs *only* the Pallas kernel static analyzer
(:mod:`repro.analysis.kernel_lint`) over the shipped kernel zoo — a
separate mode because it needs JAX for capture while the schedule passes
stay jax-free.
"""

from __future__ import annotations

import argparse
import sys
import time
from typing import Iterable, List, Tuple

from ..core import schedules as S
from ..core import planner as P
from ..core.cost_model import H100_DGX
from ..core.topology import ring, standard_topologies
from .invariants import (
    check_circuit_realizability,
    check_concurrent_plan,
    check_mode_monotonicity,
    check_plan,
    check_round_feasibility,
)
from .verify import verify_schedule

_D = float(1 << 20)


def _generator_cases() -> Iterable[Tuple[str, S.Schedule, object]]:
    """(label, schedule, groups-or-None) for every verifiable generator."""
    for n in (2, 3, 4, 6, 8, 16):
        yield f"ring_rs n={n}", S.ring_reduce_scatter(n, _D), None
        yield f"ring_ag n={n}", S.ring_all_gather(n, _D), None
        yield f"ring_ar n={n}", S.ring_all_reduce(n, _D), None
        yield f"ring_ef8_ar n={n}", S.ring_ef8_all_reduce(n, _D), None
        yield f"direct_a2a n={n}", S.direct_all_to_all(n, _D), None
        yield f"ring_a2a n={n}", S.ring_all_to_all(n, _D), None
    for n in (2, 4, 8, 16):
        yield f"rhd_rs n={n}", S.rhd_reduce_scatter(n, _D), None
        yield f"rhd_ag n={n}", S.rhd_all_gather(n, _D), None
        yield f"rhd_ar n={n}", S.rhd_all_reduce(n, _D), None
        yield f"dex_a2a n={n}", S.dex_all_to_all(n, _D), None
    for dims in ((2, 2), (2, 3), (2, 4), (3, 3), (2, 2, 2), (4, 4), (2, 3, 4)):
        yield f"bucket_rs {dims}", S.bucket_reduce_scatter(dims, _D), None
        yield f"bucket_ag {dims}", S.bucket_all_gather(dims, _D), None
        yield f"bucket_ar {dims}", S.bucket_all_reduce(dims, _D), None
    yield "p2p 1->3", S.p2p(4, 1, 3, _D), None
    # compositions
    for n, tx in ((8, 1), (16, 2)):
        yield (f"split_fanout rhd_rs n={n} tx={tx}",
               S.split_for_fanout(S.rhd_reduce_scatter(n, _D), tx), None)
    tp_groups, dp_groups = S.mesh_groups(4, 2)
    yield ("replicate tp ring_ar",
           S.replicate_groups(S.ring_all_reduce(4, _D), tp_groups, 8), tp_groups)
    yield ("replicate dp rhd_rs",
           S.replicate_groups(S.rhd_reduce_scatter(2, _D), dp_groups, 8), dp_groups)


def _section(name: str, failures: List[str], t0: float) -> bool:
    status = "ok" if not failures else f"{len(failures)} FAILURE(S)"
    print(f"[verify] {name}: {status} ({time.perf_counter() - t0:.1f}s)")
    for f in failures:
        print(f"  {f}")
    return not failures


def run_kernels() -> int:
    """Kernel-lint section: analyze every shipped Pallas kernel case."""
    from .kernel_lint import run_shipped  # lazy: needs JAX

    t0 = time.perf_counter()
    failures = run_shipped(verbose=True)
    ok = _section("kernel lint (shipped Pallas kernels)",
                  [f"{failures} failing case(s)"] if failures else [], t0)
    print(f"[verify] {'PASS' if ok else 'FAIL'}")
    return 0 if ok else 1


def run(quick: bool = False) -> int:
    ok = True

    t0 = time.perf_counter()
    failures: List[str] = []
    n_cases = 0
    for label, sched, groups in _generator_cases():
        n_cases += 1
        res = verify_schedule(sched, groups=groups)
        if not res.verifiable or not res.ok:
            failures.append(f"{label}: {res}")
    ok &= _section(f"dataflow ({n_cases} schedules)", failures, t0)

    t0 = time.perf_counter()
    failures = []
    feas_cases = [S.ring_reduce_scatter(8, _D), S.rhd_all_reduce(8, _D),
                  S.dex_all_to_all(8, _D), S.direct_all_to_all(6, _D),
                  S.bucket_all_reduce((2, 4), _D)]
    for sched in feas_cases:
        for v in check_round_feasibility(sched, H100_DGX):
            failures.append(f"{sched.algorithm}/{sched.collective}: {v}")
    ok &= _section(f"round feasibility ({len(feas_cases)} schedules)", failures, t0)

    t0 = time.perf_counter()
    failures = []
    realiz = [S.rhd_reduce_scatter(8, _D), S.direct_all_to_all(8, _D),
              S.ring_all_reduce(8, _D)]
    if not quick:
        realiz += [S.dex_all_to_all(16, _D), S.ring_all_to_all(16, _D)]
    for sched in realiz:
        for v in check_circuit_realizability(sched):
            failures.append(f"{sched.algorithm}/{sched.collective} "
                            f"n={sched.n}: {v}")
    ok &= _section(f"circuit realizability ({len(realiz)} schedules)", failures, t0)

    t0 = time.perf_counter()
    failures = []
    n = 8
    g0 = ring(n)
    std = list(standard_topologies(n).values())
    plan_cases = [
        (S.rhd_reduce_scatter(n, _D), H100_DGX),
        (S.dex_all_to_all(n, _D), H100_DGX),
        (S.ring_all_reduce(n, _D),
         H100_DGX.with_link_reconfig(H100_DGX.reconfig_delay / 8)),
        (S.rhd_all_reduce(n, _D),
         H100_DGX.with_link_reconfig(H100_DGX.reconfig_delay / 8, overlap=True)),
    ]
    for sched, hw in plan_cases:
        p = P.plan(g0, std, sched, hw)
        for v in check_plan(p, g0, std):
            failures.append(f"plan {sched.algorithm}/{sched.collective} "
                            f"[{hw.reconfig_mode}]: {v}")
    for v in check_mode_monotonicity(g0, std, S.rhd_reduce_scatter(n, _D), H100_DGX):
        failures.append(f"monotonicity: {v}")
    ok &= _section(f"plan accounting ({len(plan_cases)} plans + modes)", failures, t0)

    t0 = time.perf_counter()
    failures = []
    tp_groups, dp_groups = S.mesh_groups(4, 2)
    s_tp = S.replicate_groups(S.ring_all_reduce(4, _D), tp_groups, n)
    s_dp = S.replicate_groups(S.ring_all_reduce(2, _D), dp_groups, n)
    cp = P.plan_concurrent(g0, std, [s_tp, s_dp], H100_DGX)
    for v in check_concurrent_plan(cp, g0, std):
        failures.append(f"concurrent: {v}")
    ok &= _section("concurrent accounting (1 joint plan)", failures, t0)

    print(f"[verify] {'PASS' if ok else 'FAIL'}")
    return 0 if ok else 1


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.analysis")
    ap.add_argument("--quick", action="store_true",
                    help="skip the n=16 realizability cases")
    ap.add_argument("--kernels", action="store_true",
                    help="run only the Pallas kernel static analyzer")
    args = ap.parse_args(argv)
    if args.kernels:
        return run_kernels()
    return run(quick=args.quick)


if __name__ == "__main__":
    sys.exit(main())
