"""Plan and circuit invariant checker (static, no device execution).

Complements the chunk-dataflow verifier (``analysis/verify.py``): where the
verifier proves a schedule computes the right *values*, this module proves a
schedule/plan is *realizable and priced consistently* on the photonic fabric:

* **Round feasibility** — per-round fan-out against the tile's transmitter /
  receiver budget (``HardwareParams.tx_per_gpu`` / ``rx_per_gpu``),
  permutation validity for single-Tx tiles, endpoint sanity.
* **Circuit realizability** — every distinct round structure routes on the
  MZI mesh (Algorithm 3, ``core/circuits.py``) and on the inter-server fiber
  graph (Algorithm 4, ``core/fibers.py``), with the routers' own validity
  invariants re-checked on their output.
* **Plan accounting** — an Algorithm-1 :class:`~repro.core.planner.Plan` is
  replayed against a freshly built :class:`~repro.core.planner.PlanStructure`:
  every step's state must be enterable and feasible, its round cost must
  reprice identically, reconfiguration is charged exactly when the edge set
  changes (zero on stay-put), overlap charges only the excess over the
  previous round, and the totals must sum.
* **Mode monotonicity** — for the same scenario, planned cost under
  ``overlap`` ≤ ``partial`` ≤ ``serial`` reconfiguration pricing (partial is
  capped at the full-fabric delay; overlap only subtracts).
* **Concurrent accounting** — a :class:`~repro.core.planner.ConcurrentPlan`
  is replayed through the planner's own joint evaluator: comm/reconfig
  decomposition must match, reconfiguration is charged only on *union*
  edge-set changes, every group's traffic must route inside its own
  allocated topology, and the never-worse-than-sequential bound must hold.

All checks return :class:`InvariantViolation` lists; :func:`assert_invariants`
raises :class:`PlanInvariantError` on any non-empty result.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, replace
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from collections import Counter

from ..core.circuits import CircuitRequest, MZIMesh, route_circuits, validate_routes
from ..core.cost_model import (
    HardwareParams,
    round_cost_from_factors,
    round_structure_key,
)
from ..core.fibers import route_fibers, server_grid
from ..core.planner import (
    HierarchicalPlan,
    Plan,
    ConcurrentPlan,
    _JointState,
    _pod_standard_set,
    build_structure,
    plan,
)
from ..core.schedules import Schedule, pod_subschedules
from ..core.topology import Topology, induced_topology, quotient_topology

_REL_TOL = 1e-9
_ABS_TOL = 1e-12


def _close(a: float, b: float) -> bool:
    return math.isclose(a, b, rel_tol=_REL_TOL, abs_tol=_ABS_TOL)


@dataclass(frozen=True)
class InvariantViolation:
    kind: str
    where: str
    message: str

    def __str__(self) -> str:
        return f"{self.where} [{self.kind}] {self.message}"


class PlanInvariantError(AssertionError):
    def __init__(self, violations: Sequence[InvariantViolation]):
        self.violations = tuple(violations)
        lines = [f"{len(violations)} invariant violation(s)"]
        lines += [f"  {v}" for v in violations]
        super().__init__("\n".join(lines))


# ------------------------------------------------------------ round feasibility


def check_round_feasibility(
    schedule: Schedule,
    hw: Optional[HardwareParams] = None,
    *,
    tx_limit: Optional[int] = None,
    rx_limit: Optional[int] = None,
) -> List[InvariantViolation]:
    """Fan-out vs. transmitter budget, permutation validity, endpoint sanity.

    Limits default to ``hw.tx_per_gpu`` / ``hw.rx_per_gpu`` (1 each when no
    ``hw`` is given — the paper's single-Tx tile, where every round must be
    a permutation so one circuit set realizes it).
    """
    tx = tx_limit if tx_limit is not None else (hw.tx_per_gpu if hw else 1)
    rx = rx_limit if rx_limit is not None else (hw.rx_per_gpu if hw else 1)
    out: List[InvariantViolation] = []
    n = schedule.n
    for ri, rnd in enumerate(schedule.rounds):
        fan_out: Dict[int, int] = {}
        fan_in: Dict[int, int] = {}
        for t in rnd.transfers:
            if not (0 <= t.src < n and 0 <= t.dst < n):
                out.append(InvariantViolation(
                    "bad-rank", f"round {ri}",
                    f"transfer {t.src}->{t.dst} outside [0,{n})"))
                continue
            if t.src == t.dst:
                out.append(InvariantViolation(
                    "self-transfer", f"round {ri}", f"rank {t.src} sends to itself"))
                continue
            fan_out[t.src] = fan_out.get(t.src, 0) + 1
            fan_in[t.dst] = fan_in.get(t.dst, 0) + 1
        for r, k in sorted(fan_out.items()):
            if k > tx:
                out.append(InvariantViolation(
                    "tx-limit", f"round {ri}",
                    f"rank {r} drives {k} circuits but has {tx} transmitter(s)"))
        for r, k in sorted(fan_in.items()):
            if k > rx:
                out.append(InvariantViolation(
                    "rx-limit", f"round {ri}",
                    f"rank {r} terminates {k} circuits but has {rx} receiver(s)"))
        if tx == 1 and rx == 1 and rnd.transfers and not rnd.is_permutation():
            out.append(InvariantViolation(
                "not-permutation", f"round {ri}",
                "single-Tx tiles need each round to be a permutation"))
    return out


# --------------------------------------------------------- circuit realizability


def _default_mesh(n: int) -> Tuple[MZIMesh, List[int]]:
    """A square MZI mesh with one attachment node per rank, spread over the
    grid (ranks pinned to distinct switches, row-major with stride).  The
    side is 2·(⌈√n⌉): enough waveguide capacity that any permutation round
    routes under the default WDM binning (see ``check_circuit_realizability``)."""
    side = 2 * max(2, math.isqrt(max(n - 1, 1)) + 1)
    mesh = MZIMesh(side, side)
    stride = max(1, mesh.n_nodes // max(n, 1))
    nodes = [(r * stride) % mesh.n_nodes for r in range(n)]
    assert len(set(nodes)) == n
    return mesh, nodes


def check_circuit_realizability(
    schedule: Schedule,
    *,
    mesh: Optional[MZIMesh] = None,
    rank_nodes: Optional[Sequence[int]] = None,
    n_wavelengths: Optional[int] = None,
    check_fibers: bool = True,
    gpus_per_server: int = 4,
) -> List[InvariantViolation]:
    """Route every distinct round structure with Algorithms 3 and 4.

    Alg. 3: each round's (src, dst) pairs become circuit requests on an MZI
    mesh (default: square grid with ranks pinned to spread-out switches);
    the round is realizable iff no request fails, and the router's output is
    re-validated with ``validate_routes``.  Transmitters are binned into
    ``n_wavelengths`` WDM groups (wavelength = src mod bins, default
    ``max(2, n // 2)``), so Alg. 3's per-λ-per-waveguide exclusivity is
    exercised without modelling one λ per tile.  Alg. 4: the same pairs,
    collapsed to server-to-server demands on a ``server_grid``, must route
    with flow conservation (every route connects its endpoints; the
    reported per-edge loads must equal a recount over the routes).

    Rounds are deduplicated by pair-structure key, so e.g. a ring's n−1
    identical-permutation rounds are routed once.
    """
    out: List[InvariantViolation] = []
    n = schedule.n
    if mesh is None or rank_nodes is None:
        mesh, rank_nodes = _default_mesh(n)
    bins = n_wavelengths if n_wavelengths is not None else max(2, n // 2)
    n_servers = max(1, -(-n // gpus_per_server))
    fiber_topo = server_grid(n_servers) if (check_fibers and n_servers > 1) else None

    seen: Dict[object, int] = {}
    for ri, rnd in enumerate(schedule.rounds):
        pairs = [t.pair() for t in rnd.transfers]
        if not pairs:
            continue
        key = round_structure_key(pairs)
        if key in seen:
            continue
        seen[key] = ri

        reqs = [CircuitRequest(rank_nodes[s], rank_nodes[d], s % bins)
                for s, d in pairs if 0 <= s < n and 0 <= d < n and s != d]
        if len(reqs) != len(pairs):
            out.append(InvariantViolation(
                "bad-request", f"round {ri}",
                "transfers with invalid endpoints cannot be routed"))
            continue
        result = route_circuits(mesh, reqs)
        if result.failed:
            out.append(InvariantViolation(
                "mesh-unroutable", f"round {ri}",
                f"Alg. 3 failed to place {len(result.failed)} of "
                f"{len(reqs)} circuits on a {mesh.rows}x{mesh.cols} mesh"))
        else:
            try:
                validate_routes(mesh, result, reqs)
            except AssertionError as e:  # router broke its own invariant
                out.append(InvariantViolation(
                    "mesh-invalid-routes", f"round {ri}", str(e)))

        if fiber_topo is not None:
            demands = [(s // gpus_per_server, d // gpus_per_server)
                       for s, d in pairs if s // gpus_per_server != d // gpus_per_server]
            if not demands:
                continue
            try:
                routing = route_fibers(fiber_topo, demands)
            except RuntimeError as e:
                out.append(InvariantViolation(
                    "fiber-unroutable", f"round {ri}", str(e)))
                continue
            recount: Dict[Tuple[int, int], int] = {}
            for (s, d), path in zip(demands, routing.routes):
                if path[0] != s or path[-1] != d:
                    out.append(InvariantViolation(
                        "fiber-bad-route", f"round {ri}",
                        f"route for {s}->{d} connects {path[0]}->{path[-1]}"))
                for a, b in zip(path[:-1], path[1:]):
                    recount[(a, b)] = recount.get((a, b), 0) + 1
            if recount != {e: c for e, c in routing.edge_load.items() if c}:
                out.append(InvariantViolation(
                    "fiber-load-mismatch", f"round {ri}",
                    "Alg. 4 edge loads disagree with a recount over its routes"))
            elif routing.z != max(recount.values(), default=0):
                out.append(InvariantViolation(
                    "fiber-z-mismatch", f"round {ri}",
                    f"z={routing.z} but max recounted load is "
                    f"{max(recount.values(), default=0)}"))
    return out


# -------------------------------------------------------------- plan accounting


def check_plan(
    p: Plan, g0: Topology, standard: Sequence[Topology]
) -> List[InvariantViolation]:
    """Replay an Algorithm-1 plan against a freshly built structure."""
    out: List[InvariantViolation] = []
    sched, hw = p.schedule, p.hw
    structure = build_structure(g0, standard, sched, hw)
    states = structure.states

    if len(p.steps) != len(sched.rounds):
        out.append(InvariantViolation(
            "step-count", "plan",
            f"{len(p.steps)} steps for {len(sched.rounds)} rounds"))
        return out

    prev = structure.g0_idx
    prev_comm = 0.0
    total = 0.0
    for i, step in enumerate(p.steps):
        where = f"step {i}"
        if step.round_index != i:
            out.append(InvariantViolation(
                "round-index", where, f"round_index={step.round_index}"))
        s = step.state_idx
        if not 0 <= s < len(states):
            out.append(InvariantViolation(
                "state-index", where, f"state_idx={s} of {len(states)}"))
            return out
        if step.topo_name != states[s].topo.name:
            out.append(InvariantViolation(
                "state-name", where,
                f"step names {step.topo_name!r}, structure has "
                f"{states[s].topo.name!r}"))
        if s != prev and not structure.enterable[i, s]:
            out.append(InvariantViolation(
                "entry", where,
                f"state {states[s].topo.name} is not enterable at round {i}"))
        if not structure.feasible[i, s]:
            out.append(InvariantViolation(
                "infeasible-state", where,
                f"round {i} does not route on {states[s].topo.name}"))
        want = round_cost_from_factors(
            int(structure.dilation[i, s]), int(structure.congestion[i, s]),
            bool(structure.feasible[i, s]), sched.rounds[i].size, hw)
        if not _close(step.cost.total, want.total):
            out.append(InvariantViolation(
                "round-cost", where,
                f"step prices {step.cost.total:.6g}, repricing gives "
                f"{want.total:.6g}"))
        if step.reconfigured != (s != prev):
            out.append(InvariantViolation(
                "reconfigured-flag", where,
                f"reconfigured={step.reconfigured} but state "
                f"{'changed' if s != prev else 'stayed'}"))
        want_rc = float(structure.trans[prev, s])
        if hw.overlap and i > 0:
            want_rc = max(0.0, want_rc - prev_comm)
        if s == prev and step.reconfig_cost != 0.0:
            out.append(InvariantViolation(
                "reconfig-on-stay", where,
                f"charged {step.reconfig_cost:.6g} without an edge-set change"))
        elif not _close(step.reconfig_cost, want_rc):
            out.append(InvariantViolation(
                "reconfig-cost", where,
                f"step charges {step.reconfig_cost:.6g}, transition table "
                f"gives {want_rc:.6g}"))
        total += step.cost.total + step.reconfig_cost
        prev_comm = step.cost.total
        prev = s
    if not _close(total, p.total_cost):
        out.append(InvariantViolation(
            "total-cost", "plan",
            f"steps sum to {total:.6g}, plan claims {p.total_cost:.6g}"))
    final = states[prev].topo if p.steps else g0
    if p.final_topology is not None and p.final_topology.edges != final.edges:
        out.append(InvariantViolation(
            "final-topology", "plan",
            "final_topology does not match the last step's state"))
    return out


def _prefixed(
    violations: Sequence[InvariantViolation], prefix: str
) -> List[InvariantViolation]:
    return [
        InvariantViolation(v.kind, f"{prefix}: {v.where}", v.message)
        for v in violations
    ]


def check_hierarchical_plan(
    hp: HierarchicalPlan, g0: Topology, standard: Sequence[Topology]
) -> List[InvariantViolation]:
    """Replay a two-level hierarchical plan: both planning levels, the
    pod decomposition itself, and the stitching arithmetic.

    * every representative pod plan replays through :func:`check_plan`
      against the pod's induced fabric (violations prefixed ``pod p``),
      and the coarse inter-pod plan against the quotient fabric
      (prefixed ``inter``);
    * **containment/conservation** — per round, each pod's executed
      transfers (its shared representative plan, mapped to the pod's local
      ids) must be exactly the original round's traffic inside that pod,
      and the declared ``boundary`` pod-pair multiplicities must be exactly
      the original cross-pod traffic — nothing dropped, invented, or
      leaked across a pod boundary;
    * **stitching** — ``round_costs[i]`` must equal the max over groups of
      round ``i``'s comm + reconfig (barrier-synced independent groups),
      and ``total_cost`` their sum.

    With one pod the plan *is* the flat exact DP and delegates to
    :func:`check_plan` on the caller's inputs.
    """
    out: List[InvariantViolation] = []
    sched = hp.schedule
    n, R, P = sched.n, len(sched.rounds), len(hp.pods)

    if sorted(r for pod in hp.pods for r in pod) != list(range(n)):
        out.append(InvariantViolation(
            "pods-not-partition", "pods",
            f"pods do not partition ranks 0..{n - 1} exactly once"))
        return out
    if len(hp.pod_plans) != P:
        out.append(InvariantViolation(
            "pod-plan-count", "pods",
            f"{len(hp.pod_plans)} pod plans for {P} pods"))
        return out
    for pp in hp.pod_plans:
        if pp.ranks != hp.pods[pp.pod_index]:
            out.append(InvariantViolation(
                "pod-ranks", f"pod {pp.pod_index}",
                "PodPlan.ranks disagrees with the pod partition"))
            return out

    if P == 1:
        if hp.inter_plan is not None:
            out.append(InvariantViolation(
                "inter-plan", "inter",
                "single-pod plan carries an inter-pod phase"))
        out += _prefixed(
            check_plan(hp.pod_plans[0].plan, g0, standard), "pod 0")
        group_plans: List[Plan] = [hp.pod_plans[0].plan]
    else:
        intra, rep, boundary = pod_subschedules(sched, hp.pods)
        if hp.rep != rep:
            out.append(InvariantViolation(
                "rep-map", "pods",
                "stored pod-representative map disagrees with the "
                "schedule's pod equivalence classes"))
        for p in sorted(set(hp.rep)):
            ranks = hp.pods[p]
            pod_g0 = induced_topology(g0, ranks, name=f"{g0.name}|pod{p}")
            out += _prefixed(
                check_plan(
                    hp.pod_plans[p].plan, pod_g0, _pod_standard_set(len(ranks))
                ),
                f"pod {p}",
            )
        if hp.inter_plan is None:
            out.append(InvariantViolation(
                "inter-plan", "inter", f"{P} pods but no inter-pod plan"))
            return out
        coarse_g0 = quotient_topology(g0, hp.pods, name=f"{g0.name}/pods")
        if hp.inter_plan.schedule.n != P:
            out.append(InvariantViolation(
                "inter-n", "inter",
                f"coarse schedule spans {hp.inter_plan.schedule.n} "
                f"super-ranks for {P} pods"))
            return out
        out += _prefixed(
            check_plan(hp.inter_plan, coarse_g0, _pod_standard_set(P)),
            "inter",
        )
        if len(hp.inter_plan.schedule.rounds) != R:
            out.append(InvariantViolation(
                "inter-rounds", "inter",
                f"coarse schedule has {len(hp.inter_plan.schedule.rounds)} "
                f"rounds for horizon {R}"))
            return out
        group_plans = [hp.pod_plans[p].plan for p in sorted(set(hp.rep))]
        group_plans.append(hp.inter_plan)

    # ---- containment / conservation against the original schedule
    pod_of = [0] * n
    local_of = [0] * n
    for p, ranks in enumerate(hp.pods):
        for j, r in enumerate(ranks):
            pod_of[r] = p
            local_of[r] = j
    if len(hp.boundary) != R:
        out.append(InvariantViolation(
            "boundary-length", "boundary",
            f"{len(hp.boundary)} boundary rounds for horizon {R}"))
        return out
    for i, rnd in enumerate(sched.rounds):
        cross: Counter = Counter()
        inside: List[Counter] = [Counter() for _ in range(P)]
        for t in rnd.transfers:
            if t.src == t.dst:
                continue
            ps, pd = pod_of[t.src], pod_of[t.dst]
            if ps == pd:
                inside[ps][(local_of[t.src], local_of[t.dst])] += 1
            else:
                cross[(ps, pd)] += 1
        if tuple(sorted(cross.items())) != hp.boundary[i]:
            out.append(InvariantViolation(
                "boundary-conservation", f"round {i}",
                "declared cross-pod pod-pair multiplicities disagree with "
                "the schedule's actual cross-pod traffic"))
        if P > 1 and hp.inter_plan is not None:
            crnd = hp.inter_plan.schedule.rounds[i]
            executed = Counter(
                (t.src, t.dst) for t in crnd.transfers if t.src != t.dst)
            if set(executed) != set(cross):
                out.append(InvariantViolation(
                    "inter-containment", f"round {i}",
                    "coarse round's pod pairs are not exactly the round's "
                    "cross-pod pairs"))
            if crnd.size != rnd.size:
                out.append(InvariantViolation(
                    "inter-size", f"round {i}",
                    f"coarse round carries {crnd.size} bytes, original "
                    f"round {rnd.size}"))
        for pp in hp.pod_plans:
            psched = pp.plan.schedule
            if len(psched.rounds) != R:
                out.append(InvariantViolation(
                    "pod-rounds", f"pod {pp.pod_index}",
                    f"pod plan has {len(psched.rounds)} rounds for "
                    f"horizon {R}"))
                return out
            prnd = psched.rounds[i]
            executed = Counter(
                (t.src, t.dst) for t in prnd.transfers if t.src != t.dst)
            if executed != inside[pp.pod_index]:
                out.append(InvariantViolation(
                    "pod-containment", f"pod {pp.pod_index} round {i}",
                    "pod's executed transfers are not exactly the original "
                    "round's traffic inside the pod"))
            if prnd.size != rnd.size:
                out.append(InvariantViolation(
                    "pod-size", f"pod {pp.pod_index} round {i}",
                    f"pod round carries {prnd.size} bytes, original round "
                    f"{rnd.size}"))

    # ---- stitching arithmetic: barrier-synced independent groups
    if len(hp.round_costs) != R:
        out.append(InvariantViolation(
            "round-costs-length", "stitching",
            f"{len(hp.round_costs)} round costs for horizon {R}"))
        return out
    for i in range(R):
        want = max((gp.steps[i].total for gp in group_plans), default=0.0)
        if not _close(hp.round_costs[i], want):
            out.append(InvariantViolation(
                "round-cost-stitching", f"round {i}",
                f"stitched cost {hp.round_costs[i]:.6g}, slowest group "
                f"gives {want:.6g}"))
    if not _close(hp.total_cost, sum(hp.round_costs)):
        out.append(InvariantViolation(
            "total-cost", "stitching",
            f"round costs sum to {sum(hp.round_costs):.6g}, plan claims "
            f"{hp.total_cost:.6g}"))
    if hp.final_topology is not None:
        out.append(InvariantViolation(
            "final-topology", "stitching",
            "hierarchical plans cannot thread a single final topology"))
    return out


def check_mode_monotonicity(
    g0: Topology,
    standard: Sequence[Topology],
    schedule: Schedule,
    hw: HardwareParams,
    r_link: Optional[float] = None,
) -> List[InvariantViolation]:
    """Planned cost must satisfy overlap ≤ partial ≤ serial pointwise.

    Partial reconfiguration is capped at the full-fabric delay, so for every
    transition it is ≤ serial; overlap only ever subtracts.  The optimal
    plan under a pointwise-cheaper pricing can therefore never cost more.
    """
    if r_link is None:
        r_link = hw.reconfig_delay_per_link
    if r_link is None:
        n_edges = max(len(g0.edges), 1)
        r_link = hw.reconfig_delay / (2 * n_edges)
    serial = replace(hw, reconfig_delay_per_link=None, overlap=False)
    partial = serial.with_link_reconfig(r_link)
    overlap = serial.with_link_reconfig(r_link, overlap=True)
    costs = {m.reconfig_mode: plan(g0, standard, schedule, m).total_cost
             for m in (serial, partial, overlap)}
    out: List[InvariantViolation] = []
    if costs["partial"] > costs["serial"] + _ABS_TOL + _REL_TOL * costs["serial"]:
        out.append(InvariantViolation(
            "mode-monotonicity", "partial vs serial",
            f"partial {costs['partial']:.6g} > serial {costs['serial']:.6g}"))
    if costs["overlap"] > costs["partial"] + _ABS_TOL + _REL_TOL * costs["partial"]:
        out.append(InvariantViolation(
            "mode-monotonicity", "overlap vs partial",
            f"overlap {costs['overlap']:.6g} > partial {costs['partial']:.6g}"))
    return out


# -------------------------------------------------------- concurrent accounting


def check_concurrent_plan(
    cp: ConcurrentPlan, g0: Topology, standard: Sequence[Topology]
) -> List[InvariantViolation]:
    """Replay a joint plan through the planner's own evaluator."""
    out: List[InvariantViolation] = []
    schedules = [g.schedule for g in cp.groups]
    structures = [build_structure(g0, standard, sch, cp.hw) for sch in schedules]
    # offsets=() predates the arrival-offset field (and means all-zero)
    ev = _JointState(
        g0, structures, schedules, cp.hw, offsets=cp.offsets or None
    )

    seqs = []
    for gi, grp in enumerate(cp.groups):
        if len(grp.states) != cp.n_rounds:
            out.append(InvariantViolation(
                "seq-length", f"group {gi}",
                f"{len(grp.states)} states for horizon {cp.n_rounds}"))
            return out
        ns = len(structures[gi].states)
        for i, s in enumerate(grp.states):
            if not 0 <= s < ns:
                out.append(InvariantViolation(
                    "state-index", f"group {gi} round {i}", f"state {s} of {ns}"))
                return out
            name = structures[gi].states[s].topo.name
            if grp.state_names[i] != name:
                out.append(InvariantViolation(
                    "state-name", f"group {gi} round {i}",
                    f"plan names {grp.state_names[i]!r}, structure has {name!r}"))
        seqs.append(tuple(grp.states))

    total, comm, reconf, final_vec = ev.evaluate(seqs)
    if not _close(total, cp.joint_cost):
        out.append(InvariantViolation(
            "joint-cost", "plan",
            f"evaluator gives {total:.6g}, plan claims {cp.joint_cost:.6g}"))
    if not _close(float(sum(comm)), cp.comm_cost):
        out.append(InvariantViolation(
            "comm-cost", "plan",
            f"evaluator gives {sum(comm):.6g}, plan claims {cp.comm_cost:.6g}"))
    if not _close(float(sum(reconf)), cp.reconfig_cost):
        out.append(InvariantViolation(
            "reconfig-cost", "plan",
            f"evaluator gives {sum(reconf):.6g}, plan claims "
            f"{cp.reconfig_cost:.6g}"))

    # reconfig charged only on union edge-set changes
    prev = ev.g0_vec
    for i in range(cp.n_rounds):
        u = ev.union_vec([seqs[g][i] for g in range(ev.G)])
        if not np.any(prev ^ u) and reconf[i] != 0.0:
            out.append(InvariantViolation(
                "reconfig-without-change", f"round {i}",
                f"charged {reconf[i]:.6g} with an unchanged union edge set"))
        prev = u

    # every group's traffic routes inside its own allocated topology
    # (joint-round indices: a group with an arrival offset moves traffic
    # only inside its [offset, offset + rounds) window; loads() is empty
    # outside it and the check below is vacuous there)
    for g in range(ev.G):
        for i in range(ev.R):
            if not ev.pairs[g][i]:
                continue
            ld = ev.loads(g, i, seqs[g][i])
            if ld is None:
                out.append(InvariantViolation(
                    "group-unroutable", f"group {g} round {i}",
                    f"traffic does not route on allocated state "
                    f"{structures[g].states[seqs[g][i]].topo.name}"))
                continue
            idx, _ = ld
            alloc = ev.inc[g][seqs[g][i]]
            if idx.shape[0] and not alloc[idx].all():
                out.append(InvariantViolation(
                    "alloc-escape", f"group {g} round {i}",
                    "routed load touches edges outside the group's allocation"))

    # never worse than the sequential-independent baseline
    seq_cost = float(sum(g.solo.total_cost for g in cp.groups))
    if not _close(seq_cost, cp.sequential_cost):
        out.append(InvariantViolation(
            "sequential-cost", "plan",
            f"solo plans sum to {seq_cost:.6g}, plan claims "
            f"{cp.sequential_cost:.6g}"))
    if cp.serialized != (cp.joint_cost > cp.sequential_cost):
        out.append(InvariantViolation(
            "serialized-flag", "plan",
            f"serialized={cp.serialized} with joint {cp.joint_cost:.6g} vs "
            f"sequential {cp.sequential_cost:.6g}"))
    bound = min(cp.joint_cost, cp.sequential_cost)
    if cp.total_cost > bound + _ABS_TOL + _REL_TOL * bound:
        out.append(InvariantViolation(
            "never-worse", "plan",
            f"total {cp.total_cost:.6g} exceeds min(joint, sequential) "
            f"{bound:.6g}"))
    return out


# ------------------------------------------------------------------ aggregation


def check_schedule(
    schedule: Schedule,
    hw: Optional[HardwareParams] = None,
    *,
    realizability: bool = False,
) -> List[InvariantViolation]:
    """Round feasibility (+ optionally Alg. 3/4 realizability) for a schedule."""
    out = check_round_feasibility(schedule, hw)
    if realizability:
        out += check_circuit_realizability(schedule)
    return out


def assert_invariants(violations: Sequence[InvariantViolation]) -> None:
    if violations:
        raise PlanInvariantError(violations)
