"""Static analyzer for the Pallas kernels — grid/BlockSpec dataflow proofs
plus an AST precision/hygiene lint.

The schedule verifier (PR 6) gave collectives a device-free proof
substrate; this module gives the kernels layer the same, ahead of the
ROADMAP's comm/compute-fusion work (fused epilogues are only worth building
on kernels that are provably race-free).  Per captured
:class:`~repro.analysis.pallas_model.CallSite` it proves:

* **coverage** — every element of every output is written by *exactly one*
  writer class.  Programs whose index map ignores some grid axes form one
  class (a TPU grid iterates sequentially, so revisiting a block along an
  ignored axis — ssd's carried ``fin`` output — is a serialization, not a
  race); gaps and overlaps are reported with the offending program ids and
  block coordinates.
* **write-race freedom** — two programs that differ in a *depended-on* grid
  axis must never map to overlapping output footprints; outputs aliasing an
  input (``input_output_aliases``) must read and write the identical
  footprint at every grid point.
* **bounds** — every in/out block footprint stays inside the (padded)
  operand shape at every grid point (rmsnorm's pad-then-slice path, flash's
  causal streaming: the *spec-level* footprints; in-kernel dynamic slices
  like flash's ``last_kb`` skip are the kernel body's job and are covered
  by the interpret-mode parity tests, not this pass).
* **scratch-carry discipline** — a VMEM scratch that is both read and
  written (ssd's ``state_ref``) carries state across grid steps, which is
  only legal when (a) a ``pl.when(program_id(k) == 0)``-guarded reset
  exists, (b) its axis ``k`` is the innermost grid dimension (the only one
  Pallas TPU iterates fastest, so the carry sequence is contiguous), and
  (c) that axis is not declared ``parallel`` in ``dimension_semantics``.
  Both structure checks are AST-level (:func:`summarize_kernel`).
* **precision/hygiene (AST)** — sub-fp32 operand reads must upcast to fp32
  before arithmetic (``.astype(jnp.float32)`` on the ref read), sub-fp32
  output stores must cast on store (``.astype(o_ref.dtype)``), kernel
  parameters that are unused or only ever multiplied by a literal zero are
  dead (the rule that caught flash's ``q_offset_blocks``), and the VMEM
  working set (double-buffered in/out blocks + scratch) must fit a budget.

Entry points: :func:`analyze_call_site` (one captured model),
:func:`analyze_callable` (capture a wrapper, analyze every site),
:func:`verify_entry_point` (memoized, used by the ``kernels/*/ops.py``
dispatchers under ``PCCL_VERIFY=1``), and :func:`run_shipped` /
``python -m repro.analysis --kernels`` (the CI gate over the three shipped
kernels).  A seeded mutation corpus (``tests/test_kernel_lint.py``)
measures the kill rate on corrupted index maps, off-by-one grids, swapped
block dims and dropped resets.

No device execution anywhere; JAX is only imported for capture.
"""

from __future__ import annotations

import ast
import inspect
import itertools
import textwrap
import threading
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Set, Tuple

import numpy as np

from .pallas_model import Box, CallSite, CaptureError, capture_call_sites

__all__ = [
    "KernelLintError",
    "KernelReport",
    "KernelSummary",
    "KernelViolation",
    "KINDS",
    "analyze_call_site",
    "analyze_callable",
    "assert_kernel_clean",
    "run_shipped",
    "shipped_kernel_cases",
    "summarize_kernel",
    "verify_entry_point",
]

#: Violation kinds emitted by the analyzer (stable identifiers for tests).
KINDS = (
    "grid-empty",          # a grid dimension is <= 0
    "grid-unenumerable",   # too many programs to enumerate exactly
    "oob-read",            # an input footprint leaves the operand shape
    "oob-write",           # an output footprint leaves the output shape
    "write-race",          # two writer classes touch overlapping footprints
    "coverage-gap",        # some output elements are never written
    "coverage-misaligned", # unaligned writer set too large to check exactly
    "alias-mismatch",      # input_output_aliases with unequal footprints
    "scratch-no-reset",    # carried scratch without a pl.when(id==0) reset
    "scratch-carry-axis",  # reset axis is not the innermost grid dimension
    "scratch-carry-parallel",  # carry axis declared parallel
    "missing-store-cast",  # sub-fp32 output stored without .astype(ref.dtype)
    "low-precision-read",  # sub-fp32 operand read without fp32 upcast
    "dead-param",          # kernel param unused or only multiplied by zero
    "vmem-budget",         # estimated VMEM working set exceeds the budget
)

#: Default VMEM working-set budget (one TPU core's VMEM).
DEFAULT_VMEM_BUDGET = 16 * 1024 * 1024

#: Exact-enumeration cap: grids beyond this report grid-unenumerable
#: instead of silently sampling.
MAX_PROGRAMS = 1 << 17

_SUB_FP32 = ("bfloat16", "float16")


class KernelLintError(AssertionError):
    """Raised by :func:`assert_kernel_clean` / :func:`verify_entry_point`."""

    def __init__(self, reports: Sequence["KernelReport"]):
        self.reports = tuple(reports)
        super().__init__("\n".join(str(r) for r in reports))


@dataclass(frozen=True)
class KernelViolation:
    """One attributable kernel-lint failure."""

    kind: str
    site: str                              # call-site (kernel) name
    operand: Optional[str] = None          # "out[0]" / "in[2]" / "scratch[0]" / param
    program: Optional[Tuple[int, ...]] = None  # offending program id(s)
    box: Optional[Tuple[int, ...]] = None  # block coords or element offset
    detail: str = ""

    def __str__(self) -> str:
        loc = self.site
        if self.operand is not None:
            loc += f" {self.operand}"
        msg = f"{loc} [{self.kind}]"
        if self.program is not None:
            msg += f" program {self.program}"
        if self.box is not None:
            msg += f" block {self.box}"
        if self.detail:
            msg += f": {self.detail}"
        return msg


@dataclass(frozen=True)
class KernelReport:
    """Outcome of analyzing one call site."""

    site: str
    grid: Tuple[int, ...]
    programs_checked: int
    violations: Tuple[KernelViolation, ...] = ()

    @property
    def ok(self) -> bool:
        return not self.violations

    def __str__(self) -> str:
        head = f"{self.site} grid={self.grid}"
        if self.ok:
            return f"{head}: clean over {self.programs_checked} programs"
        lines = [f"{head}: {len(self.violations)} violation(s)"]
        lines += [f"  {v}" for v in self.violations]
        return "\n".join(lines)


# ------------------------------------------------------------ AST summary


@dataclass
class KernelSummary:
    """Structural facts about one kernel body, extracted from its AST."""

    fn_name: str
    in_params: Tuple[str, ...] = ()
    out_params: Tuple[str, ...] = ()
    scratch_params: Tuple[str, ...] = ()
    config_params: Tuple[str, ...] = ()
    reads: Set[str] = field(default_factory=set)       # subscript loads
    writes: Set[str] = field(default_factory=set)      # subscript stores
    carried_reads: Set[str] = field(default_factory=set)  # loads outside resets
    resets: Dict[str, Set[int]] = field(default_factory=dict)  # scratch → axes
    raw_reads: Dict[str, List[int]] = field(default_factory=dict)  # no fp32 upcast
    uncast_stores: Dict[str, List[int]] = field(default_factory=dict)
    uses: Dict[str, int] = field(default_factory=dict)      # Name loads per param
    zero_uses: Dict[str, int] = field(default_factory=dict)  # uses inside *0
    parsed: bool = True  # False when the source was unavailable


def _unwrap(fn: Callable) -> Callable:
    while hasattr(fn, "func") and callable(getattr(fn, "func")):
        fn = fn.func
    return fn


def _is_float32(node: ast.expr) -> bool:
    if isinstance(node, ast.Attribute):
        return node.attr == "float32"
    if isinstance(node, ast.Name):
        return node.id == "float32"
    return isinstance(node, ast.Constant) and node.value == "float32"


def _is_dtype_of(node: ast.expr, params: Sequence[str]) -> bool:
    """``<ref>.dtype`` where ``<ref>`` is an out/in/scratch param."""
    return (isinstance(node, ast.Attribute) and node.attr == "dtype"
            and isinstance(node.value, ast.Name) and node.value.id in params)


def _program_id_axis(node: ast.expr, aliases: Dict[str, int]) -> Optional[int]:
    """Resolve an expression to a grid axis: ``pl.program_id(k)`` inline or
    a local alias previously assigned from one."""
    if isinstance(node, ast.Name):
        return aliases.get(node.id)
    if (isinstance(node, ast.Call) and isinstance(node.func, ast.Attribute)
            and node.func.attr == "program_id" and node.args
            and isinstance(node.args[0], ast.Constant)):
        return int(node.args[0].value)
    return None


def _reset_axis(dec: ast.expr, aliases: Dict[str, int]) -> Optional[int]:
    """Axis ``k`` of a ``pl.when(<program_id(k)> == 0)`` decorator."""
    if not (isinstance(dec, ast.Call) and isinstance(dec.func, ast.Attribute)
            and dec.func.attr == "when" and dec.args):
        return None
    cond = dec.args[0]
    if not (isinstance(cond, ast.Compare) and len(cond.ops) == 1
            and isinstance(cond.ops[0], ast.Eq)):
        return None
    left, right = cond.left, cond.comparators[0]
    for a, b in ((left, right), (right, left)):
        if isinstance(b, ast.Constant) and b.value == 0:
            axis = _program_id_axis(a, aliases)
            if axis is not None:
                return axis
    return None


class _KernelVisitor(ast.NodeVisitor):
    def __init__(self, summary: KernelSummary, all_params: Sequence[str]):
        self.s = summary
        self.all_params = tuple(all_params)
        self.aliases: Dict[str, int] = {}
        self._in_reset: List[str] = []  # scratch names the current pl.when resets
        self._mult_zero_depth = 0

    # -- helpers

    def _subscript_base(self, node: ast.Subscript) -> Optional[str]:
        base = node.value
        if isinstance(base, ast.Name) and base.id in self.all_params:
            return base.id
        return None

    def _product_has_zero(self, node: ast.BinOp) -> bool:
        """True when a (possibly nested) multiplication chain has a literal
        zero factor — the whole product is statically zero."""
        factors: List[ast.expr] = []

        def flatten(n: ast.expr) -> None:
            if isinstance(n, ast.BinOp) and isinstance(n.op, ast.Mult):
                flatten(n.left)
                flatten(n.right)
            else:
                factors.append(n)

        flatten(node)
        return any(isinstance(f, ast.Constant) and f.value == 0 for f in factors)

    # -- visitors

    def visit_Assign(self, node: ast.Assign) -> None:
        # program-id aliases: `ci = pl.program_id(1)`
        axis = _program_id_axis(node.value, self.aliases)
        if axis is not None:
            for tgt in node.targets:
                if isinstance(tgt, ast.Name):
                    self.aliases[tgt.id] = axis
        for tgt in node.targets:
            if isinstance(tgt, ast.Subscript):
                name = self._subscript_base(tgt)
                if name is not None:
                    self.s.writes.add(name)
                    if name in self._in_reset and name in self.s.scratch_params:
                        pass  # reset store, recorded via the decorator
                    if (name in self.s.out_params
                            and not self._is_cast_store(node.value)):
                        self.s.uncast_stores.setdefault(name, []).append(
                            node.lineno)
        self.generic_visit(node)

    def _is_cast_store(self, value: ast.expr) -> bool:
        return (isinstance(value, ast.Call)
                and isinstance(value.func, ast.Attribute)
                and value.func.attr == "astype"
                and len(value.args) == 1
                and _is_dtype_of(value.args[0], self.all_params))

    def visit_Subscript(self, node: ast.Subscript) -> None:
        if isinstance(node.ctx, ast.Load):
            name = self._subscript_base(node)
            if name is not None:
                self.s.reads.add(name)
                if name in self.s.scratch_params and not self._in_reset:
                    self.s.carried_reads.add(name)
        self.generic_visit(node)

    def visit_Call(self, node: ast.Call) -> None:
        # mark `<ref>[...].astype(jnp.float32)` reads as upcast
        if (isinstance(node.func, ast.Attribute) and node.func.attr == "astype"
                and isinstance(node.func.value, ast.Subscript)):
            name = self._subscript_base(node.func.value)
            if (name is not None and len(node.args) == 1
                    and _is_float32(node.args[0])):
                # visit children but skip the raw-read bookkeeping below
                self.s.reads.add(name)
                if name in self.s.scratch_params and not self._in_reset:
                    self.s.carried_reads.add(name)
                for a in node.args:
                    self.visit(a)
                self.visit(node.func.value.slice)
                return
        self.generic_visit(node)

    def visit_Name(self, node: ast.Name) -> None:
        if isinstance(node.ctx, ast.Load) and node.id in self.all_params:
            self.s.uses[node.id] = self.s.uses.get(node.id, 0) + 1
            if self._mult_zero_depth:
                self.s.zero_uses[node.id] = self.s.zero_uses.get(node.id, 0) + 1

    def visit_BinOp(self, node: ast.BinOp) -> None:
        if isinstance(node.op, ast.Mult) and self._product_has_zero(node):
            self._mult_zero_depth += 1
            self.generic_visit(node)
            self._mult_zero_depth -= 1
        else:
            self.generic_visit(node)

    def visit_FunctionDef(self, node: ast.FunctionDef) -> None:
        reset_axes = [
            _reset_axis(dec, self.aliases) for dec in node.decorator_list
        ]
        reset_axes = [a for a in reset_axes if a is not None]
        if reset_axes:
            stored = {
                self._subscript_base(t)
                for stmt in ast.walk(node)
                if isinstance(stmt, ast.Assign)
                for t in stmt.targets
                if isinstance(t, ast.Subscript)
            }
            stored_scratch = [
                s for s in stored if s in self.s.scratch_params
            ]
            self._in_reset = stored_scratch
            for s in stored_scratch:
                self.s.resets.setdefault(s, set()).update(reset_axes)
        for dec in node.decorator_list:
            self.visit(dec)
        for stmt in node.body:
            self.visit(stmt)
        self._in_reset = []

    visit_AsyncFunctionDef = visit_FunctionDef  # type: ignore[assignment]


def _collect_raw_reads(tree: ast.AST, summary: KernelSummary) -> None:
    """Second pass: subscript loads of in-params NOT wrapped in
    ``.astype(jnp.float32)`` (checked via parent inspection)."""
    parents: Dict[ast.AST, ast.AST] = {}
    for node in ast.walk(tree):
        for child in ast.iter_child_nodes(node):
            parents[child] = node
    for node in ast.walk(tree):
        if not (isinstance(node, ast.Subscript)
                and isinstance(node.ctx, ast.Load)):
            continue
        base = node.value
        if not (isinstance(base, ast.Name) and base.id in summary.in_params):
            continue
        p = parents.get(node)
        upcast = False
        if (isinstance(p, ast.Attribute) and p.attr == "astype"):
            call = parents.get(p)
            if (isinstance(call, ast.Call) and len(call.args) == 1
                    and _is_float32(call.args[0])):
                upcast = True
        if not upcast:
            summary.raw_reads.setdefault(base.id, []).append(node.lineno)


def summarize_kernel(
    kernel: Callable, n_in: int, n_out: int, n_scratch: int
) -> KernelSummary:
    """AST-level structural summary of a kernel body.

    ``kernel`` may be a ``functools.partial``; the positional parameters of
    the unwrapped function are split ``[in refs | out refs | scratch refs]``
    by the counts from the captured call site, and keyword-only parameters
    are the config params.  When the source is unavailable (defined in a
    REPL / exec), ``parsed=False`` and the AST rules are skipped — model
    checks still run.
    """
    fn = _unwrap(kernel)
    name = getattr(fn, "__name__", str(fn))
    try:
        src = textwrap.dedent(inspect.getsource(fn))
        tree = ast.parse(src)
        sig = inspect.signature(fn)
    except (OSError, TypeError, SyntaxError):
        return KernelSummary(fn_name=name, parsed=False)
    positional = [
        p.name for p in sig.parameters.values()
        if p.kind in (p.POSITIONAL_ONLY, p.POSITIONAL_OR_KEYWORD)
    ]
    config = [
        p.name for p in sig.parameters.values() if p.kind == p.KEYWORD_ONLY
    ]
    if len(positional) != n_in + n_out + n_scratch:
        # signature/spec mismatch: let the model checks speak; don't guess
        return KernelSummary(fn_name=name, parsed=False)
    summary = KernelSummary(
        fn_name=name,
        in_params=tuple(positional[:n_in]),
        out_params=tuple(positional[n_in:n_in + n_out]),
        scratch_params=tuple(positional[n_in + n_out:]),
        config_params=tuple(config),
    )
    fndef = next(
        (n for n in ast.walk(tree) if isinstance(n, ast.FunctionDef)
         and n.name == name), None,
    )
    if fndef is None:
        return KernelSummary(fn_name=name, parsed=False)
    visitor = _KernelVisitor(summary, positional + config)
    for stmt in fndef.body:
        visitor.visit(stmt)
    _collect_raw_reads(fndef, summary)
    return summary


# -------------------------------------------------------- model-side checks


def _check_grid(site: CallSite, out: List[KernelViolation]) -> bool:
    ok = True
    for a, g in enumerate(site.grid):
        if g <= 0:
            out.append(KernelViolation(
                "grid-empty", site.name, program=None,
                detail=f"grid axis {a} has extent {g}"))
            ok = False
    if ok and site.num_programs > MAX_PROGRAMS:
        out.append(KernelViolation(
            "grid-unenumerable", site.name,
            detail=f"{site.num_programs} programs > cap {MAX_PROGRAMS}; "
                   "cannot prove coverage exactly"))
        ok = False
    return ok


def _check_bounds(
    site: CallSite, programs: Sequence[Tuple[int, ...]],
    out: List[KernelViolation], max_per_spec: int = 3,
) -> Dict[str, Dict[Tuple[int, ...], Tuple[int, ...]]]:
    """Bounds for every spec at every program; returns out-spec offset maps
    (program → element offset) for the coverage pass."""
    offsets: Dict[str, Dict[Tuple[int, ...], Tuple[int, ...]]] = {}
    for role, blocks, shapes in (
        ("in", site.in_blocks, site.in_shapes),
        ("out", site.out_blocks, site.out_shapes),
    ):
        kind = "oob-read" if role == "in" else "oob-write"
        for i, (blk, shape) in enumerate(zip(blocks, shapes)):
            label = f"{role}[{i}]"
            per_prog: Dict[Tuple[int, ...], Tuple[int, ...]] = {}
            reported = 0
            for p in programs:
                try:
                    box = blk.footprint(p)
                except Exception as e:  # index map crashed on this id
                    out.append(KernelViolation(
                        kind, site.name, label, p,
                        detail=f"index map failed: {e}"))
                    reported += 1
                    if reported >= max_per_spec:
                        break
                    continue
                per_prog[p] = box.offset
                if not box.within(shape):
                    if reported < max_per_spec:
                        out.append(KernelViolation(
                            kind, site.name, label, p, box.offset,
                            detail=f"footprint {box.offset}+{box.size} "
                                   f"outside operand shape {shape}"))
                    reported += 1
            if reported > max_per_spec:
                out.append(KernelViolation(
                    kind, site.name, label,
                    detail=f"… {reported - max_per_spec} more programs "
                           "out of bounds"))
            if role == "out":
                offsets[label] = per_prog
    return offsets


def _dependent_axes(
    programs: Sequence[Tuple[int, ...]],
    offset_of: Dict[Tuple[int, ...], Tuple[int, ...]],
    n_axes: int,
) -> Set[int]:
    """Grid axes whose variation (others fixed) ever changes the footprint."""
    dep: Set[int] = set()
    for a in range(n_axes):
        seen: Dict[Tuple[int, ...], Tuple[int, ...]] = {}
        for p in programs:
            key = p[:a] + p[a + 1:]
            off = offset_of.get(p)
            if off is None:
                continue
            prev = seen.get(key)
            if prev is None:
                seen[key] = off
            elif prev != off:
                dep.add(a)
                break
    return dep


def _check_output_coverage(
    site: CallSite, programs: Sequence[Tuple[int, ...]],
    offsets: Dict[str, Dict[Tuple[int, ...], Tuple[int, ...]]],
    out: List[KernelViolation],
) -> None:
    for oi, (blk, shape) in enumerate(zip(site.out_blocks, site.out_shapes)):
        label = f"out[{oi}]"
        per_prog = offsets.get(label, {})
        if len(per_prog) != len(programs):
            continue  # bounds pass already reported index-map failures
        sizes = blk.sizes
        dep = _dependent_axes(programs, per_prog, len(site.grid))
        dep_sorted = sorted(dep)
        # writer class = projection onto depended-on axes; single-axis
        # invariance composes, so each class maps to exactly one box
        class_rep: Dict[Tuple[int, ...], Tuple[int, ...]] = {}
        class_off: Dict[Tuple[int, ...], Tuple[int, ...]] = {}
        for p in programs:
            cls = tuple(p[a] for a in dep_sorted)
            if cls not in class_rep:
                class_rep[cls] = p
                class_off[cls] = per_prog[p]
        # parallel revisit: an ignored axis with extent > 1 that is declared
        # parallel means concurrent programs rewrite the same block
        if site.dimension_semantics is not None:
            for a, sem in enumerate(site.dimension_semantics):
                if (sem == "parallel" and a not in dep
                        and a < len(site.grid) and site.grid[a] > 1):
                    out.append(KernelViolation(
                        "write-race", site.name, label,
                        detail=f"grid axis {a} is parallel but {label}'s "
                               "index map ignores it: "
                               f"{site.grid[a]} programs write each block"))
        aligned = all(
            s > 0 and all(off[d] % s == 0 for off in class_off.values())
            for d, s in enumerate(sizes)
        )
        if aligned:
            # boxes are cells of a regular lattice: identical or disjoint
            cell_of: Dict[Tuple[int, ...], Tuple[int, ...]] = {}
            race = 0
            for cls, off in class_off.items():
                cell = tuple(o // s for o, s in zip(off, sizes))
                other = cell_of.get(cell)
                if other is not None:
                    if race < 3:
                        out.append(KernelViolation(
                            "write-race", site.name, label,
                            program=class_rep[cls], box=cell,
                            detail="also written by program "
                                   f"{class_rep[other]}"))
                    race += 1
                else:
                    cell_of[cell] = cls
            if race > 3:
                out.append(KernelViolation(
                    "write-race", site.name, label,
                    detail=f"… {race - 3} more colliding writer classes"))
            # coverage: the lattice must tile the output exactly
            ragged = [d for d, s in enumerate(sizes) if shape[d] % s]
            if ragged:
                out.append(KernelViolation(
                    "coverage-gap", site.name, label,
                    detail=f"block {sizes} does not divide output "
                           f"shape {shape} on dims {ragged}"))
                continue
            expected = [shape[d] // s for d, s in enumerate(sizes)]
            total = 1
            for e in expected:
                total *= e
            if len(cell_of) < total:
                missing = []
                for cell in itertools.product(*(range(e) for e in expected)):
                    if cell not in cell_of:
                        missing.append(cell)
                        if len(missing) == 3:
                            break
                out.append(KernelViolation(
                    "coverage-gap", site.name, label, box=missing[0],
                    detail=f"{total - len(cell_of)} of {total} blocks never "
                           f"written; first missing block coords: {missing}"))
        else:
            classes = list(class_off.items())
            if len(classes) > 2048:
                out.append(KernelViolation(
                    "coverage-misaligned", site.name, label,
                    detail=f"{len(classes)} unaligned writer classes; "
                           "exact overlap check refused"))
                continue
            overlap = False
            for (c1, o1), (c2, o2) in itertools.combinations(classes, 2):
                if Box(o1, sizes).overlaps(Box(o2, sizes)):
                    out.append(KernelViolation(
                        "write-race", site.name, label,
                        program=class_rep[c1], box=o1,
                        detail=f"overlaps program {class_rep[c2]} at {o2}"))
                    overlap = True
                    break
            if not overlap:
                vol = sum(Box(o, sizes).volume for _, o in classes)
                want = 1
                for d in shape:
                    want *= d
                if vol < want:
                    out.append(KernelViolation(
                        "coverage-gap", site.name, label,
                        detail=f"disjoint writes cover {vol} of {want} "
                               "elements"))


def _check_aliases(
    site: CallSite, programs: Sequence[Tuple[int, ...]],
    out: List[KernelViolation],
) -> None:
    for i_in, i_out in site.input_output_aliases:
        label = f"in[{i_in}]~out[{i_out}]"
        if i_in >= len(site.in_blocks) or i_out >= len(site.out_blocks):
            out.append(KernelViolation(
                "alias-mismatch", site.name, label,
                detail="alias index out of range"))
            continue
        if site.in_shapes[i_in] != site.out_shapes[i_out] or (
                site.in_dtypes[i_in] != site.out_dtypes[i_out]):
            out.append(KernelViolation(
                "alias-mismatch", site.name, label,
                detail=f"aliased buffers differ: "
                       f"{site.in_shapes[i_in]}/{site.in_dtypes[i_in]} vs "
                       f"{site.out_shapes[i_out]}/{site.out_dtypes[i_out]}"))
            continue
        bi, bo = site.in_blocks[i_in], site.out_blocks[i_out]
        for p in programs:
            if bi.footprint(p) != bo.footprint(p):
                out.append(KernelViolation(
                    "alias-mismatch", site.name, label, p,
                    detail=f"read footprint {bi.footprint(p).offset} != "
                           f"write footprint {bo.footprint(p).offset}; an "
                           "aliased operand must be consumed exactly where "
                           "it is overwritten"))
                break


def _check_scratch_carry(
    site: CallSite, summary: KernelSummary, out: List[KernelViolation]
) -> None:
    if not summary.parsed:
        return
    innermost = len(site.grid) - 1
    for si, name in enumerate(summary.scratch_params):
        carried = name in summary.carried_reads and name in summary.writes
        if not carried:
            continue
        label = f"scratch[{si}]({name})"
        axes = summary.resets.get(name)
        if not axes:
            out.append(KernelViolation(
                "scratch-no-reset", site.name, label,
                detail="scratch is read and written across grid steps but "
                       "has no pl.when(program_id == 0) reset: the first "
                       "step of every outer program observes stale state"))
            continue
        for axis in sorted(axes):
            if axis != innermost:
                out.append(KernelViolation(
                    "scratch-carry-axis", site.name, label,
                    detail=f"carry reset keys on grid axis {axis}, but only "
                           f"the innermost axis {innermost} iterates "
                           "contiguously per outer program on TPU"))
            if (site.dimension_semantics is not None
                    and axis < len(site.dimension_semantics)
                    and site.dimension_semantics[axis] == "parallel"):
                out.append(KernelViolation(
                    "scratch-carry-parallel", site.name, label,
                    detail=f"carry axis {axis} is declared parallel; carried "
                           "VMEM state requires sequential iteration"))


def _check_precision(
    site: CallSite, summary: KernelSummary, out: List[KernelViolation]
) -> None:
    if not summary.parsed:
        return
    for i, name in enumerate(summary.in_params):
        if site.in_dtypes[i] in _SUB_FP32 and name in summary.raw_reads:
            lines = summary.raw_reads[name]
            out.append(KernelViolation(
                "low-precision-read", site.name, f"in[{i}]({name})",
                detail=f"{site.in_dtypes[i]} operand read without "
                       f".astype(jnp.float32) at line(s) {lines}: "
                       "accumulation must be fp32"))
    for i, name in enumerate(summary.out_params):
        if site.out_dtypes[i] in _SUB_FP32 and name in summary.uncast_stores:
            lines = summary.uncast_stores[name]
            out.append(KernelViolation(
                "missing-store-cast", site.name, f"out[{i}]({name})",
                detail=f"store to {site.out_dtypes[i]} output without "
                       f".astype({name}.dtype) at line(s) {lines}"))


def _check_dead_params(
    site: CallSite, summary: KernelSummary, out: List[KernelViolation]
) -> None:
    if not summary.parsed:
        return
    ref_params = summary.in_params + summary.out_params + summary.scratch_params
    for name in ref_params + summary.config_params:
        uses = summary.uses.get(name, 0)
        # ref params are used via subscripts, which count as Name loads too
        if name in ref_params and (
                name in summary.reads or name in summary.writes):
            continue
        if uses == 0:
            out.append(KernelViolation(
                "dead-param", site.name, name,
                detail="kernel parameter is never used"))
        elif summary.zero_uses.get(name, 0) >= uses:
            out.append(KernelViolation(
                "dead-param", site.name, name,
                detail="every use is multiplied by a literal 0 — the "
                       "parameter has no effect"))


def _dtype_bytes(name: str) -> int:
    return np.dtype(name).itemsize


def _check_vmem(
    site: CallSite, vmem_budget: Optional[int], out: List[KernelViolation]
) -> None:
    if vmem_budget is None:
        return
    # Pallas double-buffers pipelined in/out blocks; scratch is single
    block_bytes = 0
    for blocks, dtypes in ((site.in_blocks, site.in_dtypes),
                           (site.out_blocks, site.out_dtypes)):
        for blk, dt in zip(blocks, dtypes):
            b = _dtype_bytes(dt)
            for s in blk.sizes:
                b *= s
            block_bytes += b
    scratch_bytes = 0
    for shape, dt in zip(site.scratch_shapes, site.scratch_dtypes):
        b = _dtype_bytes(dt)
        for s in shape:
            b *= s
        scratch_bytes += b
    est = 2 * block_bytes + scratch_bytes
    if est > vmem_budget:
        out.append(KernelViolation(
            "vmem-budget", site.name,
            detail=f"estimated VMEM working set {est} B (2×{block_bytes} B "
                   f"double-buffered blocks + {scratch_bytes} B scratch) "
                   f"exceeds budget {vmem_budget} B"))


# ------------------------------------------------------------- entry points


def analyze_call_site(
    site: CallSite,
    *,
    summary: Optional[KernelSummary] = None,
    vmem_budget: Optional[int] = DEFAULT_VMEM_BUDGET,
) -> KernelReport:
    """Run every rule over one captured call site.

    ``summary`` overrides the AST extraction (the mutation corpus corrupts
    summaries directly, e.g. to model a dropped reset); by default it is
    derived from ``site.kernel``.
    """
    out: List[KernelViolation] = []
    if not _check_grid(site, out):
        return KernelReport(site.name, site.grid, 0, tuple(out))
    programs = list(itertools.product(*(range(g) for g in site.grid)))
    offsets = _check_bounds(site, programs, out)
    _check_output_coverage(site, programs, offsets, out)
    _check_aliases(site, programs, out)
    if summary is None and site.kernel is not None:
        summary = summarize_kernel(
            site.kernel, len(site.in_blocks), len(site.out_blocks),
            len(site.scratch_shapes),
        )
    if summary is not None:
        _check_scratch_carry(site, summary, out)
        _check_precision(site, summary, out)
        _check_dead_params(site, summary, out)
    _check_vmem(site, vmem_budget, out)
    return KernelReport(site.name, site.grid, len(programs), tuple(out))


def analyze_callable(
    fn: Callable, *args: Any,
    vmem_budget: Optional[int] = DEFAULT_VMEM_BUDGET,
    **kwargs: Any,
) -> List[KernelReport]:
    """Capture ``fn(*args, **kwargs)`` and analyze every reached call site."""
    sites = capture_call_sites(fn, *args, **kwargs)
    return [analyze_call_site(s, vmem_budget=vmem_budget) for s in sites]


def assert_kernel_clean(fn: Callable, *args: Any, **kwargs: Any) -> List[KernelReport]:
    """Analyze and raise :class:`KernelLintError` on any violation."""
    reports = analyze_callable(fn, *args, **kwargs)
    bad = [r for r in reports if not r.ok]
    if bad:
        raise KernelLintError(bad)
    return reports


# ------------------------------------------- PCCL_VERIFY entry-point gating

_VERIFY_LOCK = threading.Lock()
_VERIFIED: "Dict[Any, bool]" = {}  # signature → clean (bounded)
_VERIFIED_MAX = 256


def _signature(label: str, args: Sequence[Any], kwargs: Dict[str, Any]) -> Any:
    shapes = tuple(
        (tuple(a.shape), str(a.dtype)) if hasattr(a, "shape") else repr(a)
        for a in args
    )
    statics = tuple(sorted((k, repr(v)) for k, v in kwargs.items()))
    return (label, shapes, statics)


def verify_entry_point(
    label: str, fn: Callable, args: Sequence[Any],
    kwargs: Optional[Dict[str, Any]] = None,
) -> None:
    """``PCCL_VERIFY=1`` hook for the ``kernels/*/ops.py`` dispatchers.

    Captures and analyzes the wrapper once per (label, shape/dtype
    signature, static kwargs) — repeats are an O(1) cache hit under the
    lock — and raises :class:`KernelLintError` on any violation, *before*
    the real ``pallas_call`` runs.  Tracer arguments are fine: only shapes
    and dtypes are read.
    """
    kwargs = dict(kwargs or {})
    key = _signature(label, args, kwargs)
    with _VERIFY_LOCK:
        if key in _VERIFIED:
            return
    reports = analyze_callable(fn, *args, **kwargs)
    bad = [r for r in reports if not r.ok]
    if bad:
        raise KernelLintError(bad)
    with _VERIFY_LOCK:
        if len(_VERIFIED) >= _VERIFIED_MAX:
            _VERIFIED.clear()
        _VERIFIED[key] = True


def clear_verified_cache() -> None:
    """Drop the entry-point verification memo (tests)."""
    with _VERIFY_LOCK:
        _VERIFIED.clear()


# ------------------------------------------------------- shipped kernel zoo


def shipped_kernel_cases() -> List[Tuple[str, Callable, Tuple[Any, ...], Dict[str, Any]]]:
    """(label, wrapper, abstract args, kwargs) for every shipped Pallas
    kernel, at shapes that exercise the interesting paths: GQA head
    mapping + causal streaming (flash), row *and* lane padding (rmsnorm),
    the chunk-carried scratch + sequence padding (ssd), the K-carried
    accumulator + reduce-scatter-chunk epilogue shapes (matmul)."""
    import jax
    import jax.numpy as jnp

    from ..kernels.flash.kernel import flash_attention_pallas
    from ..kernels.matmul.kernel import matmul_pallas
    from ..kernels.rmsnorm.kernel import rmsnorm_pallas
    from ..kernels.ssd.kernel import ssd_pallas

    def sds(shape, dtype=jnp.float32):
        return jax.ShapeDtypeStruct(shape, dtype)

    cases: List[Tuple[str, Callable, Tuple[Any, ...], Dict[str, Any]]] = []
    # flash: GQA 2:1, bf16 (exercises the precision rules), causal
    cases.append((
        "flash causal gqa",
        flash_attention_pallas,
        (sds((2, 256, 4, 32), jnp.bfloat16), sds((2, 256, 2, 32), jnp.bfloat16),
         sds((2, 256, 2, 32), jnp.bfloat16)),
        dict(causal=True, block_q=128, block_k=128),
    ))
    cases.append((
        "flash non-causal",
        flash_attention_pallas,
        (sds((1, 256, 2, 64)), sds((1, 256, 2, 64)), sds((1, 256, 2, 64))),
        dict(causal=False, block_q=64, block_k=128),
    ))
    # rmsnorm: row padding (300 → 512) AND lane padding (100 → 128)
    cases.append((
        "rmsnorm padded rows+lanes",
        rmsnorm_pallas,
        (sds((300, 100), jnp.bfloat16), sds((100,))),
        dict(block_rows=256),
    ))
    cases.append((
        "rmsnorm aligned",
        rmsnorm_pallas,
        (sds((512, 128)), sds((128,))),
        dict(block_rows=128),
    ))
    # matmul: fp32 scratch accumulator carried over the innermost K axis,
    # multi-K-block so the @pl.when reset/epilogue pair is load-bearing
    cases.append((
        "matmul epilogue multi-k",
        matmul_pallas,
        (sds((256, 256)), sds((256, 128))),
        dict(block_m=64, block_n=128, block_k=128),
    ))
    # matmul at the fused reduce-scatter chunk shape (bf16 precision rules)
    cases.append((
        "matmul fused-chunk bf16",
        matmul_pallas,
        (sds((32, 128), jnp.bfloat16), sds((128, 128), jnp.bfloat16)),
        dict(block_m=32),
    ))
    # ssd: carried state scratch; S=80 pads to 96 with chunk 32
    cases.append((
        "ssd carried state",
        ssd_pallas,
        (sds((1, 80, 2, 16), jnp.bfloat16), sds((1, 80, 2)),
         sds((1, 80, 2, 8), jnp.bfloat16), sds((1, 80, 2, 8), jnp.bfloat16)),
        dict(chunk=32),
    ))
    return cases


def run_shipped(verbose: bool = True) -> int:
    """Analyze every shipped kernel case; print one line per case.

    The CI ``verify`` stage runs this as ``python -m repro.analysis
    --kernels``; returns the number of failing cases.
    """
    failures = 0
    for label, fn, args, kwargs in shipped_kernel_cases():
        try:
            reports = analyze_callable(fn, *args, **kwargs)
        except CaptureError as e:
            print(f"[kernels] {label}: CAPTURE FAILED: {e}")
            failures += 1
            continue
        bad = [r for r in reports if not r.ok]
        if bad:
            failures += 1
            if verbose:
                for r in bad:
                    print(f"[kernels] {label}: {r}")
        elif verbose:
            checked = sum(r.programs_checked for r in reports)
            print(f"[kernels] {label}: clean "
                  f"({len(reports)} call site(s), {checked} programs)")
    return failures
