"""AST-based concurrency lint for the repro tree.

The repo's planner/executor stack keeps process-wide shared state — the
shortest-path / linear-topology / edge-load caches in ``core/cost_model.py``,
the transition cache in ``core/planner.py``, the exec-engine LRUs and trace
counter in ``comm/exec_engine.py``, and the per-session plan/structure caches
in ``api/session.py`` — all of which must be mutated under their owning lock
(sessions and the executor are explicitly documented as thread-safe).  PRs
2–4 fixed several bugs of exactly three shapes; this pass flags them
statically:

* **UG01 unguarded-global-mutation** — a module-level mutable (or a name in
  the shared-cache registry below) is mutated without holding the lock that
  guards it elsewhere in the module.  The owning lock is *inferred*: if any
  mutation of ``G`` happens inside ``with L:``, every mutation of ``G`` must
  hold one of ``G``'s observed locks.  Registry names must always be
  guarded, even if the module never locks them.
* **CG01 unguarded-attr-mutation** — same discipline for instance state: in
  a class that owns a lock attribute (``self._lock = threading.Lock()``),
  any mutation of a shared attribute (one mutated under the lock somewhere,
  or a mutable container assigned in ``__init__``) outside a
  ``with self.<lock>:`` block and outside ``__init__``.
* **FA01 function-attribute-state** — state stashed on a function object
  (``fn.cache = …``): invisible to locks, shared across threads, and the
  bug class behind the PR-2 ``last_objs`` fix.
* **MD01 mutable-default** — mutable default argument values.

Objects that lock internally (``StructureTable``, the exec-engine
``_LruCache`` instances, ``PlanCache``) are safe to *call* from anywhere;
only rebinding those module globals is a mutation.  A finding can be
suppressed by putting ``# lint-ok`` on the offending line (used sparingly,
with a reason in a comment).

Run as ``python -m repro.analysis.lint_concurrency [paths…]`` (the CI lint
stage does) — prints findings and exits non-zero if any.
"""

from __future__ import annotations

import ast
import sys
from dataclasses import dataclass
from pathlib import Path
from typing import Dict, List, Optional, Sequence, Set, Tuple

#: Module-level names that are always shared across threads and must only be
#: mutated under a lock, regardless of what this one module's AST shows.
SHARED_CACHE_REGISTRY = {
    "_SP_CACHE",          # core/cost_model: shortest-path factor cache
    "_LINEAR_CACHE",      # core/cost_model: linear-topology label cache
    "_EDGE_LOAD_CACHE",   # core/cost_model: per-edge load cache
    "_TRANS_CACHE",       # core/planner: transition-cost table cache
    "_TRACES",            # comm/exec_engine: retrace counter
}

#: Module-level singletons that serialize internally; calling their methods
#: needs no external lock, but *rebinding* them is still a mutation.
INTERNALLY_LOCKED = {"STRUCTURE_TABLE", "_COMPILED", "EXECUTABLES"}

_MUTATOR_METHODS = {
    "append", "appendleft", "add", "clear", "discard", "extend", "insert",
    "move_to_end", "pop", "popitem", "popleft", "remove", "setdefault",
    "sort", "update",
}
_MUTABLE_FACTORIES = {
    "dict", "list", "set", "OrderedDict", "defaultdict", "Counter", "deque",
}
_LOCK_FACTORIES = {"Lock", "RLock"}


@dataclass(frozen=True)
class Finding:
    path: str
    line: int
    rule: str
    name: str
    message: str

    def __str__(self) -> str:
        return f"{self.path}:{self.line}: {self.rule} [{self.name}] {self.message}"


@dataclass(frozen=True)
class _Mutation:
    name: str
    line: int
    locks: Tuple[str, ...]  # canonical lock tokens held at the site
    func: str               # enclosing function / method name


def _call_name(node: ast.expr) -> Optional[str]:
    """Trailing name of a call target: ``threading.Lock`` → ``Lock``."""
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Attribute):
        return node.attr
    return None


def _is_lock_factory(value: ast.expr) -> bool:
    return (isinstance(value, ast.Call)
            and _call_name(value.func) in _LOCK_FACTORIES)


def _is_mutable_literal(value: ast.expr) -> bool:
    if isinstance(value, (ast.Dict, ast.List, ast.Set, ast.DictComp,
                          ast.ListComp, ast.SetComp)):
        return True
    return (isinstance(value, ast.Call)
            and _call_name(value.func) in _MUTABLE_FACTORIES)


def _lock_token(expr: ast.expr) -> Optional[str]:
    """Canonical token for a ``with`` context manager that is a lock-ish
    name: ``Name`` → that name, ``self.X`` → ``self.X``.  ``None`` for
    anything else (contextlib helpers, file handles, …)."""
    if isinstance(expr, ast.Name):
        return expr.id
    if (isinstance(expr, ast.Attribute) and isinstance(expr.value, ast.Name)
            and expr.value.id == "self"):
        return f"self.{expr.attr}"
    return None


def _mutation_target(node: ast.expr) -> Optional[Tuple[str, str]]:
    """Classify the container a store/del/mutator-call touches.

    Returns ``(kind, name)`` with kind ``global`` (module-level name),
    ``attr`` (``self.<name>``), or ``None`` when the base is a local/other
    expression.  ``module.NAME`` counts as a global mutation of ``NAME`` so
    cross-module pokes at registry caches are caught too.
    """
    # peel subscripts: G[k], self.a[k], G[k][j]…
    while isinstance(node, ast.Subscript):
        node = node.value
    if isinstance(node, ast.Name):
        return ("global", node.id)
    if isinstance(node, ast.Attribute) and isinstance(node.value, ast.Name):
        if node.value.id == "self":
            return ("attr", node.attr)
        return ("global", node.attr)  # module.NAME
    return None


class _FunctionScanner(ast.NodeVisitor):
    """Collects mutation events inside one function body, tracking the
    stack of held locks across ``with`` blocks."""

    def __init__(self, func_name: str, module_funcs: Set[str]):
        self.func_name = func_name
        self.module_funcs = module_funcs
        self.locks: List[str] = []
        self.globals_declared: Set[str] = set()
        self.mutations: List[_Mutation] = []  # global-kind events
        self.attr_mutations: List[_Mutation] = []  # self.<attr> events
        self.func_attr_stores: List[Tuple[str, int]] = []
        self.lock_attr_defs: Set[str] = set()  # self.X = threading.Lock()
        self.mutable_attr_defs: Set[str] = set()  # self.X = {} / [] / dict()…
        self.attr_rebinds: List[_Mutation] = []

    # ---- helpers

    def _held(self) -> Tuple[str, ...]:
        return tuple(self.locks)

    def _record_target(self, tgt: ast.expr, line: int, *, is_rebind: bool) -> None:
        cls = _mutation_target(tgt)
        if cls is None:
            return
        kind, name = cls
        if kind == "global":
            if isinstance(tgt, ast.Name):
                # plain `G = …` only mutates shared state when declared global
                if is_rebind and name not in self.globals_declared:
                    return
            if (isinstance(tgt, ast.Attribute)
                    and name not in SHARED_CACHE_REGISTRY
                    and name not in INTERNALLY_LOCKED):
                # f.attr = … — function-attribute state when f is a function
                if isinstance(tgt.value, ast.Name) and tgt.value.id in self.module_funcs:
                    self.func_attr_stores.append((tgt.value.id, line))
                return
            self.mutations.append(
                _Mutation(name, line, self._held(), self.func_name))
        else:
            if is_rebind and isinstance(tgt, ast.Attribute):
                self.attr_rebinds.append(
                    _Mutation(name, line, self._held(), self.func_name))
            else:
                self.attr_mutations.append(
                    _Mutation(name, line, self._held(), self.func_name))

    # ---- visitors

    def visit_Global(self, node: ast.Global) -> None:
        self.globals_declared.update(node.names)

    def visit_With(self, node: ast.With) -> None:
        tokens = [t for item in node.items
                  if (t := _lock_token(item.context_expr)) is not None]
        self.locks.extend(tokens)
        for item in node.items:
            self.visit(item.context_expr)
        for stmt in node.body:
            self.visit(stmt)
        del self.locks[len(self.locks) - len(tokens):]

    def visit_Assign(self, node: ast.Assign) -> None:
        for tgt in node.targets:
            self._record_target(tgt, node.lineno,
                                is_rebind=not isinstance(tgt, ast.Subscript))
            # remember lock / mutable-container attribute definitions
            if (isinstance(tgt, ast.Attribute) and isinstance(tgt.value, ast.Name)
                    and tgt.value.id == "self"):
                if _is_lock_factory(node.value):
                    self.lock_attr_defs.add(tgt.attr)
                elif _is_mutable_literal(node.value):
                    self.mutable_attr_defs.add(tgt.attr)
        self.visit(node.value)

    def visit_AugAssign(self, node: ast.AugAssign) -> None:
        self._record_target(node.target, node.lineno,
                            is_rebind=isinstance(node.target, ast.Name))
        self.visit(node.value)

    def visit_AnnAssign(self, node: ast.AnnAssign) -> None:
        if node.value is not None:
            self._record_target(node.target, node.lineno,
                                is_rebind=not isinstance(node.target, ast.Subscript))
            tgt = node.target
            if (isinstance(tgt, ast.Attribute) and isinstance(tgt.value, ast.Name)
                    and tgt.value.id == "self"):
                if _is_lock_factory(node.value):
                    self.lock_attr_defs.add(tgt.attr)
                elif _is_mutable_literal(node.value):
                    self.mutable_attr_defs.add(tgt.attr)
            self.visit(node.value)

    def visit_Delete(self, node: ast.Delete) -> None:
        for tgt in node.targets:
            if isinstance(tgt, ast.Subscript):
                self._record_target(tgt, node.lineno, is_rebind=False)

    def visit_Call(self, node: ast.Call) -> None:
        if (isinstance(node.func, ast.Attribute)
                and node.func.attr in _MUTATOR_METHODS):
            base = node.func.value
            cls = _mutation_target(base)
            if cls is not None:
                kind, name = cls
                if kind == "global" and not isinstance(base, ast.Name):
                    # module.NAME.mutate(…): only registry names are shared
                    if name not in SHARED_CACHE_REGISTRY:
                        cls = None
                if cls is not None and name not in INTERNALLY_LOCKED:
                    m = _Mutation(name, node.lineno, self._held(), self.func_name)
                    (self.mutations if kind == "global"
                     else self.attr_mutations).append(m)
        self.generic_visit(node)

    def visit_FunctionDef(self, node: ast.FunctionDef) -> None:
        # nested defs execute later, possibly without the current locks —
        # scan them with an empty lock stack
        inner = _FunctionScanner(f"{self.func_name}.{node.name}", self.module_funcs)
        for stmt in node.body:
            inner.visit(stmt)
        inner.globals_declared |= self.globals_declared
        self.mutations.extend(inner.mutations)
        self.attr_mutations.extend(inner.attr_mutations)
        self.attr_rebinds.extend(inner.attr_rebinds)
        self.func_attr_stores.extend(inner.func_attr_stores)

    visit_AsyncFunctionDef = visit_FunctionDef  # type: ignore[assignment]


def _check_defaults(fn: ast.FunctionDef, path: str, out: List[Finding]) -> None:
    args = fn.args
    for default in list(args.defaults) + [d for d in args.kw_defaults if d]:
        if _is_mutable_literal(default):
            out.append(Finding(
                path, default.lineno, "MD01", fn.name,
                "mutable default argument is shared across calls"))


def lint_module(path: str, source: Optional[str] = None) -> List[Finding]:
    """Run all rules over one module; returns unsuppressed findings."""
    if source is None:
        source = Path(path).read_text()
    try:
        tree = ast.parse(source, filename=path)
    except SyntaxError as e:
        return [Finding(path, e.lineno or 0, "PARSE", "-", str(e))]
    lines = source.splitlines()

    module_funcs: Set[str] = set()
    module_locks: Set[str] = set()
    module_mutables: Set[str] = set()
    for stmt in tree.body:
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
            module_funcs.add(stmt.name)
        elif isinstance(stmt, (ast.Assign, ast.AnnAssign)):
            targets = stmt.targets if isinstance(stmt, ast.Assign) else [stmt.target]
            value = stmt.value
            if value is None:
                continue
            for tgt in targets:
                if not isinstance(tgt, ast.Name):
                    continue
                if _is_lock_factory(value):
                    module_locks.add(tgt.id)
                elif _is_mutable_literal(value):
                    module_mutables.add(tgt.id)

    out: List[Finding] = []
    global_events: List[_Mutation] = []

    def scan_function(fn: ast.FunctionDef, qual: str) -> _FunctionScanner:
        _check_defaults(fn, path, out)
        sc = _FunctionScanner(qual, module_funcs)
        for stmt in fn.body:
            sc.visit(stmt)
        for fname, line in sc.func_attr_stores:
            out.append(Finding(
                path, line, "FA01", fname,
                "state stored on a function object is unsynchronized "
                "process-global state"))
        global_events.extend(sc.mutations)
        return sc

    # ---- module functions and classes
    for stmt in tree.body:
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
            scan_function(stmt, stmt.name)
        elif isinstance(stmt, ast.ClassDef):
            methods = [s for s in stmt.body
                       if isinstance(s, (ast.FunctionDef, ast.AsyncFunctionDef))]
            scanners = [(m.name, scan_function(m, f"{stmt.name}.{m.name}"))
                        for m in methods]
            lock_attrs = set().union(*(sc.lock_attr_defs for _, sc in scanners)) \
                if scanners else set()
            if not lock_attrs:
                continue
            lock_tokens = {f"self.{a}" for a in lock_attrs}
            shared: Set[str] = set()
            guarded_by: Dict[str, Set[str]] = {}
            events: List[Tuple[str, _Mutation]] = []
            for mname, sc in scanners:
                for ev in sc.attr_mutations + sc.attr_rebinds:
                    if ev.name in lock_attrs:
                        continue
                    events.append((mname, ev))
                    held = set(ev.locks) & lock_tokens
                    if held:
                        shared.add(ev.name)
                        guarded_by.setdefault(ev.name, set()).update(held)
                if mname == "__init__":
                    shared |= sc.mutable_attr_defs
            for mname, ev in events:
                if mname == "__init__" or ev.name not in shared:
                    continue
                owners = guarded_by.get(ev.name, lock_tokens)
                if not set(ev.locks) & owners:
                    out.append(Finding(
                        path, ev.line, "CG01", f"self.{ev.name}",
                        f"mutated in {ev.func} without holding "
                        f"{' / '.join(sorted(owners))}"))

    # ---- UG01: module-global lock discipline
    interesting = module_mutables | SHARED_CACHE_REGISTRY | INTERNALLY_LOCKED
    guarded: Dict[str, Set[str]] = {}
    for ev in global_events:
        if ev.name in interesting and set(ev.locks) & module_locks:
            guarded.setdefault(ev.name, set()).update(set(ev.locks) & module_locks)
    for ev in global_events:
        if ev.name not in interesting:
            continue
        must_guard = (ev.name in SHARED_CACHE_REGISTRY
                      or ev.name in INTERNALLY_LOCKED
                      or ev.name in guarded)
        if not must_guard:
            continue  # module never locks this name: no intent to infer from
        owners = guarded.get(ev.name, module_locks)
        if not set(ev.locks) & owners:
            hint = " / ".join(sorted(owners)) if owners else "a lock"
            out.append(Finding(
                path, ev.line, "UG01", ev.name,
                f"mutated in {ev.func} without holding {hint}"))

    # ---- suppression
    def suppressed(f: Finding) -> bool:
        return 0 < f.line <= len(lines) and "# lint-ok" in lines[f.line - 1]

    return sorted((f for f in out if not suppressed(f)),
                  key=lambda f: (f.path, f.line, f.rule))


def lint_paths(paths: Sequence[str]) -> List[Finding]:
    findings: List[Finding] = []
    for p in paths:
        path = Path(p)
        files = sorted(path.rglob("*.py")) if path.is_dir() else [path]
        for f in files:
            findings.extend(lint_module(str(f)))
    return findings


def main(argv: Optional[Sequence[str]] = None) -> int:
    args = list(sys.argv[1:] if argv is None else argv)
    paths = args or ["src/repro"]
    findings = lint_paths(paths)
    for f in findings:
        print(f)
    print(f"concurrency lint: {len(findings)} finding(s) in "
          f"{', '.join(paths)}")
    return 1 if findings else 0


if __name__ == "__main__":
    raise SystemExit(main())
