"""Abstract model of ``pl.pallas_call`` sites — the kernel-side analogue of
the schedule verifier's chunk lattice.

A Pallas TPU kernel is, statically, a *grid* of programs plus one
``BlockSpec`` per operand: the index map sends a program id tuple to block
coordinates, the block shape scales those to an element-space footprint.
Everything the kernel lint proves (coverage, write-race freedom, bounds,
scratch-carry discipline — see :mod:`repro.analysis.kernel_lint`) is a
property of these footprints, so this module extracts them **without any
device execution**:

* :func:`capture_call_sites` runs a kernel *wrapper* (e.g.
  ``flash_attention_pallas``) under :func:`jax.eval_shape` with
  ``pl.pallas_call`` temporarily replaced by a recorder — the wrapper's own
  reshapes/pads/transposes trace abstractly, the recorder stores the grid,
  specs, operand/out shapes and returns zeros of ``out_shape``, and nothing
  is compiled or executed.
* :class:`BlockModel.footprint` evaluates one index map at one enumerated
  program id and returns the element-space :class:`Box` (``None`` block
  dims are squeezed: size 1, offset = the raw coordinate; sized dims scale
  the block coordinate by the block extent — Pallas semantics).

JAX is imported lazily (only :func:`capture_call_sites` needs it), so
importing this module from jax-free analysis contexts stays cheap.  The
recorder patches the public ``jax.experimental.pallas.pallas_call``
attribute, which covers every call site written as ``pl.pallas_call(...)``
against a ``from jax.experimental import pallas as pl`` import — the only
idiom in this tree (enforced by the shipped-kernel registry in
``kernel_lint``).  Capture is serialized under a module lock because the
patch is process-global.
"""

from __future__ import annotations

import threading
from dataclasses import dataclass, replace
from typing import Any, Callable, List, Optional, Sequence, Tuple

import numpy as np

__all__ = [
    "BlockModel",
    "Box",
    "CallSite",
    "CaptureError",
    "capture_call_sites",
    "whole_array_box",
]


class CaptureError(RuntimeError):
    """A wrapper could not be captured (no pallas_call reached, bad specs)."""


# ----------------------------------------------------------------- geometry


@dataclass(frozen=True)
class Box:
    """An axis-aligned element-space footprint: ``[offset, offset+size)``."""

    offset: Tuple[int, ...]
    size: Tuple[int, ...]

    def __post_init__(self) -> None:
        if len(self.offset) != len(self.size):
            raise ValueError(f"rank mismatch: {self.offset} vs {self.size}")

    @property
    def volume(self) -> int:
        v = 1
        for s in self.size:
            v *= s
        return v

    @property
    def end(self) -> Tuple[int, ...]:
        return tuple(o + s for o, s in zip(self.offset, self.size))

    def within(self, shape: Sequence[int]) -> bool:
        """True when the whole box lies inside ``[0, shape)``."""
        return len(shape) == len(self.offset) and all(
            0 <= o and o + s <= d for o, s, d in zip(self.offset, self.size, shape)
        )

    def overlaps(self, other: "Box") -> bool:
        return all(
            o1 < o2 + s2 and o2 < o1 + s1
            for o1, s1, o2, s2 in zip(self.offset, self.size, other.offset, other.size)
        )


def whole_array_box(shape: Sequence[int]) -> Box:
    return Box((0,) * len(shape), tuple(int(d) for d in shape))


# -------------------------------------------------------------- block model


@dataclass(frozen=True)
class BlockModel:
    """One operand's ``BlockSpec`` as captured: shape with ``None`` dims
    preserved, plus the raw index map (program ids → block coordinates)."""

    block_shape: Tuple[Optional[int], ...]
    index_map: Callable[..., Tuple[int, ...]]

    @property
    def sizes(self) -> Tuple[int, ...]:
        """Element-space extent per dim (``None`` squeezed dims are 1)."""
        return tuple(1 if b is None else int(b) for b in self.block_shape)

    def coords(self, program: Sequence[int]) -> Tuple[int, ...]:
        out = self.index_map(*program)
        if not isinstance(out, tuple):
            out = (out,)
        if len(out) != len(self.block_shape):
            raise CaptureError(
                f"index map returned {len(out)} coords for a "
                f"{len(self.block_shape)}-dim block {self.block_shape}"
            )
        return tuple(int(c) for c in out)

    def footprint(self, program: Sequence[int]) -> Box:
        """Element-space box this program touches through this spec.

        ``None`` block dims index by element (size-1 slice, then squeezed);
        sized dims index by block, so the offset is ``coord * block_dim``.
        """
        coords = self.coords(program)
        offset = tuple(
            c if b is None else c * int(b)
            for c, b in zip(coords, self.block_shape)
        )
        return Box(offset, self.sizes)


# ---------------------------------------------------------------- call site


@dataclass(frozen=True)
class CallSite:
    """One captured ``pl.pallas_call``: everything the lint needs, nothing
    device-side.  Dtypes are numpy dtype *names* so jax-free consumers can
    compare them without importing JAX."""

    name: str
    grid: Tuple[int, ...]
    in_blocks: Tuple[BlockModel, ...]
    in_shapes: Tuple[Tuple[int, ...], ...]
    in_dtypes: Tuple[str, ...]
    out_blocks: Tuple[BlockModel, ...]
    out_shapes: Tuple[Tuple[int, ...], ...]
    out_dtypes: Tuple[str, ...]
    scratch_shapes: Tuple[Tuple[int, ...], ...] = ()
    scratch_dtypes: Tuple[str, ...] = ()
    kernel: Optional[Callable] = None  # as passed (possibly functools.partial)
    input_output_aliases: Tuple[Tuple[int, int], ...] = ()
    dimension_semantics: Optional[Tuple[str, ...]] = None

    @property
    def num_programs(self) -> int:
        n = 1
        for g in self.grid:
            n *= g
        return n

    def with_in_block(self, i: int, block: BlockModel) -> "CallSite":
        blocks = list(self.in_blocks)
        blocks[i] = block
        return replace(self, in_blocks=tuple(blocks))

    def with_out_block(self, i: int, block: BlockModel) -> "CallSite":
        blocks = list(self.out_blocks)
        blocks[i] = block
        return replace(self, out_blocks=tuple(blocks))


def _dtype_name(dt: Any) -> str:
    return np.dtype(dt).name


def _as_seq(x: Any) -> List[Any]:
    return list(x) if isinstance(x, (list, tuple)) else [x]


def _block_of(spec: Any, operand_shape: Tuple[int, ...]) -> BlockModel:
    """Normalize a captured ``pl.BlockSpec`` into a :class:`BlockModel`.

    A spec with no block shape means "whole array, one block" (the
    memory-space-only form); a missing index map defaults to block 0.
    """
    shape = getattr(spec, "block_shape", None)
    imap = getattr(spec, "index_map", None)
    if shape is None:
        shape = tuple(int(d) for d in operand_shape)
    else:
        shape = tuple(None if b is None else int(b) for b in shape)
    if imap is None:
        ndim = len(shape)

        def imap(*_ids, _ndim=ndim):
            return (0,) * _ndim

    return BlockModel(block_shape=shape, index_map=imap)


def _normalize_grid(grid: Any) -> Tuple[int, ...]:
    if grid is None:
        return ()
    if isinstance(grid, int):
        return (int(grid),)
    return tuple(int(g) for g in grid)


def _dimension_semantics(kw: dict) -> Optional[Tuple[str, ...]]:
    """Pull ``dimension_semantics`` out of ``compiler_params`` when present
    (both the dict form and the TPUCompilerParams object form)."""
    cp = kw.get("compiler_params")
    if cp is None:
        return None
    if isinstance(cp, dict):
        for v in cp.values():
            if isinstance(v, dict) and "dimension_semantics" in v:
                ds = v["dimension_semantics"]
                return tuple(str(s) for s in ds) if ds is not None else None
        ds = cp.get("dimension_semantics")
        return tuple(str(s) for s in ds) if ds is not None else None
    ds = getattr(cp, "dimension_semantics", None)
    return tuple(str(s) for s in ds) if ds is not None else None


# ------------------------------------------------------------------ capture

_CAPTURE_LOCK = threading.Lock()  # the pallas_call patch is process-global


def _abstract(x: Any) -> Any:
    import jax

    if hasattr(x, "shape") and hasattr(x, "dtype"):
        return jax.ShapeDtypeStruct(tuple(x.shape), x.dtype)
    return x


def capture_call_sites(fn: Callable, *args: Any, **kwargs: Any) -> List[CallSite]:
    """Trace ``fn(*args, **kwargs)`` abstractly and record every
    ``pl.pallas_call`` it reaches.

    ``args`` may be concrete arrays or ``ShapeDtypeStruct``s — only shapes
    and dtypes are used.  Returns the call sites in execution order; raises
    :class:`CaptureError` if none is reached (a wrapper that silently takes
    a non-Pallas path must not "pass" the kernel lint vacuously).
    """
    import jax
    import jax.numpy as jnp
    from jax.experimental import pallas as pallas_mod

    sites: List[CallSite] = []

    def fake_pallas_call(kernel, *pargs, **kw):
        out_shape = kw.get("out_shape", pargs[0] if pargs else None)
        if out_shape is None:
            raise CaptureError("pallas_call without out_shape")
        grid = kw.get("grid")
        if grid is None and kw.get("grid_spec") is not None:
            gs = kw["grid_spec"]
            grid = getattr(gs, "grid", None)
            kw = dict(kw, in_specs=getattr(gs, "in_specs", kw.get("in_specs")),
                      out_specs=getattr(gs, "out_specs", kw.get("out_specs")))
        grid_t = _normalize_grid(grid)
        out_leaves = _as_seq(out_shape)
        out_specs = _as_seq(kw.get("out_specs", [None] * len(out_leaves)))
        scratch = _as_seq(kw.get("scratch_shapes", ()) or ())
        aliases = kw.get("input_output_aliases") or {}
        alias_t = tuple(sorted((int(i), int(o)) for i, o in dict(aliases).items()))
        kname = getattr(getattr(kernel, "func", kernel), "__name__", str(kernel))

        def runner(*operands):
            in_specs = _as_seq(kw.get("in_specs", [None] * len(operands)))
            if len(in_specs) != len(operands):
                raise CaptureError(
                    f"{kname}: {len(operands)} operands but "
                    f"{len(in_specs)} in_specs"
                )
            in_shapes = tuple(tuple(int(d) for d in o.shape) for o in operands)
            site = CallSite(
                name=kname,
                grid=grid_t,
                in_blocks=tuple(
                    _block_of(s, shp) for s, shp in zip(in_specs, in_shapes)
                ),
                in_shapes=in_shapes,
                in_dtypes=tuple(_dtype_name(o.dtype) for o in operands),
                out_blocks=tuple(
                    _block_of(s, tuple(l.shape))
                    for s, l in zip(out_specs, out_leaves)
                ),
                out_shapes=tuple(tuple(int(d) for d in l.shape) for l in out_leaves),
                out_dtypes=tuple(_dtype_name(l.dtype) for l in out_leaves),
                scratch_shapes=tuple(
                    tuple(int(d) for d in s.shape) for s in scratch
                ),
                scratch_dtypes=tuple(_dtype_name(s.dtype) for s in scratch),
                kernel=kernel,
                input_output_aliases=alias_t,
                dimension_semantics=_dimension_semantics(kw),
            )
            sites.append(site)
            outs = [jnp.zeros(l.shape, l.dtype) for l in out_leaves]
            return outs[0] if not isinstance(out_shape, (list, tuple)) else outs

        return runner

    abstract_args = tuple(_abstract(a) for a in args)
    with _CAPTURE_LOCK:
        real = pallas_mod.pallas_call
        pallas_mod.pallas_call = fake_pallas_call
        try:
            jax.eval_shape(lambda *a: fn(*a, **kwargs), *abstract_args)
        finally:
            pallas_mod.pallas_call = real
    if not sites:
        raise CaptureError(
            f"{getattr(fn, '__name__', fn)}: no pallas_call reached during "
            "capture (wrapper took a non-Pallas path?)"
        )
    return sites
