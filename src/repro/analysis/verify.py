"""Static chunk-dataflow verifier for collective schedules.

Abstract interpretation over a :class:`~repro.core.schedules.Schedule`'s
rounds: per rank we track a lattice of chunk states —

* **absent** — the rank holds no live copy of the chunk;
* **partial** — a copy reduced over a *contribution mask* (bitmask of source
  ranks whose data has been folded in);
* **complete** — mask == all n ranks;
* **retired** — the rank held a copy but handed it off via a ``reduce``
  send; the physical buffer slot still contains the *stale* bytes.

This is deliberately stronger than the dynamic oracle in
``core/simulate.py``, whose mask-union semantics cannot distinguish a
double-counted contribution from an idempotent re-delivery.  The static
semantics here mirror what the executable interpreter actually does
(``comm/primitives.py``): a ``reduce`` receive is ``buf.at[slot].add(...)``
(so overlapping contributions double-count and adding into a retired slot
folds in stale data), and a store receive is ``buf.at[slot].set(...)``
(overwrite, so a partial store on top of a complete copy *loses* data).

Postconditions proven per collective (chunk-id conventions of
``core/schedules.py``):

* ``reduce_scatter`` — rank ``i`` holds chunk ``i`` reduced over all n
  contributions *exactly once* (disjointness of every merge is checked
  en route, so "exactly once" is structural, not just final-state).
* ``all_gather``    — every rank holds every chunk complete.
* ``all_reduce``    — every rank holds every chunk reduced over all n.
* ``all_to_all``    — rank ``t`` holds block ``s*n + t`` from origin ``s``
  for every ``s`` (origin→destination delivery).
* ``p2p``           — the destination holds the payload.

Failures are attributable: every :class:`Violation` carries the round
index, rank, chunk, a machine-readable ``kind``, and expected vs. actual
abstract state.  Schedules without chunk metadata (e.g. ``swing``, which
models only the (src, dst, w) pattern) raise
:class:`UnverifiableScheduleError` rather than vacuously passing.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

from ..core.schedules import Schedule

Mask = int  # bitmask of contributing ranks

#: Violation kinds emitted by the verifier (stable identifiers for tests).
KINDS = (
    "send-absent",  # rank sends a chunk it holds no live copy of
    "send-retired",  # rank sends a chunk it already handed off (stale bytes)
    "duplicate-contribution",  # reduce merge with overlapping masks
    "stale-slot-reduce",  # reduce lands in a retired slot (adds stale data)
    "reduce-into-absent",  # reduce lands in a slot that was never populated
    "conflicting-store",  # two same-round stores of one chunk disagree
    "mixed-reduce-store",  # one (rank, chunk) gets reduce + store in a round
    "postcondition",  # final abstract state misses the collective's goal
    "bad-rank",  # transfer endpoint outside [0, n)
    "self-transfer",  # src == dst
    "cross-group-transfer",  # transfer crosses a process-group boundary
    "bad-groups",  # groups overlap / rank outside every group
)


def _full_mask(n: int) -> Mask:
    return (1 << n) - 1


def _mask_str(mask: Mask) -> str:
    return "{" + ",".join(str(r) for r in range(mask.bit_length()) if mask >> r & 1) + "}"


class ScheduleVerificationError(AssertionError):
    """Raised by :func:`assert_verified` when a schedule fails verification."""

    def __init__(self, result: "VerificationResult"):
        self.result = result
        super().__init__(str(result))


class UnverifiableScheduleError(ScheduleVerificationError):
    """The schedule carries no chunk metadata, so dataflow cannot be checked."""

    def __init__(self, result: "VerificationResult"):
        super().__init__(result)


@dataclass(frozen=True)
class Violation:
    """One attributable verification failure."""

    kind: str
    round_index: Optional[int]  # None for postcondition violations
    rank: Optional[int]
    chunk: Optional[int]
    expected: str = ""
    actual: str = ""
    group: Optional[int] = None

    def __str__(self) -> str:
        where = "post" if self.round_index is None else f"round {self.round_index}"
        loc = f"{where}: rank {self.rank} chunk {self.chunk}"
        if self.group is not None:
            loc += f" (group {self.group})"
        msg = f"{loc} [{self.kind}]"
        if self.expected or self.actual:
            msg += f" expected {self.expected}, got {self.actual}"
        return msg


@dataclass(frozen=True)
class VerificationResult:
    """Outcome of verifying one schedule."""

    collective: str
    algorithm: str
    n: int
    ok: bool
    verifiable: bool
    violations: Tuple[Violation, ...] = ()
    rounds_checked: int = 0

    def __str__(self) -> str:
        head = f"{self.collective}/{self.algorithm} n={self.n}"
        if not self.verifiable:
            return f"{head}: unverifiable (no chunk metadata)"
        if self.ok:
            return f"{head}: verified over {self.rounds_checked} rounds"
        lines = [f"{head}: {len(self.violations)} violation(s)"]
        lines += [f"  {v}" for v in self.violations]
        return "\n".join(lines)


@dataclass
class _RankState:
    """Abstract per-rank chunk state."""

    held: Dict[int, Mask] = field(default_factory=dict)
    retired: Dict[int, Mask] = field(default_factory=dict)


def _initial_states(schedule: Schedule) -> List[_RankState]:
    n = schedule.n
    states = [_RankState() for _ in range(n)]
    if schedule.collective in ("reduce_scatter", "all_reduce"):
        for r in range(n):
            for c in range(n):
                states[r].held[c] = 1 << r
    elif schedule.collective == "all_gather":
        for r in range(n):
            states[r].held[r] = _full_mask(n)  # AG input is already reduced
    elif schedule.collective == "all_to_all":
        for s in range(n):
            for t in range(n):
                states[s].held[s * n + t] = 1 << s
    elif schedule.collective == "p2p":
        for rnd in schedule.rounds:
            for t in rnd.transfers:
                states[t.src].held[0] = 1 << t.src
                return states
    else:
        raise ValueError(f"unknown collective {schedule.collective!r}")
    return states


def _check_postcondition(
    schedule: Schedule, states: List[_RankState], out: List[Violation], limit: int
) -> None:
    n = schedule.n
    full = _full_mask(n)

    def fail(rank: int, chunk: int, expected: str, actual: str) -> None:
        if len(out) < limit:
            out.append(Violation("postcondition", None, rank, chunk, expected, actual))

    def actual_of(rank: int, chunk: int) -> str:
        st = states[rank]
        if chunk in st.held:
            return f"mask {_mask_str(st.held[chunk])}"
        if chunk in st.retired:
            return f"retired (stale mask {_mask_str(st.retired[chunk])})"
        return "absent"

    if schedule.collective == "reduce_scatter":
        for r in range(n):
            if states[r].held.get(r, 0) != full:
                fail(r, r, f"sum over all {n} contributions", actual_of(r, r))
    elif schedule.collective in ("all_gather", "all_reduce"):
        what = "complete copy" if schedule.collective == "all_gather" else (
            f"sum over all {n} contributions"
        )
        for r in range(n):
            for c in range(n):
                if states[r].held.get(c, 0) != full:
                    fail(r, c, what, actual_of(r, c))
    elif schedule.collective == "all_to_all":
        for t in range(n):
            for s in range(n):
                c = s * n + t
                if states[t].held.get(c, 0) != (1 << s):
                    fail(t, c, f"block {s}->{t} from origin {s}", actual_of(t, c))
    elif schedule.collective == "p2p":
        tr = next((t for rnd in schedule.rounds for t in rnd.transfers), None)
        if tr is None:
            fail(None, 0, "a point-to-point delivery", "empty schedule")
        elif states[tr.dst].held.get(0, 0) != (1 << tr.src):
            fail(tr.dst, 0, f"payload from rank {tr.src}", actual_of(tr.dst, 0))


def _verify_flat(schedule: Schedule, max_violations: int) -> Tuple[List[Violation], int]:
    """Interpret a group-free schedule; returns (violations, rounds checked)."""
    n = schedule.n
    states = _initial_states(schedule)
    out: List[Violation] = []

    def emit(v: Violation) -> None:
        if len(out) < max_violations:
            out.append(v)

    for ri, rnd in enumerate(schedule.rounds):
        # Phase 1: read every send against the pre-round state.
        # incoming[(dst, chunk)] = list of (src, mask, reduce)
        incoming: Dict[Tuple[int, int], List[Tuple[int, Mask, bool]]] = {}
        for t in rnd.transfers:
            if not (0 <= t.src < n and 0 <= t.dst < n):
                emit(Violation("bad-rank", ri, t.src, None,
                               f"ranks in [0,{n})", f"{t.src}->{t.dst}"))
                continue
            if t.src == t.dst:
                emit(Violation("self-transfer", ri, t.src, None,
                               "distinct endpoints", f"{t.src}->{t.dst}"))
                continue
            st = states[t.src]
            for c in t.chunks:
                if c in st.held:
                    incoming.setdefault((t.dst, c), []).append(
                        (t.src, st.held[c], t.reduce)
                    )
                elif c in st.retired:
                    emit(Violation("send-retired", ri, t.src, c,
                                   "live copy",
                                   f"retired (stale mask {_mask_str(st.retired[c])})"))
                else:
                    emit(Violation("send-absent", ri, t.src, c, "live copy", "absent"))

        # Phase 2: apply receives, then retire reduce-sent copies.
        reduce_sent: List[Tuple[int, int]] = []  # (src, chunk) handed off
        for (dst, c), arrivals in incoming.items():
            reduces = [(s, m) for s, m, red in arrivals if red]
            stores = [(s, m) for s, m, red in arrivals if not red]
            if reduces and stores:
                emit(Violation("mixed-reduce-store", ri, dst, c,
                               "a single receive mode",
                               f"{len(reduces)} reduce + {len(stores)} store"))
                continue
            st = states[dst]
            if reduces:
                if c in st.held:
                    acc = st.held[c]
                elif c in st.retired:
                    emit(Violation("stale-slot-reduce", ri, dst, c,
                                   "reduce into a live slot",
                                   f"retired (stale mask {_mask_str(st.retired[c])})"))
                    acc = 0
                else:
                    emit(Violation("reduce-into-absent", ri, dst, c,
                                   "reduce into a populated slot", "absent"))
                    acc = 0
                for s, m in reduces:
                    if acc & m:
                        emit(Violation("duplicate-contribution", ri, dst, c,
                                       "disjoint contribution masks",
                                       f"overlap {_mask_str(acc & m)} from rank {s}"))
                    acc |= m
                st.held[c] = acc
                st.retired.pop(c, None)
                for s, _ in reduces:
                    reduce_sent.append((s, c))
            else:
                masks = {m for _, m in stores}
                if len(masks) > 1:
                    emit(Violation("conflicting-store", ri, dst, c,
                                   "identical same-round stores",
                                   " vs ".join(_mask_str(m) for m in sorted(masks))))
                # overwrite semantics: the slot takes the incoming bytes,
                # whatever was there before (live, retired or absent).
                st.held[c] = stores[-1][1]
                st.retired.pop(c, None)
        for s, c in reduce_sent:
            st = states[s]
            if c in st.held:  # may have been refreshed by a same-round receive
                recv_here = (s, c) in incoming
                if not recv_here:
                    st.retired[c] = st.held.pop(c)

    _check_postcondition(schedule, states, out, max_violations)
    return out, len(schedule.rounds)


def _split_groups(
    schedule: Schedule, groups: Sequence[Sequence[int]], max_violations: int
) -> Tuple[List[Violation], int]:
    """Verify a ``replicate_groups`` composition: each group's sub-schedule is
    checked independently (group-local chunk ids, per the ``Communicator.split``
    convention); transfers crossing a group boundary are violations."""
    from ..core.schedules import Round, Transfer

    out: List[Violation] = []
    rank_to_group: Dict[int, int] = {}
    for gi, g in enumerate(groups):
        for r in g:
            if r in rank_to_group or not 0 <= r < schedule.n:
                out.append(Violation("bad-groups", None, r, None,
                                     "disjoint groups within [0,n)", f"rank {r}"))
                return out, 0
            rank_to_group[r] = gi
    if len(rank_to_group) != schedule.n:
        missing = sorted(set(range(schedule.n)) - set(rank_to_group))
        out.append(Violation("bad-groups", None, missing[0] if missing else None,
                             None, "groups cover every rank",
                             f"{len(missing)} uncovered"))
        return out, 0

    rounds_checked = 0
    for gi, g in enumerate(groups):
        local = {r: i for i, r in enumerate(g)}
        local_rounds: List[Round] = []
        for ri, rnd in enumerate(schedule.rounds):
            transfers = []
            for t in rnd.transfers:
                gs, gd = rank_to_group.get(t.src), rank_to_group.get(t.dst)
                if gs == gi or gd == gi:
                    if gs != gd:
                        if len(out) < max_violations:
                            out.append(Violation(
                                "cross-group-transfer", ri, t.src, None,
                                f"transfer within group {gi}",
                                f"{t.src}(g{gs})->{t.dst}(g{gd})", group=gi))
                        continue
                    if gs == gi:
                        transfers.append(Transfer(local[t.src], local[t.dst],
                                                  t.chunks, t.reduce))
            local_rounds.append(Round(tuple(transfers), rnd.size))
        sub = Schedule(schedule.collective, schedule.algorithm, len(g),
                       schedule.buffer_bytes, tuple(local_rounds))
        sub_viol, checked = _verify_flat(sub, max_violations - len(out))
        rounds_checked = max(rounds_checked, checked)
        for v in sub_viol:
            rank = g[v.rank] if v.rank is not None and v.rank < len(g) else v.rank
            out.append(Violation(v.kind, v.round_index, rank, v.chunk,
                                 v.expected, v.actual, group=gi))
    return out, rounds_checked


def verify_schedule(
    schedule: Schedule,
    *,
    groups: Optional[Sequence[Sequence[int]]] = None,
    max_violations: int = 50,
) -> VerificationResult:
    """Statically verify a schedule's collective postcondition.

    ``groups`` handles :func:`~repro.core.schedules.replicate_groups`
    compositions: each group is verified as an independent ``m``-rank
    sub-collective with group-local chunk ids.

    Returns a :class:`VerificationResult`; never raises on mere violations
    (use :func:`assert_verified` for raise-on-failure semantics).
    """
    has_chunks = any(t.chunks for rnd in schedule.rounds for t in rnd.transfers)
    has_transfers = any(rnd.transfers for rnd in schedule.rounds)
    if has_transfers and not has_chunks:
        return VerificationResult(schedule.collective, schedule.algorithm,
                                  schedule.n, ok=False, verifiable=False)
    if groups is not None:
        violations, checked = _split_groups(schedule, groups, max_violations)
    else:
        violations, checked = _verify_flat(schedule, max_violations)
    return VerificationResult(
        schedule.collective, schedule.algorithm, schedule.n,
        ok=not violations, verifiable=True,
        violations=tuple(violations), rounds_checked=checked,
    )


def assert_verified(
    schedule: Schedule, *, groups: Optional[Sequence[Sequence[int]]] = None
) -> VerificationResult:
    """Verify and raise :class:`ScheduleVerificationError` on any failure.

    Schedules with no chunk metadata raise :class:`UnverifiableScheduleError`
    (a subclass), so "cannot check" is never silently reported as "correct".
    """
    result = verify_schedule(schedule, groups=groups)
    if not result.verifiable:
        raise UnverifiableScheduleError(result)
    if not result.ok:
        raise ScheduleVerificationError(result)
    return result
