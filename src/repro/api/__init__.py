"""Top-level PCCL API: sessions, communicators, pluggable backends.

The front door for application code::

    from repro.api import PcclSession
    from repro.core import cost_model as cm, topology as T

    session = PcclSession(cm.H100_DGX, g0=T.ring(128))
    plan = session.plan("reduce_scatter", 256 * 2**20)   # cached + threaded

    comm = session.communicator("data", 8, backend="interp")
    # inside shard_map:  grads = comm.all_reduce(grads)
    tp = comm.split([r % 2 for r in range(8)])           # DP×TP sub-groups

Legacy entry points (``repro.core.pccl.plan_collective`` and
``repro.comm.PcclComm``) remain as deprecation shims over this package.
"""

from repro.core.pccl import ConcurrentCollectiveRequest, ConcurrentPcclPlan

from .backends import (
    Backend,
    InterpBackend,
    SimBackend,
    XlaBackend,
    get_backend,
    register_backend,
)
from .communicator import Communicator, subgroup_schedule
from .session import (
    AnyPlanRequest,
    CacheStats,
    ConcurrentPlanRequest,
    HierarchicalPlanRequest,
    PcclSession,
    PlanCache,
    PlanRequest,
    PlanSweepRequest,
    ReplanRequest,
    StructureCache,
)

__all__ = [
    "AnyPlanRequest",
    "Backend",
    "CacheStats",
    "Communicator",
    "ConcurrentCollectiveRequest",
    "ConcurrentPcclPlan",
    "ConcurrentPlanRequest",
    "HierarchicalPlanRequest",
    "InterpBackend",
    "PcclSession",
    "PlanCache",
    "PlanRequest",
    "PlanSweepRequest",
    "ReplanRequest",
    "SimBackend",
    "StructureCache",
    "XlaBackend",
    "get_backend",
    "register_backend",
    "subgroup_schedule",
]
