"""Pluggable execution backends for :class:`repro.api.Communicator`.

Three implementations of one protocol:

* ``interp`` — the executable ppermute schedule interpreter
  (``repro.comm.primitives``): every planned round lowers to exactly one
  ``lax.ppermute`` whose permutation *is* the circuit set PCCL would program
  on the photonic fabric.  Call inside ``shard_map``.
* ``xla``    — native ``lax`` collectives; the paper-faithful A/B baseline
  (what ``PcclComm(algorithm="xla")`` used to spell as a string hack).
* ``sim``    — cost-model-only: data passes through with single-copy
  placeholder semantics while the *planned* time of every collective is
  accumulated on ``elapsed_s``.  Lets benchmarks and the serve/launch layers
  drive the identical Communicator API with no devices at all.

JAX is imported lazily so a ``sim``-only process never touches it.
"""

from __future__ import annotations

from typing import TYPE_CHECKING, List, Protocol, Tuple, runtime_checkable

from repro.comm.errors import ScheduleExecutionError  # JAX-free

if TYPE_CHECKING:  # pragma: no cover
    from .communicator import Communicator


@runtime_checkable
class Backend(Protocol):
    """Executes the four PCCL primitives for one communicator."""

    name: str

    def all_reduce(self, comm: "Communicator", x): ...

    def reduce_scatter(self, comm: "Communicator", x): ...

    def all_gather(self, comm: "Communicator", x): ...

    def all_to_all(self, comm: "Communicator", x): ...


def _item_bytes(x) -> int:
    return x.dtype.itemsize


def _check_divisible(x, n: int) -> None:
    """Same leading-dim precondition (and error) as the interp interpreter."""
    if x.shape[0] % n:
        raise ScheduleExecutionError(
            f"leading dim {x.shape[0]} not divisible by {n} ranks"
        )


def _xla_groups(comm: "Communicator"):
    return [list(g) for g in comm.groups] if comm.groups is not None else None


class XlaBackend:
    """Native lax collectives (baseline; no PCCL planning involved)."""

    name = "xla"

    def all_reduce(self, comm, x):
        from jax import lax

        return lax.psum(x, comm.axis_name, axis_index_groups=_xla_groups(comm))

    def reduce_scatter(self, comm, x):
        from jax import lax

        return lax.psum_scatter(
            x, comm.axis_name, scatter_dimension=0, tiled=True,
            axis_index_groups=_xla_groups(comm),
        )

    def all_gather(self, comm, x):
        from jax import lax

        return lax.all_gather(
            x, comm.axis_name, axis=0, tiled=True,
            axis_index_groups=_xla_groups(comm),
        )

    def all_to_all(self, comm, x):
        from jax import lax

        b = x.shape[0] // comm.n
        y = x.reshape((comm.n, b) + x.shape[1:])
        y = lax.all_to_all(
            y, comm.axis_name, split_axis=0, concat_axis=0, tiled=False,
            axis_index_groups=_xla_groups(comm),
        )
        return y.reshape(x.shape)


class InterpBackend:
    """Schedule interpreter: planned rounds → ppermute (inside shard_map)."""

    name = "interp"

    # -- full-axis path reuses the proven primitives wrappers ------------
    def all_reduce(self, comm, x):
        import jax.numpy as jnp

        shape = x.shape
        flat = x.reshape(-1)
        pad = (-flat.size) % comm.n
        if pad:
            flat = jnp.concatenate([flat, jnp.zeros((pad,), flat.dtype)])
        sched = comm.axis_schedule("all_reduce", flat.size * _item_bytes(flat))
        out = self._run(comm, "all_reduce", flat, sched)
        if pad:
            out = out[: out.size - pad]
        return out.reshape(shape)

    def reduce_scatter(self, comm, x):
        sched = comm.axis_schedule("reduce_scatter", x.size * _item_bytes(x))
        return self._run(comm, "reduce_scatter", x, sched)

    def all_gather(self, comm, x):
        sched = comm.axis_schedule("all_gather", x.size * _item_bytes(x) * comm.n)
        return self._run(comm, "all_gather", x, sched)

    def all_to_all(self, comm, x):
        sched = comm.axis_schedule("all_to_all", x.size * _item_bytes(x))
        return self._run(comm, "all_to_all", x, sched)

    # -- dispatch: ungrouped → primitives; grouped → local-rank variants --
    def _run(self, comm, collective, x, sched):
        from repro.comm import primitives as P

        if comm.groups is None:
            return getattr(P, collective)(x, sched, comm.axis_name)
        return _grouped_collective(comm, collective, x, sched)


def _local_index(comm: "Communicator"):
    """me → index within my group, as a traced lookup table."""
    import jax.numpy as jnp
    import numpy as np
    from jax import lax

    table = np.zeros(comm.axis_size, dtype=np.int32)
    for g in comm.groups:
        for i, rank in enumerate(g):
            table[rank] = i
    me = lax.axis_index(comm.axis_name)
    return jnp.take(jnp.asarray(table), me)


def _grouped_collective(comm: "Communicator", collective: str, x, sched):
    """Group-local collectives on a split communicator.

    Mirrors ``repro.comm.primitives`` wrappers with the rank's *group-local*
    index: the composed schedule already routes between global ranks, while
    chunk ids (and local buffers) stay group-local.
    """
    import jax.numpy as jnp

    from repro.comm.primitives import ScheduleExecutionError, execute_schedule

    m = comm.n
    me_local = _local_index(comm)
    if collective in ("reduce_scatter", "all_reduce", "all_to_all") and x.shape[0] % m:
        raise ScheduleExecutionError(
            f"leading dim {x.shape[0]} not divisible by group size {m}"
        )
    if collective == "reduce_scatter":
        chunks = x.reshape((m, x.shape[0] // m) + x.shape[1:])
        chunks = execute_schedule(chunks, sched, comm.axis_name)
        return jnp.take(chunks, me_local, axis=0)
    if collective == "all_reduce":
        chunks = x.reshape((m, x.shape[0] // m) + x.shape[1:])
        chunks = execute_schedule(chunks, sched, comm.axis_name)
        return chunks.reshape(x.shape)
    if collective == "all_gather":
        chunks = jnp.zeros((m,) + x.shape, x.dtype).at[me_local].set(x)
        chunks = execute_schedule(chunks, sched, comm.axis_name)
        return chunks.reshape((m * x.shape[0],) + x.shape[1:])
    if collective == "all_to_all":
        blocks = x.reshape((m, x.shape[0] // m) + x.shape[1:])
        state = jnp.zeros((m, m) + blocks.shape[1:], blocks.dtype)
        state = state.at[me_local].set(blocks)
        flat = state.reshape((m * m,) + blocks.shape[1:])
        flat = execute_schedule(flat, sched, comm.axis_name)
        state = flat.reshape((m, m) + blocks.shape[1:])
        return jnp.take(state, me_local, axis=1).reshape(x.shape)
    raise ScheduleExecutionError(f"unknown collective {collective!r}")


class SimBackend:
    """Cost-model-only execution: accumulate planned time, pass data through.

    Data semantics are single-copy placeholders (the caller holds the only
    logical copy): ``all_reduce``/``all_to_all`` return the input unchanged,
    ``reduce_scatter`` returns **rank 0's** shard slice (there is no real
    rank here, so the first ``shape[0] // n`` rows stand in for "my shard" —
    only the shape is meaningful, not which values land in it),
    ``all_gather`` tiles the shard ``n`` times — shapes match the real
    backends so code paths are identical, but no inter-device data movement
    happens (or is needed).  Shape preconditions (leading-dim divisibility)
    raise the same :class:`~repro.comm.errors.ScheduleExecutionError` as the
    ``interp`` backend instead of silently mis-shaping the output.
    """

    name = "sim"

    def __init__(self) -> None:
        self.elapsed_s = 0.0
        self.events: List[Tuple[str, float, float]] = []  # (coll, nbytes, cost)

    def _charge(self, comm, collective, nbytes) -> None:
        cost = comm.estimate(collective, nbytes)
        self.elapsed_s += cost
        self.events.append((collective, float(nbytes), cost))

    def all_reduce(self, comm, x):
        self._charge(comm, "all_reduce", x.size * _item_bytes(x))
        return x

    def reduce_scatter(self, comm, x):
        _check_divisible(x, comm.n)
        self._charge(comm, "reduce_scatter", x.size * _item_bytes(x))
        return x[: x.shape[0] // comm.n]  # rank 0's shard (placeholder)

    def all_gather(self, comm, x):
        import numpy as np

        self._charge(comm, "all_gather", x.size * _item_bytes(x) * comm.n)
        return np.concatenate([np.asarray(x)] * comm.n, axis=0)

    def all_to_all(self, comm, x):
        _check_divisible(x, comm.n)
        self._charge(comm, "all_to_all", x.size * _item_bytes(x))
        return x


_BACKENDS = {"xla": XlaBackend, "interp": InterpBackend, "sim": SimBackend}


def get_backend(name: str) -> Backend:
    """Fresh backend instance by name (``xla`` | ``interp`` | ``sim``)."""
    try:
        return _BACKENDS[name]()
    except KeyError:
        raise ValueError(
            f"unknown backend {name!r}; available: {sorted(_BACKENDS)}"
        ) from None


def register_backend(name: str, cls) -> None:
    """Extension point: register a custom Backend implementation."""
    _BACKENDS[name] = cls
