"""Pluggable execution backends for :class:`repro.api.Communicator`.

Three implementations of one protocol:

* ``interp`` — the compiled-schedule execution engine
  (``repro.comm.exec_engine`` under ``repro.comm.primitives``): every
  planned round lowers to exactly one ``lax.ppermute`` whose permutation
  *is* the circuit set PCCL would program on the photonic fabric, with
  per-round tables compiled once per schedule and runs of like rounds
  fused into a single ``lax.scan``.  Call inside ``shard_map`` — or call
  with a **concrete** ``(axis_size, *local)`` array and the backend runs it
  through a process-wide cache of jitted ``shard_map`` executables keyed by
  ``(schedule fingerprint, shape, dtype, axis name, group fingerprint)``;
  repeated same-shape collectives then dispatch with zero retraces, and
  shape-preserving collectives (all_reduce, all_to_all) donate the input
  chunk buffer to the executable.
* ``xla``    — native ``lax`` collectives; the paper-faithful A/B baseline
  (what ``PcclComm(algorithm="xla")`` used to spell as a string hack).
* ``sim``    — cost-model-only: data passes through with single-copy
  placeholder semantics while the *planned* time of every collective is
  accumulated on ``elapsed_s``.  Lets benchmarks and the serve/launch layers
  drive the identical Communicator API with no devices at all.

JAX is imported lazily so a ``sim``-only process never touches it.
"""

from __future__ import annotations

from typing import TYPE_CHECKING, List, Optional, Protocol, Tuple, runtime_checkable

from repro.comm.errors import ScheduleExecutionError  # JAX-free

if TYPE_CHECKING:  # pragma: no cover
    from repro.core.schedules import Schedule

    from .communicator import Communicator


@runtime_checkable
class Backend(Protocol):
    """Executes the four PCCL primitives for one communicator."""

    name: str

    def all_reduce(self, comm: "Communicator", x): ...

    def reduce_scatter(self, comm: "Communicator", x): ...

    def all_gather(self, comm: "Communicator", x): ...

    def all_to_all(self, comm: "Communicator", x): ...


def _item_bytes(x) -> int:
    return x.dtype.itemsize


def _check_divisible(x, n: int) -> None:
    """Same leading-dim precondition (and error) as the interp interpreter."""
    if x.shape[0] % n:
        raise ScheduleExecutionError(
            f"leading dim {x.shape[0]} not divisible by {n} ranks"
        )


def _xla_groups(comm: "Communicator"):
    return [list(g) for g in comm.groups] if comm.groups is not None else None


class XlaBackend:
    """Native lax collectives (baseline; no PCCL planning involved)."""

    name = "xla"

    def all_reduce(self, comm, x):
        from jax import lax

        return lax.psum(x, comm.axis_name, axis_index_groups=_xla_groups(comm))

    def reduce_scatter(self, comm, x):
        from jax import lax

        return lax.psum_scatter(
            x, comm.axis_name, scatter_dimension=0, tiled=True,
            axis_index_groups=_xla_groups(comm),
        )

    def all_gather(self, comm, x):
        from jax import lax

        return lax.all_gather(
            x, comm.axis_name, axis=0, tiled=True,
            axis_index_groups=_xla_groups(comm),
        )

    def all_to_all(self, comm, x):
        from jax import lax

        b = x.shape[0] // comm.n
        y = x.reshape((comm.n, b) + x.shape[1:])
        y = lax.all_to_all(
            y, comm.axis_name, split_axis=0, concat_axis=0, tiled=False,
            axis_index_groups=_xla_groups(comm),
        )
        return y.reshape(x.shape)


def _eager_eligible(x) -> bool:
    """True only for actual arrays *outside any trace*.

    Checking the operand alone is not enough: a constant created or closed
    over inside a ``shard_map`` body is not a tracer, yet must still take
    the trace path (the axis name is bound there, and re-entering jit
    mid-trace would be wrong).
    """
    import jax

    return not isinstance(x, jax.core.Tracer) and jax.core.trace_state_clean()


class InterpBackend:
    """Compiled schedule engine: planned rounds → fused ppermute groups.

    Inside ``shard_map`` the collectives trace as usual (compiled tables
    are memoized process-wide, so retraces skip all Python table
    derivation).  Called with concrete arrays, the backend instead routes
    through :func:`_run_eager`'s jitted-executable cache.
    """

    name = "interp"

    def all_reduce(self, comm, x):
        return self._collective(comm, "all_reduce", x)

    def reduce_scatter(self, comm, x):
        return self._collective(comm, "reduce_scatter", x)

    def all_gather(self, comm, x):
        return self._collective(comm, "all_gather", x)

    def all_to_all(self, comm, x):
        return self._collective(comm, "all_to_all", x)

    # ------------------------------------------------------------ dispatch
    def _collective(self, comm, collective, x):
        if _eager_eligible(x):
            return _run_eager(comm, collective, x)
        return self._traced(comm, collective, x, None)

    def _traced(self, comm, collective, x, sched: "Optional[Schedule]"):
        """Trace-time body; ``sched`` pre-resolved on the eager path (the
        executable must run exactly the schedule its cache key names)."""
        from repro.comm import exec_engine

        exec_engine.note_trace()
        if collective == "all_reduce":
            import jax.numpy as jnp

            shape = x.shape
            flat = x.reshape(-1)
            pad = (-flat.size) % comm.n
            if pad:
                flat = jnp.concatenate([flat, jnp.zeros((pad,), flat.dtype)])
            if sched is None:
                sched = comm.axis_schedule(
                    "all_reduce", flat.size * _item_bytes(flat)
                )
            out = self._run(comm, "all_reduce", flat, sched)
            if pad:
                out = out[: out.size - pad]
            return out.reshape(shape)
        if sched is None:
            mult = comm.n if collective == "all_gather" else 1
            sched = comm.axis_schedule(collective, x.size * _item_bytes(x) * mult)
        return self._run(comm, collective, x, sched)

    # -- dispatch: ungrouped → primitives; grouped → local-rank variants --
    def _run(self, comm, collective, x, sched):
        from repro.comm import primitives as P

        if comm.groups is None:
            if collective == "all_reduce" and sched.algorithm == "ring_ef8":
                # planner-selected wire compression: int8 payloads per hop
                from repro.comm.fusion import all_reduce_quantized

                return all_reduce_quantized(x, sched, comm.axis_name)
            return getattr(P, collective)(x, sched, comm.axis_name)
        return _grouped_collective(comm, collective, x, sched)


# ------------------------------------------------------------- eager path


def _eager_nbytes(comm, collective, local_shape, itemsize: int) -> float:
    """The nbytes the trace path will derive from the local operand."""
    import math

    size = math.prod(local_shape) if local_shape else 1
    if collective == "all_reduce":
        return float(size + ((-size) % comm.n)) * itemsize
    if collective == "all_gather":
        return float(size) * itemsize * comm.n
    return float(size) * itemsize


def _run_eager(comm, collective, x):
    """Concrete-array path: one cached, jitted shard_map executable.

    ``x`` is the **global** operand: ``(axis_size, *local)``, row ``r``
    being rank ``r``'s local operand of the in-``shard_map`` convention
    (all_reduce: full addend; reduce_scatter: ``(n·k, …)``; all_gather:
    shard; all_to_all: dest-major blocks).  The output keeps the leading
    axis: row ``r`` is rank ``r``'s local result.

    Executables are memoized process-wide in
    ``repro.comm.exec_engine.EXECUTABLES`` keyed by ``(schedule
    fingerprint, collective, global shape, dtype, axis name, group
    fingerprint)`` — a repeated same-shape collective is a cache hit and
    zero retraces.  Shape-preserving collectives donate the input buffer
    to XLA, so steady-state loops reuse the chunk storage.
    """
    import jax

    from repro.comm import exec_engine

    if x.ndim < 1 or x.shape[0] != comm.axis_size:
        raise ScheduleExecutionError(
            f"eager {collective}: expected global (axis_size={comm.axis_size},"
            f" *local) operand, got shape {tuple(x.shape)}; inside shard_map"
            " pass the local operand instead"
        )
    if len(jax.devices()) < comm.axis_size:
        raise ScheduleExecutionError(
            f"eager {collective} over axis {comm.axis_name!r} needs "
            f"{comm.axis_size} devices, found {len(jax.devices())}; call "
            "inside shard_map or set --xla_force_host_platform_device_count"
        )
    sched = comm.axis_schedule(
        collective, _eager_nbytes(comm, collective, x.shape[1:], _item_bytes(x))
    )
    key = (
        sched.fingerprint(),
        collective,
        tuple(x.shape),
        str(x.dtype),
        comm.axis_name,
        comm.group_fingerprint(),
    )
    fn = exec_engine.EXECUTABLES.get(key)
    if fn is None:
        fn = _build_executable(comm, collective, sched, tuple(x.shape))
        exec_engine.EXECUTABLES.put(key, fn)
    return fn(x)


class _ExecView:
    """Static execution-time view of a Communicator.

    Everything ``InterpBackend._traced`` touches once the schedule is
    resolved — and nothing more: cached executables live in a
    process-wide LRU, so closing over the live Communicator would pin its
    whole PcclSession (plan + structure caches) for the cache's lifetime.
    """

    __slots__ = ("axis_name", "n", "axis_size", "groups", "_table", "_table_dev")

    def __init__(self, comm: "Communicator") -> None:
        self.axis_name = comm.axis_name
        self.n = comm.n
        self.axis_size = comm.axis_size
        self.groups = comm.groups
        self._table = comm.local_index_table()
        # built outside any trace, so this shares the communicator's own
        # cached upload rather than re-implementing it
        self._table_dev = comm.local_index_device_table()

    def local_index_table(self):
        return self._table

    def local_index_device_table(self):
        return self._table_dev


def _build_executable(comm, collective, sched, global_shape):
    """jit(shard_map(...)) over the resolved schedule; donates when the
    output buffer can alias the input, decided structurally by
    ``exec_engine.donation_compatible`` (whole-array footprints must
    coincide — the same Box model the kernel lint applies to
    ``input_output_aliases``; no tracing, so 0-retrace guarantees hold)."""
    import jax
    from jax.sharding import PartitionSpec as P

    from repro import compat
    from repro.comm import exec_engine

    backend = comm.backend  # stateless InterpBackend
    view = _ExecView(comm)
    axis = view.axis_name

    def inner(xl):
        return backend._traced(view, collective, xl[0], sched)[None]

    mesh = compat.make_mesh(
        (view.axis_size,), (axis,), devices=jax.devices()[: view.axis_size]
    )
    spec = P(axis, *([None] * (len(global_shape) - 1)))
    fun = compat.shard_map(
        inner, mesh=mesh, in_specs=spec, out_specs=spec, check_vma=False
    )
    donate = (
        (0,) if exec_engine.donation_compatible(collective, global_shape) else ()
    )
    return jax.jit(fun, donate_argnums=donate)


def _local_index(comm: "Communicator"):
    """me → index within my group, as a traced lookup of the communicator's
    cached rank→local table (built and uploaded once, not per trace)."""
    import jax.numpy as jnp
    from jax import lax

    me = lax.axis_index(comm.axis_name)
    return jnp.take(comm.local_index_device_table(), me)


def _grouped_collective(comm: "Communicator", collective: str, x, sched):
    """Group-local collectives on a split communicator.

    Mirrors ``repro.comm.primitives`` wrappers with the rank's *group-local*
    index: the composed schedule already routes between global ranks, while
    chunk ids (and local buffers) stay group-local.
    """
    import jax.numpy as jnp
    from jax import lax

    from repro.comm.exec_engine import (
        compile_all_to_all,
        execute_all_to_all_compact,
    )
    from repro.comm.primitives import ScheduleExecutionError, execute_schedule

    m = comm.n
    me_local = _local_index(comm)
    if collective in ("reduce_scatter", "all_reduce", "all_to_all") and x.shape[0] % m:
        raise ScheduleExecutionError(
            f"leading dim {x.shape[0]} not divisible by group size {m}"
        )
    if collective == "reduce_scatter":
        chunks = x.reshape((m, x.shape[0] // m) + x.shape[1:])
        chunks = execute_schedule(chunks, sched, comm.axis_name)
        return jnp.take(chunks, me_local, axis=0)
    if collective == "all_reduce":
        chunks = x.reshape((m, x.shape[0] // m) + x.shape[1:])
        if sched.algorithm == "ring_ef8":
            from repro.comm.exec_engine import compile_schedule
            from repro.comm.fusion import execute_compiled_quantized

            chunks = execute_compiled_quantized(
                chunks, compile_schedule(sched), comm.axis_name
            )
        else:
            chunks = execute_schedule(chunks, sched, comm.axis_name)
        return chunks.reshape(x.shape)
    if collective == "all_gather":
        chunks = jnp.zeros((m,) + x.shape, x.dtype).at[me_local].set(x)
        chunks = execute_schedule(chunks, sched, comm.axis_name)
        return chunks.reshape((m * x.shape[0],) + x.shape[1:])
    if collective == "all_to_all":
        blocks = x.reshape((m, x.shape[0] // m) + x.shape[1:])
        local_of = tuple(int(v) for v in comm.local_index_table())
        compact = compile_all_to_all(sched, m, local_of)
        if compact is not None:
            me = lax.axis_index(comm.axis_name)
            return execute_all_to_all_compact(
                blocks, compact, comm.axis_name, me
            ).reshape(x.shape)
        # dense fallback: O(m²·blk) origin×target state
        state = jnp.zeros((m, m) + blocks.shape[1:], blocks.dtype)
        state = state.at[me_local].set(blocks)
        flat = state.reshape((m * m,) + blocks.shape[1:])
        flat = execute_schedule(flat, sched, comm.axis_name)
        state = flat.reshape((m, m) + blocks.shape[1:])
        return jnp.take(state, me_local, axis=1).reshape(x.shape)
    raise ScheduleExecutionError(f"unknown collective {collective!r}")


class SimBackend:
    """Cost-model-only execution: accumulate planned time, pass data through.

    Data semantics are single-copy placeholders (the caller holds the only
    logical copy): ``all_reduce``/``all_to_all`` return the input unchanged,
    ``reduce_scatter`` returns **rank 0's** shard slice (there is no real
    rank here, so the first ``shape[0] // n`` rows stand in for "my shard" —
    only the shape is meaningful, not which values land in it),
    ``all_gather`` tiles the shard ``n`` times — shapes match the real
    backends so code paths are identical, but no inter-device data movement
    happens (or is needed).  Tiling happens in the input's own array
    namespace (numpy in → numpy out, jax in → jax out), so a sim-backend
    pipeline over device arrays never hops to host mid-graph.  Shape
    preconditions (leading-dim divisibility) raise the same
    :class:`~repro.comm.errors.ScheduleExecutionError` as the ``interp``
    backend instead of silently mis-shaping the output.
    """

    name = "sim"

    def __init__(self) -> None:
        self.elapsed_s = 0.0
        self.events: List[Tuple[str, float, float]] = []  # (coll, nbytes, cost)

    def _charge(self, comm, collective, nbytes) -> None:
        cost = comm.estimate(collective, nbytes)
        self.elapsed_s += cost
        self.events.append((collective, float(nbytes), cost))

    def all_reduce(self, comm, x):
        self._charge(comm, "all_reduce", x.size * _item_bytes(x))
        return x

    def reduce_scatter(self, comm, x):
        _check_divisible(x, comm.n)
        self._charge(comm, "reduce_scatter", x.size * _item_bytes(x))
        return x[: x.shape[0] // comm.n]  # rank 0's shard (placeholder)

    def all_gather(self, comm, x):
        import numpy as np

        self._charge(comm, "all_gather", x.size * _item_bytes(x) * comm.n)
        reps = (comm.n,) + (1,) * (x.ndim - 1)
        if isinstance(x, np.ndarray):
            return np.tile(x, reps)
        import jax.numpy as jnp  # jax array in → jax array out, one tile

        return jnp.tile(x, reps)

    def all_to_all(self, comm, x):
        _check_divisible(x, comm.n)
        self._charge(comm, "all_to_all", x.size * _item_bytes(x))
        return x


_BACKENDS = {"xla": XlaBackend, "interp": InterpBackend, "sim": SimBackend}


def get_backend(name: str) -> Backend:
    """Fresh backend instance by name (``xla`` | ``interp`` | ``sim``)."""
    try:
        return _BACKENDS[name]()
    except KeyError:
        raise ValueError(
            f"unknown backend {name!r}; available: {sorted(_BACKENDS)}"
        ) from None


def register_backend(name: str, cls) -> None:
    """Extension point: register a custom Backend implementation."""
    _BACKENDS[name] = cls
