"""Communicator — executable collectives bound to a mesh axis + backend.

A :class:`Communicator` is created by :meth:`repro.api.PcclSession.communicator`
and owns *no* planning state of its own: every schedule comes from the
session's plan cache, so all communicators of a session share plans and
fabric-state threading.

Process groups (``split``)
--------------------------
``comm.split(colors)`` partitions the axis into equal-sized sub-groups by
color — the hierarchical-mesh pattern (DP×TP): ranks with the same color
form one group, and the returned communicator runs each collective *within
every group simultaneously* (exactly ``axis_index_groups`` semantics for the
``xla`` backend; the ``interp`` backend replicates the group-local schedule
across groups so each ppermute round stays one full-axis permutation).
Plans are made for the group size, so the planner prices the sub-collective,
not the full axis.
"""

from __future__ import annotations

from typing import TYPE_CHECKING, Any, Optional, Sequence, Tuple, Union

import numpy as np

from repro.comm.exec_engine import _LruCache  # jax-free

from repro.core.schedules import Groups, Schedule
from repro.core.schedules import replicate_groups as subgroup_schedule  # noqa: F401 back-compat re-export

from .backends import Backend, get_backend

if TYPE_CHECKING:  # pragma: no cover
    from .session import PcclSession


class Communicator:
    """Collectives over (a partition of) one mesh axis.

    Not constructed directly — use ``session.communicator(...)`` and
    ``Communicator.split``.
    """

    def __init__(
        self,
        session: "PcclSession",
        axis_name: str,
        n: int,
        *,
        backend: Union[str, Backend] = "interp",
        algorithm: str = "auto",
        groups: Optional[Groups] = None,
        axis_size: Optional[int] = None,
        rel_error_tol: Optional[float] = None,
    ) -> None:
        self.session = session
        self.axis_name = axis_name
        self.n = n                      # ranks per group (plans use this)
        self.algorithm = algorithm
        # declared error tolerance: lets auto arbitration consider lossy
        # wire-compressed algorithms (see PcclSession.plan)
        self.rel_error_tol = rel_error_tol
        self.groups = groups            # None → the single full-axis group
        self.axis_size = axis_size if axis_size is not None else n
        self.backend: Backend = (
            get_backend(backend) if isinstance(backend, str) else backend
        )
        self._local_table: Optional[np.ndarray] = None
        self._local_table_dev: Optional[Any] = None
        # composed full-axis schedules, keyed (fingerprint, buffer_bytes):
        # subgroup_schedule rebuilds every transfer, so the eager hot path
        # must not pay it (or the fingerprint hash) per call
        self._axis_sched_cache = _LruCache(max_entries=64)
        if groups is not None:
            sizes = {len(g) for g in groups}
            if sizes != {n}:
                raise ValueError(f"unequal group sizes {sizes} (need all == {n})")
            flat = sorted(r for g in groups for r in g)
            if flat != list(range(self.axis_size)):
                raise ValueError("groups must partition the axis exactly once")

    # ------------------------------------------------------------- planning
    def _schedule(self, collective: str, nbytes: float) -> Schedule:
        """Group-size schedule from the session's (cached) planner."""
        return self.session.plan(
            collective, nbytes, n=self.n, algorithm=self.algorithm,
            rel_error_tol=self.rel_error_tol,
        ).schedule

    def axis_schedule(self, collective: str, nbytes: float) -> Schedule:
        """The executable full-axis schedule (groups composed in).

        Composed schedules are memoized per communicator — the group-local
        fingerprint covers the transfers, ``buffer_bytes`` the sizes — so
        repeated collectives on a split communicator return one object
        (with its fingerprint already memoized) instead of recomposing.
        """
        sched = self._schedule(collective, nbytes)
        if self.groups is None:
            return sched
        key = (sched.fingerprint(), sched.buffer_bytes)
        composed = self._axis_sched_cache.get(key)
        if composed is None:
            composed = subgroup_schedule(sched, self.groups, self.axis_size)
            self._axis_sched_cache.put(key, composed)
        return composed

    def chosen_algorithm(self, collective: str, nbytes: float) -> str:
        return self._schedule(collective, nbytes).algorithm

    def concurrent_request(
        self, collective: str, nbytes: float, *, algorithm: Optional[str] = None
    ):
        """A :class:`~repro.core.pccl.ConcurrentCollectiveRequest` for this
        communicator's process groups, for
        :meth:`~repro.api.session.PcclSession.plan_concurrent` — a split
        communicator contributes its groups (every group runs the collective
        simultaneously), a full-axis one a single domain-spanning group.
        ``nbytes`` is the per-rank buffer size within a group."""
        from repro.core.pccl import ConcurrentCollectiveRequest

        return ConcurrentCollectiveRequest(
            collective,
            float(nbytes),
            groups=self.groups,
            algorithm=algorithm or self.algorithm,
        )

    def estimate(self, collective: str, nbytes: float) -> float:
        """Planned time (seconds) of one collective from the current fabric."""
        return self.session.plan(
            collective, nbytes, n=self.n, algorithm=self.algorithm,
            rel_error_tol=self.rel_error_tol,
        ).cost

    def replan(
        self,
        collective: str,
        nbytes: float,
        *,
        failed_edges: Sequence[Tuple[int, int]] = (),
        failed_ranks: Sequence[int] = (),
    ):
        """Warm-replan this communicator's collective after fabric faults.

        Forwards to :meth:`PcclSession.replan` at this communicator's group
        size: only planner states the failed links/ranks actually touch are
        re-routed (O(affected)), the result is bit-identical to cold-planning
        the degraded fabric, and the session permanently drops the dead
        links for every later plan on this axis.  Edges/ranks are group-local
        indices (the planner's rank space for this communicator)."""
        return self.session.replan(
            collective,
            nbytes,
            n=self.n,
            algorithm=self.algorithm,
            failed_edges=failed_edges,
            failed_ranks=failed_ranks,
        )

    # ----------------------------------------------------------- primitives
    def all_reduce(self, x):
        return self.backend.all_reduce(self, x)

    def reduce_scatter(self, x):
        """x: (n·k, …) per-rank addend → (k, …) reduced shard."""
        return self.backend.reduce_scatter(self, x)

    def all_gather(self, x):
        """x: (k, …) shard → (n·k, …) gathered."""
        return self.backend.all_gather(self, x)

    def all_to_all(self, x):
        """x: (n·b, …) destination-major blocks → (n·b, …) origin-major."""
        return self.backend.all_to_all(self, x)

    # --------------------------------------------------------------- groups
    def split(self, colors: Sequence[int], *, backend: Optional[str] = None,
              algorithm: Optional[str] = None) -> "Communicator":
        """Partition the axis into same-color sub-groups (MPI comm_split).

        ``colors[i]`` is the color of axis rank ``i``; ranks sharing a color
        form one group and every group runs the collective independently
        (and concurrently).  All groups must end up the same size.

        The parent's backend *instance* is shared by default so stateful
        backends keep one account (e.g. ``sim_elapsed_s`` covers sub-group
        traffic too); pass ``backend="..."`` to get a fresh one instead.

        Resizing is a warm-path event: the sub-communicator plans at the
        new group size through the same session, so its structure cache
        (keyed without ``nbytes``) and any prior plans at that size are
        reused — only a genuinely new (size, fabric, algorithm) combination
        routes, and later faults go through :meth:`replan` incrementally.
        """
        if self.groups is not None:
            raise ValueError("split() on an already-split communicator")
        if len(colors) != self.axis_size:
            raise ValueError(
                f"need one color per axis rank ({self.axis_size}), got {len(colors)}"
            )
        by_color: dict = {}
        for rank, color in enumerate(colors):
            by_color.setdefault(color, []).append(rank)
        groups = tuple(tuple(g) for _, g in sorted(by_color.items()))
        sizes = {len(g) for g in groups}
        if len(sizes) != 1:
            raise ValueError(f"split produced unequal group sizes: {sizes}")
        m = sizes.pop()
        return Communicator(
            self.session,
            self.axis_name,
            m,
            backend=backend if backend is not None else self.backend,
            algorithm=algorithm or self.algorithm,
            groups=groups,
            axis_size=self.axis_size,
            rel_error_tol=self.rel_error_tol,
        )

    def group_fingerprint(self) -> Tuple:
        """Hashable identity of the axis partition — part of the engine's
        executable-cache key (full axis vs. a particular split execute
        differently even when the group-local schedule coincides)."""
        if self.groups is None:
            return ("full", self.axis_size)
        return ("split", self.groups)

    def local_index_table(self) -> np.ndarray:
        """rank → group-local index, built once and cached on the
        communicator (identity mapping for the full axis).  Grouped-
        collective traces index this instead of rebuilding the table."""
        if self._local_table is None:
            if self.groups is None:
                table = np.arange(self.axis_size, dtype=np.int32)
            else:
                table = np.zeros(self.axis_size, dtype=np.int32)
                for g in self.groups:
                    for i, rank in enumerate(g):
                        table[rank] = i
            table.flags.writeable = False
            self._local_table = table
        return self._local_table

    def local_index_device_table(self):
        """The same table as a device array, uploaded once per communicator
        (not once per trace)."""
        if self._local_table_dev is None:
            import jax
            import jax.numpy as jnp

            # a first use under a trace must still yield a cacheable
            # *concrete* array, not a leaked tracer
            with jax.ensure_compile_time_eval():
                self._local_table_dev = jnp.asarray(self.local_index_table())
        return self._local_table_dev

    def group_of(self, rank: int) -> Tuple[int, ...]:
        """Axis ranks in ``rank``'s group."""
        if self.groups is None:
            return tuple(range(self.axis_size))
        for g in self.groups:
            if rank in g:
                return g
        raise ValueError(f"rank {rank} not on this axis")

    # ------------------------------------------------------------ sim stats
    @property
    def sim_elapsed_s(self) -> float:
        """Accumulated simulated communication time (``sim`` backend only)."""
        return getattr(self.backend, "elapsed_s", 0.0)
