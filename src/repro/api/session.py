"""PcclSession — the stateful front door to PCCL planning.

The paper presents PCCL as a *library*: one entry point that, given a
collective request and the current fabric state, synthesizes the cheapest
reconfiguration-aware execution.  :class:`PcclSession` is that entry point.
It improves on the free-function facade (``repro.core.pccl``) in two ways:

* **Two-level plan cache** — plans are memoized by
  ``(collective, n, nbytes, algorithm, dims, fabric-fingerprint)``, so a
  training loop that issues the same gradient all-reduce every step plans
  once.  Underneath, a *structure cache* keyed without ``nbytes`` holds the
  planner's size-independent routing/transition tables, so a plan-cache
  miss at a new buffer size (a sweep, a new gradient bucket) skips all
  routing and pays only the cheap numeric phase.  Hit/miss accounting is
  exposed via :attr:`PcclSession.stats` / :attr:`PcclSession.structure_stats`,
  and :meth:`PcclSession.plan_sweep` prices a whole list of buffer sizes in
  one batched numeric pass.
* **Fabric-state threading** — the final topology of plan *k* becomes the
  initial topology ``G0`` of plan *k+1*.  Back-to-back collectives therefore
  stop paying for reconfigurations the fabric already has: e.g. a repeated
  ring reduce-scatter re-enters its own ideal ring for free, saving one
  reconfiguration delay per iteration versus cold-start planning.

Executable collectives hang off :meth:`PcclSession.communicator`, which
returns :class:`~repro.api.communicator.Communicator` objects bound to a
mesh axis and a pluggable backend (``interp`` / ``xla`` / ``sim``).
"""

from __future__ import annotations

import threading
from collections import OrderedDict
from dataclasses import dataclass
from typing import (
    TYPE_CHECKING,
    Any,
    Dict,
    FrozenSet,
    Iterable,
    List,
    Optional,
    Sequence,
    Tuple,
)

from repro.core.cost_model import (
    STRUCTURE_TABLE,
    HardwareParams,
    ScheduleCost,
    schedule_cost_fixed,
)
from repro.core.pccl import (
    CollectiveRequest,
    ConcurrentCollectiveRequest,
    ConcurrentPcclPlan,
    PcclPlan,
    default_standard_set,
    plan_collective_hierarchical,
    plan_collective_sweep,
    plan_concurrent_collectives,
    replan_collective,
)
from repro.core.planner import PlanStructure, trans_cache_stats
from repro.core import schedules as S
from repro.core.topology import Edge, Topology, degrade_topology, ring

if TYPE_CHECKING:  # pragma: no cover
    from .communicator import Communicator

# (collective, n, nbytes, algorithm, dims, fabric edge-set fingerprint)
PlanKey = Tuple[str, int, float, str, Optional[Tuple[int, ...]], FrozenSet[Edge]]
# PlanKey minus nbytes: everything a plan's *structure* depends on
StructureKey = Tuple[str, int, str, Optional[Tuple[int, ...]], FrozenSet[Edge]]


# --------------------------------------------------------------------------
# The PlanRequest family — the session's unified planning surface.
#
# Every way to ask the planner for something is a frozen, hashable request
# value handed to :meth:`PcclSession.submit`.  The five named entrypoints
# (``plan`` / ``plan_sweep`` / ``plan_hierarchical`` / ``replan`` /
# ``plan_concurrent``) are thin wrappers that build one of these — callers
# that construct requests directly (queues, arbiters, RPC layers) get the
# exact same cached behavior, and requests can be stored, compared, and
# replayed.  These types are API-stable (see CONTRIBUTING.md): fields are
# only ever *added*, with defaults that preserve old behavior.
# --------------------------------------------------------------------------


@dataclass(frozen=True)
class PlanRequest:
    """One reconfiguration-aware plan from the current fabric state.

    Equivalent to :meth:`PcclSession.plan` with the same arguments.
    """

    collective: str
    nbytes: float
    n: Optional[int] = None
    algorithm: str = "paper_default"
    dims: Optional[Tuple[int, ...]] = None
    rel_error_tol: Optional[float] = None

    def __post_init__(self) -> None:
        object.__setattr__(self, "nbytes", float(self.nbytes))
        if self.dims is not None:
            object.__setattr__(self, "dims", tuple(self.dims))


@dataclass(frozen=True)
class PlanSweepRequest:
    """Price one collective at many buffer sizes in one batched numeric
    phase (:meth:`PcclSession.plan_sweep`); fabric state is not threaded."""

    collective: str
    sizes: Tuple[float, ...]
    n: Optional[int] = None
    algorithm: str = "paper_default"
    dims: Optional[Tuple[int, ...]] = None
    rel_error_tol: Optional[float] = None

    def __post_init__(self) -> None:
        object.__setattr__(self, "sizes", tuple(float(d) for d in self.sizes))
        if self.dims is not None:
            object.__setattr__(self, "dims", tuple(self.dims))


@dataclass(frozen=True)
class HierarchicalPlanRequest:
    """Two-level (per-pod exact + coarse inter-pod) plan
    (:meth:`PcclSession.plan_hierarchical`)."""

    collective: str
    nbytes: float
    n: Optional[int] = None
    algorithm: str = "paper_default"
    dims: Optional[Tuple[int, ...]] = None
    pods: Optional[Tuple[Tuple[int, ...], ...]] = None
    pod_size: Optional[int] = None

    def __post_init__(self) -> None:
        object.__setattr__(self, "nbytes", float(self.nbytes))
        if self.dims is not None:
            object.__setattr__(self, "dims", tuple(self.dims))
        if self.pods is not None:
            object.__setattr__(
                self, "pods", tuple(tuple(p) for p in self.pods)
            )


@dataclass(frozen=True)
class ReplanRequest:
    """Warm incremental replan after link/rank failures
    (:meth:`PcclSession.replan`); permanently degrades the fabric."""

    collective: str
    nbytes: float
    n: Optional[int] = None
    algorithm: str = "paper_default"
    dims: Optional[Tuple[int, ...]] = None
    failed_edges: Tuple[Edge, ...] = ()
    failed_ranks: Tuple[int, ...] = ()

    def __post_init__(self) -> None:
        object.__setattr__(self, "nbytes", float(self.nbytes))
        if self.dims is not None:
            object.__setattr__(self, "dims", tuple(self.dims))
        object.__setattr__(
            self,
            "failed_edges",
            tuple((int(u), int(v)) for (u, v) in self.failed_edges),
        )
        object.__setattr__(
            self, "failed_ranks", tuple(int(r) for r in self.failed_ranks)
        )


@dataclass(frozen=True)
class ConcurrentPlanRequest:
    """Joint plan for several concurrently-active collectives
    (:meth:`PcclSession.plan_concurrent`).

    ``offsets`` gives each constituent request an arrival-round offset —
    group ``g``'s round ``i`` executes at joint round ``i + offsets[g]`` —
    so staggered admissions (a decode wave joining mid-prefill) don't force
    round-0 alignment; during its idle prefix a group may pre-position into
    any state enterable at its first round.
    """

    requests: Tuple[ConcurrentCollectiveRequest, ...]
    n: Optional[int] = None
    offsets: Optional[Tuple[int, ...]] = None

    def __post_init__(self) -> None:
        object.__setattr__(self, "requests", tuple(self.requests))
        if self.offsets is not None:
            object.__setattr__(
                self, "offsets", tuple(int(o) for o in self.offsets)
            )


AnyPlanRequest = (
    PlanRequest,
    PlanSweepRequest,
    HierarchicalPlanRequest,
    ReplanRequest,
    ConcurrentPlanRequest,
)


@dataclass(frozen=True)
class CacheStats:
    hits: int
    misses: int
    size: int
    evictions: int = 0
    bytes: int = 0  # estimated value footprint (0 for unmetered caches)

    @property
    def requests(self) -> int:
        return self.hits + self.misses


@dataclass(frozen=True)
class StructureStatsTotals(CacheStats):
    """:attr:`PcclSession.structure_stats` — the session's structure-bundle
    cache accounting plus the process-wide planner table totals behind it
    (``bytes`` = this session's cached ``PlanStructure`` arrays;
    ``table_bytes``/``trans_bytes`` = the shared routing structure table and
    transition memo, which size-aware eviction keeps bounded at large n)."""

    table_bytes: int = 0
    table_entries: int = 0
    trans_bytes: int = 0
    trans_entries: int = 0


class PlanCache:
    """Bounded LRU plan memo with hit/miss/eviction accounting.

    ``max_entries`` defaults generously — a training loop rarely plans more
    than a handful of distinct keys — but keeps a long-running serving
    session that plans many distinct ``nbytes`` from growing without limit.
    Lookup/store/clear are lock-guarded: ``move_to_end``/``popitem`` are not
    safe under concurrent mutation, and sessions may plan from worker
    threads.
    """

    def __init__(
        self, max_entries: int = 4096, max_bytes: Optional[int] = None
    ) -> None:
        if max_entries < 1:
            raise ValueError(f"max_entries must be >= 1, got {max_entries}")
        if max_bytes is not None and max_bytes < 1:
            raise ValueError(f"max_bytes must be >= 1, got {max_bytes}")
        self._plans: "OrderedDict[PlanKey, PcclPlan]" = OrderedDict()
        self.max_entries = max_entries
        self.max_bytes = max_bytes
        self._lock = threading.Lock()
        self._hits = 0
        self._misses = 0
        self._evictions = 0
        self._bytes = 0
        self._charges: Dict[PlanKey, int] = {}

    def _charge(self, value: Any) -> int:
        """Estimated byte footprint of a cached value; 0 = unmetered."""
        return 0

    def lookup(self, key: PlanKey) -> Optional[PcclPlan]:
        with self._lock:
            plan = self._plans.get(key)
            if plan is not None:
                self._hits += 1
                self._plans.move_to_end(key)
            else:
                self._misses += 1
            return plan

    def store(self, key: PlanKey, plan: PcclPlan) -> None:
        charge = self._charge(plan)
        with self._lock:
            # Bundles are mutated in place and re-stored, so an existing
            # key's charge is replaced, not accumulated.
            self._bytes += charge - self._charges.pop(key, 0)
            if charge:
                self._charges[key] = charge
            self._plans[key] = plan
            self._plans.move_to_end(key)
            # Byte pressure never evicts the entry just stored (>1 floor),
            # so a single oversized bundle still caches.
            while len(self._plans) > 1 and (
                len(self._plans) > self.max_entries
                or (self.max_bytes is not None and self._bytes > self.max_bytes)
            ):
                old_key, _ = self._plans.popitem(last=False)
                self._bytes -= self._charges.pop(old_key, 0)
                self._evictions += 1

    def clear(self) -> None:
        with self._lock:
            self._plans.clear()
            self._charges.clear()
            self._hits = 0
            self._misses = 0
            self._evictions = 0
            self._bytes = 0

    @property
    def stats(self) -> CacheStats:
        with self._lock:
            return CacheStats(
                self._hits,
                self._misses,
                len(self._plans),
                self._evictions,
                self._bytes,
            )


class StructureCache(PlanCache):
    """First level of the session's two-level plan cache.

    Maps a :data:`StructureKey` — a plan key *without* ``nbytes`` — to the
    per-candidate-algorithm ``{algorithm: PlanStructure}`` bundle produced
    by the planner's size-independent phase.  A plan-cache miss at a new
    buffer size reuses the bundle and pays only the cheap numeric phase;
    only a new (collective, fabric, algorithm-mode) combination routes.
    Same bounded lock-guarded LRU semantics as :class:`PlanCache`, plus
    byte-charged eviction: bundles are charged their numpy array footprint
    so large-n structures (tables scale with states × rounds) cannot pin
    unbounded memory no matter how few entries they span.
    """

    def _charge(self, value: Any) -> int:
        total = 0
        for structure in value.values():
            for arr in (
                structure.dilation,
                structure.congestion,
                structure.feasible,
                structure.enterable,
                structure.trans,
            ):
                total += int(arr.nbytes)
            total += 512  # fixed overhead: states, keys, dict slot
        return total


class PcclSession:
    """Stateful planning session over one photonic fabric.

    Args:
      hw: α–β + reconfiguration hardware parameters.  ``hw``'s
        reconfiguration mode (``HardwareParams.reconfig_mode``) flows through
        every plan: with partial/overlapped reconfiguration
        (``hw.with_link_reconfig(r_link, overlap=True)``) the threaded fabric
        state makes warm starts even cheaper — the fabric already holds most
        of the next plan's circuits, so only the few changed links are
        reprogrammed (and hidden behind communication).
      g0: initial fabric topology.  Optional; collectives over ``n`` ranks
        with no recorded fabric default to ``ring(n)`` (the paper's G0).
      standard_set: the planner's standard fallback graphs ``S``
        (Algorithm 1).  Defaults to ``{ring, torus2d}`` per rank count.
      thread_fabric: when True (default) each plan's final topology becomes
        the next plan's ``G0`` for the same rank count.  Benchmarks that
        need cold-start numbers pass False.
      max_cached_plans: LRU bound on the plan cache (evictions show up in
        :attr:`stats`).
      max_cached_structures: LRU bound on the structure cache — the first
        level of the two-level cache, keyed without ``nbytes``, holding the
        planner's size-independent routing/transition tables.  A plan-cache
        miss that hits here (e.g. a new buffer size over a known fabric)
        skips all routing and pays only the numeric phase.
      max_structure_bytes: byte bound on the same structure cache.  Entry
        counts alone under-bound memory at large ``n`` (one n=1024 bundle
        dwarfs hundreds of n=16 ones), so bundles are charged their numpy
        array footprint and evicted LRU-first past this cap (totals in
        :attr:`structure_stats`).
    """

    def __init__(
        self,
        hw: HardwareParams,
        g0: Optional[Topology] = None,
        standard_set: Optional[Sequence[Topology]] = None,
        *,
        thread_fabric: bool = True,
        max_cached_plans: int = 4096,
        max_cached_structures: int = 512,
        max_structure_bytes: int = 256 * 1024 * 1024,
    ) -> None:
        self.hw = hw
        self.thread_fabric = thread_fabric
        self.cache = PlanCache(max_entries=max_cached_plans)
        self.structures = StructureCache(
            max_entries=max_cached_structures, max_bytes=max_structure_bytes
        )
        # plan() is a read-plan-store-thread sequence over fabric state;
        # serialize it so concurrent planners never start from a topology
        # the fabric doesn't hold (distinct sessions still plan in parallel)
        self._plan_lock = threading.RLock()
        self._initial: Dict[int, Topology] = {}
        self._fabric: Dict[int, Topology] = {}
        self._standard: Dict[int, List[Topology]] = {}
        self._default_n: Optional[int] = None
        if g0 is not None:
            self._initial[g0.n] = g0
            self._default_n = g0.n
        for topo in standard_set or ():
            self._standard.setdefault(topo.n, []).append(topo)

    # ------------------------------------------------------------- fabric
    def initial_fabric(self, n: Optional[int] = None) -> Topology:
        n = self._resolve_n(n)
        with self._plan_lock:  # re-entrant: plan() calls this lock held
            return self._initial.setdefault(n, ring(n))

    def fabric(self, n: Optional[int] = None) -> Topology:
        """Current fabric state for ``n``-rank collectives."""
        n = self._resolve_n(n)
        return self._fabric.get(n) or self.initial_fabric(n)

    def reset_fabric(self, n: Optional[int] = None) -> None:
        """Forget threaded state; next plan starts from the initial ``G0``."""
        with self._plan_lock:
            if n is None:
                self._fabric.clear()
            else:
                self._fabric.pop(n, None)

    def standard_set(self, n: Optional[int] = None) -> List[Topology]:
        n = self._resolve_n(n)
        with self._plan_lock:
            if n not in self._standard:
                self._standard[n] = list(default_standard_set(n))
            return self._standard[n]

    def _resolve_n(self, n: Optional[int]) -> int:
        if n is not None:
            return n
        if self._default_n is None:
            raise ValueError(
                "session has no default rank count; pass n= or construct "
                "PcclSession with g0"
            )
        return self._default_n

    # ------------------------------------------------------------ planning
    def _plan_missing(
        self,
        collective: str,
        sizes: Sequence[float],
        n: int,
        g0: Topology,
        algorithm: str,
        dims_t: Optional[Tuple[int, ...]],
        dims: Optional[Sequence[int]],
        rel_error_tol: Optional[float] = None,
    ) -> List[PcclPlan]:
        """Plan ``sizes`` through the structure cache (caller holds the
        plan lock and has already missed the per-``nbytes`` plan cache)."""
        skey: StructureKey = (collective, n, algorithm, dims_t, g0.edges)
        if rel_error_tol is not None:
            # a declared tolerance can widen the candidate set (ring_ef8),
            # so tolerant and exact requests must not share structures —
            # appended only when set, keeping every existing key unchanged
            skey = skey + (float(rel_error_tol),)
        bundle: Optional[Dict[str, PlanStructure]] = self.structures.lookup(skey)
        if bundle is None:
            bundle = {}
        plans = plan_collective_sweep(
            CollectiveRequest(
                collective, n, sizes[0], algorithm=algorithm,
                rel_error_tol=rel_error_tol,
            ),
            sizes,
            g0,
            self.hw,
            standard=self.standard_set(n),
            dims=dims,
            structure_for=bundle.get,
            on_structure=bundle.__setitem__,
        )
        self.structures.store(skey, bundle)
        return plans

    def submit(self, request: Any) -> Any:
        """Unified planning entrypoint: dispatch one frozen request value.

        Accepts any member of the :data:`AnyPlanRequest` family and returns
        what the corresponding named method would: a :class:`PcclPlan`
        (:class:`PlanRequest` / :class:`HierarchicalPlanRequest` /
        :class:`ReplanRequest`), a list of plans
        (:class:`PlanSweepRequest`), or a
        :class:`~repro.core.pccl.ConcurrentPcclPlan`
        (:class:`ConcurrentPlanRequest`).  The named methods are thin
        wrappers over this — ``session.plan(c, b)`` and
        ``session.submit(PlanRequest(c, b))`` are bit-identical, share the
        same caches, and thread fabric state the same way.
        """
        if isinstance(request, PlanRequest):
            return self._submit_plan(request)
        if isinstance(request, PlanSweepRequest):
            return self._submit_sweep(request)
        if isinstance(request, HierarchicalPlanRequest):
            return self._submit_hierarchical(request)
        if isinstance(request, ReplanRequest):
            return self._submit_replan(request)
        if isinstance(request, ConcurrentPlanRequest):
            return self._submit_concurrent(request)
        raise TypeError(
            f"submit() takes a PlanRequest-family value, got "
            f"{type(request).__name__!r}"
        )

    def plan(
        self,
        collective: str,
        nbytes: float,
        *,
        n: Optional[int] = None,
        algorithm: str = "paper_default",
        dims: Optional[Sequence[int]] = None,
        rel_error_tol: Optional[float] = None,
    ) -> PcclPlan:
        """Plan ``collective`` from the *current* fabric state (cached).

        ``rel_error_tol`` declares how much relative error the caller can
        absorb (see ``cost_model.compressed_ef_error_bound``); ``auto``
        arbitration may then also pick lossy wire-compressed algorithms.
        Tolerant plans get their own cache entries (the key is extended
        only when the tolerance is set).
        """
        return self.submit(PlanRequest(
            collective, nbytes, n=n, algorithm=algorithm,
            dims=tuple(dims) if dims is not None else None,
            rel_error_tol=rel_error_tol,
        ))

    def _submit_plan(self, req: PlanRequest) -> PcclPlan:
        with self._plan_lock:
            n = self._resolve_n(req.n)
            g0 = self.fabric(n)
            key: PlanKey = (
                req.collective,
                n,
                req.nbytes,
                req.algorithm,
                req.dims,
                g0.edges,
            )
            if req.rel_error_tol is not None:
                key = key + (float(req.rel_error_tol),)
            plan = self.cache.lookup(key)
            if plan is None:
                plan = self._plan_missing(
                    req.collective, [req.nbytes], n, g0, req.algorithm,
                    req.dims, req.dims, req.rel_error_tol,
                )[0]
                self.cache.store(key, plan)
            if self.thread_fabric and plan.final_topology is not None:
                self._fabric[n] = plan.final_topology
            return plan

    def plan_sweep(
        self,
        collective: str,
        sizes: Sequence[float],
        *,
        n: Optional[int] = None,
        algorithm: str = "paper_default",
        dims: Optional[Sequence[int]] = None,
        rel_error_tol: Optional[float] = None,
    ) -> List[PcclPlan]:
        """Plan ``collective`` at every buffer size in ``sizes``, from the
        *current* fabric state, in one batched numeric phase.

        Returns one plan per size, equal to calling :meth:`plan` per size
        on a non-threading session — bit-identical when size ratios are
        powers of two (the common sweep layout), to the last ulp otherwise
        (sweeps rescale one template schedule; see
        :func:`repro.core.planner.plan_sweep`).  A sweep prices
        alternatives, so every size starts from the same fabric state and —
        unlike :meth:`plan` — the fabric is **not** threaded afterwards.
        Results feed the per-``nbytes`` plan cache both ways:
        already-planned sizes are served from it, and newly planned sizes
        are stored for later :meth:`plan` calls.
        """
        return self.submit(PlanSweepRequest(
            collective, tuple(float(d) for d in sizes), n=n,
            algorithm=algorithm,
            dims=tuple(dims) if dims is not None else None,
            rel_error_tol=rel_error_tol,
        ))

    def _submit_sweep(self, req: PlanSweepRequest) -> List[PcclPlan]:
        with self._plan_lock:
            n = self._resolve_n(req.n)
            g0 = self.fabric(n)
            sizes_f = list(req.sizes)
            keys: List[PlanKey] = [
                (req.collective, n, d, req.algorithm, req.dims, g0.edges)
                for d in sizes_f
            ]
            if req.rel_error_tol is not None:
                keys = [k + (float(req.rel_error_tol),) for k in keys]
            plans: Dict[int, PcclPlan] = {}
            missing: List[int] = []
            for k, key in enumerate(keys):
                hit = self.cache.lookup(key)
                if hit is not None:
                    plans[k] = hit
                else:
                    missing.append(k)
            if missing:
                fresh = self._plan_missing(
                    req.collective, [sizes_f[k] for k in missing], n, g0,
                    req.algorithm, req.dims, req.dims, req.rel_error_tol,
                )
                for k, p in zip(missing, fresh):
                    self.cache.store(keys[k], p)
                    plans[k] = p
            return [plans[k] for k in range(len(sizes_f))]

    def plan_hierarchical(
        self,
        collective: str,
        nbytes: float,
        *,
        n: Optional[int] = None,
        algorithm: str = "paper_default",
        dims: Optional[Sequence[int]] = None,
        pods: Optional[Sequence[Sequence[int]]] = None,
        pod_size: Optional[int] = None,
    ) -> PcclPlan:
        """Plan ``collective`` through the two-level hierarchical path
        (per-pod exact DP + coarse inter-pod phase), cached.

        This is the scaling entry point: flat exact planning is quadratic in
        the state count (~``n``), while the hierarchical path plans one
        representative pod per equivalence class plus a ``P``-super-rank
        coarse phase — n=1024 cold plans land well inside the 1 s budget.
        With one pod (``pod_size=n``) the result wraps the flat exact plan
        bit-identically.  Hierarchical plans carry no single final fabric
        (pods own disjoint circuits), so fabric state is **not** threaded.
        """
        return self.submit(HierarchicalPlanRequest(
            collective, nbytes, n=n, algorithm=algorithm,
            dims=tuple(dims) if dims is not None else None,
            pods=tuple(tuple(p) for p in pods) if pods is not None else None,
            pod_size=pod_size,
        ))

    def _submit_hierarchical(self, req: HierarchicalPlanRequest) -> PcclPlan:
        with self._plan_lock:
            n = self._resolve_n(req.n)
            g0 = self.fabric(n)
            key = (
                "__hierarchical__",
                req.collective,
                n,
                req.nbytes,
                req.algorithm,
                req.dims,
                req.pods,
                req.pod_size,
                g0.edges,
            )
            plan = self.cache.lookup(key)
            if plan is None:
                plan = plan_collective_hierarchical(
                    CollectiveRequest(
                        req.collective, n, req.nbytes,
                        algorithm=req.algorithm,
                    ),
                    g0,
                    self.hw,
                    standard=self.standard_set(n),
                    dims=req.dims,
                    pods=req.pods,
                    pod_size=req.pod_size,
                )
                self.cache.store(key, plan)
            return plan

    def replan(
        self,
        collective: str,
        nbytes: float,
        *,
        n: Optional[int] = None,
        algorithm: str = "paper_default",
        dims: Optional[Sequence[int]] = None,
        failed_edges: Iterable[Edge] = (),
        failed_ranks: Iterable[int] = (),
    ) -> PcclPlan:
        """Warm-replan after link/rank failures: the fault-event fast path.

        ``failed_edges`` name physical links, so both directions die; a rank
        in ``failed_ranks`` loses every incident link.  The session's cached
        size-independent structures are re-priced incrementally — only
        states whose edge set actually changed re-route
        (O(affected states), see :func:`repro.core.planner.replan`) — and
        the resulting plan equals a cold plan of the degraded fabric
        bit-for-bit.  Failures are permanent: the per-``n`` fabric,
        initial fabric, and standard set are degraded in place, so every
        later :meth:`plan` (and :meth:`reset_fabric`) sees the surviving
        links only, and the refreshed structures are cached under the
        degraded fingerprint for further warm events.
        """
        return self.submit(ReplanRequest(
            collective, nbytes, n=n, algorithm=algorithm,
            dims=tuple(dims) if dims is not None else None,
            failed_edges=tuple(failed_edges),
            failed_ranks=tuple(failed_ranks),
        ))

    def _submit_replan(self, req: ReplanRequest) -> PcclPlan:
        with self._plan_lock:
            n = self._resolve_n(req.n)
            g0 = self.fabric(n)
            failed_e = frozenset(
                e for (u, v) in req.failed_edges for e in ((u, v), (v, u))
            )
            failed_r = frozenset(req.failed_ranks)
            skey: StructureKey = (
                req.collective, n, req.algorithm, req.dims, g0.edges
            )
            bundle = self.structures.lookup(skey) or {}
            new_bundle: Dict[str, PlanStructure] = {}
            plan = replan_collective(
                CollectiveRequest(
                    req.collective, n, req.nbytes, algorithm=req.algorithm
                ),
                g0,
                self.hw,
                standard=self.standard_set(n),
                dims=req.dims,
                changed_edges=tuple(failed_e),
                changed_ranks=tuple(failed_r),
                structure_for=bundle.get,
                on_structure=new_bundle.__setitem__,
            )
            self._standard[n] = [
                degrade_topology(s, failed_e, failed_r)
                for s in self.standard_set(n)
            ]
            d_g0 = degrade_topology(g0, failed_e, failed_r)
            self._fabric[n] = d_g0
            if n in self._initial:
                self._initial[n] = degrade_topology(
                    self._initial[n], failed_e, failed_r
                )
            self.structures.store(
                (req.collective, n, req.algorithm, req.dims, d_g0.edges),
                new_bundle,
            )
            self.cache.store(
                (req.collective, n, req.nbytes, req.algorithm, req.dims,
                 d_g0.edges),
                plan,
            )
            if self.thread_fabric and plan.final_topology is not None:
                self._fabric[n] = plan.final_topology
            return plan

    def plan_concurrent(
        self,
        requests: Sequence[ConcurrentCollectiveRequest],
        *,
        n: Optional[int] = None,
        offsets: Optional[Sequence[int]] = None,
    ) -> ConcurrentPcclPlan:
        """Jointly plan several concurrently-active collectives (cached).

        ``requests`` are :class:`repro.core.pccl.ConcurrentCollectiveRequest`
        specs — most conveniently built with
        :meth:`Communicator.concurrent_request`, so a TP×DP job plans both
        mesh axes in one call::

            comm = session.communicator("x", 16)
            tp = comm.split([r // 4 for r in range(16)])   # rows
            dp = comm.split([r % 4 for r in range(16)])    # columns
            cp = session.plan_concurrent([
                tp.concurrent_request("all_reduce", act_bytes),
                dp.concurrent_request("reduce_scatter", grad_bytes),
            ])

        The joint plan starts from the *current* fabric state, and the
        combined final topology (every group's last allocation) is threaded
        back as the next plan's ``G0``.  Results are memoized in the plan
        cache keyed by the full request tuple plus the fabric fingerprint;
        concurrent plans bypass the structure cache (their structures are
        built against the composed full-domain schedules).

        ``n`` (the shared fabric domain size) is inferred from any request
        that carries process groups; pass it explicitly when every request
        spans the whole domain.

        ``offsets`` (one non-negative int per request) staggers arrivals:
        request ``g``'s round ``i`` executes at joint round
        ``i + offsets[g]``, and during its idle prefix the group may
        pre-position into any state enterable at its first round — so a
        collective admitted mid-flight doesn't force round-0 alignment.
        """
        return self.submit(ConcurrentPlanRequest(
            tuple(requests), n=n,
            offsets=tuple(offsets) if offsets is not None else None,
        ))

    def _submit_concurrent(
        self, req: ConcurrentPlanRequest
    ) -> ConcurrentPcclPlan:
        with self._plan_lock:
            requests = req.requests
            if not requests:
                raise ValueError("plan_concurrent needs at least one request")
            n = req.n
            if n is None:
                for r in requests:
                    if r.groups is not None:
                        n = sum(len(g) for g in r.groups)
                        break
            n = self._resolve_n(n)
            g0 = self.fabric(n)
            key = (
                "__concurrent__",
                n,
                tuple(
                    (r.collective, float(r.nbytes), r.algorithm, r.groups)
                    for r in requests
                ),
                g0.edges,
            )
            if req.offsets is not None and any(req.offsets):
                # appended only for nonzero staggering, keeping every
                # pre-existing round-0-aligned cache key unchanged
                key = key + (req.offsets,)
            plan = self.cache.lookup(key)
            if plan is None:
                plan = plan_concurrent_collectives(
                    requests, n, g0, self.hw,
                    standard=self.standard_set(n), offsets=req.offsets,
                )
                self.cache.store(key, plan)
            if self.thread_fabric and plan.final_topology is not None:
                self._fabric[n] = plan.final_topology
            return plan

    def choose_algorithm(
        self, collective: str, nbytes: float, *, n: Optional[int] = None
    ) -> str:
        """§2.2 size-aware algorithm choice, via planned cost (cached)."""
        return self.plan(collective, nbytes, n=n, algorithm="auto").algorithm

    def baseline(
        self,
        collective: str,
        algorithm: str,
        nbytes: float,
        *,
        n: Optional[int] = None,
        topo: Optional[Topology] = None,
        dims: Optional[Sequence[int]] = None,
    ) -> ScheduleCost:
        """Fixed-topology cost of a named algorithm (the §5 baselines).

        Prices on the session's *initial* fabric by default — baselines
        cannot reconfigure, so threaded state never applies to them.
        """
        n = self._resolve_n(n)
        topo = topo or self.initial_fabric(n)
        sched = S.get_schedule(collective, algorithm, n, float(nbytes), dims=dims)
        return schedule_cost_fixed(topo, sched, self.hw)

    @property
    def stats(self) -> CacheStats:
        return self.cache.stats

    @property
    def structure_stats(self) -> StructureStatsTotals:
        """Hit/miss accounting for the size-independent structure cache,
        plus byte totals for it and the process-wide planner tables (the
        routing structure table and the transition memo), all of which are
        byte-charged and evict under memory pressure."""
        base = self.structures.stats
        table = STRUCTURE_TABLE.stats
        trans_entries, trans_bytes = trans_cache_stats()
        return StructureStatsTotals(
            base.hits,
            base.misses,
            base.size,
            base.evictions,
            base.bytes,
            table_bytes=table.bytes,
            table_entries=table.size,
            trans_bytes=trans_bytes,
            trans_entries=trans_entries,
        )

    def exec_stats(self):
        """Execution-engine counters: the jitted-executable cache (hits /
        misses / size), the compiled-schedule cache, and how many Python
        traces actually ran.  The caches are **process-wide** (executables
        are keyed by schedule fingerprint + shape + dtype + axis + groups,
        so sessions share them safely); a steady-state loop shows hits
        climbing while ``traces`` stays flat.  JAX-free to read — a
        sim-only process reports zeros.  See
        :func:`repro.comm.exec_engine.exec_stats`.
        """
        from repro.comm.exec_engine import exec_stats

        return exec_stats()

    @property
    def reconfig_mode(self) -> str:
        """``serial`` | ``partial`` | ``overlap`` — how this session's
        hardware model prices topology changes (see ``HardwareParams``)."""
        return self.hw.reconfig_mode

    # ------------------------------------------------------- communicators
    def communicator(
        self,
        axis_name: str,
        n: Optional[int] = None,
        *,
        backend: str = "interp",
        algorithm: str = "auto",
        rel_error_tol: Optional[float] = None,
    ) -> "Communicator":
        """Executable collectives over mesh axis ``axis_name``.

        ``backend`` is one of ``interp`` (ppermute schedule interpreter),
        ``xla`` (native lax collectives, the A/B baseline) or ``sim``
        (cost-model-only, no devices needed).  ``rel_error_tol`` (see
        :meth:`plan`) lets ``auto`` arbitration consider lossy
        wire-compressed algorithms for this communicator's collectives.
        """
        from .communicator import Communicator

        return Communicator(
            self, axis_name, self._resolve_n(n), backend=backend,
            algorithm=algorithm, rel_error_tol=rel_error_tol,
        )
