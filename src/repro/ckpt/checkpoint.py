"""Sharded checkpointing: npz shards + manifest, async writes, keep-k GC.

Layout (no orbax/tensorstore in this environment — same structure, small):

    <dir>/step_000123/
        manifest.json          # tree structure, shapes, dtypes, step, status
        shard_00000.npz        # flattened leaves (chunked by byte budget)
        ...
        COMMIT                 # written last → atomic validity marker

Restore picks the newest step with a COMMIT marker, so a crash mid-write can
never be resumed from (fault-tolerance requirement).  Async mode hands the
(host-transferred) arrays to a writer thread so the train loop keeps going;
``wait()`` joins before the next save or shutdown.
"""

from __future__ import annotations

import json
import pathlib
import shutil
import threading
import time
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np


@dataclass
class CheckpointConfig:
    directory: str
    keep: int = 3
    async_write: bool = True
    shard_bytes: int = 1 << 30  # 1 GiB per npz shard


class CheckpointManager:
    def __init__(self, cfg: CheckpointConfig):
        self.cfg = cfg
        self.dir = pathlib.Path(cfg.directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # ----------------------------------------------------------------- save
    def save(self, step: int, tree: Any, extra: Optional[Dict] = None) -> None:
        self.wait()
        leaves, treedef = jax.tree.flatten(tree)
        host_leaves = [np.asarray(x) for x in leaves]  # device→host before async
        if self.cfg.async_write:
            self._thread = threading.Thread(
                target=self._write, args=(step, host_leaves, str(treedef), extra),
                daemon=True,
            )
            self._thread.start()
        else:
            self._write(step, host_leaves, str(treedef), extra)

    def _write(self, step: int, leaves: List[np.ndarray], treedef_str: str,
               extra: Optional[Dict]) -> None:
        try:
            d = self.dir / f"step_{step:09d}"
            tmp = self.dir / f".tmp_step_{step:09d}"
            if tmp.exists():
                shutil.rmtree(tmp)
            tmp.mkdir(parents=True)
            shards: List[List[int]] = [[]]
            size = 0
            for i, leaf in enumerate(leaves):
                if size > self.cfg.shard_bytes and shards[-1]:
                    shards.append([])
                    size = 0
                shards[-1].append(i)
                size += leaf.nbytes
            for si, idxs in enumerate(shards):
                np.savez(tmp / f"shard_{si:05d}.npz", **{str(i): leaves[i] for i in idxs})
            manifest = {
                "step": step,
                "n_leaves": len(leaves),
                "n_shards": len(shards),
                "treedef": treedef_str,
                "shapes": [list(l.shape) for l in leaves],
                "dtypes": [str(l.dtype) for l in leaves],
                "extra": extra or {},
                "time": time.time(),
            }
            (tmp / "manifest.json").write_text(json.dumps(manifest))
            (tmp / "COMMIT").write_text("ok")
            if d.exists():
                shutil.rmtree(d)
            tmp.rename(d)  # atomic publish
            self._gc()
        except BaseException as e:  # surfaced on next wait()
            self._error = e

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            e, self._error = self._error, None
            raise e

    # -------------------------------------------------------------- restore
    def steps(self) -> List[int]:
        out = []
        for p in self.dir.glob("step_*"):
            if (p / "COMMIT").exists():
                out.append(int(p.name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        s = self.steps()
        return s[-1] if s else None

    def restore(self, template: Any, step: Optional[int] = None) -> Tuple[Any, int, Dict]:
        """Restore into the structure of ``template`` (shapes validated).
        Returns (tree, step, extra)."""
        self.wait()
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no committed checkpoints under {self.dir}")
        d = self.dir / f"step_{step:09d}"
        manifest = json.loads((d / "manifest.json").read_text())
        leaves_t, treedef = jax.tree.flatten(template)
        if len(leaves_t) != manifest["n_leaves"]:
            raise ValueError(
                f"checkpoint has {manifest['n_leaves']} leaves, template {len(leaves_t)}"
            )
        loaded: Dict[int, np.ndarray] = {}
        for si in range(manifest["n_shards"]):
            with np.load(d / f"shard_{si:05d}.npz") as z:
                for k in z.files:
                    loaded[int(k)] = z[k]
        out_leaves = []
        for i, tmpl in enumerate(leaves_t):
            arr = loaded[i]
            if tuple(arr.shape) != tuple(tmpl.shape):
                raise ValueError(f"leaf {i}: ckpt shape {arr.shape} != {tmpl.shape}")
            if hasattr(tmpl, "sharding") and tmpl.sharding is not None:
                out_leaves.append(jax.device_put(arr.astype(tmpl.dtype), tmpl.sharding))
            else:
                out_leaves.append(jax.device_put(arr.astype(tmpl.dtype)))
        return treedef.unflatten(out_leaves), step, manifest.get("extra", {})

    # ------------------------------------------------------------------- gc
    def _gc(self) -> None:
        steps = self.steps()
        for s in steps[: -self.cfg.keep]:
            shutil.rmtree(self.dir / f"step_{s:09d}", ignore_errors=True)
