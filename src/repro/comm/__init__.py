"""Executable PCCL collectives for JAX (shard_map + ppermute)."""

from .pccl_collectives import (
    ErrorFeedbackState,
    PcclComm,
    compressed_all_reduce,
    compressed_all_reduce_ef,
)
from .primitives import (
    ScheduleExecutionError,
    all_gather,
    all_reduce,
    all_to_all,
    execute_schedule,
    reduce_scatter,
)

__all__ = [k for k in dir() if not k.startswith("_")]
