"""Executable PCCL collectives for JAX (shard_map + ppermute).

Re-exports are lazy (PEP 562): the interpreter modules import JAX at module
scope, and device-free users (the ``sim`` backend, planning-only processes)
must be able to import :mod:`repro.comm.errors` — and this package — without
touching it.
"""

from .errors import ScheduleExecutionError

_LAZY = {
    "ErrorFeedbackState": ".pccl_collectives",
    "PcclComm": ".pccl_collectives",
    "compressed_all_reduce": ".pccl_collectives",
    "compressed_all_reduce_ef": ".pccl_collectives",
    "all_gather": ".primitives",
    "all_reduce": ".primitives",
    "all_to_all": ".primitives",
    "all_to_all_dense": ".primitives",
    "execute_schedule": ".primitives",
    "execute_schedule_reference": ".primitives",
    "reduce_scatter": ".primitives",
    "run_reference": ".primitives",
    # execution engine (jax-free module; executors import jax lazily)
    "CompiledSchedule": ".exec_engine",
    "ExecStats": ".exec_engine",
    "clear_exec_caches": ".exec_engine",
    "compile_all_to_all": ".exec_engine",
    "compile_schedule": ".exec_engine",
    "exec_stats": ".exec_engine",
    "execute_compiled": ".exec_engine",
}

__all__ = ["ScheduleExecutionError", *sorted(_LAZY)]


def __getattr__(name: str):
    if name in _LAZY:
        import importlib

        return getattr(importlib.import_module(_LAZY[name], __name__), name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
