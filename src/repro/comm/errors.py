"""JAX-free error types shared by the schedule interpreter and backends.

Lives outside ``primitives`` so device-free code paths (the ``sim`` backend)
can raise the exact same exceptions without importing JAX.
"""

from __future__ import annotations


class ScheduleExecutionError(ValueError):
    pass
