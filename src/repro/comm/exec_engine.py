"""Compiled schedule execution engine (the interp backend's hot path).

The per-round interpreter (``repro.comm.primitives.execute_schedule_reference``)
re-derives static per-round tables on every trace and emits one
``ppermute`` + scatter pair per round — O(rounds) Python work *and*
O(rounds) trace size per call.  This module lowers a
:class:`~repro.core.schedules.Schedule` **once** into a
:class:`CompiledSchedule` and memoizes it process-wide:

* **one compile pass** derives every round's ``(perm, send_ids, recv_ids,
  reduce)`` table (same validation as the reference interpreter, with the
  round index and the schedule's collective/algorithm in every error), then
* **folds consecutive rounds** that share a permutation, reduce-flag and
  chunk count into one :class:`RoundGroup` whose stacked ``(rounds, n, k)``
  chunk-id tables drive a single ``lax.scan`` — trace size and compile time
  drop from O(rounds) to O(round-groups) (ring RS/AG and every bucket axis
  phase collapse to one group; irregular schedules — RHD, DEX — keep the
  per-round fallback, which is just a group of length 1), and
* an **O(n·blk) all-to-all** compile (:func:`compile_all_to_all`) addresses
  blocks by *current holder slot* instead of the dense origin×target grid:
  a static simulation assigns every in-flight block a slot in an ``(n, blk)``
  buffer — exactly one live block per slot, asserted from the chunk
  metadata — and returns ``None`` (callers fall back to the dense path)
  whenever the metadata cannot be slot-addressed.

Execution (:func:`execute_compiled`) is **bit-identical** to the reference
interpreter: the same integer chunk ids are gathered, permuted and
scattered in the same order, so reductions see the same add order per
receiver.  The ``lax.scan`` merely rolls the identical round body into a
loop.

Caches and counters (compiled-table LRU, the jitted-executable LRU that
``repro.api.backends`` fills, and the trace counter) are process-wide,
lock-guarded and surfaced through :func:`exec_stats` /
``PcclSession.exec_stats()``.  This module imports JAX lazily — planning-
and sim-only processes can read stats without touching it.
"""

from __future__ import annotations

import os
import sys
import threading
from collections import OrderedDict
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.core.schedules import Round, Schedule

from .errors import ScheduleExecutionError

__all__ = [
    "CompiledSchedule",
    "ExecStats",
    "RoundGroup",
    "clear_exec_caches",
    "compile_all_to_all",
    "compile_schedule",
    "donation_compatible",
    "exec_stats",
    "expected_eager_result_shape",
    "execute_all_to_all_compact",
    "execute_compiled",
    "note_fallback_dispatch",
    "note_fused_dispatch",
    "note_trace",
    "round_tables",
]


# ----------------------------------------------------------- round tables


def round_tables(
    rnd: Round, n: int, *, ctx: str = ""
) -> Tuple[List[Tuple[int, int]], np.ndarray, np.ndarray, bool]:
    """Static per-round tables: ``(perm, send_ids[n,k], recv_ids[n,k], reduce)``.

    ``ctx`` prefixes every :class:`ScheduleExecutionError` so trace-time
    failures name the round and schedule they came from.
    """

    def err(msg: str) -> ScheduleExecutionError:
        return ScheduleExecutionError(f"{ctx}{msg}" if ctx else msg)

    if not rnd.is_permutation():
        raise err("round is not a permutation (Tx/Rx > 1)")
    senders = {t.src for t in rnd.transfers}
    if len(senders) != n:
        raise err(f"round must have all {n} ranks sending, got {len(senders)}")
    ks = {len(t.chunks) for t in rnd.transfers}
    if len(ks) != 1:
        raise err(f"non-uniform chunk counts per rank: {ks}")
    k = ks.pop()
    if k == 0:
        raise err("schedule has no chunk metadata (e.g. swing)")
    reduces = {t.reduce for t in rnd.transfers}
    if len(reduces) != 1:
        raise err("mixed reduce/store within one round")
    perm = sorted((t.src, t.dst) for t in rnd.transfers)
    send_ids = np.zeros((n, k), dtype=np.int32)
    recv_ids = np.zeros((n, k), dtype=np.int32)
    for t in rnd.transfers:
        send_ids[t.src] = np.asarray(t.chunks, dtype=np.int32)
        recv_ids[t.dst] = np.asarray(t.chunks, dtype=np.int32)
    return perm, send_ids, recv_ids, reduces.pop()


def _ctx(schedule: Schedule, i: int) -> str:
    return (
        f"{schedule.collective}/{schedule.algorithm} "
        f"round {i}/{schedule.num_rounds}: "
    )


# ------------------------------------------------------- compiled schedule


@dataclass(frozen=True)
class RoundGroup:
    """Consecutive rounds sharing ``(perm, reduce, k)``, tables stacked."""

    perm: Tuple[Tuple[int, int], ...]
    reduce: bool
    send_ids: np.ndarray  # (rounds, n, k) int32, read-only
    recv_ids: np.ndarray  # (rounds, n, k) int32, read-only

    @property
    def rounds(self) -> int:
        return self.send_ids.shape[0]


@dataclass(frozen=True)
class CompiledSchedule:
    """A schedule lowered once: validated, stacked, group-folded tables.

    ``final_slots`` is only set by :func:`compile_all_to_all`: row ``r`` maps
    origin (group-local) rank ``o`` to the slot of rank ``r``'s buffer that
    holds the block ``o → r`` after the last round.
    """

    fingerprint: str
    collective: str
    algorithm: str
    n: int  # table rows == schedule.n (the axis span)
    num_rounds: int
    groups: Tuple[RoundGroup, ...]
    final_slots: Optional[np.ndarray] = None  # (n, m) int32 — compact a2a


def _freeze(a: np.ndarray) -> np.ndarray:
    a.flags.writeable = False
    return a


def _fold_groups(
    tables: List[Tuple[List[Tuple[int, int]], np.ndarray, np.ndarray, bool]]
) -> Tuple[RoundGroup, ...]:
    """Stack consecutive rounds with equal (perm, reduce, k) into groups."""
    groups: List[RoundGroup] = []
    i = 0
    while i < len(tables):
        perm, send, recv, reduce = tables[i]
        j = i + 1
        while j < len(tables):
            p2, s2, _, r2 = tables[j]
            if p2 != perm or r2 != reduce or s2.shape != send.shape:
                break
            j += 1
        groups.append(
            RoundGroup(
                perm=tuple(perm),
                reduce=reduce,
                send_ids=_freeze(np.stack([t[1] for t in tables[i:j]])),
                recv_ids=_freeze(np.stack([t[2] for t in tables[i:j]])),
            )
        )
        i = j
    return tuple(groups)


def compile_schedule(schedule: Schedule) -> CompiledSchedule:
    """Lower ``schedule`` to stacked round-group tables (memoized by
    :meth:`Schedule.fingerprint`).

    With ``PCCL_VERIFY=1`` in the environment, every schedule is first run
    through the static chunk-dataflow verifier
    (:func:`repro.analysis.verify.assert_verified`) — a compile-time proof
    of the collective's postcondition.  The check runs only on a cache
    miss (compiles are fingerprint-memoized) and the env var is read only
    on that miss, so the disabled path costs nothing.
    """
    fp = schedule.fingerprint()
    cached = _COMPILED.get(fp)
    if cached is not None:
        return cached
    if os.environ.get("PCCL_VERIFY", "0") not in ("", "0"):
        from repro.analysis.verify import assert_verified  # lazy: avoids cycle

        assert_verified(schedule)
    tables = [
        round_tables(rnd, schedule.n, ctx=_ctx(schedule, i))
        for i, rnd in enumerate(schedule.rounds)
    ]
    compiled = CompiledSchedule(
        fingerprint=fp,
        collective=schedule.collective,
        algorithm=schedule.algorithm,
        n=schedule.n,
        num_rounds=schedule.num_rounds,
        groups=_fold_groups(tables),
    )
    _COMPILED.put(fp, compiled)
    return compiled


# ------------------------------------------------ compact (O(n)) all-to-all


def compile_all_to_all(
    schedule: Schedule, m: int, local_of: Tuple[int, ...]
) -> Optional[CompiledSchedule]:
    """Slot-addressed all-to-all: O(m·blk) state instead of O(m²·blk).

    The dense path keeps an origin×target grid so any set of in-flight
    blocks can coexist; but every generated all-to-all schedule keeps at
    most ``m`` live blocks per rank, so ``m`` slots suffice.  This compile
    statically simulates the chunk metadata: each rank starts holding its
    ``m`` outgoing blocks dest-major (slot ``t`` = block for group-local
    rank ``t``, matching ``x.reshape(m, …)``), each round's sends vacate
    slots and its receives land on free ones (gather-before-scatter, so a
    slot sent from this round can be reused this round), and a final
    ``(len(local_of), m)`` table maps origins to slots for the post-pass
    gather.

    Args:
      schedule: an all_to_all schedule over ``len(local_of)`` ranks with
        group-local chunk ids ``o*m + t`` (full-axis: ``local_of`` is the
        identity and ``m == schedule.n``).
      m: group size (blocks per rank).
      local_of: global rank → group-local index.

    Returns ``None`` whenever the metadata cannot be slot-addressed — a
    sender not holding a chunk it sends, a duplicated live block, a reduce
    round, or an unmet post-condition — in which case callers use the
    dense path.  Memoized by ``(fingerprint, local_of)``; the sentinel for
    "checked, infeasible" is cached too so the simulation runs once.
    """
    n_rows = schedule.n
    if len(local_of) != n_rows:
        raise ScheduleExecutionError(
            f"local_of covers {len(local_of)} ranks, schedule has {n_rows}"
        )
    key = (schedule.fingerprint(), m, tuple(local_of))
    cached = _COMPILED.get(key)
    if cached is not None:
        return None if cached is _INFEASIBLE else cached

    compiled = _compile_all_to_all(schedule, m, tuple(local_of))
    _COMPILED.put(key, _INFEASIBLE if compiled is None else compiled)
    return compiled


def _compile_all_to_all(
    schedule: Schedule, m: int, local_of: Tuple[int, ...]
) -> Optional[CompiledSchedule]:
    n_rows = schedule.n
    # pos[r]: chunk id -> slot, for the blocks rank r currently holds
    pos: List[Dict[int, int]] = [
        {local_of[r] * m + t: t for t in range(m)} for r in range(n_rows)
    ]
    tables = []
    for i, rnd in enumerate(schedule.rounds):
        perm, send_ids, recv_ids, reduce = round_tables(
            rnd, n_rows, ctx=_ctx(schedule, i)
        )
        if reduce:
            return None  # all-to-all never reduces; metadata says otherwise
        k = send_ids.shape[1]
        send_slots = np.zeros((n_rows, k), dtype=np.int32)
        recv_slots = np.zeros((n_rows, k), dtype=np.int32)
        # gather phase: every send leaves its slot (frees it for this
        # round's receive — the executor gathers payloads before scattering)
        for t in rnd.transfers:
            for j, c in enumerate(t.chunks):
                slot = pos[t.src].pop(c, None)
                if slot is None:
                    return None  # sender does not hold this chunk
                send_slots[t.src, j] = slot
        # scatter phase: receives land on free slots, ascending order
        for t in rnd.transfers:
            held = set(pos[t.dst].values())
            free = [s for s in range(m) if s not in held]
            if len(t.chunks) > len(free):
                return None  # more live blocks than slots
            for j, c in enumerate(t.chunks):
                if c in pos[t.dst]:
                    return None  # duplicated live block
                pos[t.dst][c] = free[j]
                recv_slots[t.dst, j] = free[j]
        tables.append((perm, send_slots, recv_slots, False))

    final_slots = np.zeros((n_rows, m), dtype=np.int32)
    for r in range(n_rows):
        for o in range(m):
            slot = pos[r].get(o * m + local_of[r])
            if slot is None:
                return None  # post-condition unmet: block (o -> r) missing
            final_slots[r, o] = slot
    return CompiledSchedule(
        fingerprint=schedule.fingerprint(),
        collective=schedule.collective,
        algorithm=schedule.algorithm,
        n=n_rows,
        num_rounds=schedule.num_rounds,
        groups=_fold_groups(tables),
        final_slots=_freeze(final_slots),
    )


# ----------------------------------------------------- donation aliasing


def expected_eager_result_shape(
    collective: str, global_shape: Tuple[int, ...]
) -> Tuple[int, ...]:
    """Result shape of the eager path for a ``(axis_size, *local)`` operand.

    Purely structural — no tracing (the eager path's 0-retrace guarantee
    must survive the check).  Row ``r`` of the result is rank ``r``'s local
    output, so the leading axis is preserved and only the first local dim
    scales: reduce-scatter splits it ``n`` ways, all-gather concatenates
    ``n`` shards, all-reduce and all-to-all preserve it.
    """
    global_shape = tuple(int(d) for d in global_shape)
    n = global_shape[0]
    if collective in ("all_reduce", "all_to_all"):
        return global_shape
    if collective == "reduce_scatter":
        if len(global_shape) < 2 or n <= 0 or global_shape[1] % n:
            raise ScheduleExecutionError(
                f"reduce_scatter: local leading dim of {global_shape} not "
                f"divisible by axis size {n}"
            )
        return (n, global_shape[1] // n) + global_shape[2:]
    if collective == "all_gather":
        if len(global_shape) < 2:
            raise ScheduleExecutionError(
                f"all_gather: operand {global_shape} has no local dims"
            )
        return (n, global_shape[1] * n) + global_shape[2:]
    raise ScheduleExecutionError(f"unknown collective {collective!r}")


def donation_compatible(collective: str, global_shape: Tuple[int, ...]) -> bool:
    """May the eager executable donate operand 0 to XLA?

    Donation aliases the result buffer onto the input buffer, which is
    only sound when their whole-array footprints coincide — the same
    :class:`~repro.analysis.pallas_model.Box` model the kernel lint uses
    for ``input_output_aliases``, applied at the executable boundary.
    """
    from repro.analysis.pallas_model import whole_array_box  # lazy: no cycle

    try:
        out_shape = expected_eager_result_shape(collective, global_shape)
    except ScheduleExecutionError:
        return False
    return whole_array_box(tuple(global_shape)) == whole_array_box(out_shape)


# --------------------------------------------------------------- execution


def execute_compiled(chunks, compiled: CompiledSchedule, axis_name: str, *, me=None):
    """Run a compiled schedule on a local chunk buffer inside ``shard_map``.

    Bit-identical to the per-round reference interpreter: same gathers,
    same permutation per round, same scatter-add/store order.  ``me``
    defaults to ``lax.axis_index(axis_name)``; grouped callers that index
    their buffers with a *group-local* rank still pass nothing here — the
    tables are always row-indexed by the global axis index.
    """
    import jax.numpy as jnp
    from jax import lax

    if me is None:
        me = lax.axis_index(axis_name)

    def apply_round(buf, send, recv, grp):
        payload = jnp.take(buf, send, axis=0)
        got = lax.ppermute(payload, axis_name, grp.perm)
        return buf.at[recv].add(got) if grp.reduce else buf.at[recv].set(got)

    for grp in compiled.groups:
        send = jnp.take(jnp.asarray(grp.send_ids), me, axis=1)  # (rounds, k)
        recv = jnp.take(jnp.asarray(grp.recv_ids), me, axis=1)
        if grp.rounds == 1:
            chunks = apply_round(chunks, send[0], recv[0], grp)
        else:

            def body(buf, sr, _grp=grp):
                return apply_round(buf, sr[0], sr[1], _grp), None

            chunks, _ = lax.scan(body, chunks, (send, recv))
    return chunks


def execute_all_to_all_compact(blocks, compiled: CompiledSchedule, axis_name: str, me):
    """Slot-compiled all-to-all: run the rounds, then gather origin-major.

    ``blocks`` is the (m, blk, …) dest-major local buffer; the return is
    (m, blk, …) origin-major.  Shared by the full-axis and grouped paths
    so the slot-gather epilogue exists exactly once.
    """
    import jax.numpy as jnp

    out = execute_compiled(blocks, compiled, axis_name, me=me)
    sel = jnp.take(jnp.asarray(compiled.final_slots), me, axis=0)  # (m,)
    return jnp.take(out, sel, axis=0)


# ------------------------------------------------------- caches & counters


class _LruCache:
    """Lock-guarded bounded LRU with hit/miss/eviction accounting."""

    def __init__(self, max_entries: int) -> None:
        self._store: "OrderedDict[Any, Any]" = OrderedDict()
        self.max_entries = max_entries
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def get(self, key):
        with self._lock:
            val = self._store.get(key)
            if val is not None:
                self.hits += 1
                self._store.move_to_end(key)
            else:
                self.misses += 1
            return val

    def put(self, key, value) -> None:
        with self._lock:
            self._store[key] = value
            self._store.move_to_end(key)
            while len(self._store) > self.max_entries:
                self._store.popitem(last=False)
                self.evictions += 1

    def clear(self) -> None:
        with self._lock:
            self._store.clear()
            self.hits = self.misses = self.evictions = 0

    def __len__(self) -> int:
        with self._lock:
            return len(self._store)


_INFEASIBLE = object()  # cached "slot compile checked and rejected" sentinel

_COMPILED = _LruCache(max_entries=256)  # fingerprint → CompiledSchedule
EXECUTABLES = _LruCache(max_entries=128)  # exec key → jitted callable

_TRACE_LOCK = threading.Lock()
_TRACES = 0

# Overlap counters, filled by repro.comm.fusion: dispatches that streamed
# producer tiles into collective rounds (vs. took the sequential fallback),
# how many chunks were streamed, and how many payload bytes moved while
# compute was still running (an upper bound on hideable wire time).
_OVERLAP_LOCK = threading.Lock()
_FUSED_DISPATCHES = 0
_FALLBACK_DISPATCHES = 0
_CHUNKS_STREAMED = 0
_BYTES_HIDDEN = 0


def note_trace() -> None:
    """Record one trace through the engine (Python body of a jitted path)."""
    global _TRACES
    with _TRACE_LOCK:
        _TRACES += 1


def note_fused_dispatch(chunks_streamed: int, bytes_hidden: int) -> None:
    """Record one fused (comm-under-compute) dispatch and its overlap volume."""
    global _FUSED_DISPATCHES, _CHUNKS_STREAMED, _BYTES_HIDDEN
    with _OVERLAP_LOCK:
        _FUSED_DISPATCHES += 1
        _CHUNKS_STREAMED += int(chunks_streamed)
        _BYTES_HIDDEN += int(bytes_hidden)


def note_fallback_dispatch() -> None:
    """Record one dispatch where fusion was requested but fell back."""
    global _FALLBACK_DISPATCHES
    with _OVERLAP_LOCK:
        _FALLBACK_DISPATCHES += 1


@dataclass(frozen=True)
class ExecStats:
    """Process-wide execution-engine counters (see ``exec_stats()``)."""

    executable_hits: int
    executable_misses: int
    executable_size: int
    compiled_hits: int
    compiled_misses: int
    compiled_size: int
    traces: int
    fused_dispatches: int = 0
    fallback_dispatches: int = 0
    chunks_streamed: int = 0
    bytes_hidden: int = 0


def exec_stats() -> ExecStats:
    """Snapshot of the engine's process-wide caches and trace counter.

    * ``executable_*`` — the jitted-executable cache the eager interp path
      fills (key: schedule fingerprint, global shape, dtype, axis name,
      group fingerprint).
    * ``compiled_*`` — the schedule→stacked-tables compile cache.
    * ``traces`` — how many times a Python trace actually ran; a warm
      steady state stops incrementing it.
    * ``fused_*``/``fallback_*``/``chunks_streamed``/``bytes_hidden`` —
      overlap counters from ``repro.comm.fusion`` (see
      :func:`note_fused_dispatch`).
    """
    with _TRACE_LOCK:
        traces = _TRACES
    with _OVERLAP_LOCK:
        fused, fallback = _FUSED_DISPATCHES, _FALLBACK_DISPATCHES
        streamed, hidden = _CHUNKS_STREAMED, _BYTES_HIDDEN
    return ExecStats(
        executable_hits=EXECUTABLES.hits,
        executable_misses=EXECUTABLES.misses,
        executable_size=len(EXECUTABLES),
        compiled_hits=_COMPILED.hits,
        compiled_misses=_COMPILED.misses,
        compiled_size=len(_COMPILED),
        traces=traces,
        fused_dispatches=fused,
        fallback_dispatches=fallback,
        chunks_streamed=streamed,
        bytes_hidden=hidden,
    )


def clear_exec_caches() -> None:
    """Drop compiled tables + executables and zero all counters (tests).

    Also clears the ``PCCL_VERIFY=1`` per-dispatch kernel-analysis memo
    (``repro.analysis.kernel_lint._VERIFIED``) so tests that toggle the env
    var cannot see stale verdicts — but only when that module is already
    loaded: importing it here would pull JAX into planning-/sim-only
    processes that this module deliberately keeps JAX-free.
    """
    global _TRACES, _FUSED_DISPATCHES, _FALLBACK_DISPATCHES
    global _CHUNKS_STREAMED, _BYTES_HIDDEN
    _COMPILED.clear()
    EXECUTABLES.clear()
    with _TRACE_LOCK:
        _TRACES = 0
    with _OVERLAP_LOCK:
        _FUSED_DISPATCHES = _FALLBACK_DISPATCHES = 0
        _CHUNKS_STREAMED = _BYTES_HIDDEN = 0
    lint = sys.modules.get("repro.analysis.kernel_lint")
    if lint is not None:
        lint.clear_verified_cache()
