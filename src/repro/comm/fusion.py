"""Comm/compute fusion: collective rounds stream through producer/consumer
kernels instead of running kernel → barrier → collective.

PCCL's end-to-end win comes from hiding communication behind compute
(PAPER.md §7), and the repo has both halves — Pallas kernels and the
compiled schedule engine (``repro.comm.exec_engine``) — but the unfused
path runs them strictly back to back.  This module fuses three seams:

**Producer-side: tile-streaming matmul + reduce-scatter**
  (:func:`fused_matmul_reduce_scatter`).  The blocked matmul
  (``repro.kernels.matmul``) finishes one output row-chunk at a time; a
  ring reduce-scatter needs its chunks at staggered *deadlines* (rank
  ``i`` first sends chunk ``i-1``, at round ``t`` it receives chunk
  ``i-t-2`` — the ring's own pipelining).  :func:`stream_program` turns a
  :class:`~repro.comm.exec_engine.CompiledSchedule` into a per-rank chunk
  *compute order* (stable sort by deadline) and proves the joint program
  feasible: a double-buffered ``lax.scan`` over steps ``s = 1..n-1``
  computes tile ``order[s]`` and then runs round ``s-1``, so round ``r``
  of chunk ``c`` starts as soon as tile ``c`` is done and all wire time
  except the final round overlaps compute.  The result is **bit-identical**
  to unfused compute-then-communicate: per-chunk kernel calls reproduce the
  whole-``M`` call exactly (see ``kernels/matmul/kernel.py``), and the
  feasibility proof guarantees no round ever reads or accumulates into a
  chunk slot before its tile was stored — every add then sees the same
  operands in the same order as ``execute_schedule_reference``.

**Consumer-side: rmsnorm at all-reduce arrival**
  (:func:`fused_all_reduce_rmsnorm`).  The last all-reduce round's output
  feeds the rmsnorm kernel inside the same jitted executable — the
  post-collective normalization pass (a full extra HBM round trip plus a
  dispatch) disappears.  Row-wise rmsnorm commutes with how the buffer is
  sharded, so this is bit-identical to all_reduce → rmsnorm by
  construction.

**Wire-compressed collectives** (:func:`execute_compiled_quantized`,
  :func:`all_reduce_quantized`).  The int8 error-feedback collective from
  ``repro.comm.pccl_collectives`` promoted to a planner-visible algorithm
  (``ring_ef8``): same transfers as ``ring``, each hop's payload quantized
  to int8 + one fp32 scale (4x less wire), priced by the cost model via
  ``Round.size`` and gated by the documented accuracy bound
  (``repro.core.cost_model.compressed_ef_error_bound``).  Stateful error
  feedback (the residual) remains a caller-side composition
  (``compressed_all_reduce_ef``); the planner prices the wire format.

Both fused entry points are **eager**: they take the global
``(axis_size, *local)`` operand convention of the interp backend's eager
path and memoize one jitted ``shard_map`` executable per (schedule,
shapes, blocks) in ``exec_engine.EXECUTABLES``.  Whenever a precondition
fails — grouped communicator, chunk rows not divisible, blocks that don't
tile, a schedule with no feasible stream program — they **fall back** to
the unfused kernel-then-collective path (never an error, never padding:
padding would break bit-identity).  Every dispatch is counted
(``exec_engine.note_fused_dispatch`` / ``note_fallback_dispatch``) and
surfaced through ``exec_stats()``.
"""

from __future__ import annotations

import threading
from dataclasses import dataclass
from typing import Optional, Tuple

import numpy as np

from repro.comm.errors import ScheduleExecutionError

from . import exec_engine
from .exec_engine import CompiledSchedule

__all__ = [
    "StreamProgram",
    "all_reduce_quantized",
    "execute_compiled_quantized",
    "fused_all_reduce_rmsnorm",
    "fused_matmul_reduce_scatter",
    "stream_program",
]


# ------------------------------------------------------- stream programs


@dataclass(frozen=True)
class StreamProgram:
    """Joint (tile, round) program for a streamable reduce-scatter.

    ``order[r]`` is rank ``r``'s chunk *compute order*: tile ``order[r, 0]``
    is computed in the prologue, then step ``s`` computes tile
    ``order[r, s]`` and runs round ``s-1``.  ``send``/``recv`` are the
    compiled tables with the (always 1 here) chunk axis squeezed.
    """

    perm: Tuple[Tuple[int, int], ...]
    order: np.ndarray  # (n, n_chunks) int32 — per-rank compute order
    send: np.ndarray   # (rounds, n) int32
    recv: np.ndarray   # (rounds, n) int32

    @property
    def rounds(self) -> int:
        return self.send.shape[0]


_STREAM_LOCK = threading.Lock()
_STREAM_PROGRAMS: dict = {}  # fingerprint -> StreamProgram | None
_STREAM_MAX = 64


def stream_program(compiled: CompiledSchedule) -> Optional[StreamProgram]:
    """Derive the per-rank tile order that lets rounds start early.

    A schedule is *streamable* when tiles can be produced one per step and
    every round still only touches chunk slots whose tile is already
    stored.  Requirements (ring reduce-scatter satisfies all of them;
    anything else returns ``None`` and callers run unfused):

    * one reducing :class:`~repro.comm.exec_engine.RoundGroup` with one
      chunk per rank per round (``k == 1``) over ``n`` chunks in
      ``n - 1`` rounds (the scan pairs one fresh tile with one round);
    * per rank, sorting chunks by *deadline* — the first round that sends
      the chunk or accumulates into it (``n - 1`` for untouched chunks) —
      yields an order in which at most ``t + 2`` chunks are needed by the
      end of round ``t`` (prologue tile + one tile per step).

    The deadline check is exact, not heuristic: it is precisely the
    condition under which the fused scan is bit-identical to unfused
    execution (no round observes an unset slot).  Memoized by schedule
    fingerprint, including the ``None`` verdict.
    """
    fp = compiled.fingerprint
    with _STREAM_LOCK:
        if fp in _STREAM_PROGRAMS:
            return _STREAM_PROGRAMS[fp]
    prog = _stream_program(compiled)
    with _STREAM_LOCK:
        if len(_STREAM_PROGRAMS) >= _STREAM_MAX:
            _STREAM_PROGRAMS.clear()
        _STREAM_PROGRAMS[fp] = prog
    return prog


def _stream_program(compiled: CompiledSchedule) -> Optional[StreamProgram]:
    if len(compiled.groups) != 1:
        return None
    grp = compiled.groups[0]
    rounds, n, k = grp.send_ids.shape
    if not grp.reduce or k != 1:
        return None
    n_chunks = int(max(grp.send_ids.max(), grp.recv_ids.max())) + 1
    if n_chunks != n or rounds != n_chunks - 1:
        return None
    send = grp.send_ids[:, :, 0]  # (rounds, n)
    recv = grp.recv_ids[:, :, 0]
    order = np.zeros((n, n_chunks), dtype=np.int32)
    for r in range(n):
        deadline = np.full(n_chunks, rounds, dtype=np.int64)
        for t in range(rounds - 1, -1, -1):
            deadline[send[t, r]] = t
            deadline[recv[t, r]] = t
        rank_order = np.argsort(deadline, kind="stable")
        # feasibility: by the time round t runs, t + 2 tiles are stored
        need = np.zeros(rounds, dtype=np.int64)
        for c in range(n_chunks):
            if deadline[c] < rounds:
                need[deadline[c]] += 1
        if (np.cumsum(need) > np.arange(rounds) + 2).any():
            return None
        order[r] = rank_order.astype(np.int32)
    return StreamProgram(
        perm=grp.perm,
        order=order,
        send=np.ascontiguousarray(send),
        recv=np.ascontiguousarray(recv),
    )


# -------------------------------------- producer fusion: matmul → reduce-scatter


def _resolve_interpret(interpret: Optional[bool]) -> bool:
    import jax

    return jax.default_backend() == "cpu" if interpret is None else interpret


def _eager_eligible(x) -> bool:
    from repro.api.backends import _eager_eligible as eligible

    return eligible(x)


def fused_matmul_reduce_scatter(
    comm,
    x,
    w,
    *,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 128,
    interpret: Optional[bool] = None,
):
    """``reduce_scatter(x_r @ w)`` with rounds streamed under the matmul.

    Eager entry point (concrete arrays, outside any trace — the global
    operand convention of the interp backend's eager path):

    Args:
      comm: interp-backend :class:`~repro.api.Communicator`.
      x: ``(axis_size, M, K)`` — row ``r`` is rank ``r``'s local activation.
      w: ``(K, N)`` replicated weight.

    Returns ``(axis_size, M // n, N)``: row ``r`` is rank ``r``'s fully
    reduced output shard ``sum_q (x_q @ w)[r·Mc : (r+1)·Mc]``.

    Takes the fused tile-streaming path when the communicator is
    ungrouped, ``M`` divides into ``n`` chunk rows, the (clipped) blocks
    tile each ``(Mc, K, N)`` chunk exactly, and the planned schedule
    admits a :func:`stream_program`; otherwise falls back to the unfused
    kernel-then-collective composition (identical result — the fused path
    is bit-identical by construction).
    """
    from repro.kernels.matmul.ops import tiles_exactly

    if not _eager_eligible(x) or not _eager_eligible(w):
        raise ScheduleExecutionError(
            "fused_matmul_reduce_scatter is an eager entry point; inside a "
            "trace compose the matmul and reduce_scatter directly"
        )
    if x.ndim != 3 or x.shape[0] != comm.axis_size:
        raise ScheduleExecutionError(
            f"expected global (axis_size={comm.axis_size}, M, K) operand, "
            f"got shape {tuple(x.shape)}"
        )
    if w.ndim != 2 or x.shape[2] != w.shape[0]:
        raise ScheduleExecutionError(
            f"weight shape {tuple(w.shape)} does not match x {tuple(x.shape)}"
        )
    n = comm.n
    _, M, K = x.shape
    N = w.shape[1]
    blocks = (block_m, block_n, block_k)
    interpret = _resolve_interpret(interpret)

    fusable = comm.groups is None and M % n == 0
    prog = None
    sched = None
    if fusable and tiles_exactly(
        M // n, K, N, block_m=block_m, block_n=block_n, block_k=block_k
    ):
        sched = comm.axis_schedule(
            "reduce_scatter", float(M) * N * x.dtype.itemsize
        )
        prog = stream_program(exec_engine.compile_schedule(sched))
    if prog is None:
        return _unfused_matmul_reduce_scatter(
            comm, x, w, blocks=blocks, interpret=interpret
        )

    key = (
        "fused_mm_rs",
        sched.fingerprint(),
        tuple(x.shape),
        tuple(w.shape),
        str(x.dtype),
        comm.axis_name,
        comm.group_fingerprint(),
        blocks,
        interpret,
    )
    fn = exec_engine.EXECUTABLES.get(key)
    if fn is None:
        fn = _build_fused_mm_rs(
            comm, prog, tuple(x.shape), N, x.dtype, blocks, interpret
        )
        exec_engine.EXECUTABLES.put(key, fn)
    out = fn(x, w)
    Mc = M // n
    # every round but the last runs with later tiles still pending
    exec_engine.note_fused_dispatch(
        chunks_streamed=n,
        bytes_hidden=comm.axis_size
        * max(0, prog.rounds - 1)
        * Mc
        * N
        * x.dtype.itemsize,
    )
    return out


def _unfused_matmul_reduce_scatter(comm, x, w, *, blocks, interpret):
    """Sequential fallback: whole-M kernel dispatch, then the collective."""
    from repro.kernels.matmul.ops import matmul

    S, M, K = x.shape
    bm, bn, bk = blocks
    y = matmul(
        x.reshape(S * M, K), w,
        block_m=bm, block_n=bn, block_k=bk,
        use_pallas=True, interpret=interpret,
    ).reshape(S, M, w.shape[1])
    exec_engine.note_fallback_dispatch()
    return comm.reduce_scatter(y)


def _build_fused_mm_rs(comm, prog, x_shape, N, dtype, blocks, interpret):
    """jit(shard_map(...)) running the joint (tile, round) stream program."""
    import jax
    import jax.numpy as jnp
    from jax import lax
    from jax.sharding import PartitionSpec as P

    from repro import compat
    from repro.kernels.matmul.kernel import matmul_pallas

    axis = comm.axis_name
    S, M, K = x_shape
    n = prog.order.shape[1]
    Mc = M // n
    bm, bn, bk = blocks
    order_dev = jnp.asarray(prog.order)
    send_dev = jnp.asarray(prog.send)
    recv_dev = jnp.asarray(prog.recv)
    perm = prog.perm

    def inner(xl, wl):
        exec_engine.note_trace()
        xloc = xl[0]  # (M, K)
        me = lax.axis_index(axis)
        order = jnp.take(order_dev, me, axis=0)  # (n,)
        send = jnp.take(send_dev, me, axis=1)    # (rounds,)
        recv = jnp.take(recv_dev, me, axis=1)

        def tile(c):
            rows = lax.dynamic_slice(xloc, (c * Mc, 0), (Mc, K))
            return matmul_pallas(
                rows, wl, block_m=bm, block_n=bn, block_k=bk,
                interpret=interpret,
            )

        buf = jnp.zeros((n, Mc, N), dtype)
        buf = buf.at[order[0]].set(tile(order[0]))

        def body(b, step):
            c, s_id, r_id = step
            b = b.at[c].set(tile(c))  # tile c is done …
            got = lax.ppermute(b[s_id], axis, perm)
            return b.at[r_id].add(got), None  # … so its round starts now

        buf, _ = lax.scan(body, buf, (order[1:], send, recv))
        return jnp.take(buf, me, axis=0)[None]

    mesh = compat.make_mesh((S,), (axis,), devices=jax.devices()[:S])
    fun = compat.shard_map(
        inner,
        mesh=mesh,
        in_specs=(P(axis, None, None), P(None, None)),
        out_specs=P(axis, None, None),
        check_vma=False,
    )
    return jax.jit(fun)


# -------------------------------- consumer fusion: all-reduce → rmsnorm


def fused_all_reduce_rmsnorm(
    comm,
    x,
    gamma,
    *,
    eps: float = 1e-5,
    interpret: Optional[bool] = None,
):
    """``rmsnorm(all_reduce(x), gamma)`` as one dispatch.

    Eager entry point: ``x`` is the global ``(axis_size, *local)`` operand
    (``local[-1] == gamma.shape[0]``), the return keeps the leading axis.
    The rmsnorm kernel runs on the last round's arrival inside the same
    executable, so the post-collective normalization pass (its own HBM
    round trip and dispatch) disappears.  Bit-identical to
    ``comm.all_reduce(x)`` followed by the rmsnorm kernel — rmsnorm is
    row-wise, so fusing it under the shard_map changes nothing numerically.

    Falls back to the sequential two-dispatch composition when the
    communicator is grouped or the flattened local size is not divisible
    by ``n`` (the unfused all_reduce pads; padding inside the fused
    executable would change the chunk layout the schedule was planned
    for).
    """
    import math

    if not _eager_eligible(x) or not _eager_eligible(gamma):
        raise ScheduleExecutionError(
            "fused_all_reduce_rmsnorm is an eager entry point; inside a "
            "trace compose all_reduce and rmsnorm directly"
        )
    if x.ndim < 2 or x.shape[0] != comm.axis_size:
        raise ScheduleExecutionError(
            f"expected global (axis_size={comm.axis_size}, *local) operand "
            f"with a feature axis, got shape {tuple(x.shape)}"
        )
    if gamma.ndim != 1 or x.shape[-1] != gamma.shape[0]:
        raise ScheduleExecutionError(
            f"gamma shape {tuple(gamma.shape)} does not match x feature axis "
            f"{tuple(x.shape)}"
        )
    from repro.kernels.rmsnorm.ops import rmsnorm

    interpret = _resolve_interpret(interpret)
    local_size = math.prod(x.shape[1:])
    if comm.groups is not None or local_size % comm.n:
        exec_engine.note_fallback_dispatch()
        red = comm.all_reduce(x)
        return rmsnorm(red, gamma, eps=eps, use_pallas=True, interpret=interpret)

    sched = comm.axis_schedule("all_reduce", float(local_size) * x.dtype.itemsize)
    key = (
        "fused_ar_rms",
        sched.fingerprint(),
        tuple(x.shape),
        tuple(gamma.shape),
        str(x.dtype),
        comm.axis_name,
        comm.group_fingerprint(),
        float(eps),
        interpret,
    )
    fn = exec_engine.EXECUTABLES.get(key)
    if fn is None:
        fn = _build_fused_ar_rms(comm, sched, tuple(x.shape), eps, interpret)
        exec_engine.EXECUTABLES.put(key, fn)
    out = fn(x, gamma)
    # consumer-side fusion: no producer tiles streamed, but one whole
    # normalization pass (read + write of the local buffer) is hidden
    exec_engine.note_fused_dispatch(chunks_streamed=0, bytes_hidden=0)
    return out


def _build_fused_ar_rms(comm, sched, x_shape, eps, interpret):
    import jax
    from jax.sharding import PartitionSpec as P

    from repro import compat
    from repro.comm import primitives as prims
    from repro.kernels.rmsnorm.ops import rmsnorm

    axis = comm.axis_name
    S = x_shape[0]

    def inner(xl, g):
        exec_engine.note_trace()
        xloc = xl[0]
        flat = xloc.reshape(-1)
        red = prims.all_reduce(flat, sched, axis).reshape(xloc.shape)
        out = rmsnorm(red, g, eps=eps, use_pallas=True, interpret=interpret)
        return out[None]

    mesh = compat.make_mesh((S,), (axis,), devices=jax.devices()[:S])
    spec = P(axis, *([None] * (len(x_shape) - 1)))
    fun = compat.shard_map(
        inner,
        mesh=mesh,
        in_specs=(spec, P(None)),
        out_specs=spec,
        check_vma=False,
    )
    return jax.jit(fun)


# -------------------------------------- wire-compressed (int8) execution


def execute_compiled_quantized(chunks, compiled: CompiledSchedule, axis_name: str, *, me=None):
    """:func:`~repro.comm.exec_engine.execute_compiled` with int8 wire.

    Identical gather/permute/scatter structure, but every hop's payload is
    quantized to int8 with one shared fp32 scale (``max|payload| / 127``)
    before the ``ppermute`` and dequantized on arrival — 4x less wire
    traffic, which is exactly what the ``ring_ef8`` schedule's
    ``Round.size * 0.25`` prices.  Lossy: per hop the round-trip error is
    at most ``scale / 2``; the accumulated bound lives in
    ``repro.core.cost_model.compressed_ef_error_bound`` and gates when
    arbitration may pick the algorithm.
    """
    import jax.numpy as jnp
    from jax import lax

    from .pccl_collectives import _dequantize, _quantize

    if me is None:
        me = lax.axis_index(axis_name)

    def apply_round(buf, send, recv, grp):
        payload = jnp.take(buf, send, axis=0)
        q, scale = _quantize(payload)
        q = lax.ppermute(q, axis_name, grp.perm)
        scale = lax.ppermute(scale, axis_name, grp.perm)
        got = _dequantize(q, scale).astype(buf.dtype)
        return buf.at[recv].add(got) if grp.reduce else buf.at[recv].set(got)

    for grp in compiled.groups:
        send = jnp.take(jnp.asarray(grp.send_ids), me, axis=1)  # (rounds, k)
        recv = jnp.take(jnp.asarray(grp.recv_ids), me, axis=1)
        if grp.rounds == 1:
            chunks = apply_round(chunks, send[0], recv[0], grp)
        else:

            def body(buf, sr, _grp=grp):
                return apply_round(buf, sr[0], sr[1], _grp), None

            chunks, _ = lax.scan(body, chunks, (send, recv))
    return chunks


def all_reduce_quantized(x, schedule, axis_name: str):
    """int8-on-the-wire all_reduce — the executable form of ``ring_ef8``.

    Same wrapper contract as :func:`repro.comm.primitives.all_reduce`
    (call inside ``shard_map``; ``x`` is the full per-rank addend), same
    chunk layout, but rounds run through
    :func:`execute_compiled_quantized`.  ``repro.api.backends`` routes
    ungrouped all_reduce here whenever the planned schedule's algorithm is
    ``ring_ef8``.
    """
    from .primitives import _split_chunks

    compiled = exec_engine.compile_schedule(schedule)
    chunks = _split_chunks(x, schedule.n)
    chunks = execute_compiled_quantized(chunks, compiled, axis_name)
    return chunks.reshape(x.shape)
