"""Legacy PCCL collective API (deprecation shim) + compressed all-reduce.

.. deprecated::
    ``PcclComm`` is a thin shim over the session API — use
    :class:`repro.api.PcclSession` and ``session.communicator(...)`` instead,
    which add a shared plan cache, fabric-state threading across collectives,
    ``split()`` sub-groups, and pluggable backends.  The old
    ``algorithm="xla"`` string hack maps to ``backend="xla"``.

Migration::

    # before
    comm = PcclComm(axis_name="data", n=8, hw=cost_model.TPU_V5E_PHOTONIC)
    # after
    session = PcclSession(cost_model.TPU_V5E_PHOTONIC)
    comm = session.communicator("data", 8, backend="interp")

The int8-compressed gradient all-reduce with error feedback lives here too
(not deprecated; it is schedule-independent).
"""

from __future__ import annotations

import warnings
from dataclasses import dataclass
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import cost_model as cm
from repro.core import schedules as S
from repro.core.topology import Topology, ring


@dataclass
class PcclComm:
    """Deprecated: session-less communicator (see module docstring)."""

    axis_name: str
    n: int
    hw: cm.HardwareParams = cm.TPU_V5E_PHOTONIC
    g0: Optional[Topology] = None
    algorithm: str = "auto"  # auto | xla | ring | rhd | dex | direct

    def __post_init__(self) -> None:
        from repro.core.pccl import SHIM_REMOVAL_VERSION

        warnings.warn(
            f"PcclComm is deprecated and will be removed in repro "
            f"{SHIM_REMOVAL_VERSION}; use repro.api.PcclSession.communicator()"
            f" for execution and PcclSession.submit(PlanRequest(...)) for "
            f"planning (it delegates bit-identically until then)",
            DeprecationWarning,
            stacklevel=2,
        )
        if self.g0 is None:
            self.g0 = ring(self.n)
        from repro.api import PcclSession

        # Legacy behavior: plan every collective cold from g0 (no threading).
        self._session = PcclSession(self.hw, g0=self.g0, thread_fabric=False)
        self._comm = self._session.communicator(
            self.axis_name,
            self.n,
            backend="xla" if self.algorithm == "xla" else "interp",
            algorithm="auto" if self.algorithm == "xla" else self.algorithm,
        )

    # ------------------------------------------------------------- planning
    def _schedule(self, collective: str, nbytes: float) -> S.Schedule:
        return self._comm._schedule(collective, nbytes)

    def chosen_algorithm(self, collective: str, nbytes: float) -> str:
        return self._comm.chosen_algorithm(collective, nbytes)

    # ----------------------------------------------------------- primitives
    def all_reduce(self, x: jax.Array) -> jax.Array:
        return self._comm.all_reduce(x)

    def reduce_scatter(self, x: jax.Array) -> jax.Array:
        """x: (n·k, …) per-rank addend → (k, …) reduced shard."""
        return self._comm.reduce_scatter(x)

    def all_gather(self, x: jax.Array) -> jax.Array:
        """x: (k, …) shard → (n·k, …) gathered."""
        return self._comm.all_gather(x)

    def all_to_all(self, x: jax.Array) -> jax.Array:
        """x: (n·b, …) destination-major blocks → (n·b, …) origin-major."""
        return self._comm.all_to_all(x)


# --------------------------------------------------------------------------
# Int8-compressed gradient all-reduce with error feedback (beyond-paper
# distributed-optimization trick; see DESIGN.md §3.4). Ring RS with per-hop
# requantization + ring AG of the reduced int8 chunks: wire bytes drop 4×
# vs fp32 at a quantization error bounded by per-chunk max/127 per hop,
# compensated across steps by the error-feedback residual.
# --------------------------------------------------------------------------


def _quantize(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-30
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compressed_all_reduce(
    x: jax.Array, axis_name: str, n: int
) -> jax.Array:
    """Ring all-reduce over int8 payloads with fp32 local accumulation.

    Call inside shard_map. x: flat fp32 buffer with size divisible by n.
    """
    chunks = x.reshape(n, -1)
    perm = [(i, (i + 1) % n) for i in range(n)]
    me = lax.axis_index(axis_name)

    # --- reduce-scatter phase: n-1 hops, chunk (me - t - 1) sent onward
    acc = chunks  # fp32 accumulation buffer
    send_idx = (me - 1) % n
    for _ in range(n - 1):
        q, s = _quantize(jnp.take(acc, send_idx, axis=0))
        q = lax.ppermute(q, axis_name, perm)
        s = lax.ppermute(s, axis_name, perm)
        recv_idx = (send_idx - 1) % n
        acc = acc.at[recv_idx].add(_dequantize(q, s))
        send_idx = recv_idx
    # now chunk `me` is fully reduced on this rank

    # --- all-gather phase: forward the reduced chunk around the ring in int8
    out = acc
    send_idx = me
    q, s = _quantize(jnp.take(out, send_idx, axis=0))
    for _ in range(n - 1):
        q = lax.ppermute(q, axis_name, perm)
        s = lax.ppermute(s, axis_name, perm)
        recv_idx = (send_idx - 1) % n
        out = out.at[recv_idx].set(_dequantize(q, s))
        send_idx = recv_idx
    return out.reshape(x.shape)


@dataclass
class ErrorFeedbackState:
    """Residual carried across steps so quantization error doesn't bias SGD."""

    residual: jax.Array

    @staticmethod
    def init(shape, dtype=jnp.float32) -> "ErrorFeedbackState":
        return ErrorFeedbackState(jnp.zeros(shape, dtype))


def compressed_all_reduce_ef(
    x: jax.Array, ef: ErrorFeedbackState, axis_name: str, n: int
) -> Tuple[jax.Array, ErrorFeedbackState]:
    """Error-feedback wrapper: reduce (x + residual), keep the new residual."""
    target = x + ef.residual
    reduced = compressed_all_reduce(target, axis_name, n)
    # residual = what we *meant* to send minus what the wire format conveyed.
    # Approximate the conveyed value by re-quantizing locally (unbiased proxy).
    q, s = _quantize(target)
    conveyed = _dequantize(q, s)
    return reduced, ErrorFeedbackState(target - conveyed)
