"""User-facing PCCL collective API for JAX programs.

``PcclComm`` binds a mesh axis to a planned collective configuration: the
PCCL planner (core) chooses the algorithm per primitive × buffer size, and
the executable interpreter (``comm.primitives``) runs the chosen schedule as
ppermute rounds.  Intended use inside ``shard_map``::

    comm = PcclComm(axis_name="data", n=8, hw=cost_model.TPU_V5E_PHOTONIC)

    def step(grads):                      # inside shard_map
        return comm.all_reduce(grads)     # schedule-driven, not XLA psum

Schedules are planned at trace time (buffer sizes are static under jit) and
cached.  ``algorithm="auto"`` reproduces the paper's §2.2 size-aware choice;
``algorithm="xla"`` falls back to the native XLA collective (the
paper-faithful *baseline* for A/B comparisons in benchmarks/EXPERIMENTS).
"""

from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core import cost_model as cm
from repro.core import schedules as S
from repro.core.pccl import CollectiveRequest, plan_collective
from repro.core.topology import Topology, ring

from . import primitives as P


def _pow2(n: int) -> bool:
    return n >= 2 and (n & (n - 1)) == 0


@dataclass
class PcclComm:
    axis_name: str
    n: int
    hw: cm.HardwareParams = cm.TPU_V5E_PHOTONIC
    g0: Optional[Topology] = None
    algorithm: str = "auto"  # auto | xla | ring | rhd | dex | direct
    _cache: Dict[Tuple[str, float], S.Schedule] = field(default_factory=dict)

    def __post_init__(self) -> None:
        if self.g0 is None:
            self.g0 = ring(self.n)

    # ------------------------------------------------------------- planning
    def _schedule(self, collective: str, nbytes: float) -> S.Schedule:
        key = (collective, nbytes)
        if key not in self._cache:
            if self.algorithm in ("auto", "paper_default"):
                plan = plan_collective(
                    CollectiveRequest(collective, self.n, nbytes, algorithm=self.algorithm),
                    self.g0,
                    self.hw,
                )
                self._cache[key] = plan.schedule
            else:
                self._cache[key] = S.get_schedule(
                    collective, self.algorithm, self.n, nbytes
                )
        return self._cache[key]

    def chosen_algorithm(self, collective: str, nbytes: float) -> str:
        return self._schedule(collective, nbytes).algorithm

    # ----------------------------------------------------------- primitives
    def all_reduce(self, x: jax.Array) -> jax.Array:
        if self.algorithm == "xla":
            return lax.psum(x, self.axis_name)
        shape = x.shape
        flat, pad = _flatten_pad(x, self.n)
        sched = self._schedule("all_reduce", flat.size * flat.dtype.itemsize)
        out = P.all_reduce(flat, sched, self.axis_name)
        return _unpad(out, pad).reshape(shape)

    def reduce_scatter(self, x: jax.Array) -> jax.Array:
        """x: (n·k, …) per-rank addend → (k, …) reduced shard."""
        if self.algorithm == "xla":
            return lax.psum_scatter(x, self.axis_name, scatter_dimension=0, tiled=True)
        sched = self._schedule("reduce_scatter", x.size * x.dtype.itemsize)
        return P.reduce_scatter(x, sched, self.axis_name)

    def all_gather(self, x: jax.Array) -> jax.Array:
        """x: (k, …) shard → (n·k, …) gathered."""
        if self.algorithm == "xla":
            return lax.all_gather(x, self.axis_name, axis=0, tiled=True)
        sched = self._schedule("all_gather", x.size * x.dtype.itemsize * self.n)
        return P.all_gather(x, sched, self.axis_name)

    def all_to_all(self, x: jax.Array) -> jax.Array:
        """x: (n·b, …) destination-major blocks → (n·b, …) origin-major."""
        if self.algorithm == "xla":
            b = x.shape[0] // self.n
            y = x.reshape((self.n, b) + x.shape[1:])
            y = lax.all_to_all(y, self.axis_name, split_axis=0, concat_axis=0, tiled=False)
            return y.reshape(x.shape)
        sched = self._schedule("all_to_all", x.size * x.dtype.itemsize)
        return P.all_to_all(x, sched, self.axis_name)


def _flatten_pad(x: jax.Array, n: int) -> Tuple[jax.Array, int]:
    flat = x.reshape(-1)
    pad = (-flat.size) % n
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), flat.dtype)])
    return flat, pad


def _unpad(x: jax.Array, pad: int) -> jax.Array:
    return x[: x.size - pad] if pad else x


# --------------------------------------------------------------------------
# Int8-compressed gradient all-reduce with error feedback (beyond-paper
# distributed-optimization trick; see DESIGN.md §3.4). Ring RS with per-hop
# requantization + ring AG of the reduced int8 chunks: wire bytes drop 4×
# vs fp32 at a quantization error bounded by per-chunk max/127 per hop,
# compensated across steps by the error-feedback residual.
# --------------------------------------------------------------------------


def _quantize(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-30
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compressed_all_reduce(
    x: jax.Array, axis_name: str, n: int
) -> jax.Array:
    """Ring all-reduce over int8 payloads with fp32 local accumulation.

    Call inside shard_map. x: flat fp32 buffer with size divisible by n.
    """
    chunks = x.reshape(n, -1)
    perm = [(i, (i + 1) % n) for i in range(n)]
    me = lax.axis_index(axis_name)

    # --- reduce-scatter phase: n-1 hops, chunk (me - t - 1) sent onward
    acc = chunks  # fp32 accumulation buffer
    send_idx = (me - 1) % n
    for _ in range(n - 1):
        q, s = _quantize(jnp.take(acc, send_idx, axis=0))
        q = lax.ppermute(q, axis_name, perm)
        s = lax.ppermute(s, axis_name, perm)
        recv_idx = (send_idx - 1) % n
        acc = acc.at[recv_idx].add(_dequantize(q, s))
        send_idx = recv_idx
    # now chunk `me` is fully reduced on this rank

    # --- all-gather phase: forward the reduced chunk around the ring in int8
    out = acc
    send_idx = me
    q, s = _quantize(jnp.take(out, send_idx, axis=0))
    for _ in range(n - 1):
        q = lax.ppermute(q, axis_name, perm)
        s = lax.ppermute(s, axis_name, perm)
        recv_idx = (send_idx - 1) % n
        out = out.at[recv_idx].set(_dequantize(q, s))
        send_idx = recv_idx
    return out.reshape(x.shape)


@dataclass
class ErrorFeedbackState:
    """Residual carried across steps so quantization error doesn't bias SGD."""

    residual: jax.Array

    @staticmethod
    def init(shape, dtype=jnp.float32) -> "ErrorFeedbackState":
        return ErrorFeedbackState(jnp.zeros(shape, dtype))


def compressed_all_reduce_ef(
    x: jax.Array, ef: ErrorFeedbackState, axis_name: str, n: int
) -> Tuple[jax.Array, ErrorFeedbackState]:
    """Error-feedback wrapper: reduce (x + residual), keep the new residual."""
    target = x + ef.residual
    reduced = compressed_all_reduce(target, axis_name, n)
    # residual = what we *meant* to send minus what the wire format conveyed.
    # Approximate the conveyed value by re-quantizing locally (unbiased proxy).
    q, s = _quantize(target)
    conveyed = _dequantize(q, s)
    return reduced, ErrorFeedbackState(target - conveyed)
