"""Executable PCCL collectives: schedules → ``jax.lax.ppermute`` rounds.

This is the TPU-native realization of PCCL's "one circuit set per round"
(DESIGN.md §2): every :class:`~repro.core.schedules.Round` of a schedule is a
permutation (each rank ≤1 Tx, ≤1 Rx — the paper's per-tile transmitter
constraint), so it lowers to exactly one ``ppermute`` whose permutation *is*
the circuit set PCCL would program on the photonic fabric.

``execute_schedule`` is a generic interpreter: it reads the chunk metadata of
the *same* Schedule objects the analytical planner prices, so the modeled and
executed communication cannot drift apart.  Per round it

1. gathers the chunks this rank must send (a static per-rank table indexed by
   the runtime ``axis_index``),
2. ppermutes them along the mesh axis, and
3. scatter-adds (reduce rounds) or scatter-stores (gather rounds) the payload
   into the local chunk buffer.

Requirements on the schedule (all generators in ``core.schedules`` satisfy
them; asserted at trace time):
* every round is a permutation in which **every** rank sends, and
* within a round all ranks send the same number of chunks.
"""

from __future__ import annotations

import functools
from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core.schedules import Round, Schedule

from .errors import ScheduleExecutionError


def _round_tables(rnd: Round, n: int) -> Tuple[List[Tuple[int, int]], np.ndarray, np.ndarray, bool]:
    """Static per-round tables: (perm, send_ids[n,k], recv_ids[n,k], reduce)."""
    if not rnd.is_permutation():
        raise ScheduleExecutionError("round is not a permutation (Tx/Rx > 1)")
    senders = {t.src for t in rnd.transfers}
    if len(senders) != n:
        raise ScheduleExecutionError(
            f"round must have all {n} ranks sending, got {len(senders)}"
        )
    ks = {len(t.chunks) for t in rnd.transfers}
    if len(ks) != 1:
        raise ScheduleExecutionError(f"non-uniform chunk counts per rank: {ks}")
    k = ks.pop()
    if k == 0:
        raise ScheduleExecutionError("schedule has no chunk metadata (e.g. swing)")
    reduces = {t.reduce for t in rnd.transfers}
    if len(reduces) != 1:
        raise ScheduleExecutionError("mixed reduce/store within one round")
    perm = sorted((t.src, t.dst) for t in rnd.transfers)
    send_ids = np.zeros((n, k), dtype=np.int32)
    recv_ids = np.zeros((n, k), dtype=np.int32)
    for t in rnd.transfers:
        send_ids[t.src] = np.asarray(t.chunks, dtype=np.int32)
        recv_ids[t.dst] = np.asarray(t.chunks, dtype=np.int32)
    return perm, send_ids, recv_ids, reduces.pop()


def execute_schedule(
    chunks: jax.Array, schedule: Schedule, axis_name: str
) -> jax.Array:
    """Run a schedule's rounds on a local chunk buffer inside ``shard_map``.

    Args:
      chunks: (n_chunks, *chunk_shape) local buffer; chunk ids as in the
        schedule (RS/AG: n_chunks == n; AllToAll: n_chunks == n with id
        src*n+dst mapped to local block dst — see callers).
      schedule: permutation-round schedule from ``repro.core.schedules``.
      axis_name: mesh axis of size ``schedule.n``.

    Returns the updated local chunk buffer.
    """
    n = schedule.n
    me = lax.axis_index(axis_name)
    for rnd in schedule.rounds:
        perm, send_ids, recv_ids, reduce = _round_tables(rnd, n)
        my_send = jnp.take(jnp.asarray(send_ids), me, axis=0)       # (k,)
        my_recv = jnp.take(jnp.asarray(recv_ids), me, axis=0)       # (k,)
        payload = jnp.take(chunks, my_send, axis=0)                 # (k, …)
        got = lax.ppermute(payload, axis_name, perm)
        if reduce:
            chunks = chunks.at[my_recv].add(got)
        else:
            chunks = chunks.at[my_recv].set(got)
    return chunks


# --------------------------------------------------------------------------
# Collective wrappers (call inside shard_map over `axis_name`).
# --------------------------------------------------------------------------


def _split_chunks(x: jax.Array, n: int) -> jax.Array:
    if x.shape[0] % n:
        raise ScheduleExecutionError(
            f"leading dim {x.shape[0]} not divisible by {n} ranks"
        )
    return x.reshape((n, x.shape[0] // n) + x.shape[1:])


def reduce_scatter(x: jax.Array, schedule: Schedule, axis_name: str) -> jax.Array:
    """x: full per-rank buffer (each rank holds its own addend).
    Returns this rank's fully reduced chunk (1/n of the buffer)."""
    n = schedule.n
    chunks = _split_chunks(x, n)
    chunks = execute_schedule(chunks, schedule, axis_name)
    me = lax.axis_index(axis_name)
    return jnp.take(chunks, me, axis=0)


def all_gather(x: jax.Array, schedule: Schedule, axis_name: str) -> jax.Array:
    """x: this rank's shard. Returns the concatenated full buffer."""
    n = schedule.n
    me = lax.axis_index(axis_name)
    chunks = jnp.zeros((n,) + x.shape, x.dtype).at[me].set(x)
    chunks = execute_schedule(chunks, schedule, axis_name)
    return chunks.reshape((n * x.shape[0],) + x.shape[1:])


def all_reduce(x: jax.Array, schedule: Schedule, axis_name: str) -> jax.Array:
    """x: full per-rank buffer. Returns sum over ranks, replicated.
    The schedule must be an all_reduce composition (RS rounds + AG rounds)."""
    n = schedule.n
    chunks = _split_chunks(x, n)
    chunks = execute_schedule(chunks, schedule, axis_name)
    return chunks.reshape(x.shape)


def all_to_all(x: jax.Array, schedule: Schedule, axis_name: str) -> jax.Array:
    """x: (n*blk, …) where block j is this rank's payload for rank j.
    Returns (n*blk, …) where block j is the payload received from rank j.

    Chunk ids in all_to_all schedules are ``src*n + dst``; locally each rank
    stores the block for chunk id c at slot that depends on the phase: we keep
    a full n×n-addressable buffer indexed by origin — memory-inefficient for
    huge n but exact w.r.t. the schedule semantics (blocks in flight from
    different origins can coexist at one rank, e.g. DEX)."""
    n = schedule.n
    blocks = _split_chunks(x, n)                       # (n, blk, …) dest-major
    me = lax.axis_index(axis_name)
    # state[o, t] = block from origin o to target t, held locally (zeros if
    # not present). Initially we hold row `me`.
    state = jnp.zeros((n, n) + blocks.shape[1:], blocks.dtype)
    state = state.at[me].set(blocks)
    flat = state.reshape((n * n,) + blocks.shape[1:])
    flat = execute_schedule(flat, schedule, axis_name)
    state = flat.reshape((n, n) + blocks.shape[1:])
    # post-condition: we hold (o -> me) for every origin o
    return jnp.take(state, me, axis=1).reshape(x.shape)
