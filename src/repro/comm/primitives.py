"""Executable PCCL collectives: schedules → ``jax.lax.ppermute`` rounds.

This is the TPU-native realization of PCCL's "one circuit set per round"
(DESIGN.md §2): every :class:`~repro.core.schedules.Round` of a schedule is a
permutation (each rank ≤1 Tx, ≤1 Rx — the paper's per-tile transmitter
constraint), so it lowers to exactly one ``ppermute`` whose permutation *is*
the circuit set PCCL would program on the photonic fabric.

``execute_schedule`` is the hot path: it hands the schedule to the compiled
execution engine (:mod:`repro.comm.exec_engine`), which derives all static
per-round tables once (memoized process-wide by ``Schedule.fingerprint()``)
and folds runs of rounds sharing a permutation into a single ``lax.scan`` —
same chunk metadata, same add order, bit-identical outputs, O(round-groups)
trace size.  ``execute_schedule_reference`` keeps the original per-round
interpreter as the engine's equivalence oracle (tests, benchmarks).

``all_to_all`` uses the engine's slot-addressed compile: local state is one
``(n, blk)`` buffer — O(n·blk) memory — whenever the chunk metadata admits
one live block per slot (every generated all-to-all schedule does; asserted
statically at compile time).  ``all_to_all_dense`` keeps the original
origin×target O(n²·blk) state as the fallback and cross-check path.

Requirements on the schedule (all generators in ``core.schedules`` satisfy
them; asserted at trace time):
* every round is a permutation in which **every** rank sends, and
* within a round all ranks send the same number of chunks.
"""

from __future__ import annotations

from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core.schedules import Round, Schedule

from .errors import ScheduleExecutionError
from .exec_engine import (
    compile_all_to_all,
    compile_schedule,
    execute_all_to_all_compact,
    execute_compiled,
    round_tables,
)


def _round_tables(
    rnd: Round, n: int, *, ctx: str = ""
) -> Tuple[List[Tuple[int, int]], np.ndarray, np.ndarray, bool]:
    """Static per-round tables (see :func:`repro.comm.exec_engine.round_tables`)."""
    return round_tables(rnd, n, ctx=ctx)


def execute_schedule(
    chunks: jax.Array, schedule: Schedule, axis_name: str
) -> jax.Array:
    """Run a schedule's rounds on a local chunk buffer inside ``shard_map``.

    Args:
      chunks: (n_chunks, *chunk_shape) local buffer; chunk ids as in the
        schedule (RS/AG: n_chunks == n; AllToAll: n_chunks == n with id
        src*n+dst mapped to local block dst — see callers).
      schedule: permutation-round schedule from ``repro.core.schedules``.
      axis_name: mesh axis of size ``schedule.n``.

    Returns the updated local chunk buffer.  Compiles the schedule once
    (process-wide memo) and runs the fused engine — bit-identical to
    :func:`execute_schedule_reference`.
    """
    return execute_compiled(chunks, compile_schedule(schedule), axis_name)


def execute_schedule_reference(
    chunks: jax.Array, schedule: Schedule, axis_name: str
) -> jax.Array:
    """Pre-engine per-round interpreter — the engine's bit-identity oracle.

    Re-derives static tables per round per trace and emits one ppermute +
    scatter pair per round with no fusion.  Kept for equivalence tests and
    the ``exec_bench`` old-vs-new comparison; use ``execute_schedule``.
    """
    n = schedule.n
    me = lax.axis_index(axis_name)
    for i, rnd in enumerate(schedule.rounds):
        ctx = f"{schedule.collective}/{schedule.algorithm} round {i}/{schedule.num_rounds}: "
        perm, send_ids, recv_ids, reduce = round_tables(rnd, n, ctx=ctx)
        my_send = jnp.take(jnp.asarray(send_ids), me, axis=0)       # (k,)
        my_recv = jnp.take(jnp.asarray(recv_ids), me, axis=0)       # (k,)
        payload = jnp.take(chunks, my_send, axis=0)                 # (k, …)
        got = lax.ppermute(payload, axis_name, perm)
        if reduce:
            chunks = chunks.at[my_recv].add(got)
        else:
            chunks = chunks.at[my_recv].set(got)
    return chunks


# --------------------------------------------------------------------------
# Collective wrappers (call inside shard_map over `axis_name`).
# --------------------------------------------------------------------------


def _split_chunks(x: jax.Array, n: int) -> jax.Array:
    if x.shape[0] % n:
        raise ScheduleExecutionError(
            f"leading dim {x.shape[0]} not divisible by {n} ranks"
        )
    return x.reshape((n, x.shape[0] // n) + x.shape[1:])


def reduce_scatter(x: jax.Array, schedule: Schedule, axis_name: str) -> jax.Array:
    """x: full per-rank buffer (each rank holds its own addend).
    Returns this rank's fully reduced chunk (1/n of the buffer)."""
    n = schedule.n
    chunks = _split_chunks(x, n)
    chunks = execute_schedule(chunks, schedule, axis_name)
    me = lax.axis_index(axis_name)
    return jnp.take(chunks, me, axis=0)


def all_gather(x: jax.Array, schedule: Schedule, axis_name: str) -> jax.Array:
    """x: this rank's shard. Returns the concatenated full buffer."""
    n = schedule.n
    me = lax.axis_index(axis_name)
    chunks = jnp.zeros((n,) + x.shape, x.dtype).at[me].set(x)
    chunks = execute_schedule(chunks, schedule, axis_name)
    return chunks.reshape((n * x.shape[0],) + x.shape[1:])


def all_reduce(x: jax.Array, schedule: Schedule, axis_name: str) -> jax.Array:
    """x: full per-rank buffer. Returns sum over ranks, replicated.
    The schedule must be an all_reduce composition (RS rounds + AG rounds)."""
    n = schedule.n
    chunks = _split_chunks(x, n)
    chunks = execute_schedule(chunks, schedule, axis_name)
    return chunks.reshape(x.shape)


def all_to_all(x: jax.Array, schedule: Schedule, axis_name: str) -> jax.Array:
    """x: (n*blk, …) where block j is this rank's payload for rank j.
    Returns (n*blk, …) where block j is the payload received from rank j.

    Chunk ids in all_to_all schedules are ``src*n + dst``.  The engine's
    slot-addressed compile keeps local state at one (n, blk, …) buffer —
    O(n·blk) memory — assigning every in-flight block a live slot from the
    static chunk metadata; schedules whose metadata cannot be
    slot-addressed fall back to :func:`all_to_all_dense`.
    """
    n = schedule.n
    compact = compile_all_to_all(schedule, n, tuple(range(n)))
    if compact is None:
        return all_to_all_dense(x, schedule, axis_name)
    blocks = _split_chunks(x, n)                       # (n, blk, …) dest-major
    me = lax.axis_index(axis_name)
    return execute_all_to_all_compact(blocks, compact, axis_name, me).reshape(x.shape)


def run_reference(
    collective: str, x: jax.Array, schedule: Schedule, axis_name: str
) -> jax.Array:
    """Whole-collective pre-engine interpreter — the bit-identity oracle.

    The original wrappers verbatim over :func:`execute_schedule_reference`
    (dense all-to-all state included); shared by the equivalence tests and
    ``benchmarks/exec_bench.py`` so the oracle exists exactly once.
    """
    n = schedule.n
    me = lax.axis_index(axis_name)
    if collective == "reduce_scatter":
        chunks = _split_chunks(x, n)
        chunks = execute_schedule_reference(chunks, schedule, axis_name)
        return jnp.take(chunks, me, axis=0)
    if collective == "all_gather":
        chunks = jnp.zeros((n,) + x.shape, x.dtype).at[me].set(x)
        chunks = execute_schedule_reference(chunks, schedule, axis_name)
        return chunks.reshape((n * x.shape[0],) + x.shape[1:])
    if collective == "all_reduce":
        chunks = _split_chunks(x, n)
        chunks = execute_schedule_reference(chunks, schedule, axis_name)
        return chunks.reshape(x.shape)
    if collective == "all_to_all":
        blocks = _split_chunks(x, n)
        state = jnp.zeros((n, n) + blocks.shape[1:], blocks.dtype)
        state = state.at[me].set(blocks)
        flat = state.reshape((n * n,) + blocks.shape[1:])
        flat = execute_schedule_reference(flat, schedule, axis_name)
        state = flat.reshape((n, n) + blocks.shape[1:])
        return jnp.take(state, me, axis=1).reshape(x.shape)
    raise ScheduleExecutionError(f"unknown collective {collective!r}")


def all_to_all_dense(x: jax.Array, schedule: Schedule, axis_name: str) -> jax.Array:
    """Dense-state all-to-all: the pre-engine fallback and cross-check path.

    Keeps a full n×n-addressable buffer indexed by origin — O(n²·blk)
    memory, but exact for *any* schedule semantics (arbitrarily many blocks
    in flight from different origins can coexist at one rank)."""
    n = schedule.n
    blocks = _split_chunks(x, n)                       # (n, blk, …) dest-major
    me = lax.axis_index(axis_name)
    # state[o, t] = block from origin o to target t, held locally (zeros if
    # not present). Initially we hold row `me`.
    state = jnp.zeros((n, n) + blocks.shape[1:], blocks.dtype)
    state = state.at[me].set(blocks)
    flat = state.reshape((n * n,) + blocks.shape[1:])
    flat = execute_schedule(flat, schedule, axis_name)
    state = flat.reshape((n, n) + blocks.shape[1:])
    # post-condition: we hold (o -> me) for every origin o
    return jnp.take(state, me, axis=1).reshape(x.shape)
