"""Version-compatibility shims for the JAX APIs this repo spans.

The library is developed against recent JAX but must run on older releases
(the CI image pins one without ``jax.shard_map`` / ``jax.sharding.AxisType``).
Everything that touches those APIs goes through here:

* :func:`shard_map` — ``jax.shard_map`` when present, else
  ``jax.experimental.shard_map.shard_map``; the new ``check_vma`` kwarg is
  translated to the old ``check_rep``.
* :func:`make_mesh` — ``jax.make_mesh`` with ``axis_types`` only when the
  running JAX accepts it (older versions have neither the kwarg nor
  ``jax.sharding.AxisType``).
* :data:`AXIS_TYPE_AUTO` — ``jax.sharding.AxisType.Auto`` or ``None``.
"""

from __future__ import annotations

import inspect
from typing import Any, Optional, Sequence

import jax

__all__ = ["AXIS_TYPE_AUTO", "make_mesh", "shard_map"]

AXIS_TYPE_AUTO = getattr(getattr(jax.sharding, "AxisType", None), "Auto", None)

if hasattr(jax, "shard_map"):
    _shard_map = jax.shard_map
else:  # pragma: no cover - exercised on older JAX only
    from jax.experimental.shard_map import shard_map as _shard_map

_SHARD_MAP_PARAMS = set(inspect.signature(_shard_map).parameters)


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
    """`jax.shard_map` across JAX versions (``check_vma`` ↔ ``check_rep``)."""
    kwargs = {"mesh": mesh, "in_specs": in_specs, "out_specs": out_specs}
    if "check_vma" in _SHARD_MAP_PARAMS:
        kwargs["check_vma"] = check_vma
    elif "check_rep" in _SHARD_MAP_PARAMS:
        kwargs["check_rep"] = check_vma
    return _shard_map(f, **kwargs)


_MAKE_MESH_PARAMS = set(inspect.signature(jax.make_mesh).parameters)


def make_mesh(
    axis_shapes: Sequence[int],
    axis_names: Sequence[str],
    *,
    devices: Optional[Sequence[Any]] = None,
    auto_axis_types: bool = True,
) -> jax.sharding.Mesh:
    """`jax.make_mesh` that only passes ``axis_types`` where supported."""
    kwargs: dict = {}
    if devices is not None:
        kwargs["devices"] = devices
    if (
        auto_axis_types
        and AXIS_TYPE_AUTO is not None
        and "axis_types" in _MAKE_MESH_PARAMS
    ):
        kwargs["axis_types"] = (AXIS_TYPE_AUTO,) * len(tuple(axis_names))
    return jax.make_mesh(tuple(axis_shapes), tuple(axis_names), **kwargs)
