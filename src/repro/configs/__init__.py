"""Architecture registry: ``get_config("<arch-id>")`` (+ ``SHAPES``)."""

from importlib import import_module
from typing import Dict, List

from .base import (
    SHAPES,
    EncDecConfig,
    HybridConfig,
    MLAConfig,
    ModelConfig,
    MoEConfig,
    ShapeConfig,
    SSMConfig,
    VLMConfig,
    XLSTMConfig,
    cells_for,
    shape_applicable,
)

_MODULES = {
    "granite-20b": "granite_20b",
    "chatglm3-6b": "chatglm3_6b",
    "mistral-large-123b": "mistral_large_123b",
    "minitron-4b": "minitron_4b",
    "xlstm-1.3b": "xlstm_1_3b",
    "internvl2-26b": "internvl2_26b",
    "olmoe-1b-7b": "olmoe_1b_7b",
    "deepseek-v2-lite-16b": "deepseek_v2_lite_16b",
    "whisper-small": "whisper_small",
    "zamba2-2.7b": "zamba2_2_7b",
    "bert-base-paper": "bert_base",
}

ARCH_IDS: List[str] = [k for k in _MODULES if k != "bert-base-paper"]


def get_config(arch: str) -> ModelConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; have {sorted(_MODULES)}")
    return import_module(f"repro.configs.{_MODULES[arch]}").CONFIG


def all_configs() -> Dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCH_IDS}
