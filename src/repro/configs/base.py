"""Config system: model architecture + input-shape registry.

Every assigned architecture gets a module ``configs/<id>.py`` exporting
``CONFIG`` (exact published numbers) built on these dataclasses.  Each config
can derive a ``reduced()`` variant — same family and code paths, tiny sizes —
used by CPU smoke tests; the full config is only ever lowered via
ShapeDtypeStructs in the dry-run.

The four assigned input shapes live in ``SHAPES``; applicability per arch
(decode vs train vs long-context) is resolved by :func:`cells_for`.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, replace
from typing import Dict, List, Optional, Tuple

import jax.numpy as jnp


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_expert: int               # per-expert FFN hidden size
    n_shared: int = 0           # shared (always-on) experts
    capacity_factor: float = 1.25
    first_dense: int = 0        # leading dense layers (DeepSeek: 1)
    d_first_dense_ff: int = 0   # FFN width of those dense layers
    # "grouped": per-batch-row dispatch groups → (G,E,C,D) buffers sharded
    # over data×model (EP×DP). "global": legacy single pool (§Perf baseline —
    # replicates expert compute across the data axis).
    dispatch: str = "grouped"


@dataclass(frozen=True)
class MLAConfig:
    kv_lora: int = 512
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_dim: int = 128


@dataclass(frozen=True)
class SSMConfig:
    d_state: int = 64
    head_dim: int = 64
    expand: int = 2
    conv_width: int = 4
    chunk: int = 64


@dataclass(frozen=True)
class XLSTMConfig:
    slstm_every: int = 8        # one sLSTM block per this many blocks
    proj_factor: float = 2.0
    chunk: int = 64


@dataclass(frozen=True)
class HybridConfig:
    shared_attn_every: int = 6  # Zamba2: shared attn block cadence
    lora_rank: int = 64


@dataclass(frozen=True)
class EncDecConfig:
    n_enc_layers: int = 12
    enc_seq: int = 1500         # stubbed mel-frame embeddings


@dataclass(frozen=True)
class VLMConfig:
    n_img_tokens: int = 256     # stubbed ViT patch embeddings


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | ssm | vlm | audio | hybrid
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None          # default d_model // n_heads
    mlp_type: str = "swiglu"                # swiglu | gelu | relu2
    rope_style: str = "full"                # full | chatglm_2d | none | sinusoidal
    norm_type: str = "rmsnorm"              # rmsnorm | layernorm
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    xlstm: Optional[XLSTMConfig] = None
    hybrid: Optional[HybridConfig] = None
    enc_dec: Optional[EncDecConfig] = None
    vlm: Optional[VLMConfig] = None
    dtype: str = "bfloat16"                 # activation/compute dtype
    param_dtype: str = "float32"
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    remat: str = "full"                     # full | dots | none
    attention_impl: str = "full"            # full | blocked (flash-style jnp)
    scan_layers: bool = True
    use_pallas: bool = False                # Pallas kernels (TPU) vs jnp ref
    source: str = ""

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.n_heads

    @property
    def sub_quadratic(self) -> bool:
        """Can this arch decode at 500 K context (SSM/linear/hybrid state)?"""
        return self.family in ("ssm", "hybrid")

    @property
    def has_decoder(self) -> bool:
        return True  # all assigned archs are decoder-bearing

    def act_dtype(self):
        return jnp.bfloat16 if self.dtype == "bfloat16" else jnp.float32

    def reduced(self) -> "ModelConfig":
        """Tiny same-family variant for CPU smoke tests."""
        kw: Dict = dict(
            n_layers=min(self.n_layers, 4),
            d_model=64,
            n_heads=4,
            n_kv_heads=max(1, min(self.n_kv_heads * 4 // self.n_heads, 4)),
            d_ff=128,
            vocab=256,
            head_dim=16,
            use_pallas=False,
            scan_layers=self.scan_layers,
            dtype="float32",  # CPU smoke: fp32 is faster & removes bf16 noise
        )
        if self.moe:
            kw["moe"] = replace(
                self.moe,
                n_experts=8,
                top_k=min(self.moe.top_k, 2),
                d_expert=32,
                d_first_dense_ff=64 if self.moe.first_dense else 0,
                # no token dropping in smoke tests: decode must equal prefill
                capacity_factor=float(8),
            )
        if self.mla:
            kw["mla"] = MLAConfig(kv_lora=32, qk_nope_dim=16, qk_rope_dim=8, v_dim=16)
        if self.ssm:
            kw["ssm"] = replace(self.ssm, d_state=16, head_dim=16, chunk=16)
        if self.xlstm:
            kw["xlstm"] = replace(self.xlstm, slstm_every=2, chunk=16)
            kw["n_layers"] = 4
        if self.hybrid:
            kw["hybrid"] = replace(self.hybrid, shared_attn_every=2, lora_rank=8)
        if self.enc_dec:
            kw["enc_dec"] = EncDecConfig(n_enc_layers=2, enc_seq=32)
        if self.vlm:
            kw["vlm"] = VLMConfig(n_img_tokens=8)
        return replace(self, **kw)


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """(runs?, reason-if-skipped). long_500k needs sub-quadratic attention
    (DESIGN.md §3.2); all assigned archs have decoders so decode shapes run."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, "pure full-attention arch: 500k decode needs sub-quadratic attention"
    return True, ""


def cells_for(cfg: ModelConfig) -> List[Tuple[ShapeConfig, bool, str]]:
    return [(s, *shape_applicable(cfg, s)) for s in SHAPES.values()]
