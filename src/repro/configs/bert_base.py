"""bert_base: the paper's own end-to-end workload (§6): 12L 16H d_model=2048
transformer trained with FlexFlow-style simulation. We model it as a dense
decoder with GELU MLP (d_ff=4*d) for the task-graph benchmarks."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="bert-base-paper",
    family="dense",
    n_layers=12,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=8192,
    vocab=30522,
    mlp_type="gelu",
    norm_type="layernorm",
    source="paper §6 workload",
)
