"""deepseek-v2-lite-16b [moe]: 27L d_model=2048 16H, MLA (kv_lora=512),
2 shared + 64 routed experts top-6, expert d_ff=1408, vocab=102400, first
layer dense (d_ff=10944) [arXiv:2405.04434; hf].

NOTE: the assignment prose says "160 routed" but the spec header says
"MoE 64e top-6"; 64 routed is correct for V2-*Lite* (DESIGN.md §3.1)."""
from .base import MLAConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b",
    family="moe",
    n_layers=27,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab=102400,
    mlp_type="swiglu",
    mla=MLAConfig(kv_lora=512, qk_nope_dim=128, qk_rope_dim=64, v_dim=128),
    moe=MoEConfig(
        n_experts=64,
        top_k=6,
        d_expert=1408,
        n_shared=2,
        first_dense=1,
        d_first_dense_ff=10944,
    ),
    source="arXiv:2405.04434; hf",
)
