"""internvl2-26b [vlm]: InternLM2-20B backbone 48L d_model=6144 48H (GQA kv=8)
d_ff=16384 vocab=92553; InternViT frontend is a stub (precomputed patch
embeddings via input_specs) [arXiv:2404.16821; hf]."""
from .base import ModelConfig, VLMConfig

CONFIG = ModelConfig(
    name="internvl2-26b",
    family="vlm",
    n_layers=48,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=16384,
    vocab=92553,
    mlp_type="swiglu",
    vlm=VLMConfig(n_img_tokens=256),
    source="arXiv:2404.16821; hf",
)
