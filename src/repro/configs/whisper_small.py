"""whisper-small [audio]: enc-dec, 12+12L d_model=768 12H (MHA kv=12)
d_ff=3072 vocab=51865; conv mel frontend is a stub (precomputed frame
embeddings via input_specs) [arXiv:2212.04356; unverified]."""
from .base import EncDecConfig, ModelConfig

CONFIG = ModelConfig(
    name="whisper-small",
    family="audio",
    n_layers=12,
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    d_ff=3072,
    vocab=51865,
    mlp_type="gelu",
    norm_type="layernorm",
    rope_style="sinusoidal",
    enc_dec=EncDecConfig(n_enc_layers=12, enc_seq=1500),
    source="arXiv:2212.04356; unverified",
)
