"""xlstm-1.3b [ssm]: 48 blocks d_model=2048 4H, sLSTM + mLSTM, no FFN
(d_ff=0 honoured: the blocks carry their own up/down projections)
[arXiv:2405.04517; unverified]."""
from .base import ModelConfig, XLSTMConfig

CONFIG = ModelConfig(
    name="xlstm-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab=50304,
    head_dim=512,
    rope_style="none",
    xlstm=XLSTMConfig(slstm_every=8, proj_factor=2.0, chunk=64),
    source="arXiv:2405.04517; unverified",
)
