"""zamba2-2.7b [hybrid]: 54 Mamba2 blocks d_model=2560, ssm_state=64, plus one
parameter-shared attention+MLP block (32H GQA kv=32, d_ff=10240) invoked every
6 blocks with per-invocation LoRA [arXiv:2411.15242; hf]."""
from .base import HybridConfig, ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    family="hybrid",
    n_layers=54,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    d_ff=10240,
    vocab=32000,
    mlp_type="gelu",
    ssm=SSMConfig(d_state=64, head_dim=64, expand=2, conv_width=4, chunk=64),
    hybrid=HybridConfig(shared_attn_every=6, lora_rank=64),
    source="arXiv:2411.15242; hf",
)
