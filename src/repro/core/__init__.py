"""PCCL core — the paper's primary contribution.

Layers:
* ``topology``   — circuit-graph zoo (ring/torus/grid/hypercube/ideal).
* ``schedules``  — collective algorithm round schedules (ring, RHD, bucket,
                   swing, DEX, direct, p2p) with chunk-level semantics.
* ``cost_model`` — extended α–β model with congestion + dilation (Alg. 2).
* ``planner``    — the reconfiguration scheduler (Alg. 1) as an exact DP,
                   plus brute-force and MILP oracles.
* ``simulate``   — semantic verifier for schedule post-conditions.
* ``circuits``   — MZI-mesh circuit routing (Alg. 3).
* ``fibers``     — inter-server fiber routing ILP/heuristic (Alg. 4).
* ``pccl``       — user-facing planning facade.
"""

from .cost_model import (
    H100_DGX,
    PRESETS,
    TPU_V5E_OCS,
    TPU_V5E_PHOTONIC,
    HardwareParams,
    RoundCost,
    ScheduleCost,
    comm_cost_round,
    ideal_cost,
    reconfig_cost,
    schedule_cost_fixed,
)
from .pccl import (
    CollectiveRequest,
    PcclPlan,
    baseline_cost,
    choose_algorithm,
    plan_collective,
    plan_collective_sweep,
    theoretical_cost,
)
from .planner import (
    Plan,
    PlanStep,
    PlanStructure,
    build_structure,
    plan,
    plan_bruteforce,
    plan_milp,
    plan_sweep,
)
from .schedules import Round, Schedule, Transfer, get_schedule, split_for_fanout
from .simulate import SimulationError, simulate, verify
from .topology import (
    Topology,
    from_transfers,
    fully_connected,
    grid2d,
    grid3d,
    hypercube,
    line,
    ring,
    standard_topologies,
    topology_by_name,
    torus2d,
    torus3d,
)

__all__ = [k for k in dir() if not k.startswith("_")]
