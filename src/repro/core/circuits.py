"""Algorithm 3: routing optical circuits on the MZI mesh (paper §4.2, App. B).

The interposer's optical fabric is modeled as a grid graph whose nodes are
MZI switches and whose edges are waveguide segments.  A circuit request is
``(src_node, dst_node, wavelength)``; a route is valid iff no waveguide on it
already carries a circuit of the same wavelength (one circuit per λ per
waveguide).  Routing is shortest-path with edge penalization: occupied
same-λ edges are made expensive, an invalid candidate path penalizes its
conflicted edges further, and the search retries up to TRIALS times
(Algorithm 3 verbatim, with the Dijkstra inner loop done by
``scipy.sparse.csgraph.dijkstra`` so a 256×256 mesh with 65 K MZIs routes in
well under the paper's 2.5 s budget — Fig. 19a).
"""

from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np
from scipy.sparse import csr_matrix
from scipy.sparse.csgraph import dijkstra

_BLOCK = 1.0e7  # weight that effectively forbids an edge for this search


@dataclass
class MZIMesh:
    """rows × cols grid of MZI nodes; 4-neighbour waveguide edges."""

    rows: int
    cols: int

    def __post_init__(self) -> None:
        n = self.rows * self.cols
        heads: List[int] = []
        tails: List[int] = []
        for r in range(self.rows):
            for c in range(self.cols):
                u = r * self.cols + c
                if c + 1 < self.cols:
                    heads.append(u)
                    tails.append(u + 1)
                if r + 1 < self.rows:
                    heads.append(u)
                    tails.append(u + self.cols)
        # store undirected edges once; expand to symmetric CSR on demand
        self._eu = np.asarray(heads, dtype=np.int64)
        self._ev = np.asarray(tails, dtype=np.int64)
        self.n_nodes = n
        self.n_edges = len(heads)
        self._edge_index: Dict[Tuple[int, int], int] = {}
        for i, (u, v) in enumerate(zip(heads, tails)):
            self._edge_index[(u, v)] = i
            self._edge_index[(v, u)] = i

    def edge_id(self, u: int, v: int) -> int:
        return self._edge_index[(u, v)]

    def graph(self, weights: np.ndarray) -> csr_matrix:
        row = np.concatenate([self._eu, self._ev])
        col = np.concatenate([self._ev, self._eu])
        dat = np.concatenate([weights, weights])
        return csr_matrix((dat, (row, col)), shape=(self.n_nodes, self.n_nodes))


@dataclass
class CircuitRequest:
    src: int
    dst: int
    wavelength: int = 0


@dataclass
class RoutingResult:
    routes: Dict[int, List[int]]             # request index -> node path
    edge_counts: Dict[int, np.ndarray]       # wavelength -> per-edge circuit count
    failed: List[int]
    elapsed_s: float

    @property
    def max_edge_load(self) -> int:
        if not self.edge_counts:
            return 0
        return int(max(int(c.max()) for c in self.edge_counts.values()))


def route_circuits(
    mesh: MZIMesh,
    requests: Sequence[CircuitRequest],
    max_overlap: int = 0,
    trials: int = 6,
    penalize_factor: float = 4.0,
    rip_up: bool = True,
    restarts: int = 2,
) -> RoutingResult:
    """Algorithm 3: Mesh Routing with Edge Reuse Constraint.

    ``rip_up=True`` adds a bounded rip-up-and-reroute fallback beyond the
    paper's greedy loop: when a request cannot find a conflict-free path, the
    circuits blocking its cheapest path are torn out, the request is placed,
    and the victims are re-routed.  This fixes greedy ordering artefacts
    (e.g. an early circuit turning at a mesh corner consumes both corner
    waveguides) without changing the algorithm's validity invariant.

    ``restarts`` retries the whole placement with failed requests promoted to
    the front of the order (negotiated-congestion style): a request that lost
    to earlier greedy choices claims its waveguides first on the next pass.
    Passes with no failures never restart, so routable instances pay nothing.
    """
    t0 = time.perf_counter()
    order = list(range(len(requests)))
    routes, counts, failed = _route_pass(
        mesh, requests, order, max_overlap, trials, penalize_factor, rip_up
    )
    for _ in range(restarts):
        if not failed:
            break
        order = failed + [i for i in order if i not in failed]
        r2, c2, f2 = _route_pass(
            mesh, requests, order, max_overlap, trials, penalize_factor, rip_up
        )
        if len(f2) >= len(failed):
            # passes are deterministic: the same failed-first order would
            # just repeat this result — stop instead of re-running it
            break
        routes, counts, failed = r2, c2, f2
    return RoutingResult(
        routes, counts, sorted(failed), time.perf_counter() - t0
    )


def _route_pass(
    mesh: MZIMesh,
    requests: Sequence[CircuitRequest],
    order: Sequence[int],
    max_overlap: int,
    trials: int,
    penalize_factor: float,
    rip_up: bool,
):
    """One greedy placement pass over ``requests`` in ``order``."""
    base = np.ones(mesh.n_edges)
    counts: Dict[int, np.ndarray] = {}
    penalties: Dict[int, np.ndarray] = {}
    routes: Dict[int, List[int]] = {}
    failed: List[int] = []

    def edges_of(path: List[int]) -> List[int]:
        return [mesh.edge_id(a, b) for a, b in zip(path[:-1], path[1:])]

    def try_route(req: CircuitRequest) -> Optional[List[int]]:
        """The paper's trials loop: penalized Dijkstra until conflict-free."""
        lam = req.wavelength
        cnt = counts.setdefault(lam, np.zeros(mesh.n_edges, dtype=np.int64))
        pen = penalties.setdefault(lam, np.ones(mesh.n_edges))
        for _ in range(trials):
            # ``max_overlap`` same-λ circuits are tolerated per waveguide;
            # default 0 → an occupied waveguide is (soft-)blocked for this λ.
            w = np.where(cnt > max_overlap, _BLOCK, base * pen)
            g = mesh.graph(w)
            dist, pred = dijkstra(
                g, directed=False, indices=req.src, return_predecessors=True
            )
            if not np.isfinite(dist[req.dst]):
                return None
            path = _extract_path(pred, req.src, req.dst)
            conflicted = [e for e in edges_of(path) if cnt[e] > max_overlap]
            if not conflicted and dist[req.dst] < _BLOCK:
                return path
            for e in conflicted or edges_of(path):
                pen[e] *= penalize_factor  # Alg. 3 line 11
        return None

    def commit(ridx: int, req: CircuitRequest, path: List[int]) -> None:
        routes[ridx] = path
        cnt = counts[req.wavelength]
        pen = penalties[req.wavelength]
        for e in edges_of(path):
            cnt[e] += 1
            pen[e] *= 1.05  # mild load-balancing for later searches

    def uncommit(ridx: int, req: CircuitRequest) -> None:
        cnt = counts[req.wavelength]
        for e in edges_of(routes.pop(ridx)):
            cnt[e] -= 1

    for ridx in order:
        req = requests[ridx]
        path = try_route(req)
        if path is not None:
            commit(ridx, req, path)
            continue
        if rip_up:
            path = _rip_up_place(mesh, requests, ridx, req, routes, counts,
                                 max_overlap, try_route, commit, uncommit)
            if path is not None:
                continue
        failed.append(ridx)
    return routes, counts, failed


def _rip_up_place(mesh, requests, ridx, req, routes, counts, max_overlap,
                  try_route, commit, uncommit) -> Optional[List[int]]:
    """Tear out the circuits blocking `req`'s cheapest path, place it, then
    re-route the victims (single level; victims may not rip further)."""
    lam = req.wavelength
    cnt = counts[lam]
    # cheapest path counting conflicts as a (finite) cost
    w = np.where(cnt > max_overlap, 1000.0, 1.0)
    g = mesh.graph(w)
    dist, pred = dijkstra(g, directed=False, indices=req.src, return_predecessors=True)
    if not np.isfinite(dist[req.dst]):
        return None
    path = _extract_path(pred, req.src, req.dst)
    want = {mesh.edge_id(a, b) for a, b in zip(path[:-1], path[1:])}
    victims = []
    for other_idx, other_path in list(routes.items()):
        if requests[other_idx].wavelength != lam:
            continue
        oe = {mesh.edge_id(a, b) for a, b in zip(other_path[:-1], other_path[1:])}
        if oe & want:
            victims.append(other_idx)
    for v in victims:
        uncommit(v, requests[v])
    if any(cnt[e] > max_overlap for e in want):
        # still conflicted (other-λ or shared victims) — restore and give up
        for v in victims:
            p = try_route(requests[v])
            if p is not None:
                commit(v, requests[v], p)
        return None
    commit(ridx, req, path)
    lost = []
    for v in victims:
        p = try_route(requests[v])
        if p is not None:
            commit(v, requests[v], p)
        else:
            lost.append(v)
    if lost:
        # placing `req` stranded a victim — undo everything
        uncommit(ridx, req)
        for v in victims:
            if v in routes:
                uncommit(v, requests[v])
        for v in victims:
            p = try_route(requests[v])
            if p is not None:
                commit(v, requests[v], p)
        return None
    return path


def _extract_path(pred: np.ndarray, src: int, dst: int) -> List[int]:
    path = [dst]
    while path[-1] != src:
        p = int(pred[path[-1]])
        if p < 0:
            raise RuntimeError("broken predecessor chain")
        path.append(p)
    path.reverse()
    return path


def random_requests(
    mesh: MZIMesh, k: int, n_wavelengths: int = 1, seed: int = 0
) -> List[CircuitRequest]:
    """Random (src, dst) pairs on distinct nodes — the Fig. 19a workload."""
    rng = np.random.default_rng(seed)
    reqs = []
    for _ in range(k):
        s, d = rng.choice(mesh.n_nodes, size=2, replace=False)
        reqs.append(CircuitRequest(int(s), int(d), int(rng.integers(n_wavelengths))))
    return reqs


def validate_routes(mesh: MZIMesh, result: RoutingResult,
                    requests: Sequence[CircuitRequest], max_overlap: int = 0) -> None:
    """Check signal-integrity invariant: per λ, per waveguide, ≤ 1+max_overlap
    circuits; and each route actually connects its endpoints."""
    per_lam: Dict[int, np.ndarray] = {}
    for ridx, path in result.routes.items():
        req = requests[ridx]
        assert path[0] == req.src and path[-1] == req.dst, "route endpoints wrong"
        cnt = per_lam.setdefault(req.wavelength, np.zeros(mesh.n_edges, dtype=np.int64))
        for a, b in zip(path[:-1], path[1:]):
            cnt[mesh.edge_id(a, b)] += 1
    for lam, cnt in per_lam.items():
        assert cnt.max() <= 1 + max_overlap, (
            f"wavelength {lam} has {int(cnt.max())} overlapping circuits"
        )
