"""Extended α–β cost model with congestion and dilation (paper §3, Alg. 2).

``comm_cost_round`` is Algorithm 2 verbatim: route every transfer of a round
on the candidate topology via BFS shortest paths, then

* ``dilation``   = max path hops across transfers (latency multiplier on α),
* ``congestion`` = max number of transfers sharing one *directed* edge
  (bandwidth divisor, paper Fig. 6), and

``cost = α · dilation + β · congestion · w``  (Alg. 2 line 15; Eq. 1 summed
over rounds).  A transfer with no path returns the large penalty.

Hardware presets carry the constants used in the paper's evaluation (§5) and
the TPU-v5e adaptation target used by the launch/roofline stack.
"""

from __future__ import annotations

import threading
from collections import OrderedDict
from dataclasses import dataclass, replace
from typing import Dict, FrozenSet, List, Optional, Sequence, Tuple

from .schedules import Round, Schedule
from .topology import Topology, _BIG

LARGE_PENALTY = 1.0e18  # seconds; Alg. 2 line 10


@dataclass(frozen=True)
class HardwareParams:
    """α–β coefficients plus reconfiguration delay (all seconds / bytes).

    Reconfiguration cost model (``reconfig_cost``):

    * ``reconfig_delay_per_link is None`` (default) — the paper's serial
      model: any topology change pays the full fabric delay ``r``.
    * ``reconfig_delay_per_link = r_link`` — partial reconfiguration: a
      change pays ``r_link`` per *changed* directed circuit (set up or torn
      down), capped at ``reconfig_delay``.  Models switches that reprogram
      ports independently rather than the whole fabric at once.
    * ``overlap = True`` — additionally hide round *i*'s reprogramming
      behind round *i−1*'s communication (SWOT-style
      reconfiguration/communication overlap); the planner charges only the
      part of the reconfiguration that outlasts the previous round.
    """

    name: str
    alpha: float            # fixed per-transfer cost (s)
    beta: float             # 1 / link bandwidth (s per byte)
    reconfig_delay: float   # r: full-fabric reprogram time (s)
    tx_per_gpu: int = 1     # optical transmitters per accelerator tile
    rx_per_gpu: int = 1
    # r_link: per-changed-circuit reprogram time (s); None → serial model
    reconfig_delay_per_link: Optional[float] = None
    # hide reconfiguration behind the previous round's communication
    overlap: bool = False

    def with_reconfig(self, r: float) -> "HardwareParams":
        return replace(self, name=f"{self.name}_r{r:g}", reconfig_delay=r)

    def with_link_reconfig(
        self, r_link: float, *, overlap: bool = False
    ) -> "HardwareParams":
        """Partial-reconfiguration variant (optionally overlapped)."""
        tag = f"{self.name}_rl{r_link:g}" + ("_ov" if overlap else "")
        return replace(
            self, name=tag, reconfig_delay_per_link=r_link, overlap=overlap
        )

    def with_overlap(self, overlap: bool = True) -> "HardwareParams":
        return replace(self, name=f"{self.name}_ov", overlap=overlap)

    @property
    def reconfig_mode(self) -> str:
        """``serial`` | ``partial`` | ``overlap`` (how changes are priced)."""
        if self.overlap:
            return "overlap"
        return "serial" if self.reconfig_delay_per_link is None else "partial"


def reconfig_cost(prev_topo: Topology, next_topo: Topology, hw: HardwareParams) -> float:
    """Cost (s) of reprogramming the fabric from ``prev_topo`` to ``next_topo``.

    Serial model: the full ``reconfig_delay`` on any change.  Partial model
    (``reconfig_delay_per_link`` set): ``r_link`` per changed directed
    circuit — circuits present in exactly one of the two edge sets — capped
    at the full-fabric delay.  Identical edge sets always cost 0.

    Overlap (``hw.overlap``) is *not* applied here: it depends on what the
    fabric is doing while reprogramming, so the planner subtracts the
    previous round's communication time at the DP transition.
    """
    if prev_topo.edges == next_topo.edges:
        return 0.0
    if hw.reconfig_delay_per_link is None:
        return hw.reconfig_delay
    changed = len(prev_topo.edges ^ next_topo.edges)
    return min(hw.reconfig_delay, hw.reconfig_delay_per_link * changed)


# §5: α = 3 µs (H100 DGX p2p latency), β = 1/450 GB/s (NVLink), r = 5 µs
# (Passage-class MZI switching).  Appendix A sweeps r ∈ {10, 25, 50, 500} µs;
# Fig. 9 uses 1 ms (MEMS-class).
H100_DGX = HardwareParams("h100_dgx", alpha=3e-6, beta=1.0 / (450e9), reconfig_delay=5e-6)
H100_DGX_R10US = H100_DGX.with_reconfig(10e-6)
H100_DGX_R25US = H100_DGX.with_reconfig(25e-6)
H100_DGX_R50US = H100_DGX.with_reconfig(50e-6)
H100_DGX_R500US = H100_DGX.with_reconfig(500e-6)
H100_DGX_R1MS = H100_DGX.with_reconfig(1e-3)

# TPU v5e adaptation target: 50 GB/s per ICI link, ~1 µs software α,
# OCS-class reconfiguration (ms) and Passage-class (µs) variants.
TPU_V5E_OCS = HardwareParams("tpu_v5e_ocs", alpha=1e-6, beta=1.0 / (50e9), reconfig_delay=2e-3)
TPU_V5E_PHOTONIC = HardwareParams("tpu_v5e_photonic", alpha=1e-6, beta=1.0 / (50e9), reconfig_delay=5e-6)

PRESETS: Dict[str, HardwareParams] = {
    p.name: p
    for p in [
        H100_DGX,
        H100_DGX_R10US,
        H100_DGX_R25US,
        H100_DGX_R50US,
        H100_DGX_R500US,
        H100_DGX_R1MS,
        TPU_V5E_OCS,
        TPU_V5E_PHOTONIC,
    ]
}


@dataclass(frozen=True)
class RoundCost:
    """Per-round cost with the decomposition used by Figs. 8/9."""

    total: float
    dilation: int
    congestion: int
    alpha_base: float        # α (one hop, no dilation)
    beta_base: float         # β·w (full bandwidth, no congestion)
    dilation_extra: float    # (dilation-1)·α
    congestion_extra: float  # (congestion-1)·β·w
    feasible: bool


# Bounded LRU over (n, edges) → (dist, pred).  Sessions may plan from
# multiple threads, so all access is lock-guarded; eviction drops only the
# least-recently-used entry (a blanket clear() used to dump the hot entry
# mid-sweep).
_SP_CACHE: "OrderedDict[Tuple, Tuple]" = OrderedDict()
_SP_CACHE_MAX = 64
_SP_CACHE_LOCK = threading.Lock()


def _scipy_paths(topo: Topology):
    """(dist, pred) all-pairs unweighted shortest paths — C-speed via scipy.
    Cached per topology; the planner prices O(rounds × states) rounds so this
    is the hot path (paper claims <1 s for the largest scale-up domains)."""
    import numpy as np

    key = (topo.n, topo.edges)
    with _SP_CACHE_LOCK:
        hit = _SP_CACHE.get(key)
        if hit is not None:
            _SP_CACHE.move_to_end(key)
            return hit
    from scipy.sparse import csr_matrix
    from scipy.sparse.csgraph import shortest_path as sp

    n = topo.n
    if topo.edges:
        rows, cols = zip(*topo.edges)
    else:
        rows, cols = (), ()
    g = csr_matrix(
        (np.ones(len(rows)), (np.asarray(rows), np.asarray(cols))), shape=(n, n)
    )
    dist, pred = sp(g, method="D", directed=True, unweighted=True,
                    return_predecessors=True)
    with _SP_CACHE_LOCK:
        _SP_CACHE[key] = (dist, pred)
        _SP_CACHE.move_to_end(key)
        while len(_SP_CACHE) > _SP_CACHE_MAX:
            _SP_CACHE.popitem(last=False)
    return dist, pred


PairKey = FrozenSet[Tuple[Tuple[int, int], int]]


# Bounded LRU over (n, edges) → component labels for *linear* graphs (every
# node: out-degree ≤ 1 and in-degree ≤ 1, i.e. unions of simple paths and
# cycles — exactly the ideal graphs of permutation rounds, the planner's
# dominant candidate states).  None is cached too: "not linear" is as
# expensive to rediscover as the labels are to build.
_LINEAR_CACHE: "OrderedDict[Tuple, Optional[Tuple]]" = OrderedDict()
_LINEAR_CACHE_MAX = 512
_LINEAR_CACHE_LOCK = threading.Lock()


def _linear_labels(topo: Topology):
    """(comp, pos, off, length, cyclic, n_slots) labels for a linear graph,
    or None if ``topo`` is not linear.

    ``comp[v]``/``pos[v]`` place each node on its path/cycle; ``off[c]``
    gives component ``c`` a private block of ``length[c] + 1`` edge slots
    (slot ``p`` = the edge out of position ``p``), so all components share
    one flat difference array when counting edge loads."""
    import numpy as np

    key = (topo.n, topo.edges)
    with _LINEAR_CACHE_LOCK:
        if key in _LINEAR_CACHE:
            _LINEAR_CACHE.move_to_end(key)
            return _LINEAR_CACHE[key]

    n = topo.n
    succ = [-1] * n
    pred = [-1] * n
    linear = True
    for u, v in topo.edges:
        if succ[u] != -1 or pred[v] != -1:
            linear = False
            break
        succ[u] = v
        pred[v] = u

    labels = None
    if linear:
        comp = [-1] * n
        pos = [0] * n
        length: List[int] = []
        cyclic: List[bool] = []
        for s in range(n):  # paths (and isolated nodes) start where pred is unset
            if pred[s] == -1:
                u, p, c = s, 0, len(length)
                while u != -1:
                    comp[u] = c
                    pos[u] = p
                    p += 1
                    u = succ[u]
                length.append(p)
                cyclic.append(False)
        for s in range(n):  # everything left lies on a cycle
            if comp[s] == -1:
                u, p, c = s, 0, len(length)
                while comp[u] == -1:
                    comp[u] = c
                    pos[u] = p
                    p += 1
                    u = succ[u]
                length.append(p)
                cyclic.append(True)
        length_a = np.asarray(length, dtype=np.int64)
        off = np.zeros(len(length) + 1, dtype=np.int64)
        np.cumsum(length_a + 1, out=off[1:])
        labels = (
            np.asarray(comp, dtype=np.int64),
            np.asarray(pos, dtype=np.int64),
            off[:-1],
            length_a,
            np.asarray(cyclic, dtype=bool),
            int(off[-1]),
        )

    with _LINEAR_CACHE_LOCK:
        _LINEAR_CACHE[key] = labels
        _LINEAR_CACHE.move_to_end(key)
        while len(_LINEAR_CACHE) > _LINEAR_CACHE_MAX:
            _LINEAR_CACHE.popitem(last=False)
    return labels


def _route_pairs_linear(labels, srcs, dsts) -> Tuple[int, int, bool]:
    """Route on a linear graph: unique paths ⇒ exact dilation/congestion.

    Distance is position arithmetic per component; per-edge load is an
    interval count (difference array over each component's edge slots,
    cycles split at the wrap point).  ``srcs``/``dsts`` are index arrays."""
    import numpy as np

    comp, pos, off, length, cyclic, n_slots = labels
    cu = comp[srcs]
    if (cu != comp[dsts]).any():
        return (_BIG, _BIG, False)
    L = length[cu]
    cyc = cyclic[cu]
    pu = pos[srcs]
    pv = pos[dsts]
    d = pv - pu
    if (~cyc & (d < 0)).any():  # backwards along a path: unreachable
        return (_BIG, _BIG, False)
    d = np.where(cyc, d % L, d)  # src != dst on one comp ⇒ d ≥ 1
    dilation = int(d.max())

    base = off[cu]
    wrap = cyc & (pv < pu)
    plus1 = base + pu
    minus1 = np.where(wrap, base + L, base + pv)
    plus2 = base[wrap]
    minus2 = (base + pv)[wrap]
    idx = np.concatenate([plus1, plus2, minus1, minus2])
    sgn = np.ones(idx.shape[0])
    sgn[plus1.shape[0] + plus2.shape[0]:] = -1.0
    diff = np.bincount(idx, weights=sgn, minlength=n_slots + 1)
    return (dilation, int(diff.cumsum().max()), True)


class _StackedLinear:
    """Label arrays of many linear topologies stacked for batch routing.

    Component ids and edge slots are globalized (state ``s`` owns slot block
    ``[bounds[s], bounds[s+1])``), so one set of vectorized ops routes a
    round against *every* linear candidate state simultaneously — the
    planner's structure phase is O(distinct round structures) batched calls
    instead of O(structures × states) scalar ones."""

    def __init__(self, labels_list: Sequence[Tuple]) -> None:
        import numpy as np

        comp_rows, pos_rows, lens, cycs, offs = [], [], [], [], []
        comp_base = 0
        slot_base = 0
        bounds = [0]
        for comp, pos, off, length, cyclic, n_slots in labels_list:
            comp_rows.append(comp + comp_base)
            pos_rows.append(pos)
            offs.append(off + slot_base)
            lens.append(length)
            cycs.append(cyclic)
            comp_base += length.shape[0]
            slot_base += n_slots
            bounds.append(slot_base)
        self.comp = np.stack(comp_rows)          # (S, n) global comp ids
        self.pos = np.stack(pos_rows)            # (S, n)
        self.glen = np.concatenate(lens)         # (C,)
        self.gcyc = np.concatenate(cycs)         # (C,)
        self.goff = np.concatenate(offs)         # (C,) global slot offsets
        self.bounds = np.asarray(bounds)         # (S+1,)
        self.n_slots = slot_base


def _route_linear_batch(stacked: "_StackedLinear", srcs, dsts):
    """(dilation, congestion, feasible) arrays over all stacked states.

    Identical arithmetic to :func:`_route_pairs_linear` per state; the diff
    arrays of all states share one flat buffer (each state's block sums to
    zero, so a single cumsum segments cleanly at block boundaries)."""
    import numpy as np

    cu = stacked.comp[:, srcs]                   # (S, P)
    cv = stacked.comp[:, dsts]
    L = stacked.glen[cu]
    cyc = stacked.gcyc[cu]
    pu = stacked.pos[:, srcs]
    pv = stacked.pos[:, dsts]
    d = pv - pu
    ok = (cu == cv) & (cyc | (d > 0))
    feas = ok.all(axis=1)                        # (S,)
    d = np.where(cyc, d % L, d)  # feasible rows: every entry ≥ 1
    dil = np.where(feas, d.max(axis=1), _BIG)

    cong = np.full(feas.shape[0], _BIG, dtype=np.int64)
    fidx = np.nonzero(feas)[0]
    if fidx.shape[0]:
        base = stacked.goff[cu[fidx]]            # (F, P)
        pu_f, pv_f, L_f = pu[fidx], pv[fidx], L[fidx]
        wrap = stacked.gcyc[cu[fidx]] & (pv_f < pu_f)
        plus1 = (base + pu_f).ravel()
        minus1 = np.where(wrap, base + L_f, base + pv_f).ravel()
        plus2 = base[wrap]                       # bool-indexing flattens
        minus2 = (base + pv_f)[wrap]
        idx = np.concatenate([plus1, plus2, minus1, minus2])
        sgn = np.ones(idx.shape[0])
        sgn[plus1.shape[0] + plus2.shape[0]:] = -1.0
        run = np.bincount(idx, weights=sgn, minlength=stacked.n_slots + 1).cumsum()
        # each feasible block's running load; interleaved infeasible blocks
        # contributed nothing so their slots sit at exactly 0
        seg = np.maximum.reduceat(run, stacked.bounds[fidx])
        cong[fidx] = seg.astype(np.int64)
    return dil, cong, feas


# Bounded LRU over (n, edges) → path-position labels for *bidirectional path
# forests* (every directed edge has its reverse, undirected degree ≤ 2, no
# cycles) — exactly the shape of a ring fabric that lost a link, the dominant
# state on the warm-replan path.  Cycles are excluded: antipodal pairs on an
# even bidirectional cycle have tied shortest paths, so routes would not be
# provably identical to the predecessor-walk leg.  None is cached too.
_BIDI_CACHE: "OrderedDict[Tuple, Optional[Tuple]]" = OrderedDict()
_BIDI_CACHE_MAX = 512
_BIDI_CACHE_LOCK = threading.Lock()


def _bidi_path_labels(topo: Topology):
    """(comp, pos, off, n_slots) labels for a bidirectional path forest, or
    None if ``topo`` is not one.

    ``comp[v]``/``pos[v]`` place each node on its undirected path; component
    ``c`` owns slot block ``[off[c], off[c] + length_c)`` where slot ``p``
    stands for the segment between positions ``p`` and ``p + 1`` (one slot
    per direction plane, see :func:`_route_rounds_bidi`).  On such graphs
    every pair has a *unique* simple path, so shortest-path routing is
    forced and results are bit-identical to the general predecessor walk."""
    import numpy as np

    key = (topo.n, topo.edges)
    with _BIDI_CACHE_LOCK:
        if key in _BIDI_CACHE:
            _BIDI_CACHE.move_to_end(key)
            return _BIDI_CACHE[key]

    n = topo.n
    adj: List[List[int]] = [[] for _ in range(n)]
    ok = True
    for u, v in topo.edges:
        if (v, u) not in topo.edges:
            ok = False
            break
        adj[u].append(v)
    if ok:
        ok = all(len(a) <= 2 for a in adj)
    labels = None
    if ok:
        comp = [-1] * n
        pos = [0] * n
        length: List[int] = []
        for s in range(n):  # paths start at endpoints (degree 0 or 1)
            if comp[s] != -1 or len(adj[s]) == 2:
                continue
            c = len(length)
            u, prev, p = s, -1, 0
            while u != -1:
                comp[u] = c
                pos[u] = p
                p += 1
                nxt = -1
                for w in adj[u]:
                    if w != prev:
                        nxt = w
                        break
                prev, u = u, nxt
            length.append(p)
        if all(c != -1 for c in comp):  # unvisited nodes would lie on cycles
            length_a = np.asarray(length, dtype=np.int64)
            off = np.zeros(len(length) + 1, dtype=np.int64)
            np.cumsum(length_a, out=off[1:])
            labels = (
                np.asarray(comp, dtype=np.int64),
                np.asarray(pos, dtype=np.int64),
                off[:-1],
                int(off[-1]),
            )

    with _BIDI_CACHE_LOCK:
        _BIDI_CACHE[key] = labels
        _BIDI_CACHE.move_to_end(key)
        while len(_BIDI_CACHE) > _BIDI_CACHE_MAX:
            _BIDI_CACHE.popitem(last=False)
    return labels


def _route_rounds_bidi(
    labels, pair_arrays_list: Sequence[Tuple]
) -> List[Tuple[int, int, bool]]:
    """Batch-route many rounds on ONE bidirectional path forest.

    Same contract as :func:`_route_rounds_general` (and bit-identical to it:
    unique simple paths force the same routes) without any shortest-path
    machinery — dilation is position arithmetic, congestion is two interval
    difference planes (one per travel direction, since the two directed
    circuits of a segment are distinct links) cumsum'd per round."""
    import numpy as np

    comp, pos, off, n_slots = labels
    R = len(pair_arrays_list)
    counts = np.asarray([s.shape[0] for s, _ in pair_arrays_list])
    srcs = np.concatenate([s for s, _ in pair_arrays_list])
    dsts = np.concatenate([d for _, d in pair_arrays_list])
    seg = np.repeat(np.arange(R), counts)

    cu = comp[srcs]
    same = cu == comp[dsts]
    feas = np.bincount(seg[~same], minlength=R) == 0
    pu = pos[srcs]
    pv = pos[dsts]
    d = np.abs(pv - pu)
    dil = np.zeros(R, dtype=np.int64)
    keep = feas[seg]
    np.maximum.at(dil, seg[keep], d[keep])

    base = off[cu]
    lo = base + np.minimum(pu, pv)
    hi = base + np.maximum(pu, pv)
    fwd = keep & (pu < pv)          # ascending positions: forward plane
    bwd = keep & (pu > pv)          # descending: backward plane
    m = R * n_slots
    rowbase = seg * n_slots
    plus = np.concatenate([(rowbase + lo)[fwd], m + (rowbase + lo)[bwd]])
    minus = np.concatenate([(rowbase + hi)[fwd], m + (rowbase + hi)[bwd]])
    diff = np.bincount(plus, minlength=2 * m) - np.bincount(
        minus, minlength=2 * m
    )
    # rows: fwd plane rounds 0..R-1, then bwd plane; each component block's
    # entries cancel before the block ends, so one row cumsum segments cleanly
    run = diff.reshape(2 * R, n_slots).cumsum(axis=1)
    mx = run.max(axis=1)
    cong = np.maximum(mx[:R], mx[R:])

    out: List[Tuple[int, int, bool]] = []
    for k in range(R):
        if feas[k]:
            out.append((int(dil[k]), int(cong[k]), True))
        else:
            out.append((_BIG, _BIG, False))
    return out


def _route_rounds_general(
    topo: Topology, pair_arrays_list: Sequence[Tuple]
) -> List[Tuple[int, int, bool]]:
    """Batch the general shortest-path leg of :func:`_route_pairs` over many
    rounds on ONE topology: a single predecessor-matrix walk prices every
    round simultaneously instead of one walk per round.

    ``pair_arrays_list[k]`` is round ``k``'s prebuilt ``(srcs, dsts)`` index
    arrays (non-empty, self-pairs already dropped).  Returns one
    ``(dilation, congestion, feasible)`` triple per round, bit-identical to
    calling ``_route_pairs(topo, pairs, allow_fast=False)`` per round: the
    same ``dist``/``pred`` matrices drive the same deterministic routes, the
    per-round edge-load multisets are segment-tagged rather than recomputed.
    The warm-replan path leans on this — a degraded standard topology must
    re-price every distinct round of the schedule, and per-round scalar
    walks were the dominant cost of ``planner.replan``."""
    import numpy as np

    R = len(pair_arrays_list)
    dist, pred = _scipy_paths(topo)
    n = topo.n
    counts = np.asarray([s.shape[0] for s, _ in pair_arrays_list])
    srcs = np.concatenate([s for s, _ in pair_arrays_list])
    dsts = np.concatenate([d for _, d in pair_arrays_list])
    seg = np.repeat(np.arange(R), counts)

    d = dist[srcs, dsts]
    finite = np.isfinite(d)
    feas = np.bincount(seg[~finite], minlength=R) == 0
    dil = np.zeros(R)
    np.maximum.at(dil, seg[finite], d[finite])

    # walk only the pairs of fully feasible rounds (infinite-distance pairs
    # would never terminate; their rounds are already (_BIG, _BIG, False))
    keep = feas[seg]
    ws, wseg = srcs[keep], seg[keep]
    cur = dsts[keep].copy()
    codes: List = []
    active = cur != ws
    nn = n * n
    while active.any():
        prev = pred[ws[active], cur[active]]
        codes.append(
            wseg[active] * nn + prev.astype(np.int64) * n + cur[active]
        )
        nxt = cur.copy()
        nxt[active] = prev
        cur = nxt
        active = cur != ws
    if codes:
        all_codes = np.concatenate(codes)
        if R * nn <= (1 << 23):
            # dense per-round edge-load counting: one bincount + row max
            # beats the O(E log E) sort of np.unique at modest R·n²
            loads = np.bincount(all_codes, minlength=R * nn)
            cong_a = loads.reshape(R, nn).max(axis=1)
            return [
                (int(dil[k]), int(cong_a[k]), True) if feas[k]
                else (_BIG, _BIG, False)
                for k in range(R)
            ]
        uniq, cnts = np.unique(all_codes, return_counts=True)
        useg = uniq // nn  # ascending (uniq is sorted)
        bounds = np.searchsorted(useg, np.arange(R + 1))
    else:  # every round infeasible (or all pairs self-pairs, excluded above)
        cnts = np.zeros(0, dtype=np.int64)
        bounds = np.zeros(R + 1, dtype=np.int64)

    out: List[Tuple[int, int, bool]] = []
    for k in range(R):
        if not feas[k]:
            out.append((_BIG, _BIG, False))
            continue
        block = cnts[bounds[k]:bounds[k + 1]]
        cong = int(block.max()) if block.shape[0] else 0
        out.append((int(dil[k]), cong, True))
    return out


def pairs_of(rnd: Round) -> List[Tuple[int, int]]:
    """The (src, dst) pairs of a round that actually move data.

    Memoized on the round itself (schedules are memoized too, so planners
    keep re-pricing the same ``Round`` objects); callers must not mutate
    the returned list."""
    cached = rnd.__dict__.get("_pairs")
    if cached is None:
        cached = [(t.src, t.dst) for t in rnd.transfers if t.src != t.dst]
        object.__setattr__(rnd, "_pairs", cached)
    return cached


# Bounded LRU over (n, edges, pair-multiset) → per-directed-edge loads.
# The concurrent-group arbiter (planner.plan_concurrent) prices cross-group
# contention per *link*, which needs the full load vector rather than the
# max that STRUCTURE_TABLE keeps.
_EDGE_LOAD_CACHE: "OrderedDict[Tuple, Optional[Tuple]]" = OrderedDict()
_EDGE_LOAD_CACHE_MAX = 65536
_EDGE_LOAD_CACHE_LOCK = threading.Lock()


def edge_loads(
    topo: Topology,
    pairs: Sequence[Tuple[int, int]],
    key: Optional[PairKey] = None,
) -> Optional[Tuple[int, Tuple[Tuple[Tuple[int, int], int], ...]]]:
    """``(dilation, ((edge, count), ...))`` for routing ``pairs`` on ``topo``,
    or ``None`` when some pair has no path.

    Routes follow the same deterministic shortest paths as
    :func:`_route_pairs`' general path (the ``_scipy_paths`` predecessor
    walk).  Every fast path in ``_route_pairs`` routes along *unique*
    shortest paths (linear graphs, direct circuits, functional graphs), so
    ``max(count)`` here always equals the congestion factor
    ``STRUCTURE_TABLE`` reports and ``dilation`` matches exactly — the
    concurrent arbiter's per-link pricing degenerates to Alg. 2's
    ``(D, C)`` whenever a group has the fabric to itself.
    """
    import numpy as np

    if not pairs:
        return (0, ())
    if key is None:
        key = round_structure_key(pairs)
    full_key = (topo.n, topo.edges, key)
    with _EDGE_LOAD_CACHE_LOCK:
        if full_key in _EDGE_LOAD_CACHE:
            _EDGE_LOAD_CACHE.move_to_end(full_key)
            return _EDGE_LOAD_CACHE[full_key]

    srcs = np.asarray([p[0] for p in pairs])
    dsts = np.asarray([p[1] for p in pairs])
    dist, pred = _scipy_paths(topo)
    d = dist[srcs, dsts]
    result: Optional[Tuple] = None
    if np.all(np.isfinite(d)):
        dilation = int(d.max())
        cur = dsts.copy()
        codes: List = []
        active = cur != srcs
        while active.any():
            prev = pred[srcs[active], cur[active]]
            codes.append(prev.astype(np.int64) * topo.n + cur[active])
            nxt = cur.copy()
            nxt[active] = prev
            cur = nxt
            active = cur != srcs
        uniq, counts = np.unique(np.concatenate(codes), return_counts=True)
        loads = tuple(
            ((int(c) // topo.n, int(c) % topo.n), int(k))
            for c, k in zip(uniq.tolist(), counts.tolist())
        )
        result = (dilation, loads)

    with _EDGE_LOAD_CACHE_LOCK:
        _EDGE_LOAD_CACHE[full_key] = result
        _EDGE_LOAD_CACHE.move_to_end(full_key)
        while len(_EDGE_LOAD_CACHE) > _EDGE_LOAD_CACHE_MAX:
            _EDGE_LOAD_CACHE.popitem(last=False)
    return result


def round_structure_key(pairs: Sequence[Tuple[int, int]]) -> PairKey:
    """Canonical pair-*multiset* key of a round's structure.

    Dilation/congestion (Alg. 2) depend only on which (src, dst) pairs a
    round routes and how many copies of each — not on the payload size and
    not on transfer order.  Rounds sharing this key share routing factors on
    every topology."""
    from collections import Counter

    return frozenset(Counter(pairs).items())


def _route_pairs(
    topo: Topology,
    pairs: Sequence[Tuple[int, int]],
    *,
    allow_fast: bool = True,
    pair_arrays=None,
) -> Tuple[int, int, bool]:
    """Algorithm 2 lines 1–14 on explicit pairs: (dilation, congestion,
    feasible).  ``allow_fast=False`` forces the scipy general path (used by
    the property tests to cross-check the fast paths).  ``pair_arrays`` is
    an optional prebuilt ``(srcs, dsts)`` index-array pair — callers pricing
    one round against many topologies build it once.

    General path is vectorized: all transfers' shortest paths are walked
    simultaneously via the predecessor matrix (one numpy step per hop
    depth)."""
    import numpy as np

    if not pairs:
        return (0, 0, True)
    if pair_arrays is None:
        pair_arrays = (
            np.asarray([p[0] for p in pairs]),
            np.asarray([p[1] for p in pairs]),
        )
    srcs, dsts = pair_arrays

    # Fast path 1: linear graphs (out-degree ≤ 1 AND in-degree ≤ 1 — unions
    # of simple paths/cycles, i.e. permutation rounds' ideal graphs, the
    # planner's dominant candidate states): paths are unique and
    # distances/edge loads fall out of cached component position labels,
    # vectorized over all transfers at once.  Subsumes the
    # round-on-its-own-ideal-graph query (every pair a direct circuit).
    if allow_fast:
        labels = _linear_labels(topo)
        if labels is not None:
            return _route_pairs_linear(labels, srcs, dsts)

    # Fast path 2: every transfer is a direct circuit on a non-linear
    # topology.  Any length-1 shortest path is necessarily the direct edge,
    # so this agrees with the general path exactly.
    if allow_fast and all(p in topo.edges for p in pairs):
        from collections import Counter

        cong = max(Counter(pairs).values())
        return (1, cong, True)

    # Fast path 3: other functional graphs (out-degree ≤ 1 but some node
    # receives twice): the only path from u is the unique outgoing chain.
    out: Dict[int, int] = {}
    functional = allow_fast
    for u, v in topo.edges:
        if not functional:
            break
        if u in out:
            functional = False
            break
        out[u] = v
    if functional:
        edge_usage: Dict[Tuple[int, int], int] = {}
        dil = 0
        for s, d in pairs:
            cur, hops = s, 0
            while cur != d:
                nxt = out.get(cur)
                if nxt is None or hops > topo.n:
                    return (_BIG, _BIG, False)
                edge_usage[(cur, nxt)] = edge_usage.get((cur, nxt), 0) + 1
                cur = nxt
                hops += 1
            dil = max(dil, hops)
        return (dil, max(edge_usage.values(), default=0), True)

    dist, pred = _scipy_paths(topo)
    d = dist[srcs, dsts]
    if not np.all(np.isfinite(d)):
        return (_BIG, _BIG, False)
    dilation = int(d.max())

    # walk every path back from dst to src in lockstep
    cur = dsts.copy()
    codes: List = []
    active = cur != srcs
    while active.any():
        prev = pred[srcs[active], cur[active]]
        codes.append(prev.astype(np.int64) * topo.n + cur[active])
        nxt = cur.copy()
        nxt[active] = prev
        cur = nxt
        active = cur != srcs
    all_codes = np.concatenate(codes)
    _, counts = np.unique(all_codes, return_counts=True)
    return (dilation, int(counts.max()), True)


@dataclass(frozen=True)
class StructureStats:
    """Hit/miss accounting for :class:`StructureTable`.  ``misses`` is the
    number of actual routing computations (the quantity the planner
    benchmarks report as *routing calls*); ``bytes`` is the table's current
    estimated key+value footprint (what size-aware eviction charges
    against)."""

    hits: int
    misses: int
    size: int
    evictions: int = 0
    bytes: int = 0

    @property
    def routing_calls(self) -> int:
        return self.misses


class StructureTable:
    """Cache of size-independent routing factors (the planner's *structure*
    phase).

    Keyed by ``(topology edge-set, round pair-multiset)``: dilation and
    congestion are integers that depend only on the candidate topology and
    which pairs a round routes, never on α/β/w.  A buffer-size sweep
    therefore prices every size from one routing pass, and ring/bucket
    schedules — whose rounds share a single pair set — collapse to one
    routing query per candidate topology.

    Lock-guarded bounded LRU (same discipline as ``_SP_CACHE``): sessions
    may plan from multiple threads, and eviction drops only the
    least-recently-used entry.  Eviction is *size-aware*: each entry is
    charged by the estimated bytes of its key (the dominant cost — a key
    holds a topology edge-set plus a round pair-multiset, both O(n) tuples
    of tuples), so an n=1024 structure phase whose keys are ~100 KiB each
    cannot pin gigabytes behind an entry-count limit sized for n=16.
    """

    # key footprint ≈ per-element tuple/int overhead × (edges + pairs) + slack
    _CHARGE_PER_ELEM = 120
    _CHARGE_BASE = 512

    def __init__(
        self, max_entries: int = 65536, max_bytes: int = 128 * 1024 * 1024
    ) -> None:
        if max_entries < 1:
            raise ValueError(f"max_entries must be >= 1, got {max_entries}")
        if max_bytes < 1:
            raise ValueError(f"max_bytes must be >= 1, got {max_bytes}")
        self._table: "OrderedDict[Tuple, Tuple[int, int, bool]]" = OrderedDict()
        self.max_entries = max_entries
        self.max_bytes = max_bytes
        self._lock = threading.Lock()
        self._hits = 0
        self._misses = 0
        self._evictions = 0
        self._bytes = 0

    @classmethod
    def _charge(cls, full_key: Tuple) -> int:
        _, edges, pair_key = full_key
        return cls._CHARGE_PER_ELEM * (len(edges) + len(pair_key)) + cls._CHARGE_BASE

    def lookup(
        self, topo: Topology, key: PairKey
    ) -> Optional[Tuple[int, int, bool]]:
        """Cached factors or None; counts a hit or a miss (a miss means the
        caller is about to route — ``misses`` tallies routing computations)."""
        full_key = (topo.n, topo.edges, key)
        with self._lock:
            hit = self._table.get(full_key)
            if hit is not None:
                self._hits += 1
                self._table.move_to_end(full_key)
            else:
                self._misses += 1
            return hit

    def store(
        self, topo: Topology, key: PairKey, factors: Tuple[int, int, bool]
    ) -> None:
        full_key = (topo.n, topo.edges, key)
        with self._lock:
            if full_key not in self._table:
                self._bytes += self._charge(full_key)
            self._table[full_key] = factors
            self._table.move_to_end(full_key)
            while len(self._table) > 1 and (
                len(self._table) > self.max_entries or self._bytes > self.max_bytes
            ):
                victim, _ = self._table.popitem(last=False)
                self._bytes -= self._charge(victim)
                self._evictions += 1

    def store_many(
        self,
        topo: Topology,
        items: Sequence[Tuple[PairKey, Tuple[int, int, bool]]],
    ) -> None:
        """Bulk :meth:`store` for one topology under a single lock round —
        batch routers deposit a whole schedule's worth of rounds at once."""
        n, edges = topo.n, topo.edges
        with self._lock:
            for key, factors in items:
                full_key = (n, edges, key)
                if full_key not in self._table:
                    self._bytes += self._charge(full_key)
                self._table[full_key] = factors
                self._table.move_to_end(full_key)
            while len(self._table) > 1 and (
                len(self._table) > self.max_entries or self._bytes > self.max_bytes
            ):
                victim, _ = self._table.popitem(last=False)
                self._bytes -= self._charge(victim)
                self._evictions += 1

    def factors(
        self,
        topo: Topology,
        pairs: Sequence[Tuple[int, int]],
        key: Optional[PairKey] = None,
        pair_arrays=None,
    ) -> Tuple[int, int, bool]:
        """(dilation, congestion, feasible) for routing ``pairs`` on
        ``topo``, computing at most once per (edge-set, pair-multiset).
        ``key``/``pair_arrays`` let bulk callers amortize key and index
        construction across topologies."""
        if not pairs:
            return (0, 0, True)
        if key is None:
            key = round_structure_key(pairs)
        hit = self.lookup(topo, key)
        if hit is not None:
            return hit
        factors = _route_pairs(topo, pairs, pair_arrays=pair_arrays)
        self.store(topo, key, factors)
        return factors

    def clear(self) -> None:
        with self._lock:
            self._table.clear()
            self._hits = 0
            self._misses = 0
            self._evictions = 0
            self._bytes = 0

    @property
    def stats(self) -> StructureStats:
        with self._lock:
            return StructureStats(
                self._hits, self._misses, len(self._table), self._evictions,
                self._bytes,
            )


#: Process-wide structure table; all ``round_factors`` queries go through it.
STRUCTURE_TABLE = StructureTable()


def round_factors(topo: Topology, rnd: Round) -> Tuple[int, int, bool]:
    """Algorithm 2 lines 1–14: (dilation, congestion, feasible), cached by
    ``(topology edge-set, round pair-multiset)`` in :data:`STRUCTURE_TABLE`."""
    return STRUCTURE_TABLE.factors(topo, pairs_of(rnd))


def clear_structure_caches(keep_shortest_paths: bool = False) -> None:
    """Drop the routing caches in this module.  Benchmarks call this to
    time cold planning; ``keep_shortest_paths=True`` retains ``_SP_CACHE``
    (which predates the structure table and persists across ``plan()``
    calls), for baselines that model the pre-split planner faithfully."""
    STRUCTURE_TABLE.clear()
    if not keep_shortest_paths:
        with _SP_CACHE_LOCK:
            _SP_CACHE.clear()
    with _LINEAR_CACHE_LOCK:
        _LINEAR_CACHE.clear()
    with _BIDI_CACHE_LOCK:
        _BIDI_CACHE.clear()
    with _EDGE_LOAD_CACHE_LOCK:
        _EDGE_LOAD_CACHE.clear()


def round_cost_from_factors(
    dilation: int, congestion: int, feasible: bool, size: float, hw: HardwareParams
) -> RoundCost:
    """Price routing factors at one size: α·dilation + β·congestion·w.

    The single source of the Alg. 2 arithmetic — :func:`comm_cost_round` and
    the planner's batched numeric phase both use it, so per-size plans and
    ``plan_sweep`` agree bit-for-bit."""
    if not feasible:
        return RoundCost(LARGE_PENALTY, dilation, congestion, 0, 0, 0, 0, False)
    if dilation == 0:  # empty round
        return RoundCost(0.0, 0, 0, 0.0, 0.0, 0.0, 0.0, True)
    alpha_base = hw.alpha
    beta_base = hw.beta * size
    dil_extra = (dilation - 1) * hw.alpha
    con_extra = (congestion - 1) * hw.beta * size
    total = hw.alpha * dilation + hw.beta * congestion * size
    return RoundCost(total, dilation, congestion, alpha_base, beta_base, dil_extra, con_extra, True)


def comm_cost_round(
    topo: Topology, rnd: Round, w: Optional[float], hw: HardwareParams
) -> RoundCost:
    """Algorithm 2: α·dilation + β·congestion·w, or the large penalty."""
    size = rnd.size if w is None else w
    dilation, congestion, feasible = round_factors(topo, rnd)
    return round_cost_from_factors(dilation, congestion, feasible, size, hw)


@dataclass(frozen=True)
class ScheduleCost:
    """Fixed-topology cost of a whole schedule (baseline algorithms, Eq. 1)."""

    total: float
    rounds: Tuple[RoundCost, ...]

    @property
    def alpha_base(self) -> float:
        return sum(r.alpha_base for r in self.rounds)

    @property
    def beta_base(self) -> float:
        return sum(r.beta_base for r in self.rounds)

    @property
    def dilation_extra(self) -> float:
        return sum(r.dilation_extra for r in self.rounds)

    @property
    def congestion_extra(self) -> float:
        return sum(r.congestion_extra for r in self.rounds)

    def breakdown(self) -> Dict[str, float]:
        return {
            "alpha": self.alpha_base,
            "beta": self.beta_base,
            "dilation": self.dilation_extra,
            "congestion": self.congestion_extra,
            "reconfig": 0.0,
            "total": self.total,
        }


def schedule_cost_fixed(topo: Topology, schedule: Schedule, hw: HardwareParams) -> ScheduleCost:
    """Eq. 1: Σ_i (α·d_i + β·c_i·w_i) on a topology that never changes."""
    per = tuple(comm_cost_round(topo, rnd, None, hw) for rnd in schedule.rounds)
    return ScheduleCost(sum(r.total for r in per), per)


def ideal_cost(schedule: Schedule, hw: HardwareParams) -> float:
    """Textbook α–β cost: every round on its perfectly matched topology."""
    return sum(hw.alpha + hw.beta * r.size for r in schedule.rounds if r.transfers)


def lower_bound_reduce_scatter(n: int, d: float, hw: HardwareParams) -> float:
    """β lower bound (each rank must move (n-1)/n·d) + α lower bound (log2 n)."""
    import math

    return hw.alpha * math.ceil(math.log2(n)) + hw.beta * d * (n - 1) / n


def compressed_ef_error_bound(n: int) -> float:
    """Documented accuracy bound of the ``ring_ef8`` all-reduce wire format.

    ``ring_ef8`` runs the ring all-reduce with every hop's payload
    quantized to int8 + one fp32 scale (``scale = max|payload| / 127``, see
    ``repro.comm.fusion.execute_compiled_quantized``), which is what lets
    the schedule price each round at ``size / 4``.  The quantize→dequantize
    round trip errs at most ``scale / 2`` per element per hop, every
    payload (partial sums while reduce-scattering, final sums while
    gathering) is bounded in magnitude by ``n · A`` where
    ``A = max_i ||x_i||_inf``, and one output element transits at most
    ``2(n-1)`` quantizing hops — so to first order

        ``|out - exact| <= 2(n-1) · (n·A)/254  =  bound(n) · n · A``

    elementwise, with ``bound(n) = (n-1)/127``.  This is the *relative*
    bound (w.r.t. the exact result's maximum representable magnitude
    ``n·A``) that arbitration gates on: ``ring_ef8`` only enters the
    candidate set when the caller declares ``rel_error_tol >= bound(n)``
    (see :func:`repro.core.pccl.candidate_algorithms`).  First-order:
    quantization error feeding later hops' payload maxima is second-order
    small and deliberately ignored; callers needing exactness simply leave
    ``rel_error_tol`` unset.
    """
    if n < 2:
        raise ValueError(f"collective needs n >= 2 ranks, got {n}")
    return (n - 1) / 127.0
