"""Extended α–β cost model with congestion and dilation (paper §3, Alg. 2).

``comm_cost_round`` is Algorithm 2 verbatim: route every transfer of a round
on the candidate topology via BFS shortest paths, then

* ``dilation``   = max path hops across transfers (latency multiplier on α),
* ``congestion`` = max number of transfers sharing one *directed* edge
  (bandwidth divisor, paper Fig. 6), and

``cost = α · dilation + β · congestion · w``  (Alg. 2 line 15; Eq. 1 summed
over rounds).  A transfer with no path returns the large penalty.

Hardware presets carry the constants used in the paper's evaluation (§5) and
the TPU-v5e adaptation target used by the launch/roofline stack.
"""

from __future__ import annotations

import threading
from collections import OrderedDict
from dataclasses import dataclass, replace
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

from .schedules import Round, Schedule
from .topology import Topology, _BIG

LARGE_PENALTY = 1.0e18  # seconds; Alg. 2 line 10


@dataclass(frozen=True)
class HardwareParams:
    """α–β coefficients plus reconfiguration delay (all seconds / bytes).

    Reconfiguration cost model (``reconfig_cost``):

    * ``reconfig_delay_per_link is None`` (default) — the paper's serial
      model: any topology change pays the full fabric delay ``r``.
    * ``reconfig_delay_per_link = r_link`` — partial reconfiguration: a
      change pays ``r_link`` per *changed* directed circuit (set up or torn
      down), capped at ``reconfig_delay``.  Models switches that reprogram
      ports independently rather than the whole fabric at once.
    * ``overlap = True`` — additionally hide round *i*'s reprogramming
      behind round *i−1*'s communication (SWOT-style
      reconfiguration/communication overlap); the planner charges only the
      part of the reconfiguration that outlasts the previous round.
    """

    name: str
    alpha: float            # fixed per-transfer cost (s)
    beta: float             # 1 / link bandwidth (s per byte)
    reconfig_delay: float   # r: full-fabric reprogram time (s)
    tx_per_gpu: int = 1     # optical transmitters per accelerator tile
    rx_per_gpu: int = 1
    # r_link: per-changed-circuit reprogram time (s); None → serial model
    reconfig_delay_per_link: Optional[float] = None
    # hide reconfiguration behind the previous round's communication
    overlap: bool = False

    def with_reconfig(self, r: float) -> "HardwareParams":
        return replace(self, name=f"{self.name}_r{r:g}", reconfig_delay=r)

    def with_link_reconfig(
        self, r_link: float, *, overlap: bool = False
    ) -> "HardwareParams":
        """Partial-reconfiguration variant (optionally overlapped)."""
        tag = f"{self.name}_rl{r_link:g}" + ("_ov" if overlap else "")
        return replace(
            self, name=tag, reconfig_delay_per_link=r_link, overlap=overlap
        )

    def with_overlap(self, overlap: bool = True) -> "HardwareParams":
        return replace(self, name=f"{self.name}_ov", overlap=overlap)

    @property
    def reconfig_mode(self) -> str:
        """``serial`` | ``partial`` | ``overlap`` (how changes are priced)."""
        if self.overlap:
            return "overlap"
        return "serial" if self.reconfig_delay_per_link is None else "partial"


def reconfig_cost(prev_topo: Topology, next_topo: Topology, hw: HardwareParams) -> float:
    """Cost (s) of reprogramming the fabric from ``prev_topo`` to ``next_topo``.

    Serial model: the full ``reconfig_delay`` on any change.  Partial model
    (``reconfig_delay_per_link`` set): ``r_link`` per changed directed
    circuit — circuits present in exactly one of the two edge sets — capped
    at the full-fabric delay.  Identical edge sets always cost 0.

    Overlap (``hw.overlap``) is *not* applied here: it depends on what the
    fabric is doing while reprogramming, so the planner subtracts the
    previous round's communication time at the DP transition.
    """
    if prev_topo.edges == next_topo.edges:
        return 0.0
    if hw.reconfig_delay_per_link is None:
        return hw.reconfig_delay
    changed = len(prev_topo.edges ^ next_topo.edges)
    return min(hw.reconfig_delay, hw.reconfig_delay_per_link * changed)


# §5: α = 3 µs (H100 DGX p2p latency), β = 1/450 GB/s (NVLink), r = 5 µs
# (Passage-class MZI switching).  Appendix A sweeps r ∈ {10, 25, 50, 500} µs;
# Fig. 9 uses 1 ms (MEMS-class).
H100_DGX = HardwareParams("h100_dgx", alpha=3e-6, beta=1.0 / (450e9), reconfig_delay=5e-6)
H100_DGX_R10US = H100_DGX.with_reconfig(10e-6)
H100_DGX_R25US = H100_DGX.with_reconfig(25e-6)
H100_DGX_R50US = H100_DGX.with_reconfig(50e-6)
H100_DGX_R500US = H100_DGX.with_reconfig(500e-6)
H100_DGX_R1MS = H100_DGX.with_reconfig(1e-3)

# TPU v5e adaptation target: 50 GB/s per ICI link, ~1 µs software α,
# OCS-class reconfiguration (ms) and Passage-class (µs) variants.
TPU_V5E_OCS = HardwareParams("tpu_v5e_ocs", alpha=1e-6, beta=1.0 / (50e9), reconfig_delay=2e-3)
TPU_V5E_PHOTONIC = HardwareParams("tpu_v5e_photonic", alpha=1e-6, beta=1.0 / (50e9), reconfig_delay=5e-6)

PRESETS: Dict[str, HardwareParams] = {
    p.name: p
    for p in [
        H100_DGX,
        H100_DGX_R10US,
        H100_DGX_R25US,
        H100_DGX_R50US,
        H100_DGX_R500US,
        H100_DGX_R1MS,
        TPU_V5E_OCS,
        TPU_V5E_PHOTONIC,
    ]
}


@dataclass(frozen=True)
class RoundCost:
    """Per-round cost with the decomposition used by Figs. 8/9."""

    total: float
    dilation: int
    congestion: int
    alpha_base: float        # α (one hop, no dilation)
    beta_base: float         # β·w (full bandwidth, no congestion)
    dilation_extra: float    # (dilation-1)·α
    congestion_extra: float  # (congestion-1)·β·w
    feasible: bool


# Bounded LRU over (n, edges) → (dist, pred).  Sessions may plan from
# multiple threads, so all access is lock-guarded; eviction drops only the
# least-recently-used entry (a blanket clear() used to dump the hot entry
# mid-sweep).
_SP_CACHE: "OrderedDict[Tuple, Tuple]" = OrderedDict()
_SP_CACHE_MAX = 64
_SP_CACHE_LOCK = threading.Lock()


def _scipy_paths(topo: Topology):
    """(dist, pred) all-pairs unweighted shortest paths — C-speed via scipy.
    Cached per topology; the planner prices O(rounds × states) rounds so this
    is the hot path (paper claims <1 s for the largest scale-up domains)."""
    import numpy as np

    key = (topo.n, topo.edges)
    with _SP_CACHE_LOCK:
        hit = _SP_CACHE.get(key)
        if hit is not None:
            _SP_CACHE.move_to_end(key)
            return hit
    from scipy.sparse import csr_matrix
    from scipy.sparse.csgraph import shortest_path as sp

    n = topo.n
    if topo.edges:
        rows, cols = zip(*topo.edges)
    else:
        rows, cols = (), ()
    g = csr_matrix(
        (np.ones(len(rows)), (np.asarray(rows), np.asarray(cols))), shape=(n, n)
    )
    dist, pred = sp(g, method="D", directed=True, unweighted=True,
                    return_predecessors=True)
    with _SP_CACHE_LOCK:
        _SP_CACHE[key] = (dist, pred)
        _SP_CACHE.move_to_end(key)
        while len(_SP_CACHE) > _SP_CACHE_MAX:
            _SP_CACHE.popitem(last=False)
    return dist, pred


def round_factors(topo: Topology, rnd: Round) -> Tuple[int, int, bool]:
    """Algorithm 2 lines 1–14: (dilation, congestion, feasible).

    Vectorized: all transfers' shortest paths are walked simultaneously via
    the predecessor matrix (one numpy step per hop depth)."""
    import numpy as np

    pairs = [(t.src, t.dst) for t in rnd.transfers if t.src != t.dst]
    if not pairs:
        return (0, 0, True)

    # Fast path 1: every transfer is a direct circuit (a round priced on its
    # own ideal graph — the planner's most common query).
    if all(p in topo.edges for p in pairs):
        from collections import Counter

        cong = max(Counter(pairs).values())
        return (1, cong, True)

    # Fast path 2: functional graphs (out-degree ≤ 1, i.e. other rounds'
    # ideal graphs): the only path from u is the unique outgoing chain.
    out: Dict[int, int] = {}
    functional = True
    for u, v in topo.edges:
        if u in out:
            functional = False
            break
        out[u] = v
    if functional:
        edge_usage: Dict[Tuple[int, int], int] = {}
        dil = 0
        for s, d in pairs:
            cur, hops = s, 0
            while cur != d:
                nxt = out.get(cur)
                if nxt is None or hops > topo.n:
                    return (_BIG, _BIG, False)
                edge_usage[(cur, nxt)] = edge_usage.get((cur, nxt), 0) + 1
                cur = nxt
                hops += 1
            dil = max(dil, hops)
        return (dil, max(edge_usage.values(), default=0), True)

    srcs = np.asarray([p[0] for p in pairs])
    dsts = np.asarray([p[1] for p in pairs])
    dist, pred = _scipy_paths(topo)
    d = dist[srcs, dsts]
    if not np.all(np.isfinite(d)):
        return (_BIG, _BIG, False)
    dilation = int(d.max())

    # walk every path back from dst to src in lockstep
    cur = dsts.copy()
    codes: List = []
    active = cur != srcs
    while active.any():
        prev = pred[srcs[active], cur[active]]
        codes.append(prev.astype(np.int64) * topo.n + cur[active])
        nxt = cur.copy()
        nxt[active] = prev
        cur = nxt
        active = cur != srcs
    all_codes = np.concatenate(codes)
    _, counts = np.unique(all_codes, return_counts=True)
    return (dilation, int(counts.max()), True)


def comm_cost_round(
    topo: Topology, rnd: Round, w: Optional[float], hw: HardwareParams
) -> RoundCost:
    """Algorithm 2: α·dilation + β·congestion·w, or the large penalty."""
    size = rnd.size if w is None else w
    dilation, congestion, feasible = round_factors(topo, rnd)
    if not feasible:
        return RoundCost(LARGE_PENALTY, dilation, congestion, 0, 0, 0, 0, False)
    if dilation == 0:  # empty round
        return RoundCost(0.0, 0, 0, 0.0, 0.0, 0.0, 0.0, True)
    alpha_base = hw.alpha
    beta_base = hw.beta * size
    dil_extra = (dilation - 1) * hw.alpha
    con_extra = (congestion - 1) * hw.beta * size
    total = hw.alpha * dilation + hw.beta * congestion * size
    return RoundCost(total, dilation, congestion, alpha_base, beta_base, dil_extra, con_extra, True)


@dataclass(frozen=True)
class ScheduleCost:
    """Fixed-topology cost of a whole schedule (baseline algorithms, Eq. 1)."""

    total: float
    rounds: Tuple[RoundCost, ...]

    @property
    def alpha_base(self) -> float:
        return sum(r.alpha_base for r in self.rounds)

    @property
    def beta_base(self) -> float:
        return sum(r.beta_base for r in self.rounds)

    @property
    def dilation_extra(self) -> float:
        return sum(r.dilation_extra for r in self.rounds)

    @property
    def congestion_extra(self) -> float:
        return sum(r.congestion_extra for r in self.rounds)

    def breakdown(self) -> Dict[str, float]:
        return {
            "alpha": self.alpha_base,
            "beta": self.beta_base,
            "dilation": self.dilation_extra,
            "congestion": self.congestion_extra,
            "reconfig": 0.0,
            "total": self.total,
        }


def schedule_cost_fixed(topo: Topology, schedule: Schedule, hw: HardwareParams) -> ScheduleCost:
    """Eq. 1: Σ_i (α·d_i + β·c_i·w_i) on a topology that never changes."""
    per = tuple(comm_cost_round(topo, rnd, None, hw) for rnd in schedule.rounds)
    return ScheduleCost(sum(r.total for r in per), per)


def ideal_cost(schedule: Schedule, hw: HardwareParams) -> float:
    """Textbook α–β cost: every round on its perfectly matched topology."""
    return sum(hw.alpha + hw.beta * r.size for r in schedule.rounds if r.transfers)


def lower_bound_reduce_scatter(n: int, d: float, hw: HardwareParams) -> float:
    """β lower bound (each rank must move (n-1)/n·d) + α lower bound (log2 n)."""
    import math

    return hw.alpha * math.ceil(math.log2(n)) + hw.beta * d * (n - 1) / n
