"""Algorithm 4: inter-server fiber routing with flow conservation (App. B.1).

Servers are nodes of a grid graph; fibers are the edges between them.  A
fiber carries one circuit per wavelength, so the number of fibers that must
be physically attached between two adjacent servers equals the maximum number
of circuits routed across that server-to-server edge.  Algorithm 4 is an ILP:
route every (src, dst) demand with per-route flow conservation while
minimizing ``z``, the maximum per-edge overlap — ``z`` is "the lowest number
of fibers required that can support all the circuit requests".

Two solvers:

* :func:`route_fibers_milp` — the paper's ILP verbatim via scipy/HiGHS
  (binary ``x^i_{u,v}`` per route per directed edge + integer ``z``).  Exact;
  used for small instances and to certify the heuristic in tests.
* :func:`route_fibers` — load-aware successive shortest paths followed by a
  reroute-improvement loop on the argmax edge.  This scales to the paper's
  64-server / 512-circuit workload in seconds and reproduces the headline
  numbers (≤ 7 fibers @ 100 circuits, ≤ 31 @ 512 — §4.2).
"""

from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .topology import Topology, grid2d

Edge = Tuple[int, int]


@dataclass
class FiberRouting:
    routes: List[List[int]]           # node path per request
    edge_load: Dict[Edge, int]        # directed edge -> circuits crossing it
    z: int                            # max load = fibers needed on worst edge
    elapsed_s: float


def _directed_edges(topo: Topology) -> List[Edge]:
    return sorted(topo.edges)


def _dijkstra_loaded(
    adj: Dict[int, List[int]], load: Dict[Edge, int], src: int, dst: int,
    blocked_above: Optional[int] = None, load_weight: float = 1.0,
) -> Optional[List[int]]:
    """Shortest path where edge weight = 1 + load_weight·load; edges with
    load > blocked_above (if given) are unusable."""
    import heapq

    INF = float("inf")
    dist = {src: 0.0}
    prev: Dict[int, int] = {}
    heap = [(0.0, src)]
    seen = set()
    while heap:
        d, u = heapq.heappop(heap)
        if u in seen:
            continue
        seen.add(u)
        if u == dst:
            break
        for v in adj[u]:
            l = load.get((u, v), 0)
            if blocked_above is not None and l > blocked_above:
                continue
            nd = d + 1.0 + load_weight * l
            if nd < dist.get(v, INF):
                dist[v] = nd
                prev[v] = u
                heapq.heappush(heap, (nd, v))
    if dst not in dist:
        return None
    path = [dst]
    while path[-1] != src:
        path.append(prev[path[-1]])
    path.reverse()
    return path


def route_fibers(
    topo: Topology,
    demands: Sequence[Edge],
    existing: Optional[Dict[Edge, int]] = None,
    improve_iters: int = 2000,
) -> FiberRouting:
    """Heuristic min-max routing: greedy load-aware paths + argmax rerouting."""
    t0 = time.perf_counter()
    adj: Dict[int, List[int]] = {u: [] for u in range(topo.n)}
    for u, v in topo.edges:
        adj[u].append(v)
    load: Dict[Edge, int] = dict(existing or {})
    routes: List[List[int]] = []

    for s, d in demands:
        path = _dijkstra_loaded(adj, load, s, d, load_weight=1.0)
        if path is None:
            raise RuntimeError(f"no path {s}->{d}")
        for a, b in zip(path[:-1], path[1:]):
            load[(a, b)] = load.get((a, b), 0) + 1
        routes.append(path)

    def zmax() -> int:
        return max(load.values(), default=0)

    # improvement: pull one route off the worst edge if a sub-z path exists
    for _ in range(improve_iters):
        z = zmax()
        if z <= 1:
            break
        worst = max(load, key=lambda e: load[e])
        moved = False
        for ri, path in enumerate(routes):
            pairs = list(zip(path[:-1], path[1:]))
            if worst not in pairs:
                continue
            for a, b in pairs:  # remove this route's load
                load[(a, b)] -= 1
            alt = _dijkstra_loaded(
                adj, load, path[0], path[-1], blocked_above=z - 2, load_weight=0.25
            )
            if alt is not None and max(
                load.get((a, b), 0) for a, b in zip(alt[:-1], alt[1:])
            ) <= z - 2:
                routes[ri] = alt
                for a, b in zip(alt[:-1], alt[1:]):
                    load[(a, b)] = load.get((a, b), 0) + 1
                moved = True
                break
            for a, b in pairs:  # restore
                load[(a, b)] += 1
        if not moved:
            break
    load = {e: c for e, c in load.items() if c > 0}
    return FiberRouting(routes, load, max(load.values(), default=0), time.perf_counter() - t0)


def route_fibers_milp(
    topo: Topology,
    demands: Sequence[Edge],
    existing: Optional[Dict[Edge, int]] = None,
) -> FiberRouting:
    """Algorithm 4 as written: minimize z s.t. per-route unit flow."""
    from scipy.optimize import LinearConstraint, milp
    from scipy.sparse import lil_matrix

    t0 = time.perf_counter()
    edges = _directed_edges(topo)
    ne = len(edges)
    eidx = {e: i for i, e in enumerate(edges)}
    nreq = len(demands)
    existing = existing or {}

    # variables: x[i, e] for i in routes, e in edges; then z
    nv = nreq * ne + 1
    zvar = nreq * ne

    def x(i: int, e: int) -> int:
        return i * ne + e

    c = np.zeros(nv)
    c[zvar] = 1.0

    rows: List[Tuple[Dict[int, float], float, float]] = []
    for i, (s, d) in enumerate(demands):
        for v in range(topo.n):
            out_edges = [eidx[e] for e in edges if e[0] == v]
            in_edges = [eidx[e] for e in edges if e[1] == v]
            coeffs: Dict[int, float] = {}
            for e in out_edges:
                coeffs[x(i, e)] = coeffs.get(x(i, e), 0.0) + 1.0
            for e in in_edges:
                coeffs[x(i, e)] = coeffs.get(x(i, e), 0.0) - 1.0
            if v == s:
                rows.append((coeffs, 1.0, 1.0))     # src: one net outflow
            elif v == d:
                rows.append((coeffs, -1.0, -1.0))   # dst: one net inflow
            else:
                rows.append((coeffs, 0.0, 0.0))     # conservation
        # forbid flow back into src / out of dst (paper's extra constraints)
        for e in [eidx[e] for e in edges if e[1] == s]:
            rows.append(({x(i, e): 1.0}, 0.0, 0.0))
        for e in [eidx[e] for e in edges if e[0] == d]:
            rows.append(({x(i, e): 1.0}, 0.0, 0.0))

    for e in range(ne):
        coeffs = {x(i, e): 1.0 for i in range(nreq)}
        coeffs[zvar] = -1.0
        rows.append((coeffs, -np.inf, -float(existing.get(edges[e], 0))))

    A = lil_matrix((len(rows), nv))
    lb = np.empty(len(rows))
    ub = np.empty(len(rows))
    for k, (coeffs, lo, hi) in enumerate(rows):
        for var, coef in coeffs.items():
            A[k, var] = coef
        lb[k] = lo
        ub[k] = hi

    integrality = np.ones(nv)
    lo_b = np.zeros(nv)
    hi_b = np.ones(nv)
    hi_b[zvar] = np.inf
    from scipy.optimize import Bounds

    res = milp(
        c=c,
        constraints=LinearConstraint(A.tocsr(), lb, ub),
        integrality=integrality,
        bounds=Bounds(lo_b, hi_b),
    )
    if not res.success:
        raise RuntimeError(f"fiber MILP failed: {res.message}")
    xs = np.round(res.x[:zvar]).astype(int).reshape(nreq, ne)
    routes = []
    for i, (s, d) in enumerate(demands):
        nxt = {edges[e][0]: edges[e][1] for e in range(ne) if xs[i, e]}
        path = [s]
        guard = 0
        while path[-1] != d:
            path.append(nxt[path[-1]])
            guard += 1
            if guard > topo.n:
                raise RuntimeError("cyclic MILP route")
        routes.append(path)
    load: Dict[Edge, int] = dict(existing)
    for e in range(ne):
        tot = int(xs[:, e].sum())
        if tot:
            load[edges[e]] = load.get(edges[e], 0) + tot
    return FiberRouting(routes, load, int(round(res.fun)), time.perf_counter() - t0)


def random_demands(topo: Topology, k: int, seed: int = 0) -> List[Edge]:
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(k):
        s, d = rng.choice(topo.n, size=2, replace=False)
        out.append((int(s), int(d)))
    return out


def server_grid(n_servers: int) -> Topology:
    """The paper's evaluation substrate: a square-ish server grid (64 → 8×8)."""
    from .topology import square_dims2

    a, b = square_dims2(n_servers)
    return grid2d(a, b)
