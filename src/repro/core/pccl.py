"""PCCL facade — the library's user-facing planning API.

Given a collective request (primitive, #ranks, buffer size), an initial
fabric state ``G0``, and hardware parameters, :func:`plan_collective`

1. builds the candidate algorithm schedules for that primitive (§2.2: the
   right algorithm depends on buffer size and hardware — there is no silver
   bullet),
2. runs the reconfiguration planner (Algorithm 1) on each schedule, and
3. returns the cheapest :class:`PcclPlan`, alongside fixed-topology baseline
   costs so callers (benchmarks, the training integration) can report the
   paper's comparisons directly.

The default input schedules follow the paper: RHD for reduce-scatter /
all-reduce (§5 "PCCL Inputs"), DEX for all-to-all (Fig. 10a), with ``auto``
additionally considering Ring (large-buffer β-optimal) and letting the
planner arbitrate — this is the "selecting the right algorithm" knob PCCL
exposes to distributed-ML programmers.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

from . import schedules as S
from .cost_model import HardwareParams, ScheduleCost, ideal_cost, schedule_cost_fixed
from .planner import Plan, plan
from .schedules import Schedule
from .topology import Topology, ring, standard_topologies


@dataclass(frozen=True)
class PcclPlan:
    request: "CollectiveRequest"
    schedule: Schedule
    plan: Plan
    candidates: Tuple[Tuple[str, float], ...]  # (algorithm, planned cost)

    @property
    def cost(self) -> float:
        return self.plan.total_cost

    @property
    def algorithm(self) -> str:
        return self.schedule.algorithm

    @property
    def num_reconfigs(self) -> int:
        return self.plan.num_reconfigs

    @property
    def final_topology(self) -> Optional[Topology]:
        """Fabric state after the last round (threaded by PcclSession)."""
        return self.plan.final_topology

    def breakdown(self) -> Dict[str, float]:
        return self.plan.breakdown()


@dataclass(frozen=True)
class CollectiveRequest:
    collective: str          # reduce_scatter | all_gather | all_reduce | all_to_all
    n: int
    buffer_bytes: float
    algorithm: str = "paper_default"  # or explicit name, or "auto"


def _pow2(n: int) -> bool:
    return n >= 2 and (n & (n - 1)) == 0


def candidate_algorithms(collective: str, n: int, mode: str) -> List[str]:
    if mode not in ("auto", "paper_default"):
        return [mode]
    if collective in ("reduce_scatter", "all_gather", "all_reduce"):
        if mode == "paper_default":
            return ["rhd"] if _pow2(n) else ["ring"]
        # §2.2: PCCL lets the user pick ANY known algorithm as the input
        # schedule — auto mode arbitrates over the full zoo via the planner.
        algos = ["ring", "bucket2d", "bucket3d"]
        if _pow2(n):
            algos.append("rhd")
        return algos
    if collective == "all_to_all":
        if mode == "paper_default":
            return ["dex"] if _pow2(n) else ["direct"]
        algos = ["direct"]
        if _pow2(n):
            algos.append("dex")
        return algos
    if collective == "p2p":
        return ["p2p"]
    raise ValueError(f"unknown collective {collective!r}")


def default_standard_set(n: int) -> List[Topology]:
    """S of Algorithm 1: standard connected graphs the planner may fall back
    to when per-round ideal graphs would strand future rounds (§4.1)."""
    std = standard_topologies(n)
    return [std["ring"], std["torus2d"]]


def plan_collective(
    request: CollectiveRequest,
    g0: Topology,
    hw: HardwareParams,
    standard: Optional[Sequence[Topology]] = None,
    dims: Optional[Sequence[int]] = None,
) -> PcclPlan:
    """Plan one collective from a cold fabric state.

    The reconfiguration cost model rides on ``hw``
    (``HardwareParams.reconfig_mode``): the paper's serial full-delay model
    by default, or per-changed-link partial reconfiguration — optionally
    hidden behind the previous round's communication — via
    ``hw.with_link_reconfig(r_link, overlap=True)``.

    .. deprecated::
        Application code should go through :class:`repro.api.PcclSession`,
        which adds plan caching and fabric-state threading across
        collectives.  This free function remains as the stateless planning
        kernel the session calls into (and as a back-compat shim).
    """
    if standard is None:
        standard = default_standard_set(request.n)
    best: Optional[PcclPlan] = None
    cands: List[Tuple[str, float]] = []
    for algo in candidate_algorithms(request.collective, request.n, request.algorithm):
        algo_dims = dims
        if algo_dims is None and algo.startswith("bucket"):
            from .topology import square_dims2, square_dims3

            algo_dims = (
                square_dims2(request.n) if algo == "bucket2d" else square_dims3(request.n)
            )
            if min(algo_dims) == 1:
                continue  # degenerate factorization
        sched = S.get_schedule(
            request.collective, algo, request.n, request.buffer_bytes, dims=algo_dims
        )
        p = plan(g0, standard, sched, hw)
        cands.append((algo, p.total_cost))
        if best is None or p.total_cost < best.cost:
            best = PcclPlan(request, sched, p, ())
    assert best is not None
    return PcclPlan(request, best.schedule, best.plan, tuple(cands))


def baseline_cost(
    collective: str,
    algorithm: str,
    topo: Topology,
    n: int,
    buffer_bytes: float,
    hw: HardwareParams,
    dims: Optional[Sequence[int]] = None,
) -> ScheduleCost:
    """Fixed-topology cost of a named algorithm (the §5 baselines)."""
    sched = S.get_schedule(collective, algorithm, n, buffer_bytes, dims=dims)
    return schedule_cost_fixed(topo, sched, hw)


def theoretical_cost(
    collective: str, algorithm: str, n: int, buffer_bytes: float,
    hw: HardwareParams, dims: Optional[Sequence[int]] = None,
) -> float:
    """Textbook α–β cost of the algorithm (every round contention-free)."""
    sched = S.get_schedule(collective, algorithm, n, buffer_bytes, dims=dims)
    return ideal_cost(sched, hw)


# --------------------------------------------------------------------------
# Size-aware algorithm choice used by the training integration: the paper's
# §2.2 guidance (latency-optimal for small buffers, bandwidth-optimal for
# large) falls out of planned costs rather than a hand-tuned threshold.
# --------------------------------------------------------------------------

def choose_algorithm(
    collective: str, n: int, buffer_bytes: float, hw: HardwareParams,
    g0: Optional[Topology] = None,
) -> str:
    """.. deprecated:: use ``PcclSession.choose_algorithm`` (cached, fabric
    aware).  Kept as a stateless shim for existing call sites/tests."""
    g0 = g0 or ring(n)
    p = plan_collective(
        CollectiveRequest(collective, n, buffer_bytes, algorithm="auto"), g0, hw
    )
    return p.algorithm
