"""PCCL facade — the library's user-facing planning API.

Given a collective request (primitive, #ranks, buffer size), an initial
fabric state ``G0``, and hardware parameters, :func:`plan_collective`

1. builds the candidate algorithm schedules for that primitive (§2.2: the
   right algorithm depends on buffer size and hardware — there is no silver
   bullet),
2. runs the reconfiguration planner (Algorithm 1) on each schedule, and
3. returns the cheapest :class:`PcclPlan`, alongside fixed-topology baseline
   costs so callers (benchmarks, the training integration) can report the
   paper's comparisons directly.

The default input schedules follow the paper: RHD for reduce-scatter /
all-reduce (§5 "PCCL Inputs"), DEX for all-to-all (Fig. 10a), with ``auto``
additionally considering Ring (large-buffer β-optimal) and letting the
planner arbitrate — this is the "selecting the right algorithm" knob PCCL
exposes to distributed-ML programmers.
"""

from __future__ import annotations

import warnings
from dataclasses import dataclass, replace
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from . import schedules as S
from .cost_model import (
    HardwareParams,
    ScheduleCost,
    compressed_ef_error_bound,
    ideal_cost,
    schedule_cost_fixed,
)
from .planner import (
    ConcurrentPlan,
    HierarchicalPlan,
    Plan,
    PlanStructure,
    _plans_from_structure,
    build_structure,
    plan_concurrent,
    plan_hierarchical,
    plan_sweep,
    replan,
)
from .schedules import Groups, Schedule, replicate_groups
from .topology import Topology, ring, standard_topologies


@dataclass(frozen=True)
class PcclPlan:
    request: "CollectiveRequest"
    schedule: Schedule
    # flat exact-DP plan, or a stitched two-level plan (same accounting
    # surface: total_cost / num_reconfigs / final_topology / breakdown)
    plan: "Plan | HierarchicalPlan"
    candidates: Tuple[Tuple[str, float], ...]  # (algorithm, planned cost)

    @property
    def cost(self) -> float:
        return self.plan.total_cost

    @property
    def algorithm(self) -> str:
        return self.schedule.algorithm

    @property
    def num_reconfigs(self) -> int:
        return self.plan.num_reconfigs

    @property
    def final_topology(self) -> Optional[Topology]:
        """Fabric state after the last round (threaded by PcclSession)."""
        return self.plan.final_topology

    def breakdown(self) -> Dict[str, float]:
        return self.plan.breakdown()


# Version in which the PR-1 deprecation shims (bare plan_collective /
# choose_algorithm here, PcclComm in repro.comm) are removed.  Their
# replacement is the unified request surface: PcclSession.submit(PlanRequest)
# (repro.api.session) — every shim warning names both, and
# tests/test_pccl_facade.py asserts the shims still delegate bit-identically
# until then.
SHIM_REMOVAL_VERSION = "2.0"


def _warn_deprecated(old: str, replacement: str) -> None:
    warnings.warn(
        f"{old} is deprecated and will be removed in repro "
        f"{SHIM_REMOVAL_VERSION}; use {replacement} instead",
        DeprecationWarning,
        stacklevel=3,
    )


@dataclass(frozen=True)
class CollectiveRequest:
    collective: str          # reduce_scatter | all_gather | all_reduce | all_to_all
    n: int
    buffer_bytes: float
    algorithm: str = "paper_default"  # or explicit name, or "auto"
    # Caller-declared tolerance on the result's relative error (w.r.t. the
    # exact result's max representable magnitude — see
    # cost_model.compressed_ef_error_bound).  None = exact results only;
    # setting it lets auto arbitration also consider lossy wire-compressed
    # algorithms (ring_ef8) whose documented bound fits under it.
    rel_error_tol: Optional[float] = None


def _pow2(n: int) -> bool:
    return n >= 2 and (n & (n - 1)) == 0


def candidate_algorithms(
    collective: str, n: int, mode: str,
    rel_error_tol: Optional[float] = None,
) -> List[str]:
    if mode not in ("auto", "paper_default"):
        return [mode]
    if collective in ("reduce_scatter", "all_gather", "all_reduce"):
        if mode == "paper_default":
            return ["rhd"] if _pow2(n) else ["ring"]
        # §2.2: PCCL lets the user pick ANY known algorithm as the input
        # schedule — auto mode arbitrates over the full zoo via the planner.
        algos = ["ring", "bucket2d", "bucket3d"]
        if _pow2(n):
            algos.append("rhd")
        if (
            collective == "all_reduce"
            and rel_error_tol is not None
            and rel_error_tol >= compressed_ef_error_bound(n)
        ):
            # int8-on-the-wire ring: bytes/4 serialization, lossy within the
            # documented bound — only a candidate when the caller's declared
            # tolerance covers that bound.
            algos.append("ring_ef8")
        return algos
    if collective == "all_to_all":
        if mode == "paper_default":
            return ["dex"] if _pow2(n) else ["direct"]
        algos = ["direct"]
        if _pow2(n):
            algos.append("dex")
        return algos
    if collective == "p2p":
        return ["p2p"]
    raise ValueError(f"unknown collective {collective!r}")


def candidate_dims(
    algo: str, n: int, dims: Optional[Sequence[int]]
) -> Tuple[Optional[Sequence[int]], bool]:
    """(dims, usable) for one candidate algorithm: bucket algorithms over an
    ``n`` with only a degenerate (min dim 1) factorization are unusable and
    must be skipped by every arbitration path the same way."""
    if dims is None and algo.startswith("bucket"):
        from .topology import square_dims2, square_dims3

        dims = square_dims2(n) if algo == "bucket2d" else square_dims3(n)
        if min(dims) == 1:
            return None, False
    return dims, True


def default_standard_set(n: int) -> List[Topology]:
    """S of Algorithm 1: standard connected graphs the planner may fall back
    to when per-round ideal graphs would strand future rounds (§4.1)."""
    std = standard_topologies(n)
    return [std["ring"], std["torus2d"]]


def plan_collective(
    request: CollectiveRequest,
    g0: Topology,
    hw: HardwareParams,
    standard: Optional[Sequence[Topology]] = None,
    dims: Optional[Sequence[int]] = None,
) -> PcclPlan:
    """Plan one collective from a cold fabric state.

    The reconfiguration cost model rides on ``hw``
    (``HardwareParams.reconfig_mode``): the paper's serial full-delay model
    by default, or per-changed-link partial reconfiguration — optionally
    hidden behind the previous round's communication — via
    ``hw.with_link_reconfig(r_link, overlap=True)``.

    .. deprecated::
        Removed in repro 2.0 (``SHIM_REMOVAL_VERSION``).  Application code
        should go through ``PcclSession.submit(PlanRequest(...))``
        (:class:`repro.api.PcclSession`), which adds plan caching and
        fabric-state threading across collectives.  The stateless planning
        kernel the session calls into is :func:`plan_collective_sweep`,
        which stays; this bare entry point warns and delegates
        bit-identically until removal.
    """
    _warn_deprecated(
        "bare plan_collective",
        "PcclSession.submit(PlanRequest(collective, nbytes)) from repro.api",
    )
    return plan_collective_sweep(
        request, [request.buffer_bytes], g0, hw, standard=standard, dims=dims
    )[0]


def plan_collective_sweep(
    request: CollectiveRequest,
    sizes: Sequence[float],
    g0: Topology,
    hw: HardwareParams,
    standard: Optional[Sequence[Topology]] = None,
    dims: Optional[Sequence[int]] = None,
    structure_for: Optional[Callable[[str], Optional[PlanStructure]]] = None,
    on_structure: Optional[Callable[[str, PlanStructure], None]] = None,
) -> List[PcclPlan]:
    """Plan one collective at many buffer sizes from one fabric state.

    The batched front of :func:`plan_collective`: per candidate algorithm,
    one size-independent structure phase (``planner.build_structure``)
    prices every size via ``planner.plan_sweep``, and the cheapest plan is
    selected *per size* — exactly the arbitration a per-size
    ``plan_collective`` loop performs.  ``request.buffer_bytes`` is ignored
    in favour of ``sizes``.

    Each candidate's schedule is *built once* at ``sizes[0]`` and rescaled
    to the other sizes (schedule generators are the next cost after routing
    in a sweep; only ``Round.size`` varies with the buffer).  Plans for
    ``sizes[0]`` are therefore bit-identical to ``plan_collective`` at that
    size; other sizes are bit-identical whenever their ratio to ``sizes[0]``
    is a power of two (the common sweep layout) and equal to the last ulp
    otherwise — see :func:`repro.core.planner.plan_sweep`.

    ``structure_for`` / ``on_structure`` let a caller (the session's
    two-level cache) reuse structures across calls: ``structure_for(algo)``
    may return a previously built :class:`PlanStructure` for that candidate
    algorithm, and ``on_structure(algo, structure)`` is invoked for each one
    built here.
    """
    if standard is None:
        standard = default_standard_set(request.n)
    sizes = list(sizes)
    best: List[Optional[PcclPlan]] = [None] * len(sizes)
    cands: List[List[Tuple[str, float]]] = [[] for _ in sizes]
    for algo in candidate_algorithms(
        request.collective, request.n, request.algorithm,
        request.rel_error_tol,
    ):
        algo_dims, usable = candidate_dims(algo, request.n, dims)
        if not usable:
            continue
        template = S.get_schedule(
            request.collective, algo, request.n, sizes[0], dims=algo_dims
        )
        structure = structure_for(algo) if structure_for is not None else None
        if structure is None:
            structure = build_structure(g0, standard, template, hw)
            if on_structure is not None:
                on_structure(algo, structure)
        plans = plan_sweep(
            g0, standard, template, hw, sizes, structure=structure
        )
        for k, p in enumerate(plans):
            cands[k].append((algo, p.total_cost))
            if best[k] is None or p.total_cost < best[k].cost:
                req_k = (
                    request
                    if sizes[k] == request.buffer_bytes
                    else replace(request, buffer_bytes=sizes[k])
                )
                best[k] = PcclPlan(req_k, p.schedule, p, ())
    out: List[PcclPlan] = []
    for b, c in zip(best, cands):
        assert b is not None
        out.append(PcclPlan(b.request, b.schedule, b.plan, tuple(c)))
    return out


def plan_collective_hierarchical(
    request: CollectiveRequest,
    g0: Topology,
    hw: HardwareParams,
    standard: Optional[Sequence[Topology]] = None,
    dims: Optional[Sequence[int]] = None,
    *,
    pods: Optional[Sequence[Sequence[int]]] = None,
    pod_size: Optional[int] = None,
) -> PcclPlan:
    """Plan one collective through the two-level hierarchical path
    (:func:`repro.core.planner.plan_hierarchical`), arbitrating candidate
    algorithms by stitched cost exactly like :func:`plan_collective` does by
    flat cost.

    This is the scaling path: flat exact planning is O(rounds · states²)
    with states ~ n, while the hierarchical path plans one representative
    pod and one P-super-rank coarse phase.  With a single pod it degrades
    to the flat exact DP (bit-identical plan inside ``.plan.pod_plans[0]``).
    """
    if standard is None:
        standard = default_standard_set(request.n)
    best: Optional[PcclPlan] = None
    cands: List[Tuple[str, float]] = []
    for algo in candidate_algorithms(
        request.collective, request.n, request.algorithm,
        request.rel_error_tol,
    ):
        algo_dims, usable = candidate_dims(algo, request.n, dims)
        if not usable:
            continue
        schedule = S.get_schedule(
            request.collective, algo, request.n, request.buffer_bytes,
            dims=algo_dims,
        )
        hp = plan_hierarchical(
            g0, standard, schedule, hw, pods=pods, pod_size=pod_size
        )
        cands.append((algo, hp.total_cost))
        if best is None or hp.total_cost < best.cost:
            best = PcclPlan(request, schedule, hp, ())
    if best is None:
        raise ValueError(
            f"no usable candidate algorithm for {request.collective} at "
            f"n={request.n}"
        )
    return PcclPlan(best.request, best.schedule, best.plan, tuple(cands))


def replan_collective(
    request: CollectiveRequest,
    g0: Topology,
    hw: HardwareParams,
    standard: Optional[Sequence[Topology]] = None,
    dims: Optional[Sequence[int]] = None,
    *,
    changed_edges: Sequence[Tuple[int, int]] = (),
    changed_ranks: Sequence[int] = (),
    structure_for: Optional[Callable[[str], Optional[PlanStructure]]] = None,
    on_structure: Optional[Callable[[str, PlanStructure], None]] = None,
) -> PcclPlan:
    """Warm-replan one collective after a fabric mutation.

    ``g0``/``standard`` are the *pre-failure* fabric inputs; candidate
    algorithms whose structures are available via ``structure_for`` take the
    incremental O(affected-states) path of :func:`repro.core.planner.replan`
    (cold building otherwise), and ``on_structure`` receives each
    post-mutation structure for recaching.  Arbitration across candidates
    matches :func:`plan_collective` on the degraded fabric exactly.
    """
    if standard is None:
        standard = default_standard_set(request.n)
    best: Optional[PcclPlan] = None
    cands: List[Tuple[str, float]] = []
    for algo in candidate_algorithms(
        request.collective, request.n, request.algorithm,
        request.rel_error_tol,
    ):
        algo_dims, usable = candidate_dims(algo, request.n, dims)
        if not usable:
            continue
        schedule = S.get_schedule(
            request.collective, algo, request.n, request.buffer_bytes,
            dims=algo_dims,
        )
        structure = structure_for(algo) if structure_for is not None else None
        p, new_structure = replan(
            g0, standard, schedule, hw, structure,
            changed_edges=changed_edges, changed_ranks=changed_ranks,
        )
        if on_structure is not None:
            on_structure(algo, new_structure)
        cands.append((algo, p.total_cost))
        if best is None or p.total_cost < best.cost:
            best = PcclPlan(request, schedule, p, ())
    if best is None:
        raise ValueError(
            f"no usable candidate algorithm for {request.collective} at "
            f"n={request.n}"
        )
    return PcclPlan(best.request, best.schedule, best.plan, tuple(cands))


# --------------------------------------------------------- concurrent groups


@dataclass(frozen=True)
class ConcurrentCollectiveRequest:
    """One member of a concurrent plan: a collective over one process-group
    set of a shared ``n``-rank fabric domain.

    ``groups`` partitions the domain into equal-size groups that each run
    the collective simultaneously (the ``Communicator.split`` pattern — TP
    rows / DP columns of a 2-D mesh); ``None`` means a single group spanning
    the whole domain.  ``nbytes`` is the per-rank buffer size *within* a
    group, and ``algorithm`` follows :func:`candidate_algorithms` semantics
    (``auto`` arbitrates over the zoo via each candidate's solo plan).
    """

    collective: str
    nbytes: float
    groups: Optional[Groups] = None
    algorithm: str = "paper_default"

    def __post_init__(self) -> None:
        # normalize list-of-lists literals: group sets are part of hashable
        # plan-cache keys, so they must be tuples all the way down
        if self.groups is not None:
            object.__setattr__(
                self, "groups", tuple(tuple(g) for g in self.groups)
            )

    def group_size(self, n: int) -> int:
        return len(self.groups[0]) if self.groups else n


@dataclass(frozen=True)
class ConcurrentPcclPlan:
    """Joint plan for several concurrent collective requests (the facade
    wrapper around :class:`repro.core.planner.ConcurrentPlan`)."""

    requests: Tuple[ConcurrentCollectiveRequest, ...]
    n: int
    algorithms: Tuple[str, ...]       # chosen algorithm per request
    plan: ConcurrentPlan

    @property
    def cost(self) -> float:
        return self.plan.total_cost

    @property
    def joint_cost(self) -> float:
        return self.plan.joint_cost

    @property
    def sequential_cost(self) -> float:
        return self.plan.sequential_cost

    @property
    def speedup(self) -> float:
        return self.plan.speedup

    @property
    def serialized(self) -> bool:
        return self.plan.serialized

    @property
    def final_topology(self) -> Optional[Topology]:
        return self.plan.final_topology

    @property
    def offsets(self) -> Tuple[int, ...]:
        """Per-request arrival-round offsets the joint plan was built for."""
        return self.plan.offsets

    def solo_costs(self) -> Tuple[float, ...]:
        """Per-request fabric-to-itself planned costs (the sequential parts)."""
        return tuple(g.solo.total_cost for g in self.plan.groups)


def _validate_concurrent_groups(
    requests: Sequence[ConcurrentCollectiveRequest], n: int
) -> None:
    for req in requests:
        if req.groups is None:
            continue
        sizes = {len(g) for g in req.groups}
        if len(sizes) != 1:
            raise ValueError(
                f"request {req.collective}: unequal group sizes {sizes}"
            )
        flat = sorted(r for g in req.groups for r in g)
        if flat != list(range(n)):
            raise ValueError(
                f"request {req.collective}: groups must partition the "
                f"{n}-rank domain exactly once"
            )


def plan_concurrent_collectives(
    requests: Sequence[ConcurrentCollectiveRequest],
    n: int,
    g0: Topology,
    hw: HardwareParams,
    standard: Optional[Sequence[Topology]] = None,
    *,
    offsets: Optional[Sequence[int]] = None,
) -> ConcurrentPcclPlan:
    """Jointly plan several concurrently-active collectives on one fabric.

    Per request, each candidate algorithm's group-local schedule is built at
    the requested size, composed across its process groups
    (:func:`repro.core.schedules.replicate_groups`) and solo-planned; the
    cheapest candidate is that request's input schedule — the same per-size
    arbitration as :func:`plan_collective`, applied per group.  The chosen
    schedules (structures reused from arbitration) then go through the
    multi-group arbiter :func:`repro.core.planner.plan_concurrent`, which
    overlaps the groups' rounds with per-link contention pricing and never
    prices worse than running the solo plans sequentially.

    ``offsets`` (one arrival round per request) staggers admissions: request
    ``k``'s rounds start at joint round ``offsets[k]`` — see
    :func:`repro.core.planner.plan_concurrent`.
    """
    requests = tuple(requests)
    if not requests:
        raise ValueError("plan_concurrent_collectives needs at least one request")
    if offsets is not None and len(tuple(offsets)) != len(requests):
        raise ValueError(
            f"got {len(tuple(offsets))} offsets for {len(requests)} requests"
        )
    if standard is None:
        standard = default_standard_set(n)
    _validate_concurrent_groups(requests, n)

    chosen_scheds: List[Schedule] = []
    chosen_structs: List[PlanStructure] = []
    chosen_solos: List[Plan] = []
    algorithms: List[str] = []
    for req in requests:
        m = req.group_size(n)
        best_plan: Optional[Plan] = None
        best_sched: Optional[Schedule] = None
        best_struct: Optional[PlanStructure] = None
        for algo in candidate_algorithms(
            req.collective, m, req.algorithm,
            getattr(req, "rel_error_tol", None),
        ):
            algo_dims, usable = candidate_dims(algo, m, None)
            if not usable:
                continue
            local = S.get_schedule(
                req.collective, algo, m, req.nbytes, dims=algo_dims
            )
            sched = (
                replicate_groups(local, req.groups, n)
                if req.groups is not None
                else local
            )
            struct = build_structure(g0, standard, sched, hw)
            solo = _plans_from_structure(struct, [sched], hw)[0]
            if best_plan is None or solo.total_cost < best_plan.total_cost:
                best_plan, best_sched, best_struct = solo, sched, struct
        if best_sched is None or best_struct is None:
            raise ValueError(
                f"request {req.collective} (group size {m}, algorithm "
                f"{req.algorithm!r}) has no usable candidate schedule — "
                "e.g. a bucket algorithm over a group size with a "
                "degenerate factorization"
            )
        chosen_scheds.append(best_sched)
        chosen_structs.append(best_struct)
        chosen_solos.append(best_plan)
        algorithms.append(best_sched.algorithm)

    joint = plan_concurrent(
        g0, standard, chosen_scheds, hw,
        structures=chosen_structs, solo_plans=chosen_solos,
        offsets=offsets,
    )
    return ConcurrentPcclPlan(
        requests=requests,
        n=n,
        algorithms=tuple(algorithms),
        plan=joint,
    )


def baseline_cost(
    collective: str,
    algorithm: str,
    topo: Topology,
    n: int,
    buffer_bytes: float,
    hw: HardwareParams,
    dims: Optional[Sequence[int]] = None,
) -> ScheduleCost:
    """Fixed-topology cost of a named algorithm (the §5 baselines)."""
    sched = S.get_schedule(collective, algorithm, n, buffer_bytes, dims=dims)
    return schedule_cost_fixed(topo, sched, hw)


def theoretical_cost(
    collective: str, algorithm: str, n: int, buffer_bytes: float,
    hw: HardwareParams, dims: Optional[Sequence[int]] = None,
) -> float:
    """Textbook α–β cost of the algorithm (every round contention-free)."""
    sched = S.get_schedule(collective, algorithm, n, buffer_bytes, dims=dims)
    return ideal_cost(sched, hw)


# --------------------------------------------------------------------------
# Size-aware algorithm choice used by the training integration: the paper's
# §2.2 guidance (latency-optimal for small buffers, bandwidth-optimal for
# large) falls out of planned costs rather than a hand-tuned threshold.
# --------------------------------------------------------------------------

def choose_algorithm(
    collective: str, n: int, buffer_bytes: float, hw: HardwareParams,
    g0: Optional[Topology] = None,
) -> str:
    """.. deprecated:: removed in repro 2.0 (``SHIM_REMOVAL_VERSION``) —
    use ``PcclSession.choose_algorithm`` or
    ``PcclSession.submit(PlanRequest(..., algorithm="auto")).algorithm``
    (cached, fabric aware).  Kept as a stateless shim that delegates
    bit-identically until then."""
    _warn_deprecated(
        "bare choose_algorithm",
        "PcclSession.choose_algorithm (or PcclSession.submit(PlanRequest("
        "..., algorithm='auto')).algorithm) from repro.api",
    )
    g0 = g0 or ring(n)
    p = plan_collective_sweep(
        CollectiveRequest(collective, n, buffer_bytes, algorithm="auto"),
        [buffer_bytes], g0, hw,
    )[0]
    return p.algorithm
