"""PCCL's reconfiguration planner (paper Algorithm 1).

The paper formulates "when to reconfigure" as an ILP over binary ``t_{i,j}``
(round *i* uses topology *j*) with

* one-topology-per-round (Eq. 4),
* contiguous use of round-derived ideal topologies (Eq. 5: an ideal graph can
  only be *entered* in the round that generates it, then carried forward), and
* reconfiguration cost paid on a topology change between consecutive rounds
  (Eq. 7), with per-round cost = CommCost (Algorithm 2) + ReconfCost.

That constraint structure is a shortest path over a tiny layered graph, so the
primary solver here is an **exact dynamic program** (`plan`):

    f(i, s) = CommCost(topo(s), R_i, w_i)
              + min over admissible predecessors p of [ f(i-1, p) + T_i(p, s) ]

where the state space is the edge-set-deduplicated union of {G0} ∪ S ∪
{ideal(R_k)}.  Deduplication matters for fidelity: e.g. every round of a ring
schedule has the *same* ideal graph, so staying on it must not re-pay ``r``
(paper Eq. 7 charges only on change).

Structure / numeric split
-------------------------
Everything the buffer size touches is a *price*; everything expensive is
*structure*.  The planner therefore runs in two phases:

* **Structure phase** (:func:`build_structure`, size-independent): the
  deduplicated state set, the per-(round, state) dilation/congestion integer
  matrices ``(D, C)`` (Algorithm 2 routing, served by
  ``cost_model.STRUCTURE_TABLE`` keyed on (edge-set, pair-multiset) so
  structurally identical rounds are routed once), and the pairwise
  reconfiguration table (``_transition_costs``: an edge-incidence boolean
  matrix and one vectorized symmetric-difference count, memoized across
  calls).
* **Numeric phase** (cheap): price ``(D, C)`` at the requested α/β/w and run
  the DP.  The DP value table is batched over a *size axis* — ``f`` has shape
  ``(len(sizes), ns)`` — so :func:`plan_sweep` prices an entire buffer-size
  sweep from a single structure phase.  ``plan`` is the K=1 special case of
  the same code path, which makes sweep plans bit-identical to a per-size
  ``plan`` loop (same step sequence, same totals, same tie-breaking).

The transition cost ``T_i(p, s)`` generalizes the paper's ``r·1[p≠s]``
(``cost_model.reconfig_cost``):

* serial (default): the full fabric delay ``r`` on any change — the paper's
  pessimistic model, bit-identical to the original planner;
* partial (``hw.reconfig_delay_per_link``): ``r_link`` per changed directed
  circuit, capped at ``r`` — only the links that differ are reprogrammed;
* overlapped (``hw.overlap``): ``max(0, ReconfCost(p, s) − CommCost_{i−1}(p))``
  for ``i ≥ 1`` — round *i*'s reprogramming is hidden behind round *i−1*'s
  communication (SWOT-style overlap).  The reconfiguration out of ``G0``
  (round 0) has nothing to hide behind and is always paid in full.

Cross-checks (used in tests):
* `plan_bruteforce` — exhaustive enumeration of all feasible assignments.
* `plan_milp` — the paper's ILP (with pairwise transition variables when
  costs are non-uniform), via scipy HiGHS.

All three agree in every reconfiguration mode; the DP runs in
O(rounds · states²) *numeric* work after O(distinct round structures ·
states) routing calls, and plans the largest scale-up domains in well under
the paper's one-second budget (§4.1).
"""

from __future__ import annotations

import threading
from collections import OrderedDict
from dataclasses import dataclass, replace
from typing import Dict, FrozenSet, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from .cost_model import (
    LARGE_PENALTY,
    STRUCTURE_TABLE,
    HardwareParams,
    RoundCost,
    clear_structure_caches,
    comm_cost_round,
    edge_loads,
    pairs_of,
    reconfig_cost,
    round_cost_from_factors,
    round_structure_key,
)
from .schedules import Round, Schedule, Transfer, pod_subschedules
from .topology import (
    Edge,
    Topology,
    degrade_topology,
    derive_pods,
    induced_topology,
    quotient_topology,
    ring,
    square_dims2,
    torus2d,
)


@dataclass(frozen=True)
class TopoState:
    """One deduplicated candidate topology for the DP/ILP."""

    idx: int
    topo: Topology
    standard: bool                       # in {G0} ∪ S: enterable at any round
    entry_rounds: FrozenSet[int]         # rounds whose ideal graph this is

    def enterable_at(self, i: int) -> bool:
        return self.standard or i in self.entry_rounds


@dataclass(frozen=True)
class PlanStep:
    round_index: int
    state_idx: int
    topo_name: str
    reconfigured: bool
    cost: RoundCost
    reconfig_cost: float

    @property
    def total(self) -> float:
        return self.cost.total + self.reconfig_cost


@dataclass(frozen=True)
class Plan:
    schedule: Schedule
    hw: HardwareParams
    steps: Tuple[PlanStep, ...]
    total_cost: float
    # Topology the fabric is left in after the last round (G0 for empty
    # schedules).  Sessions thread this into the next plan's G0 so
    # back-to-back collectives don't re-pay reconfigurations (api.session).
    final_topology: Optional[Topology] = None

    @property
    def num_reconfigs(self) -> int:
        return sum(1 for s in self.steps if s.reconfigured)

    def breakdown(self) -> Dict[str, float]:
        return {
            "alpha": sum(s.cost.alpha_base for s in self.steps),
            "beta": sum(s.cost.beta_base for s in self.steps),
            "dilation": sum(s.cost.dilation_extra for s in self.steps),
            "congestion": sum(s.cost.congestion_extra for s in self.steps),
            "reconfig": sum(s.reconfig_cost for s in self.steps),
            "total": self.total_cost,
        }


def build_states(
    g0: Topology, standard: Sequence[Topology], schedule: Schedule
) -> List[TopoState]:
    """Dedup {G0} ∪ S ∪ ideal-graphs by directed edge set (input set G of
    Alg. 1 with the bitmap identity of Eq. 7 applied to edge sets)."""
    by_edges: Dict[FrozenSet[Edge], Dict] = {}

    def add(topo: Topology, is_standard: bool, entry_round: Optional[int]) -> None:
        rec = by_edges.setdefault(
            topo.edges, {"topo": topo, "standard": False, "entries": set()}
        )
        rec["standard"] = rec["standard"] or is_standard
        if entry_round is not None:
            rec["entries"].add(entry_round)

    add(g0, True, None)
    for s in standard:
        if s.n != schedule.n:
            raise ValueError(f"standard topology {s.name} has n={s.n} != {schedule.n}")
        add(s, True, None)
    for i, rnd in enumerate(schedule.rounds):
        add(rnd.ideal_topology(schedule.n), False, i)

    states = []
    for k, rec in enumerate(by_edges.values()):
        states.append(
            TopoState(k, rec["topo"], rec["standard"], frozenset(rec["entries"]))
        )
    return states


# -------------------------------------------------------------- structure


@dataclass(frozen=True, eq=False)
class PlanStructure:
    """The size-independent phase of Algorithm 1.

    Holds everything ``plan``/``plan_sweep`` need that does not depend on
    α/β/w: the deduplicated state set, the ``(rounds × states)`` integer
    dilation/congestion matrices plus feasibility mask, and the pairwise
    reconfiguration-cost table.  Building one is the expensive part of
    planning; pricing it at a size is a handful of vectorized ops.  Sessions
    cache these keyed *without* ``nbytes`` (api.session.PcclSession).
    """

    states: Tuple[TopoState, ...]
    g0_idx: int
    n_rounds: int
    dilation: np.ndarray      # (R, ns) int64
    congestion: np.ndarray    # (R, ns) int64
    feasible: np.ndarray      # (R, ns) bool
    enterable: np.ndarray     # (R, ns) bool: Eq. 5 entry constraint
    trans: np.ndarray         # (ns, ns) float64, read-only
    round_keys: Tuple         # per-round pair-multiset keys (for validation)
    # build provenance, checked when a structure is reused (plan_sweep):
    # trans bakes in these reconfig params, g0_idx this start state
    g0_edges: FrozenSet[Edge] = frozenset()
    reconfig_params: Tuple[float, Optional[float]] = (0.0, None)
    # edge-sets of standard topologies dropped by the dead-state prune
    # (infeasible for every round, e.g. disconnected by link failures) —
    # recorded so structure reuse can still validate its standard set
    pruned_standard: FrozenSet[FrozenSet[Edge]] = frozenset()
    # the exact Schedule object this structure was built from, when known:
    # ``_check_structure`` skips the O(rounds × pairs) round-key replay on an
    # identity hit (the session's ``get_schedule`` memo hands every caller
    # one shared object, so warm replans validate in O(1))
    schedule: Optional[Schedule] = None


def _round_structures(
    states: Sequence[TopoState],
    schedule: Schedule,
    round_keys: Optional[Tuple] = None,
) -> Tuple[np.ndarray, np.ndarray, np.ndarray, Tuple]:
    """(dilation, congestion, feasible, round_keys): Algorithm 2's integer
    factors for every (round, state).

    Structurally identical rounds (same pair multiset) are routed once and
    their rows copied — a ring schedule's n−1 rounds are one routing query
    per state.  Individual (topology, pair-set) queries additionally hit the
    process-wide ``STRUCTURE_TABLE``.

    Non-linear states carry undirected-component labels computed once per
    state; a (state, round) combo whose pairs cross components is marked
    infeasible without routing (no shortest-path walk) — the common case on
    degraded fabrics and coarsened inter-pod graphs, where disconnected
    candidates would otherwise each pay a scipy APSP.  The rounds a
    non-linear state still has to route after the table and component
    shortcuts are priced in one batched predecessor walk
    (``_route_rounds_general``), not one scalar walk per round."""
    from .cost_model import (
        _StackedLinear,
        _bidi_path_labels,
        _linear_labels,
        _route_linear_batch,
        _route_rounds_bidi,
        _route_rounds_general,
    )
    from .topology import _BIG

    n_rounds = len(schedule.rounds)
    ns = len(states)

    # --- deduplicate rounds by pair multiset; route only the distinct ones,
    # then expand rows back to the full round axis with one fancy index.
    # A caller that already knows the per-round keys (replan reuses the
    # validated ``structure.round_keys``) passes them in and skips the
    # O(rounds × pairs) key derivation.
    keys: List = []
    first: Dict = {}
    didx = np.empty(n_rounds, dtype=np.int64)
    d_keys: List = []
    d_arrays: List = []  # (srcs, dsts) index arrays, or None for empty rounds
    for i, rnd in enumerate(schedule.rounds):
        pairs: Optional[List[Tuple[int, int]]] = None
        if round_keys is not None:
            key = round_keys[i]
        else:
            pairs = pairs_of(rnd)
            key = round_structure_key(pairs)
        keys.append(key)
        k = first.get(key)
        if k is None:
            k = len(d_keys)
            first[key] = k
            d_keys.append(key)
            arrs = rnd.__dict__.get("_pair_arrays", False)
            if arrs is False:  # memoized alongside pairs_of: same rounds
                if pairs is None:  # get re-priced across plans and replans
                    pairs = pairs_of(rnd)
                if pairs:
                    arrs = (
                        np.asarray([p[0] for p in pairs]),
                        np.asarray([p[1] for p in pairs]),
                    )
                else:
                    arrs = None
                object.__setattr__(rnd, "_pair_arrays", arrs)
            d_arrays.append(arrs)
        didx[i] = k
    nd = len(d_keys)
    ddil = np.zeros((nd, ns), dtype=np.int64)
    dcong = np.zeros((nd, ns), dtype=np.int64)
    dfeas = np.ones((nd, ns), dtype=bool)

    # Linear states (permutation ideal graphs — usually most of the state
    # set) are routed against each distinct round structure in ONE batched
    # numpy pass over stacked component labels, not per-state calls.
    lin_states: List[TopoState] = []
    lin_labels: List = []
    other_states: List[TopoState] = []
    for s in states:
        lab = _linear_labels(s.topo)
        if lab is not None:
            lin_states.append(s)
            lin_labels.append(lab)
        else:
            other_states.append(s)
    stacked = _StackedLinear(lin_labels) if lin_states else None

    for k in range(nd):
        if d_arrays[k] is None:  # empty round: (0, 0, True) on every topology
            continue
        if stacked is None:
            break
        key = d_keys[k]
        srcs, dsts = d_arrays[k]
        cached = {}
        for s in lin_states:
            hit = STRUCTURE_TABLE.lookup(s.topo, key)
            if hit is not None:
                cached[s.idx] = hit
        if len(cached) == len(lin_states):
            for s_idx, (d, c, ok) in cached.items():
                ddil[k, s_idx], dcong[k, s_idx], dfeas[k, s_idx] = d, c, ok
        else:
            bd, bc, bf = _route_linear_batch(stacked, srcs, dsts)
            for t, s in enumerate(lin_states):
                f3 = (int(bd[t]), int(bc[t]), bool(bf[t]))
                if s.idx not in cached:
                    STRUCTURE_TABLE.store(s.topo, key, f3)
                ddil[k, s.idx], dcong[k, s.idx], dfeas[k, s.idx] = f3

    # Non-linear states: table lookups and the cross-component shortcut
    # first; whatever survives is routed in ONE batched shortest-path walk
    # per state over all its uncached distinct rounds (the warm-replan
    # path's dominant cost was one scalar walk per round here).
    for s in other_states:
        # bidirectional path forests (a ring that lost a link — the typical
        # degraded fabric) route by position arithmetic, no path walk and no
        # per-round component prefilter (the router prices infeasibility)
        bidi = _bidi_path_labels(s.topo)
        lab: Optional[np.ndarray] = None
        pending: List[int] = []
        for k in range(nd):
            if d_arrays[k] is None:
                continue
            f3 = STRUCTURE_TABLE.lookup(s.topo, d_keys[k])
            if f3 is None:
                if bidi is None:
                    if lab is None:
                        lab = _undirected_components(s.topo)
                    srcs, dsts = d_arrays[k]
                    if (lab[srcs] != lab[dsts]).any():
                        # a pair crosses undirected components: unroutable
                        # in any direction, same verdict every routing path
                        # returns
                        f3 = (_BIG, _BIG, False)
                        STRUCTURE_TABLE.store(s.topo, d_keys[k], f3)
                    else:
                        pending.append(k)
                        continue
                else:
                    pending.append(k)
                    continue
            ddil[k, s.idx], dcong[k, s.idx], dfeas[k, s.idx] = f3
        if not pending:
            continue
        if bidi is not None:
            routed = _route_rounds_bidi(bidi, [d_arrays[k] for k in pending])
        else:
            routed = _route_rounds_general(
                s.topo, [d_arrays[k] for k in pending]
            )
        STRUCTURE_TABLE.store_many(
            s.topo, [(d_keys[k], f3) for k, f3 in zip(pending, routed)]
        )
        for k, f3 in zip(pending, routed):
            ddil[k, s.idx], dcong[k, s.idx], dfeas[k, s.idx] = f3

    return ddil[didx], dcong[didx], dfeas[didx], tuple(keys)


def _undirected_components(topo: Topology) -> np.ndarray:
    """Undirected connected-component label per node.  A pair whose endpoints
    sit in different components is unroutable regardless of direction —
    Algorithm 2's feasibility has this as a necessary condition that needs no
    shortest-path computation."""
    parent = list(range(topo.n))

    def find(x: int) -> int:
        while parent[x] != x:
            parent[x] = parent[parent[x]]
            x = parent[x]
        return x

    for u, v in topo.edges:
        ru, rv = find(u), find(v)
        if ru != rv:
            parent[ru] = rv
    return np.asarray([find(x) for x in range(topo.n)], dtype=np.int64)


def build_structure(
    g0: Topology,
    standard: Sequence[Topology],
    schedule: Schedule,
    hw: HardwareParams,
) -> PlanStructure:
    """Run the size-independent phase once; see :class:`PlanStructure`.

    Only ``schedule``'s round *structure* (pair multisets) matters — its
    sizes are ignored, so one structure prices any member of a buffer-size
    sweep."""
    states = build_states(g0, standard, schedule)
    dil, cong, feas, keys = _round_structures(states, schedule)
    states, dil, cong, feas, pruned = _prune_dead_states(
        states, g0, dil, cong, feas
    )
    trans = _transition_costs(states, hw)
    enterable = _enterable_mask(states, len(schedule.rounds))
    return PlanStructure(
        states=tuple(states),
        g0_idx=_g0_state(states, g0),
        n_rounds=len(schedule.rounds),
        dilation=dil,
        congestion=cong,
        feasible=feas,
        enterable=enterable,
        trans=trans,
        round_keys=keys,
        g0_edges=g0.edges,
        reconfig_params=(hw.reconfig_delay, hw.reconfig_delay_per_link),
        pruned_standard=pruned,
        schedule=schedule,
    )


def _enterable_mask(states: Sequence[TopoState], n_rounds: int) -> np.ndarray:
    """(rounds × states) Eq. 5 entry mask, column-scattered rather than
    evaluated per cell (R·ns ``enterable_at`` calls add up at n≥1024)."""
    ent = np.zeros((n_rounds, len(states)), dtype=bool)
    for s in states:
        if s.standard:
            ent[:, s.idx] = True
        else:
            for i in s.entry_rounds:
                ent[i, s.idx] = True
    return ent


def _prune_dead_states(
    states: List[TopoState],
    g0: Topology,
    dil: np.ndarray,
    cong: np.ndarray,
    feas: np.ndarray,
) -> Tuple[List[TopoState], np.ndarray, np.ndarray, np.ndarray,
           FrozenSet[FrozenSet[Edge]]]:
    """Drop candidate states that are infeasible for *every* round, before
    the transition table is built — each dead state would otherwise cost a
    row and column of the ns² table plus a DP lane while never being part
    of any finite-cost plan.  G0 is always kept (it is the start state even
    when a degraded fabric cannot route a single round).  Ideal-graph states
    are feasible at their own entry round by construction, so on a healthy
    fabric the mask never fires and plans are bit-identical with or without
    this pass; what it prunes in practice are standard/initial topologies
    disconnected by link failures (``replan``) and coarse super-rank
    candidates that cannot carry a boundary round (``plan_hierarchical``)."""
    if feas.size == 0:  # no rounds: nothing to judge feasibility against
        return states, dil, cong, feas, frozenset()
    keep = feas.any(axis=0)
    keep[_g0_state(states, g0)] = True
    if keep.all():
        return states, dil, cong, feas, frozenset()
    pruned = frozenset(s.topo.edges for s in states if not keep[s.idx])
    states = [
        replace(s, idx=k)
        for k, s in enumerate(s for s in states if keep[s.idx])
    ]
    return states, dil[:, keep], cong[:, keep], feas[:, keep], pruned


# Bounded LRU over (state edge-sets, reconfig params) → transition matrix.
# A session sweeping buffer sizes re-plans the same (states, hw) pair per
# size point; the table is dense but small (ns² floats), so memoizing it
# behind the same lock/LRU discipline as _SP_CACHE removes the rebuild.
# Eviction is size-aware on top of the entry count: an entry is charged its
# array bytes plus its key's edge-set footprint, so 64 n=1024 entries
# (each key alone holds ~1k-edge topologies) cannot pin gigabytes.
_TRANS_CACHE: "OrderedDict[Tuple, np.ndarray]" = OrderedDict()
_TRANS_CACHE_MAX = 64
_TRANS_CACHE_MAX_BYTES = 64 * 1024 * 1024
_TRANS_CACHE_BYTES = [0]  # mutable cell, guarded by _TRANS_CACHE_LOCK
_TRANS_CACHE_LOCK = threading.Lock()


def _trans_entry_charge(key: Tuple, arr: np.ndarray) -> int:
    edge_sets = key[0]
    return int(arr.nbytes) + 120 * sum(len(es) for es in edge_sets) + 512


def trans_cache_stats() -> Tuple[int, int]:
    """(entries, estimated bytes) currently held by the transition memo."""
    with _TRANS_CACHE_LOCK:
        return len(_TRANS_CACHE), _TRANS_CACHE_BYTES[0]


def _transition_costs_update(
    old_trans: np.ndarray,
    states: Sequence[TopoState],
    changed_idx: Sequence[int],
    hw: HardwareParams,
) -> np.ndarray:
    """Rows/columns of the transition table touched by ``changed_idx``,
    recomputed against ``old_trans`` (the pre-mutation table for the same
    state positions).  Exactly the arithmetic of :func:`_transition_costs`
    restricted to the affected pairs — ``|E_p Δ E_s|`` via set symmetric
    difference instead of the full incidence matmul — so a warm replan does
    O(changed · ns) set work, not O(ns²) + an O(ns · |E|) matrix build."""
    trans = np.array(old_trans)  # writable copy; old_trans is read-only
    edge_sets = [s.topo.edges for s in states]
    for c in changed_idx:
        ec = edge_sets[c]
        if hw.reconfig_delay_per_link is None:
            # serial mode needs only 1[E_c ≠ E_s], and state edge sets are
            # pairwise distinct by construction: every off-diagonal entry
            # is the flat delay, no symmetric differences at all
            row = np.full(len(edge_sets), hw.reconfig_delay)
            row[c] = 0.0
        else:
            cnt = np.fromiter(
                (len(ec ^ es) for es in edge_sets),
                dtype=np.float64,
                count=len(edge_sets),
            )
            row = np.minimum(hw.reconfig_delay, hw.reconfig_delay_per_link * cnt)
        trans[c, :] = row
        trans[:, c] = row
    trans.setflags(write=False)
    return trans


def _transition_costs(states: Sequence[TopoState], hw: HardwareParams) -> np.ndarray:
    """trans[p, s] = ReconfCost(topo_p → topo_s); 0 on the diagonal.

    Vectorized: states become rows of a boolean edge-incidence matrix over
    the union of all states' directed edges (edges in no state contribute 0
    to any symmetric difference), and ``|E_p Δ E_s|`` for every pair falls
    out of one matmul: ``|E_p| + |E_s| − 2·|E_p ∩ E_s|``.  States are
    deduplicated by edge set, so every off-diagonal entry is a genuine
    change (serial mode: the constant ``r``, recovering the paper's
    ``r·1[p≠s]``).

    Memoized per (state edge-sets, reconfiguration params); the returned
    array is shared and marked read-only."""
    key = (
        tuple(s.topo.edges for s in states),
        hw.reconfig_delay,
        hw.reconfig_delay_per_link,
    )
    with _TRANS_CACHE_LOCK:
        hit = _TRANS_CACHE.get(key)
        if hit is not None:
            _TRANS_CACHE.move_to_end(key)
            return hit

    ns = len(states)
    edge_union = sorted(set().union(*(s.topo.edges for s in states)) or set())
    index = {e: k for k, e in enumerate(edge_union)}
    # float64 so the Gram matrix goes through BLAS; counts stay exact
    inc = np.zeros((ns, max(len(edge_union), 1)))
    for s in states:
        for e in s.topo.edges:
            inc[s.idx, index[e]] = 1.0
    sizes = inc.sum(axis=1)
    changed = sizes[:, None] + sizes[None, :] - 2.0 * (inc @ inc.T)
    if hw.reconfig_delay_per_link is None:
        trans = np.where(changed > 0, hw.reconfig_delay, 0.0)
    else:
        trans = np.minimum(hw.reconfig_delay, hw.reconfig_delay_per_link * changed)
    trans.setflags(write=False)

    with _TRANS_CACHE_LOCK:
        if key not in _TRANS_CACHE:
            _TRANS_CACHE_BYTES[0] += _trans_entry_charge(key, trans)
        _TRANS_CACHE[key] = trans
        _TRANS_CACHE.move_to_end(key)
        while len(_TRANS_CACHE) > 1 and (
            len(_TRANS_CACHE) > _TRANS_CACHE_MAX
            or _TRANS_CACHE_BYTES[0] > _TRANS_CACHE_MAX_BYTES
        ):
            vkey, varr = _TRANS_CACHE.popitem(last=False)
            _TRANS_CACHE_BYTES[0] -= _trans_entry_charge(vkey, varr)
    return trans


def clear_planner_caches(keep_shortest_paths: bool = False) -> None:
    """Drop the transition memo plus every routing cache below it (structure
    table, shortest paths).  Benchmarks use this to time cold planning;
    ``keep_shortest_paths=True`` retains the pre-split-era ``_SP_CACHE``
    (see ``cost_model.clear_structure_caches``)."""
    with _TRANS_CACHE_LOCK:
        _TRANS_CACHE.clear()
        _TRANS_CACHE_BYTES[0] = 0
    clear_structure_caches(keep_shortest_paths=keep_shortest_paths)


# ---------------------------------------------------------------- numeric


def _price(structure: PlanStructure, schedules: Sequence[Schedule],
           hw: HardwareParams) -> np.ndarray:
    """cost[k, i, s] = CommCost(topo_s, R_i, w_i(size_k)): the numeric phase.

    One vectorized expression over the whole ``(sizes × rounds × states)``
    block, with the identical operation order to
    ``cost_model.round_cost_from_factors`` so batched totals are bit-equal
    to scalar pricing."""
    w = np.array([[r.size for r in sch.rounds] for sch in schedules])  # (K, R)
    dil = structure.dilation[None, :, :]
    cong = structure.congestion[None, :, :]
    priced = hw.alpha * dil + (hw.beta * cong) * w[:, :, None]
    cost = np.where(dil == 0, 0.0, priced)
    return np.where(~structure.feasible[None, :, :], LARGE_PENALTY, cost)


def _effective_transition(
    trans: np.ndarray, cost: np.ndarray, i: int, hw: HardwareParams
) -> np.ndarray:
    """T_i[p, s] for entering round ``i`` on ``s`` from round ``i−1`` on ``p``.

    With overlap enabled, reprogramming round ``i``'s circuits happens while
    round ``i−1`` communicates on ``p``; only the excess is charged.  Round 0
    (``i == 0``) has no previous communication and pays ``trans`` in full.
    """
    if not hw.overlap or i == 0:
        return trans
    return np.maximum(0.0, trans - cost[i - 1][:, None])


def _g0_state(states: Sequence[TopoState], g0: Topology) -> int:
    for s in states:
        if s.topo.edges == g0.edges:
            return s.idx
    raise AssertionError("G0 missing from state set")


def _plans_from_structure(
    structure: PlanStructure,
    schedules: Sequence[Schedule],
    hw: HardwareParams,
) -> List[Plan]:
    """Exact DP over a pre-built structure, batched along the size axis.

    ``f`` is shaped ``(K, ns)`` per round (K = len(schedules)); every numpy
    step mirrors the K=1 recurrence exactly — same candidate sums, same
    argmin tie-breaking (first minimum, stay-put wins ties per Eq. 7's
    charge-only-on-change semantics) — so each returned plan is bit-identical
    to planning its schedule alone."""
    states = structure.states
    g0_idx = structure.g0_idx
    trans = structure.trans
    n_rounds = structure.n_rounds
    K = len(schedules)
    ns = len(states)
    idx = np.arange(ns)
    cost = _price(structure, schedules, hw)          # (K, R, ns)

    INF = float("inf")
    f = np.full((K, n_rounds, ns), INF)
    parent = np.full((K, n_rounds, ns), -1, dtype=np.int64)

    enter0 = structure.enterable[0] | (idx == g0_idx)
    f[:, 0, enter0] = cost[:, 0, enter0] + trans[g0_idx, enter0][None, :]
    parent[:, 0, enter0] = g0_idx

    if K == 1:
        # 2-D specialization of the loop below for the single-schedule case
        # (every plan()/replan() call): diagonal views instead of fancy
        # indexing, full-width wheres instead of masked assignment, no K
        # broadcasting.  Same candidate sums and tie-breaking element for
        # element — plan_sweep's sweep ≡ loop tests pin the two paths to
        # each other bit-for-bit.
        f0, parent0, cost0 = f[0], parent[0], cost[0]
        eff = trans
        cand = np.empty((ns, ns))
        for i in range(1, n_rounds):
            prev = f0[i - 1]
            if hw.overlap:
                eff = np.maximum(0.0, trans - cost0[i - 1][:, None])
            np.add(prev[:, None], eff, out=cand)     # cand[p, s]
            best_p = cand.argmin(axis=0)
            best = cand.min(axis=0)
            stay = np.diagonal(cand)
            prefer_stay = stay <= best
            best = np.where(prefer_stay, stay, best)
            best_p = np.where(prefer_stay, idx, best_p)

            enterable = structure.enterable[i]
            fin = np.isfinite(prev)
            f0[i] = np.where(
                enterable, best + cost0[i],
                np.where(fin, prev + cost0[i], INF),
            )
            parent0[i] = np.where(
                enterable, best_p, np.where(fin, idx, -1)
            )
    else:
        eff = trans[None, :, :]  # constant unless overlap re-derives per round
        cand = np.empty((K, ns, ns))
        for i in range(1, n_rounds):
            prev = f[:, i - 1, :]                    # (K, ns)
            if hw.overlap:
                eff = np.maximum(
                    0.0, trans[None, :, :] - cost[:, i - 1, :, None]
                )
            np.add(prev[:, :, None], eff, out=cand)  # cand[k, p, s]
            best_p = cand.argmin(axis=1)             # (K, ns)
            best = cand.min(axis=1)  # same element argmin names: first min
            # staying put (p == s, zero transition) wins ties, matching
            # Eq. 7's charge-only-on-change semantics
            stay = cand[:, idx, idx]
            prefer_stay = stay <= best
            best = np.where(prefer_stay, stay, best)
            best_p = np.where(prefer_stay, idx[None, :], best_p)

            enterable = structure.enterable[i]
            f[:, i, enterable] = best[:, enterable] + cost[:, i, enterable]
            parent[:, i, enterable] = best_p[:, enterable]
            carry = ~enterable
            if carry.any():
                # Eq. 5: ideal graphs outside their entry round carry only
                fin = np.isfinite(prev[:, carry])
                f[:, i, carry] = np.where(
                    fin, prev[:, carry] + cost[:, i, carry], INF
                )
                parent[:, i, carry] = np.where(fin, idx[carry][None, :], -1)

    last = f[:, n_rounds - 1, :].argmin(axis=1)      # (K,)
    plans: List[Plan] = []
    for k, sched in enumerate(schedules):
        seq = [int(last[k])]
        for i in range(n_rounds - 1, 0, -1):
            seq.append(int(parent[k, i, seq[-1]]))
        seq.reverse()

        steps: List[PlanStep] = []
        prev_idx = g0_idx
        for i, s_idx in enumerate(seq):
            reconf = s_idx != prev_idx
            t = trans[prev_idx, s_idx]
            if hw.overlap and i > 0:
                t = max(0.0, t - cost[k, i - 1, prev_idx])
            steps.append(
                PlanStep(
                    round_index=i,
                    state_idx=s_idx,
                    topo_name=states[s_idx].topo.name,
                    reconfigured=reconf,
                    cost=round_cost_from_factors(
                        int(structure.dilation[i, s_idx]),
                        int(structure.congestion[i, s_idx]),
                        bool(structure.feasible[i, s_idx]),
                        sched.rounds[i].size,
                        hw,
                    ),
                    reconfig_cost=float(t),
                )
            )
            prev_idx = s_idx
        plans.append(
            Plan(
                sched, hw, tuple(steps), float(f[k, n_rounds - 1, seq[-1]]),
                final_topology=states[seq[-1]].topo,
            )
        )
    return plans


def plan(
    g0: Topology,
    standard: Sequence[Topology],
    schedule: Schedule,
    hw: HardwareParams,
) -> Plan:
    """Exact DP solution of Algorithm 1 (any reconfiguration mode)."""
    if len(schedule.rounds) == 0:
        return Plan(schedule, hw, (), 0.0, final_topology=g0)
    structure = build_structure(g0, standard, schedule, hw)
    return _plans_from_structure(structure, [schedule], hw)[0]


def _rescale_schedule(schedule: Schedule, nbytes: float) -> Schedule:
    """Same round structure, every payload scaled to buffer size ``nbytes``."""
    if not schedule.rounds:
        return replace(schedule, buffer_bytes=nbytes)
    factor = nbytes / schedule.buffer_bytes
    return replace(
        schedule,
        buffer_bytes=nbytes,
        rounds=tuple(replace(r, size=r.size * factor) for r in schedule.rounds),
    )


def _check_structure(
    structure: PlanStructure,
    g0: Topology,
    standard: Sequence[Topology],
    schedule: Schedule,
    hw: HardwareParams,
) -> None:
    """Validate a caller-supplied structure against the planning inputs.

    A structure may have been built from a different schedule, fabric, or
    hardware model — its (D, C) matrices, transition table, and start state
    are only valid for its own."""
    if structure.g0_edges != g0.edges:
        raise ValueError(
            "supplied structure was built for a different G0 edge set"
        )
    if structure.reconfig_params != (
        hw.reconfig_delay, hw.reconfig_delay_per_link
    ):
        raise ValueError(
            "supplied structure was built under different reconfiguration "
            f"parameters {structure.reconfig_params}; its transition table "
            "does not price this hardware model"
        )
    std_edges = {s.topo.edges for s in structure.states if s.standard}
    for topo in standard:
        if (
            topo.edges not in std_edges
            and topo.edges not in structure.pruned_standard
        ):
            raise ValueError(
                f"standard topology {topo.name} is not a state of the "
                "supplied structure"
            )
    if len(schedule.rounds) != structure.n_rounds:
        raise ValueError(
            f"template has {len(schedule.rounds)} rounds; supplied "
            f"structure has {structure.n_rounds}"
        )
    if structure.schedule is schedule:
        # built from this exact (immutable) Schedule object — the per-round
        # key replay below would be comparing the schedule with itself.  The
        # common warm path: get_schedule's memo hands out shared objects.
        return
    for i, rnd in enumerate(schedule.rounds):
        if round_structure_key(pairs_of(rnd)) != structure.round_keys[i]:
            raise ValueError(
                f"template round {i} does not match the supplied "
                "structure's pair multiset"
            )


def plan_sweep(
    g0: Topology,
    standard: Sequence[Topology],
    schedule: Schedule,
    hw: HardwareParams,
    sizes: Sequence[float],
    *,
    schedules: Optional[Sequence[Schedule]] = None,
    structure: Optional[PlanStructure] = None,
) -> List[Plan]:
    """Plan one schedule structure at many buffer sizes — one structure
    phase, one batched numeric phase.

    ``schedule`` donates the round *structure* (which pairs, which rounds);
    ``sizes`` are the per-rank buffer sizes to price.  By default each size
    point reuses ``schedule`` with payloads rescaled proportionally; pass
    ``schedules`` (one per size, e.g. rebuilt via
    ``schedules.get_schedule``) when exact per-size payload arithmetic
    matters — every schedule must share ``schedule``'s round structure.
    Pass ``structure`` to reuse a previously built :class:`PlanStructure`.

    Returns one :class:`Plan` per size, bit-identical (steps and totals) to
    calling :func:`plan` on each size's schedule individually.  (With the
    default rescaling, a rescaled payload ``r.size · (d / d_ref)`` can
    differ in the last ulp from a generator-built one at ``d`` unless the
    size ratio is a power of two — build the sweep's template at the size
    whose exactness matters, or pass ``schedules``.)
    """
    if len(schedule.rounds) == 0:
        sweep = (
            schedules
            if schedules is not None
            else [_rescale_schedule(schedule, float(d)) for d in sizes]
        )
        return [Plan(sch, hw, (), 0.0, final_topology=g0) for sch in sweep]
    if structure is None:
        structure = build_structure(g0, standard, schedule, hw)
    else:
        _check_structure(structure, g0, standard, schedule, hw)
    if schedules is None:
        # rescaled schedules share the template's transfers, so they match
        # the (now template-validated) structure by construction
        schedules = [_rescale_schedule(schedule, float(d)) for d in sizes]
    else:
        if len(schedules) != len(sizes):
            raise ValueError(
                f"got {len(schedules)} schedules for {len(sizes)} sizes"
            )
        for sch in schedules:
            if len(sch.rounds) != structure.n_rounds:
                raise ValueError(
                    f"schedule {sch.algorithm}@{sch.buffer_bytes:g}B has "
                    f"{len(sch.rounds)} rounds; structure has {structure.n_rounds}"
                )
            for i, rnd in enumerate(sch.rounds):
                if round_structure_key(pairs_of(rnd)) != structure.round_keys[i]:
                    raise ValueError(
                        f"schedule {sch.algorithm}@{sch.buffer_bytes:g}B round {i} "
                        "does not match the structure's pair multiset"
                    )
    return _plans_from_structure(structure, schedules, hw)


# ------------------------------------------------- hierarchical planning


@dataclass(frozen=True)
class PodPlan:
    """One pod's slice of a hierarchical plan: the exact DP's plan for the
    pod's intra-pod sub-schedule, expressed over local rank ids
    (``ranks[local]`` is the global rank).  Structurally identical pods
    share one underlying :class:`Plan` object."""

    pod_index: int
    ranks: Tuple[int, ...]
    plan: Plan


@dataclass(frozen=True)
class HierarchicalPlan:
    """A stitched two-level plan: per-pod exact DP plans plus one coarse
    inter-pod plan over the super-rank (quotient) graph.

    Execution model priced here: pods own disjoint circuits and reconfigure
    independently, the boundary network is one more independent group, and
    every round is barrier-synced across groups — so round ``i`` lasts as
    long as its slowest group's reconfiguration + communication,
    ``round_costs[i] = max over groups of (steps[i].cost + reconfig)``, and
    ``total_cost = Σ_i round_costs[i]``.  The inter-pod phase is
    *capacity-optimistic*: each distinct pod pair of a round becomes one
    coarse transfer at the full round payload, so multiple rank pairs
    crossing the same pod pair are assumed to share aggregated boundary
    bandwidth.  ``analysis.invariants.check_hierarchical_plan`` replays both
    levels plus this stitching arithmetic.

    With one pod there is no decomposition: ``pod_plans[0].plan`` *is* the
    flat exact-DP plan (bit-identical steps and totals) and ``inter_plan``
    is ``None``.
    """

    schedule: Schedule
    hw: HardwareParams
    pods: Tuple[Tuple[int, ...], ...]
    rep: Tuple[int, ...]                 # pod → representative pod index
    pod_plans: Tuple[PodPlan, ...]
    inter_plan: Optional[Plan]
    # per-round cross-pod traffic: sorted ((src_pod, dst_pod), multiplicity)
    boundary: Tuple[Tuple[Tuple[Tuple[int, int], int], ...], ...]
    round_costs: Tuple[float, ...]
    total_cost: float
    # like Plan.final_topology, but a stitched fabric state is not a single
    # Topology the session can thread forward — always None
    final_topology: Optional[Topology] = None

    def groups(self) -> Tuple[Plan, ...]:
        """The distinct per-group plans (one per pod equivalence class,
        plus the inter-pod plan when present)."""
        seen: Dict[int, Plan] = {}
        for pp in self.pod_plans:
            seen.setdefault(id(pp.plan), pp.plan)
        out = tuple(seen.values())
        if self.inter_plan is not None:
            out = out + (self.inter_plan,)
        return out

    @property
    def num_reconfigs(self) -> int:
        return sum(g.num_reconfigs for g in self.groups())

    def breakdown(self) -> Dict[str, float]:
        pod_totals = [pp.plan.total_cost for pp in self.pod_plans]
        return {
            "total": self.total_cost,
            "max_pod_total": max(pod_totals, default=0.0),
            "inter_total": (
                self.inter_plan.total_cost if self.inter_plan is not None else 0.0
            ),
            "num_pods": float(len(self.pods)),
        }


def _pod_standard_set(m: int) -> List[Topology]:
    """Standard candidates for an m-node (sub-)fabric — the same ring +
    most-square torus pair ``pccl.default_standard_set`` uses at the top
    level (duplicates dedup away in ``build_states``)."""
    if m < 2:
        return []
    std = [ring(m)]
    a, b = square_dims2(m)
    if a >= 2:  # a 1×m "torus" is just the ring again
        std.append(torus2d(a, b))
    return std


def plan_hierarchical(
    g0: Topology,
    standard: Sequence[Topology],
    schedule: Schedule,
    hw: HardwareParams,
    *,
    pods: Optional[Sequence[Sequence[int]]] = None,
    pod_size: Optional[int] = None,
) -> HierarchicalPlan:
    """Two-level planning: exact DP per pod, exact DP over the coarse
    super-rank graph, stitched (see :class:`HierarchicalPlan`).

    ``pods`` partitions the ranks explicitly; otherwise
    ``topology.derive_pods(n, pod_size)`` cuts contiguous blocks aligned
    with the fabric's torus tiles / ring segments.  Pods with identical
    intra-pod round structure are planned once (``schedules.
    pod_subschedules`` deduplication), so planning cost scales with the
    number of *distinct* pod classes — typically one — plus one coarse
    phase over ``P`` super-ranks, not with ``n``.

    With ``pods=1`` (or ``pod_size=n``) this *is* the flat exact DP on the
    caller's inputs, wrapped: same steps, same total.
    """
    n = schedule.n
    if g0.n != n:
        raise ValueError(f"G0 has n={g0.n}, schedule has n={n}")
    if pods is None:
        pods = derive_pods(n, pod_size)
    else:
        pods = tuple(tuple(p) for p in pods)
    P = len(pods)
    R = len(schedule.rounds)

    if P == 1:
        flat = plan(g0, standard, schedule, hw)
        return HierarchicalPlan(
            schedule=schedule,
            hw=hw,
            pods=pods,
            rep=(0,),
            pod_plans=(PodPlan(0, pods[0], flat),),
            inter_plan=None,
            boundary=tuple(() for _ in range(R)),
            round_costs=tuple(s.total for s in flat.steps),
            total_cost=flat.total_cost,
        )

    intra, rep, boundary = pod_subschedules(schedule, pods)

    rep_plans: Dict[int, Plan] = {}
    for p in sorted(set(rep)):
        ranks = pods[p]
        pod_g0 = induced_topology(g0, ranks, name=f"{g0.name}|pod{p}")
        rep_plans[p] = plan(pod_g0, _pod_standard_set(len(ranks)), intra[p], hw)

    coarse_g0 = quotient_topology(g0, pods, name=f"{g0.name}/pods")
    # rounds crossing the same pod pairs share one coarse transfer tuple —
    # keyed on the pairs alone, since multiplicities (which differ round to
    # round) don't change the capacity-optimistic coarse round
    coarse_transfers: Dict[Tuple, Tuple[Transfer, ...]] = {}
    coarse_rounds = []
    for i in range(R):
        pkey = tuple(pr for pr, _cnt in boundary[i])
        ts = coarse_transfers.get(pkey)
        if ts is None:
            ts = tuple(Transfer(u, v) for u, v in pkey)
            coarse_transfers[pkey] = ts
        coarse_rounds.append(Round(ts, schedule.rounds[i].size))
    coarse_schedule = Schedule(
        schedule.collective,
        f"{schedule.algorithm}@inter",
        P,
        schedule.buffer_bytes,
        tuple(coarse_rounds),
    )
    inter = plan(coarse_g0, _pod_standard_set(P), coarse_schedule, hw)

    group_plans = [rep_plans[p] for p in sorted(set(rep))] + [inter]
    round_costs = tuple(
        max(gp.steps[i].total for gp in group_plans) for i in range(R)
    )
    return HierarchicalPlan(
        schedule=schedule,
        hw=hw,
        pods=pods,
        rep=rep,
        pod_plans=tuple(
            PodPlan(p, pods[p], rep_plans[rep[p]]) for p in range(P)
        ),
        inter_plan=inter,
        boundary=boundary,
        round_costs=round_costs,
        total_cost=float(sum(round_costs)),
    )


# ------------------------------------------------- incremental replanning


def replan(
    g0: Topology,
    standard: Sequence[Topology],
    schedule: Schedule,
    hw: HardwareParams,
    structure: Optional[PlanStructure] = None,
    *,
    changed_edges: Iterable[Edge] = (),
    changed_ranks: Iterable[int] = (),
) -> Tuple[Plan, PlanStructure]:
    """Warm replanning after a fabric mutation: O(affected states) routing
    instead of a cold structure phase.

    ``g0``/``standard`` are the *pre-failure* inputs ``structure`` was built
    from; ``changed_edges`` (directed circuits — pass both directions of a
    dead physical link) and ``changed_ranks`` (every incident circuit dies)
    describe the mutation.  The fault model degrades the initial and
    standard topologies only — round ideal graphs are what the switch
    *programs*, so they regenerate from the schedule unchanged.

    Column-level reuse: degraded states keep their position in the state
    set, so (dilation, congestion, feasibility) columns of states whose
    edge set did not change are copied from ``structure`` and only the
    degraded states — typically 2–3 of hundreds — are re-routed (round
    deduplication and the ``STRUCTURE_TABLE`` still apply).  The transition
    table rebuild is one memoized vectorized pass.  The result is
    bit-identical — same steps, same totals, same tie-breaks — to cold

        plan(degrade(g0), [degrade(s) for s in standard], schedule, hw)

    which is also the fallback whenever column reuse is unsound (no
    ``structure`` supplied, degradation merged two states into one edge
    set, or the supplied structure had already pruned states).  Returns
    ``(plan, structure)`` for the degraded fabric so sessions can cache the
    new structure for subsequent warm paths.
    """
    failed_e = frozenset(changed_edges)
    failed_r = frozenset(changed_ranks)
    d_g0 = degrade_topology(g0, failed_e, failed_r)
    d_std = [degrade_topology(s, failed_e, failed_r) for s in standard]

    def cold() -> Tuple[Plan, PlanStructure]:
        s2 = build_structure(d_g0, d_std, schedule, hw)
        return _plans_from_structure(s2, [schedule], hw)[0], s2

    if len(schedule.rounds) == 0:
        s2 = build_structure(d_g0, d_std, schedule, hw)
        return Plan(schedule, hw, (), 0.0, final_topology=d_g0), s2
    if structure is None:
        return cold()
    _check_structure(structure, g0, standard, schedule, hw)
    if structure.pruned_standard:
        # the old columns do not cover the pruned states; start clean
        return cold()

    # Reconstruct the degraded state set from the old one without replaying
    # ``build_states`` over every round: the fault model touches only the
    # fabric-derived states (G0 + standards, the ones flagged ``standard``),
    # ideal-graph states regenerate from the schedule unchanged.  The
    # rebuild-from-scratch path remains for the cases positional reuse
    # cannot express: a degraded fabric state colliding with another
    # state's edge set (merge), or a state that doubles as a round's ideal
    # graph changing shape (it would split in a cold build).
    old = structure.states
    fresh: Optional[List[TopoState]] = None
    degraded: Dict[int, Topology] = {}
    # positional reuse is only sound when the old fabric states are exactly
    # the caller's {G0} ∪ standard (the documented contract; anything else
    # goes through the rebuild-and-compare path below)
    split = {o.topo.edges for o in old if o.standard} != (
        {g0.edges} | {s.edges for s in standard}
    )
    for o in old:
        if split:
            break
        if not o.standard:
            continue
        d_topo = degrade_topology(o.topo, failed_e, failed_r)
        if d_topo.edges == o.topo.edges:
            continue
        if o.entry_rounds:
            split = True
            break
        degraded[o.idx] = d_topo
    if not split:
        edge_sets = [
            degraded[o.idx].edges if o.idx in degraded else o.topo.edges
            for o in old
        ]
        if len(set(edge_sets)) == len(edge_sets):
            fresh = [
                replace(o, topo=degraded[o.idx]) if o.idx in degraded else o
                for o in old
            ]
    if fresh is None:
        rebuilt = build_states(d_g0, d_std, schedule)
        if len(rebuilt) != len(old) or any(
            f.standard != o.standard or f.entry_rounds != o.entry_rounds
            for f, o in zip(rebuilt, old)
        ):
            # degradation merged or split states: positional column reuse
            # is no longer sound
            return cold()
        fresh = rebuilt
        changed_idx = [
            f.idx for f, o in zip(fresh, old) if f.topo.edges != o.topo.edges
        ]
    else:
        changed_idx = sorted(degraded)
    if not changed_idx:
        return _plans_from_structure(structure, [schedule], hw)[0], structure

    dil = structure.dilation.copy()
    cong = structure.congestion.copy()
    feas = structure.feasible.copy()
    sub = [replace(fresh[i], idx=j) for j, i in enumerate(changed_idx)]
    sdil, scong, sfeas, _skeys = _round_structures(
        sub, schedule, round_keys=structure.round_keys
    )
    dil[:, changed_idx] = sdil
    cong[:, changed_idx] = scong
    feas[:, changed_idx] = sfeas

    states, dil, cong, feas, pruned = _prune_dead_states(
        fresh, d_g0, dil, cong, feas
    )
    if pruned:
        # pruning reindexed the states; positional reuse of the old
        # transition table is off — rebuild it (memoized, vectorized)
        trans = _transition_costs(states, hw)
    else:
        trans = _transition_costs_update(structure.trans, states, changed_idx, hw)
    enterable = _enterable_mask(states, len(schedule.rounds))
    new_structure = PlanStructure(
        states=tuple(states),
        g0_idx=_g0_state(states, d_g0),
        n_rounds=structure.n_rounds,
        dilation=dil,
        congestion=cong,
        feasible=feas,
        enterable=enterable,
        trans=trans,
        round_keys=structure.round_keys,
        g0_edges=d_g0.edges,
        reconfig_params=(hw.reconfig_delay, hw.reconfig_delay_per_link),
        pruned_standard=pruned,
        schedule=schedule,
    )
    return _plans_from_structure(new_structure, [schedule], hw)[0], new_structure


# ------------------------------------------------------------------ oracles


def _round_costs(
    states: Sequence[TopoState], schedule: Schedule, hw: HardwareParams
) -> Tuple[np.ndarray, Dict[Tuple[int, int], RoundCost]]:
    """(cost, objs): cost[i, s] = CommCost(topo_s, R_i, w_i) (Algorithm 2)
    and objs[(i, s)] the full RoundCost decomposition.

    Structurally identical rounds at the same payload size — keyed by
    ``(pair multiset, size)`` — share one row of costs and one set of
    RoundCost objects, so e.g. a ring schedule's n−1 identical rounds are
    priced once even outside ``plan_sweep``."""
    n_rounds = len(schedule.rounds)
    cost = np.empty((n_rounds, len(states)))
    cost_objs: Dict[Tuple[int, int], RoundCost] = {}
    first_row: Dict[Tuple, int] = {}
    for i, rnd in enumerate(schedule.rounds):
        key = (round_structure_key(pairs_of(rnd)), rnd.size)
        j = first_row.get(key)
        if j is not None:
            cost[i] = cost[j]
            for s in states:
                cost_objs[(i, s.idx)] = cost_objs[(j, s.idx)]
            continue
        first_row[key] = i
        for s in states:
            rc = comm_cost_round(s.topo, rnd, None, hw)
            cost[i, s.idx] = rc.total
            cost_objs[(i, s.idx)] = rc
    return cost, cost_objs


def plan_bruteforce(
    g0: Topology,
    standard: Sequence[Topology],
    schedule: Schedule,
    hw: HardwareParams,
) -> float:
    """Exhaustive minimum over all feasible topology assignments (tests only)."""
    states = build_states(g0, standard, schedule)
    n_rounds = len(schedule.rounds)
    cost, _ = _round_costs(states, schedule, hw)
    g0_idx = _g0_state(states, g0)
    trans = _transition_costs(states, hw)
    effs = [_effective_transition(trans, cost, i, hw) for i in range(n_rounds)]
    best = [float("inf")]

    def feasible(prev: int, s: TopoState, i: int) -> bool:
        return s.enterable_at(i) or s.idx == prev

    def dfs(i: int, prev: int, acc: float) -> None:
        if acc >= best[0]:
            return
        if i == n_rounds:
            best[0] = acc
            return
        for s in states:
            if not feasible(prev, s, i):
                continue
            step = cost[i, s.idx] + effs[i][prev, s.idx]
            dfs(i + 1, s.idx, acc + step)

    dfs(0, g0_idx, 0.0)
    return best[0]


def plan_milp(
    g0: Topology,
    standard: Sequence[Topology],
    schedule: Schedule,
    hw: HardwareParams,
) -> float:
    """The paper's ILP (Eqs. 2–7) via scipy HiGHS, for cross-validation.

    Variables: t_{i,j} ∈ {0,1} for each round i and state j, plus — because
    partial/overlapped reconfiguration makes the transition cost depend on
    the *pair* of consecutive topologies, not just "changed or not" — flow
    variables y_{i,p,s} ≥ 0 linearizing t_{i-1,p} ∧ t_{i,s}:

        Σ_s y_{i,p,s} = t_{i-1,p}   ∀ i ≥ 1, p
        Σ_p y_{i,p,s} = t_{i,s}     ∀ i ≥ 1, s

    With binary t each round's y is a one-unit transportation problem whose
    only feasible point is the indicator of the chosen (p, s) pair, so the
    continuous relaxation of y is exact.  Objective:
    Σ t_{i,j}·CommCost + Σ y_{i,p,s}·T_i(p, s), with the round-0 transition
    out of G0 folded into the t_{0,j} coefficients.  Constraint 5 becomes
    t_{i,j} ≤ t_{i-1,j} for non-standard j outside its entry rounds.
    """
    from scipy.optimize import LinearConstraint, milp
    from scipy.sparse import lil_matrix

    states = build_states(g0, standard, schedule)
    n_rounds = len(schedule.rounds)
    ns = len(states)
    cost, _ = _round_costs(states, schedule, hw)
    g0_idx = _g0_state(states, g0)
    trans = _transition_costs(states, hw)

    # variable layout: t vars [0, n_rounds*ns), y vars afterwards
    nt = n_rounds * ns
    nv = nt + max(0, n_rounds - 1) * ns * ns

    def t(i: int, j: int) -> int:
        return i * ns + j

    def y(i: int, p: int, s: int) -> int:  # i >= 1
        return nt + (i - 1) * ns * ns + p * ns + s

    c = np.zeros(nv)
    for i in range(n_rounds):
        for j in range(ns):
            c[t(i, j)] = cost[i, j]
    for j in range(ns):
        c[t(0, j)] += trans[g0_idx, j]
    for i in range(1, n_rounds):
        eff = _effective_transition(trans, cost, i, hw)
        for p in range(ns):
            for s in range(ns):
                c[y(i, p, s)] = eff[p, s]

    rows: List[Tuple[Dict[int, float], float, float]] = []  # (coeffs, lb, ub)

    # Eq. 4: exactly one topology per round
    for i in range(n_rounds):
        rows.append(({t(i, j): 1.0 for j in range(ns)}, 1.0, 1.0))

    # transition-flow consistency
    for i in range(1, n_rounds):
        for p in range(ns):
            coeffs = {y(i, p, s): 1.0 for s in range(ns)}
            coeffs[t(i - 1, p)] = -1.0
            rows.append((coeffs, 0.0, 0.0))
        for s in range(ns):
            coeffs = {y(i, p, s): 1.0 for p in range(ns)}
            coeffs[t(i, s)] = -1.0
            rows.append((coeffs, 0.0, 0.0))

    # Eq. 5 (carry-only for ideal states outside entry rounds)
    for j, s in enumerate(states):
        if s.standard:
            continue
        for i in range(n_rounds):
            if i in s.entry_rounds:
                continue
            if i == 0:
                rows.append(({t(0, j): 1.0}, 0.0, 0.0))
            else:
                rows.append(({t(i, j): 1.0, t(i - 1, j): -1.0}, -np.inf, 0.0))

    A = lil_matrix((len(rows), nv))
    lb = np.empty(len(rows))
    ub = np.empty(len(rows))
    for k, (coeffs, lo, hi) in enumerate(rows):
        for var, coef in coeffs.items():
            A[k, var] = coef
        lb[k] = lo
        ub[k] = hi

    integrality = np.zeros(nv)
    integrality[:nt] = 1.0
    res = milp(
        c=c,
        constraints=LinearConstraint(A.tocsr(), lb, ub),
        integrality=integrality,
        bounds=(0, 1),
    )
    if not res.success:
        raise RuntimeError(f"MILP failed: {res.message}")
    return float(res.fun)


# ------------------------------------------------------- concurrent groups
#
# Real training steps keep several collectives in flight at once — TP
# all-reduce, DP reduce-scatter, PP sends — all sharing one photonic fabric,
# while Algorithm 1 prices each as if it owned every link.  The arbiter below
# plans them *jointly*: each group keeps its own Algorithm-1 state machine
# (its PlanStructure), but a joint round's circuits are the union of the
# per-group allocations, and traffic is priced per *link*:
#
#   comm_i = max_g [ α·D_g(i, s_g) + β·max_{e ∈ routes_g} Σ_h w_h·load_h(e) ]
#
# i.e. every group's round runs at the speed of its most-contended link,
# counting every group's bytes on that link, and the joint round finishes
# when the slowest group does.  With link-disjoint allocations this
# degenerates to max_g (α·D_g + β·C_g·w_g) — the groups genuinely overlap —
# and shared links surface as priced congestion, the same
# α·dilation + β·congestion·w arithmetic as Algorithm 2.  Reconfiguration is
# charged on changes of the *union* edge set (a circuit some group already
# holds is free for a group that needs it next round); finished groups'
# circuits persist, so they never bill later transitions.
#
# Solvers, same discipline as the single-group planner:
#   * `plan_concurrent`  — greedy + refinement: seed every group with its
#     independent (solo) plan, then iterate exact per-group best-response
#     DPs (others fixed) until the joint cost stops improving; several
#     deterministic seeds, best taken.
#   * `plan_concurrent_exact` — exact DP over the product state space
#     (oracle for n ≤ 8 tests).
# The sequential-independent baseline (each group solo-planned cold from
# G0, costs summed — exactly what a per-collective planner bills a step)
# bounds the result: `total_cost = min(joint, sequential)`, so joint
# planning never prices worse than sequential independent planning.

_EMPTY_LOADS = (np.zeros(0, dtype=np.int64), np.zeros(0))
_MISS = object()


@dataclass(frozen=True)
class ConcurrentGroupPlan:
    """One group's slice of a :class:`ConcurrentPlan`."""

    schedule: Schedule
    solo: Plan                      # planned as if the group owned the fabric
    states: Tuple[int, ...]         # chosen state per joint round (carried
                                    # unchanged past the group's last round)
    state_names: Tuple[str, ...]


@dataclass(frozen=True)
class ConcurrentPlan:
    """Joint plan for several collectives sharing one fabric."""

    hw: HardwareParams
    groups: Tuple[ConcurrentGroupPlan, ...]
    n_rounds: int                   # joint horizon = max group offset+rounds
    joint_cost: float               # converged aligned-overlap cost
    sequential_cost: float          # Σ solo costs (time-multiplexed fabric)
    serialized: bool                # joint overlap did not pay; run back-to-back
    comm_cost: float                # joint decomposition (aligned candidate)
    reconfig_cost: float
    final_topology: Optional[Topology] = None
    # per-group arrival-round offsets (empty = every group starts at round 0);
    # the invariant checker replays the joint cost with these
    offsets: Tuple[int, ...] = ()

    @property
    def total_cost(self) -> float:
        """What the arbiter charges, bounded by the sequential baseline —
        the sum of cold fabric-to-itself solo plans, i.e. what today's
        per-collective planner bills a step.  (A real back-to-back run
        would start each group from its predecessor's final topology; the
        baseline deliberately prices the *independent-planning* model the
        arbiter is competing against, not that threaded execution.)"""
        return self.sequential_cost if self.serialized else self.joint_cost

    @property
    def speedup(self) -> float:
        """Planned-cost improvement over sequential independent planning."""
        return self.sequential_cost / self.total_cost if self.total_cost > 0 else 1.0


class _JointState:
    """Shared arrays for joint evaluation and best-response over one
    concurrent instance: the directed-edge universe of every group's states,
    per-group incidence/dilation/feasibility matrices padded to the joint
    horizon, and a memo of per-(group, round, state) link loads.

    ``offsets`` gives each group an *arrival round*: group ``g``'s schedule
    round ``i`` executes at joint round ``i + offsets[g]``, so staggered
    admissions (a serving arbiter admitting requests mid-step) are not
    forced into round-0 alignment.  Before its offset a group moves no
    traffic; it holds ``G0`` by default but may *pre-position* into any
    state enterable at its round 0 (paying the union reconfiguration then —
    which overlapped reconfiguration can hide behind the other groups'
    in-flight rounds, exactly the reconfigure-during-idle-gaps win)."""

    def __init__(
        self,
        g0: Topology,
        structures: Sequence[PlanStructure],
        schedules: Sequence[Schedule],
        hw: HardwareParams,
        *,
        offsets: Optional[Sequence[int]] = None,
    ) -> None:
        self.hw = hw
        self.structures = list(structures)
        self.schedules = list(schedules)
        self.G = len(structures)
        self.rounds_g = [len(sch.rounds) for sch in schedules]
        if offsets is None:
            self.offsets: Tuple[int, ...] = (0,) * self.G
        else:
            self.offsets = tuple(int(o) for o in offsets)
            if len(self.offsets) != self.G:
                raise ValueError(
                    f"got {len(self.offsets)} offsets for {self.G} schedules"
                )
            if any(o < 0 for o in self.offsets):
                raise ValueError(f"offsets must be >= 0, got {self.offsets}")
        self.R = max(o + r for o, r in zip(self.offsets, self.rounds_g))
        universe = set(g0.edges)
        for st in self.structures:
            for s in st.states:
                universe |= s.topo.edges
        self.edges = sorted(universe)
        self.E = max(len(self.edges), 1)
        self._eidx = {e: k for k, e in enumerate(self.edges)}
        self.g0_vec = np.zeros(self.E, dtype=bool)
        for e in g0.edges:
            self.g0_vec[self._eidx[e]] = True

        self.inc: List[np.ndarray] = []      # (ns, E) bool
        self.dil: List[np.ndarray] = []      # (R, ns), padded 0
        self.feas: List[np.ndarray] = []     # (R, ns) bool, padded True
        self.enter: List[np.ndarray] = []    # (R, ns) bool, padded False
        self.sizes: List[np.ndarray] = []    # (R,) bytes/transfer, padded 0
        self.pairs: List[List[List[Tuple[int, int]]]] = []
        self.pair_keys: List[List] = []
        for st, sch, rg, off in zip(
            self.structures, self.schedules, self.rounds_g, self.offsets
        ):
            ns = len(st.states)
            inc = np.zeros((ns, self.E), dtype=bool)
            for s in st.states:
                for e in s.topo.edges:
                    inc[s.idx, self._eidx[e]] = True
            self.inc.append(inc)
            dil = np.zeros((self.R, ns))
            dil[off:off + rg] = st.dilation
            feas = np.ones((self.R, ns), dtype=bool)
            feas[off:off + rg] = st.feasible
            ent = np.zeros((self.R, ns), dtype=bool)
            ent[off:off + rg] = st.enterable
            if off:
                # idle prefix: pre-position into anything round 0 may enter
                ent[:off] = st.enterable[0]
            self.dil.append(dil)
            self.feas.append(feas)
            self.enter.append(ent)
            sz = np.zeros(self.R)
            prs: List[List[Tuple[int, int]]] = []
            keys: List = []
            for i in range(self.R):
                j = i - off
                if 0 <= j < rg:
                    prs.append(pairs_of(sch.rounds[j]))
                    keys.append(st.round_keys[j])
                    sz[i] = sch.rounds[j].size
                else:
                    prs.append([])
                    keys.append(None)
            self.sizes.append(sz)
            self.pairs.append(prs)
            self.pair_keys.append(keys)
        self._loads: Dict[Tuple, Optional[Tuple[np.ndarray, np.ndarray]]] = {}
        self._scratch = np.zeros(self.E)

    # ---------------------------------------------------------- link loads
    def loads(self, g: int, i: int, s: int):
        """(edge-index array, count array) of group ``g``'s round-``i``
        transfers routed on state ``s``'s topology — each group's traffic is
        confined to its own allocation.  ``None`` when unroutable; empty
        arrays for empty rounds and joint rounds outside the group's
        ``[offset, offset + rounds)`` window."""
        if not self.pairs[g][i]:
            return _EMPTY_LOADS
        key = (g, self.pair_keys[g][i], s)
        hit = self._loads.get(key, _MISS)
        if hit is not _MISS:
            return hit
        res = edge_loads(
            self.structures[g].states[s].topo,
            self.pairs[g][i],
            key=self.pair_keys[g][i],
        )
        out: Optional[Tuple[np.ndarray, np.ndarray]] = None
        if res is not None:
            _, pairs_counts = res
            idx = np.asarray([self._eidx[e] for e, _ in pairs_counts], dtype=np.int64)
            cnt = np.asarray([c for _, c in pairs_counts], dtype=np.float64)
            out = (idx, cnt)
        self._loads[key] = out
        return out

    # --------------------------------------------------------- joint costs
    def comm_of(self, states_at_i: Sequence[int], i: int) -> float:
        """Joint communication time of round ``i`` with each group ``g`` on
        state ``states_at_i[g]`` (see the model comment above)."""
        hw = self.hw
        scratch = self._scratch
        active: List[Tuple[int, np.ndarray]] = []
        try:
            for g in range(self.G):
                ld = self.loads(g, i, states_at_i[g])
                if ld is None:
                    return LARGE_PENALTY
                idx, cnt = ld
                if idx.shape[0] == 0:
                    continue
                scratch[idx] += self.sizes[g][i] * cnt
                active.append((g, idx))
            worst = 0.0
            for g, idx in active:
                rc = hw.alpha * float(self.dil[g][i, states_at_i[g]]) + \
                    hw.beta * float(scratch[idx].max())
                if rc > worst:
                    worst = rc
            return worst
        finally:
            for _, idx in active:
                scratch[idx] = 0.0

    def union_vec(self, states_at_i: Sequence[int]) -> np.ndarray:
        u = np.zeros(self.E, dtype=bool)
        for g in range(self.G):
            u |= self.inc[g][states_at_i[g]]
        return u

    def _price_scalar(self, changed: int) -> float:
        if changed == 0:
            return 0.0
        if self.hw.reconfig_delay_per_link is None:
            return self.hw.reconfig_delay
        return min(self.hw.reconfig_delay,
                   self.hw.reconfig_delay_per_link * changed)

    def _price_vec(self, changed: np.ndarray) -> np.ndarray:
        if self.hw.reconfig_delay_per_link is None:
            return np.where(changed > 0.5, self.hw.reconfig_delay, 0.0)
        return np.minimum(self.hw.reconfig_delay,
                          self.hw.reconfig_delay_per_link * changed)

    def evaluate(self, seqs: Sequence[Sequence[int]]):
        """(total, per-round comm, per-round reconfig, final union vector)
        of a full assignment — the single source of joint-cost arithmetic
        (solvers re-evaluate through here, so candidates compare exactly)."""
        comm = [
            self.comm_of([seqs[g][i] for g in range(self.G)], i)
            for i in range(self.R)
        ]
        prev = self.g0_vec
        reconf: List[float] = []
        for i in range(self.R):
            u = self.union_vec([seqs[g][i] for g in range(self.G)])
            t = self._price_scalar(int(np.count_nonzero(prev ^ u)))
            if self.hw.overlap and i > 0:
                t = max(0.0, t - comm[i - 1])
            reconf.append(t)
            prev = u
        return float(sum(comm) + sum(reconf)), comm, reconf, prev

    # ------------------------------------------------------- best response
    def best_response(
        self, seqs: Sequence[Sequence[int]], g: int, others: Sequence[int]
    ) -> Tuple[int, ...]:
        """Exact DP for group ``g`` with the groups in ``others`` frozen at
        their ``seqs`` assignments (groups outside ``others`` are absent).

        Per-round costs are the *joint* comm times (g's state slows other
        groups whose links it shares, and vice versa), and transitions price
        changes of the union edge set — so the DP minimizes exactly the
        quantity :meth:`evaluate` reports, restricted to ``g``'s coordinate.
        """
        hw = self.hw
        st = self.structures[g]
        ns = len(st.states)
        R, E = self.R, self.E

        o_vec = np.zeros((R, E), dtype=bool)
        o_bytes = np.zeros((R, E))
        o_terms: List[List[Tuple[float, np.ndarray]]] = []
        for i in range(R):
            terms: List[Tuple[float, np.ndarray]] = []
            for h in others:
                s_h = seqs[h][i]
                o_vec[i] |= self.inc[h][s_h]
                ld = self.loads(h, i, s_h)
                if ld is None or ld[0].shape[0] == 0:
                    continue
                idx, cnt = ld
                o_bytes[i, idx] += self.sizes[h][i] * cnt
                terms.append((hw.alpha * float(self.dil[h][i, s_h]), idx))
            o_terms.append(terms)

        # joint comm cost of round i per candidate state of g, others fixed
        cost = np.full((R, ns), LARGE_PENALTY)
        for i in range(R):
            if not self.pairs[g][i]:
                base = 0.0
                for a_dh, idx_h in o_terms[i]:
                    rc = a_dh + hw.beta * float(o_bytes[i, idx_h].max())
                    if rc > base:
                        base = rc
                cost[i, :] = base
                continue
            w = self.sizes[g][i]
            for s in range(ns):
                if not self.feas[g][i, s]:
                    continue
                ld = self.loads(g, i, s)
                if ld is None:
                    continue
                idx, cnt = ld
                scratch = o_bytes[i].copy()
                scratch[idx] += w * cnt
                rc = hw.alpha * float(self.dil[g][i, s]) + \
                    hw.beta * float(scratch[idx].max())
                for a_dh, idx_h in o_terms[i]:
                    rc_h = a_dh + hw.beta * float(scratch[idx_h].max())
                    if rc_h > rc:
                        rc = rc_h
                cost[i, s] = rc

        # DP with per-round transition tables (the union edge set seen from
        # g's side changes with the others' rounds); same vectorization and
        # tie-breaking as _plans_from_structure
        idxs = np.arange(ns)
        INF = float("inf")
        f = np.full((R, ns), INF)
        parent = np.full((R, ns), -1, dtype=np.int64)
        B_prev: Optional[np.ndarray] = None
        for i in range(R):
            B = self.inc[g] | o_vec[i][None, :]
            Bf = B.astype(np.float64)
            Bsz = Bf.sum(axis=1)
            if i == 0:
                g0f = self.g0_vec.astype(np.float64)
                changed = g0f.sum() + Bsz - 2.0 * (Bf @ g0f)
                T = self._price_vec(changed)
                enter0 = self.enter[g][0] | (idxs == st.g0_idx)
                f[0, enter0] = cost[0, enter0] + T[enter0]
                parent[0, enter0] = st.g0_idx
            else:
                Af = B_prev.astype(np.float64)
                Asz = Af.sum(axis=1)
                changed = Asz[:, None] + Bsz[None, :] - 2.0 * (Af @ Bf.T)
                T = self._price_vec(changed)
                if hw.overlap:
                    eff = np.maximum(0.0, T - cost[i - 1][:, None])
                else:
                    eff = T
                cand = f[i - 1][:, None] + eff
                best_p = cand.argmin(axis=0)
                best = cand[best_p, idxs]
                stay = cand[idxs, idxs]
                prefer_stay = stay <= best
                best = np.where(prefer_stay, stay, best)
                best_p = np.where(prefer_stay, idxs, best_p)
                ent = self.enter[g][i]
                f[i, ent] = best[ent] + cost[i, ent]
                parent[i, ent] = best_p[ent]
                carry = ~ent
                if carry.any():
                    # staying put is not free here: the union still changes
                    # when *other* groups reconfigure around g's held edges,
                    # so the carried state pays the diagonal transition —
                    # exactly what evaluate() charges
                    diag = np.diagonal(eff)
                    fin = np.isfinite(f[i - 1, carry])
                    f[i, carry] = np.where(
                        fin,
                        f[i - 1, carry] + diag[carry] + cost[i, carry],
                        INF,
                    )
                    parent[i, carry] = np.where(fin, idxs[carry], -1)
            B_prev = B
        last = int(f[R - 1].argmin())
        seq = [last]
        for i in range(R - 1, 0, -1):
            seq.append(int(parent[i, seq[-1]]))
        seq.reverse()
        return tuple(seq)


def _concurrent_structures(
    g0: Topology,
    standard: Sequence[Topology],
    schedules: Sequence[Schedule],
    hw: HardwareParams,
    structures: Optional[Sequence[PlanStructure]],
) -> List[PlanStructure]:
    for sch in schedules:
        if sch.n != g0.n:
            raise ValueError(
                f"schedule {sch.algorithm} spans n={sch.n} ranks but the "
                f"fabric has n={g0.n}"
            )
        if not sch.rounds:
            raise ValueError("plan_concurrent needs non-empty schedules")
    if structures is None:
        return [build_structure(g0, standard, sch, hw) for sch in schedules]
    structures = list(structures)
    if len(structures) != len(schedules):
        raise ValueError(
            f"got {len(structures)} structures for {len(schedules)} schedules"
        )
    for st, sch in zip(structures, schedules):
        _check_structure(st, g0, standard, sch, hw)
    return structures


def plan_concurrent(
    g0: Topology,
    standard: Sequence[Topology],
    schedules: Sequence[Schedule],
    hw: HardwareParams,
    *,
    structures: Optional[Sequence[PlanStructure]] = None,
    solo_plans: Optional[Sequence[Plan]] = None,
    refine_passes: int = 6,
    offsets: Optional[Sequence[int]] = None,
) -> ConcurrentPlan:
    """Jointly plan several concurrently-active collectives on one fabric.

    Each schedule is a full-domain composition of one process-group set's
    collective (``schedules.replicate_groups``); rounds are aligned by index
    (bulk-synchronous steps) and groups that finish early leave their
    circuits in place.  See the module comment above for the cost model.

    The solver seeds every group with its independent Algorithm-1 plan,
    adds greedy staggered seeds (each group best-responding to the groups
    placed before it, in both orders), refines each seed by round-robin
    exact best-response DPs until the joint cost stops improving, and keeps
    the cheapest.  Everything is deterministic — same inputs, same plan,
    bit-for-bit.  ``total_cost`` is bounded by the sequential-independent
    baseline (``sequential_cost``: each group solo-planned cold from ``G0``,
    costs summed — the independent-planning model, not a threaded
    back-to-back execution), so the arbiter never prices worse than
    sequential independent planning; ``serialized`` says the bound was the
    better choice (it can be, e.g., under overlapped reconfiguration, where
    serial execution hides reprogramming better than sharing does).

    ``offsets`` staggers arrivals: group ``g``'s round ``i`` executes at
    joint round ``i + offsets[g]`` (see :class:`_JointState`) — the online
    arbiter's admission path, where a prefill collective admitted mid-step
    joins decode rounds already in flight instead of forcing round-0
    alignment.
    """
    schedules = list(schedules)
    if not schedules:
        raise ValueError("plan_concurrent needs at least one schedule")
    structures = _concurrent_structures(g0, standard, schedules, hw, structures)
    if solo_plans is not None:
        # a caller that already solo-planned each group during algorithm
        # arbitration (pccl.plan_concurrent_collectives) passes the plans in
        # so the O(rounds·ns²) numeric phase is not re-run per group
        solo = list(solo_plans)
        if len(solo) != len(schedules):
            raise ValueError(
                f"got {len(solo)} solo plans for {len(schedules)} schedules"
            )
    else:
        solo = [
            _plans_from_structure(st, [sch], hw)[0]
            for st, sch in zip(structures, schedules)
        ]
    sequential_cost = float(sum(p.total_cost for p in solo))

    ev = _JointState(g0, structures, schedules, hw, offsets=offsets)
    R, G = ev.R, ev.G

    def padded(plan: Plan, g: int) -> Tuple[int, ...]:
        # idle prefix holds G0 (the solo seed never pre-positions; the
        # best-response refinement may), then the solo states, then the
        # final state carried to the joint horizon
        seq = [structures[g].g0_idx] * ev.offsets[g]
        seq += [s.state_idx for s in plan.steps]
        seq += [seq[-1]] * (R - len(seq))
        return tuple(seq)

    def refine(seqs: List[Tuple[int, ...]]):
        total = ev.evaluate(seqs)[0]
        for _ in range(max(refine_passes, 0)):
            improved = False
            for g in range(G):
                trial = list(seqs)
                trial[g] = ev.best_response(
                    seqs, g, [h for h in range(G) if h != g]
                )
                t = ev.evaluate(trial)[0]
                if t < total:
                    seqs, total = trial, t
                    improved = True
            if not improved:
                break
        return seqs, total

    base = [padded(p, g) for g, p in enumerate(solo)]
    candidates = [refine(list(base))]
    if G > 1:
        # staggered greedy seeds: grant the fabric in priority order, each
        # group best-responding to those already placed
        for order in (list(range(G)), list(reversed(range(G)))):
            seqs = list(base)
            placed: List[int] = []
            for g in order:
                seqs[g] = ev.best_response(seqs, g, placed)
                placed.append(g)
            candidates.append(refine(seqs))
    best_seqs, _ = min(candidates, key=lambda c: c[1])
    joint_cost, comm, reconf, final_vec = ev.evaluate(best_seqs)
    serialized = joint_cost > sequential_cost
    if serialized:
        final_topo = solo[-1].final_topology
    else:
        final_edges = frozenset(
            e for e, on in zip(ev.edges, final_vec.tolist()) if on
        )
        final_topo = Topology(g0.n, final_edges, name="concurrent_final")
    groups = tuple(
        ConcurrentGroupPlan(
            schedule=sch,
            solo=sp,
            states=tuple(best_seqs[g]),
            state_names=tuple(
                structures[g].states[s].topo.name for s in best_seqs[g]
            ),
        )
        for g, (sch, sp) in enumerate(zip(schedules, solo))
    )
    return ConcurrentPlan(
        hw=hw,
        groups=groups,
        n_rounds=R,
        joint_cost=float(joint_cost),
        sequential_cost=sequential_cost,
        serialized=serialized,
        comm_cost=float(sum(comm)),
        reconfig_cost=float(sum(reconf)),
        final_topology=final_topo,
        # all-zero staggering IS round-0 alignment: normalize to the empty
        # tuple so aligned plans compare equal however the caller spelled it
        offsets=ev.offsets if any(ev.offsets) else (),
    )


def plan_concurrent_exact(
    g0: Topology,
    standard: Sequence[Topology],
    schedules: Sequence[Schedule],
    hw: HardwareParams,
    *,
    structures: Optional[Sequence[PlanStructure]] = None,
    max_product_states: int = 4096,
    offsets: Optional[Sequence[int]] = None,
) -> float:
    """Exact joint DP over the product state space (oracle for n ≤ 8 tests).

    Returns the optimal *aligned* joint cost — the quantity
    ``plan_concurrent(...).joint_cost`` approximates; the serialized
    fallback is deliberately outside its search space.  ``offsets`` carries
    the same arrival-round semantics as :func:`plan_concurrent`."""
    import itertools

    schedules = list(schedules)
    if not schedules:
        raise ValueError("plan_concurrent_exact needs at least one schedule")
    structures = _concurrent_structures(g0, standard, schedules, hw, structures)
    ev = _JointState(g0, structures, schedules, hw, offsets=offsets)
    G, R = ev.G, ev.R
    ns_list = [len(st.states) for st in structures]
    n_prod = 1
    for k in ns_list:
        n_prod *= k
    if n_prod > max_product_states:
        raise ValueError(
            f"product state space {n_prod} exceeds {max_product_states}; "
            "the exact solver is an oracle for small instances"
        )
    prod = list(itertools.product(*[range(k) for k in ns_list]))
    unions = {tup: ev.union_vec(tup) for tup in prod}
    comm_memo: Dict[Tuple[int, Tuple[int, ...]], float] = {}

    def comm(tup: Tuple[int, ...], i: int) -> float:
        key = (i, tup)
        v = comm_memo.get(key)
        if v is None:
            v = ev.comm_of(tup, i)
            comm_memo[key] = v
        return v

    f: Dict[Tuple[int, ...], float] = {}
    for tup in prod:
        if all(
            ev.enter[g][0][s] or s == structures[g].g0_idx
            for g, s in enumerate(tup)
        ):
            changed = int(np.count_nonzero(ev.g0_vec ^ unions[tup]))
            f[tup] = ev._price_scalar(changed) + comm(tup, 0)
    for i in range(1, R):
        nf: Dict[Tuple[int, ...], float] = {}
        for ptup, pv in f.items():
            pc = comm(ptup, i - 1)
            pu = unions[ptup]
            for stup in prod:
                if not all(
                    ev.enter[g][i][s] or s == ptup[g]
                    for g, s in enumerate(stup)
                ):
                    continue
                t = ev._price_scalar(int(np.count_nonzero(pu ^ unions[stup])))
                if hw.overlap:
                    t = max(0.0, t - pc)
                v = pv + t + comm(stup, i)
                old = nf.get(stup)
                if old is None or v < old:
                    nf[stup] = v
        f = nf
    return float(min(f.values()))
