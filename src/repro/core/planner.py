"""PCCL's reconfiguration planner (paper Algorithm 1).

The paper formulates "when to reconfigure" as an ILP over binary ``t_{i,j}``
(round *i* uses topology *j*) with

* one-topology-per-round (Eq. 4),
* contiguous use of round-derived ideal topologies (Eq. 5: an ideal graph can
  only be *entered* in the round that generates it, then carried forward), and
* reconfiguration cost paid on a topology change between consecutive rounds
  (Eq. 7), with per-round cost = CommCost (Algorithm 2) + ReconfCost.

That constraint structure is a shortest path over a tiny layered graph, so the
primary solver here is an **exact dynamic program** (`plan`):

    f(i, s) = CommCost(topo(s), R_i, w_i)
              + min over admissible predecessors p of [ f(i-1, p) + T_i(p, s) ]

where the state space is the edge-set-deduplicated union of {G0} ∪ S ∪
{ideal(R_k)}.  Deduplication matters for fidelity: e.g. every round of a ring
schedule has the *same* ideal graph, so staying on it must not re-pay ``r``
(paper Eq. 7 charges only on change).

The transition cost ``T_i(p, s)`` generalizes the paper's ``r·1[p≠s]``
(``cost_model.reconfig_cost``):

* serial (default): the full fabric delay ``r`` on any change — the paper's
  pessimistic model, bit-identical to the original planner;
* partial (``hw.reconfig_delay_per_link``): ``r_link`` per changed directed
  circuit, capped at ``r`` — only the links that differ are reprogrammed;
* overlapped (``hw.overlap``): ``max(0, ReconfCost(p, s) − CommCost_{i−1}(p))``
  for ``i ≥ 1`` — round *i*'s reprogramming is hidden behind round *i−1*'s
  communication (SWOT-style overlap).  The reconfiguration out of ``G0``
  (round 0) has nothing to hide behind and is always paid in full.

Cross-checks (used in tests):
* `plan_bruteforce` — exhaustive enumeration of all feasible assignments.
* `plan_milp` — the paper's ILP (with pairwise transition variables when
  costs are non-uniform), via scipy HiGHS.

All three agree in every reconfiguration mode; the DP runs in
O(rounds · states²) and plans the largest scale-up domains in well under the
paper's one-second budget (§4.1).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, FrozenSet, List, Optional, Sequence, Tuple

import numpy as np

from .cost_model import HardwareParams, RoundCost, comm_cost_round, reconfig_cost
from .schedules import Round, Schedule
from .topology import Edge, Topology, from_transfers


@dataclass(frozen=True)
class TopoState:
    """One deduplicated candidate topology for the DP/ILP."""

    idx: int
    topo: Topology
    standard: bool                       # in {G0} ∪ S: enterable at any round
    entry_rounds: FrozenSet[int]         # rounds whose ideal graph this is

    def enterable_at(self, i: int) -> bool:
        return self.standard or i in self.entry_rounds


@dataclass(frozen=True)
class PlanStep:
    round_index: int
    state_idx: int
    topo_name: str
    reconfigured: bool
    cost: RoundCost
    reconfig_cost: float

    @property
    def total(self) -> float:
        return self.cost.total + self.reconfig_cost


@dataclass(frozen=True)
class Plan:
    schedule: Schedule
    hw: HardwareParams
    steps: Tuple[PlanStep, ...]
    total_cost: float
    # Topology the fabric is left in after the last round (G0 for empty
    # schedules).  Sessions thread this into the next plan's G0 so
    # back-to-back collectives don't re-pay reconfigurations (api.session).
    final_topology: Optional[Topology] = None

    @property
    def num_reconfigs(self) -> int:
        return sum(1 for s in self.steps if s.reconfigured)

    def breakdown(self) -> Dict[str, float]:
        return {
            "alpha": sum(s.cost.alpha_base for s in self.steps),
            "beta": sum(s.cost.beta_base for s in self.steps),
            "dilation": sum(s.cost.dilation_extra for s in self.steps),
            "congestion": sum(s.cost.congestion_extra for s in self.steps),
            "reconfig": sum(s.reconfig_cost for s in self.steps),
            "total": self.total_cost,
        }


def build_states(
    g0: Topology, standard: Sequence[Topology], schedule: Schedule
) -> List[TopoState]:
    """Dedup {G0} ∪ S ∪ ideal-graphs by directed edge set (input set G of
    Alg. 1 with the bitmap identity of Eq. 7 applied to edge sets)."""
    by_edges: Dict[FrozenSet[Edge], Dict] = {}

    def add(topo: Topology, is_standard: bool, entry_round: Optional[int]) -> None:
        rec = by_edges.setdefault(
            topo.edges, {"topo": topo, "standard": False, "entries": set()}
        )
        rec["standard"] = rec["standard"] or is_standard
        if entry_round is not None:
            rec["entries"].add(entry_round)

    add(g0, True, None)
    for s in standard:
        if s.n != schedule.n:
            raise ValueError(f"standard topology {s.name} has n={s.n} != {schedule.n}")
        add(s, True, None)
    for i, rnd in enumerate(schedule.rounds):
        add(rnd.ideal_topology(schedule.n), False, i)

    states = []
    for k, rec in enumerate(by_edges.values()):
        states.append(
            TopoState(k, rec["topo"], rec["standard"], frozenset(rec["entries"]))
        )
    return states


def _round_costs(
    states: Sequence[TopoState], schedule: Schedule, hw: HardwareParams
) -> Tuple[np.ndarray, Dict[Tuple[int, int], RoundCost]]:
    """(cost, objs): cost[i, s] = CommCost(topo_s, R_i, w_i) (Algorithm 2)
    and objs[(i, s)] the full RoundCost decomposition."""
    n_rounds = len(schedule.rounds)
    cost = np.empty((n_rounds, len(states)))
    cost_objs: Dict[Tuple[int, int], RoundCost] = {}
    for i, rnd in enumerate(schedule.rounds):
        for s in states:
            rc = comm_cost_round(s.topo, rnd, None, hw)
            cost[i, s.idx] = rc.total
            cost_objs[(i, s.idx)] = rc
    return cost, cost_objs


def _transition_costs(states: Sequence[TopoState], hw: HardwareParams) -> np.ndarray:
    """trans[p, s] = ReconfCost(topo_p → topo_s); 0 on the diagonal.

    States are deduplicated by edge set, so every off-diagonal entry is a
    genuine change (serial mode: the constant ``r``, recovering the paper's
    ``r·1[p≠s]``)."""
    ns = len(states)
    trans = np.zeros((ns, ns))
    for p in states:
        for s in states:
            if p.idx != s.idx:
                trans[p.idx, s.idx] = reconfig_cost(p.topo, s.topo, hw)
    return trans


def _effective_transition(
    trans: np.ndarray, cost: np.ndarray, i: int, hw: HardwareParams
) -> np.ndarray:
    """T_i[p, s] for entering round ``i`` on ``s`` from round ``i−1`` on ``p``.

    With overlap enabled, reprogramming round ``i``'s circuits happens while
    round ``i−1`` communicates on ``p``; only the excess is charged.  Round 0
    (``i == 0``) has no previous communication and pays ``trans`` in full.
    """
    if not hw.overlap or i == 0:
        return trans
    return np.maximum(0.0, trans - cost[i - 1][:, None])


def _g0_state(states: Sequence[TopoState], g0: Topology) -> int:
    for s in states:
        if s.topo.edges == g0.edges:
            return s.idx
    raise AssertionError("G0 missing from state set")


def plan(
    g0: Topology,
    standard: Sequence[Topology],
    schedule: Schedule,
    hw: HardwareParams,
) -> Plan:
    """Exact DP solution of Algorithm 1 (any reconfiguration mode)."""
    states = build_states(g0, standard, schedule)
    n_rounds = len(schedule.rounds)
    if n_rounds == 0:
        return Plan(schedule, hw, (), 0.0, final_topology=g0)
    cost, cost_objs = _round_costs(states, schedule, hw)
    g0_idx = _g0_state(states, g0)
    trans = _transition_costs(states, hw)

    INF = float("inf")
    ns = len(states)
    idx = np.arange(ns)
    f = np.full((n_rounds, ns), INF)
    parent = np.full((n_rounds, ns), -1, dtype=np.int64)

    for s in states:
        if s.enterable_at(0) or s.idx == g0_idx:
            f[0, s.idx] = cost[0, s.idx] + trans[g0_idx, s.idx]
            parent[0, s.idx] = g0_idx

    effs = [_effective_transition(trans, cost, i, hw) for i in range(n_rounds)]

    for i in range(1, n_rounds):
        prev = f[i - 1]
        cand = prev[:, None] + effs[i]      # cand[p, s]: arrive at s from p
        best_p = cand.argmin(axis=0)
        best = cand[best_p, idx]
        # staying put (p == s, zero transition) wins ties, matching Eq. 7's
        # charge-only-on-change semantics
        stay = cand[idx, idx]
        prefer_stay = stay <= best
        best = np.where(prefer_stay, stay, best)
        best_p = np.where(prefer_stay, idx, best_p)
        for s in states:
            j = s.idx
            if s.enterable_at(i):
                f[i, j] = best[j] + cost[i, j]
                parent[i, j] = best_p[j]
            elif np.isfinite(prev[j]):
                # Eq. 5: ideal graphs outside their entry round carry only
                f[i, j] = prev[j] + cost[i, j]
                parent[i, j] = j

    last = int(f[n_rounds - 1].argmin())
    total = float(f[n_rounds - 1, last])

    # backtrack
    seq = [last]
    for i in range(n_rounds - 1, 0, -1):
        seq.append(int(parent[i, seq[-1]]))
    seq.reverse()

    steps: List[PlanStep] = []
    prev_idx = g0_idx
    for i, s_idx in enumerate(seq):
        reconf = s_idx != prev_idx
        eff = effs[i]
        steps.append(
            PlanStep(
                round_index=i,
                state_idx=s_idx,
                topo_name=states[s_idx].topo.name,
                reconfigured=reconf,
                cost=cost_objs[(i, s_idx)],
                reconfig_cost=float(eff[prev_idx, s_idx]),
            )
        )
        prev_idx = s_idx
    return Plan(
        schedule, hw, tuple(steps), total, final_topology=states[seq[-1]].topo
    )


# ------------------------------------------------------------------ oracles


def plan_bruteforce(
    g0: Topology,
    standard: Sequence[Topology],
    schedule: Schedule,
    hw: HardwareParams,
) -> float:
    """Exhaustive minimum over all feasible topology assignments (tests only)."""
    states = build_states(g0, standard, schedule)
    n_rounds = len(schedule.rounds)
    cost, _ = _round_costs(states, schedule, hw)
    g0_idx = _g0_state(states, g0)
    trans = _transition_costs(states, hw)
    effs = [_effective_transition(trans, cost, i, hw) for i in range(n_rounds)]
    best = [float("inf")]

    def feasible(prev: int, s: TopoState, i: int) -> bool:
        return s.enterable_at(i) or s.idx == prev

    def dfs(i: int, prev: int, acc: float) -> None:
        if acc >= best[0]:
            return
        if i == n_rounds:
            best[0] = acc
            return
        for s in states:
            if not feasible(prev, s, i):
                continue
            step = cost[i, s.idx] + effs[i][prev, s.idx]
            dfs(i + 1, s.idx, acc + step)

    dfs(0, g0_idx, 0.0)
    return best[0]


def plan_milp(
    g0: Topology,
    standard: Sequence[Topology],
    schedule: Schedule,
    hw: HardwareParams,
) -> float:
    """The paper's ILP (Eqs. 2–7) via scipy HiGHS, for cross-validation.

    Variables: t_{i,j} ∈ {0,1} for each round i and state j, plus — because
    partial/overlapped reconfiguration makes the transition cost depend on
    the *pair* of consecutive topologies, not just "changed or not" — flow
    variables y_{i,p,s} ≥ 0 linearizing t_{i-1,p} ∧ t_{i,s}:

        Σ_s y_{i,p,s} = t_{i-1,p}   ∀ i ≥ 1, p
        Σ_p y_{i,p,s} = t_{i,s}     ∀ i ≥ 1, s

    With binary t each round's y is a one-unit transportation problem whose
    only feasible point is the indicator of the chosen (p, s) pair, so the
    continuous relaxation of y is exact.  Objective:
    Σ t_{i,j}·CommCost + Σ y_{i,p,s}·T_i(p, s), with the round-0 transition
    out of G0 folded into the t_{0,j} coefficients.  Constraint 5 becomes
    t_{i,j} ≤ t_{i-1,j} for non-standard j outside its entry rounds.
    """
    from scipy.optimize import LinearConstraint, milp
    from scipy.sparse import lil_matrix

    states = build_states(g0, standard, schedule)
    n_rounds = len(schedule.rounds)
    ns = len(states)
    cost, _ = _round_costs(states, schedule, hw)
    g0_idx = _g0_state(states, g0)
    trans = _transition_costs(states, hw)

    # variable layout: t vars [0, n_rounds*ns), y vars afterwards
    nt = n_rounds * ns
    nv = nt + max(0, n_rounds - 1) * ns * ns

    def t(i: int, j: int) -> int:
        return i * ns + j

    def y(i: int, p: int, s: int) -> int:  # i >= 1
        return nt + (i - 1) * ns * ns + p * ns + s

    c = np.zeros(nv)
    for i in range(n_rounds):
        for j in range(ns):
            c[t(i, j)] = cost[i, j]
    for j in range(ns):
        c[t(0, j)] += trans[g0_idx, j]
    for i in range(1, n_rounds):
        eff = _effective_transition(trans, cost, i, hw)
        for p in range(ns):
            for s in range(ns):
                c[y(i, p, s)] = eff[p, s]

    rows: List[Tuple[Dict[int, float], float, float]] = []  # (coeffs, lb, ub)

    # Eq. 4: exactly one topology per round
    for i in range(n_rounds):
        rows.append(({t(i, j): 1.0 for j in range(ns)}, 1.0, 1.0))

    # transition-flow consistency
    for i in range(1, n_rounds):
        for p in range(ns):
            coeffs = {y(i, p, s): 1.0 for s in range(ns)}
            coeffs[t(i - 1, p)] = -1.0
            rows.append((coeffs, 0.0, 0.0))
        for s in range(ns):
            coeffs = {y(i, p, s): 1.0 for p in range(ns)}
            coeffs[t(i, s)] = -1.0
            rows.append((coeffs, 0.0, 0.0))

    # Eq. 5 (carry-only for ideal states outside entry rounds)
    for j, s in enumerate(states):
        if s.standard:
            continue
        for i in range(n_rounds):
            if i in s.entry_rounds:
                continue
            if i == 0:
                rows.append(({t(0, j): 1.0}, 0.0, 0.0))
            else:
                rows.append(({t(i, j): 1.0, t(i - 1, j): -1.0}, -np.inf, 0.0))

    A = lil_matrix((len(rows), nv))
    lb = np.empty(len(rows))
    ub = np.empty(len(rows))
    for k, (coeffs, lo, hi) in enumerate(rows):
        for var, coef in coeffs.items():
            A[k, var] = coef
        lb[k] = lo
        ub[k] = hi

    integrality = np.zeros(nv)
    integrality[:nt] = 1.0
    res = milp(
        c=c,
        constraints=LinearConstraint(A.tocsr(), lb, ub),
        integrality=integrality,
        bounds=(0, 1),
    )
    if not res.success:
        raise RuntimeError(f"MILP failed: {res.message}")
    return float(res.fun)
