"""PCCL's reconfiguration planner (paper Algorithm 1).

The paper formulates "when to reconfigure" as an ILP over binary ``t_{i,j}``
(round *i* uses topology *j*) with

* one-topology-per-round (Eq. 4),
* contiguous use of round-derived ideal topologies (Eq. 5: an ideal graph can
  only be *entered* in the round that generates it, then carried forward), and
* reconfiguration cost paid on a topology change between consecutive rounds
  (Eq. 7), with per-round cost = CommCost (Algorithm 2) + ReconfCost.

That constraint structure is a shortest path over a tiny layered graph, so the
primary solver here is an **exact dynamic program** (`plan`):

    f(i, s) = CommCost(topo(s), R_i, w_i)
              + min over admissible predecessors p of [ f(i-1, p) + r·1[p≠s] ]

where the state space is the edge-set-deduplicated union of {G0} ∪ S ∪
{ideal(R_k)}.  Deduplication matters for fidelity: e.g. every round of a ring
schedule has the *same* ideal graph, so staying on it must not re-pay ``r``
(paper Eq. 7 charges only on change).

Cross-checks (used in tests):
* `plan_bruteforce` — exhaustive enumeration of all feasible assignments.
* `plan_milp` — the paper's ILP, literally, via scipy HiGHS.

All three agree; the DP runs in O(rounds · states²) and plans the largest
scale-up domains in well under the paper's one-second budget (§4.1).
"""

from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Dict, FrozenSet, List, Optional, Sequence, Tuple

import numpy as np

from .cost_model import HardwareParams, RoundCost, comm_cost_round
from .schedules import Round, Schedule
from .topology import Edge, Topology, from_transfers


@dataclass(frozen=True)
class TopoState:
    """One deduplicated candidate topology for the DP/ILP."""

    idx: int
    topo: Topology
    standard: bool                       # in {G0} ∪ S: enterable at any round
    entry_rounds: FrozenSet[int]         # rounds whose ideal graph this is

    def enterable_at(self, i: int) -> bool:
        return self.standard or i in self.entry_rounds


@dataclass(frozen=True)
class PlanStep:
    round_index: int
    state_idx: int
    topo_name: str
    reconfigured: bool
    cost: RoundCost
    reconfig_cost: float

    @property
    def total(self) -> float:
        return self.cost.total + self.reconfig_cost


@dataclass(frozen=True)
class Plan:
    schedule: Schedule
    hw: HardwareParams
    steps: Tuple[PlanStep, ...]
    total_cost: float
    # Topology the fabric is left in after the last round (G0 for empty
    # schedules).  Sessions thread this into the next plan's G0 so
    # back-to-back collectives don't re-pay reconfigurations (api.session).
    final_topology: Optional[Topology] = None

    @property
    def num_reconfigs(self) -> int:
        return sum(1 for s in self.steps if s.reconfigured)

    def breakdown(self) -> Dict[str, float]:
        return {
            "alpha": sum(s.cost.alpha_base for s in self.steps),
            "beta": sum(s.cost.beta_base for s in self.steps),
            "dilation": sum(s.cost.dilation_extra for s in self.steps),
            "congestion": sum(s.cost.congestion_extra for s in self.steps),
            "reconfig": sum(s.reconfig_cost for s in self.steps),
            "total": self.total_cost,
        }


def build_states(
    g0: Topology, standard: Sequence[Topology], schedule: Schedule
) -> List[TopoState]:
    """Dedup {G0} ∪ S ∪ ideal-graphs by directed edge set (input set G of
    Alg. 1 with the bitmap identity of Eq. 7 applied to edge sets)."""
    by_edges: Dict[FrozenSet[Edge], Dict] = {}

    def add(topo: Topology, is_standard: bool, entry_round: Optional[int]) -> None:
        rec = by_edges.setdefault(
            topo.edges, {"topo": topo, "standard": False, "entries": set()}
        )
        rec["standard"] = rec["standard"] or is_standard
        if entry_round is not None:
            rec["entries"].add(entry_round)

    add(g0, True, None)
    for s in standard:
        if s.n != schedule.n:
            raise ValueError(f"standard topology {s.name} has n={s.n} != {schedule.n}")
        add(s, True, None)
    for i, rnd in enumerate(schedule.rounds):
        add(rnd.ideal_topology(schedule.n), False, i)

    states = []
    for k, rec in enumerate(by_edges.values()):
        states.append(
            TopoState(k, rec["topo"], rec["standard"], frozenset(rec["entries"]))
        )
    return states


def _round_costs(
    states: Sequence[TopoState], schedule: Schedule, hw: HardwareParams
) -> np.ndarray:
    """cost[i, s] = CommCost(topo_s, R_i, w_i) (Algorithm 2), cached per state."""
    n_rounds = len(schedule.rounds)
    cost = np.empty((n_rounds, len(states)))
    cost_objs: Dict[Tuple[int, int], RoundCost] = {}
    for i, rnd in enumerate(schedule.rounds):
        for s in states:
            rc = comm_cost_round(s.topo, rnd, None, hw)
            cost[i, s.idx] = rc.total
            cost_objs[(i, s.idx)] = rc
    _round_costs.last_objs = cost_objs  # type: ignore[attr-defined]
    return cost


def _g0_state(states: Sequence[TopoState], g0: Topology) -> int:
    for s in states:
        if s.topo.edges == g0.edges:
            return s.idx
    raise AssertionError("G0 missing from state set")


def plan(
    g0: Topology,
    standard: Sequence[Topology],
    schedule: Schedule,
    hw: HardwareParams,
) -> Plan:
    """Exact DP solution of Algorithm 1."""
    states = build_states(g0, standard, schedule)
    n_rounds = len(schedule.rounds)
    if n_rounds == 0:
        return Plan(schedule, hw, (), 0.0, final_topology=g0)
    cost = _round_costs(states, schedule, hw)
    cost_objs = _round_costs.last_objs  # type: ignore[attr-defined]
    g0_idx = _g0_state(states, g0)
    r = hw.reconfig_delay

    INF = float("inf")
    ns = len(states)
    f = np.full((n_rounds, ns), INF)
    parent = np.full((n_rounds, ns), -1, dtype=np.int64)

    for s in states:
        if s.enterable_at(0) or s.idx == g0_idx:
            f[0, s.idx] = cost[0, s.idx] + (0.0 if s.idx == g0_idx else r)
            parent[0, s.idx] = g0_idx

    for i in range(1, n_rounds):
        # predecessor minima: best over all states, plus per-state carry value
        prev = f[i - 1]
        best_prev = prev.min()
        best_prev_idx = int(prev.argmin())
        for s in states:
            carry = prev[s.idx]  # stay on the same topology: no reconfig
            if s.enterable_at(i):
                # entering/re-entering: pay r unless predecessor is itself
                enter = best_prev + r
                enter_idx = best_prev_idx
                if enter_idx == s.idx:
                    # best predecessor is already this state → carry is better
                    # or equal; also consider second-best for a true "enter"
                    masked = prev.copy()
                    masked[s.idx] = INF
                    if np.isfinite(masked.min()):
                        enter = masked.min() + r
                        enter_idx = int(masked.argmin())
                    else:
                        enter = INF
                if carry <= enter:
                    f[i, s.idx] = carry + cost[i, s.idx]
                    parent[i, s.idx] = s.idx
                else:
                    f[i, s.idx] = enter + cost[i, s.idx]
                    parent[i, s.idx] = enter_idx
            else:
                if np.isfinite(carry):
                    f[i, s.idx] = carry + cost[i, s.idx]
                    parent[i, s.idx] = s.idx

    last = int(f[n_rounds - 1].argmin())
    total = float(f[n_rounds - 1, last])

    # backtrack
    seq = [last]
    for i in range(n_rounds - 1, 0, -1):
        seq.append(int(parent[i, seq[-1]]))
    seq.reverse()

    steps: List[PlanStep] = []
    prev_idx = g0_idx
    for i, s_idx in enumerate(seq):
        reconf = s_idx != prev_idx
        steps.append(
            PlanStep(
                round_index=i,
                state_idx=s_idx,
                topo_name=states[s_idx].topo.name,
                reconfigured=reconf,
                cost=cost_objs[(i, s_idx)],
                reconfig_cost=r if reconf else 0.0,
            )
        )
        prev_idx = s_idx
    return Plan(
        schedule, hw, tuple(steps), total, final_topology=states[seq[-1]].topo
    )


# ------------------------------------------------------------------ oracles


def plan_bruteforce(
    g0: Topology,
    standard: Sequence[Topology],
    schedule: Schedule,
    hw: HardwareParams,
) -> float:
    """Exhaustive minimum over all feasible topology assignments (tests only)."""
    states = build_states(g0, standard, schedule)
    n_rounds = len(schedule.rounds)
    cost = _round_costs(states, schedule, hw)
    g0_idx = _g0_state(states, g0)
    r = hw.reconfig_delay
    best = [float("inf")]

    def feasible(prev: int, s: TopoState, i: int) -> bool:
        return s.enterable_at(i) or s.idx == prev

    def dfs(i: int, prev: int, acc: float) -> None:
        if acc >= best[0]:
            return
        if i == n_rounds:
            best[0] = acc
            return
        for s in states:
            if not feasible(prev, s, i):
                continue
            step = cost[i, s.idx] + (0.0 if s.idx == prev else r)
            dfs(i + 1, s.idx, acc + step)

    dfs(0, g0_idx, 0.0)
    return best[0]


def plan_milp(
    g0: Topology,
    standard: Sequence[Topology],
    schedule: Schedule,
    hw: HardwareParams,
) -> float:
    """The paper's ILP (Eqs. 2–7) via scipy HiGHS, for cross-validation.

    Variables: t_{i,j} ∈ {0,1} for each round i and state j, plus
    same_{i,j} ∈ {0,1} linearizing Bitmap(t_{i,j}) ∧ Bitmap(t_{i-1,j}).
    Objective: Σ t_{i,j}·CommCost + r·Σ_i (1 - Σ_j same_{i,j}),
    with same_{0,j} only allowed for j = G0's state (no initial reconfig).
    Constraint 5 becomes t_{i,j} ≤ t_{i-1,j} for non-standard j outside its
    entry rounds.
    """
    from scipy.optimize import LinearConstraint, milp
    from scipy.sparse import lil_matrix

    states = build_states(g0, standard, schedule)
    n_rounds = len(schedule.rounds)
    ns = len(states)
    cost = _round_costs(states, schedule, hw)
    g0_idx = _g0_state(states, g0)
    r = hw.reconfig_delay

    # variable layout: t vars [0, n_rounds*ns), same vars [n_rounds*ns, 2*...)
    nt = n_rounds * ns
    nv = 2 * nt

    def t(i: int, j: int) -> int:
        return i * ns + j

    def same(i: int, j: int) -> int:
        return nt + i * ns + j

    c = np.zeros(nv)
    for i in range(n_rounds):
        for j in range(ns):
            c[t(i, j)] = cost[i, j]
            c[same(i, j)] = -r  # + r per round added as constant afterwards

    rows: List[Tuple[Dict[int, float], float, float]] = []  # (coeffs, lb, ub)

    # Eq. 4: exactly one topology per round
    for i in range(n_rounds):
        rows.append(({t(i, j): 1.0 for j in range(ns)}, 1.0, 1.0))

    # same_{i,j} ≤ t_{i,j}; same_{i,j} ≤ t_{i-1,j} (i=0 compares against G0)
    for i in range(n_rounds):
        for j in range(ns):
            rows.append(({same(i, j): 1.0, t(i, j): -1.0}, -np.inf, 0.0))
            if i == 0:
                if j != g0_idx:
                    rows.append(({same(i, j): 1.0}, 0.0, 0.0))
            else:
                rows.append(({same(i, j): 1.0, t(i - 1, j): -1.0}, -np.inf, 0.0))

    # at most one 'same' per round (it indicates "no change")
    for i in range(n_rounds):
        rows.append(({same(i, j): 1.0 for j in range(ns)}, 0.0, 1.0))

    # Eq. 5 (carry-only for ideal states outside entry rounds)
    for j, s in enumerate(states):
        if s.standard:
            continue
        for i in range(n_rounds):
            if i in s.entry_rounds:
                continue
            if i == 0:
                rows.append(({t(0, j): 1.0}, 0.0, 0.0))
            else:
                rows.append(({t(i, j): 1.0, t(i - 1, j): -1.0}, -np.inf, 0.0))

    A = lil_matrix((len(rows), nv))
    lb = np.empty(len(rows))
    ub = np.empty(len(rows))
    for k, (coeffs, lo, hi) in enumerate(rows):
        for var, coef in coeffs.items():
            A[k, var] = coef
        lb[k] = lo
        ub[k] = hi

    res = milp(
        c=c,
        constraints=LinearConstraint(A.tocsr(), lb, ub),
        integrality=np.ones(nv),
        bounds=(0, 1),
    )
    if not res.success:
        raise RuntimeError(f"MILP failed: {res.message}")
    return float(res.fun + r * n_rounds)
