"""Collective communication schedules (the algorithm zoo of §5).

A :class:`Schedule` is the paper's ``R = {R_0 … R_{n-1}}``: an ordered list of
:class:`Round`s, each a set of transfers ``(src, dst)`` plus the per-transfer
payload size for that round (``w_i``).  Schedules are the single source of
truth shared by

* the analytical cost model / planner (which only needs ``(src, dst, w)``),
* the chunk-level semantic simulator (``core/simulate.py``) that proves every
  schedule satisfies its collective's post-condition, and
* the executable JAX collectives (``comm/primitives.py``) that interpret every
  round as one ``jax.lax.ppermute`` + local reduce/concat step.

To serve all three, transfers carry chunk metadata: ``chunks`` is the tuple of
logical chunk ids moved, and ``reduce`` says whether the receiver accumulates
(reduce-scatter-like) or stores (all-gather / all-to-all-like).

Implemented algorithms (paper §5 "Algorithms"):

* ``ring_reduce_scatter`` / ``ring_all_gather`` / ``ring_all_reduce`` — NCCL's
  bandwidth-optimal ring.
* ``rhd_reduce_scatter`` / ``rhd_all_gather`` / ``rhd_all_reduce`` — recursive
  halving/doubling (Thakur et al.), the paper's default PCCL input schedule.
* ``bucket_reduce_scatter`` / ``…all_gather`` / ``…all_reduce`` — the
  multi-dimensional torus "Bucket" algorithm (TPU-style, per-dimension rings).
* ``swing_reduce_scatter`` — Swing (De Sensi et al., NSDI'24) distance pattern.
* ``dex_all_to_all`` — hypercube direct-exchange AllToAll (Foster, ch. 11),
  latency-optimal log2(N) steps; the paper's AllToAll input (Fig. 10a).
* ``direct_all_to_all`` — N-1 round pairwise exchange (bandwidth-optimal).
* ``p2p`` — single point-to-point transfer (§6 PEER-TO-PEER nodes).

Chunk-id conventions
--------------------
Reduce-scatter / all-gather over ``N`` ranks split the buffer into ``N`` equal
chunks; chunk ``c`` "belongs" to rank ``c`` (RS post-condition: rank c holds
the fully reduced chunk c; AG pre-condition: rank c contributes chunk c).
All-to-all uses chunk id ``src * N + dst`` for the block rank ``src`` sends to
rank ``dst``.
"""

from __future__ import annotations

import hashlib
import math
import threading
from collections import OrderedDict
from dataclasses import dataclass, field, replace
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from .topology import Topology, from_transfers

# --------------------------------------------------------------------------- data


@dataclass(frozen=True, slots=True)
class Transfer:
    # slots: schedules at n=1024 hold millions of transfers; dropping the
    # per-instance dict roughly halves their footprint
    src: int
    dst: int
    chunks: Tuple[int, ...] = ()
    reduce: bool = False  # receiver accumulates (True) or stores (False)

    def pair(self) -> Tuple[int, int]:
        return (self.src, self.dst)


@dataclass(frozen=True)
class Round:
    transfers: Tuple[Transfer, ...]
    size: float  # bytes sent per transfer in this round (w_i)

    def pairs(self) -> List[Tuple[int, int]]:
        return [t.pair() for t in self.transfers]

    def max_fanout(self) -> int:
        out: Dict[int, int] = {}
        inn: Dict[int, int] = {}
        for t in self.transfers:
            out[t.src] = out.get(t.src, 0) + 1
            inn[t.dst] = inn.get(t.dst, 0) + 1
        return max(max(out.values(), default=0), max(inn.values(), default=0))

    def is_permutation(self) -> bool:
        """True iff every rank sends <=1 and receives <=1 — one circuit set."""
        return self.max_fanout() <= 1

    def ideal_topology(self, n: int) -> Topology:
        return from_transfers(n, [t.pair() for t in self.transfers], name="ideal")


@dataclass(frozen=True)
class Schedule:
    collective: str  # reduce_scatter | all_gather | all_reduce | all_to_all | p2p
    algorithm: str
    n: int
    buffer_bytes: float  # per-rank buffer size d
    rounds: Tuple[Round, ...]

    @property
    def num_rounds(self) -> int:
        return len(self.rounds)

    def fingerprint(self) -> str:
        """Stable content hash of everything *execution* depends on.

        Covers ``(collective, algorithm, n)`` and every round's transfer
        tuples ``(src, dst, chunks, reduce)`` — i.e. the per-round
        permutations and chunk tables.  The encoding is injective: rounds
        are delimited by ``#R``, transfers by ``|``, fields by ``>``/``:``/
        ``,``, none of which can occur inside the integer fields — so
        distinct permutation or chunk tables collide only if blake2b
        itself does (regression-tested in ``tests/test_exec_engine.py``).

        Byte sizes (``buffer_bytes``, ``Round.size``) are **deliberately
        excluded**: they price the schedule (planner/cost-model inputs)
        but do not change what the executor does, so a buffer-size sweep
        over one rescaled template shares a single compiled executable.
        Never key size-dependent data (costs, plans) by fingerprint alone.

        Memoized on first use (cheap blake2b over a canonical encoding;
        the frozen dataclass stores it via ``object.__setattr__``).
        """
        fp = self.__dict__.get("_fingerprint")
        if fp is None:
            h = hashlib.blake2b(digest_size=16)
            h.update(f"{self.collective}|{self.algorithm}|{self.n}".encode())
            for rnd in self.rounds:
                h.update(b"#R")
                for t in rnd.transfers:
                    chunks = ",".join(map(str, t.chunks))
                    h.update(f"|{t.src}>{t.dst}:{int(t.reduce)}:{chunks}".encode())
            fp = h.hexdigest()
            object.__setattr__(self, "_fingerprint", fp)
        return fp

    def total_bytes_per_rank(self) -> float:
        """Max bytes any single rank sends across the schedule (β proxy)."""
        sent: Dict[int, float] = {}
        for r in self.rounds:
            for t in r.transfers:
                sent[t.src] = sent.get(t.src, 0.0) + r.size
        return max(sent.values(), default=0.0)

    def round_sizes(self) -> List[float]:
        return [r.size for r in self.rounds]


# --------------------------------------------------------------------- helpers


Groups = Tuple[Tuple[int, ...], ...]


def mesh_groups(tp: int, dp: int) -> Tuple[Groups, Groups]:
    """(TP groups, DP groups) of a row-major ``tp×dp`` 2-D mesh placement:
    rank = d·tp + t, TP groups are the rows, DP groups the columns.  The
    canonical process-group layout for concurrent TP∥DP planning."""
    tp_groups = tuple(tuple(range(d * tp, (d + 1) * tp)) for d in range(dp))
    dp_groups = tuple(tuple(d * tp + t for d in range(dp)) for t in range(tp))
    return tp_groups, dp_groups


def replicate_groups(sched: Schedule, groups: Groups, n_axis: int) -> Schedule:
    """Replicate a group-local schedule across all groups of an axis.

    The input schedule is over ``m = len(group)`` local ranks; the output is
    over the full ``n_axis`` ranks with every group's transfers composed into
    each round — the process-group pattern (TP rows / DP columns of a 2-D
    mesh) used by ``Communicator.split`` and the concurrent-group arbiter.
    Chunk ids stay group-local (every rank holds ``m`` chunks), which is
    exactly what the ppermute interpreter indexes with.
    """
    rounds = []
    for rnd in sched.rounds:
        transfers = tuple(
            replace(t, src=g[t.src], dst=g[t.dst])
            for g in groups
            for t in rnd.transfers
        )
        rounds.append(Round(transfers, rnd.size))
    return Schedule(
        sched.collective, sched.algorithm, n_axis, sched.buffer_bytes, tuple(rounds)
    )


def _require_pow2(n: int, algo: str) -> int:
    if n < 2 or n & (n - 1):
        raise ValueError(f"{algo} requires power-of-two ranks, got {n}")
    return n.bit_length() - 1


def _chunk(d: float, n: int) -> float:
    return d / n


# ------------------------------------------------------------------------ ring


def ring_reduce_scatter(n: int, d: float) -> Schedule:
    """N-1 rounds; round t: rank i sends the partial of chunk (i - t - 1)
    mod N to i+1, receiver accumulates.  Chunk ids are born canonical: after
    N-1 rounds rank i holds the fully reduced chunk i (the naive "(i - t)
    mod N" labelling would leave rank i owning chunk i+1 and need an O(n²)
    relabelling pass — at n=1024 that pass doubled generation time)."""
    size = _chunk(d, n)
    ctup = [(c,) for c in range(n)]  # chunk tuples shared across rounds
    rounds = []
    for t in range(n - 1):
        transfers = tuple(
            Transfer(i, (i + 1) % n, chunks=ctup[(i - t - 1) % n], reduce=True)
            for i in range(n)
        )
        rounds.append(Round(transfers, size))
    return Schedule("reduce_scatter", "ring", n, d, tuple(rounds))


def ring_all_gather(n: int, d: float) -> Schedule:
    """N-1 rounds; round t: rank i forwards chunk (i - t) mod N to i+1."""
    size = _chunk(d, n)
    ctup = [(c,) for c in range(n)]  # chunk tuples shared across rounds
    rounds = []
    for t in range(n - 1):
        transfers = tuple(
            Transfer(i, (i + 1) % n, chunks=ctup[(i - t) % n], reduce=False)
            for i in range(n)
        )
        rounds.append(Round(transfers, size))
    return Schedule("all_gather", "ring", n, d, tuple(rounds))


def ring_all_reduce(n: int, d: float) -> Schedule:
    rs = ring_reduce_scatter(n, d)
    ag = ring_all_gather(n, d)
    return Schedule("all_reduce", "ring", n, d, rs.rounds + ag.rounds)


def ring_ef8_all_reduce(n: int, d: float) -> Schedule:
    """Ring all-reduce with int8-on-the-wire payloads (algorithm ``ring_ef8``).

    Same transfers and chunk metadata as :func:`ring_all_reduce` — the
    dataflow verifier proves the identical postcondition — but every
    round's wire size is ``/4``: payloads travel as int8 plus one fp32
    scale (amortized away for the chunk sizes the planner prices), so the
    cost model automatically prices bytes/4 serialization from
    ``Round.size`` with no special-casing.  Execution routes through
    :func:`repro.comm.fusion.all_reduce_quantized`; the result is *lossy*,
    bounded by :func:`repro.core.cost_model.compressed_ef_error_bound`, so
    arbitration only considers this algorithm when the caller declares a
    tolerance at least that large (``rel_error_tol``).
    """
    base = ring_all_reduce(n, d)
    rounds = tuple(Round(r.transfers, r.size * 0.25) for r in base.rounds)
    return Schedule("all_reduce", "ring_ef8", n, d, rounds)


# ------------------------------------------------------------------------- RHD


def _block_of(rank: int, bit: int, n: int) -> Tuple[int, ...]:
    """Chunk ids in rank's half w.r.t. the given bit position."""
    return tuple(c for c in range(n) if ((c >> bit) & 1) == ((rank >> bit) & 1))


def rhd_reduce_scatter(n: int, d: float) -> Schedule:
    """Recursive halving: log2(N) rounds, round k pairs ranks differing in bit
    (log2 N - 1 - k); each sends the half of the (still-needed) chunk range
    that belongs to the partner's side.  Sizes d/2, d/4, …, d/N."""
    k = _require_pow2(n, "rhd")
    rounds = []
    for step in range(k):
        bit = k - 1 - step
        transfers = []
        half = 1 << bit
        for i in range(n):
            partner = i ^ half
            # chunks still live for i form the aligned 2^(bit+1)-block around
            # i; the half sent is the partner's side of that block.
            block_start = (i >> (bit + 1)) << (bit + 1)
            send_start = block_start + (half if (partner >> bit) & 1 else 0)
            send = tuple(range(send_start, send_start + half))
            transfers.append(Transfer(i, partner, chunks=send, reduce=True))
        rounds.append(Round(tuple(transfers), d / (2 ** (step + 1))))
    return Schedule("reduce_scatter", "rhd", n, d, tuple(rounds))


def rhd_all_gather(n: int, d: float) -> Schedule:
    """Recursive doubling: round k pairs ranks differing in bit k; each sends
    everything it currently holds.  Sizes d/N, 2d/N, …, d/2 (paper Fig. 5)."""
    k = _require_pow2(n, "rhd")
    rounds = []
    for step in range(k):
        bit = step
        size = 1 << bit
        transfers = []
        for i in range(n):
            partner = i ^ size
            # holds the aligned 2^bit block containing its own chunk
            start = (i >> bit) << bit
            held = tuple(range(start, start + size))
            transfers.append(Transfer(i, partner, chunks=held, reduce=False))
        rounds.append(Round(tuple(transfers), d * (2 ** step) / n))
    return Schedule("all_gather", "rhd", n, d, tuple(rounds))


def rhd_all_reduce(n: int, d: float) -> Schedule:
    rs = rhd_reduce_scatter(n, d)
    ag = rhd_all_gather(n, d)
    return Schedule("all_reduce", "rhd", n, d, rs.rounds + ag.rounds)


# ---------------------------------------------------------------------- bucket


def _axis_ring_groups(dims: Sequence[int], axis: int) -> List[List[int]]:
    """Node groups forming rings along `axis` of a row-major multidim layout."""
    import itertools as it

    strides = []
    s = 1
    for dsz in reversed(dims):
        strides.append(s)
        s *= dsz
    strides.reverse()
    groups = []
    other_axes = [a for a in range(len(dims)) if a != axis]
    for other in it.product(*[range(dims[a]) for a in other_axes]):
        base = sum(c * strides[a] for c, a in zip(other, other_axes))
        groups.append([base + j * strides[axis] for j in range(dims[axis])])
    return groups


def bucket_reduce_scatter(dims: Sequence[int], d: float) -> Schedule:
    """Multi-dimensional bucket (TPU torus) reduce-scatter: per-dimension ring
    reduce-scatters over successively smaller shards.  All transfers are
    nearest-neighbour rings along one torus axis, so the schedule is
    congestion/dilation-free on a matching torus."""
    n = math.prod(dims)
    rounds: List[Round] = []
    shard = d  # bytes each rank still owns before this phase
    # chunk bookkeeping: chunk ids are flat ranks; at each phase the chunks a
    # rank is responsible for narrow to those sharing its coordinates on all
    # completed axes.
    import itertools as it

    strides = []
    s = 1
    for dsz in reversed(dims):
        strides.append(s)
        s *= dsz
    strides.reverse()

    def coord(r: int) -> Tuple[int, ...]:
        return tuple((r // strides[a]) % dims[a] for a in range(len(dims)))

    for axis, dsz in enumerate(dims):
        if dsz == 1:
            continue
        groups = _axis_ring_groups(dims, axis)
        per_round = shard / dsz
        for t in range(dsz - 1):
            transfers = []
            for grp in groups:
                for idx, node in enumerate(grp):
                    nxt = grp[(idx + 1) % dsz]
                    # chunks whose axis-coordinate equals (idx - t - 1) mod dsz
                    # travel this round (ring RS canonical rotation), and must
                    # agree with `node` on all previous axes' coordinates.
                    cc = coord(node)
                    sel = []
                    for c in range(n):
                        ccc = coord(c)
                        if any(ccc[a] != cc[a] for a in range(axis)):
                            continue
                        if ccc[axis] == (cc[axis] - t - 1) % dsz:
                            sel.append(c)
                    transfers.append(
                        Transfer(node, nxt, chunks=tuple(sel), reduce=True)
                    )
            rounds.append(Round(tuple(transfers), per_round))
        shard = shard / dsz
    return Schedule("reduce_scatter", f"bucket{len(dims)}d", n, d, tuple(rounds))


def bucket_all_gather(dims: Sequence[int], d: float) -> Schedule:
    """Mirror of bucket RS: per-dimension ring all-gathers, last axis first."""
    n = math.prod(dims)
    rs = bucket_reduce_scatter(dims, d)
    rounds: List[Round] = []
    for rnd in reversed(rs.rounds):
        rounds.append(
            Round(
                tuple(
                    Transfer(t.dst, t.src, chunks=t.chunks, reduce=False)
                    for t in rnd.transfers
                ),
                rnd.size,
            )
        )
    return Schedule("all_gather", f"bucket{len(dims)}d", n, d, tuple(rounds))


def bucket_all_reduce(dims: Sequence[int], d: float) -> Schedule:
    rs = bucket_reduce_scatter(dims, d)
    ag = bucket_all_gather(dims, d)
    return Schedule("all_reduce", f"bucket{len(dims)}d", len(ag.rounds) and rs.n or rs.n, d, rs.rounds + ag.rounds)


# ----------------------------------------------------------------------- swing


def swing_distance(step: int) -> int:
    """δ_s = (1 - (-2)^{s+1}) / 3 → 1, -1, 3, -5, 11, -21, …"""
    return (1 - (-2) ** (step + 1)) // 3


def swing_reduce_scatter(n: int, d: float) -> Schedule:
    """Swing (NSDI'24): log2(N) rounds; rank r talks to
    ρ(r, s) = r + (-1)^r · δ_s (mod N).  Halving sizes like RHD.  We model the
    communication pattern (src, dst, w) — chunk routing follows Swing's block
    permutation which the semantic simulator does not need to replay (the
    planner and figures use only the pattern; see tests for the permutation
    property)."""
    k = _require_pow2(n, "swing")
    rounds = []
    for step in range(k):
        delta = swing_distance(step)
        transfers = []
        for r in range(n):
            peer = (r + delta) % n if r % 2 == 0 else (r - delta) % n
            transfers.append(Transfer(r, peer, chunks=(), reduce=True))
        rounds.append(Round(tuple(transfers), d / (2 ** (step + 1))))
    return Schedule("reduce_scatter", "swing", n, d, tuple(rounds))


def swing_all_reduce(n: int, d: float) -> Schedule:
    rs = swing_reduce_scatter(n, d)
    mirror = tuple(
        Round(
            tuple(Transfer(t.dst, t.src, chunks=(), reduce=False) for t in r.transfers),
            r.size,
        )
        for r in reversed(rs.rounds)
    )
    return Schedule("all_reduce", "swing", n, d, rs.rounds + mirror)


# ------------------------------------------------------------------- all-to-all


def dex_all_to_all(n: int, d: float) -> Schedule:
    """Hypercube direct-exchange: log2(N) rounds; round k partner = r ^ 2^k;
    send every held block whose final destination differs in bit k.  Each
    round moves d/2 bytes per rank (α-optimal, β pays (d/2)·log N)."""
    k = _require_pow2(n, "dex")
    # track where blocks live: blocks[(origin, dest)] = current holder
    holder = {(o, t): o for o in range(n) for t in range(n)}
    rounds = []
    for step in range(k):
        bit = step
        transfers_by_pair: Dict[Tuple[int, int], List[int]] = {}
        for (o, t), h in holder.items():
            if ((t >> bit) & 1) != ((h >> bit) & 1):
                p = h ^ (1 << bit)
                transfers_by_pair.setdefault((h, p), []).append(o * n + t)
        transfers = tuple(
            Transfer(src, dst, chunks=tuple(sorted(chs)), reduce=False)
            for (src, dst), chs in sorted(transfers_by_pair.items())
        )
        for tr in transfers:
            for ch in tr.chunks:
                holder[(ch // n, ch % n)] = tr.dst
        rounds.append(Round(transfers, d / 2))
    assert all(h == t for (o, t), h in holder.items())
    return Schedule("all_to_all", "dex", n, d, tuple(rounds))


def direct_all_to_all(n: int, d: float) -> Schedule:
    """N-1 rounds; round t rank i sends its block for (i+t+1) mod n directly."""
    rounds = []
    for t in range(n - 1):
        transfers = tuple(
            Transfer(i, (i + t + 1) % n, chunks=(i * n + (i + t + 1) % n,), reduce=False)
            for i in range(n)
        )
        rounds.append(Round(transfers, _chunk(d, n)))
    return Schedule("all_to_all", "direct", n, d, tuple(rounds))


def ring_all_to_all(n: int, d: float) -> Schedule:
    """Ring-based AllToAll: blocks hop neighbour-to-neighbour; round t moves
    every block that still needs to travel ≥1 more hop one step forward.
    N-1 rounds, round t carries (n-1-t)/n · d bytes per rank."""
    rounds = []
    for t in range(n - 1):
        remaining = n - 1 - t
        chunks_by_pair: Dict[Tuple[int, int], List[int]] = {}
        for o in range(n):
            for dst in range(n):
                hops = (dst - o) % n
                if hops > t:  # still in flight; currently at (o + t) % n
                    cur = (o + t) % n
                    chunks_by_pair.setdefault((cur, (cur + 1) % n), []).append(o * n + dst)
        transfers = tuple(
            Transfer(s, r, chunks=tuple(sorted(c)), reduce=False)
            for (s, r), c in sorted(chunks_by_pair.items())
        )
        rounds.append(Round(transfers, d * remaining / n))
    return Schedule("all_to_all", "ring", n, d, tuple(rounds))


# ------------------------------------------------------------------------- p2p


def p2p(n: int, src: int, dst: int, d: float) -> Schedule:
    return Schedule(
        "p2p",
        "p2p",
        n,
        d,
        (Round((Transfer(src, dst, chunks=(0,), reduce=False),), d),),
    )


# ------------------------------------------------------- Tx/Rx-limit splitting


def split_for_fanout(schedule: Schedule, tx_limit: int) -> Schedule:
    """§4.2: if a round needs more simultaneous circuits per GPU than the tile
    has transmitters, split it into sub-rounds until every sub-round fits."""
    if tx_limit < 1:
        raise ValueError("tx_limit must be >= 1")
    new_rounds: List[Round] = []
    for rnd in schedule.rounds:
        if rnd.max_fanout() <= tx_limit:
            new_rounds.append(rnd)
            continue
        # greedy colouring: repeatedly peel a sub-round respecting the limit
        pending = list(rnd.transfers)
        while pending:
            out_cnt: Dict[int, int] = {}
            in_cnt: Dict[int, int] = {}
            take, rest = [], []
            for t in pending:
                if out_cnt.get(t.src, 0) < tx_limit and in_cnt.get(t.dst, 0) < tx_limit:
                    take.append(t)
                    out_cnt[t.src] = out_cnt.get(t.src, 0) + 1
                    in_cnt[t.dst] = in_cnt.get(t.dst, 0) + 1
                else:
                    rest.append(t)
            new_rounds.append(Round(tuple(take), rnd.size))
            pending = rest
    return replace(schedule, rounds=tuple(new_rounds))


# ------------------------------------------------- hierarchical decomposition


def pod_subschedules(
    schedule: Schedule, pods: Sequence[Sequence[int]]
) -> Tuple[
    Tuple[Schedule, ...],
    Tuple[int, ...],
    Tuple[Tuple[Tuple[Tuple[int, int], int], ...], ...],
]:
    """Split a schedule into per-pod intra-pod schedules plus the cross-pod
    boundary traffic (the two-level planner's inputs).

    Returns ``(intra, rep, boundary)``:

    * ``intra[p]`` — a planning-only :class:`Schedule` over pod ``p``'s local
      rank ids with exactly the global round count (rounds with no intra-pod
      transfers stay as empty rounds, keeping round indices aligned for
      stitching).  Chunk metadata is dropped: these schedules price
      communication, they are never executed.
    * ``rep[p]`` — the representative pod whose Schedule object ``intra[p]``
      *is*.  Pods with identical local round structure (same local pair
      multisets every round, same size) share one object, so structurally
      identical pods are planned once.
    * ``boundary[i]`` — round ``i``'s cross-pod traffic as sorted
      ``((src_pod, dst_pod), multiplicity)`` pairs.

    The decomposition is conservative: every transfer of every round appears
    either in exactly one pod's intra round or (as its pod pair) in the
    boundary multiset — ``analysis/invariants.py`` replays this containment.
    Rounds are deduplicated by pair multiset before any per-pod work, so
    e.g. a ring schedule's n−1 identical rounds decompose once.
    """
    import numpy as np

    n = schedule.n
    pods = tuple(tuple(p) for p in pods)
    pod_of = np.full(n, -1, dtype=np.int64)
    local_of = np.zeros(n, dtype=np.int64)
    for p, ranks in enumerate(pods):
        for j, r in enumerate(ranks):
            if not 0 <= r < n:
                raise ValueError(f"pod {p} rank {r} outside [0,{n})")
            if pod_of[r] != -1:
                raise ValueError(f"rank {r} appears in two pods")
            pod_of[r] = p
            local_of[r] = j
    if (pod_of == -1).any():
        raise ValueError("pods must cover every rank exactly once")
    P = len(pods)
    sizes = [len(p) for p in pods]
    mmax = max(sizes)

    # One decomposition per distinct round structure, deduplicated by the
    # pair *sequence* (cheap: a tuple of existing ints, no array build) —
    # slightly finer than the pair multiset, but generator-built schedules
    # emit repeated rounds in identical order, so e.g. a ring schedule's
    # 2(n−1) rounds still collapse to one entry.  Only distinct rounds pay
    # the numpy conversion; this pass is the only place in the planner that
    # touches every transfer of every round.
    from itertools import chain
    from operator import attrgetter

    get_sd = attrgetter("src", "dst")
    R = len(schedule.rounds)
    round_keys: List[int] = []
    key_index: Dict[Tuple, int] = {}
    distinct: List[Round] = []
    d_arrays: List = []                # [distinct] -> (srcs, dsts) or None
    for rnd in schedule.rounds:
        prs = tuple(map(get_sd, rnd.transfers))
        kidx = key_index.get(prs)
        if kidx is None:
            kidx = len(distinct)
            key_index[prs] = kidx
            distinct.append(rnd)
            if prs:
                arr = np.fromiter(
                    chain.from_iterable(prs), dtype=np.int64, count=2 * len(prs)
                ).reshape(-1, 2)
                arr = arr[arr[:, 0] != arr[:, 1]]
            if prs and len(arr):
                d_arrays.append((arr[:, 0], arr[:, 1]))
            else:
                d_arrays.append(None)
        round_keys.append(kidx)

    # per distinct round: boundary pairs + a per-pod signature of the local
    # pair multiset (sorted local codes as raw bytes — cheap to compare)
    d_boundary: List[Tuple[Tuple[Tuple[int, int], int], ...]] = []
    d_sigs: List[List[bytes]] = []     # [distinct][pod] -> signature
    d_local: List[Tuple] = []          # [distinct] -> (pod-sorted arrays) for pass 2
    for k, rnd in enumerate(distinct):
        if d_arrays[k] is None:
            d_boundary.append(())
            d_sigs.append([b""] * P)
            d_local.append(None)
            continue
        srcs, dsts = d_arrays[k]
        pu, pv = pod_of[srcs], pod_of[dsts]
        cross = pu != pv
        codes = pu[cross] * P + pv[cross]
        uniq, cnt = np.unique(codes, return_counts=True)
        d_boundary.append(tuple(
            ((int(c) // P, int(c) % P), int(k))
            for c, k in zip(uniq.tolist(), cnt.tolist())
        ))
        intra = ~cross
        ip = pu[intra]
        lcode = local_of[srcs[intra]] * mmax + local_of[dsts[intra]]
        order = np.lexsort((lcode, ip))
        ip_s, lcode_s = ip[order], lcode[order]
        bounds = np.searchsorted(ip_s, np.arange(P + 1))
        d_sigs.append([
            lcode_s[bounds[p]:bounds[p + 1]].tobytes() for p in range(P)
        ])
        d_local.append((ip_s, lcode_s, bounds))

    # pod classes: identical size + identical signature on every distinct round
    class_of: Dict[Tuple, int] = {}
    rep = [0] * P
    for p in range(P):
        ckey = (sizes[p], tuple(d_sigs[k][p] for k in range(len(distinct))))
        rep[p] = class_of.setdefault(ckey, p)

    # build intra schedules for representatives only
    rep_scheds: Dict[int, Schedule] = {}
    for p in set(rep):
        m = sizes[p]
        d_rounds: List[Round] = []
        for k, rnd in enumerate(distinct):
            if d_local[k] is None:
                d_rounds.append(Round((), rnd.size))
                continue
            ip_s, lcode_s, bounds = d_local[k]
            codes = lcode_s[bounds[p]:bounds[p + 1]]
            d_rounds.append(Round(
                tuple(
                    Transfer(int(c) // mmax, int(c) % mmax)
                    for c in codes.tolist()
                ),
                rnd.size,
            ))
        # rounds sharing a pair structure share the Round object unless
        # their payloads differ (then only the size is swapped out)
        rep_scheds[p] = Schedule(
            schedule.collective,
            f"{schedule.algorithm}@pod{p}",
            m,
            schedule.buffer_bytes,
            tuple(
                base if base.size == rnd.size else Round(base.transfers, rnd.size)
                for rnd, base in (
                    (schedule.rounds[i], d_rounds[round_keys[i]])
                    for i in range(R)
                )
            ),
        )
    intra = tuple(rep_scheds[rep[p]] for p in range(P))
    boundary = tuple(d_boundary[round_keys[i]] for i in range(R))
    return intra, tuple(rep), boundary


# ----------------------------------------------------------------- registries

ScheduleFn = Callable[[int, float], Schedule]

# Bounded LRU over (collective, algorithm, n, d, dims) → Schedule.  Schedules
# are deterministic in their key and immutable (frozen dataclasses; the lazy
# ``fingerprint`` memo is idempotent), so sharing one object across planner /
# session / bench callers is safe.  Generation is the single most expensive
# artifact at scale — an n=1024 ring all-reduce is ~2M Transfer objects —
# and unlike the planner's routing caches it does not depend on fabric state
# or hardware params, so ``planner.clear_planner_caches`` deliberately leaves
# this memo alone (cold *planning* never includes re-deriving the schedule).
# Capacity is small: entries are hundreds of MB at n=1024.
_SCHEDULE_CACHE: "OrderedDict[Tuple, Schedule]" = OrderedDict()
_SCHEDULE_CACHE_MAX = 8
_SCHEDULE_CACHE_LOCK = threading.Lock()


def clear_schedule_cache() -> None:
    """Drop every memoized ``get_schedule`` result (tests / memory pressure)."""
    with _SCHEDULE_CACHE_LOCK:
        _SCHEDULE_CACHE.clear()


def get_schedule(collective: str, algorithm: str, n: int, d: float,
                 dims: Optional[Sequence[int]] = None) -> Schedule:
    """Uniform constructor used by the planner facade and benchmarks.

    Memoized: repeated lookups of the same (collective, algorithm, n, d,
    dims) return one shared immutable Schedule object."""
    cache_key = (
        collective, algorithm, n, float(d),
        tuple(dims) if dims is not None else None,
    )
    with _SCHEDULE_CACHE_LOCK:
        hit = _SCHEDULE_CACHE.get(cache_key)
        if hit is not None:
            _SCHEDULE_CACHE.move_to_end(cache_key)
            return hit
    sched = _build_schedule(collective, algorithm, n, d, dims)
    with _SCHEDULE_CACHE_LOCK:
        _SCHEDULE_CACHE[cache_key] = sched
        _SCHEDULE_CACHE.move_to_end(cache_key)
        while len(_SCHEDULE_CACHE) > _SCHEDULE_CACHE_MAX:
            _SCHEDULE_CACHE.popitem(last=False)
    return sched


def _build_schedule(collective: str, algorithm: str, n: int, d: float,
                    dims: Optional[Sequence[int]] = None) -> Schedule:
    key = (collective, algorithm)
    if algorithm.startswith("bucket"):
        if dims is None:
            raise ValueError("bucket algorithms need torus dims")
        fn = {
            "reduce_scatter": bucket_reduce_scatter,
            "all_gather": bucket_all_gather,
            "all_reduce": bucket_all_reduce,
        }[collective]
        return fn(dims, d)
    table: Dict[Tuple[str, str], ScheduleFn] = {
        ("reduce_scatter", "ring"): ring_reduce_scatter,
        ("reduce_scatter", "rhd"): rhd_reduce_scatter,
        ("reduce_scatter", "swing"): swing_reduce_scatter,
        ("all_gather", "ring"): ring_all_gather,
        ("all_gather", "rhd"): rhd_all_gather,
        ("all_reduce", "ring"): ring_all_reduce,
        ("all_reduce", "ring_ef8"): ring_ef8_all_reduce,
        ("all_reduce", "rhd"): rhd_all_reduce,
        ("all_reduce", "swing"): swing_all_reduce,
        ("all_to_all", "dex"): dex_all_to_all,
        ("all_to_all", "direct"): direct_all_to_all,
        ("all_to_all", "ring"): ring_all_to_all,
    }
    if key not in table:
        raise KeyError(f"no schedule for {key}")
    return table[key](n, d)
