"""Chunk-level semantic simulator for collective schedules.

Executes a :class:`~repro.core.schedules.Schedule`'s rounds on symbolic chunk
state and checks the collective's post-condition (§3, Fig. 4).  This is the
oracle that proves a schedule is *correct* independent of its cost, and it is
exercised by unit + hypothesis property tests for every generator.

Semantics
---------
* reduce-scatter / all-reduce reductions are tracked as *contribution masks*:
  each rank's copy of chunk ``c`` is the set of source ranks whose data has
  been folded in.  Sending with ``reduce=True`` unions masks at the receiver;
  the sender retires its copy (it transferred responsibility).
* all-gather / all-to-all track chunk *presence* (masks are just replicated).

Post-conditions verified
------------------------
* reduce_scatter: rank c holds chunk c with mask == all ranks.
* all_gather:     every rank holds every chunk.
* all_reduce:     every rank holds every chunk fully reduced.
* all_to_all:     rank t holds block (s → t) for every s.
* p2p:            dst holds the payload.
"""

from __future__ import annotations

from typing import Dict, List, Tuple

from .schedules import Schedule

Mask = int  # bitmask of contributing ranks


def _full_mask(n: int) -> Mask:
    return (1 << n) - 1


class SimulationError(AssertionError):
    pass


def simulate(schedule: Schedule) -> List[Dict[int, Mask]]:
    """Run the schedule; returns final per-rank {chunk_id: contribution mask}."""
    n = schedule.n
    state: List[Dict[int, Mask]] = [dict() for _ in range(n)]

    if schedule.collective in ("reduce_scatter", "all_reduce"):
        for r in range(n):
            for c in range(n):
                state[r][c] = 1 << r
    elif schedule.collective == "all_gather":
        for r in range(n):
            state[r][r] = _full_mask(n)  # AG input is already reduced
    elif schedule.collective == "all_to_all":
        for s in range(n):
            for t in range(n):
                state[s][s * n + t] = 1 << s
    elif schedule.collective == "p2p":
        src = schedule.rounds[0].transfers[0].src
        state[src][0] = 1 << src
    else:
        raise ValueError(f"unknown collective {schedule.collective}")

    for ri, rnd in enumerate(schedule.rounds):
        # two-phase: read all sends against pre-round state, then apply
        sends: List[Tuple[int, int, int, Mask, bool]] = []  # (src,dst,chunk,mask,reduce)
        for t in rnd.transfers:
            for c in t.chunks:
                if c not in state[t.src]:
                    raise SimulationError(
                        f"round {ri}: rank {t.src} sends chunk {c} it does not hold"
                    )
                sends.append((t.src, t.dst, c, state[t.src][c], t.reduce))
        for src, dst, c, mask, reduce in sends:
            if reduce:
                state[dst][c] = state[dst].get(c, 0) | mask
                # sender hands off its partial — mirrors in-place RS buffers
                del state[src][c]
            else:
                state[dst][c] = state[dst].get(c, 0) | mask
    return state


def verify(schedule: Schedule) -> None:
    """Raise SimulationError unless the post-condition holds."""
    n = schedule.n
    full = _full_mask(n)
    state = simulate(schedule)

    if schedule.collective == "reduce_scatter":
        for r in range(n):
            if state[r].get(r, 0) != full:
                raise SimulationError(
                    f"rank {r} chunk {r} mask={state[r].get(r, 0):b}, want full"
                )
    elif schedule.collective == "all_gather":
        for r in range(n):
            for c in range(n):
                if state[r].get(c, 0) != full:
                    raise SimulationError(f"rank {r} missing chunk {c}")
    elif schedule.collective == "all_reduce":
        # composition schedules (rs rounds then ag rounds): ag rounds replicate
        for r in range(n):
            for c in range(n):
                if state[r].get(c, 0) != full:
                    raise SimulationError(f"rank {r} chunk {c} not fully reduced")
    elif schedule.collective == "all_to_all":
        for t in range(n):
            for s in range(n):
                if state[t].get(s * n + t, 0) != (1 << s):
                    raise SimulationError(f"rank {t} missing block {s}->{t}")
    elif schedule.collective == "p2p":
        tr = schedule.rounds[0].transfers[0]
        if state[tr.dst].get(0, 0) != (1 << tr.src):
            raise SimulationError("p2p payload not delivered")
    else:
        raise ValueError(schedule.collective)
