"""Topology zoo for PCCL.

A :class:`Topology` is the *logical* circuit graph of a scale-up domain: nodes
are accelerators, a directed edge ``(u, v)`` is a unidirectional circuit (one
Tx at ``u``, one Rx at ``v``).  Physical links on electrical fabrics are
full-duplex, so all standard constructors emit both directions; congestion is
counted per *direction* (paper Fig. 6 measures per-direction overlap).

The planner (Algorithm 1) draws candidate topologies from three places:

* ``G0``            — the initial fabric state (any constructor below),
* ``S``             — a set of standard connected graphs (§4.1 "Managing
                      disconnected graphs"),
* ``ideal(round)``  — the graph whose edges are exactly one round's transfers
                      (:func:`from_transfers`), i.e. the circuit set PCCL would
                      program for that round.

All-pairs shortest path (BFS, unweighted) is cached per topology because
Algorithm 2 queries it once per transfer per candidate topology per round.
"""

from __future__ import annotations

import itertools
import threading
from collections import OrderedDict, deque
from dataclasses import dataclass
from typing import Dict, FrozenSet, Iterable, List, Optional, Sequence, Tuple

Edge = Tuple[int, int]

_BIG = 10 ** 9  # "large penalty" hop count for disconnected pairs (Alg. 2 line 10)


@dataclass(frozen=True)
class Topology:
    """Immutable directed graph over ``n`` accelerators."""

    n: int
    edges: FrozenSet[Edge]
    name: str = "custom"

    # ------------------------------------------------------------------ utils
    def __post_init__(self) -> None:
        for u, v in self.edges:
            if not (0 <= u < self.n and 0 <= v < self.n):
                raise ValueError(f"edge {(u, v)} out of range for n={self.n}")
            if u == v:
                raise ValueError(f"self-loop {(u, v)} not allowed")

    def __hash__(self) -> int:  # frozen dataclass already hashes; keep explicit
        return hash((self.n, self.edges))

    def adjacency(self) -> List[List[int]]:
        adj: List[List[int]] = [[] for _ in range(self.n)]
        for u, v in self.edges:
            adj[u].append(v)
        return adj

    def out_degree(self, u: int) -> int:
        return sum(1 for (a, _) in self.edges if a == u)

    def in_degree(self, v: int) -> int:
        return sum(1 for (_, b) in self.edges if b == v)

    def has_edge(self, u: int, v: int) -> bool:
        return (u, v) in self.edges

    # ------------------------------------------------------ shortest paths
    def shortest_path(self, src: int, dst: int) -> Optional[List[int]]:
        """BFS shortest path (list of nodes) or None if disconnected."""
        if src == dst:
            return [src]
        parents = _bfs_parents(self, src)
        if parents[dst] is None and dst != src:
            return None
        path = [dst]
        while path[-1] != src:
            p = parents[path[-1]]
            if p is None:
                return None
            path.append(p)
        path.reverse()
        return path

    def hop_count(self, src: int, dst: int) -> int:
        """Shortest-path hops; _BIG if disconnected (Alg. 2 penalty)."""
        dists = _apsp(self)[src]
        return dists[dst]

    def is_connected(self) -> bool:
        dists = _apsp(self)[0]
        return all(d < _BIG for d in dists)

    def undirected_link_count(self) -> int:
        return len({tuple(sorted(e)) for e in self.edges})


# Caches keyed by (n, edges) so equal topologies share work.
_BFS_CACHE: Dict[Tuple[int, FrozenSet[Edge], int], List[Optional[int]]] = {}
_APSP_CACHE: Dict[Tuple[int, FrozenSet[Edge]], List[List[int]]] = {}


def _bfs_parents(t: Topology, src: int) -> List[Optional[int]]:
    key = (t.n, t.edges, src)
    hit = _BFS_CACHE.get(key)
    if hit is not None:
        return hit
    adj = t.adjacency()
    parents: List[Optional[int]] = [None] * t.n
    seen = [False] * t.n
    seen[src] = True
    q = deque([src])
    while q:
        u = q.popleft()
        for v in adj[u]:
            if not seen[v]:
                seen[v] = True
                parents[v] = u
                q.append(v)
    _BFS_CACHE[key] = parents
    return parents


def _apsp(t: Topology) -> List[List[int]]:
    key = (t.n, t.edges)
    hit = _APSP_CACHE.get(key)
    if hit is not None:
        return hit
    adj = t.adjacency()
    all_d: List[List[int]] = []
    for s in range(t.n):
        dist = [_BIG] * t.n
        dist[s] = 0
        q = deque([s])
        while q:
            u = q.popleft()
            for v in adj[u]:
                if dist[v] >= _BIG:
                    dist[v] = dist[u] + 1
                    q.append(v)
        all_d.append(dist)
    _APSP_CACHE[key] = all_d
    return all_d


def clear_caches() -> None:
    _BFS_CACHE.clear()
    _APSP_CACHE.clear()
    with _DEGRADE_CACHE_LOCK:
        _DEGRADE_CACHE.clear()


# ---------------------------------------------------------------------------
# Constructors — the five baseline fabrics of §5 plus hypercube & ideal graphs.
# ---------------------------------------------------------------------------

def _bidir(pairs: Iterable[Edge]) -> FrozenSet[Edge]:
    out = set()
    for u, v in pairs:
        out.add((u, v))
        out.add((v, u))
    return frozenset(out)


def ring(n: int) -> Topology:
    """1-D torus: i <-> i+1 mod n."""
    if n < 2:
        raise ValueError("ring needs n >= 2")
    return Topology(n, _bidir((i, (i + 1) % n) for i in range(n)), name=f"ring{n}")


def line(n: int) -> Topology:
    """1-D grid (ring without wraparound)."""
    return Topology(n, _bidir((i, i + 1) for i in range(n - 1)), name=f"line{n}")


def _grid_nd(dims: Sequence[int], wrap: bool, name: str) -> Topology:
    n = 1
    for d in dims:
        n *= d
    strides = []
    s = 1
    for d in reversed(dims):
        strides.append(s)
        s *= d
    strides.reverse()  # strides[i] multiplies coordinate i

    def flat(coord: Sequence[int]) -> int:
        return sum(c * st for c, st in zip(coord, strides))

    pairs: List[Edge] = []
    for coord in itertools.product(*[range(d) for d in dims]):
        for axis, d in enumerate(dims):
            c = list(coord)
            if coord[axis] + 1 < d:
                c[axis] = coord[axis] + 1
                pairs.append((flat(coord), flat(c)))
            elif wrap and d > 2:
                c[axis] = 0
                pairs.append((flat(coord), flat(c)))
    return Topology(n, _bidir(pairs), name=name)


def torus2d(a: int, b: int) -> Topology:
    return _grid_nd((a, b), wrap=True, name=f"torus2d_{a}x{b}")


def torus3d(a: int, b: int, c: int) -> Topology:
    return _grid_nd((a, b, c), wrap=True, name=f"torus3d_{a}x{b}x{c}")


def grid2d(a: int, b: int) -> Topology:
    """2-D mesh — torus without wraparound (paper: "Grid is a torus without
    wrap around links", plotted as HC=Grid)."""
    return _grid_nd((a, b), wrap=False, name=f"grid2d_{a}x{b}")


def grid3d(a: int, b: int, c: int) -> Topology:
    return _grid_nd((a, b, c), wrap=False, name=f"grid3d_{a}x{b}x{c}")


def hypercube(n: int) -> Topology:
    if n & (n - 1):
        raise ValueError("hypercube needs power-of-two n")
    pairs = []
    k = n.bit_length() - 1
    for u in range(n):
        for b in range(k):
            v = u ^ (1 << b)
            if u < v:
                pairs.append((u, v))
    return Topology(n, _bidir(pairs), name=f"hypercube{n}")


def fully_connected(n: int) -> Topology:
    return Topology(
        n,
        frozenset((u, v) for u in range(n) for v in range(n) if u != v),
        name=f"full{n}",
    )


def from_transfers(n: int, transfers: Iterable[Edge], name: str = "ideal") -> Topology:
    """The *ideal* (round-matched) topology: one unidirectional circuit per
    transfer — what PCCL programs onto the photonic fabric for that round
    (set ``I`` in Algorithm 1)."""
    return Topology(n, frozenset(transfers), name=name)


# Factorizations used to place N accelerators on 2-D / 3-D fabrics; §5 uses
# 32/64/128-GPU domains.  We choose the most-square factorization.

def square_dims2(n: int) -> Tuple[int, int]:
    a = int(n ** 0.5)
    while n % a:
        a -= 1
    return (a, n // a)


def square_dims3(n: int) -> Tuple[int, int, int]:
    best = (1, 1, n)
    best_score = n * n
    for a in range(1, int(round(n ** (1 / 3))) + 2):
        if n % a:
            continue
        m = n // a
        for b in range(a, int(m ** 0.5) + 1):
            if m % b:
                continue
            c = m // b
            score = (c - a) + (c - b)  # prefer near-cubic
            if score < best_score:
                best_score = score
                best = (a, b, c)
    return best


def standard_topologies(n: int) -> Dict[str, Topology]:
    """The five baseline fabrics of §5 (plus hypercube when n is 2^k)."""
    a2, b2 = square_dims2(n)
    a3, b3, c3 = square_dims3(n)
    topos = {
        "ring": ring(n),
        "torus2d": torus2d(a2, b2),
        "torus3d": torus3d(a3, b3, c3),
        "grid2d": grid2d(a2, b2),
        "grid3d": grid3d(a3, b3, c3),
    }
    if n & (n - 1) == 0 and n >= 2:
        topos["hypercube"] = hypercube(n)
    return topos


def topology_by_name(name: str, n: int) -> Topology:
    std = standard_topologies(n)
    if name not in std:
        raise KeyError(f"unknown topology {name!r}; have {sorted(std)}")
    return std[name]


# ---------------------------------------------------------------------------
# Hierarchical decomposition + fault helpers (used by the two-level planner
# and incremental replanning; see core/planner.py).
# ---------------------------------------------------------------------------


def derive_pods(n: int, pod_size: Optional[int] = None) -> Tuple[Tuple[int, ...], ...]:
    """Partition ``n`` ranks into contiguous equal-size pods.

    ``pod_size`` defaults to the larger factor of the most-square 2-D
    factorization — the column length of the torus the fabric would be laid
    out on — so pods line up with torus tiles / ring segments (16 → 4 pods
    of 4, 128 → 8 pods of 16, 1024 → 32 pods of 32).  A prime ``n`` yields
    a single pod, which the hierarchical planner treats as "no hierarchy"
    and delegates to the flat exact DP.
    """
    if n < 1:
        raise ValueError(f"need n >= 1, got {n}")
    if pod_size is None:
        _, pod_size = square_dims2(n)
    if pod_size < 1 or n % pod_size:
        raise ValueError(f"pod_size {pod_size} does not divide n={n}")
    return tuple(
        tuple(range(p * pod_size, (p + 1) * pod_size))
        for p in range(n // pod_size)
    )


def induced_topology(topo: Topology, ranks: Sequence[int], name: str) -> Topology:
    """The subgraph of ``topo`` induced on ``ranks``, relabelled to local ids
    ``0..len(ranks)-1`` (a pod's view of the fabric)."""
    local = {r: i for i, r in enumerate(ranks)}
    edges = frozenset(
        (local[u], local[v]) for u, v in topo.edges if u in local and v in local
    )
    return Topology(len(ranks), edges, name=name)


def quotient_topology(
    topo: Topology, pods: Sequence[Sequence[int]], name: str = "quotient"
) -> Topology:
    """The super-rank graph: one node per pod, an edge (p, q) iff some
    directed edge of ``topo`` crosses from pod ``p`` into pod ``q``."""
    pod_of: Dict[int, int] = {}
    for p, ranks in enumerate(pods):
        for r in ranks:
            pod_of[r] = p
    edges = frozenset(
        (pod_of[u], pod_of[v])
        for u, v in topo.edges
        if pod_of[u] != pod_of[v]
    )
    return Topology(len(pods), edges, name=name)


_DEGRADE_CACHE: "OrderedDict[Tuple, Topology]" = OrderedDict()
_DEGRADE_CACHE_MAX = 256
_DEGRADE_CACHE_LOCK = threading.Lock()


def degrade_topology(
    topo: Topology,
    failed_edges: Iterable[Edge] = (),
    failed_ranks: Iterable[int] = (),
) -> Topology:
    """``topo`` with the failed directed circuits removed (fault model: a
    dead link can no longer carry a circuit in that direction; a dead rank
    loses every incident circuit).  Edges not present are ignored, so
    callers may pass both directions of a physical link uniformly.  Returns
    ``topo`` itself when nothing changes, keeping cache keys (edge-set
    identity) stable for unaffected topologies.

    Memoized (bounded LRU): a fault event degrades the same topologies in
    the session layer, the planner's replan fast path, and the fault
    runtime — one edge-set filter serves them all."""
    failed = frozenset(failed_edges)
    ranks = frozenset(failed_ranks)
    key = (topo.n, topo.edges, failed, ranks)
    with _DEGRADE_CACHE_LOCK:
        hit = _DEGRADE_CACHE.get(key)
        if hit is not None:
            _DEGRADE_CACHE.move_to_end(key)
            return hit
    kept = frozenset(
        e for e in topo.edges
        if e not in failed and e[0] not in ranks and e[1] not in ranks
    )
    if kept == topo.edges:
        out = topo
    else:
        out = Topology(topo.n, kept, name=f"{topo.name}~degraded")
    with _DEGRADE_CACHE_LOCK:
        _DEGRADE_CACHE[key] = out
        _DEGRADE_CACHE.move_to_end(key)
        while len(_DEGRADE_CACHE) > _DEGRADE_CACHE_MAX:
            _DEGRADE_CACHE.popitem(last=False)
    return out
