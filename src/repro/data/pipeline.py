"""Synthetic token data pipeline: host-sharded, deterministic, prefetching.

Production shape without external datasets (none are installed here): each
host generates its disjoint shard of the global batch from a seeded
Philox stream keyed by (seed, step, host), so any host can regenerate any
step — which is what makes checkpoint-restart and elastic re-sharding exact:
a restarted (or re-balanced) job replays the identical token stream.

A background thread keeps ``prefetch`` batches ahead of the consumer.
"""

from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Dict, Iterator, Optional

import numpy as np

from repro.configs.base import ModelConfig


@dataclass(frozen=True)
class DataConfig:
    global_batch: int
    seq_len: int
    seed: int = 0
    n_hosts: int = 1
    host_id: int = 0
    # straggler mitigation hook: a slow host can be assigned fewer grains
    grains_per_host: Optional[Dict[int, int]] = None


class SyntheticLMData:
    """Deterministic synthetic LM batches (zipf-ish token marginals so the
    loss curve is non-trivial)."""

    def __init__(self, cfg: ModelConfig, data: DataConfig):
        self.cfg = cfg
        self.data = data
        if data.global_batch % data.n_hosts:
            raise ValueError("global batch must divide across hosts")
        self.per_host = data.global_batch // data.n_hosts

    def host_batch(self, step: int, host_id: Optional[int] = None) -> Dict[str, np.ndarray]:
        host = self.data.host_id if host_id is None else host_id
        # Philox keyed by (seed, step·N_hosts + host): any host regenerates
        # any step independently (checkpoint-restart / elastic re-shard)
        key = (self.data.seed << 32) ^ (step * max(self.data.n_hosts, 1) + host)
        gen = np.random.Generator(np.random.Philox(key=key))
        B, S = self.per_host, self.data.seq_len
        cfg = self.cfg
        # zipf marginals clipped to vocab
        toks = gen.zipf(1.3, size=(B, S)).astype(np.int64) % cfg.vocab
        out: Dict[str, np.ndarray] = {"tokens": toks.astype(np.int32)}
        if cfg.vlm:
            out["tokens"] = out["tokens"][:, : S - cfg.vlm.n_img_tokens]
            out["img_embeds"] = gen.normal(
                size=(B, cfg.vlm.n_img_tokens, cfg.d_model)
            ).astype(np.float32)
        if cfg.enc_dec:
            out["enc_frames"] = gen.normal(
                size=(B, cfg.enc_dec.enc_seq, cfg.d_model)
            ).astype(np.float32)
        return out

    def global_batch(self, step: int) -> Dict[str, np.ndarray]:
        """All hosts' shards concatenated (single-process testing/training)."""
        parts = [self.host_batch(step, h) for h in range(self.data.n_hosts)]
        return {k: np.concatenate([p[k] for p in parts], axis=0) for k in parts[0]}

    def iterate(self, start_step: int = 0, prefetch: int = 2) -> Iterator[Dict[str, np.ndarray]]:
        """Prefetching iterator from ``start_step`` (checkpoint resume)."""
        q: "queue.Queue" = queue.Queue(maxsize=prefetch)
        stop = threading.Event()

        def worker():
            step = start_step
            while not stop.is_set():
                q.put((step, self.global_batch(step)))
                step += 1

        t = threading.Thread(target=worker, daemon=True)
        t.start()
        try:
            while True:
                step, batch = q.get()
                yield batch
        finally:
            stop.set()
