"""Pallas TPU flash-attention forward kernel (causal, GQA).

Grid: (batch · q_heads, num_q_blocks); each program streams K/V blocks of its
KV head through VMEM while maintaining the online-softmax running max ``m``,
normalizer ``l`` and fp32 accumulator ``acc`` in scratch.  Block shapes are
(block_q, head_dim) / (block_k, head_dim) — multiples of 128 on the MXU-
aligned dims by default.

Causal skipping: KV blocks strictly above the diagonal are not computed
(``when`` guard on the block index), giving the ~2× causal FLOP saving.

TPU adaptation notes (DESIGN.md §2): this is the standard HBM→VMEM streaming
decomposition; no warp-level primitives are involved, the MXU consumes the
(block_q × head_dim) @ (head_dim × block_k) tiles directly.

Validated against ``ref.attention_reference`` in interpret mode (CPU) over
shape/dtype sweeps — see tests/test_kernels_flash.py.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _flash_fwd_kernel(
    q_ref,      # (block_q, d)
    k_ref,      # (T, d)      — full K for this kv head (streamed via slices)
    v_ref,      # (T, d)
    o_ref,      # (block_q, d)
    *,
    block_k: int,
    causal: bool,
    sm_scale: float,
):
    block_q, d = q_ref.shape
    T = k_ref.shape[0]
    qi = pl.program_id(1)

    q = q_ref[...].astype(jnp.float32) * sm_scale
    q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, 1), 0)

    m = jnp.full((block_q, 1), NEG_INF, jnp.float32)
    l = jnp.zeros((block_q, 1), jnp.float32)
    acc = jnp.zeros((block_q, d), jnp.float32)

    num_kb = pl.cdiv(T, block_k)
    if causal:
        # last KV block that intersects this q block's causal window
        last_kb = jnp.minimum(num_kb, (qi + 1) * block_q // block_k + 1)
    else:
        last_kb = num_kb

    def body(kb, carry):
        m, l, acc = carry
        k = k_ref[pl.dslice(kb * block_k, block_k), :].astype(jnp.float32)
        v = v_ref[pl.dslice(kb * block_k, block_k), :].astype(jnp.float32)
        s = q @ k.T  # (block_q, block_k)
        if causal:
            k_pos = kb * block_k + jax.lax.broadcasted_iota(jnp.int32, (1, block_k), 1)
            s = jnp.where(k_pos <= q_pos, s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m - m_new)
        l_new = alpha * l + p.sum(axis=-1, keepdims=True)
        acc_new = alpha * acc + p @ v
        return m_new, l_new, acc_new

    m, l, acc = jax.lax.fori_loop(0, last_kb, body, (m, l, acc))
    o_ref[...] = (acc / jnp.maximum(l, 1e-30)).astype(o_ref.dtype)


def flash_attention_pallas(
    q: jax.Array,   # (B, S, H, D)
    k: jax.Array,   # (B, T, K, D)
    v: jax.Array,   # (B, T, K, D)
    *,
    causal: bool = True,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    B, S, H, D = q.shape
    T, K = k.shape[1], k.shape[2]
    G = H // K
    assert S == T or not causal, "causal kernel assumes aligned q/kv windows"
    block_q = min(block_q, S)
    block_k = min(block_k, T)
    assert S % block_q == 0 and T % block_k == 0, (S, T, block_q, block_k)

    # (B,S,H,D) -> (B*H, S, D); the kv row for q-head program h is h // G,
    # resolved in the BlockSpec index_map (no materialized repeat).
    qh = q.transpose(0, 2, 1, 3).reshape(B * H, S, D)
    kh = k.transpose(0, 2, 1, 3).reshape(B * K, T, D)
    vh = v.transpose(0, 2, 1, 3).reshape(B * K, T, D)

    grid = (B * H, S // block_q)
    kernel = functools.partial(
        _flash_fwd_kernel,
        block_k=block_k,
        causal=causal,
        sm_scale=1.0 / math.sqrt(D),
    )

    def kv_index(h, i):
        b, hh = h // H, h % H
        return (b * K + hh // G, 0, 0)

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, block_q, D), lambda h, i: (h, i, 0)),
            pl.BlockSpec((None, T, D), kv_index),
            pl.BlockSpec((None, T, D), kv_index),
        ],
        out_specs=pl.BlockSpec((None, block_q, D), lambda h, i: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, S, D), q.dtype),
        interpret=interpret,
    )(qh, kh, vh)
    return out.reshape(B, H, S, D).transpose(0, 2, 1, 3)
