"""Jit'd entry point for flash attention: Pallas kernel or jnp oracle."""

from __future__ import annotations

import os
from typing import Optional

import jax

from .ref import attention_reference


def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    block_q: int = 128,
    block_k: int = 128,
    interpret: Optional[bool] = None,
    force_reference: bool = False,
) -> jax.Array:
    if force_reference:
        return attention_reference(q, k, v, causal=causal)
    from .kernel import flash_attention_pallas

    if os.environ.get("PCCL_VERIFY", "0") not in ("", "0"):
        from ...analysis.kernel_lint import verify_entry_point

        verify_entry_point(
            "flash_attention", flash_attention_pallas, (q, k, v),
            dict(causal=causal, block_q=block_q, block_k=block_k),
        )
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    return flash_attention_pallas(
        q, k, v, causal=causal, block_q=block_q, block_k=block_k,
        interpret=interpret,
    )
