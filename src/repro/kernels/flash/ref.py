"""Pure-jnp oracle for causal GQA flash attention.

Numerically identical semantics to the Pallas kernel: causal softmax(QKᵀ/√d)V
with grouped KV heads, fp32 accumulation.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention_reference(
    q: jax.Array,   # (B, S, H, D)
    k: jax.Array,   # (B, T, K, D)
    v: jax.Array,   # (B, T, K, D)
    *,
    causal: bool = True,
) -> jax.Array:
    B, S, H, D = q.shape
    T, K = k.shape[1], k.shape[2]
    G = H // K
    qg = q.reshape(B, S, K, G, D)
    scores = jnp.einsum("bskgd,btkd->bkgst", qg, k).astype(jnp.float32) / math.sqrt(D)
    if causal:
        mask = jnp.arange(T)[None, :] <= jnp.arange(S)[:, None] + (T - S)
        scores = jnp.where(mask[None, None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgst,btkd->bskgd", probs, v)
    return out.reshape(B, S, H, D)
