from .ops import matmul, tiles_exactly  # noqa: F401
from .ref import matmul_reference  # noqa: F401
