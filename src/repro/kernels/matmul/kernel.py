"""Pallas TPU kernel: blocked matmul with a reduce-scatter-ready epilogue.

Grid ``(M/bm, N/bn, K/bk)`` with the contraction axis **innermost** (Pallas
TPU iterates it sequentially per output tile): a ``(bm, bn)`` fp32 VMEM
scratch accumulator is reset when the K index wraps to 0, accumulates one
``(bm, bk) @ (bk, bn)`` MXU product per step, and the epilogue writes the
finished tile (cast to the output dtype) on the last K step only.

The epilogue is what the comm/compute fusion layer (``repro.comm.fusion``)
feeds on: output row-blocks are produced tile-by-tile in grid-row order, so
a reduce-scatter chunk (a contiguous row block) is complete — and ready to
enter its collective round — as soon as its row of tiles has been written.
The fused executor calls this kernel once *per chunk* (``M = chunk rows``);
because each output tile depends only on its own row block of ``x`` and the
shared ``w``, per-chunk calls are **bit-identical** to one whole-``M`` call
at the same block sizes (same fp32 accumulation order per tile).

Block sizes must tile the operands exactly; the wrapper raises
``ValueError`` otherwise (callers — ``ops.matmul`` and the fusion layer —
fall back to the unfused/reference path instead of silently padding, which
would break the bit-identity contract above).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _matmul_kernel(x_ref, w_ref, o_ref, acc_ref, *, n_k: int):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _reset():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        x_ref[...].astype(jnp.float32),
        w_ref[...].astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )

    @pl.when(ki == n_k - 1)
    def _epilogue():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def matmul_pallas(
    x: jax.Array,   # (M, K)
    w: jax.Array,   # (K, N)
    *,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """Blocked ``x @ w`` with fp32 accumulation, output in ``x.dtype``.

    Requested block sizes are clipped to the operand dims; the clipped
    blocks must then divide ``(M, K, N)`` exactly (no padding — see module
    docstring).  Raises ``ValueError`` on non-divisible shapes so callers
    can take their unfused/reference fallback.
    """
    if x.ndim != 2 or w.ndim != 2 or x.shape[1] != w.shape[0]:
        raise ValueError(
            f"matmul_pallas: need (M,K)@(K,N), got {x.shape} @ {w.shape}"
        )
    M, K = x.shape
    N = w.shape[1]
    if M == 0 or K == 0 or N == 0:
        raise ValueError(f"matmul_pallas: empty operand {x.shape} @ {w.shape}")
    bm, bk, bn = min(block_m, M), min(block_k, K), min(block_n, N)
    if M % bm or K % bk or N % bn:
        raise ValueError(
            f"matmul_pallas: blocks ({bm},{bk},{bn}) do not tile "
            f"({M},{K},{N}) exactly"
        )
    n_k = K // bk
    return pl.pallas_call(
        functools.partial(_matmul_kernel, n_k=n_k),
        grid=(M // bm, N // bn, n_k),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, w)
