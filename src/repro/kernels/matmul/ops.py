"""Jit'd entry point for the blocked matmul: Pallas kernel or jnp oracle.

Same dispatcher contract as the other kernel packages: ``use_pallas``
selects the kernel, ``PCCL_VERIFY=1`` runs the static kernel analyzer on
the exact shapes about to execute (memoized per signature), and
``interpret`` defaults to interpret mode on CPU.  Shapes the requested
blocks cannot tile exactly fall back to the reference (the kernel refuses
to pad — see ``kernel.py``); ``tiles_exactly`` exposes that predicate so
the fusion layer can decide *before* building a fused executable.
"""

from __future__ import annotations

import os
from typing import Optional

import jax

from .ref import matmul_reference


def tiles_exactly(
    M: int, K: int, N: int,
    *, block_m: int = 128, block_n: int = 128, block_k: int = 128,
) -> bool:
    """True iff the (clipped) blocks tile ``(M, K, N)`` with no remainder."""
    if M == 0 or K == 0 or N == 0:
        return False
    bm, bk, bn = min(block_m, M), min(block_k, K), min(block_n, N)
    return not (M % bm or K % bk or N % bn)


def matmul(
    x: jax.Array,
    w: jax.Array,
    *,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 128,
    use_pallas: bool = False,
    interpret: Optional[bool] = None,
) -> jax.Array:
    if not use_pallas or not tiles_exactly(
        x.shape[0], x.shape[1], w.shape[1],
        block_m=block_m, block_n=block_n, block_k=block_k,
    ):
        return matmul_reference(x, w)
    from .kernel import matmul_pallas

    if os.environ.get("PCCL_VERIFY", "0") not in ("", "0"):
        from ...analysis.kernel_lint import verify_entry_point

        verify_entry_point(
            "matmul", matmul_pallas, (x, w),
            dict(block_m=block_m, block_n=block_n, block_k=block_k),
        )
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    return matmul_pallas(
        x, w, block_m=block_m, block_n=block_n, block_k=block_k,
        interpret=interpret,
    )
