"""jnp oracle for the blocked matmul kernel.

Numerically equivalent (fp32 accumulation, output cast to the input dtype)
but *not* bit-identical to the blocked kernel when ``K > block_k`` — XLA's
contraction order differs from the kernel's per-K-block accumulation.
Bit-level checks therefore compare blocked-vs-blocked (whole-``M`` call vs
per-chunk calls at the same block sizes, see ``kernel.py``); this reference
carries the allclose-level correctness tests.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def matmul_reference(x: jax.Array, w: jax.Array) -> jax.Array:
    out = jnp.dot(
        x.astype(jnp.float32),
        w.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )
    return out.astype(x.dtype)
