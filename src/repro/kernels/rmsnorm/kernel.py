"""Pallas TPU kernel: fused RMSNorm × weight.

Grid over row blocks of the flattened (rows, d) input; each program loads a
(block_rows, d) tile into VMEM, reduces in fp32, scales by the (d,)-broadcast
weight, and writes the tile back — one HBM round-trip instead of the three
(square-reduce / rsqrt-mul / weight-mul) an unfused lowering can incur.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, w_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)               # (rows, d)
    w = w_ref[...].astype(jnp.float32)               # (1, d)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    o_ref[...] = (x * jax.lax.rsqrt(var + eps) * w).astype(o_ref.dtype)


def rmsnorm_pallas(
    x: jax.Array,
    w: jax.Array,
    *,
    eps: float = 1e-5,
    block_rows: int = 256,
    interpret: bool = False,
) -> jax.Array:
    orig_shape = x.shape
    d = x.shape[-1]
    rows = x.size // d
    xf = x.reshape(rows, d)
    block_rows = min(block_rows, rows)
    pad = (-rows) % block_rows
    if pad:
        xf = jnp.pad(xf, ((0, pad), (0, 0)))
    grid = (xf.shape[0] // block_rows,)
    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((1, d), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(xf.shape, x.dtype),
        interpret=interpret,
    )(xf, w.reshape(1, d))
    if pad:
        out = out[:rows]
    return out.reshape(orig_shape)
