"""Pallas TPU kernel: fused RMSNorm × weight.

Grid over row blocks of the flattened (rows, d) input; each program loads a
(block_rows, d) tile into VMEM, reduces in fp32, scales by the (d,)-broadcast
weight, and writes the tile back — one HBM round-trip instead of the three
(square-reduce / rsqrt-mul / weight-mul) an unfused lowering can incur.

Both axes are padded to legal tile shapes: rows up to a multiple of
``block_rows``, and the feature axis up to a multiple of the 128-lane VPU
width.  The lane padding is zeros, which contribute exactly 0.0 to the
square-sum, so dividing by the *true* ``d`` (not the padded width) keeps the
numerics bit-identical to the unpadded mean.  Degenerate inputs
(``rows == 0`` or ``d == 0``) raise ``ValueError`` instead of building an
empty grid.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANE = 128  # TPU VPU lane width: the last tile dim must be a multiple


def _rmsnorm_kernel(x_ref, w_ref, o_ref, *, eps: float, d: int):
    x = x_ref[...].astype(jnp.float32)               # (rows, d_padded)
    w = w_ref[...].astype(jnp.float32)               # (1, d_padded)
    # zero lane-padding adds 0.0 to the sum; dividing by the true d gives
    # exactly the mean over the real features
    var = jnp.sum(jnp.square(x), axis=-1, keepdims=True) / d
    o_ref[...] = (x * jax.lax.rsqrt(var + eps) * w).astype(o_ref.dtype)


def rmsnorm_pallas(
    x: jax.Array,
    w: jax.Array,
    *,
    eps: float = 1e-5,
    block_rows: int = 256,
    interpret: bool = False,
) -> jax.Array:
    orig_shape = x.shape
    d = x.shape[-1]
    if d == 0:
        raise ValueError(f"rmsnorm_pallas: feature dim is 0 (shape {orig_shape})")
    rows = x.size // d
    if rows == 0:
        raise ValueError(
            f"rmsnorm_pallas: input has no rows (shape {orig_shape}); "
            "an empty batch would build an empty Pallas grid"
        )
    if w.size != d:
        raise ValueError(
            f"rmsnorm_pallas: weight size {w.size} != feature dim {d}"
        )
    xf = x.reshape(rows, d)
    wf = w.reshape(1, d)
    lane_pad = (-d) % LANE
    if lane_pad:
        xf = jnp.pad(xf, ((0, 0), (0, lane_pad)))
        wf = jnp.pad(wf, ((0, 0), (0, lane_pad)))
    dp = d + lane_pad
    block_rows = min(block_rows, rows)
    row_pad = (-rows) % block_rows
    if row_pad:
        xf = jnp.pad(xf, ((0, row_pad), (0, 0)))
    grid = (xf.shape[0] // block_rows,)
    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps, d=d),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, dp), lambda i: (i, 0)),
            pl.BlockSpec((1, dp), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, dp), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(xf.shape, x.dtype),
        interpret=interpret,
    )(xf, wf)
    if row_pad or lane_pad:
        out = out[:rows, :d]
    return out.reshape(orig_shape)
