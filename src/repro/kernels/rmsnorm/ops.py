"""Jit'd entry point for fused RMSNorm: Pallas kernel or jnp oracle."""

from __future__ import annotations

import os
from typing import Optional

import jax

from .ref import rmsnorm_reference


def rmsnorm(
    x: jax.Array,
    w: jax.Array,
    *,
    eps: float = 1e-5,
    use_pallas: bool = False,
    interpret: Optional[bool] = None,
) -> jax.Array:
    if not use_pallas:
        return rmsnorm_reference(x, w, eps=eps)
    from .kernel import rmsnorm_pallas

    if os.environ.get("PCCL_VERIFY", "0") not in ("", "0"):
        from ...analysis.kernel_lint import verify_entry_point

        verify_entry_point("rmsnorm", rmsnorm_pallas, (x, w), dict(eps=eps))
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    return rmsnorm_pallas(x, w, eps=eps, interpret=interpret)
