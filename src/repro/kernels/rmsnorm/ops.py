"""Jit'd entry point for fused RMSNorm: Pallas kernel or jnp oracle."""

from __future__ import annotations

from typing import Optional

import jax

from .ref import rmsnorm_reference


def rmsnorm(
    x: jax.Array,
    w: jax.Array,
    *,
    eps: float = 1e-5,
    use_pallas: bool = False,
    interpret: Optional[bool] = None,
) -> jax.Array:
    if not use_pallas:
        return rmsnorm_reference(x, w, eps=eps)
    from .kernel import rmsnorm_pallas

    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    return rmsnorm_pallas(x, w, eps=eps, interpret=interpret)
