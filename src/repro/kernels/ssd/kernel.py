"""Pallas TPU kernel for the Mamba-2 SSD chunked scan.

Grid: (batch · heads, num_chunks).  The chunk axis is the *innermost* grid
dimension, which Pallas TPU iterates sequentially — the running recurrent
state R (P × N, fp32) lives in a VMEM scratch buffer and is carried across
chunk steps of the same (batch, head) program, reset when the chunk index
wraps to 0.  Per chunk the kernel computes

    Y_diag = ((C Bᵀ) ⊙ exp(cum_t − cum_s) tril) X        (intra-chunk, MXU)
    Y_off  = exp(cum_t) · (C R)                           (cross-chunk)
    R'     = exp(total) · R + Σ_s exp(total − cum_s) X_s ⊗ B_s

Tile sizes: chunk length L × head_dim P and L × state N — L defaults to 128
(MXU-aligned); P/N are the model's head_dim/d_state (128/64 for the assigned
archs → aligned or half-aligned lanes).

B/C must be per-head here ((B,S,H,N)); the shared-across-heads layout of
Mamba-2's n_groups=1 is expanded by ``ops.ssd`` only when the Pallas path is
selected.  Validated against ``ref.ssd_reference`` in interpret mode.
"""

from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(
    x_ref,      # (L, P)
    la_ref,     # (L, 1)
    b_ref,      # (L, N)
    c_ref,      # (L, N)
    y_ref,      # (L, P)
    fin_ref,    # (P, N) final-state output
    state_ref,  # (P, N) fp32 VMEM scratch — running inter-chunk state
):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[...].astype(jnp.float32)          # (L, P)
    la = la_ref[...].astype(jnp.float32)        # (L, 1)
    b = b_ref[...].astype(jnp.float32)          # (L, N)
    c = c_ref[...].astype(jnp.float32)          # (L, N)
    L = x.shape[0]

    cum = jnp.cumsum(la, axis=0)                # (L, 1)
    total = cum[L - 1, 0]

    # intra-chunk
    dec = cum - cum.T                           # (L, L): cum_t - cum_s
    tri = jax.lax.broadcasted_iota(jnp.int32, (L, L), 0) >= jax.lax.broadcasted_iota(
        jnp.int32, (L, L), 1
    )
    w = jnp.where(tri, jnp.exp(dec), 0.0) * (c @ b.T)
    y = w @ x                                   # (L, P)

    # cross-chunk using state BEFORE this chunk
    R = state_ref[...]                          # (P, N)
    y = y + jnp.exp(cum) * (c @ R.T)

    # update state
    decay_to_end = jnp.exp(total - cum)         # (L, 1)
    new_state = jnp.exp(total) * R + (x * decay_to_end).T @ b
    state_ref[...] = new_state
    y_ref[...] = y.astype(y_ref.dtype)

    nc = pl.num_programs(1)

    @pl.when(ci == nc - 1)
    def _emit():
        fin_ref[...] = new_state.astype(fin_ref.dtype)


def ssd_pallas(
    X: jax.Array,    # (B, S, H, P)
    la: jax.Array,   # (B, S, H)
    Bm: jax.Array,   # (B, S, N) or (B, S, H, N)
    Cm: jax.Array,   # same as Bm
    *,
    chunk: int = 128,
    initial_state=None,
    interpret: bool = False,
):
    assert initial_state is None, "pallas path starts from zero state"
    B, S, H, P = X.shape
    if Bm.ndim == 3:
        Bm = jnp.broadcast_to(Bm[:, :, None, :], (B, S, H, Bm.shape[-1]))
        Cm = jnp.broadcast_to(Cm[:, :, None, :], (B, S, H, Cm.shape[-1]))
    N = Bm.shape[-1]
    orig_S = S
    if S % chunk:
        pad = chunk - S % chunk
        X = jnp.pad(X, ((0, 0), (0, pad), (0, 0), (0, 0)))
        la = jnp.pad(la, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        S = X.shape[1]
    nc = S // chunk

    xb = X.transpose(0, 2, 1, 3).reshape(B * H, S, P)
    lab = la.transpose(0, 2, 1).reshape(B * H, S, 1)
    bb = Bm.transpose(0, 2, 1, 3).reshape(B * H, S, N)
    cb = Cm.transpose(0, 2, 1, 3).reshape(B * H, S, N)

    grid = (B * H, nc)
    y, fin = pl.pallas_call(
        _ssd_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, chunk, P), lambda h, c: (h, c, 0)),
            pl.BlockSpec((None, chunk, 1), lambda h, c: (h, c, 0)),
            pl.BlockSpec((None, chunk, N), lambda h, c: (h, c, 0)),
            pl.BlockSpec((None, chunk, N), lambda h, c: (h, c, 0)),
        ],
        out_specs=[
            pl.BlockSpec((None, chunk, P), lambda h, c: (h, c, 0)),
            pl.BlockSpec((None, P, N), lambda h, c: (h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B * H, S, P), X.dtype),
            jax.ShapeDtypeStruct((B * H, P, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        interpret=interpret,
    )(xb, lab, bb, cb)
    Y = y.reshape(B, H, S, P).transpose(0, 2, 1, 3)[:, :orig_S]
    final = fin.reshape(B, H, P, N)
    return Y, final
