"""Jit'd entry points for the SSD scan: Pallas kernel or jnp oracle.

Models call :func:`ssd`; ``use_pallas=True`` routes to the Pallas TPU kernel
(``kernel.py``, validated in interpret mode on CPU), otherwise the pure-jnp
reference (`ref.py`) — identical math, XLA-fused.
"""

from __future__ import annotations

import os
from typing import Optional, Tuple

import jax

from .ref import ssd_decode_step, ssd_reference


def ssd(
    X: jax.Array,
    la: jax.Array,
    Bm: jax.Array,
    Cm: jax.Array,
    *,
    chunk: int = 64,
    initial_state: Optional[jax.Array] = None,
    use_pallas: bool = False,
    interpret: Optional[bool] = None,
) -> Tuple[jax.Array, jax.Array]:
    if use_pallas:
        from .kernel import ssd_pallas

        if os.environ.get("PCCL_VERIFY", "0") not in ("", "0"):
            from ...analysis.kernel_lint import verify_entry_point

            verify_entry_point(
                "ssd", ssd_pallas, (X, la, Bm, Cm),
                dict(chunk=chunk, initial_state=initial_state),
            )
        if interpret is None:
            interpret = jax.default_backend() == "cpu"
        return ssd_pallas(
            X, la, Bm, Cm, chunk=chunk, initial_state=initial_state,
            interpret=interpret,
        )
    return ssd_reference(X, la, Bm, Cm, chunk=chunk, initial_state=initial_state)


__all__ = ["ssd", "ssd_decode_step"]
