"""Pure-jnp oracle for the Mamba-2 SSD chunked scan.

State space:  h_t = exp(la_t) · h_{t-1} + X_t ⊗ B_t ,   y_t = C_t · h_t
with per-(step, head) log-decay ``la`` (= dt·a for Mamba-2, = log f for
mLSTM-style gated linear attention) and pre-weighted inputs ``X`` (= dt·x for
Mamba-2, = i·v for mLSTM).

The chunked algorithm (chunk length L):
  * intra-chunk: Y_diag[t] = Σ_{s≤t, same chunk} exp(cum_t − cum_s)(C_t·B_s) X_s
  * chunk states: S_c = Σ_s exp(cum_last − cum_s) X_s ⊗ B_s
  * inter-chunk recurrence: R_{c+1} = exp(Σ la_c)·R_c + S_c   (lax.scan)
  * cross-chunk output: Y_off[t] = C_t · (exp(cum_t)·R_c)

B/C may be per-head (B,S,H,N) or shared across heads (B,S,N).
Returns (Y (B,S,H,P), final_state (B,H,P,N)).
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def _bc_expand(m: jax.Array, H: int) -> jax.Array:
    if m.ndim == 3:  # (B,S,N) shared across heads
        return m[:, :, None, :]
    return m  # (B,S,H,N)


def ssd_reference(
    X: jax.Array,            # (B,S,H,P) pre-weighted inputs
    la: jax.Array,           # (B,S,H)   log decay per step
    Bm: jax.Array,           # (B,S,N) or (B,S,H,N)
    Cm: jax.Array,           # (B,S,N) or (B,S,H,N)
    *,
    chunk: int = 64,
    initial_state: Optional[jax.Array] = None,  # (B,H,P,N)
) -> Tuple[jax.Array, jax.Array]:
    B, S, H, P = X.shape
    orig_S = S
    if S % chunk:
        pad = chunk - S % chunk
        X = jnp.pad(X, ((0, 0), (0, pad), (0, 0), (0, 0)))
        la = jnp.pad(la, ((0, 0), (0, pad), (0, 0)))
        pad_spec = ((0, 0), (0, pad)) + ((0, 0),) * (Bm.ndim - 2)
        Bm = jnp.pad(Bm, pad_spec)
        Cm = jnp.pad(Cm, pad_spec)
        S = X.shape[1]
    L = chunk
    nc = S // L
    N = Bm.shape[-1]

    f32 = jnp.float32
    Xc = X.reshape(B, nc, L, H, P).astype(f32)
    lac = la.reshape(B, nc, L, H).astype(f32)
    Bc = _bc_expand(Bm, H).reshape(B, nc, L, -1, N).astype(f32)
    Cc = _bc_expand(Cm, H).reshape(B, nc, L, -1, N).astype(f32)
    Hb = Bc.shape[3]  # 1 (shared) or H

    cum = jnp.cumsum(lac, axis=2)                              # (B,nc,L,H)
    total = cum[:, :, -1, :]                                   # (B,nc,H)

    # intra-chunk: decay[t,s] = exp(cum_t - cum_s) for s<=t
    dec = cum[:, :, :, None, :] - cum[:, :, None, :, :]        # (B,nc,t,s,H)
    tri = jnp.tril(jnp.ones((L, L), bool))
    dec = jnp.where(tri[None, None, :, :, None], jnp.exp(dec), 0.0)
    scores = jnp.einsum("bclgn,bcmgn->bclmg", Cc, Bc)          # (B,nc,L,L,Hb)
    if Hb == 1:
        scores = jnp.broadcast_to(scores, scores.shape[:-1] + (H,))
    w = scores * dec                                           # (B,nc,L,L,H)
    Y_diag = jnp.einsum("bclmh,bcmhp->bclhp", w, Xc)

    # chunk states: S_c = Σ_s exp(total - cum_s) X_s ⊗ B_s   → (B,nc,H,P,N)
    decay_to_end = jnp.exp(total[:, :, None, :] - cum)         # (B,nc,L,H)
    Xw = Xc * decay_to_end[..., None]
    if Hb == 1:
        states = jnp.einsum("bclhp,bclgn->bchpn", Xw, Bc)  # g==1 summed out
    else:
        states = jnp.einsum("bclhp,bclhn->bchpn", Xw, Bc)

    # inter-chunk recurrence
    init = (
        jnp.zeros((B, H, P, N), f32)
        if initial_state is None
        else initial_state.astype(f32)
    )

    def step(carry, inp):
        st, tot = inp                                          # (B,H,P,N),(B,H)
        new = carry * jnp.exp(tot)[:, :, None, None] + st
        return new, carry                                      # emit state BEFORE chunk

    final, R = jax.lax.scan(
        step,
        init,
        (states.swapaxes(0, 1), total.swapaxes(0, 1)),
    )
    R = R.swapaxes(0, 1)                                       # (B,nc,H,P,N)

    # cross-chunk output: C_t · (exp(cum_t) · R_c)
    if Hb == 1:
        Y_off = jnp.einsum("bclgn,bchpn->bclhp", Cc, R)  # g broadcasts (g==1)
    else:
        Y_off = jnp.einsum("bclhn,bchpn->bclhp", Cc, R)
    Y_off = Y_off * jnp.exp(cum)[..., None]

    Y = (Y_diag + Y_off).reshape(B, S, H, P)[:, :orig_S]
    return Y.astype(X.dtype), final.astype(X.dtype)


def ssd_decode_step(
    state: jax.Array,        # (B,H,P,N)
    x: jax.Array,            # (B,H,P) pre-weighted input (dt·x)
    la: jax.Array,           # (B,H)   log decay
    Bm: jax.Array,           # (B,N) or (B,H,N)
    Cm: jax.Array,           # (B,N) or (B,H,N)
) -> Tuple[jax.Array, jax.Array]:
    """Single recurrent step: O(1) in context length (long_500k decode)."""
    f32 = jnp.float32
    if Bm.ndim == 2:
        Bm = Bm[:, None, :]
    if Cm.ndim == 2:
        Cm = Cm[:, None, :]
    st = state.astype(f32) * jnp.exp(la.astype(f32))[:, :, None, None]
    st = st + jnp.einsum("bhp,bhn->bhpn", x.astype(f32), jnp.broadcast_to(Bm, (x.shape[0], x.shape[1], Bm.shape[-1])).astype(f32))
    y = jnp.einsum("bhpn,bhn->bhp", st, jnp.broadcast_to(Cm, (x.shape[0], x.shape[1], Cm.shape[-1])).astype(f32))
    return y.astype(x.dtype), st.astype(state.dtype)
