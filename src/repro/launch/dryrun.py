import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 " + os.environ.get("XLA_FLAGS", "")
)

"""Multi-pod dry-run (assignment deliverable e).

For every (architecture × applicable input shape × mesh) cell this lowers the
cell's step function — ``train_step`` for train shapes, ``prefill`` for
prefill shapes, ``serve_step`` for decode shapes — with sharding-annotated
ShapeDtypeStructs (no allocation), runs ``.lower().compile()``, and records

  * ``memory_analysis``   (fits-per-device evidence),
  * ``cost_analysis``     (FLOPs / bytes for §Roofline),
  * per-collective wire bytes parsed from the optimized HLO,

into ``results/dryrun/<arch>__<shape>__<mesh>.json`` (incremental: existing
results are skipped unless --force).

Usage:
  python -m repro.launch.dryrun --all                 # every live cell, both meshes
  python -m repro.launch.dryrun --arch olmoe-1b-7b --shape train_4k --mesh multi
  python -m repro.launch.dryrun --list
"""

import argparse
import json
import pathlib
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, SHAPES, get_config, shape_applicable
from repro.launch import hlo_analysis as H
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import batch_specs, decode_specs, param_specs
from repro.models import build_model
from repro.sharding import default_rules, use_partitioning
from repro.train.optimizer import OptimizerConfig, OptState
from repro.train.train_step import make_serve_step, make_train_step

RESULTS = pathlib.Path(__file__).resolve().parents[3] / "results" / "dryrun"


def _opt_state_specs(param_sds):
    """OptState SDS tree: fp32 moments with the same shardings as params."""
    mu = jax.tree.map(
        lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32, sharding=p.sharding),
        param_sds,
    )
    step = jax.ShapeDtypeStruct((), jnp.int32)
    return OptState(step=step, mu=mu, nu=mu)


def run_cell(arch: str, shape_name: str, mesh_kind: str, *, verbose: bool = True):
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, reason = shape_applicable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
                "status": "skipped", "reason": reason}

    multi = mesh_kind == "multi"
    mesh = make_production_mesh(multi_pod=multi)
    chips = int(np.prod(list(mesh.shape.values())))
    rules = default_rules(multi_pod=multi, fsdp=True)

    t0 = time.time()
    with use_partitioning(mesh, rules):
        model = build_model(cfg)
        p_sds, p_shardings = param_specs(cfg, mesh, rules)

        if shape.kind == "train":
            step = make_train_step(model, OptimizerConfig())
            opt_sds = _opt_state_specs(p_sds)
            b_sds = batch_specs(cfg, shape, mesh, rules)
            fn = jax.jit(step, donate_argnums=(0, 1))
            lowered = fn.lower(p_sds, opt_sds, b_sds)
        elif shape.kind == "prefill":
            fn = jax.jit(model.prefill)
            b_sds = batch_specs(cfg, shape, mesh, rules)
            lowered = fn.lower(p_sds, b_sds)
        else:  # decode
            step = make_serve_step(model)
            tok_sds, state_sds = decode_specs(cfg, shape, mesh, rules)
            fn = jax.jit(step, donate_argnums=(1,))
            lowered = fn.lower(p_sds, state_sds, tok_sds)
        t_lower = time.time() - t0

        t1 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t1

    rl, stats = H.roofline_from_compiled(compiled, chips)
    mem = H.memory_analysis_dict(compiled)
    n_params = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(p_sds))

    rec = {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_kind,
        "chips": chips,
        "status": "ok",
        "n_params": n_params,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "roofline": rl.as_dict(),
        "collectives": {
            "bytes_by_op": stats.bytes_by_op,
            "count_by_op": stats.count_by_op,
        },
        "memory": mem,
    }
    if verbose:
        print(
            f"[{arch} × {shape_name} × {mesh_kind}] OK "
            f"compile={t_compile:.1f}s flops={rl.flops:.3e} "
            f"coll={rl.collective_bytes:.3e}B dominant={rl.dominant}"
        )
    return rec


def cell_path(arch, shape_name, mesh_kind) -> pathlib.Path:
    return RESULTS / f"{arch}__{shape_name}__{mesh_kind}.json"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default=None, choices=[None, "single", "multi"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--list", action="store_true")
    args = ap.parse_args()

    RESULTS.mkdir(parents=True, exist_ok=True)

    archs = [args.arch] if args.arch else ARCH_IDS
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = [args.mesh] if args.mesh else ["single", "multi"]

    if args.list:
        for a in archs:
            cfg = get_config(a)
            for s in shapes:
                ok, why = shape_applicable(cfg, SHAPES[s])
                print(f"{a:24s} {s:12s} {'RUN' if ok else 'SKIP: ' + why}")
        return

    n_fail = 0
    for a in archs:
        for s in shapes:
            for m in meshes:
                path = cell_path(a, s, m)
                if path.exists() and not args.force:
                    continue
                try:
                    rec = run_cell(a, s, m)
                except Exception as e:  # record the failure; keep going
                    rec = {
                        "arch": a, "shape": s, "mesh": m, "status": "error",
                        "error": repr(e),
                        "traceback": traceback.format_exc()[-4000:],
                    }
                    n_fail += 1
                    print(f"[{a} × {s} × {m}] FAILED: {e}")
                path.write_text(json.dumps(rec, indent=2))
    print(f"dry-run sweep complete; failures={n_fail}")


if __name__ == "__main__":
    main()
