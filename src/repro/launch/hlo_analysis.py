"""Post-SPMD HLO analysis: collective bytes + roofline terms.

The compiled (optimized, partitioned) HLO text is the ground truth for what
the fabric actually moves: ``cost_analysis`` has no per-collective numbers,
so we parse every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute instruction and sum operand sizes (assignment ROOFLINE
ANALYSIS).

Hardware constants (TPU v5e, per assignment): 197 TFLOP/s bf16, 819 GB/s
HBM, ~50 GB/s/link ICI.
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple

PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # bytes/s per chip
LINK_BW = 50e9               # bytes/s per ICI link

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16, "token": 0,
}

COLLECTIVE_OPS = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

# e.g.  bf16[16,256,128]{2,1,0}  or  f32[]  — capture dtype + dims
_TYPE_RE = re.compile(r"\b([a-z][a-z0-9]*)\[([0-9,]*)\]")
# an HLO instruction line:  %name = <result type(s)> op-name(...)
_INSTR_RE = re.compile(
    r"=\s*(\([^)]*\)|[a-z][a-z0-9]*\[[0-9,]*\](?:\{[^}]*\})?)\s+([a-z0-9-]+)\("
)
# replica_groups=[16,16]<=[256]   (16 groups × 16 devices)
_GROUPS_COMPACT_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
# replica_groups={{0,1,2,3},{4,5,6,7}}
_GROUPS_EXPLICIT_RE = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")


def _types_bytes(type_str: str) -> int:
    total = 0
    for m in _TYPE_RE.finditer(type_str):
        b = _DTYPE_BYTES.get(m.group(1))
        if b is None:
            continue
        n = 1
        if m.group(2).strip():
            for d in m.group(2).split(","):
                n *= int(d)
        total += n * b
    return total


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_COMPACT_RE.search(line)
    if m:
        return max(int(m.group(2)), 1)
    m = _GROUPS_EXPLICIT_RE.search(line)
    if m:
        return max(len(m.group(1).split(",")), 1)
    return default


@dataclass
class CollectiveStats:
    """Per-device *wire* bytes by collective type.

    The post-SPMD HLO shows only result types, so wire traffic is derived
    per op from the result size R and replica-group size S under the
    standard (ring) algorithms each backend uses:
      all-gather:         (S-1)/S · R           (receives all other shards)
      all-reduce:         2 · (S-1)/S · R       (reduce-scatter + all-gather)
      reduce-scatter:     (S-1) · R             (input = S·R, sends all but own)
      all-to-all:         (S-1)/S · R
      collective-permute: R
    This is strictly more faithful than summing raw operand sizes (which the
    optimized dump does not even carry) — noted in EXPERIMENTS.md §Roofline.
    """

    bytes_by_op: Dict[str, int] = field(default_factory=dict)
    count_by_op: Dict[str, int] = field(default_factory=dict)

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_op.values())

    @property
    def total_count(self) -> int:
        return sum(self.count_by_op.values())


_WIRE_FACTOR = {
    "all-gather": lambda S: (S - 1) / S,
    "all-reduce": lambda S: 2 * (S - 1) / S,
    "reduce-scatter": lambda S: (S - 1),
    "all-to-all": lambda S: (S - 1) / S,
    "collective-permute": lambda S: 1.0,
}


def collective_stats(hlo_text: str, default_group: int = 2) -> CollectiveStats:
    st = CollectiveStats()
    for line in hlo_text.splitlines():
        m = _INSTR_RE.search(line)
        if not m:
            continue
        op = m.group(2)
        if op.endswith("-done"):
            continue  # async pair: the -start carries the semantics
        name = None
        for c in COLLECTIVE_OPS:
            if op == c or op == c + "-start":
                name = c
                break
        if name is None:
            continue
        result_bytes = _types_bytes(m.group(1))
        S = _group_size(line, default_group)
        wire = int(result_bytes * _WIRE_FACTOR[name](S))
        st.bytes_by_op[name] = st.bytes_by_op.get(name, 0) + wire
        st.count_by_op[name] = st.count_by_op.get(name, 0) + 1
    return st


@dataclass
class Roofline:
    flops: float                  # total HLO flops (all chips)
    hbm_bytes: float              # total bytes accessed (all chips)
    collective_bytes: float       # wire bytes (all chips)
    chips: int

    @property
    def compute_s(self) -> float:
        return self.flops / (self.chips * PEAK_FLOPS)

    @property
    def memory_s(self) -> float:
        return self.hbm_bytes / (self.chips * HBM_BW)

    @property
    def collective_s(self) -> float:
        return self.collective_bytes / (self.chips * LINK_BW)

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    def as_dict(self) -> Dict:
        return {
            "flops": self.flops,
            "hbm_bytes": self.hbm_bytes,
            "collective_bytes": self.collective_bytes,
            "chips": self.chips,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
        }


def roofline_from_compiled(compiled, chips: int) -> Tuple[Roofline, CollectiveStats]:
    """cost_analysis reports PER-PARTITION numbers for SPMD modules (verified
    against a hand-checked sharded matmul) — scale by chips for totals."""
    cost = compiled.cost_analysis()
    if isinstance(cost, list):  # older jax returns [dict]
        cost = cost[0]
    flops = float(cost.get("flops", 0.0)) * chips
    hbm = float(cost.get("bytes accessed", 0.0)) * chips
    stats = collective_stats(compiled.as_text())
    rl = Roofline(
        flops=flops,
        hbm_bytes=hbm,
        collective_bytes=float(stats.total_bytes) * chips,
        chips=chips,
    )
    return rl, stats


def memory_analysis_dict(compiled) -> Dict[str, Optional[int]]:
    try:
        ma = compiled.memory_analysis()
    except Exception:
        return {}
    if ma is None:
        return {}
    out = {}
    for k in (
        "argument_size_in_bytes",
        "output_size_in_bytes",
        "temp_size_in_bytes",
        "alias_size_in_bytes",
        "generated_code_size_in_bytes",
    ):
        out[k] = int(getattr(ma, k, 0) or 0)
    return out


def model_flops(cfg, shape, n_params: int, n_active: Optional[int] = None) -> float:
    """MODEL_FLOPS = 6·N·D (dense) or 6·N_active·D (MoE); D = tokens."""
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        mult = 6.0
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        mult = 2.0
    else:  # decode: one token per sequence
        tokens = shape.global_batch * 1
        mult = 2.0
    n = n_active if n_active is not None else n_params
    return mult * n * tokens
