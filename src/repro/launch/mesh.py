"""Production mesh builders (assignment MULTI-POD DRY-RUN step 1).

Functions, not module-level constants, so importing this module never touches
jax device state.  Single pod = 16×16 = 256 chips (data × model); multi-pod
adds a leading "pod" axis: 2×16×16 = 512 chips.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax

from repro import compat


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    import math

    n = math.prod(shape)
    devices = jax.devices()[:n]  # dry-run forces 512 host devices; 1 pod uses 256
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices, have {len(devices)} — run under "
            f"XLA_FLAGS=--xla_force_host_platform_device_count=512 (dryrun.py sets this)"
        )
    return compat.make_mesh(shape, axes, devices=devices)


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]):
    return compat.make_mesh(shape, axes)


def make_host_mesh(n: Optional[int] = None, axis: str = "data"):
    """Small all-devices mesh for tests/examples on host devices."""
    n = n or len(jax.devices())
    return make_mesh((n,), (axis,))
