import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 " + os.environ.get("XLA_FLAGS", "")
)

"""§Perf hillclimbing driver (assignment PERFORMANCE HILLCLIMBING).

Three cells, chosen from the 32-cell baseline table:
  * olmoe-1b-7b × train_4k         — worst useful ratio (0.003), collective-
                                     bound; also the cell most representative
                                     of the paper (MoE AllToAll, Fig. 10a).
  * mistral-large-123b × train_4k  — biggest model, memory-dominated.
  * chatglm3-6b × decode_32k       — most collective-bound relative to
                                     compute (585 ms collective vs 1 ms).

Each variant re-runs the unrolled-depth roofline extraction with one change;
records land in results/perf/<arch>__<shape>__<variant>.json and the
hypothesis→change→before/after log is assembled in EXPERIMENTS.md §Perf.

Usage: python -m repro.launch.perf [--only <variant-prefix>] [--force]
"""

import argparse
import dataclasses
import json
import pathlib

from repro.api import PcclSession
from repro.core import cost_model as cm
from repro.launch.roofline import roofline_cell

RESULTS = pathlib.Path(__file__).resolve().parents[3] / "results" / "perf"

# HLO collective op → PCCL primitive (collective-permute priced as a direct
# circuit below; it is a p2p under PCCL, not a planned collective).
_COLLECTIVE_OF_OP = {
    "all-reduce": "all_reduce",
    "all-gather": "all_gather",
    "reduce-scatter": "reduce_scatter",
    "all-to-all": "all_to_all",
}


def pccl_pricing(bytes_by_op, chips, hw=cm.TPU_V5E_PHOTONIC):
    """Re-price a cell's HLO-extracted collective traffic with PCCL.

    One session per cell: fabric state threads across the step's collective
    types, exactly as a PCCL-scheduled job would run them back-to-back.  The
    per-device wire bytes stand in for the collective buffer size (a lower
    bound; good enough for the A/B ratio against the fixed-ring fabric the
    roofline's LINK_BW model assumes).
    """
    session = PcclSession(hw)
    pccl_s = 0.0
    fixed_s = 0.0
    by_op = {}
    for op, nbytes in sorted(bytes_by_op.items()):
        if nbytes <= 0:
            continue
        if op in _COLLECTIVE_OF_OP and chips >= 2:
            coll = _COLLECTIVE_OF_OP[op]
            planned = session.plan(coll, float(nbytes), n=chips).cost
            fixed = session.baseline(coll, "ring" if coll != "all_to_all" else "direct",
                                     float(nbytes), n=chips).total
        else:  # collective-permute / unknown: direct circuit vs 1-hop fixed
            planned = hw.reconfig_delay + hw.alpha + hw.beta * nbytes
            fixed = hw.alpha + hw.beta * nbytes
        pccl_s += planned
        fixed_s += fixed
        by_op[op] = {"bytes": float(nbytes), "pccl_s": planned, "fixed_s": fixed}
    return {
        "hw": hw.name,
        "pccl_comm_s": pccl_s,
        "fixed_comm_s": fixed_s,
        "speedup": (fixed_s / pccl_s) if pccl_s else None,
        "by_op": by_op,
        "plan_cache": dataclasses.asdict(session.stats),
    }


def _moe_dispatch(mode):
    def t(cfg):
        return dataclasses.replace(cfg, moe=dataclasses.replace(cfg.moe, dispatch=mode))
    return t


def _remat(policy):
    def t(cfg):
        return dataclasses.replace(cfg, remat=policy)
    return t


def _attn(impl):
    def t(cfg):
        return dataclasses.replace(cfg, attention_impl=impl)
    return t


def _compose(*ts):
    def t(cfg):
        for f in ts:
            cfg = f(cfg)
        return cfg
    return t


# (name, arch, shape, cfg_transform, fsdp)
# NOTE: opt variants are cumulative snapshots of the code at measurement
# time; earlier JSONs are kept as the hypothesis log (EXPERIMENTS.md §Perf).
VARIANTS = [
    # --- cell 1: olmoe train_4k ------------------------------------------
    ("olmoe_train/base_global_dispatch", "olmoe-1b-7b", "train_4k",
     _moe_dispatch("global"), True),
    ("olmoe_train/opt1_grouped_dispatch", "olmoe-1b-7b", "train_4k",
     _moe_dispatch("grouped"), True),
    ("olmoe_train/opt2_grouped_local_scatter_a2a", "olmoe-1b-7b", "train_4k",
     _moe_dispatch("grouped"), True),
    ("olmoe_train/opt3_plus_remat_dots", "olmoe-1b-7b", "train_4k",
     _compose(_moe_dispatch("grouped"), _remat("dots")), True),
    # --- cell 2: mistral-large train_4k ----------------------------------
    ("mistral_train/base_remat_full", "mistral-large-123b", "train_4k",
     None, True),
    ("mistral_train/opt1_remat_dots", "mistral-large-123b", "train_4k",
     _remat("dots"), True),
    ("mistral_train/opt2_remat_none", "mistral-large-123b", "train_4k",
     _remat("none"), True),
    # --- cell 3: chatglm3 decode_32k --------------------------------------
    ("chatglm_decode/base_fsdp_params", "chatglm3-6b", "decode_32k",
     None, True),
    ("chatglm_decode/opt1_serve_sharding_no_fsdp", "chatglm3-6b", "decode_32k",
     None, False),
    ("chatglm_decode/opt2_replicated_decode_q", "chatglm3-6b", "decode_32k",
     None, False),
    # --- bonus cell 4: chatglm3 prefill_32k (memory-bound: S² scores) ------
    ("chatglm_prefill/base_full_attention", "chatglm3-6b", "prefill_32k",
     _attn("full"), True),
    ("chatglm_prefill/opt1_blocked_attention", "chatglm3-6b", "prefill_32k",
     _attn("blocked"), True),
]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    RESULTS.mkdir(parents=True, exist_ok=True)
    for name, arch, shape, transform, fsdp in VARIANTS:
        if args.only and not name.startswith(args.only):
            continue
        path = RESULTS / (name.replace("/", "__") + ".json")
        if path.exists() and not args.force:
            continue
        try:
            rec = roofline_cell(arch, shape, cfg_transform=transform, fsdp=fsdp,
                                verbose=False)
            rec["variant"] = name
            if rec.get("status") == "ok":
                rec["pccl_pricing"] = pccl_pricing(
                    rec["collective_bytes_by_op"], rec["chips"]
                )
            rl = rec["roofline"]
            pccl = rec.get("pccl_pricing", {})
            print(f"[{name}] compute={rl['compute_s']*1e3:.1f}ms "
                  f"memory={rl['memory_s']*1e3:.1f}ms "
                  f"collective={rl['collective_s']*1e3:.1f}ms "
                  f"dominant={rl['dominant']} useful={rec['useful_ratio']:.3f} "
                  f"pccl_comm={pccl.get('pccl_comm_s', 0.0)*1e3:.1f}ms")
        except Exception as e:
            import traceback
            rec = {"variant": name, "status": "error", "error": repr(e),
                   "traceback": traceback.format_exc()[-3000:]}
            print(f"[{name}] FAILED: {e}")
        path.write_text(json.dumps(rec, indent=2))


if __name__ == "__main__":
    main()
