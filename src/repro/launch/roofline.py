import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 " + os.environ.get("XLA_FLAGS", "")
)

"""§Roofline term extraction (assignment deliverable g).

XLA's HLO cost analysis counts while-loop bodies ONCE, so scanned layer
stacks massively undercount FLOPs/bytes/collective traffic.  This module
therefore lowers each cell at two small UNROLLED depths (v₁, v₂), reads the
per-partition cost analysis + post-SPMD collective bytes for each, and
linearly extrapolates every metric to the full depth:

    m(v) = a + b·v   (exact: layer cost is depth-invariant; the intercept
                      captures embeddings/logits/loss/optimizer-of-embeddings)

Depth variable per family: plain layers (dense/moe/vlm), xLSTM groups of
``slstm_every``, Zamba2 groups of ``shared_attn_every``, whisper's joint
(enc, dec) depth.  The sLSTM time-scan cannot be unrolled (S steps); its
recurrent FLOPs are added analytically (noted in EXPERIMENTS.md).

Outputs: results/roofline/<arch>__<shape>.json with the three terms
(compute/memory/collective, seconds), the dominant term, MODEL_FLOPS, and
the usefulness ratio.  Single-pod mesh per the assignment.
"""

import argparse
import dataclasses
import json
import pathlib
import time
import traceback
from typing import Dict, Optional, Tuple

import jax
import numpy as np

from repro.configs import ARCH_IDS, SHAPES, get_config, shape_applicable
from repro.configs.base import ModelConfig, ShapeConfig
from repro.launch import hlo_analysis as H
from repro.launch.dryrun import _opt_state_specs
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import batch_specs, decode_specs, param_specs
from repro.models import build_model
from repro.sharding import default_rules, use_partitioning
from repro.train.optimizer import OptimizerConfig
from repro.train.train_step import make_serve_step, make_train_step

RESULTS = pathlib.Path(__file__).resolve().parents[3] / "results" / "roofline"


def depth_points(cfg: ModelConfig) -> Tuple[Dict[int, ModelConfig], int]:
    """{v: cfg_at_depth_v}, v_full — the linear depth variable per family."""
    if cfg.xlstm:
        u = cfg.xlstm.slstm_every
        mk = lambda v: dataclasses.replace(cfg, n_layers=v * u, scan_layers=False)
        return {1: mk(1), 2: mk(2)}, cfg.n_layers // u
    if cfg.hybrid:
        u = cfg.hybrid.shared_attn_every
        mk = lambda v: dataclasses.replace(cfg, n_layers=v * u, scan_layers=False)
        return {1: mk(1), 2: mk(2)}, cfg.n_layers // u
    if cfg.enc_dec:
        mk = lambda v: dataclasses.replace(
            cfg,
            n_layers=v,
            scan_layers=False,
            enc_dec=dataclasses.replace(cfg.enc_dec, n_enc_layers=v),
        )
        return {2: mk(2), 4: mk(4)}, cfg.n_layers
    if cfg.moe and cfg.moe.first_dense:
        mk = lambda v: dataclasses.replace(
            cfg, n_layers=cfg.moe.first_dense + v, scan_layers=False
        )
        return {2: mk(2), 4: mk(4)}, cfg.n_layers - cfg.moe.first_dense
    mk = lambda v: dataclasses.replace(cfg, n_layers=v, scan_layers=False)
    return {2: mk(2), 4: mk(4)}, cfg.n_layers


def lower_cell(cfg: ModelConfig, shape: ShapeConfig, multi_pod: bool, *, fsdp: bool = True):
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = int(np.prod(list(mesh.shape.values())))
    rules = default_rules(multi_pod=multi_pod, fsdp=fsdp)
    with use_partitioning(mesh, rules):
        model = build_model(cfg)
        p_sds, _ = param_specs(cfg, mesh, rules)
        if shape.kind == "train":
            step = make_train_step(model, OptimizerConfig())
            lowered = jax.jit(step, donate_argnums=(0, 1)).lower(
                p_sds, _opt_state_specs(p_sds), batch_specs(cfg, shape, mesh, rules)
            )
        elif shape.kind == "prefill":
            lowered = jax.jit(model.prefill).lower(
                p_sds, batch_specs(cfg, shape, mesh, rules)
            )
        else:
            tok, state = decode_specs(cfg, shape, mesh, rules)
            lowered = jax.jit(make_serve_step(model), donate_argnums=(1,)).lower(
                p_sds, state, tok
            )
        compiled = lowered.compile()
    return compiled, chips


def _slstm_correction_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """sLSTM time-scan body is counted once by HLO analysis; add the
    recurrent FLOPs analytically: per token ≈ 2·(4d² input proj + 4·d·dh
    recurrence), ×3 for backward in train."""
    if not cfg.xlstm or shape.kind == "decode":
        return 0.0
    d = cfg.d_model
    dh = d // cfg.n_heads
    n_slstm = cfg.n_layers // cfg.xlstm.slstm_every
    tokens = shape.global_batch * shape.seq_len
    per_tok = 2 * (4 * d * d + 4 * d * dh)
    mult = 3.0 if shape.kind == "train" else 1.0
    return mult * n_slstm * tokens * per_tok


def _active_params(cfg: ModelConfig, n_params: int) -> Optional[int]:
    if not cfg.moe:
        return None
    m = cfg.moe
    n_moe_layers = cfg.n_layers - m.first_dense
    per_expert = 3 * cfg.d_model * m.d_expert  # swiglu gate/up/down
    inactive = (m.n_experts - m.top_k) * per_expert * n_moe_layers
    return n_params - inactive


def roofline_cell(
    arch: str,
    shape_name: str,
    *,
    verbose: bool = True,
    cfg_transform=None,
    fsdp: bool = True,
) -> Dict:
    cfg = get_config(arch)
    if cfg_transform is not None:
        cfg = cfg_transform(cfg)
    shape = SHAPES[shape_name]
    ok, reason = shape_applicable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "status": "skipped", "reason": reason}

    points, v_full = depth_points(cfg)
    vs = sorted(points)
    metrics = {}
    t0 = time.time()
    for v in vs:
        compiled, chips = lower_cell(points[v], shape, multi_pod=False, fsdp=fsdp)
        rl, stats = H.roofline_from_compiled(compiled, chips)
        metrics[v] = {
            "flops": rl.flops,
            "hbm_bytes": rl.hbm_bytes,
            "collective_bytes": rl.collective_bytes,
            "bytes_by_op": stats.bytes_by_op,
        }
    v1, v2 = vs

    def extrap(key):
        m1, m2 = metrics[v1][key], metrics[v2][key]
        b = (m2 - m1) / (v2 - v1)
        a = m1 - b * v1
        return a + b * v_full

    flops = extrap("flops") + _slstm_correction_flops(cfg, shape)
    hbm = extrap("hbm_bytes")
    coll = extrap("collective_bytes")
    by_op = {
        k: (metrics[v2]["bytes_by_op"].get(k, 0) - metrics[v1]["bytes_by_op"].get(k, 0))
        / (v2 - v1) * v_full
        + metrics[v1]["bytes_by_op"].get(k, 0)
        - (metrics[v2]["bytes_by_op"].get(k, 0) - metrics[v1]["bytes_by_op"].get(k, 0))
        / (v2 - v1) * v1
        for k in set(metrics[v1]["bytes_by_op"]) | set(metrics[v2]["bytes_by_op"])
    }

    rl = H.Roofline(flops=flops, hbm_bytes=hbm, collective_bytes=coll, chips=chips)

    # MODEL_FLOPS from full param count
    model = build_model(cfg)
    boxed = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    from repro.models.module import unbox

    n_params = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(unbox(boxed)))
    n_active = _active_params(cfg, n_params)
    mf = H.model_flops(cfg, shape, n_params, n_active)

    rec = {
        "arch": arch,
        "shape": shape_name,
        "status": "ok",
        "chips": chips,
        "n_params": n_params,
        "n_active_params": n_active,
        "points": {str(v): metrics[v] for v in vs},
        "v_full": v_full,
        "roofline": rl.as_dict(),
        "collective_bytes_by_op": by_op,
        "model_flops": mf,
        "useful_ratio": mf / flops if flops else None,
        "elapsed_s": round(time.time() - t0, 1),
    }
    if verbose:
        print(
            f"[{arch} × {shape_name}] compute={rl.compute_s*1e3:.2f}ms "
            f"memory={rl.memory_s*1e3:.2f}ms collective={rl.collective_s*1e3:.2f}ms "
            f"dominant={rl.dominant} useful={rec['useful_ratio'] and round(rec['useful_ratio'],3)}"
        )
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    RESULTS.mkdir(parents=True, exist_ok=True)
    archs = [args.arch] if args.arch else ARCH_IDS
    shapes = [args.shape] if args.shape else list(SHAPES)
    for a in archs:
        for s in shapes:
            path = RESULTS / f"{a}__{s}.json"
            if path.exists() and not args.force:
                continue
            try:
                rec = roofline_cell(a, s)
            except Exception as e:
                rec = {"arch": a, "shape": s, "status": "error", "error": repr(e),
                       "traceback": traceback.format_exc()[-4000:]}
                print(f"[{a} × {s}] FAILED: {e}")
            path.write_text(json.dumps(rec, indent=2))


if __name__ == "__main__":
    main()
