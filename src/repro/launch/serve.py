"""Serving CLI: ``python -m repro.launch.serve --arch <id> --reduced``

Runs batched prefill + decode on a reduced config and reports tokens/s.
"""

from __future__ import annotations

import argparse
import time

import numpy as np

from repro.configs import get_config
from repro.serve.engine import EngineConfig, Request, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=16)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    eng = ServeEngine(cfg, EngineConfig(batch_size=args.batch,
                                        max_len=args.prompt_len + args.new_tokens))
    rng = np.random.default_rng(0)
    reqs = [
        Request(prompt=rng.integers(0, cfg.vocab, size=args.prompt_len).astype(np.int32),
                max_new_tokens=args.new_tokens)
        for _ in range(args.batch)
    ]
    t0 = time.perf_counter()
    out = eng.generate(reqs)
    dt = time.perf_counter() - t0
    total = sum(len(r.generated) for r in out)
    print(f"generated {total} tokens in {dt:.2f}s ({total/dt:.1f} tok/s, "
          f"batch={args.batch})")
    print("sample:", out[0].generated[:8])


if __name__ == "__main__":
    main()
