"""ShapeDtypeStruct input builders for every (arch × shape) cell.

``input_specs(cfg, shape)`` returns sharding-annotated ShapeDtypeStructs for
the step function that cell lowers (train/prefill → loss/prefill inputs;
decode → one-token batch + the decode-state tree).  No device memory is ever
allocated (assignment MULTI-POD DRY-RUN step 2).
"""

from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import build_model
from repro.models.module import axes_of, unbox
from repro.sharding import partition


def _sds(shape, dtype, axes, mesh, rules):
    with partition._installed(mesh, rules):
        spec = partition.spec_for(axes, shape)
    return jax.ShapeDtypeStruct(shape, dtype, sharding=NamedSharding(mesh, spec))


def batch_specs(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh, rules) -> Dict[str, Any]:
    """Training / prefill batch ShapeDtypeStructs."""
    B, S = shape.global_batch, shape.seq_len
    out: Dict[str, Any] = {}
    if cfg.vlm:
        n_img = cfg.vlm.n_img_tokens
        out["tokens"] = _sds((B, S - n_img), jnp.int32, ("batch", "seq"), mesh, rules)
        out["img_embeds"] = _sds(
            (B, n_img, cfg.d_model), jnp.bfloat16, ("batch", None, "act_embed"), mesh, rules
        )
    else:
        out["tokens"] = _sds((B, S), jnp.int32, ("batch", "seq"), mesh, rules)
    if cfg.enc_dec:
        out["enc_frames"] = _sds(
            (B, cfg.enc_dec.enc_seq, cfg.d_model), jnp.bfloat16,
            ("batch", None, "act_embed"), mesh, rules,
        )
    return out


def decode_specs(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh, rules):
    """(tokens, state) ShapeDtypeStructs for serve_step."""
    B, S = shape.global_batch, shape.seq_len
    model = build_model(cfg)
    tokens = _sds((B, 1), jnp.int32, ("batch", None), mesh, rules)
    state_shapes = jax.eval_shape(lambda: model.init_decode_state(B, S))
    axes = model.decode_state_axes()

    def annotate(sds, ax):
        # per-layer state trees share one axes template: broadcast the axes
        # tree over the state tree by matching leaf ranks
        return _sds(sds.shape, sds.dtype, ax, mesh, rules)

    # axes trees are templates whose structure matches the state tree
    state = jax.tree.map(
        annotate,
        state_shapes,
        axes,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct),
    )
    return tokens, state


def param_specs(cfg: ModelConfig, mesh: Mesh, rules):
    """(param SDS tree with shardings, sharding tree) — via eval_shape only."""
    model = build_model(cfg)
    boxed = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    values = unbox(boxed)
    axes = axes_of(boxed)
    shardings = partition.param_sharding(axes, mesh, rules, shapes_tree=values)
    sds = jax.tree.map(
        lambda v, s: jax.ShapeDtypeStruct(v.shape, v.dtype, sharding=s), values, shardings
    )
    return sds, shardings
