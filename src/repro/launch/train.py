"""Training CLI: ``python -m repro.launch.train --arch <id> [--reduced] ...``

Examples:
  # CPU-scale run of a reduced config (any assigned arch):
  PYTHONPATH=src python -m repro.launch.train --arch olmoe-1b-7b --reduced \
      --steps 20 --batch 4 --seq 64

  # with checkpointing + injected failure to demonstrate restart:
  PYTHONPATH=src python -m repro.launch.train --arch chatglm3-6b --reduced \
      --steps 30 --ckpt-dir /tmp/ck --fail-at 12
"""

from __future__ import annotations

import argparse

from repro.ckpt.checkpoint import CheckpointConfig
from repro.configs import get_config
from repro.data.pipeline import DataConfig
from repro.runtime.fault import FailureInjector
from repro.train.optimizer import OptimizerConfig
from repro.train.trainer import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--fail-at", type=int, nargs="*", default=[])
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()

    trainer = Trainer(
        model_cfg=cfg,
        data_cfg=DataConfig(global_batch=args.batch, seq_len=args.seq),
        opt_cfg=OptimizerConfig(lr=args.lr, total_steps=args.steps, warmup_steps=max(args.steps // 10, 1)),
        trainer_cfg=TrainerConfig(
            total_steps=args.steps,
            ckpt_every=args.ckpt_every,
            microbatches=args.microbatches,
        ),
        ckpt_cfg=CheckpointConfig(args.ckpt_dir) if args.ckpt_dir else None,
        failure_injector=FailureInjector(fail_at_steps=args.fail_at),
    )
    out = trainer.run()
    print(f"final: {out['final_metrics']}")
    print(f"DP gradient all-reduce algorithm chosen by PCCL: "
          f"{out['grad_allreduce_algorithm']}")


if __name__ == "__main__":
    main()
