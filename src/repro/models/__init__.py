from .lm import Model, build_model
from .module import Box, axes_of, param_count, unbox
