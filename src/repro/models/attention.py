"""Attention: GQA/MQA with KV cache, MLA (DeepSeek-V2), cross-attention.

Three execution modes per layer:
* train     — full causal attention, no cache (flash kernel when enabled);
* prefill   — causal attention that also materializes the KV cache;
* decode    — one query token against a fixed-capacity cache (the assigned
              decode_32k / long_500k shapes lower this path).

MLA decode uses the *absorbed* formulation: queries are projected into the
KV-LoRA space so the cache stores only (c_kv, k_rope) — the paper-level
memory saving that makes deepseek-v2-lite's 32 K cache small.
"""

from __future__ import annotations

import math
from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import MLAConfig, ModelConfig
from repro.sharding import shard

from .layers import apply_rope
from .module import Box, KeyGen, normal_init

NEG_INF = -1e30


class KVCache(NamedTuple):
    k: jax.Array          # (B, T, K, Dh)  [MLA: (B, T, kv_lora)]
    v: jax.Array          # (B, T, K, Dv)  [MLA: (B, T, rope_dim) = k_rope]
    length: jax.Array     # () int32 — valid prefix


def init_cache(batch: int, max_len: int, n_kv: int, dh: int, dv: int, dtype) -> KVCache:
    return KVCache(
        k=jnp.zeros((batch, max_len, n_kv, dh), dtype),
        v=jnp.zeros((batch, max_len, n_kv, dv), dtype),
        length=jnp.zeros((), jnp.int32),
    )


def init_mla_cache(batch: int, max_len: int, mla: MLAConfig, dtype) -> KVCache:
    return KVCache(
        k=jnp.zeros((batch, max_len, mla.kv_lora), dtype),
        v=jnp.zeros((batch, max_len, mla.qk_rope_dim), dtype),
        length=jnp.zeros((), jnp.int32),
    )


# ------------------------------------------------------------------- GQA


def init_gqa(key, cfg: ModelConfig) -> Dict[str, Box]:
    kg = KeyGen(key)
    d, H, K, Dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    return {
        "wq": normal_init(kg(), (d, H, Dh), ("embed", "heads", None)),
        "wk": normal_init(kg(), (d, K, Dh), ("embed", "kv_heads", None)),
        "wv": normal_init(kg(), (d, K, Dh), ("embed", "kv_heads", None)),
        "wo": normal_init(kg(), (H, Dh, d), ("heads", None, "embed"), fan_in=H * Dh),
    }


def _attend(q: jax.Array, k: jax.Array, v: jax.Array, *, causal: bool,
            q_positions: jax.Array, kv_valid_len: Optional[jax.Array]) -> jax.Array:
    """q: (B,S,H,D); k/v: (B,T,K,D). Grouped (GQA) softmax attention, fp32
    accumulators. q_positions: (B,S) absolute positions for causal masking.
    kv_valid_len limits attention to the cache's valid prefix."""
    B, S, H, D = q.shape
    T, K = k.shape[1], k.shape[2]
    G = H // K
    qg = q.reshape(B, S, K, G, D)
    scores = jnp.einsum("bskgd,btkd->bkgst", qg, k).astype(jnp.float32)
    scores = scores / math.sqrt(D)
    kv_pos = jnp.arange(T)[None, None, None, None, :]
    mask = jnp.ones((B, 1, 1, S, T), bool)
    if causal:
        mask = mask & (kv_pos <= q_positions[:, None, None, :, None])
    if kv_valid_len is not None:
        mask = mask & (kv_pos < kv_valid_len)
    scores = jnp.where(mask, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgst,btkd->bskgd", probs, v)
    return out.reshape(B, S, H, D)


def _attend_blocked(
    q: jax.Array, k: jax.Array, v: jax.Array, *, causal: bool,
    block_q: int = 1024, block_k: int = 1024,
) -> jax.Array:
    """Online-softmax attention over KV blocks (the flash decomposition in
    pure jnp, Python-unrolled so HLO cost analysis stays exact).

    Never materializes the (S, T) score matrix — the §Perf lever for the
    memory-bound prefill cells — and skips KV blocks strictly above the
    causal diagonal (the ~2× causal FLOP saving the full einsum pays for).
    Assumes aligned q/kv windows (q position i attends kv ≤ i)."""
    B, S, H, D = q.shape
    T, K = k.shape[1], k.shape[2]
    G = H // K
    bq, bk = min(block_q, S), min(block_k, T)
    assert S % bq == 0 and T % bk == 0, (S, T, bq, bk)
    scale = 1.0 / math.sqrt(D)

    outs = []
    for i in range(S // bq):
        qi = (q[:, i * bq : (i + 1) * bq].reshape(B, bq, K, G, D)
              .astype(jnp.float32) * scale)
        m = jnp.full((B, K, G, bq, 1), NEG_INF, jnp.float32)
        l = jnp.zeros((B, K, G, bq, 1), jnp.float32)
        acc = jnp.zeros((B, K, G, bq, D), jnp.float32)
        q_hi = (i + 1) * bq - 1
        for j in range(T // bk):
            if causal and j * bk > q_hi:
                break  # fully masked block: skipped statically
            kj = k[:, j * bk : (j + 1) * bk].astype(jnp.float32)
            vj = v[:, j * bk : (j + 1) * bk].astype(jnp.float32)
            s = jnp.einsum("bqkgd,btkd->bkgqt", qi, kj)
            if causal and (j + 1) * bk - 1 > i * bq:  # diagonal block
                qpos = i * bq + jnp.arange(bq)[:, None]
                kpos = j * bk + jnp.arange(bk)[None, :]
                s = jnp.where(kpos <= qpos, s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1, keepdims=True))
            pbl = jnp.exp(s - m_new)
            alpha = jnp.exp(m - m_new)
            l = alpha * l + pbl.sum(axis=-1, keepdims=True)
            acc = alpha * acc + jnp.einsum("bkgqt,btkd->bkgqd", pbl, vj)
            m = m_new
        o = (acc / jnp.maximum(l, 1e-30)).transpose(0, 3, 1, 2, 4)  # (B,bq,K,G,D)
        outs.append(o.reshape(B, bq, H, D))
    return jnp.concatenate(outs, axis=1).astype(q.dtype)


def apply_gqa(
    p,
    cfg: ModelConfig,
    x: jax.Array,
    *,
    positions: jax.Array,
    cache: Optional[KVCache] = None,
    mode: str = "train",            # train | prefill | decode
    rope_style: Optional[str] = None,
) -> Tuple[jax.Array, Optional[KVCache]]:
    dt = x.dtype
    B, S, _ = x.shape
    style = rope_style if rope_style is not None else cfg.rope_style
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(dt))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(dt))
    q = apply_rope(q, positions, style=style)
    k = apply_rope(k, positions, style=style)
    if mode == "decode":
        # Decode queries replicate over the model axis: the KV cache is
        # seq-sharded, and a heads-sharded q forces the partitioner to
        # re-shard (≈replicate) the whole cache every step (measured ~GB/step
        # — EXPERIMENTS.md §Perf chatglm iteration 2). Replicated q keeps the
        # score/context contractions local over the sharded cache length,
        # leaving only a small per-layer all-reduce of the (B,1,H,Dh) output.
        q = shard(q, ("batch", None, None, None))
    else:
        q = shard(q, ("batch", "seq", "heads", None))
    k = shard(k, ("batch", "seq", "kv_heads", None))
    v = shard(v, ("batch", "seq", "kv_heads", None))

    new_cache = None
    if mode == "bidir":  # encoder self-attention (whisper)
        ctx = _attend(q, k, v, causal=False, q_positions=positions, kv_valid_len=None)
    elif mode == "train":
        if cfg.use_pallas:
            from repro.kernels.flash import ops as flash_ops

            ctx = flash_ops.flash_attention(q, k, v, causal=True)
        elif cfg.attention_impl == "blocked":
            ctx = _attend_blocked(q, k, v, causal=True)
        else:
            ctx = _attend(q, k, v, causal=True, q_positions=positions, kv_valid_len=None)
    elif mode == "prefill":
        assert cache is not None
        ck = jax.lax.dynamic_update_slice(cache.k, k, (0, 0, 0, 0))
        cv = jax.lax.dynamic_update_slice(cache.v, v, (0, 0, 0, 0))
        new_cache = KVCache(ck, cv, jnp.asarray(S, jnp.int32))
        if cfg.use_pallas:
            from repro.kernels.flash import ops as flash_ops

            ctx = flash_ops.flash_attention(q, k, v, causal=True)
        elif cfg.attention_impl == "blocked":
            ctx = _attend_blocked(q, k, v, causal=True)
        else:
            ctx = _attend(q, k, v, causal=True, q_positions=positions, kv_valid_len=None)
    elif mode == "decode":
        assert cache is not None and S == 1
        idx = cache.length
        # one-hot masked write instead of dynamic_update_slice: a DUS at a
        # dynamic offset along the seq-sharded cache axis makes the SPMD
        # partitioner reshard (≈replicate) the cache every step (measured
        # ~1 GB collective per layer per token — EXPERIMENTS.md §Perf
        # chatglm iteration 3). The masked write is elementwise → fully
        # local on a seq-sharded cache.
        T = cache.k.shape[1]
        sel = (jnp.arange(T) == idx)[None, :, None, None]
        ck = jnp.where(sel, k.astype(cache.k.dtype), cache.k)
        cv = jnp.where(sel, v.astype(cache.v.dtype), cache.v)
        ck = shard(ck, ("batch", "kv_seq", "kv_heads", None))
        cv = shard(cv, ("batch", "kv_seq", "kv_heads", None))
        new_cache = KVCache(ck, cv, idx + 1)
        ctx = _attend(
            q, ck, cv, causal=False, q_positions=positions, kv_valid_len=idx + 1
        )
    else:
        raise ValueError(mode)
    out = jnp.einsum("bshk,hkd->bsd", ctx, p["wo"].astype(dt))
    return shard(out, ("batch", "seq", "act_embed")), new_cache


# ------------------------------------------------------------------- MLA


def init_mla(key, cfg: ModelConfig) -> Dict[str, Box]:
    m = cfg.mla
    assert m is not None
    kg = KeyGen(key)
    d, H = cfg.d_model, cfg.n_heads
    qd = m.qk_nope_dim + m.qk_rope_dim
    return {
        "wq": normal_init(kg(), (d, H, qd), ("embed", "heads", None)),
        "w_dkv": normal_init(kg(), (d, m.kv_lora), ("embed", "kv_lora")),
        "w_kr": normal_init(kg(), (d, m.qk_rope_dim), ("embed", None)),
        "w_uk": normal_init(kg(), (m.kv_lora, H, m.qk_nope_dim), ("kv_lora", "heads", None)),
        "w_uv": normal_init(kg(), (m.kv_lora, H, m.v_dim), ("kv_lora", "heads", None)),
        "wo": normal_init(kg(), (H, m.v_dim, d), ("heads", None, "embed"), fan_in=H * m.v_dim),
    }


def apply_mla(
    p,
    cfg: ModelConfig,
    x: jax.Array,
    *,
    positions: jax.Array,
    cache: Optional[KVCache] = None,
    mode: str = "train",
) -> Tuple[jax.Array, Optional[KVCache]]:
    m = cfg.mla
    dt = x.dtype
    B, S, _ = x.shape
    H = cfg.n_heads
    dn, dr, dv = m.qk_nope_dim, m.qk_rope_dim, m.v_dim
    scale = 1.0 / math.sqrt(dn + dr)

    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(dt))
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = apply_rope(q_rope, positions, style="full")
    c_kv = x @ p["w_dkv"].astype(dt)                       # (B,S,kv_lora)
    k_rope = (x @ p["w_kr"].astype(dt))[:, :, None, :]     # (B,S,1,dr)
    k_rope = apply_rope(k_rope, positions, style="full")[:, :, 0, :]

    def expanded_attention(q_nope, q_rope, c_kv_all, k_rope_all, kv_valid, causal):
        k_nope = jnp.einsum("btl,lhk->bthk", c_kv_all, p["w_uk"].astype(dt))
        v = jnp.einsum("btl,lhk->bthk", c_kv_all, p["w_uv"].astype(dt))
        s_nope = jnp.einsum("bshk,bthk->bhst", q_nope, k_nope)
        # rope part is per-head in q; the single shared k_rope broadcasts:
        s_rope = jnp.einsum("bshk,btk->bhst", q_rope, k_rope_all)
        scores = ((s_nope + s_rope) * scale).astype(jnp.float32)
        T = c_kv_all.shape[1]
        kv_pos = jnp.arange(T)[None, None, None, :]
        mask = jnp.ones((B, 1, S, T), bool)
        if causal:
            mask = mask & (kv_pos <= positions[:, None, :, None])
        if kv_valid is not None:
            mask = mask & (kv_pos < kv_valid)
        scores = jnp.where(mask, scores, NEG_INF)
        probs = jax.nn.softmax(scores, axis=-1).astype(dt)
        return jnp.einsum("bhst,bthk->bshk", probs, v)

    new_cache = None
    if mode == "train":
        ctx = expanded_attention(q_nope, q_rope, c_kv, k_rope, None, True)
    elif mode == "prefill":
        assert cache is not None
        ck = jax.lax.dynamic_update_slice(cache.k, c_kv, (0, 0, 0))
        cr = jax.lax.dynamic_update_slice(cache.v, k_rope, (0, 0, 0))
        new_cache = KVCache(ck, cr, jnp.asarray(S, jnp.int32))
        ctx = expanded_attention(q_nope, q_rope, c_kv, k_rope, None, True)
    elif mode == "decode":
        assert cache is not None and S == 1
        idx = cache.length
        # masked write (see apply_gqa decode): local on a seq-sharded cache
        T = cache.k.shape[1]
        sel = (jnp.arange(T) == idx)[None, :, None]
        ck = jnp.where(sel, c_kv.astype(cache.k.dtype), cache.k)
        cr = jnp.where(sel, k_rope.astype(cache.v.dtype), cache.v)
        ck = shard(ck, ("batch", "kv_seq", None))
        cr = shard(cr, ("batch", "kv_seq", None))
        new_cache = KVCache(ck, cr, idx + 1)
        # absorbed decode: q_c = q_nope @ w_uk  → score against c_kv directly;
        # decode queries replicate over the model axis (see apply_gqa)
        q_c = jnp.einsum("bshk,lhk->bshl", q_nope, p["w_uk"].astype(dt))
        q_c = shard(q_c, ("batch", None, None, None))
        q_rope = shard(q_rope, ("batch", None, None, None))
        s_nope = jnp.einsum("bshl,btl->bhst", q_c, ck)
        s_rope = jnp.einsum("bshk,btk->bhst", q_rope, cr)
        scores = ((s_nope + s_rope) * scale).astype(jnp.float32)
        T = ck.shape[1]
        kv_pos = jnp.arange(T)[None, None, None, :]
        scores = jnp.where(kv_pos < idx + 1, scores, NEG_INF)
        probs = jax.nn.softmax(scores, axis=-1).astype(dt)
        ctx_c = jnp.einsum("bhst,btl->bshl", probs, ck)      # (B,1,H,kv_lora)
        ctx = jnp.einsum("bshl,lhk->bshk", ctx_c, p["w_uv"].astype(dt))
    else:
        raise ValueError(mode)

    out = jnp.einsum("bshk,hkd->bsd", ctx, p["wo"].astype(dt))
    return shard(out, ("batch", "seq", "act_embed")), new_cache


# --------------------------------------------------------- cross-attention


def init_cross_attn(key, cfg: ModelConfig) -> Dict[str, Box]:
    return init_gqa(key, cfg)


def apply_cross_attn(p, cfg: ModelConfig, x: jax.Array, enc: jax.Array) -> jax.Array:
    """Decoder query over encoder memory (whisper). No causal mask, no rope."""
    dt = x.dtype
    k = jnp.einsum("btd,dhk->bthk", enc, p["wk"].astype(dt))
    v = jnp.einsum("btd,dhk->bthk", enc, p["wv"].astype(dt))
    return apply_cross_attn_cached(p, cfg, x, {"k": k, "v": v})


def apply_cross_attn_cached(p, cfg: ModelConfig, x: jax.Array, kv) -> jax.Array:
    """Cross-attention against precomputed encoder K/V (serving path)."""
    dt = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(dt))
    B, S = x.shape[:2]
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    ctx = _attend(q, kv["k"], kv["v"], causal=False, q_positions=pos, kv_valid_len=None)
    out = jnp.einsum("bshk,hkd->bsd", ctx, p["wo"].astype(dt))
    return shard(out, ("batch", "seq", "act_embed"))
