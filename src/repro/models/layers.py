"""Common layers: norms, embeddings, RoPE variants, MLPs.

All matmuls run in the config's activation dtype (bf16 by default) with fp32
parameters cast at use; norms and softmax accumulate in fp32.  Logical axis
names on every parameter drive the sharding rules (DESIGN.md §3.3).
"""

from __future__ import annotations

import math
from typing import Dict

import jax
import jax.numpy as jnp

from repro.sharding import shard

from .module import Box, KeyGen, normal_init, ones_init, zeros_init

# ------------------------------------------------------------------- norms


def init_norm(d: int, norm_type: str) -> Dict[str, Box]:
    p = {"scale": ones_init((d,), ("embed",))}
    if norm_type == "layernorm":
        p["bias"] = zeros_init((d,), ("embed",))
    return p


def apply_norm(p, x: jax.Array, *, eps: float, norm_type: str) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    if norm_type == "rmsnorm":
        var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
        y = x32 * jax.lax.rsqrt(var + eps) * p["scale"]
    elif norm_type == "layernorm":
        mu = jnp.mean(x32, axis=-1, keepdims=True)
        var = jnp.var(x32, axis=-1, keepdims=True)
        y = (x32 - mu) * jax.lax.rsqrt(var + eps) * p["scale"] + p["bias"]
    else:
        raise ValueError(norm_type)
    return y.astype(dt)


# -------------------------------------------------------------- embeddings


def init_embedding(key, vocab: int, d: int) -> Box:
    return normal_init(key, (vocab, d), ("vocab", "embed"), scale=0.02)


def embed_lookup(table: jax.Array, ids: jax.Array, dtype) -> jax.Array:
    out = jnp.take(table.astype(dtype), ids, axis=0)
    return shard(out, ("batch", "seq", "act_embed"))


def logits_projection(table_or_w: jax.Array, x: jax.Array) -> jax.Array:
    """Vocab-parallel logits; fp32 output for a stable softmax-xent."""
    w = table_or_w.astype(jnp.float32)
    out = jnp.einsum("...d,vd->...v", x.astype(jnp.float32), w)
    return shard(out, ("batch", "seq", "vocab"))


def sinusoidal_positions(n: int, d: int) -> jax.Array:
    pos = jnp.arange(n)[:, None].astype(jnp.float32)
    dim = jnp.arange(d // 2)[None, :].astype(jnp.float32)
    inv = jnp.exp(-math.log(10000.0) * 2 * dim / d)
    ang = pos * inv
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)  # (n, d)


# -------------------------------------------------------------------- RoPE


def rope_tables(positions: jax.Array, dim: int, base: float = 10000.0):
    """cos/sin tables for the given positions. positions: (...,S)."""
    inv = 1.0 / (base ** (jnp.arange(0, dim, 2).astype(jnp.float32) / dim))
    ang = positions[..., None].astype(jnp.float32) * inv  # (...,S,dim/2)
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, positions: jax.Array, *, style: str = "full",
               base: float = 10000.0) -> jax.Array:
    """x: (B,S,H,D). ``full`` rotates all D dims (llama half-split pairing);
    ``chatglm_2d`` rotates only the first half of D with interleaved pairing
    (GLM's 2D RoPE applied to head-dim/2, the rest is position-free)."""
    if style == "none" or style == "sinusoidal":
        return x
    B, S, H, D = x.shape
    dt = x.dtype
    xf = x.astype(jnp.float32)
    if style == "full":
        cos, sin = rope_tables(positions, D, base)           # (B,S,D/2)
        cos = cos[:, :, None, :]
        sin = sin[:, :, None, :]
        x1, x2 = jnp.split(xf, 2, axis=-1)
        out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)
        return out.astype(dt)
    if style == "chatglm_2d":
        half = D // 2
        cos, sin = rope_tables(positions, half, base)        # (B,S,half/2)
        cos = cos[:, :, None, :]
        sin = sin[:, :, None, :]
        rot, passth = xf[..., :half], xf[..., half:]
        x1 = rot[..., 0::2]
        x2 = rot[..., 1::2]
        r1 = x1 * cos - x2 * sin
        r2 = x2 * cos + x1 * sin
        rot_out = jnp.stack([r1, r2], axis=-1).reshape(rot.shape)
        return jnp.concatenate([rot_out, passth], axis=-1).astype(dt)
    raise ValueError(f"unknown rope style {style}")


# --------------------------------------------------------------------- MLP


def init_mlp(key, d: int, f: int, mlp_type: str) -> Dict[str, Box]:
    kg = KeyGen(key)
    if mlp_type == "swiglu":
        return {
            "wi_gate": normal_init(kg(), (d, f), ("embed", "mlp")),
            "wi_up": normal_init(kg(), (d, f), ("embed", "mlp")),
            "wo": normal_init(kg(), (f, d), ("mlp", "embed")),
        }
    return {
        "wi": normal_init(kg(), (d, f), ("embed", "mlp")),
        "wo": normal_init(kg(), (f, d), ("mlp", "embed")),
    }


def apply_mlp(p, x: jax.Array, *, mlp_type: str) -> jax.Array:
    dt = x.dtype
    if mlp_type == "swiglu":
        g = x @ p["wi_gate"].astype(dt)
        u = x @ p["wi_up"].astype(dt)
        h = jax.nn.silu(g) * u
    elif mlp_type == "gelu":
        h = jax.nn.gelu(x @ p["wi"].astype(dt))
    elif mlp_type == "relu2":
        h = jnp.square(jax.nn.relu(x @ p["wi"].astype(dt)))
    else:
        raise ValueError(mlp_type)
    h = shard(h, ("batch", "seq", "mlp"))
    return h @ p["wo"].astype(dt)
