"""Model builders for all assigned architecture families.

``build_model(cfg)`` returns a :class:`Model` exposing:

* ``init(key)``                          → Box param tree (values + logical axes)
* ``loss(params, batch, rng)``           → (scalar loss, metrics)     [train_4k]
* ``prefill(params, batch)``             → (last-pos logits, decode state)
                                                                      [prefill_32k]
* ``decode_step(params, state, tokens)`` → (logits, new state)        [decode_32k,
                                                                       long_500k]
* ``init_decode_state(batch, max_len)``  → zeroed cache/state tree

Layer stacks are ``lax.scan``-ed over stacked parameters (one compiled layer
body regardless of depth — essential for 88-layer dry-run compiles), with
``jax.checkpoint`` remat around the train body.  Heterogeneous stacks
(DeepSeek dense layer 0, xLSTM sLSTM cadence, Zamba2 shared-attention cadence)
scan over repeating *groups*.

Modality frontends are stubs per the assignment: batches carry precomputed
``img_embeds`` (vlm) / ``enc_frames`` (audio) at ``d_model`` width.
"""

from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.sharding import shard

from . import attention as A
from . import moe as M
from . import ssm as SSM
from .layers import (
    apply_mlp,
    apply_norm,
    embed_lookup,
    init_embedding,
    init_mlp,
    init_norm,
    logits_projection,
    sinusoidal_positions,
)
from .module import Box, KeyGen, normal_init, stack_init

Batch = Dict[str, jax.Array]


def _remat(fn, cfg: ModelConfig):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        return jax.checkpoint(fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(fn)


def _maybe_scan(cfg: ModelConfig, body, carry, xs):
    """lax.scan over stacked layer params, or a Python unroll when
    cfg.scan_layers=False (the roofline path: XLA cost analysis counts while
    bodies once, so exact FLOP/byte accounting needs unrolled modules)."""
    if cfg.scan_layers:
        return jax.lax.scan(body, carry, xs)
    n = jax.tree.leaves(xs)[0].shape[0]
    ys = []
    for i in range(n):
        x_i = jax.tree.map(lambda a: a[i], xs)
        carry, y = body(carry, x_i)
        ys.append(y)
    if ys and ys[0] is not None:
        ys = jax.tree.map(lambda *zs: jnp.stack(zs), *ys)
    else:
        ys = None
    return carry, ys


def _positions(B: int, S: int, offset=0) -> jax.Array:
    return jnp.broadcast_to(jnp.arange(S)[None] + offset, (B, S))


def _xent(logits: jax.Array, targets: jax.Array) -> jax.Array:
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    return (lse - gold).mean()


# ===========================================================================
# Transformer decoder layer (dense / moe / vlm / audio-decoder)
# ===========================================================================


def _init_decoder_layer(key, cfg: ModelConfig, *, kind: str, cross: bool = False):
    kg = KeyGen(key)
    p: Dict[str, Any] = {"ln1": init_norm(cfg.d_model, cfg.norm_type)}
    p["attn"] = A.init_mla(kg(), cfg) if cfg.mla else A.init_gqa(kg(), cfg)
    if cross:
        p["ln_x"] = init_norm(cfg.d_model, cfg.norm_type)
        p["xattn"] = A.init_cross_attn(kg(), cfg)
    p["ln2"] = init_norm(cfg.d_model, cfg.norm_type)
    if kind == "moe":
        p["ffn"] = M.init_moe(kg(), cfg)
    elif kind == "dense_wide":  # DeepSeek first dense layer
        p["ffn"] = init_mlp(kg(), cfg.d_model, cfg.moe.d_first_dense_ff, cfg.mlp_type)
    else:
        p["ffn"] = init_mlp(kg(), cfg.d_model, cfg.d_ff, cfg.mlp_type)
    return p


def _apply_decoder_layer(
    p, cfg: ModelConfig, x, *, positions, cache, mode, kind: str,
    enc: Optional[jax.Array] = None, cross_kv=None,
):
    h = apply_norm(p["ln1"], x, eps=cfg.norm_eps, norm_type=cfg.norm_type)
    attn_fn = A.apply_mla if cfg.mla else A.apply_gqa
    a_out, new_cache = attn_fn(p["attn"], cfg, h, positions=positions, cache=cache, mode=mode)
    x = x + a_out
    if "xattn" in p:
        h = apply_norm(p["ln_x"], x, eps=cfg.norm_eps, norm_type=cfg.norm_type)
        if cross_kv is not None:
            xa = A.apply_cross_attn_cached(p["xattn"], cfg, h, cross_kv)
        else:
            xa = A.apply_cross_attn(p["xattn"], cfg, h, enc)
        x = x + xa
    h = apply_norm(p["ln2"], x, eps=cfg.norm_eps, norm_type=cfg.norm_type)
    if kind == "moe":
        f_out, aux = M.apply_moe(p["ffn"], cfg, h)
    else:
        f_out, aux = apply_mlp(p["ffn"], h, mlp_type=cfg.mlp_type), jnp.zeros((), jnp.float32)
    return x + f_out, new_cache, aux


# ===========================================================================
# Model base
# ===========================================================================


class Model:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    # -- to be provided by subclasses ------------------------------------
    def init(self, key):  # pragma: no cover - interface
        raise NotImplementedError

    def loss(self, params, batch: Batch, rng=None):
        raise NotImplementedError

    def prefill(self, params, batch: Batch):
        raise NotImplementedError

    def decode_step(self, params, state, tokens: jax.Array):
        raise NotImplementedError

    def init_decode_state(self, batch: int, max_len: int):
        raise NotImplementedError

    def decode_state_axes(self):
        """Logical-axis tree matching init_decode_state's structure (used by
        the launcher to build decode-state shardings; fit-or-drop handles
        non-divisible dims like batch=1 or kv_heads < TP degree)."""
        raise NotImplementedError

    # -- conveniences ------------------------------------------------------
    def cache_dtype(self):
        return self.cfg.act_dtype()


_KV_AXES = A.KVCache(
    k=(None, "batch", "kv_seq", "kv_heads", None),
    v=(None, "batch", "kv_seq", "kv_heads", None),
    length=(None,),
)
_MLA_KV_AXES = A.KVCache(
    k=(None, "batch", "kv_seq", None),
    v=(None, "batch", "kv_seq", None),
    length=(None,),
)


# ===========================================================================
# Decoder-only LM (dense / moe / vlm)
# ===========================================================================


class DecoderLM(Model):
    def __init__(self, cfg: ModelConfig):
        super().__init__(cfg)
        moe = cfg.moe
        self.n_front = moe.first_dense if moe else 0
        self.n_scan = cfg.n_layers - self.n_front
        self.kind = "moe" if moe else "dense"

    # ------------------------------------------------------------- params
    def init(self, key):
        cfg = self.cfg
        kg = KeyGen(key)
        p: Dict[str, Any] = {
            "embed": init_embedding(kg(), cfg.vocab, cfg.d_model),
            "ln_f": init_norm(cfg.d_model, cfg.norm_type),
            "lm_head": normal_init(kg(), (cfg.vocab, cfg.d_model), ("vocab", "embed"), scale=0.02),
        }
        for i in range(self.n_front):
            p[f"front_{i}"] = _init_decoder_layer(kg(), cfg, kind="dense_wide")
        p["layers"] = stack_init(
            lambda k: _init_decoder_layer(k, cfg, kind=self.kind), kg(), self.n_scan
        )
        if cfg.vlm:
            p["img_proj"] = normal_init(kg(), (cfg.d_model, cfg.d_model), ("embed", "embed"))
        return p

    # ------------------------------------------------------------ helpers
    def _embed_inputs(self, params, batch: Batch) -> jax.Array:
        cfg = self.cfg
        x = embed_lookup(params["embed"], batch["tokens"], cfg.act_dtype())
        if cfg.vlm:
            img = batch["img_embeds"].astype(cfg.act_dtype()) @ params["img_proj"].astype(cfg.act_dtype())
            x = jnp.concatenate([img, x], axis=1)
        return shard(x, ("batch", "seq", "act_embed"))

    def _stack(self, params, x, positions, caches, mode):
        cfg = self.cfg
        aux_total = jnp.zeros((), jnp.float32)
        front_caches = []
        for i in range(self.n_front):
            c = None if caches is None else jax.tree.map(lambda a: a[i], caches)
            x, nc, aux = _apply_decoder_layer(
                params[f"front_{i}"], cfg, x, positions=positions, cache=c,
                mode=mode, kind="dense_wide",
            )
            aux_total = aux_total + aux
            front_caches.append(nc)

        layer_params = params["layers"]

        if mode == "train":
            def body(carry, lp):
                h, aux = carry
                h, _, a = _apply_decoder_layer(
                    lp, cfg, h, positions=positions, cache=None, mode="train",
                    kind=self.kind,
                )
                return (h, aux + a), None

            (x, aux_total), _ = _maybe_scan(
                cfg, _remat(body, cfg), (x, aux_total), layer_params
            )
            new_caches = None
        else:
            scan_caches = (
                None if caches is None
                else jax.tree.map(lambda a: a[self.n_front :], caches)
            )

            def body(h, xs):
                lp, c = xs
                h, nc, _ = _apply_decoder_layer(
                    lp, cfg, h, positions=positions, cache=c, mode=mode,
                    kind=self.kind,
                )
                return h, nc

            x, new_scan = _maybe_scan(cfg, body, x, (layer_params, scan_caches))
            new_caches = new_scan
            if self.n_front:
                new_caches = jax.tree.map(
                    lambda f, s: jnp.concatenate([f, s], axis=0),
                    _stack_front(front_caches),
                    new_scan,
                )
        return x, new_caches, aux_total

    # -------------------------------------------------------------- train
    def loss(self, params, batch: Batch, rng=None):
        cfg = self.cfg
        x = self._embed_inputs(params, batch)
        B, S = x.shape[:2]
        positions = _positions(B, S)
        x, _, aux = self._stack(params, x, positions, None, "train")
        x = apply_norm(params["ln_f"], x, eps=cfg.norm_eps, norm_type=cfg.norm_type)
        n_img = cfg.vlm.n_img_tokens if cfg.vlm else 0
        text = x[:, n_img:, :]
        logits = logits_projection(params["lm_head"], text[:, :-1])
        loss = _xent(logits, batch["tokens"][:, 1:])
        if cfg.moe:
            loss = loss + 0.01 * aux / max(self.n_scan, 1)
        return loss, {"xent": loss, "aux": aux}

    # ------------------------------------------------------------ serving
    def init_decode_state(self, batch: int, max_len: int):
        cfg = self.cfg
        L = cfg.n_layers
        dt = self.cache_dtype()
        if cfg.mla:
            one = A.init_mla_cache(batch, max_len, cfg.mla, dt)
        else:
            one = A.init_cache(
                batch, max_len, cfg.n_kv_heads, cfg.resolved_head_dim,
                cfg.resolved_head_dim, dt,
            )
        return jax.tree.map(lambda a: jnp.broadcast_to(a[None], (L,) + a.shape).copy(), one)

    def decode_state_axes(self):
        return _MLA_KV_AXES if self.cfg.mla else _KV_AXES

    def prefill(self, params, batch: Batch, max_len: Optional[int] = None):
        cfg = self.cfg
        x = self._embed_inputs(params, batch)
        B, S = x.shape[:2]
        positions = _positions(B, S)
        # cache headroom: decode appends AFTER the prompt — without it the
        # first decoded token has no slot (and a clamped dynamic-update-slice
        # silently corrupts the last prompt position)
        caches = self.init_decode_state(B, max_len=max_len or S + 64)
        x, new_caches, _ = self._stack(params, x, positions, caches, "prefill")
        x = apply_norm(params["ln_f"], x, eps=cfg.norm_eps, norm_type=cfg.norm_type)
        logits = logits_projection(params["lm_head"], x[:, -1:])
        return logits, new_caches

    def decode_step(self, params, state, tokens: jax.Array):
        cfg = self.cfg
        x = embed_lookup(params["embed"], tokens, cfg.act_dtype())
        B = x.shape[0]
        length = state.length[0] if hasattr(state, "length") else state["length"][0]
        positions = jnp.broadcast_to(length[None, None], (B, 1)).astype(jnp.int32)
        x, new_caches, _ = self._stack(params, x, positions, state, "decode")
        x = apply_norm(params["ln_f"], x, eps=cfg.norm_eps, norm_type=cfg.norm_type)
        logits = logits_projection(params["lm_head"], x)
        return logits, new_caches


def _stack_front(front_caches):
    """Stack a list of per-layer cache trees along a new leading axis."""
    return jax.tree.map(lambda *xs: jnp.stack(xs, axis=0), *front_caches)


# ===========================================================================
# Encoder–decoder (whisper)
# ===========================================================================


def _init_encoder_layer(key, cfg: ModelConfig):
    kg = KeyGen(key)
    return {
        "ln1": init_norm(cfg.d_model, cfg.norm_type),
        "attn": A.init_gqa(kg(), cfg),
        "ln2": init_norm(cfg.d_model, cfg.norm_type),
        "ffn": init_mlp(kg(), cfg.d_model, cfg.d_ff, cfg.mlp_type),
    }


def _apply_encoder_layer(p, cfg: ModelConfig, x):
    h = apply_norm(p["ln1"], x, eps=cfg.norm_eps, norm_type=cfg.norm_type)
    B, S = h.shape[:2]
    a, _ = A.apply_gqa(
        p["attn"], cfg, h, positions=_positions(B, S), mode="bidir",
        rope_style="none",
    )
    x = x + a
    h = apply_norm(p["ln2"], x, eps=cfg.norm_eps, norm_type=cfg.norm_type)
    return x + apply_mlp(p["ffn"], h, mlp_type=cfg.mlp_type)


class EncDecLM(Model):
    """Whisper-style: stubbed mel-frame embeddings → encoder → decoder LM."""

    def init(self, key):
        cfg = self.cfg
        kg = KeyGen(key)
        ed = cfg.enc_dec
        return {
            "embed": init_embedding(kg(), cfg.vocab, cfg.d_model),
            "lm_head": normal_init(kg(), (cfg.vocab, cfg.d_model), ("vocab", "embed"), scale=0.02),
            "ln_f": init_norm(cfg.d_model, cfg.norm_type),
            "ln_enc": init_norm(cfg.d_model, cfg.norm_type),
            "enc_layers": stack_init(lambda k: _init_encoder_layer(k, cfg), kg(), ed.n_enc_layers),
            "dec_layers": stack_init(
                lambda k: _init_decoder_layer(k, cfg, kind="dense", cross=True),
                kg(),
                cfg.n_layers,
            ),
        }

    def _encode(self, params, frames: jax.Array) -> jax.Array:
        cfg = self.cfg
        x = frames.astype(cfg.act_dtype())
        x = x + sinusoidal_positions(x.shape[1], cfg.d_model).astype(x.dtype)[None]
        x = shard(x, ("batch", "seq", "act_embed"))

        def body(h, lp):
            return _apply_encoder_layer(lp, cfg, h), None

        x, _ = _maybe_scan(cfg, _remat(body, cfg), x, params["enc_layers"])
        return apply_norm(params["ln_enc"], x, eps=cfg.norm_eps, norm_type=cfg.norm_type)

    def _decode_stack(self, params, x, positions, enc, caches, mode, cross_kv=None):
        cfg = self.cfg

        def body(h, xs):
            if caches is None:
                lp = xs
                h, _, _ = _apply_decoder_layer(
                    lp, cfg, h, positions=positions, cache=None, mode="train",
                    kind="dense", enc=enc,
                )
                return h, None
            lp, c, ckv = xs
            h, nc, _ = _apply_decoder_layer(
                lp, cfg, h, positions=positions, cache=c, mode=mode,
                kind="dense", enc=enc, cross_kv=ckv,
            )
            return h, nc

        if caches is None:
            x, _ = _maybe_scan(
                cfg, _remat(body, cfg) if mode == "train" else body, x,
                params["dec_layers"],
            )
            return x, None
        x, new_caches = _maybe_scan(cfg, body, x, (params["dec_layers"], caches, cross_kv))
        return x, new_caches

    def loss(self, params, batch: Batch, rng=None):
        cfg = self.cfg
        enc = self._encode(params, batch["enc_frames"])
        x = embed_lookup(params["embed"], batch["tokens"], cfg.act_dtype())
        B, S = x.shape[:2]
        x = x + sinusoidal_positions(S, cfg.d_model).astype(x.dtype)[None]
        x, _ = self._decode_stack(params, x, _positions(B, S), enc, None, "train")
        x = apply_norm(params["ln_f"], x, eps=cfg.norm_eps, norm_type=cfg.norm_type)
        logits = logits_projection(params["lm_head"], x[:, :-1])
        loss = _xent(logits, batch["tokens"][:, 1:])
        return loss, {"xent": loss}

    def _cross_kv(self, params, enc: jax.Array):
        """Precompute per-layer cross-attention K/V from encoder output."""
        cfg = self.cfg
        dt = enc.dtype

        def one(lp):
            k = jnp.einsum("btd,dhk->bthk", enc, lp["xattn"]["wk"].astype(dt))
            v = jnp.einsum("btd,dhk->bthk", enc, lp["xattn"]["wv"].astype(dt))
            return {"k": k, "v": v}

        return jax.vmap(one)(params["dec_layers"])

    def init_decode_state(self, batch: int, max_len: int):
        cfg = self.cfg
        dt = self.cache_dtype()
        L = cfg.n_layers
        one = A.init_cache(batch, max_len, cfg.n_kv_heads, cfg.resolved_head_dim,
                           cfg.resolved_head_dim, dt)
        self_c = jax.tree.map(lambda a: jnp.broadcast_to(a[None], (L,) + a.shape).copy(), one)
        H, Dh = cfg.n_kv_heads, cfg.resolved_head_dim
        cross = {
            "k": jnp.zeros((L, batch, cfg.enc_dec.enc_seq, H, Dh), dt),
            "v": jnp.zeros((L, batch, cfg.enc_dec.enc_seq, H, Dh), dt),
        }
        return {"self": self_c, "cross": cross}

    def decode_state_axes(self):
        return {
            "self": _KV_AXES,
            "cross": {
                "k": (None, "batch", None, "kv_heads", None),
                "v": (None, "batch", None, "kv_heads", None),
            },
        }

    def prefill(self, params, batch: Batch, max_len: Optional[int] = None):
        cfg = self.cfg
        enc = self._encode(params, batch["enc_frames"])
        x = embed_lookup(params["embed"], batch["tokens"], cfg.act_dtype())
        B, S = x.shape[:2]
        x = x + sinusoidal_positions(max_len or S + 64, cfg.d_model).astype(x.dtype)[None, :S]
        caches = self.init_decode_state(B, max_len or S + 64)["self"]
        cross = self._cross_kv(params, enc)
        x, new_caches = self._decode_stack(
            params, x, _positions(B, S), None, caches, "prefill", cross_kv=cross
        )
        x = apply_norm(params["ln_f"], x, eps=cfg.norm_eps, norm_type=cfg.norm_type)
        logits = logits_projection(params["lm_head"], x[:, -1:])
        return logits, {"self": new_caches, "cross": cross}

    def decode_step(self, params, state, tokens: jax.Array):
        cfg = self.cfg
        x = embed_lookup(params["embed"], tokens, cfg.act_dtype())
        B = x.shape[0]
        length = state["self"].length[0]
        pos_tab = sinusoidal_positions(state["self"].k.shape[2], cfg.d_model)
        x = x + jax.lax.dynamic_slice_in_dim(pos_tab, length, 1, axis=0).astype(x.dtype)[None]
        positions = jnp.broadcast_to(length[None, None], (B, 1)).astype(jnp.int32)
        x, new_caches = self._decode_stack(
            params, x, positions, None, state["self"], "decode", cross_kv=state["cross"]
        )
        x = apply_norm(params["ln_f"], x, eps=cfg.norm_eps, norm_type=cfg.norm_type)
        logits = logits_projection(params["lm_head"], x)
        return logits, {"self": new_caches, "cross": state["cross"]}


# ===========================================================================
# xLSTM (groups of mLSTM with an sLSTM every `slstm_every`)
# ===========================================================================


class XLSTMLM(Model):
    def __init__(self, cfg: ModelConfig):
        super().__init__(cfg)
        xc = cfg.xlstm
        assert cfg.n_layers % xc.slstm_every == 0
        self.n_groups = cfg.n_layers // xc.slstm_every
        self.m_per_group = xc.slstm_every - 1

    def init(self, key):
        cfg = self.cfg
        kg = KeyGen(key)

        def group_init(k):
            kg2 = KeyGen(k)
            return {
                "mlstm": stack_init(lambda kk: _with_norm(SSM.init_mlstm, kk, cfg), kg2(), self.m_per_group),
                "slstm": _with_norm(SSM.init_slstm, kg2(), cfg),
            }

        return {
            "embed": init_embedding(kg(), cfg.vocab, cfg.d_model),
            "lm_head": normal_init(kg(), (cfg.vocab, cfg.d_model), ("vocab", "embed"), scale=0.02),
            "ln_f": init_norm(cfg.d_model, cfg.norm_type),
            "groups": stack_init(group_init, kg(), self.n_groups),
        }

    def _apply_block(self, gp, x, states, mode):
        cfg = self.cfg

        def m_body(h, xs):
            lp, st = xs
            hh = apply_norm(lp["ln"], h, eps=cfg.norm_eps, norm_type=cfg.norm_type)
            out, new_st = SSM.apply_mlstm(lp["p"], cfg, hh, state=st, mode=mode)
            if new_st is None:
                new_st = st
            return h + out, new_st

        x, new_m = _maybe_scan(self.cfg, m_body, x, (gp["mlstm"], states["mlstm"]))
        sp = gp["slstm"]
        hh = apply_norm(sp["ln"], x, eps=cfg.norm_eps, norm_type=cfg.norm_type)
        out, new_s = SSM.apply_slstm(sp["p"], cfg, hh, state=states["slstm"], mode=mode)
        if new_s is None:
            new_s = states["slstm"]
        return x + out, {"mlstm": new_m, "slstm": new_s}

    def _stack(self, params, x, states, mode):
        def body(h, xs):
            gp, st = xs
            h, new_st = self._apply_block(gp, h, st, mode)
            return h, new_st

        wrapped = _remat(body, self.cfg) if mode == "train" else body
        x, new_states = _maybe_scan(self.cfg, wrapped, x, (params["groups"], states))
        return x, new_states

    def init_decode_state(self, batch: int, max_len: int = 0):
        cfg = self.cfg
        dt = jnp.float32  # recurrent states in fp32 for stability
        m_one = SSM.init_mlstm_state(cfg, batch, dt)
        s_one = SSM.init_slstm_state(cfg, batch, dt)
        G, Mg = self.n_groups, self.m_per_group
        return {
            "mlstm": jax.tree.map(lambda a: jnp.broadcast_to(a[None, None], (G, Mg) + a.shape).copy(), m_one),
            "slstm": jax.tree.map(lambda a: jnp.broadcast_to(a[None], (G,) + a.shape).copy(), s_one),
        }

    def decode_state_axes(self):
        return {
            "mlstm": SSM.MLSTMState(
                C=(None, None, "batch", "ssm_heads", "ssm_inner", None),
                n=(None, None, "batch", "ssm_heads", None, None),
            ),
            "slstm": SSM.SLSTMState(
                h=(None, "batch", "ssm_heads", None),
                c=(None, "batch", "ssm_heads", None),
                n=(None, "batch", "ssm_heads", None),
                m=(None, "batch", "ssm_heads", None),
            ),
        }

    def loss(self, params, batch: Batch, rng=None):
        cfg = self.cfg
        x = embed_lookup(params["embed"], batch["tokens"], cfg.act_dtype())
        B = x.shape[0]
        states = self.init_decode_state(B)
        x, _ = self._stack(params, x, states, "train")
        x = apply_norm(params["ln_f"], x, eps=cfg.norm_eps, norm_type=cfg.norm_type)
        logits = logits_projection(params["lm_head"], x[:, :-1])
        loss = _xent(logits, batch["tokens"][:, 1:])
        return loss, {"xent": loss}

    def prefill(self, params, batch: Batch):
        cfg = self.cfg
        x = embed_lookup(params["embed"], batch["tokens"], cfg.act_dtype())
        B = x.shape[0]
        states = self.init_decode_state(B)
        x, new_states = self._stack(params, x, states, "prefill")
        x = apply_norm(params["ln_f"], x, eps=cfg.norm_eps, norm_type=cfg.norm_type)
        logits = logits_projection(params["lm_head"], x[:, -1:])
        return logits, new_states

    def decode_step(self, params, state, tokens: jax.Array):
        cfg = self.cfg
        x = embed_lookup(params["embed"], tokens, cfg.act_dtype())
        x, new_states = self._stack(params, x, state, "decode")
        x = apply_norm(params["ln_f"], x, eps=cfg.norm_eps, norm_type=cfg.norm_type)
        logits = logits_projection(params["lm_head"], x)
        return logits, new_states


def _with_norm(init_fn, key, cfg):
    kg = KeyGen(key)
    return {"ln": init_norm(cfg.d_model, cfg.norm_type), "p": init_fn(kg(), cfg)}


# ===========================================================================
# Zamba2 hybrid: Mamba2 stack + one shared attention block with LoRA
# ===========================================================================


class HybridLM(Model):
    def __init__(self, cfg: ModelConfig):
        super().__init__(cfg)
        hc = cfg.hybrid
        assert cfg.n_layers % hc.shared_attn_every == 0
        self.n_groups = cfg.n_layers // hc.shared_attn_every
        self.per_group = hc.shared_attn_every

    def init(self, key):
        cfg = self.cfg
        kg = KeyGen(key)
        r = cfg.hybrid.lora_rank
        d, H, K, Dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim

        def lora_init(k):
            kg2 = KeyGen(k)
            return {
                name: {
                    "a": normal_init(kg2(), (d, r), ("embed", None), scale=0.02),
                    "b": normal_init(kg2(), (r, heads, Dh), (None, ax, None), scale=0.02),
                }
                for name, heads, ax in [("q", H, "heads"), ("k", K, "kv_heads"), ("v", K, "kv_heads")]
            }

        shared = {
            "ln1": init_norm(d, cfg.norm_type),
            "attn": A.init_gqa(kg(), cfg),
            "ln2": init_norm(d, cfg.norm_type),
            "ffn": init_mlp(kg(), d, cfg.d_ff, cfg.mlp_type),
        }
        return {
            "embed": init_embedding(kg(), cfg.vocab, d),
            "lm_head": normal_init(kg(), (cfg.vocab, d), ("vocab", "embed"), scale=0.02),
            "ln_f": init_norm(d, cfg.norm_type),
            "shared": shared,
            "mamba": stack_init(
                lambda k: _with_norm(SSM.init_mamba2, k, cfg), kg(),
                self.n_groups * self.per_group,
            ),
            "lora": stack_init(lora_init, kg(), self.n_groups),
        }

    def _shared_attn(self, params, lora, cfg, x, positions, cache, mode):
        """Shared transformer block with per-invocation LoRA on q/k/v."""
        sp = params["shared"]
        dt = x.dtype
        h = apply_norm(sp["ln1"], x, eps=cfg.norm_eps, norm_type=cfg.norm_type)
        p_attn = dict(sp["attn"])
        # effective weights: w + a @ b  (rank-r update per invocation)
        for name, wname in [("q", "wq"), ("k", "wk"), ("v", "wv")]:
            delta = jnp.einsum("dr,rhk->dhk", lora[name]["a"], lora[name]["b"])
            p_attn[wname] = sp["attn"][wname] + delta
        a_out, new_cache = A.apply_gqa(p_attn, cfg, h, positions=positions, cache=cache, mode=mode)
        x = x + a_out
        h = apply_norm(sp["ln2"], x, eps=cfg.norm_eps, norm_type=cfg.norm_type)
        return x + apply_mlp(sp["ffn"], h, mlp_type=cfg.mlp_type), new_cache

    def _stack(self, params, x, positions, states, mode):
        cfg = self.cfg
        G, Pg = self.n_groups, self.per_group
        mamba_params = jax.tree.map(
            lambda a: a.reshape((G, Pg) + a.shape[1:]), params["mamba"]
        )

        def group_body(carry, xs):
            h = carry
            gp, lora, st = xs

            def m_body(hh, inner):
                lp, mst = inner
                z = apply_norm(lp["ln"], hh, eps=cfg.norm_eps, norm_type=cfg.norm_type)
                out, new_st = SSM.apply_mamba2(lp["p"], cfg, z, state=mst, mode=mode)
                if new_st is None:
                    new_st = mst
                return hh + out, new_st

            h, new_m = _maybe_scan(cfg, m_body, h, (gp, st["mamba"]))
            h, new_kv = self._shared_attn(params, lora, cfg, h, positions, st["attn"], mode)
            if new_kv is None:
                new_kv = st["attn"]
            return h, {"mamba": new_m, "attn": new_kv}

        wrapped = _remat(group_body, cfg) if mode == "train" else group_body
        x, new_states = _maybe_scan(cfg, wrapped, x, (mamba_params, params["lora"], states))
        return x, new_states

    def init_decode_state(self, batch: int, max_len: int):
        cfg = self.cfg
        G, Pg = self.n_groups, self.per_group
        m_one = SSM.init_mamba2_state(cfg, batch, jnp.float32)
        kv_one = A.init_cache(batch, max_len, cfg.n_kv_heads, cfg.resolved_head_dim,
                              cfg.resolved_head_dim, self.cache_dtype())
        return {
            "mamba": jax.tree.map(lambda a: jnp.broadcast_to(a[None, None], (G, Pg) + a.shape).copy(), m_one),
            "attn": jax.tree.map(lambda a: jnp.broadcast_to(a[None], (G,) + a.shape).copy(), kv_one),
        }

    def decode_state_axes(self):
        return {
            "mamba": SSM.Mamba2State(
                conv=(None, None, "batch", None, "ssm_inner"),
                ssm=(None, None, "batch", "ssm_heads", None, None),
            ),
            "attn": _KV_AXES,
        }

    def loss(self, params, batch: Batch, rng=None):
        cfg = self.cfg
        x = embed_lookup(params["embed"], batch["tokens"], cfg.act_dtype())
        B, S = x.shape[:2]
        states = self.init_decode_state(B, max_len=S)
        # train mode ignores the attn caches; mamba states start at zero
        x, _ = self._stack(params, x, _positions(B, S), states, "train")
        x = apply_norm(params["ln_f"], x, eps=cfg.norm_eps, norm_type=cfg.norm_type)
        logits = logits_projection(params["lm_head"], x[:, :-1])
        loss = _xent(logits, batch["tokens"][:, 1:])
        return loss, {"xent": loss}

    def prefill(self, params, batch: Batch, max_len: Optional[int] = None):
        cfg = self.cfg
        x = embed_lookup(params["embed"], batch["tokens"], cfg.act_dtype())
        B, S = x.shape[:2]
        states = self.init_decode_state(B, max_len=max_len or S + 64)
        x, new_states = self._stack(params, x, _positions(B, S), states, "prefill")
        x = apply_norm(params["ln_f"], x, eps=cfg.norm_eps, norm_type=cfg.norm_type)
        logits = logits_projection(params["lm_head"], x[:, -1:])
        return logits, new_states

    def decode_step(self, params, state, tokens: jax.Array):
        cfg = self.cfg
        x = embed_lookup(params["embed"], tokens, cfg.act_dtype())
        B = x.shape[0]
        length = state["attn"].length[0]
        positions = jnp.broadcast_to(length[None, None], (B, 1)).astype(jnp.int32)
        x, new_states = self._stack(params, x, positions, state, "decode")
        x = apply_norm(params["ln_f"], x, eps=cfg.norm_eps, norm_type=cfg.norm_type)
        logits = logits_projection(params["lm_head"], x)
        return logits, new_states


# ===========================================================================


def build_model(cfg: ModelConfig) -> Model:
    if cfg.family == "audio":
        return EncDecLM(cfg)
    if cfg.family == "ssm" and cfg.xlstm:
        return XLSTMLM(cfg)
    if cfg.family == "hybrid":
        return HybridLM(cfg)
    return DecoderLM(cfg)
