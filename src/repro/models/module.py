"""Minimal parameter-tree module system (no flax in this environment).

Parameters are nested dicts whose leaves are :class:`Box` — an array plus its
*logical axis names* (consumed by ``repro.sharding``).  Model init functions
return Box trees; ``unbox``/``axes_of`` split them into a plain value tree
(what apply functions consume) and an axes tree (what the launcher turns into
NamedShardings).
"""

from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Axes = Tuple[Optional[str], ...]


@dataclass
class Box:
    value: Any            # jax.Array or ShapeDtypeStruct
    axes: Axes

    def __post_init__(self) -> None:
        if hasattr(self.value, "ndim") and len(self.axes) != self.value.ndim:
            raise ValueError(
                f"axes {self.axes} rank != value rank {self.value.shape}"
            )


# Box is a pytree node carrying its axes as static aux data, so init
# functions can run under jax.eval_shape / jit and still return Box trees
# (the dry-run never materializes full-model parameters).
jax.tree_util.register_pytree_node(
    Box,
    lambda b: ((b.value,), b.axes),
    lambda axes, children: Box(children[0], axes),
)


def is_box(x: Any) -> bool:
    return isinstance(x, Box)


def unbox(tree):
    return jax.tree.map(lambda b: b.value, tree, is_leaf=is_box)


def axes_of(tree):
    return jax.tree.map(lambda b: b.axes, tree, is_leaf=is_box)


def box_like(values, axes):
    """Zip a value tree and an axes tree back into a Box tree."""
    return jax.tree.map(
        lambda v, a: Box(v, a), values, axes,
        is_leaf=lambda x: isinstance(x, tuple) and all(isinstance(t, (str, type(None))) for t in x),
    )


# ------------------------------------------------------------- initializers


def normal_init(key, shape, axes: Axes, *, scale: Optional[float] = None,
                dtype=jnp.float32, fan_in: Optional[int] = None) -> Box:
    """Truncated-normal with 1/sqrt(fan_in) scale (fan_in = shape[0] unless
    given)."""
    if scale is None:
        fi = fan_in if fan_in is not None else shape[0]
        scale = 1.0 / math.sqrt(max(fi, 1))
    v = scale * jax.random.truncated_normal(key, -2.0, 2.0, shape, dtype)
    return Box(v, axes)


def zeros_init(shape, axes: Axes, dtype=jnp.float32) -> Box:
    return Box(jnp.zeros(shape, dtype), axes)


def ones_init(shape, axes: Axes, dtype=jnp.float32) -> Box:
    return Box(jnp.ones(shape, dtype), axes)


def const_init(value, axes: Axes) -> Box:
    return Box(jnp.asarray(value), axes)


class KeyGen:
    """Splitting helper: kg = KeyGen(key); w = init(kg(), ...)."""

    def __init__(self, key):
        self._key = key

    def __call__(self):
        self._key, sub = jax.random.split(self._key)
        return sub


def stack_init(init_fn: Callable[[jax.Array], Any], key, n: int):
    """vmap an init function over n layer keys → stacked Box tree with a
    leading layer axis (axes get a leading None)."""
    keys = jax.random.split(key, n)
    vals = jax.vmap(lambda k: unbox(init_fn(k)))(keys)
    axes = axes_of(init_fn(jax.random.PRNGKey(0)))
    stacked_axes = jax.tree.map(
        lambda a: (None,) + a,
        axes,
        is_leaf=lambda x: isinstance(x, tuple)
        and all(isinstance(t, (str, type(None))) for t in x),
    )
    return box_like(vals, stacked_axes)


def param_count(tree) -> int:
    vals = unbox(tree) if any(is_box(l) for l in jax.tree.leaves(tree, is_leaf=is_box)) else tree
    return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(vals))


def cast_tree(tree, dtype):
    return jax.tree.map(lambda x: x.astype(dtype) if hasattr(x, "astype") else x, tree)
