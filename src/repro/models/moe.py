"""Mixture-of-Experts layer: token-choice top-k with capacity + EP sharding.

Dispatch is scatter/gather based (no (T, E, C) one-hot blow-up): tokens are
assigned slot ids ``expert·C + position_in_expert`` and scattered into an
(E·C, D) buffer whose expert axis is sharded over the "model" mesh axis
(expert parallelism).  Under pjit this materializes exactly the EP AllToAll
pattern the paper studies (§5 AllToAll, Fig. 10a: MoE models alternate
latency-sensitive AllToAll with bandwidth-hungry AllReduce).

Shared experts (DeepSeek) are plain always-on MLPs added to the routed
output.  The load-balancing auxiliary loss follows Switch/OLMoE:
``E · Σ_e f_e · p_e`` (fraction routed × mean router prob).
"""

from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.sharding import shard

from .layers import apply_mlp, init_mlp
from .module import KeyGen, normal_init


def init_moe(key, cfg: ModelConfig) -> Dict:
    moe = cfg.moe
    assert moe is not None
    kg = KeyGen(key)
    d, E, F = cfg.d_model, moe.n_experts, moe.d_expert
    p: Dict = {
        "router": normal_init(kg(), (d, E), ("embed", "experts"), scale=0.02),
        "wi_gate": normal_init(kg(), (E, d, F), ("experts", "embed", "expert_mlp"), fan_in=d),
        "wi_up": normal_init(kg(), (E, d, F), ("experts", "embed", "expert_mlp"), fan_in=d),
        "wo": normal_init(kg(), (E, F, d), ("experts", "expert_mlp", "embed"), fan_in=F),
    }
    if moe.n_shared:
        p["shared"] = [
            init_mlp(kg(), d, moe.d_expert, cfg.mlp_type) for _ in range(moe.n_shared)
        ]
    return p


def apply_moe(p, cfg: ModelConfig, x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, D) → (output (B,S,D), aux load-balance loss ()).

    GROUPED dispatch (EP × DP): each batch row is a dispatch group with its
    own capacity, so the (G, E, C, D) expert buffers shard over *both* the
    data axis (G) and the model axis (E).  The original global-dispatch
    variant (``cfg.moe.dispatch == "global"``) had no group dim, which
    replicated the entire expert compute across the data axis — kept as the
    §Perf hillclimb baseline (EXPERIMENTS.md)."""
    moe = cfg.moe
    if getattr(moe, "dispatch", "grouped") == "global":
        return _apply_moe_global(p, cfg, x)
    B, S, D = x.shape
    E, K = moe.n_experts, moe.top_k
    dt = x.dtype

    # ---- routing (fp32 for a stable softmax) --------------------------------
    logits = x.astype(jnp.float32) @ p["router"].astype(jnp.float32)     # (B,S,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, K)                      # (B,S,K)
    gate_vals = gate_vals / (gate_vals.sum(-1, keepdims=True) + 1e-9)

    # aux loss: fraction of tokens per expert × mean router prob per expert
    frac = jnp.mean(
        jnp.sum(jax.nn.one_hot(expert_ids, E, dtype=jnp.float32), axis=2),
        axis=(0, 1),
    ) / K
    aux = E * jnp.sum(frac * probs.mean((0, 1)))

    # ---- per-group capacity + slot assignment --------------------------------
    C = max(1, int(math.ceil(S * K / E * moe.capacity_factor)))
    flat_experts = expert_ids.reshape(B, S * K)
    onehot = jax.nn.one_hot(flat_experts, E, dtype=jnp.int32)            # (B,S·K,E)
    pos = jnp.cumsum(onehot, axis=1) - 1
    pos = jnp.take_along_axis(pos, flat_experts[..., None], axis=2)[..., 0]
    keep = pos < C
    slot = jnp.where(keep, flat_experts * C + pos, E * C)                # (B,S·K)

    # ---- dispatch: per-group LOCAL scatter to (B, E·C + 1, D) ----------------
    # The scatter destination must stay data-sharded only: scattering into a
    # model-sharded buffer makes the SPMD partitioner materialize + all-reduce
    # the whole buffer per layer (measured: 77 s → 353 s collective term —
    # EXPERIMENTS.md §Perf olmoe iteration 1). The explicit constraint below
    # keeps the scatter local; the ONE resharding to (data×model) afterwards
    # lowers to the EP AllToAll the paper studies.
    tok_ids = jnp.repeat(jnp.arange(S), K)                               # (S·K,)
    copies = x[:, tok_ids, :].astype(dt)                                 # (B,S·K,D)
    buf = shard(jnp.zeros((B, E * C + 1, D), dt), ("batch", None, "act_embed"))
    # vmap'd scatter: a per-group update the partitioner keeps batch-local
    # (an outer-product-indexed scatter is a general scatter → it replicates
    # the 43 GB buffer across the mesh; measured in §Perf olmoe iteration 2)
    buf = jax.vmap(lambda b, s, c: b.at[s].set(c, mode="drop"))(buf, slot, copies)
    buf = shard(buf, ("batch", None, "act_embed"))
    expert_in = buf[:, : E * C].reshape(B, E, C, D)
    expert_in = shard(expert_in, ("batch", "experts", None, "act_embed"))  # ↔ a2a

    # ---- expert FFN (SwiGLU): sharded over data (g) AND model (e) ------------
    g = jnp.einsum("gecd,edf->gecf", expert_in, p["wi_gate"].astype(dt))
    u = jnp.einsum("gecd,edf->gecf", expert_in, p["wi_up"].astype(dt))
    h = jax.nn.silu(g) * u
    expert_out = jnp.einsum("gecf,efd->gecd", h, p["wo"].astype(dt))
    expert_out = shard(expert_out, ("batch", "experts", None, "act_embed"))

    # ---- combine: reshard back (a2a), then per-group LOCAL gather -------------
    out_flat = jnp.concatenate(
        [expert_out.reshape(B, E * C, D), jnp.zeros((B, 1, D), dt)], axis=1
    )
    out_flat = shard(out_flat, ("batch", None, "act_embed"))             # ↔ a2a
    per_copy = jnp.take_along_axis(out_flat, slot[..., None], axis=1)    # (B,S·K,D)
    w = (gate_vals.reshape(B, S * K) * keep).astype(dt)[..., None]
    y = (per_copy * w).reshape(B, S, K, D).sum(axis=2)

    # ---- shared experts ------------------------------------------------------
    if moe.n_shared:
        for sp in p["shared"]:
            y = y + apply_mlp(sp, x.astype(dt), mlp_type=cfg.mlp_type)

    return y, aux


def _apply_moe_global(p, cfg: ModelConfig, x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Legacy global dispatch: one capacity pool over all B·S tokens; the
    (E·C, D) buffers have no data-sharded dim → expert compute replicates
    across the data axis (kept as the hillclimb baseline)."""
    moe = cfg.moe
    B, S, D = x.shape
    E, K = moe.n_experts, moe.top_k
    dt = x.dtype
    T = B * S
    xt = x.reshape(T, D)

    logits = (xt.astype(jnp.float32) @ p["router"].astype(jnp.float32))  # (T,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, K)                      # (T,K)
    gate_vals = gate_vals / (gate_vals.sum(-1, keepdims=True) + 1e-9)

    frac = jnp.mean(
        jnp.sum(jax.nn.one_hot(expert_ids, E, dtype=jnp.float32), axis=1), axis=0
    ) / K
    aux = E * jnp.sum(frac * probs.mean(0))

    C = max(1, int(math.ceil(T * K / E * moe.capacity_factor)))
    flat_experts = expert_ids.reshape(-1)                                # (T·K,)
    onehot = jax.nn.one_hot(flat_experts, E, dtype=jnp.int32)            # (T·K,E)
    pos = jnp.cumsum(onehot, axis=0) - 1
    pos = jnp.take_along_axis(pos, flat_experts[:, None], axis=1)[:, 0]
    keep = pos < C
    slot = jnp.where(keep, flat_experts * C + pos, E * C)

    tok_ids = jnp.repeat(jnp.arange(T), K)
    buf = jnp.zeros((E * C + 1, D), dt)
    buf = buf.at[slot].set(xt[tok_ids].astype(dt), mode="drop")
    expert_in = buf[: E * C].reshape(E, C, D)
    expert_in = shard(expert_in, ("experts", None, "act_embed"))

    g = jnp.einsum("ecd,edf->ecf", expert_in, p["wi_gate"].astype(dt))
    u = jnp.einsum("ecd,edf->ecf", expert_in, p["wi_up"].astype(dt))
    h = jax.nn.silu(g) * u
    expert_out = jnp.einsum("ecf,efd->ecd", h, p["wo"].astype(dt))
    expert_out = shard(expert_out, ("experts", None, "act_embed"))

    out_flat = jnp.concatenate(
        [expert_out.reshape(E * C, D), jnp.zeros((1, D), dt)], axis=0
    )
    per_copy = out_flat[slot]                                            # (T·K, D)
    w = (gate_vals.reshape(-1) * keep).astype(dt)[:, None]
    y = (per_copy * w).reshape(T, K, D).sum(axis=1).reshape(B, S, D)

    if moe.n_shared:
        for sp in p["shared"]:
            y = y + apply_mlp(sp, x.astype(dt), mlp_type=cfg.mlp_type)

    return y, aux
