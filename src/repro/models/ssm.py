"""State-space & recurrent blocks: Mamba-2 (SSD), xLSTM (mLSTM + sLSTM).

All three expose the same three-mode interface as attention layers:
* ``train/prefill`` — chunkwise-parallel over the sequence (SSD scan);
  prefill also returns the recurrent state so decode can continue from it;
* ``decode`` — O(1)-per-token recurrent update (this is what makes the
  long_500k cells *runnable* for the ssm/hybrid archs — DESIGN.md §3.2).

Deviation notes (DESIGN.md §3.1): mLSTM uses a sigmoid input gate instead of
the exp-gate + m-stabilizer (same state-space form, numerically robust in
bf16; the n-normalizer is kept).  The pre-QK causal conv of xLSTM is elided.
"""

from __future__ import annotations

import math
from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels.ssd import ops as ssd_ops
from repro.sharding import shard

from .module import Box, KeyGen, const_init, normal_init, ones_init, zeros_init

# =============================================================== Mamba-2


class Mamba2State(NamedTuple):
    conv: jax.Array   # (B, conv_width-1, d_inner + 2*d_state)
    ssm: jax.Array    # (B, H, P, N)


def _mamba_dims(cfg: ModelConfig):
    s = cfg.ssm
    di = s.expand * cfg.d_model
    H = di // s.head_dim
    return s, di, H, s.head_dim, s.d_state


def init_mamba2(key, cfg: ModelConfig) -> Dict[str, Box]:
    s, di, H, P, N = _mamba_dims(cfg)
    kg = KeyGen(key)
    d = cfg.d_model
    proj_out = 2 * di + 2 * N + H
    dt_init = jnp.log(jnp.exp(jnp.linspace(1e-3, 0.1, H)) - 1.0)  # softplus^-1
    return {
        "in_proj": normal_init(kg(), (d, proj_out), ("embed", "ssm_inner")),
        "conv_w": normal_init(kg(), (s.conv_width, di + 2 * N), (None, "ssm_inner"), scale=0.5),
        "conv_b": zeros_init((di + 2 * N,), ("ssm_inner",)),
        "A_log": const_init(jnp.log(jnp.linspace(1.0, 16.0, H)), ("ssm_heads",)),
        "D": ones_init((H,), ("ssm_heads",)),
        "dt_bias": const_init(dt_init, ("ssm_heads",)),
        "norm_scale": ones_init((di,), ("ssm_inner",)),
        "out_proj": normal_init(kg(), (di, d), ("ssm_inner", "embed")),
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array,
                 state: Optional[jax.Array] = None) -> Tuple[jax.Array, jax.Array]:
    """Depthwise causal conv via shifted adds. x: (B,S,C); w: (cw,C).
    If `state` (B,cw-1,C) is given it provides left context (decode/prefill
    continuation); returns (y, new_state = last cw-1 inputs)."""
    cw = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], cw - 1, x.shape[2]), x.dtype)
    ext = jnp.concatenate([state, x], axis=1)          # (B, S+cw-1, C)
    y = b
    S = x.shape[1]
    for j in range(cw):
        y = y + ext[:, j : j + S, :] * w[j]
    new_state = ext[:, -(cw - 1) :, :] if cw > 1 else state
    return y, new_state


def apply_mamba2(
    p,
    cfg: ModelConfig,
    x: jax.Array,
    *,
    state: Optional[Mamba2State] = None,
    mode: str = "train",
) -> Tuple[jax.Array, Optional[Mamba2State]]:
    s, di, H, P, N = _mamba_dims(cfg)
    dt_ = x.dtype
    B, S, _ = x.shape

    proj = x @ p["in_proj"].astype(dt_)
    z, xin, Bc, Cc, dtr = jnp.split(proj, [di, 2 * di, 2 * di + N, 2 * di + 2 * N], axis=-1)

    xBC = jnp.concatenate([xin, Bc, Cc], axis=-1)
    conv_state_in = state.conv if state is not None else None

    if mode == "decode":
        assert state is not None and S == 1
        xBC, new_conv = _causal_conv(xBC, p["conv_w"].astype(dt_), p["conv_b"].astype(dt_), conv_state_in)
    else:
        xBC, new_conv = _causal_conv(xBC, p["conv_w"].astype(dt_), p["conv_b"].astype(dt_), None)
    xBC = jax.nn.silu(xBC)
    xin, Bc, Cc = jnp.split(xBC, [di, di + N], axis=-1)

    xh = xin.reshape(B, S, H, P)
    xh = shard(xh, ("batch", "seq", "ssm_heads", None))
    dt = jax.nn.softplus(dtr.astype(jnp.float32) + p["dt_bias"])         # (B,S,H)
    a = -jnp.exp(p["A_log"].astype(jnp.float32))                          # (H,)
    la = (dt * a).astype(jnp.float32)
    Xw = (xh.astype(jnp.float32) * dt[..., None]).astype(dt_)

    new_state: Optional[Mamba2State] = None
    if mode == "decode":
        y, new_ssm = ssd_ops.ssd_decode_step(
            state.ssm, Xw[:, 0], la[:, 0], Bc[:, 0], Cc[:, 0]
        )
        y = y[:, None]                                                    # (B,1,H,P)
        new_state = Mamba2State(new_conv, new_ssm)
    else:
        init = state.ssm if state is not None else None
        y, final = ssd_ops.ssd(
            Xw, la, Bc, Cc, chunk=s.chunk, initial_state=init,
            use_pallas=cfg.use_pallas,
        )
        if mode == "prefill":
            new_state = Mamba2State(new_conv, final)

    y = y + xh * p["D"].astype(dt_)[None, None, :, None]
    y = y.reshape(B, S, di)
    # gated RMSNorm (Mamba-2): norm(y * silu(z)) * scale
    g = (y * jax.nn.silu(z)).astype(jnp.float32)
    var = jnp.mean(jnp.square(g), axis=-1, keepdims=True)
    g = (g * jax.lax.rsqrt(var + cfg.norm_eps) * p["norm_scale"]).astype(dt_)
    out = g @ p["out_proj"].astype(dt_)
    return shard(out, ("batch", "seq", "act_embed")), new_state


def init_mamba2_state(cfg: ModelConfig, batch: int, dtype) -> Mamba2State:
    s, di, H, P, N = _mamba_dims(cfg)
    return Mamba2State(
        conv=jnp.zeros((batch, s.conv_width - 1, di + 2 * N), dtype),
        ssm=jnp.zeros((batch, H, P, N), dtype),
    )


# ================================================================ mLSTM


class MLSTMState(NamedTuple):
    C: jax.Array     # (B, H, P, N) matrix memory
    n: jax.Array     # (B, H, 1, N) normalizer


def _mlstm_dims(cfg: ModelConfig):
    pf = cfg.xlstm.proj_factor
    di = int(pf * cfg.d_model)
    H = cfg.n_heads
    P = di // H
    N = cfg.d_model // H  # qk head dim = assigned head_dim
    return di, H, P, N


def init_mlstm(key, cfg: ModelConfig) -> Dict[str, Box]:
    kg = KeyGen(key)
    d = cfg.d_model
    di, H, P, N = _mlstm_dims(cfg)
    return {
        "up": normal_init(kg(), (d, 2 * di), ("embed", "ssm_inner")),
        # block-diagonal per-head projections (xLSTM's design; keeps the
        # 1.3B budget: dense di×di q/k/v would triple the block size)
        "wq": normal_init(kg(), (H, P, N), ("ssm_heads", None, None), fan_in=P),
        "wk": normal_init(kg(), (H, P, N), ("ssm_heads", None, None), fan_in=P),
        "wv": normal_init(kg(), (H, P, P), ("ssm_heads", None, None), fan_in=P),
        "w_igate": normal_init(kg(), (d, H), ("embed", "ssm_heads"), scale=0.02),
        "b_igate": zeros_init((H,), ("ssm_heads",)),
        "w_fgate": normal_init(kg(), (d, H), ("embed", "ssm_heads"), scale=0.02),
        "b_fgate": const_init(jnp.full((H,), 3.0), ("ssm_heads",)),  # open forget
        "norm_scale": ones_init((di,), ("ssm_inner",)),
        "down": normal_init(kg(), (di, d), ("ssm_inner", "embed")),
    }


def apply_mlstm(
    p,
    cfg: ModelConfig,
    x: jax.Array,
    *,
    state: Optional[MLSTMState] = None,
    mode: str = "train",
) -> Tuple[jax.Array, Optional[MLSTMState]]:
    di, H, P, N = _mlstm_dims(cfg)
    dt_ = x.dtype
    B, S, _ = x.shape
    up = x @ p["up"].astype(dt_)
    u, z = jnp.split(up, 2, axis=-1)
    uh = u.reshape(B, S, H, P)
    q = jnp.einsum("bshp,hpn->bshn", uh, p["wq"].astype(dt_)) / math.sqrt(N)
    k = jnp.einsum("bshp,hpn->bshn", uh, p["wk"].astype(dt_)) / math.sqrt(N)
    v = jnp.einsum("bshp,hpq->bshq", uh, p["wv"].astype(dt_))
    i = jax.nn.sigmoid((x @ p["w_igate"].astype(dt_)).astype(jnp.float32) + p["b_igate"])
    la = jax.nn.log_sigmoid((x @ p["w_fgate"].astype(dt_)).astype(jnp.float32) + p["b_fgate"])

    Xw = (v.astype(jnp.float32) * i[..., None]).astype(dt_)       # i·v
    ones = (jnp.ones((B, S, H, 1), jnp.float32) * i[..., None]).astype(dt_)

    new_state: Optional[MLSTMState] = None
    if mode == "decode":
        assert state is not None and S == 1
        num, newC = ssd_ops.ssd_decode_step(state.C, Xw[:, 0], la[:, 0], k[:, 0], q[:, 0])
        den, newn = ssd_ops.ssd_decode_step(state.n, ones[:, 0], la[:, 0], k[:, 0], q[:, 0])
        num, den = num[:, None], den[:, None]
        new_state = MLSTMState(newC, newn)
    else:
        initC = state.C if state is not None else None
        initn = state.n if state is not None else None
        num, finC = ssd_ops.ssd(Xw, la, k, q, chunk=cfg.xlstm.chunk,
                                initial_state=initC, use_pallas=cfg.use_pallas)
        den, finn = ssd_ops.ssd(ones, la, k, q, chunk=cfg.xlstm.chunk,
                                initial_state=initn)
        if mode == "prefill":
            new_state = MLSTMState(finC, finn)

    y = num.astype(jnp.float32) / jnp.maximum(jnp.abs(den.astype(jnp.float32)), 1.0)
    y = y.reshape(B, S, di).astype(dt_)
    # output norm, gated by silu(z)
    var = jnp.mean(jnp.square(y.astype(jnp.float32)), axis=-1, keepdims=True)
    y = (y.astype(jnp.float32) * jax.lax.rsqrt(var + cfg.norm_eps) * p["norm_scale"]).astype(dt_)
    y = y * jax.nn.silu(z)
    out = y @ p["down"].astype(dt_)
    return shard(out, ("batch", "seq", "act_embed")), new_state


def init_mlstm_state(cfg: ModelConfig, batch: int, dtype) -> MLSTMState:
    di, H, P, N = _mlstm_dims(cfg)
    return MLSTMState(
        C=jnp.zeros((batch, H, P, N), dtype),
        n=jnp.zeros((batch, H, 1, N), dtype),
    )


# ================================================================ sLSTM


class SLSTMState(NamedTuple):
    h: jax.Array    # (B, H, Dh)
    c: jax.Array    # (B, H, Dh)
    n: jax.Array    # (B, H, Dh)
    m: jax.Array    # (B, H, Dh)


def _slstm_dims(cfg: ModelConfig):
    H = cfg.n_heads
    Dh = cfg.d_model // H
    return H, Dh


def init_slstm(key, cfg: ModelConfig) -> Dict[str, Box]:
    kg = KeyGen(key)
    d = cfg.d_model
    H, Dh = _slstm_dims(cfg)
    f_mlp = max(int(4 * d / 3) // 2 * 2, 8)
    return {
        "w": normal_init(kg(), (d, 4, H, Dh), ("embed", None, "ssm_heads", None)),
        "r": normal_init(kg(), (H, Dh, 4, Dh), ("ssm_heads", None, None, None), fan_in=Dh),
        "b": const_init(
            jnp.concatenate([jnp.zeros((2, H, Dh)) , jnp.zeros((2, H, Dh))]).reshape(4, H, Dh)
            .at[1].set(2.0),  # forget-gate bias
            (None, "ssm_heads", None),
        ),
        "norm_scale": ones_init((d,), ("embed",)),
        "ff1": normal_init(kg(), (d, 2 * f_mlp), ("embed", "mlp")),
        "ff2": normal_init(kg(), (f_mlp, d), ("mlp", "embed")),
    }


def _slstm_step(p, x_t: jax.Array, st: SLSTMState) -> Tuple[jax.Array, SLSTMState]:
    """One sLSTM timestep with exp gating + m-stabilizer. x_t: (B, d)."""
    f32 = jnp.float32
    pre = jnp.einsum("bd,dghk->bghk", x_t.astype(f32), p["w"].astype(f32))
    pre = pre + jnp.einsum("bhk,hkgj->bghj", st.h.astype(f32), p["r"].astype(f32))
    pre = pre + p["b"].astype(f32)
    iraw, fraw, zraw, oraw = pre[:, 0], pre[:, 1], pre[:, 2], pre[:, 3]
    m_new = jnp.maximum(fraw + st.m.astype(f32), iraw)
    i = jnp.exp(iraw - m_new)
    f = jnp.exp(fraw + st.m.astype(f32) - m_new)
    c = f * st.c.astype(f32) + i * jnp.tanh(zraw)
    n = f * st.n.astype(f32) + i
    h = jax.nn.sigmoid(oraw) * c / jnp.maximum(n, 1.0)
    new = SLSTMState(h.astype(st.h.dtype), c.astype(st.c.dtype),
                     n.astype(st.n.dtype), m_new.astype(st.m.dtype))
    return h, new


def apply_slstm(
    p,
    cfg: ModelConfig,
    x: jax.Array,
    *,
    state: Optional[SLSTMState] = None,
    mode: str = "train",
) -> Tuple[jax.Array, Optional[SLSTMState]]:
    H, Dh = _slstm_dims(cfg)
    dt_ = x.dtype
    B, S, d = x.shape
    st = state if state is not None else init_slstm_state(cfg, B, jnp.float32)

    if mode == "decode":
        assert S == 1
        h, new_state = _slstm_step(p, x[:, 0], st)
        y = h.reshape(B, 1, d).astype(dt_)
    else:
        def body(carry, x_t):
            h, new = _slstm_step(p, x_t, carry)
            return new, h

        final, hs = jax.lax.scan(body, st, x.swapaxes(0, 1))
        y = hs.swapaxes(0, 1).reshape(B, S, d).astype(dt_)
        new_state = final if mode == "prefill" else None

    # output norm + small GLU FFN (xLSTM sLSTM block carries its own MLP)
    var = jnp.mean(jnp.square(y.astype(jnp.float32)), axis=-1, keepdims=True)
    y = (y.astype(jnp.float32) * jax.lax.rsqrt(var + cfg.norm_eps) * p["norm_scale"]).astype(dt_)
    g, u = jnp.split(y @ p["ff1"].astype(dt_), 2, axis=-1)
    y = (jax.nn.gelu(g) * u) @ p["ff2"].astype(dt_)
    return shard(y, ("batch", "seq", "act_embed")), new_state


def init_slstm_state(cfg: ModelConfig, batch: int, dtype) -> SLSTMState:
    H, Dh = _slstm_dims(cfg)
    z = jnp.zeros((batch, H, Dh), dtype)
    return SLSTMState(z, z, z, jnp.full((batch, H, Dh), -30.0, dtype))
