"""Fault tolerance & elasticity: failure injection, straggler detection,
elastic mesh shrink (assignment large-scale-runnability requirements).

On a real 1000-node TPU/TRN fleet these hooks attach to the coordinator's
heartbeat service; here the *policies* are implemented and unit-tested
against simulated signals, and the elastic path is exercised on host
devices (re-mesh + re-shard via device_put).
"""

from __future__ import annotations

import collections
import statistics
import time
from dataclasses import dataclass, field
from typing import TYPE_CHECKING, Any, Dict, List, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec

if TYPE_CHECKING:  # pragma: no cover
    from repro.api.session import PcclSession
    from repro.core.pccl import PcclPlan


# ----------------------------------------------------------- failure inject
class InjectedFailure(RuntimeError):
    pass


@dataclass(frozen=True)
class LinkFailure:
    """A fabric fault event: physical links (both directions die) and/or
    whole ranks (every incident link dies).  The unit handed to
    :func:`replan_after_failure` by whoever detects the fault — the
    heartbeat service on a real fleet, :class:`FailureInjector` in tests."""

    edges: Tuple[Tuple[int, int], ...] = ()
    ranks: Tuple[int, ...] = ()

    def __post_init__(self) -> None:
        if not self.edges and not self.ranks:
            raise ValueError("LinkFailure needs at least one edge or rank")


def replan_after_failure(
    session: "PcclSession",
    failure: LinkFailure,
    collective: str,
    nbytes: float,
    *,
    n: int = None,
    algorithm: str = "paper_default",
) -> "PcclPlan":
    """Turn a fault event into a warm replan: the session re-prices only
    the states the failure touched (O(affected), bit-identical to a cold
    plan of the degraded fabric) and permanently drops the dead links from
    its fabric/standard views.  See :meth:`PcclSession.replan`."""
    return session.replan(
        collective,
        nbytes,
        n=n,
        algorithm=algorithm,
        failed_edges=failure.edges,
        failed_ranks=failure.ranks,
    )


def fail_link(target: Any, u: int, v: int, *, n: int = None) -> LinkFailure:
    """Kill the physical link ``u — v`` (both directions) mid-stream.

    ``target`` is either a :class:`~repro.serve.arbiter.FabricArbiter`
    (anything with ``on_fault``) — the serving control plane warm-replans
    and keeps ticking on the degraded fabric — or a bare
    :class:`~repro.api.PcclSession`, which is degraded via
    :func:`replan_after_failure` on a representative all-reduce.  Returns
    the injected :class:`LinkFailure` so tests can assert on it.
    """
    failure = LinkFailure(edges=((u, v),))
    on_fault = getattr(target, "on_fault", None)
    if on_fault is not None:
        on_fault(failure)
    else:
        replan_after_failure(target, failure, "all_reduce", 4096.0, n=n)
    return failure


@dataclass
class FailureInjector:
    """Deterministic failure schedule: raise at the given steps (tests) —
    stands in for hardware events the trainer must survive."""

    fail_at_steps: Sequence[int] = ()
    fired: set = field(default_factory=set)

    def check(self, step: int) -> None:
        if step in self.fail_at_steps and step not in self.fired:
            self.fired.add(step)
            raise InjectedFailure(f"injected node failure at step {step}")


# --------------------------------------------------------------- stragglers
@dataclass
class StragglerConfig:
    window: int = 20              # rolling window of step times
    threshold: float = 2.0        # flag hosts slower than τ × median
    min_samples: int = 5


class StragglerDetector:
    """Per-host step-time tracking with τ×median flagging.

    Mitigation is the caller's choice (the trainer supports: rebalance data
    grains toward fast hosts, or evict + elastic re-mesh)."""

    def __init__(self, cfg: StragglerConfig, n_hosts: int):
        self.cfg = cfg
        self.times: Dict[int, collections.deque] = {
            h: collections.deque(maxlen=cfg.window) for h in range(n_hosts)
        }

    def record(self, host: int, step_time: float) -> None:
        self.times[host].append(step_time)

    def host_medians(self) -> Dict[int, float]:
        return {
            h: statistics.median(ts) for h, ts in self.times.items() if len(ts) >= self.cfg.min_samples
        }

    def stragglers(self) -> List[int]:
        med = self.host_medians()
        if len(med) < 2:
            return []
        global_med = statistics.median(med.values())
        return [h for h, m in med.items() if m > self.cfg.threshold * global_med]

    def rebalance_grains(self, total_grains: int) -> Dict[int, int]:
        """Assign data grains inversely proportional to median step time —
        the soft mitigation that keeps stragglers in the job."""
        med = self.host_medians()
        if not med:
            n = len(self.times)
            return {h: total_grains // n for h in range(n)}
        inv = {h: 1.0 / m for h, m in med.items()}
        z = sum(inv.values())
        alloc = {h: max(1, int(round(total_grains * w / z))) for h, w in inv.items()}
        # fix rounding drift
        drift = total_grains - sum(alloc.values())
        for h in sorted(alloc, key=lambda h: -inv[h]):
            if drift == 0:
                break
            alloc[h] += 1 if drift > 0 else -1
            drift += -1 if drift > 0 else 1
        return alloc


# ------------------------------------------------------------------ elastic
def shrink_mesh(mesh: Mesh, failed_device_ids: Sequence[int], axes: Tuple[str, ...],
                shrink_axis: str) -> Mesh:
    """Rebuild a smaller mesh without the failed devices by dropping whole
    slices along ``shrink_axis`` (TPU practice: evict the failed host's
    slice, keep the topology regular)."""
    devs = np.asarray(mesh.devices)
    axis_idx = list(mesh.axis_names).index(shrink_axis)
    failed = set(failed_device_ids)
    keep_slices = []
    for i in range(devs.shape[axis_idx]):
        sl = np.take(devs, i, axis=axis_idx)
        if not any(d.id in failed for d in sl.flatten()):
            keep_slices.append(i)
    if not keep_slices:
        raise RuntimeError("all slices contain failed devices")
    new = np.take(devs, keep_slices, axis=axis_idx)
    return Mesh(new, mesh.axis_names)


def reshard_tree(tree: Any, old_shardings: Any, new_mesh: Mesh) -> Any:
    """Re-shard a live tree onto a shrunk mesh, preserving PartitionSpecs
    where they still divide (fit-or-drop via the sharding layer)."""

    def move(x, sh):
        spec = sh.spec if isinstance(sh, NamedSharding) else PartitionSpec()
        parts = []
        for i, p in enumerate(spec):
            if p is None:
                parts.append(None)
                continue
            ax = (p,) if isinstance(p, str) else tuple(p)
            ax = tuple(a for a in ax if a in new_mesh.axis_names)
            prod = int(np.prod([new_mesh.shape[a] for a in ax])) if ax else 1
            parts.append(ax if ax and x.shape[i] % prod == 0 else None)
        return jax.device_put(x, NamedSharding(new_mesh, PartitionSpec(*parts)))

    return jax.tree.map(move, tree, old_shardings)
