"""Online fabric arbiter: SLA-aware serving control plane over one fabric.

A serving deployment multiplexes three collective streams onto the same
photonic fabric: **prefill** tensor-parallel all-reduces (full prompt
activations, bandwidth-bound), **decode** data-parallel all-gathers
(per-token activations, latency-bound), and **KV-cache migrations**
(all-to-all reshuffles when sequences move between replicas).  The paper's
planner answers "what is the cheapest joint execution *right now*"
(:meth:`PcclSession.plan_concurrent`); this module adds the *online*
control plane around it:

* **Admission** — a bounded queue ordered by deadline (EDF).  A full queue
  sheds the *latest-deadline* request with an attributable outcome
  (``queue_full``), never silently.
* **Batched joint planning** — each :meth:`FabricArbiter.tick` coalesces
  queued work into per-stream collectives (sizes bucketed to powers of two
  so repeat shapes hit the session's two-level plan cache — admission of a
  familiar ``(collective, n, nbytes)`` shape is O(1)), and prices them as
  one :class:`~repro.api.ConcurrentPlanRequest` with arrival-round
  ``offsets``: prefill's first all-reduce trails its compute lead, so
  decode starts immediately and prefill pre-positions its circuits during
  the idle prefix.
* **Preemption** — when the joint round would blow the earliest decode
  deadline, decode steals the fabric: the round is re-planned without
  prefill (the preempted stream's structures stay cached, so resuming it
  next round pays only the numeric phase).  A preemption that lands during
  an in-flight fused dispatch falls back to unfused execution and is
  counted (``fused_fallbacks``).
* **Load shedding** — queued requests whose deadline passed are dropped
  with ``deadline_expired`` outcomes before every planning round, keeping
  tail latency of *admitted* work bounded under overload.
* **Fault survival** — :meth:`FabricArbiter.on_fault` turns a
  :class:`~repro.runtime.fault.LinkFailure` into a warm incremental replan
  (:meth:`PcclSession.replan`); the stream continues on the degraded
  fabric with no cold restart.

Time is *virtual*: the arbiter advances its clock by each planned round's
cost (plus an optional fixed overhead), so behavior is deterministic and
benchmarks replay identical traces.  See ``benchmarks/serve_bench.py`` for
the arbiter-vs-FIFO comparison and README.md § "Serving control plane" for
the lifecycle diagram.
"""

from __future__ import annotations

import heapq
import math
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

from repro.api import (
    ConcurrentCollectiveRequest,
    ConcurrentPcclPlan,
    ConcurrentPlanRequest,
    PcclSession,
)
from repro.core.schedules import mesh_groups
from repro.runtime.fault import LinkFailure

PREFILL = "prefill"
DECODE = "decode"
KV_MIGRATION = "kv_migration"
KINDS = (PREFILL, DECODE, KV_MIGRATION)

SHED_QUEUE_FULL = "queue_full"
SHED_DEADLINE = "deadline_expired"


def _bucket(x: int) -> int:
    """Round up to a power of two so repeat shapes share plan-cache keys."""
    return 1 << max(0, int(x - 1).bit_length()) if x > 1 else 1


@dataclass(frozen=True)
class SlaTarget:
    """Latency targets used to derive admission deadlines (seconds of
    virtual fabric time from arrival)."""

    prefill_s: float = 2e-3
    decode_s: float = 2e-4
    kv_migration_s: float = 5e-3

    def deadline(self, kind: str) -> float:
        try:
            return {
                PREFILL: self.prefill_s,
                DECODE: self.decode_s,
                KV_MIGRATION: self.kv_migration_s,
            }[kind]
        except KeyError:
            raise ValueError(
                f"unknown request kind {kind!r}; one of {KINDS}"
            ) from None


@dataclass(frozen=True)
class ServeRequest:
    """One unit of collective work admitted to the arbiter.

    ``context_len`` scales the payload (prompt tokens for prefill, cached
    tokens for a KV migration; ignored for decode, which always moves one
    token's activation per sequence).  ``deadline_s`` is absolute virtual
    time; build requests with :meth:`FabricArbiter.make_request` to derive
    it from the SLA target.
    """

    rid: int
    kind: str
    context_len: int
    arrival_s: float
    deadline_s: float

    def __post_init__(self) -> None:
        if self.kind not in KINDS:
            raise ValueError(f"unknown request kind {self.kind!r}; one of {KINDS}")
        if self.context_len < 1:
            raise ValueError(f"context_len must be >= 1, got {self.context_len}")


@dataclass
class RequestOutcome:
    """Per-request attributable outcome: every admitted or rejected request
    ends in exactly one of these."""

    rid: int
    kind: str
    status: str                    # "completed" | "shed"
    reason: str = ""               # shed reason; empty for completed
    arrival_s: float = 0.0
    finish_s: float = math.nan     # virtual completion time (completed only)
    latency_s: float = math.nan    # finish - arrival (completed only)
    preemptions: int = 0           # times this request's round was preempted


@dataclass(frozen=True)
class ArbiterConfig:
    """Control-plane policy knobs (planning inputs live on the session)."""

    queue_bound: int = 64          # admission queue capacity (EDF-ordered)
    max_batch: int = 8             # per-kind requests coalesced per round
    sla: SlaTarget = field(default_factory=SlaTarget)
    preemption: bool = True        # decode may steal circuits from prefill
    fused_dispatch: bool = False   # rounds dispatch through fused kernels
    prefill_lead_rounds: int = 1   # compute lead before prefill's first AR
    round_overhead_s: float = 0.0  # fixed per-round control overhead
    serialize_rounds: bool = False  # charge rounds at the sequential
    # (one-collective-at-a-time) cost — models a fabric-unaware scheduler;
    # the FIFO baseline in benchmarks/serve_bench.py sets this

    def __post_init__(self) -> None:
        if self.queue_bound < 1:
            raise ValueError(f"queue_bound must be >= 1, got {self.queue_bound}")
        if self.max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {self.max_batch}")
        if self.prefill_lead_rounds < 0:
            raise ValueError(
                f"prefill_lead_rounds must be >= 0, got {self.prefill_lead_rounds}"
            )


class FabricArbiter:
    """SLA-aware online arbiter for one ``tp × dp`` serving fabric.

    Args:
      session: the planning session (owns caches + fabric state).
      tp / dp: tensor- and data-parallel degrees; the fabric domain is
        ``n = tp * dp`` ranks (TP rows, DP columns, as in
        :func:`repro.core.schedules.mesh_groups`).
      d_model: activation width — sets collective payload sizes.
      cfg: control-plane policy (:class:`ArbiterConfig`).
    """

    def __init__(
        self,
        session: PcclSession,
        *,
        tp: int,
        dp: int,
        d_model: int,
        cfg: Optional[ArbiterConfig] = None,
    ) -> None:
        if tp < 2:
            raise ValueError(f"arbiter needs tp >= 2 (got {tp}): prefill "
                             "all-reduces span TP groups")
        if dp < 2:
            raise ValueError(f"arbiter needs dp >= 2 (got {dp}): decode "
                             "all-gathers span DP groups")
        self.session = session
        self.tp, self.dp, self.n = tp, dp, tp * dp
        self.d_model = d_model
        self.cfg = cfg or ArbiterConfig()
        self.tp_groups, self.dp_groups = mesh_groups(tp, dp)
        self.clock = 0.0
        self.outcomes: List[RequestOutcome] = []
        self.preempted_rids: Dict[int, int] = {}   # rid -> preemption count
        self._queue: List[Tuple[float, int, ServeRequest]] = []  # EDF heap
        self._seq = 0
        self._busy_s = 0.0
        self.rounds = 0
        self.admitted = 0
        self.preemptions = 0
        self.fused_fallbacks = 0
        self.faults = 0

    # ---------------------------------------------------------- admission
    def make_request(
        self, kind: str, context_len: int = 1, *, arrival_s: Optional[float] = None
    ) -> ServeRequest:
        """Build a request with its deadline derived from the SLA target."""
        t = self.clock if arrival_s is None else float(arrival_s)
        self._seq += 1
        return ServeRequest(
            rid=self._seq, kind=kind, context_len=int(context_len),
            arrival_s=t, deadline_s=t + self.cfg.sla.deadline(kind),
        )

    def submit(self, req: ServeRequest) -> bool:
        """Admit ``req`` into the EDF queue; False = shed (``queue_full``).

        A full queue sheds the request holding the *latest* deadline —
        which may be the incumbent, not the newcomer — so overload never
        evicts urgent work in favor of slack work.
        """
        entry = (req.deadline_s, req.rid, req)
        if len(self._queue) >= self.cfg.queue_bound:
            worst = max(self._queue)
            if entry < worst:
                self._queue.remove(worst)
                heapq.heapify(self._queue)
                self._shed(worst[2], SHED_QUEUE_FULL)
            else:
                self._shed(req, SHED_QUEUE_FULL)
                return False
        heapq.heappush(self._queue, entry)
        self.admitted += 1
        return True

    def _shed(self, req: ServeRequest, reason: str) -> None:
        self.outcomes.append(RequestOutcome(
            rid=req.rid, kind=req.kind, status="shed", reason=reason,
            arrival_s=req.arrival_s,
            preemptions=self.preempted_rids.pop(req.rid, 0),
        ))

    @property
    def queue_depth(self) -> int:
        return len(self._queue)

    # ------------------------------------------------------------ planning
    def _collective_for(
        self, kind: str, batch: Sequence[ServeRequest]
    ) -> ConcurrentCollectiveRequest:
        """Map one kind's batch to a bucketed collective request."""
        count = _bucket(len(batch))
        if kind == PREFILL:
            # full prompt activation, reduced within each replica's TP row
            ctx = _bucket(max(r.context_len for r in batch))
            return ConcurrentCollectiveRequest(
                "all_reduce", 4.0 * count * ctx * self.d_model,
                groups=self.tp_groups, algorithm="auto",
            )
        if kind == DECODE:
            # one token's activation per sequence, gathered across replicas
            return ConcurrentCollectiveRequest(
                "all_gather", 4.0 * count * self.d_model,
                groups=self.dp_groups, algorithm="auto",
            )
        # KV migration: K and V cache pages reshuffled across the domain
        ctx = _bucket(max(r.context_len for r in batch))
        return ConcurrentCollectiveRequest(
            "all_to_all", 2 * 4.0 * ctx * self.d_model,
            groups=None, algorithm="auto",
        )

    def _offsets_for(self, kinds: Sequence[str]) -> Optional[Tuple[int, ...]]:
        """Arrival-round offsets: prefill's first all-reduce trails its
        compute lead, so decode/KV rounds start at joint round 0 and
        prefill pre-positions circuits during the idle prefix."""
        lead = self.cfg.prefill_lead_rounds
        if not lead or PREFILL not in kinds or len(kinds) < 2:
            return None
        return tuple(lead if k == PREFILL else 0 for k in kinds)

    def _plan(
        self,
        reqs: Sequence[ConcurrentCollectiveRequest],
        offsets: Optional[Tuple[int, ...]],
    ) -> ConcurrentPcclPlan:
        return self.session.submit(ConcurrentPlanRequest(
            tuple(reqs), n=self.n, offsets=offsets,
        ))

    def price_joint(
        self, prefill_bytes: float, decode_bytes: float
    ) -> ConcurrentPcclPlan:
        """Price one prefill-TP ∥ decode-DP step at explicit byte sizes
        (the :meth:`ServeEngine.concurrent_report` entry point; cached)."""
        return self._plan(
            (
                ConcurrentCollectiveRequest(
                    "all_reduce", prefill_bytes,
                    groups=self.tp_groups, algorithm="auto",
                ),
                ConcurrentCollectiveRequest(
                    "all_gather", decode_bytes,
                    groups=self.dp_groups, algorithm="auto",
                ),
            ),
            None,
        )

    # ---------------------------------------------------------------- tick
    def tick(self, now: Optional[float] = None) -> Dict[str, Any]:
        """Run one arbiter round at virtual time ``max(clock, now)``.

        Expires overdue queue entries, coalesces up to ``max_batch``
        requests per kind (EDF order), plans them jointly with arrival
        offsets, applies decode preemption if the round would miss the
        earliest decode deadline, advances the clock by the executed
        round's cost, and completes the executed requests.  An empty queue
        is a no-op tick (clock still advances to ``now``).
        """
        if now is not None and now > self.clock:
            self.clock = float(now)
        self._expire()
        if not self._queue:
            return {"executed": 0, "round_s": 0.0, "preempted": False,
                    "queue_depth": 0}
        batches = self._take_batches()
        kinds = [k for k in KINDS if batches[k]]
        reqs = [self._collective_for(k, batches[k]) for k in kinds]
        cp = self._plan(reqs, self._offsets_for(kinds))
        preempted = False
        if (
            self.cfg.preemption
            and PREFILL in kinds
            and DECODE in kinds
            and self._misses_decode_deadline(batches[DECODE], cp.cost)
        ):
            preempted = True
            self.preemptions += 1
            if self.cfg.fused_dispatch:
                # the fused comm/compute stream for this round is already
                # specialized to the joint schedule; abandoning prefill
                # mid-dispatch falls back to plain (unfused) execution
                self.fused_fallbacks += 1
            for r in batches[PREFILL]:
                self.preempted_rids[r.rid] = self.preempted_rids.get(r.rid, 0) + 1
                heapq.heappush(self._queue, (r.deadline_s, r.rid, r))
            batches[PREFILL] = []
            kinds = [k for k in KINDS if batches[k]]
            reqs = [self._collective_for(k, batches[k]) for k in kinds]
            cp = self._plan(reqs, self._offsets_for(kinds))
        executed_s = cp.sequential_cost if self.cfg.serialize_rounds else cp.cost
        round_s = executed_s + self.cfg.round_overhead_s
        self.clock += round_s
        self._busy_s += round_s
        self.rounds += 1
        executed = 0
        for k in kinds:
            for r in batches[k]:
                executed += 1
                self.outcomes.append(RequestOutcome(
                    rid=r.rid, kind=r.kind, status="completed",
                    arrival_s=r.arrival_s, finish_s=self.clock,
                    latency_s=self.clock - r.arrival_s,
                    preemptions=self.preempted_rids.pop(r.rid, 0),
                ))
        return {
            "executed": executed,
            "round_s": round_s,
            "joint_s": cp.cost,
            "sequential_s": cp.sequential_cost,
            "speedup": cp.speedup,
            "preempted": preempted,
            "kinds": tuple(kinds),
            "queue_depth": len(self._queue),
        }

    def _expire(self) -> None:
        keep: List[Tuple[float, int, ServeRequest]] = []
        for entry in self._queue:
            if entry[2].deadline_s <= self.clock:
                self._shed(entry[2], SHED_DEADLINE)
            else:
                keep.append(entry)
        if len(keep) != len(self._queue):
            heapq.heapify(keep)
            self._queue = keep

    def _take_batches(self) -> Dict[str, List[ServeRequest]]:
        batches: Dict[str, List[ServeRequest]] = {k: [] for k in KINDS}
        deferred: List[Tuple[float, int, ServeRequest]] = []
        while self._queue:
            entry = heapq.heappop(self._queue)
            batch = batches[entry[2].kind]
            if len(batch) < self.cfg.max_batch:
                batch.append(entry[2])
            else:
                deferred.append(entry)
        for entry in deferred:
            heapq.heappush(self._queue, entry)
        return batches

    def _misses_decode_deadline(
        self, decode_batch: Sequence[ServeRequest], round_s: float
    ) -> bool:
        earliest = min(r.deadline_s for r in decode_batch)
        return self.clock + round_s + self.cfg.round_overhead_s > earliest

    # --------------------------------------------------------------- fault
    def on_fault(self, failure: LinkFailure) -> None:
        """Survive a mid-stream fabric fault: warm-replan a representative
        collective so the session's fabric/standard views degrade and the
        refreshed structures cache under the new fingerprint; subsequent
        ticks plan on the surviving links with no cold restart."""
        from repro.runtime.fault import replan_after_failure

        replan_after_failure(
            self.session, failure, "all_reduce",
            4.0 * self.cfg.max_batch * self.d_model, n=self.n,
        )
        self.faults += 1

    # --------------------------------------------------------------- stats
    def report(self) -> Dict[str, Any]:
        """Control-plane accounting over the arbiter's lifetime."""
        completed = [o for o in self.outcomes if o.status == "completed"]
        shed = [o for o in self.outcomes if o.status == "shed"]
        total = len(self.outcomes)
        lat = sorted(o.latency_s for o in completed)

        def pct(p: float) -> float:
            if not lat:
                return math.nan
            return lat[min(len(lat) - 1, int(p * len(lat)))]

        return {
            "tp": self.tp,
            "dp": self.dp,
            "n": self.n,
            "rounds": self.rounds,
            "admitted": self.admitted,
            "completed": len(completed),
            "shed": len(shed),
            "shed_rate": (len(shed) / total) if total else 0.0,
            "shed_reasons": {
                reason: sum(1 for o in shed if o.reason == reason)
                for reason in (SHED_QUEUE_FULL, SHED_DEADLINE)
            },
            "preemptions": self.preemptions,
            "fused_fallbacks": self.fused_fallbacks,
            "faults": self.faults,
            "queue_depth": len(self._queue),
            "clock_s": self.clock,
            "utilization": (self._busy_s / self.clock) if self.clock else 0.0,
            "latency_p50_s": pct(0.50),
            "latency_p99_s": pct(0.99),
            "plan_cache": {
                "hits": self.session.stats.hits,
                "misses": self.session.stats.misses,
            },
        }
