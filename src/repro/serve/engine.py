"""Serving engine: batched prefill + decode with KV cache / recurrent state.

A minimal continuous-batching-shaped engine: requests are admitted into a
fixed-size batch, prefilled together, then decoded step-by-step; finished
sequences free their slots.  The decode step is the same ``serve_step`` the
dry-run lowers for decode_32k / long_500k.

With ``EngineConfig.tp > 1`` the engine also accounts for the tensor-parallel
activation all-reduces through the PCCL session API (``sim`` backend: the
exact Communicator the training path uses, priced by the planner with no
devices needed) — ``engine.comm_report()`` returns the planned per-token
communication time and algorithm.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import PcclSession
from repro.configs.base import ModelConfig
from repro.core import cost_model as cm
from repro.models import build_model
from repro.models.module import unbox


@dataclass
class Request:
    prompt: np.ndarray              # (S,) int32
    max_new_tokens: int = 16
    generated: List[int] = field(default_factory=list)
    done: bool = False


@dataclass(frozen=True)
class ModelSection:
    """Decoding-policy knobs: how tokens are sampled from the model."""

    greedy: bool = True


@dataclass(frozen=True)
class RuntimeSection:
    """Batching/KV-cache shape: how many sequences share the engine."""

    batch_size: int = 4
    max_len: int = 256

    def __post_init__(self) -> None:
        if self.batch_size < 1:
            raise ValueError(
                f"RuntimeSection.batch_size must be >= 1, got {self.batch_size}"
            )
        if self.max_len < 1:
            raise ValueError(
                f"RuntimeSection.max_len must be >= 1, got {self.max_len}"
            )
        if self.batch_size > self.max_len:
            raise ValueError(
                f"RuntimeSection: batch_size={self.batch_size} exceeds the "
                f"max_len={self.max_len} KV slots one sequence owns — the "
                f"engine cannot admit more sequences than slots"
            )


@dataclass(frozen=True)
class FabricSection:
    """Parallelism layout on the shared photonic fabric."""

    tp: int = 1                 # tensor-parallel degree priced via PCCL
    dp: int = 1                 # data-parallel replicas sharing the fabric
    mesh_n: Optional[int] = None  # fabric domain size; defaults to tp·dp

    def __post_init__(self) -> None:
        if self.tp < 1:
            raise ValueError(f"FabricSection.tp must be >= 1, got {self.tp}")
        if self.dp < 1:
            raise ValueError(f"FabricSection.dp must be >= 1, got {self.dp}")
        if self.mesh_n is not None and self.mesh_n != self.tp * self.dp:
            raise ValueError(
                f"FabricSection: tp*dp = {self.tp}*{self.dp} = "
                f"{self.tp * self.dp} does not cover mesh_n={self.mesh_n} "
                f"fabric ranks — fix tp/dp or drop mesh_n"
            )

    @property
    def n(self) -> int:
        """The fabric domain size every plan spans."""
        return self.mesh_n if self.mesh_n is not None else self.tp * self.dp


class EngineConfig:
    """Sectioned engine configuration with construction-time validation.

    Three frozen sections — :class:`ModelSection` (decoding policy),
    :class:`RuntimeSection` (batching/KV shape), :class:`FabricSection`
    (parallelism layout) — each validating its own invariants so a bad
    config raises an attributable ``ValueError`` at construction instead of
    failing deep inside planning.  The historical flat surface is kept
    intact both ways: flat constructor kwargs
    (``EngineConfig(batch_size=2, tp=4)``) build the sections, and flat
    attributes (``cfg.batch_size`` …) read through to them.  Pass whole
    sections for anything beyond the defaults::

        EngineConfig(runtime=RuntimeSection(8, 4096),
                     fabric=FabricSection(tp=8, dp=4, mesh_n=32))
    """

    def __init__(
        self,
        batch_size: Optional[int] = None,
        max_len: Optional[int] = None,
        greedy: Optional[bool] = None,
        tp: Optional[int] = None,
        dp: Optional[int] = None,
        *,
        model: Optional[ModelSection] = None,
        runtime: Optional[RuntimeSection] = None,
        fabric: Optional[FabricSection] = None,
    ) -> None:
        if runtime is not None and (batch_size is not None or max_len is not None):
            raise ValueError(
                "EngineConfig: pass runtime= or flat batch_size/max_len, not both"
            )
        if model is not None and greedy is not None:
            raise ValueError("EngineConfig: pass model= or flat greedy, not both")
        if fabric is not None and (tp is not None or dp is not None):
            raise ValueError("EngineConfig: pass fabric= or flat tp/dp, not both")
        self.model = model if model is not None else ModelSection(
            greedy=True if greedy is None else greedy
        )
        self.runtime = runtime if runtime is not None else RuntimeSection(
            batch_size=4 if batch_size is None else batch_size,
            max_len=256 if max_len is None else max_len,
        )
        self.fabric = fabric if fabric is not None else FabricSection(
            tp=1 if tp is None else tp, dp=1 if dp is None else dp
        )

    # ------------------------------------------------- flat read-through
    @property
    def greedy(self) -> bool:
        return self.model.greedy

    @property
    def batch_size(self) -> int:
        return self.runtime.batch_size

    @property
    def max_len(self) -> int:
        return self.runtime.max_len

    @property
    def tp(self) -> int:
        return self.fabric.tp

    @property
    def dp(self) -> int:
        return self.fabric.dp

    def __repr__(self) -> str:
        return (
            f"EngineConfig(model={self.model!r}, runtime={self.runtime!r}, "
            f"fabric={self.fabric!r})"
        )

    def __eq__(self, other: object) -> bool:
        if not isinstance(other, EngineConfig):
            return NotImplemented
        return (self.model, self.runtime, self.fabric) == (
            other.model, other.runtime, other.fabric
        )

    def __hash__(self) -> int:
        return hash((self.model, self.runtime, self.fabric))


class ServeEngine:
    def __init__(self, cfg: ModelConfig, engine_cfg: EngineConfig,
                 params: Optional[Any] = None, seed: int = 0,
                 session: Optional[PcclSession] = None):
        self.cfg = cfg
        self.ecfg = engine_cfg
        self._arbiter = None
        self.model = build_model(cfg)
        self.params = params if params is not None else unbox(
            self.model.init(jax.random.PRNGKey(seed))
        )
        # PCCL communication accounting (sim backend: plans, no devices)
        self.pccl = session
        self.comm = None
        if engine_cfg.tp > 1:
            self.pccl = self.pccl or PcclSession(cm.TPU_V5E_PHOTONIC)
            self.comm = self.pccl.communicator("model", engine_cfg.tp, backend="sim")
            self._act = np.zeros((engine_cfg.batch_size, cfg.d_model), np.float32)
        import functools

        self._prefill = jax.jit(
            functools.partial(self.model.prefill, max_len=engine_cfg.max_len)
        )
        self._decode = jax.jit(self.model.decode_step)

    def _charge_tp_step(self, seq_len: int = 1) -> None:
        """Price one model step's TP collectives: two partial-sum activation
        all-reduces per layer (attention out-proj + MLP down-proj).  Decode
        moves a (batch, d_model) activation; prefill moves the full
        (batch, seq_len, d_model) prompt activation."""
        if self.comm is None:
            return
        act = (
            self._act
            if seq_len <= 1
            else np.broadcast_to(self._act, (seq_len, *self._act.shape))
        )
        for _ in range(2 * self.cfg.n_layers):
            self.comm.all_reduce(act)

    def comm_report(self) -> Dict[str, Any]:
        """Planned TP communication accounting for this engine's lifetime.

        ``exec`` carries the execution-engine counters (executable-cache
        hits/misses, traces); zeros under the ``sim`` backend, live numbers
        when an engine is wired to an ``interp`` communicator."""
        if self.comm is None:
            return {"tp": 1, "sim_comm_s": 0.0, "algorithm": "none", "events": 0}
        report = {
            "tp": self.ecfg.tp,
            "sim_comm_s": self.comm.sim_elapsed_s,
            "algorithm": self.comm.chosen_algorithm(
                "all_reduce", self._act.size * 4
            ),
            "events": len(self.comm.backend.events),
            "exec": self.pccl.exec_stats(),
        }
        if self.ecfg.dp > 1:
            report["concurrent"] = self.concurrent_report()
        return report

    def arbiter(self, cfg: Optional[Any] = None) -> Any:
        """The engine's online fabric arbiter (lazily built, then shared).

        Returns a :class:`repro.serve.arbiter.FabricArbiter` bound to this
        engine's session and ``tp × dp`` layout; pass an
        :class:`~repro.serve.arbiter.ArbiterConfig` to rebuild with
        different control-plane policy.
        """
        from repro.serve.arbiter import FabricArbiter

        if self._arbiter is None or cfg is not None:
            self.pccl = self.pccl or PcclSession(cm.TPU_V5E_PHOTONIC)
            self._arbiter = FabricArbiter(
                self.pccl, tp=self.ecfg.tp, dp=self.ecfg.dp,
                d_model=self.cfg.d_model, cfg=cfg,
            )
        return self._arbiter

    def concurrent_report(self) -> Dict[str, Any]:
        """Joint fabric pricing for a continuous-batching step with ``dp``
        replicas on one photonic fabric: the prefill TP all-reduces (full
        ``(batch, max_len, d_model)`` prompt activation, within each
        replica's TP group) run *concurrently* with the decode-side DP
        all-gather (per-token activations exchanged across replicas).  The
        arbiter overlaps the two axes with per-link contention pricing;
        ``speedup`` is the planned gain over pricing each collective as if
        it owned the fabric (sequential baseline).  Pricing goes through
        :meth:`arbiter`, the same control plane that runs the online
        admission/preemption loop (see ``repro.serve.arbiter``).
        """
        tp, dp = self.ecfg.tp, self.ecfg.dp
        if tp < 2 or dp < 2:
            return {"tp": tp, "dp": dp, "speedup": 1.0, "serialized": False}
        prefill_bytes = 4.0 * self.ecfg.batch_size * self.ecfg.max_len * self.cfg.d_model
        decode_bytes = 4.0 * self.ecfg.batch_size * self.cfg.d_model
        cp = self.arbiter().price_joint(prefill_bytes, decode_bytes)
        return {
            "tp": tp,
            "dp": dp,
            "joint_s": cp.cost,
            "sequential_s": cp.sequential_cost,
            "speedup": cp.speedup,
            "serialized": cp.serialized,
            "algorithms": cp.algorithms,
        }

    def _extra_inputs(self, B: int) -> Dict[str, jax.Array]:
        out = {}
        if self.cfg.vlm:
            out["img_embeds"] = jnp.zeros(
                (B, self.cfg.vlm.n_img_tokens, self.cfg.d_model), jnp.float32
            )
        if self.cfg.enc_dec:
            out["enc_frames"] = jnp.zeros(
                (B, self.cfg.enc_dec.enc_seq, self.cfg.d_model), jnp.float32
            )
        return out

    def generate(self, requests: List[Request]) -> List[Request]:
        """Serve a batch of requests to completion (prefill + decode loop)."""
        B = self.ecfg.batch_size
        assert len(requests) <= B
        S = max(len(r.prompt) for r in requests)
        toks = np.zeros((B, S), np.int32)
        for i, r in enumerate(requests):
            toks[i, S - len(r.prompt):] = r.prompt  # left-pad
        batch = {"tokens": jnp.asarray(toks), **self._extra_inputs(B)}
        logits, state = self._prefill(self.params, batch)
        self._charge_tp_step(seq_len=S)
        nxt = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)[:, None]
        for i, r in enumerate(requests):
            r.generated.append(int(nxt[i, 0]))

        max_new = max(r.max_new_tokens for r in requests)
        for t in range(max_new - 1):
            logits, state = self._decode(self.params, state, nxt)
            self._charge_tp_step()
            nxt = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)[:, None]
            for i, r in enumerate(requests):
                if len(r.generated) < r.max_new_tokens:
                    r.generated.append(int(nxt[i, 0]))
        for r in requests:
            r.done = True
        return requests
