from .partition import (
    Rules,
    active_mesh,
    active_rules,
    default_rules,
    param_sharding,
    shard,
    spec_for,
    use_partitioning,
)

__all__ = [k for k in dir() if not k.startswith("_")]
