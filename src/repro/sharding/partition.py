"""Logical-axis → mesh-axis sharding rules (MaxText-style).

Model code names tensor dimensions with *logical* axes ("batch", "embed",
"heads", …).  A rule table maps logical names to mesh axes; the launcher
installs the active mesh + rules in a context, and :func:`shard` constrains
activations while :func:`param_spec` builds PartitionSpecs for parameter
trees.  When no mesh is active (CPU smoke tests) everything is a no-op, so
the same model code runs from a laptop to the 2×16×16 production mesh.

Axis semantics (DESIGN.md §3.3):
* batch            → DP over ("pod", "data")
* embed / residual → FSDP over ("pod", "data") when ``fsdp=True`` (ZeRO-3)
* heads / kv_heads / mlp / experts / q_lora / vocab → TP/EP over "model"
* seq              → sequence parallelism over "model" when ``sp=True``
"""

from __future__ import annotations

import contextlib
import threading
from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple, Union

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec

MeshAxes = Union[None, str, Tuple[str, ...]]


@dataclass(frozen=True)
class Rules:
    """Mapping from logical axis names to mesh axes."""

    table: Tuple[Tuple[str, MeshAxes], ...]

    def mesh_axes(self, logical: Optional[str]) -> MeshAxes:
        if logical is None:
            return None
        for name, axes in self.table:
            if name == logical:
                return axes
        return None

    def override(self, **kw: MeshAxes) -> "Rules":
        tab = [(k, v) for k, v in self.table if k not in kw]
        tab.extend(kw.items())
        return Rules(tuple(tab))


def default_rules(
    *,
    multi_pod: bool = False,
    fsdp: bool = True,
    sp: bool = False,
) -> Rules:
    dp: MeshAxes = ("pod", "data") if multi_pod else ("data",)
    return Rules(
        (
            ("batch", dp),
            ("embed", dp if fsdp else None),     # FSDP shards params' embed dim
            ("act_embed", None),                  # activations keep embed local
            ("seq", ("model",) if sp else None),  # sequence parallelism
            ("heads", ("model",)),
            ("kv_heads", ("model",)),
            ("mlp", ("model",)),
            ("experts", ("model",)),
            ("expert_mlp", None),
            ("q_lora", ("model",)),
            ("kv_lora", None),
            ("vocab", ("model",)),
            ("conv", None),
            ("state", None),
            ("ssm_heads", ("model",)),
            ("ssm_inner", ("model",)),
            # decode-state axes: cache length shards over whatever the batch
            # dim doesn't claim (fit-or-drop resolves conflicts per leaf)
            ("kv_seq", ("data", "model")),
        )
    )


@dataclass
class _Ctx:
    mesh: Optional[Mesh] = None
    rules: Optional[Rules] = None


_CTX = threading.local()


def _ctx() -> _Ctx:
    if not hasattr(_CTX, "v"):
        _CTX.v = _Ctx()
    return _CTX.v


@contextlib.contextmanager
def use_partitioning(mesh: Mesh, rules: Rules):
    """Install mesh + rules; model sharding helpers become active."""
    prev = _ctx().mesh, _ctx().rules
    _ctx().mesh, _ctx().rules = mesh, rules
    try:
        with mesh:  # legacy Mesh context (pjit collective lowering)
            yield
    finally:
        _ctx().mesh, _ctx().rules = prev


def active_mesh() -> Optional[Mesh]:
    return _ctx().mesh


def active_rules() -> Optional[Rules]:
    return _ctx().rules


def spec_for(
    axes: Sequence[Optional[str]], shape: Optional[Sequence[int]] = None
) -> PartitionSpec:
    """Logical axes → PartitionSpec under the active rules.

    With ``shape`` given, mesh axes that do not divide the dimension are
    dropped ("fit-or-drop"): e.g. a kv_heads=8 dim under a 16-way model axis
    replicates instead of erroring, and a batch=1 long-context decode keeps
    its batch dim unsharded.  Mesh axes are never used twice in one spec.
    """
    rules = _ctx().rules
    if rules is None:
        return PartitionSpec()
    mesh = _ctx().mesh
    used: set = set()
    parts: List[MeshAxes] = []
    for i, a in enumerate(axes):
        ma = rules.mesh_axes(a)
        if ma is None:
            parts.append(None)
            continue
        if isinstance(ma, str):
            ma = (ma,)
        ma = tuple(m for m in ma if mesh is None or m in mesh.axis_names)
        ma = tuple(m for m in ma if m not in used)
        if shape is not None and mesh is not None and ma:
            # drop trailing axes until the dim divides the shard product
            dim = shape[i]
            while ma:
                prod = int(np.prod([mesh.shape[m] for m in ma]))
                if dim % prod == 0:
                    break
                ma = ma[:-1]
        used.update(ma)
        parts.append(ma if ma else None)
    while parts and parts[-1] is None:
        parts.pop()
    return PartitionSpec(*parts)


def shard(x: jax.Array, axes: Sequence[Optional[str]]) -> jax.Array:
    """Constrain an activation to the logical axes' mesh mapping (no-op when
    no mesh is active)."""
    mesh = _ctx().mesh
    if mesh is None or _ctx().rules is None:
        return x
    if len(axes) > x.ndim:  # caller shapes vary (e.g. flattened tokens)
        return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec_for(axes)))


def param_sharding(
    axes_tree,
    mesh: Optional[Mesh] = None,
    rules: Optional[Rules] = None,
    shapes_tree=None,
):
    """Map a tree of logical-axis tuples to NamedShardings.

    ``shapes_tree`` (same structure, leaves with ``.shape``) activates
    fit-or-drop divisibility handling per leaf.
    """
    mesh = mesh or _ctx().mesh
    rules = rules or _ctx().rules
    if mesh is None or rules is None:
        raise RuntimeError("param_sharding needs an active mesh/rules")

    is_axes = lambda x: x is None or isinstance(x, tuple)

    def one(axes, sds=None):
        if axes is None:
            return NamedSharding(mesh, PartitionSpec())
        with _installed(mesh, rules):
            return NamedSharding(
                mesh, spec_for(axes, None if sds is None else sds.shape)
            )

    if shapes_tree is None:
        return jax.tree.map(one, axes_tree, is_leaf=is_axes)
    return jax.tree.map(one, axes_tree, shapes_tree, is_leaf=is_axes)


@contextlib.contextmanager
def _installed(mesh, rules):
    prev = _ctx().mesh, _ctx().rules
    _ctx().mesh, _ctx().rules = mesh, rules
    try:
        yield
    finally:
        _ctx().mesh, _ctx().rules = prev
