"""Optimizer substrate: AdamW with global-norm clipping and LR schedules.

No optax in this environment — a small, production-shaped implementation:
fp32 moments sharded exactly like their parameters (the launcher reuses the
param sharding tree for opt state), fused update math, cosine/linear
schedules with warmup, and a global-norm clip computed in fp32.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class OptimizerConfig:
    lr: float = 3e-4
    betas: Tuple[float, float] = (0.9, 0.95)
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    schedule: str = "cosine"  # cosine | linear | constant
    min_lr_ratio: float = 0.1


class OptState(NamedTuple):
    step: jax.Array   # () int32
    mu: Any           # pytree like params
    nu: Any           # pytree like params


def init_opt_state(params) -> OptState:
    zeros = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
    return OptState(
        step=jnp.zeros((), jnp.int32),
        mu=jax.tree.map(zeros, params),
        nu=jax.tree.map(zeros, params),
    )


def learning_rate(cfg: OptimizerConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / max(cfg.warmup_steps, 1), 1.0)
    frac = jnp.clip(
        (step - cfg.warmup_steps) / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0
    )
    if cfg.schedule == "cosine":
        decay = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (1 + jnp.cos(math.pi * frac))
    elif cfg.schedule == "linear":
        decay = 1.0 - (1 - cfg.min_lr_ratio) * frac
    else:
        decay = jnp.asarray(1.0)
    return cfg.lr * warm * decay


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(sum(leaves))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), norm


def adamw_update(
    cfg: OptimizerConfig, grads, params, state: OptState
) -> Tuple[Any, OptState, Dict[str, jax.Array]]:
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    step = state.step + 1
    b1, b2 = cfg.betas
    lr = learning_rate(cfg, step)
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g32
        v = b2 * v + (1 - b2) * jnp.square(g32)
        mhat = m / bc1
        vhat = v / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.mu)
    flat_v = treedef.flatten_up_to(state.nu)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, OptState(step, new_m, new_v), metrics
