"""Train/serve step builders — the functions the launcher jits and the
dry-run lowers.

``make_train_step`` supports microbatch gradient accumulation (sequential
``lax.scan`` over microbatches — the standard memory/throughput trade) and
donates params+opt_state so the update is in-place at the XLA level.
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.models.lm import Model

from .optimizer import OptimizerConfig, OptState, adamw_update


def make_train_step(
    model: Model,
    opt_cfg: OptimizerConfig,
    *,
    microbatches: int = 1,
) -> Callable:
    """Returns train_step(params, opt_state, batch) -> (params, opt_state, metrics)."""

    def grads_of(params, batch):
        def loss_fn(p):
            loss, metrics = model.loss(p, batch)
            return loss, metrics

        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        return loss, metrics, grads

    def train_step(params, opt_state: OptState, batch):
        if microbatches == 1:
            loss, metrics, grads = grads_of(params, batch)
        else:
            def split(x):
                return x.reshape((microbatches, x.shape[0] // microbatches) + x.shape[1:])

            mb = jax.tree.map(split, batch)
            zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

            def body(acc, one):
                loss_acc, g_acc = acc
                loss, _, g = grads_of(params, one)
                g_acc = jax.tree.map(lambda a, b: a + b.astype(jnp.float32), g_acc, g)
                return (loss_acc + loss, g_acc), None

            (loss, grads), _ = jax.lax.scan(body, (jnp.zeros((), jnp.float32), zero), mb)
            loss = loss / microbatches
            grads = jax.tree.map(lambda g: g / microbatches, grads)
            metrics = {"xent": loss}

        new_params, new_opt, opt_metrics = adamw_update(opt_cfg, grads, params, opt_state)
        out = {"loss": loss, **{k: v for k, v in metrics.items()}, **opt_metrics}
        return new_params, new_opt, out

    return train_step


def make_eval_step(model: Model) -> Callable:
    def eval_step(params, batch):
        loss, metrics = model.loss(params, batch)
        return {"loss": loss, **metrics}

    return eval_step


def make_prefill_step(model: Model) -> Callable:
    def prefill_step(params, batch):
        return model.prefill(params, batch)

    return prefill_step


def make_serve_step(model: Model) -> Callable:
    """One decode step: (params, state, tokens (B,1)) -> (logits, state)."""

    def serve_step(params, state, tokens):
        return model.decode_step(params, state, tokens)

    return serve_step
