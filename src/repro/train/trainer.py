"""Trainer: the fault-tolerant training loop.

Responsibilities (assignment large-scale requirements):
* jit + shard the train step (params/opt donated, batch host-fed);
* periodic async checkpoints; auto-resume from the newest committed step;
* survive injected node failures by checkpoint-restart (the outer loop
  catches, restores, and replays the deterministic data stream);
* straggler detection hooks recording per-step times;
* PCCL integration point: a :class:`repro.api.PcclSession` owned by the
  trainer plans the gradient reduction per buffer size (paper §2.2) and
  reports it in metrics — on the pjit path XLA emits the collectives, on the
  shard_map path ``session.communicator(...)`` executes the schedule-driven
  collectives (examples/pccl_dp_training.py).  The session's plan cache and
  fabric threading make the per-step planned cost the *steady-state* (warm
  fabric) cost, not the cold-start one.
"""

from __future__ import annotations

import dataclasses
import time
from dataclasses import dataclass
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import ConcurrentCollectiveRequest, PcclSession
from repro.ckpt.checkpoint import CheckpointConfig, CheckpointManager
from repro.configs.base import ModelConfig
from repro.core import cost_model as cm
from repro.data.pipeline import DataConfig, SyntheticLMData
from repro.models import build_model
from repro.models.module import axes_of, param_count, unbox
from repro.runtime.fault import (
    FailureInjector,
    InjectedFailure,
    StragglerConfig,
    StragglerDetector,
)
from repro.sharding import partition

from .optimizer import OptimizerConfig, init_opt_state
from .train_step import make_train_step


@dataclass
class TrainerConfig:
    total_steps: int = 100
    ckpt_every: int = 20
    log_every: int = 10
    microbatches: int = 1
    seed: int = 0
    max_restarts: int = 8
    # Relative-error tolerance the job accepts on the DP gradient
    # all-reduce (see repro.core.cost_model.compressed_ef_error_bound):
    # when set, PCCL's auto arbitration may plan the int8-on-the-wire
    # ring_ef8 algorithm (bytes/4 wire time) for the gradient collective.
    # None (default) keeps the gradient sum exact.
    grad_allreduce_rel_error_tol: Optional[float] = None


class Trainer:
    def __init__(
        self,
        model_cfg: ModelConfig,
        data_cfg: DataConfig,
        opt_cfg: OptimizerConfig,
        trainer_cfg: TrainerConfig,
        ckpt_cfg: Optional[CheckpointConfig] = None,
        mesh=None,
        rules=None,
        failure_injector: Optional[FailureInjector] = None,
    ):
        self.cfg = model_cfg
        self.data_cfg = data_cfg
        self.opt_cfg = opt_cfg
        self.tcfg = trainer_cfg
        self.mesh = mesh
        self.rules = rules
        self.model = build_model(model_cfg)
        self.data = SyntheticLMData(model_cfg, data_cfg)
        self.ckpt = CheckpointManager(ckpt_cfg) if ckpt_cfg else None
        self.injector = failure_injector or FailureInjector()
        self.straggler = StragglerDetector(StragglerConfig(), data_cfg.n_hosts)
        self.metrics_log: list = []

        # PCCL planning for the DP gradient all-reduce (paper integration):
        # one session per trainer; warm-plan (cold + threaded re-plan) gives
        # the steady-state per-step cost the job will actually pay.
        n_dp = data_cfg.n_hosts if mesh is None else int(mesh.shape.get("data", 1))
        n_tp = 1 if mesh is None else int(mesh.shape.get("model", 1))
        grad_bytes = 4.0 * param_count(jax.eval_shape(self.model.init, jax.random.PRNGKey(0)))
        self.pccl = PcclSession(cm.TPU_V5E_PHOTONIC)
        if n_dp >= 2:
            tol = trainer_cfg.grad_allreduce_rel_error_tol
            cold = self.pccl.plan(
                "all_reduce", grad_bytes, n=n_dp, algorithm="auto",
                rel_error_tol=tol,
            )
            warm = self.pccl.plan(
                "all_reduce", grad_bytes, n=n_dp, algorithm="auto",
                rel_error_tol=tol,
            )
            self.grad_allreduce_algorithm = warm.algorithm
            self.grad_allreduce_cost_s = {"cold": cold.cost, "steady": warm.cost}
        else:
            self.grad_allreduce_algorithm = "none"
            self.grad_allreduce_cost_s = {"cold": 0.0, "steady": 0.0}

        # DP×TP step pricing: on a 2-D mesh the TP activation all-reduces and
        # the DP gradient all-reduce are in flight *together*, so the step
        # cost is the fabric arbiter's joint plan (TP rows ∥ DP columns), not
        # the sum of two fabric-to-itself plans.
        self.concurrent_step_cost = None
        if n_dp >= 2 and n_tp >= 2:
            from repro.core.schedules import mesh_groups

            n_mesh = n_dp * n_tp
            tp_groups, dp_groups = mesh_groups(n_tp, n_dp)
            # per-group buffer sizes as the mesh actually shards them: each
            # TP group all-reduces its own DP shard of the batch activation,
            # and each DP rank reduces its 1/n_tp TP slice of the gradients
            act_bytes = (
                4.0 * (data_cfg.global_batch / n_dp)
                * data_cfg.seq_len * model_cfg.d_model
            )
            dp_grad_bytes = grad_bytes / n_tp
            cp = self.pccl.plan_concurrent(
                [
                    ConcurrentCollectiveRequest(
                        "all_reduce", act_bytes, groups=tp_groups, algorithm="auto"
                    ),
                    ConcurrentCollectiveRequest(
                        "all_reduce", dp_grad_bytes, groups=dp_groups, algorithm="auto"
                    ),
                ],
                n=n_mesh,
            )
            self.concurrent_step_cost = {
                "joint": cp.cost,
                "sequential": cp.sequential_cost,
                "speedup": cp.speedup,
                "serialized": cp.serialized,
                "algorithms": cp.algorithms,
            }

        self._step_fn = None
        self._shardings = None

    # ------------------------------------------------------------- plumbing
    def _build(self):
        step = make_train_step(self.model, self.opt_cfg, microbatches=self.tcfg.microbatches)
        if self.mesh is not None:
            self._step_fn = jax.jit(step, donate_argnums=(0, 1))
        else:
            self._step_fn = jax.jit(step, donate_argnums=(0, 1))

    def _init_state(self):
        boxed = self.model.init(jax.random.PRNGKey(self.tcfg.seed))
        params = unbox(boxed)
        if self.mesh is not None and self.rules is not None:
            shardings = partition.param_sharding(
                axes_of(boxed), self.mesh, self.rules, shapes_tree=params
            )
            params = jax.tree.map(jax.device_put, params, shardings)
            self._shardings = shardings
        return params, init_opt_state(params)

    # ----------------------------------------------------------------- run
    def run(self) -> Dict[str, Any]:
        self._build()
        restarts = 0
        while True:
            try:
                return self._run_once()
            except InjectedFailure as e:
                restarts += 1
                if restarts > self.tcfg.max_restarts:
                    raise RuntimeError("restart budget exhausted") from e
                print(f"[trainer] {e} — restarting from latest checkpoint "
                      f"(restart {restarts}/{self.tcfg.max_restarts})")
                continue

    def _run_once(self) -> Dict[str, Any]:
        ctx = (
            partition.use_partitioning(self.mesh, self.rules)
            if self.mesh is not None and self.rules is not None
            else _null_ctx()
        )
        with ctx:
            params, opt_state = self._init_state()
            start_step = 0
            if self.ckpt is not None and self.ckpt.latest_step() is not None:
                (params, opt_state), start_step, extra = self.ckpt.restore(
                    (params, opt_state)
                )
                print(f"[trainer] resumed from step {start_step}")

            last_metrics: Dict[str, float] = {}
            for step in range(start_step, self.tcfg.total_steps):
                self.injector.check(step)  # may raise → checkpoint-restart
                batch_np = self.data.global_batch(step)
                batch = {k: jnp.asarray(v) for k, v in batch_np.items()}
                t0 = time.perf_counter()
                params, opt_state, metrics = self._step_fn(params, opt_state, batch)
                jax.block_until_ready(metrics["loss"])
                dt = time.perf_counter() - t0
                for h in range(self.data_cfg.n_hosts):
                    self.straggler.record(h, dt)  # single-process: same signal
                last_metrics = {k: float(v) for k, v in metrics.items()}
                last_metrics["step_time_s"] = dt
                self.metrics_log.append({"step": step, **last_metrics})
                if step % self.tcfg.log_every == 0:
                    print(f"[trainer] step {step} loss={last_metrics['loss']:.4f} "
                          f"({dt*1e3:.0f} ms)")
                if self.ckpt is not None and (step + 1) % self.tcfg.ckpt_every == 0:
                    self.ckpt.save(step + 1, (params, opt_state), extra={"loss": last_metrics["loss"]})
            if self.ckpt is not None:
                self.ckpt.save(self.tcfg.total_steps, (params, opt_state),
                               extra={"loss": last_metrics.get("loss")})
                self.ckpt.wait()
            return {
                "params": params,
                "opt_state": opt_state,
                "final_metrics": last_metrics,
                "history": self.metrics_log,
                "grad_allreduce_algorithm": self.grad_allreduce_algorithm,
                "grad_allreduce_cost_s": self.grad_allreduce_cost_s,
                "pccl_concurrent": self.concurrent_step_cost,
                "pccl_cache": self.pccl.stats,
                "pccl_exec": self.pccl.exec_stats(),
                "stragglers": self.straggler.stragglers(),
            }


class _null_ctx:
    def __enter__(self):
        return self

    def __exit__(self, *a):
        return False
