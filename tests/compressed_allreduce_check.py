"""Device-level checks for the int8-compressed all-reduce (+error feedback).

Run as a subprocess by test_compressed_allreduce.py with 4 host devices.
Asserts the documented quantization-error bound against ``lax.psum`` and
the error-feedback bias-shrinking property across steps.
"""

import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=4 "
    + os.environ.get("XLA_FLAGS", "")
)

import jax
import numpy as np
from jax import lax
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.comm.pccl_collectives import (
    ErrorFeedbackState,
    compressed_all_reduce,
    compressed_all_reduce_ef,
)

N = 4


def _mesh():
    return compat.make_mesh((N,), ("x",))


def _smap(f, mesh, in_specs, out_specs):
    return jax.jit(
        compat.shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_vma=False
        )
    )


def check_quantization_bound_vs_psum():
    """|compressed_all_reduce - psum| within the documented per-hop bound.

    The wire format quantizes once per RS hop (n-1 hops, the payload being a
    partial sum of ≤ j addends) plus once before the AG phase (the full
    n-addend sum); each quantization errs ≤ scale/2 = max|payload| / 254.
    Summing the worst cases gives err ≤ A·(Σ_{j≤n-1} j + n) / 254 with
    A = max per-rank per-element magnitude — we assert with a 2× slack for
    the error the bound's own payload-magnitude estimate feeds back in.
    """
    mesh = _mesh()
    rng = np.random.default_rng(0)
    X = rng.normal(size=(N, N * 32)).astype(np.float32)

    out = np.asarray(
        _smap(lambda x: compressed_all_reduce(x[0], "x", N), mesh, P("x", None), P(None))(X)
    )
    want = np.asarray(
        _smap(lambda x: lax.psum(x[0], "x"), mesh, P("x", None), P(None))(X)
    )
    np.testing.assert_allclose(want, X.sum(axis=0), rtol=1e-6)

    A = np.abs(X).max()
    hops_weight = sum(range(1, N)) + N  # RS partial-sum hops + the AG quant
    bound = 2.0 * A * hops_weight / 254.0
    err = np.abs(out - want).max()
    assert err <= bound, (err, bound)
    # and the bound is doing work: the reduction is genuinely close
    rel = np.abs(out - want) / (np.abs(want) + 1e-6)
    assert np.median(rel) < 0.05, np.median(rel)
    print(f"quantization bound OK (err {err:.4f} <= bound {bound:.4f})")


def check_error_feedback_shrinks_bias():
    """Averaging EF-compensated reductions of the SAME gradient converges
    toward the exact sum; without EF the bias is static."""
    mesh = _mesh()
    rng = np.random.default_rng(1)
    X = rng.normal(size=(N, N * 16)).astype(np.float32)
    want = X.sum(axis=0)

    raw = np.asarray(
        _smap(lambda x: compressed_all_reduce(x[0], "x", N), mesh, P("x", None), P(None))(X)
    )
    err_raw = np.abs(raw - want).mean()

    def g(x, r):
        red, ef = compressed_all_reduce_ef(x[0], ErrorFeedbackState(r[0]), "x", N)
        return red, ef.residual[None]

    step = _smap(g, mesh, (P("x", None), P("x", None)), (P(None), P("x", None)))
    r = np.zeros_like(X)
    accum = np.zeros_like(want)
    errs = []
    for k in range(1, 9):
        red, r = step(X, r)
        accum += np.asarray(red)
        errs.append(np.abs(accum / k - want).mean())

    err_1, err_8 = errs[0], errs[-1]
    assert err_8 < err_1, (err_8, err_1)  # bias shrinks across steps
    assert err_8 <= err_raw * 1.05, (err_8, err_raw)
    print(f"error feedback OK (bias {err_1:.5f} -> {err_8:.5f}, raw {err_raw:.5f})")


def main():
    assert jax.device_count() == N, jax.devices()
    check_quantization_bound_vs_psum()
    check_error_feedback_shrinks_bias()
    print("ALL-COMPRESSED-OK")


if __name__ == "__main__":
    main()
