"""Shared pytest configuration: marker registry + optional-dep helpers."""

import pytest


def hypothesis_or_stubs():
    """``(given, settings, st)`` — the real hypothesis API, or stand-ins that
    skip *only* the property tests so the rest of the module still runs
    (a missing optional dep must not silence plain unit tests)."""
    try:
        from hypothesis import given, settings
        from hypothesis import strategies as st

        return given, settings, st
    except ImportError:
        class _AnyStrategy:
            """Absorbs any strategy construction/chaining at import time."""

            def __getattr__(self, name):
                return self

            def __call__(self, *args, **kwargs):
                return self

        def given(*args, **kwargs):
            return pytest.mark.skip(reason="hypothesis not installed")

        def settings(*args, **kwargs):
            return lambda f: f

        return given, settings, _AnyStrategy()


@pytest.fixture
def dataflow_verifier():
    """The static chunk-dataflow verifier, raise-on-failure form.

    Every new schedule generator must pass this fixture (see
    CONTRIBUTING.md): ``dataflow_verifier(schedule)`` proves the
    collective's postcondition statically and raises
    ``ScheduleVerificationError`` with an attributable failure
    (round/rank/chunk, expected vs. abstract state) otherwise.  Pass
    ``groups=`` for ``replicate_groups`` compositions.
    """
    from repro.analysis.verify import assert_verified

    return assert_verified


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "slow: long-running test (multi-minute subprocess or sweep)",
    )
    config.addinivalue_line(
        "markers",
        "multidevice: drives >1 host device via an XLA_FLAGS subprocess",
    )
