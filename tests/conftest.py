"""Shared pytest configuration: marker registry + optional-dep helpers."""

import pytest


def hypothesis_or_stubs():
    """``(given, settings, st)`` — the real hypothesis API, or stand-ins that
    skip *only* the property tests so the rest of the module still runs
    (a missing optional dep must not silence plain unit tests)."""
    try:
        from hypothesis import given, settings
        from hypothesis import strategies as st

        return given, settings, st
    except ImportError:
        class _AnyStrategy:
            """Absorbs any strategy construction/chaining at import time."""

            def __getattr__(self, name):
                return self

            def __call__(self, *args, **kwargs):
                return self

        def given(*args, **kwargs):
            return pytest.mark.skip(reason="hypothesis not installed")

        def settings(*args, **kwargs):
            return lambda f: f

        return given, settings, _AnyStrategy()


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "slow: long-running test (multi-minute subprocess or sweep)",
    )
    config.addinivalue_line(
        "markers",
        "multidevice: drives >1 host device via an XLA_FLAGS subprocess",
    )
