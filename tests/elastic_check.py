"""Elastic re-mesh check on 8 host devices (subprocess; see
test_fault_elastic.py): drop a failed host's slice, rebuild the mesh,
re-shard live state, and keep training."""

import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=8 " + os.environ.get("XLA_FLAGS", "")
)

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import compat
from repro.runtime.fault import reshard_tree, shrink_mesh


def main():
    mesh = compat.make_mesh((4, 2), ("data", "model"))
    sh = {
        "w": NamedSharding(mesh, P("data", "model")),
        "b": NamedSharding(mesh, P(None, "model")),
    }
    tree = {
        "w": jax.device_put(jnp.arange(64.0).reshape(8, 8), sh["w"]),
        "b": jax.device_put(jnp.ones((4, 8)), sh["b"]),
    }

    # "fail" the host holding devices of data-slice 2
    failed = [d.id for d in np.asarray(mesh.devices)[2].flatten()]
    new_mesh = shrink_mesh(mesh, failed, ("data", "model"), shrink_axis="data")
    assert dict(new_mesh.shape) == {"data": 3, "model": 2}, new_mesh.shape

    new_tree = reshard_tree(tree, sh, new_mesh)
    # values preserved exactly
    np.testing.assert_array_equal(np.asarray(new_tree["w"]), np.arange(64.0).reshape(8, 8))
    # w: 8 rows % 3 data shards != 0 → fit-or-drop replicates rows, keeps model
    spec_w = new_tree["w"].sharding.spec
    assert spec_w[1] == ("model",) or spec_w[1] == "model", spec_w
    # training continues on the shrunk mesh
    def step(t):
        return jax.tree.map(lambda x: x * 2.0, t)

    out = jax.jit(step)(new_tree)
    np.testing.assert_array_equal(np.asarray(out["b"]), 2 * np.ones((4, 8)))
    print("ELASTIC-OK")


if __name__ == "__main__":
    main()
