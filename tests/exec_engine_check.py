"""Device-level checks for the compiled schedule execution engine.

Run as a subprocess by test_exec_engine.py with 8 host devices (XLA locks
the device count at first jax init, so this cannot share a process with the
single-device suite).  Asserts:

* engine output **bit-identical** to the pre-PR per-round interpreter
  (``execute_schedule_reference`` + dense all-to-all state) for all four
  collectives × their {ring, rhd, dex, direct} algorithms × n ∈ {4, 8},
  on the full axis and on split (two-group) communicators;
* the O(n·blk) slot-addressed all-to-all cross-checks against the dense
  O(n²·blk) path;
* the eager jitted-executable cache: second identical call is a cache hit
  with zero new traces; reductions stay correct through donation.
"""

import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=8 "
    + os.environ.get("XLA_FLAGS", "")
)

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.api import PcclSession, subgroup_schedule
from repro.comm import exec_engine
from repro.comm import primitives as prim
from repro.core import cost_model as cm
from repro.core import schedules as S

ALGOS = {
    "reduce_scatter": ("ring", "rhd"),
    "all_gather": ("ring", "rhd"),
    "all_reduce": ("ring", "rhd"),
    "all_to_all": ("dex", "direct", "ring"),
}


def mesh_of(n):
    return compat.make_mesh((n,), ("x",), devices=jax.devices()[:n])


def smap(f, mesh, in_specs, out_specs):
    return jax.jit(
        compat.shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_vma=False
        )
    )


# ----------------------------------------------------- pre-PR interpreter
# Full-axis oracle: the shared ``primitives.run_reference`` (the original
# wrappers verbatim over the per-round reference executor); the grouped
# variant below exists only here.
ref_collective = prim.run_reference


def ref_grouped(collective, x, sched, axis, me_local, m):
    """Pre-PR grouped path: group-local buffers, dense a2a state."""
    if collective == "reduce_scatter":
        chunks = x.reshape((m, x.shape[0] // m) + x.shape[1:])
        chunks = prim.execute_schedule_reference(chunks, sched, axis)
        return jnp.take(chunks, me_local, axis=0)
    if collective == "all_reduce":
        chunks = x.reshape((m, x.shape[0] // m) + x.shape[1:])
        chunks = prim.execute_schedule_reference(chunks, sched, axis)
        return chunks.reshape(x.shape)
    if collective == "all_gather":
        chunks = jnp.zeros((m,) + x.shape, x.dtype).at[me_local].set(x)
        chunks = prim.execute_schedule_reference(chunks, sched, axis)
        return chunks.reshape((m * x.shape[0],) + x.shape[1:])
    if collective == "all_to_all":
        blocks = x.reshape((m, x.shape[0] // m) + x.shape[1:])
        state = jnp.zeros((m, m) + blocks.shape[1:], blocks.dtype)
        state = state.at[me_local].set(blocks)
        flat = state.reshape((m * m,) + blocks.shape[1:])
        flat = prim.execute_schedule_reference(flat, sched, axis)
        state = flat.reshape((m, m) + blocks.shape[1:])
        return jnp.take(state, me_local, axis=1).reshape(x.shape)
    raise AssertionError(collective)


def engine_collective(collective, x, sched, axis):
    return getattr(prim, collective)(x, sched, axis)


def make_schedule(collective, algo, n, d):
    return S.get_schedule(collective, algo, n, d)


def local_input(collective, n, rng):
    """Per-rank local operand (stacked rank-major into the global array)."""
    if collective == "reduce_scatter":
        return rng.normal(size=(n, n * 3)).astype(np.float32)
    if collective == "all_gather":
        return rng.normal(size=(n, 5)).astype(np.float32)
    if collective == "all_reduce":
        return rng.normal(size=(n, 2 * n)).astype(np.float32)
    return rng.normal(size=(n, n * 2)).astype(np.float32)  # all_to_all


def check_full_axis_bit_identity():
    rng = np.random.default_rng(0)
    for n in (4, 8):
        mesh = mesh_of(n)
        for collective, algos in ALGOS.items():
            X = local_input(collective, n, rng)
            d = float(X.nbytes / n)
            for algo in algos:
                sched = make_schedule(collective, algo, n, d)

                def fe(x):
                    return engine_collective(collective, x[0], sched, "x")[None]

                def fr(x):
                    return ref_collective(collective, x[0], sched, "x")[None]

                oe = np.asarray(smap(fe, mesh, P("x", None), P("x", None))(X))
                orf = np.asarray(smap(fr, mesh, P("x", None), P("x", None))(X))
                np.testing.assert_array_equal(
                    oe, orf, err_msg=f"{collective}/{algo} n={n}"
                )
            print(f"full-axis bit-identity {collective} n={n} OK")


def check_split_bit_identity():
    """Engine grouped path (Communicator.split) vs the pre-PR grouped
    interpreter, on two interleaved groups of 4 over an 8-rank axis."""
    n_axis, m = 8, 4
    mesh = mesh_of(n_axis)
    colors = [r % 2 for r in range(n_axis)]
    groups = ((0, 2, 4, 6), (1, 3, 5, 7))
    local_of = np.zeros(n_axis, np.int32)
    for g in groups:
        for i, r in enumerate(g):
            local_of[r] = i
    local_dev = jnp.asarray(local_of)
    rng = np.random.default_rng(1)
    session = PcclSession(cm.TPU_V5E_PHOTONIC, thread_fabric=False)
    root = session.communicator("x", n_axis, backend="interp")

    for collective, algos in ALGOS.items():
        X = local_input(collective, m, rng)
        X = np.concatenate([X, X[::-1] * 0.5], axis=0)[:n_axis]  # 8 rows
        d = float(X[0].nbytes)
        for algo in algos:
            sub = root.split(colors, algorithm=algo)
            sched = subgroup_schedule(make_schedule(collective, algo, m, d), groups, n_axis)

            def fe(x):
                return getattr(sub, collective)(x[0])[None]

            def fr(x):
                me_local = jnp.take(local_dev, lax.axis_index("x"))
                return ref_grouped(collective, x[0], sched, "x", me_local, m)[None]

            oe = np.asarray(smap(fe, mesh, P("x", None), P("x", None))(X))
            orf = np.asarray(smap(fr, mesh, P("x", None), P("x", None))(X))
            np.testing.assert_array_equal(
                oe, orf, err_msg=f"split {collective}/{algo}"
            )
        print(f"split bit-identity {collective} OK")


def check_compact_vs_dense_all_to_all():
    rng = np.random.default_rng(2)
    for n in (4, 8):
        mesh = mesh_of(n)
        X = rng.normal(size=(n, n * 3)).astype(np.float32)
        d = float(X.nbytes / n)
        for algo in ("dex", "direct", "ring"):
            sched = make_schedule("all_to_all", algo, n, d)
            # the compact compile must actually engage for generated schedules
            assert exec_engine.compile_all_to_all(sched, n, tuple(range(n))) is not None

            def fc(x):
                return prim.all_to_all(x[0], sched, "x")[None]

            def fd(x):
                return prim.all_to_all_dense(x[0], sched, "x")[None]

            oc = np.asarray(smap(fc, mesh, P("x", None), P("x", None))(X))
            od = np.asarray(smap(fd, mesh, P("x", None), P("x", None))(X))
            np.testing.assert_array_equal(oc, od, err_msg=f"a2a {algo} n={n}")
            # and both must satisfy the all-to-all post-condition
            want = X.reshape(n, n, 3).transpose(1, 0, 2).reshape(n, n * 3)
            np.testing.assert_array_equal(oc, want)
        print(f"compact-vs-dense all_to_all n={n} OK")


def check_executable_cache_accounting():
    """Second identical eager call = executable-cache hit, zero retraces."""
    exec_engine.clear_exec_caches()
    n = 8
    # thread_fabric=False keeps the planned schedule deterministic across
    # calls; the executable cache is keyed by fingerprint either way
    session = PcclSession(cm.TPU_V5E_PHOTONIC, thread_fabric=False)
    comm = session.communicator("x", n, backend="interp")
    rng = np.random.default_rng(3)

    X = rng.normal(size=(n, 24)).astype(np.float32)
    out1 = np.asarray(comm.all_reduce(X))
    s1 = exec_engine.exec_stats()
    assert s1.executable_misses == 1 and s1.executable_hits == 0, s1
    assert s1.traces >= 1, s1

    out2 = np.asarray(comm.all_reduce(X))
    s2 = exec_engine.exec_stats()
    assert s2.executable_hits == 1 and s2.executable_misses == 1, s2
    assert s2.traces == s1.traces, (s2, s1)  # 0 retraces on the second call

    want = np.broadcast_to(X.sum(axis=0), X.shape)
    np.testing.assert_allclose(out1, want, rtol=1e-5, atol=1e-6)
    np.testing.assert_array_equal(out1, out2)

    # a different shape is a genuine miss (and one new trace)
    Y = rng.normal(size=(n, 48)).astype(np.float32)
    comm.all_reduce(Y)
    s3 = exec_engine.exec_stats()
    assert s3.executable_misses == 2 and s3.traces == s2.traces + 1, s3

    # every eager collective round-trips through the cache
    for collective, make in (
        ("reduce_scatter", lambda: rng.normal(size=(n, n * 2)).astype(np.float32)),
        ("all_gather", lambda: rng.normal(size=(n, 3)).astype(np.float32)),
        ("all_to_all", lambda: rng.normal(size=(n, n * 2)).astype(np.float32)),
    ):
        Z = make()
        before = exec_engine.exec_stats()
        o1 = np.asarray(getattr(comm, collective)(Z))
        o2 = np.asarray(getattr(comm, collective)(Z))
        after = exec_engine.exec_stats()
        assert after.executable_hits == before.executable_hits + 1, collective
        assert after.traces == before.traces + 1, collective
        np.testing.assert_array_equal(o1, o2)
    print("executable cache accounting OK")


def check_eager_matches_shard_map():
    """The eager global-operand convention agrees with in-shard_map use."""
    n = 8
    mesh = mesh_of(n)
    session = PcclSession(cm.TPU_V5E_PHOTONIC, thread_fabric=False)
    comm = session.communicator("x", n, backend="interp")
    rng = np.random.default_rng(4)

    X = rng.normal(size=(n, n * 2)).astype(np.float32)
    eager = np.asarray(comm.all_to_all(X))
    traced = np.asarray(
        smap(lambda x: comm.all_to_all(x[0])[None], mesh, P("x", None), P("x", None))(X)
    )
    np.testing.assert_array_equal(eager, traced)

    # a concrete constant used *inside* a shard_map body is mid-trace state,
    # not an eager call — it must route through the trace path (regression:
    # tracer-only dispatch misrouted it to the eager executable builder)
    C = np.arange(n * 2, dtype=np.float32)
    outc = np.asarray(
        smap(
            lambda x: comm.all_reduce(jnp.asarray(C))[None],
            mesh, P("x", None), P("x", None),
        )(X)
    )
    np.testing.assert_allclose(outc[0], C * n, rtol=1e-6)

    # split communicator, eager: per-group reduction
    colors = [r // 4 for r in range(n)]
    sub = comm.split(colors)
    Y = rng.normal(size=(n, 12)).astype(np.float32)
    got = np.asarray(sub.all_reduce(Y))
    want = np.empty_like(Y)
    for g in ((0, 1, 2, 3), (4, 5, 6, 7)):
        s = Y[list(g)].sum(axis=0)
        for r in g:
            want[r] = s
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)
    print("eager/shard_map parity OK")


def main():
    assert jax.device_count() == 8, jax.devices()
    check_full_axis_bit_identity()
    check_split_bit_identity()
    check_compact_vs_dense_all_to_all()
    check_executable_cache_accounting()
    check_eager_matches_shard_map()
    print("ALL-EXEC-ENGINE-OK")


if __name__ == "__main__":
    main()
