"""Device-level checks for the comm/compute fusion layer.

Run as a subprocess by test_fusion.py with 8 host devices (XLA locks the
device count at first jax init, so this cannot share a process with the
single-device suite).  Asserts:

* :func:`repro.comm.fusion.fused_matmul_reduce_scatter` is **bit-identical**
  to the unfused kernel-then-collective composition across n ∈ {4, 8} and
  dtypes {float32, bfloat16}, and every fallback trigger (blocks that do
  not tile the chunk, a grouped communicator) still returns the identical
  result while counting a fallback dispatch;
* :func:`fused_all_reduce_rmsnorm` is bit-identical to
  ``all_reduce`` → rmsnorm, with the size-indivisible fallback counted;
* ``ring_ef8`` execution (``all_reduce_quantized`` through the interp
  backend, full-axis and split) stays within the documented accuracy
  bound of the exact ring all-reduce and runs the same number of rounds.
"""

import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=8 "
    + os.environ.get("XLA_FLAGS", "")
)

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import PcclSession
from repro.comm import exec_engine
from repro.comm.fusion import fused_all_reduce_rmsnorm, fused_matmul_reduce_scatter
from repro.core import cost_model as cm
from repro.core.cost_model import compressed_ef_error_bound
from repro.kernels.matmul.ops import matmul
from repro.kernels.rmsnorm.ops import rmsnorm

HW = cm.TPU_V5E_PHOTONIC
INTERPRET = jax.default_backend() == "cpu"


def fresh_comm(n, algorithm="ring"):
    session = PcclSession(HW, thread_fabric=False)
    return session.communicator("x", n, backend="interp", algorithm=algorithm)


def unfused_mm_rs(comm, x, w, **blocks):
    """The sequential oracle: whole-M kernel dispatch, then the collective."""
    S, M, K = x.shape
    y = matmul(
        x.reshape(S * M, K), w, use_pallas=True, interpret=INTERPRET, **blocks
    ).reshape(S, M, w.shape[1])
    return comm.reduce_scatter(y)


def check_fused_mm_rs_bit_identity():
    for n, M, K, N, dtype in [
        (8, 256, 128, 128, np.float32),
        (8, 64, 128, 256, np.float32),
        (4, 128, 64, 128, np.float32),
        (8, 256, 128, 128, jnp.bfloat16),
    ]:
        rng = np.random.default_rng(M + N)
        x = jnp.asarray(rng.normal(size=(n, M, K)), dtype=dtype)
        w = jnp.asarray(rng.normal(size=(K, N)), dtype=dtype)
        comm = fresh_comm(n)
        blocks = dict(block_m=M // n, block_n=N, block_k=K)
        s0 = exec_engine.exec_stats()
        got = fused_matmul_reduce_scatter(comm, x, w, **blocks)
        s1 = exec_engine.exec_stats()
        assert s1.fused_dispatches == s0.fused_dispatches + 1, (s0, s1)
        assert s1.chunks_streamed == s0.chunks_streamed + n
        assert s1.bytes_hidden > s0.bytes_hidden
        want = unfused_mm_rs(comm, x, w, **blocks)
        assert got.shape == (n, M // n, N)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    print("fused mm+RS bit-identity OK")


def check_fused_mm_rs_fallbacks():
    n, M, K, N = 8, 256, 128, 128
    rng = np.random.default_rng(7)
    x = jnp.asarray(rng.normal(size=(n, M, K)), dtype=jnp.float32)
    w = jnp.asarray(rng.normal(size=(K, N)), dtype=jnp.float32)

    # blocks that do not tile the (Mc=32, K, N) chunk -> unfused path
    comm = fresh_comm(n)
    s0 = exec_engine.exec_stats()
    got = fused_matmul_reduce_scatter(comm, x, w, block_m=24)
    s1 = exec_engine.exec_stats()
    assert s1.fallback_dispatches == s0.fallback_dispatches + 1
    assert s1.fused_dispatches == s0.fused_dispatches
    np.testing.assert_array_equal(
        np.asarray(got), np.asarray(unfused_mm_rs(comm, x, w, block_m=24))
    )

    # grouped communicator -> unfused path (two groups of 4)
    split = fresh_comm(8).split([0, 0, 0, 0, 1, 1, 1, 1])
    s0 = exec_engine.exec_stats()
    got = fused_matmul_reduce_scatter(split, x, w, block_m=32, block_n=N,
                                      block_k=K)
    s1 = exec_engine.exec_stats()
    assert s1.fallback_dispatches == s0.fallback_dispatches + 1
    np.testing.assert_array_equal(
        np.asarray(got),
        np.asarray(unfused_mm_rs(split, x, w, block_m=32, block_n=N,
                                 block_k=K)),
    )
    print("fused mm+RS fallbacks OK")


def check_fused_ar_rmsnorm():
    n, rows, d = 8, 64, 256
    rng = np.random.default_rng(11)
    x = jnp.asarray(rng.normal(size=(n, rows, d)), dtype=jnp.float32)
    g = jnp.asarray(rng.normal(size=(d,)), dtype=jnp.float32)
    comm = fresh_comm(n)
    s0 = exec_engine.exec_stats()
    got = fused_all_reduce_rmsnorm(comm, x, g)
    s1 = exec_engine.exec_stats()
    assert s1.fused_dispatches == s0.fused_dispatches + 1
    want = rmsnorm(comm.all_reduce(x), g, use_pallas=True, interpret=INTERPRET)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    # local size not divisible by n (5*254 % 8 != 0) -> sequential
    # fallback, same result
    x_odd = jnp.asarray(rng.normal(size=(n, 5, 254)), dtype=jnp.float32)
    g_odd = jnp.asarray(rng.normal(size=(254,)), dtype=jnp.float32)
    s0 = exec_engine.exec_stats()
    got = fused_all_reduce_rmsnorm(comm, x_odd, g_odd)
    s1 = exec_engine.exec_stats()
    assert s1.fallback_dispatches == s0.fallback_dispatches + 1
    want = rmsnorm(comm.all_reduce(x_odd), g_odd, use_pallas=True,
                   interpret=INTERPRET)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    print("fused AR+rmsnorm OK")


def check_ring_ef8_execution():
    n, d = 8, 512
    rng = np.random.default_rng(13)
    x = jnp.asarray(rng.normal(size=(n, d)), dtype=jnp.float32)

    exact = np.asarray(fresh_comm(n, "ring").all_reduce(x))
    lossy = np.asarray(fresh_comm(n, "ring_ef8").all_reduce(x))
    assert lossy.shape == exact.shape
    assert not np.array_equal(lossy, exact)  # it really quantized the wire
    # documented first-order bound, in absolute form: the relative bound
    # (n-1)/127 is w.r.t. n*A where A = max per-rank magnitude
    A = float(np.abs(np.asarray(x)).max())
    bound = compressed_ef_error_bound(n) * n * A
    err = float(np.abs(lossy - exact).max())
    assert err <= bound, (err, bound)

    # grouped routing: two independent groups of 4, each within its bound
    lossy_g = np.asarray(
        fresh_comm(8, "ring_ef8").split([0, 0, 0, 0, 1, 1, 1, 1]).all_reduce(x)
    )
    exact_g = np.asarray(
        fresh_comm(8, "ring").split([0, 0, 0, 0, 1, 1, 1, 1]).all_reduce(x)
    )
    bound_g = compressed_ef_error_bound(4) * 4 * A
    err_g = float(np.abs(lossy_g - exact_g).max())
    assert err_g <= bound_g, (err_g, bound_g)
    print(f"ring_ef8 execution OK (err {err:.4f} <= bound {bound:.4f})")


if __name__ == "__main__":
    assert jax.device_count() == 8, jax.devices()
    check_fused_mm_rs_bit_identity()
    check_fused_mm_rs_fallbacks()
    check_fused_ar_rmsnorm()
    check_ring_ef8_execution()
    print("ALL-FUSION-OK")
