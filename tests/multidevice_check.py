"""Multi-device equivalence checks for executable PCCL collectives.

Run as a subprocess by test_comm_multidevice.py with 8 host devices (this
must set XLA_FLAGS before importing jax, which pytest's process cannot do
without polluting single-device tests — see the dry-run rule in the
assignment).  Asserts every schedule-driven collective matches the XLA
reference collective bit-for-bit in fp32.
"""

import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=8 "
    + os.environ.get("XLA_FLAGS", "")
)

import warnings

import jax
import numpy as np
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.api import PcclSession
from repro.comm import primitives as prim
from repro.comm.pccl_collectives import (
    ErrorFeedbackState,
    PcclComm,
    compressed_all_reduce,
    compressed_all_reduce_ef,
)
from repro.core import cost_model as cm
from repro.core import schedules as S

warnings.simplefilter("ignore", DeprecationWarning)  # PcclComm shim coverage

N = 8


def _mesh():
    return compat.make_mesh((N,), ("x",))


def _smap(f, mesh, in_specs, out_specs):
    return jax.jit(compat.shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_vma=False))


def check_reduce_scatter():
    mesh = _mesh()
    rng = np.random.default_rng(0)
    X = rng.normal(size=(N, N * 6)).astype(np.float32)  # rank-major addends

    for algo, sched in [
        ("ring", S.ring_reduce_scatter(N, X.nbytes / N)),
        ("rhd", S.rhd_reduce_scatter(N, X.nbytes / N)),
    ]:
        def f(x):
            return prim.reduce_scatter(x[0], sched, "x")[None]

        out = _smap(f, mesh, P("x", None), P("x", None))(X)
        want = X.sum(axis=0).reshape(N, 6)  # chunk c belongs to rank c
        np.testing.assert_allclose(np.asarray(out), want, rtol=1e-5, atol=1e-6)
        print(f"reduce_scatter/{algo} OK")


def check_all_gather():
    mesh = _mesh()
    rng = np.random.default_rng(1)
    X = rng.normal(size=(N * 5,)).astype(np.float32)

    for algo, sched in [
        ("ring", S.ring_all_gather(N, X.nbytes)),
        ("rhd", S.rhd_all_gather(N, X.nbytes)),
    ]:
        def f(x):
            return prim.all_gather(x, sched, "x")

        out = _smap(f, mesh, P("x"), P(None))(X)
        np.testing.assert_allclose(np.asarray(out), X, rtol=0)
        print(f"all_gather/{algo} OK")


def check_all_reduce():
    mesh = _mesh()
    rng = np.random.default_rng(2)
    X = rng.normal(size=(N, 40)).astype(np.float32)

    for algo, sched in [
        ("ring", S.ring_all_reduce(N, X.nbytes / N)),
        ("rhd", S.rhd_all_reduce(N, X.nbytes / N)),
        ("bucket2d", S.bucket_all_reduce((2, 4), X.nbytes / N)),
    ]:
        def f(x):
            return prim.all_reduce(x[0], sched, "x")

        out = _smap(f, mesh, P("x", None), P(None))(X)
        np.testing.assert_allclose(np.asarray(out), X.sum(axis=0), rtol=1e-5, atol=1e-6)
        print(f"all_reduce/{algo} OK")


def check_all_to_all():
    mesh = _mesh()
    rng = np.random.default_rng(3)
    B = 3
    X = rng.normal(size=(N, N * B)).astype(np.float32)  # [rank, dest-major]

    for algo, sched in [
        ("dex", S.dex_all_to_all(N, X.nbytes / N)),
        ("direct", S.direct_all_to_all(N, X.nbytes / N)),
        ("ring", S.ring_all_to_all(N, X.nbytes / N)),
    ]:
        def f(x):
            return prim.all_to_all(x[0], sched, "x")[None]

        out = np.asarray(_smap(f, mesh, P("x", None), P("x", None))(X))
        want = (
            X.reshape(N, N, B).transpose(1, 0, 2).reshape(N, N * B)
        )  # block (s -> t) lands at rank t, origin-major
        np.testing.assert_allclose(out, want, rtol=0)
        print(f"all_to_all/{algo} OK")


def check_pccl_comm_api():
    mesh = _mesh()
    rng = np.random.default_rng(4)
    X = rng.normal(size=(N, 64)).astype(np.float32)
    comm = PcclComm(axis_name="x", n=N)
    assert comm.chosen_algorithm("all_reduce", 64 * 4) in ("rhd", "ring", "bucket2d", "bucket3d")

    def f(x):
        return comm.all_reduce(x[0])

    out = _smap(f, mesh, P("x", None), P(None))(X)
    np.testing.assert_allclose(np.asarray(out), X.sum(axis=0), rtol=1e-5, atol=1e-6)

    comm_xla = PcclComm(axis_name="x", n=N, algorithm="xla")

    def g(x):
        return comm_xla.all_reduce(x[0])

    out2 = _smap(g, mesh, P("x", None), P(None))(X)
    np.testing.assert_allclose(np.asarray(out2), X.sum(axis=0), rtol=1e-5, atol=1e-6)
    print("PcclComm API OK")


def check_compressed_all_reduce():
    mesh = _mesh()
    rng = np.random.default_rng(5)
    X = rng.normal(size=(N, N * 16)).astype(np.float32)

    def f(x):
        return compressed_all_reduce(x[0], "x", N)

    out = np.asarray(_smap(f, mesh, P("x", None), P(None))(X))
    want = X.sum(axis=0)
    rel = np.abs(out - want) / (np.abs(want) + 1e-6)
    assert np.median(rel) < 0.05, f"median rel err {np.median(rel)}"

    # error feedback: mean residual-compensated error over repeated reduces of
    # the SAME gradient should shrink vs no-EF (bias correction property)
    def g(x, r):
        red, ef = compressed_all_reduce_ef(x[0], ErrorFeedbackState(r[0]), "x", N)
        return red, ef.residual[None]

    r = np.zeros_like(X)
    accum_ef = np.zeros_like(want)
    accum_raw = np.zeros_like(want)
    steps = 8
    for _ in range(steps):
        red, r = _smap(g, mesh, (P("x", None), P("x", None)), (P(None), P("x", None)))(X, r)
        accum_ef += np.asarray(red)
        accum_raw += out
    err_ef = np.abs(accum_ef / steps - want).mean()
    err_raw = np.abs(accum_raw / steps - want).mean()
    assert err_ef <= err_raw * 1.05, (err_ef, err_raw)
    print("compressed_all_reduce OK")


def check_session_backend_parity():
    """interp and xla backends of the same Communicator agree numerically."""
    mesh = _mesh()
    rng = np.random.default_rng(6)
    session = PcclSession(cm.TPU_V5E_PHOTONIC)
    interp = session.communicator("x", N, backend="interp")
    xla = session.communicator("x", N, backend="xla")

    # all_reduce
    X = rng.normal(size=(N, 48)).astype(np.float32)
    oi = _smap(lambda x: interp.all_reduce(x[0]), mesh, P("x", None), P(None))(X)
    ox = _smap(lambda x: xla.all_reduce(x[0]), mesh, P("x", None), P(None))(X)
    np.testing.assert_allclose(np.asarray(oi), np.asarray(ox), rtol=1e-5, atol=1e-6)

    # reduce_scatter
    Y = rng.normal(size=(N, N * 4)).astype(np.float32)
    ri = _smap(lambda x: interp.reduce_scatter(x[0])[None], mesh, P("x", None), P("x", None))(Y)
    rx = _smap(lambda x: xla.reduce_scatter(x[0])[None], mesh, P("x", None), P("x", None))(Y)
    np.testing.assert_allclose(np.asarray(ri), np.asarray(rx), rtol=1e-5, atol=1e-6)

    # all_to_all
    Z = rng.normal(size=(N, N * 2)).astype(np.float32)
    ai = _smap(lambda x: interp.all_to_all(x[0])[None], mesh, P("x", None), P("x", None))(Z)
    ax = _smap(lambda x: xla.all_to_all(x[0])[None], mesh, P("x", None), P("x", None))(Z)
    np.testing.assert_allclose(np.asarray(ai), np.asarray(ax), rtol=0)

    # xla never plans; interp planned each collective exactly once
    assert session.stats.misses == 3 and session.stats.size == 3, session.stats
    print("session backend parity OK")


def check_communicator_split():
    """split(color) sub-groups reduce within each group only, on both
    backends (hierarchical DP×TP pattern)."""
    mesh = _mesh()
    rng = np.random.default_rng(7)
    session = PcclSession(cm.TPU_V5E_PHOTONIC)
    root = session.communicator("x", N, backend="interp")
    colors = [r % 2 for r in range(N)]  # two interleaved groups of 4

    X = rng.normal(size=(N, 24)).astype(np.float32)
    want = np.empty_like(X)
    for g in ((0, 2, 4, 6), (1, 3, 5, 7)):
        s = X[list(g)].sum(axis=0)
        for r in g:
            want[r] = s

    for backend in ("interp", "xla"):
        sub = root.split(colors, backend=backend)
        assert sub.n == 4 and sub.groups == ((0, 2, 4, 6), (1, 3, 5, 7))
        out = _smap(lambda x: sub.all_reduce(x[0])[None], mesh, P("x", None), P("x", None))(X)
        np.testing.assert_allclose(np.asarray(out), want, rtol=1e-5, atol=1e-6)

        # group-local all_gather: each rank gathers its group's shards
        Y = rng.normal(size=(N, 3)).astype(np.float32)
        wg = np.empty((N, 12), np.float32)
        for g in sub.groups:
            cat = np.concatenate([Y[r] for r in g])
            for r in g:
                wg[r] = cat
        og = _smap(lambda y: sub.all_gather(y[0])[None], mesh, P("x", None), P("x", None))(Y)
        np.testing.assert_allclose(np.asarray(og), wg, rtol=0)
        print(f"communicator split/{backend} OK")


def main():
    assert jax.device_count() == N, jax.devices()
    check_reduce_scatter()
    check_all_gather()
    check_all_reduce()
    check_all_to_all()
    check_pccl_comm_api()
    check_compressed_all_reduce()
    check_session_backend_parity()
    check_communicator_split()
    print("ALL-MULTIDEVICE-OK")


if __name__ == "__main__":
    main()
