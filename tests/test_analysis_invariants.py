"""Plan/circuit invariant checker: positive cases on real planner output,
negative cases on deliberately corrupted plans.

The checker re-derives every number in a ``Plan`` / ``ConcurrentPlan``
from the planner's own structure tables, so a clean result means the
accounting is internally consistent — and a corrupted field must be
attributed to the exact step/kind that disagrees.
"""

from dataclasses import replace

import pytest

from repro.analysis.invariants import (
    PlanInvariantError,
    assert_invariants,
    check_circuit_realizability,
    check_concurrent_plan,
    check_mode_monotonicity,
    check_plan,
    check_round_feasibility,
    check_schedule,
)
from repro.core import planner as P
from repro.core import schedules as S
from repro.core.cost_model import H100_DGX
from repro.core.schedules import Round, Schedule, Transfer
from repro.core.topology import ring, standard_topologies

D = float(1 << 20)
N = 8


@pytest.fixture(scope="module")
def env():
    g0 = ring(N)
    std = list(standard_topologies(N).values())
    return g0, std


# ---------------------------------------------------------- feasibility


def test_round_feasibility_clean_on_generators():
    for sched in (S.ring_reduce_scatter(8, D), S.rhd_all_reduce(8, D),
                  S.dex_all_to_all(8, D), S.bucket_all_reduce((2, 4), D)):
        assert check_round_feasibility(sched, H100_DGX) == []


def test_round_feasibility_flags_fanout_and_bad_ranks():
    base = S.direct_all_to_all(4, D)
    merged = Schedule(base.collective, base.algorithm, base.n,
                      base.buffer_bytes,
                      (Round(base.rounds[0].transfers + base.rounds[1].transfers,
                             base.rounds[0].size),) + base.rounds[2:])
    kinds = {v.kind for v in check_round_feasibility(merged, tx_limit=1)}
    assert "tx-limit" in kinds or "not-permutation" in kinds

    bad = Schedule("p2p", "direct", 4, D,
                   (Round((Transfer(0, 7, (0,), False),), D),))
    kinds = {v.kind for v in check_round_feasibility(bad)}
    assert "bad-rank" in kinds

    loop = Schedule("p2p", "direct", 4, D,
                    (Round((Transfer(2, 2, (0,), False),), D),))
    assert {v.kind for v in check_round_feasibility(loop)} == {"self-transfer"}


def test_circuit_realizability_on_representative_schedules():
    for sched in (S.rhd_reduce_scatter(8, D), S.direct_all_to_all(8, D),
                  S.ring_all_reduce(8, D)):
        assert check_circuit_realizability(sched) == []


def test_check_schedule_composes_passes():
    assert check_schedule(S.rhd_all_reduce(8, D), H100_DGX) == []
    vs = check_schedule(S.rhd_reduce_scatter(8, D), H100_DGX,
                        realizability=True)
    assert vs == []


# ----------------------------------------------------------- single plan


@pytest.mark.parametrize(
    "hw",
    [H100_DGX,
     H100_DGX.with_link_reconfig(H100_DGX.reconfig_delay / 8),
     H100_DGX.with_link_reconfig(H100_DGX.reconfig_delay / 8, overlap=True)],
    ids=["full", "partial", "overlap"],
)
def test_check_plan_clean_on_planner_output(env, hw):
    g0, std = env
    for sched in (S.rhd_reduce_scatter(N, D), S.dex_all_to_all(N, D)):
        p = P.plan(g0, std, sched, hw)
        assert check_plan(p, g0, std) == []


def test_check_plan_flags_corrupted_total(env):
    g0, std = env
    p = P.plan(g0, std, S.rhd_reduce_scatter(N, D), H100_DGX)
    bad = replace(p, total_cost=p.total_cost * 1.5)
    kinds = [v.kind for v in check_plan(bad, g0, std)]
    assert "total-cost" in kinds


def test_check_plan_flags_corrupted_step(env):
    g0, std = env
    p = P.plan(g0, std, S.rhd_reduce_scatter(N, D), H100_DGX)
    # find a reconfiguring step and inflate its reconfig charge
    idx = next(i for i, s in enumerate(p.steps) if s.reconfigured)
    steps = list(p.steps)
    steps[idx] = replace(steps[idx],
                         reconfig_cost=steps[idx].reconfig_cost + 1.0)
    bad = replace(p, steps=tuple(steps))
    vs = check_plan(bad, g0, std)
    assert any(v.kind == "reconfig-cost" and f"step {idx}" in v.where
               for v in vs)


def test_check_plan_flags_infeasible_state_swap(env):
    g0, std = env
    p = P.plan(g0, std, S.rhd_reduce_scatter(N, D), H100_DGX)
    steps = list(p.steps)
    # claim a different state index for a step without recosting it
    steps[0] = replace(steps[0], state_idx=(steps[0].state_idx + 1))
    bad = replace(p, steps=tuple(steps))
    assert check_plan(bad, g0, std) != []


def test_mode_monotonicity_holds(env):
    g0, std = env
    for sched in (S.rhd_reduce_scatter(N, D), S.ring_all_reduce(N, D)):
        assert check_mode_monotonicity(g0, std, sched, H100_DGX) == []


# ------------------------------------------------------- concurrent plan


@pytest.fixture(scope="module")
def concurrent(env):
    g0, std = env
    tp_groups, dp_groups = S.mesh_groups(4, 2)
    s_tp = S.replicate_groups(S.ring_all_reduce(4, D), tp_groups, N)
    s_dp = S.replicate_groups(S.ring_all_reduce(2, D), dp_groups, N)
    return P.plan_concurrent(g0, std, [s_tp, s_dp], H100_DGX)


def test_concurrent_plan_clean(env, concurrent):
    g0, std = env
    assert check_concurrent_plan(concurrent, g0, std) == []


def test_concurrent_plan_flags_corrupted_joint_cost(env, concurrent):
    g0, std = env
    bad = replace(concurrent, joint_cost=concurrent.joint_cost * 2.0)
    kinds = {v.kind for v in check_concurrent_plan(bad, g0, std)}
    # doubling the joint cost breaks the replayed decomposition and can
    # also flip the serialization decision — either attribution is exact
    assert kinds & {"joint-cost", "serialized-flag"}


def test_concurrent_plan_flags_corrupted_sequential(env, concurrent):
    g0, std = env
    bad = replace(concurrent, sequential_cost=concurrent.sequential_cost + 5.0)
    kinds = {v.kind for v in check_concurrent_plan(bad, g0, std)}
    assert "sequential-cost" in kinds


# ------------------------------------------------------------- raise form


def test_assert_invariants_raises_with_attribution(env):
    g0, std = env
    p = P.plan(g0, std, S.rhd_reduce_scatter(N, D), H100_DGX)
    bad = replace(p, total_cost=p.total_cost + 1.0)
    with pytest.raises(PlanInvariantError) as exc:
        assert_invariants(check_plan(bad, g0, std))
    assert "total-cost" in str(exc.value)
    assert_invariants([])  # empty list is a no-op
