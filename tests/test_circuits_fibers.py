import pytest

from repro.core import circuits as CC
from repro.core import fibers as F
from repro.core.topology import grid2d


# ------------------------------------------------------------- Algorithm 3
def test_mzi_mesh_structure():
    m = CC.MZIMesh(4, 4)
    assert m.n_nodes == 16
    assert m.n_edges == 24
    assert m.edge_id(0, 1) == m.edge_id(1, 0)


def test_route_two_disjoint_circuits():
    m = CC.MZIMesh(8, 8)
    reqs = [CC.CircuitRequest(0, 63), CC.CircuitRequest(7, 56)]
    res = CC.route_circuits(m, reqs)
    assert not res.failed
    CC.validate_routes(m, res, reqs)


def test_same_wavelength_circuits_never_share_waveguide():
    m = CC.MZIMesh(8, 8)
    reqs = CC.random_requests(m, 10, n_wavelengths=1, seed=1)
    res = CC.route_circuits(m, reqs)
    CC.validate_routes(m, res, reqs)  # asserts per-λ overlap ≤ 1
    assert len(res.failed) == 0


def test_oversubscription_fails_cleanly_never_violates_invariant():
    """Edge-disjointness per λ is a hard invariant: when demand exceeds the
    fabric, requests fail rather than share waveguides."""
    m = CC.MZIMesh(8, 8)
    reqs = CC.random_requests(m, 48, n_wavelengths=1, seed=1)
    res = CC.route_circuits(m, reqs)
    CC.validate_routes(m, res, reqs)
    # WDM relieves the contention: same demand over 4 wavelengths routes
    reqs4 = CC.random_requests(m, 48, n_wavelengths=4, seed=1)
    res4 = CC.route_circuits(m, reqs4)
    CC.validate_routes(m, res4, reqs4)
    assert len(res4.failed) < len(res.failed)


def test_wavelengths_are_independent():
    m = CC.MZIMesh(4, 4)
    # identical endpoints on different λ can share the same waveguides
    reqs = [CC.CircuitRequest(0, 15, 0), CC.CircuitRequest(0, 15, 1)]
    res = CC.route_circuits(m, reqs)
    assert not res.failed
    CC.validate_routes(m, res, reqs)


def test_conflicting_demand_forces_detour_or_failure():
    m = CC.MZIMesh(2, 2)  # tiny mesh: 4 edges
    reqs = [CC.CircuitRequest(0, 3), CC.CircuitRequest(0, 3)]
    res = CC.route_circuits(m, reqs)
    # two 0->3 circuits on one λ need edge-disjoint L-paths; the 2x2 mesh has
    # exactly two, so both must route
    assert not res.failed
    CC.validate_routes(m, res, reqs)


def test_fig19a_runtime_256_grid():
    """Fig. 19a: routes on a 256×256 mesh (65 K MZIs) in under 2.5 s."""
    m = CC.MZIMesh(256, 256)
    reqs = CC.random_requests(m, 16, n_wavelengths=4, seed=0)
    res = CC.route_circuits(m, reqs)
    assert not res.failed
    assert res.elapsed_s < 2.5
    CC.validate_routes(m, res, reqs)


# ------------------------------------------------------------- Algorithm 4
def test_fiber_routing_simple():
    topo = grid2d(2, 2)
    routing = F.route_fibers(topo, [(0, 3), (3, 0)])
    assert routing.z == 1
    for path, (s, d) in zip(routing.routes, [(0, 3), (3, 0)]):
        assert path[0] == s and path[-1] == d


def test_fiber_heuristic_matches_milp_small():
    topo = grid2d(3, 3)
    demands = F.random_demands(topo, 8, seed=3)
    h = F.route_fibers(topo, demands)
    m = F.route_fibers_milp(topo, demands)
    assert h.z >= m.z  # MILP is the certified optimum
    assert h.z - m.z <= 1  # heuristic within 1 fiber of optimal here
    # loads consistent with routes
    for routing in (h, m):
        load = {}
        for p in routing.routes:
            for a, b in zip(p[:-1], p[1:]):
                load[(a, b)] = load.get((a, b), 0) + 1
        assert max(load.values()) == routing.z or routing is m


def test_milp_respects_existing_load():
    topo = grid2d(2, 2)
    existing = {(0, 1): 3}
    r = F.route_fibers_milp(topo, [(0, 3)], existing=existing)
    # best route avoids the loaded edge (0->2->3); z counts existing load per
    # Alg. 4's  z ≥ Σ_i x_{u,v} + edge_count(u,v)
    assert r.routes == [[0, 2, 3]]
    assert r.z == 3


def test_paper_claim_64_servers_100_and_512_circuits():
    """§4.2: 'On a 64-server grid, the maximum number of fibers needed to
    support 100 and 512 random circuits is 7 and 31' (within 10 s)."""
    topo = F.server_grid(64)
    d100 = F.random_demands(topo, 100, seed=0)
    r100 = F.route_fibers(topo, d100)
    assert r100.z <= 7
    assert r100.elapsed_s < 10.0
    d512 = F.random_demands(topo, 512, seed=0)
    r512 = F.route_fibers(topo, d512)
    assert r512.z <= 31
    assert r512.elapsed_s < 10.0


# ----------------------------------------- concurrent fabric allocations
#
# The multi-group arbiter (planner.plan_concurrent) chooses per-round
# circuit allocations for several process-group sets at once; the physical
# layer must actually be able to realize them.  These tests drive each
# joint round's union circuit set through Algorithm 3 (MZI mesh routing)
# and Algorithm 4 (inter-server fiber routing) and check the hard
# invariants: per-λ waveguide disjointness, per-route flow conservation,
# and fiber-port accounting.


def _joint_round_allocations(n, tp, dp, hw=None):
    """(per-round per-group circuit sets, ConcurrentPlan) for a TP×DP mesh
    running all-reduce rows + reduce-scatter columns concurrently."""
    from repro.core import cost_model as cm
    from repro.core import schedules as S
    from repro.core import topology as T
    from repro.core.pccl import default_standard_set
    from repro.core.planner import build_structure, plan_concurrent
    from repro.core.schedules import mesh_groups, replicate_groups

    hw = hw or cm.H100_DGX
    tp_groups, dp_groups = mesh_groups(tp, dp)
    MB = 1024.0 ** 2
    scheds = [
        replicate_groups(S.get_schedule("all_reduce", "ring", tp, 64 * MB),
                         tp_groups, n),
        replicate_groups(S.get_schedule("reduce_scatter", "ring", dp, 64 * MB),
                         dp_groups, n),
    ]
    g0 = T.ring(n)
    std = default_standard_set(n)
    cp = plan_concurrent(g0, std, scheds, hw)
    structs = [build_structure(g0, std, sch, hw) for sch in scheds]
    rounds = []
    for i in range(cp.n_rounds):
        per_group = []
        for g, grp in enumerate(cp.groups):
            per_group.append(sorted(structs[g].states[grp.states[i]].topo.edges))
        rounds.append(per_group)
    return rounds, cp


def test_concurrent_allocations_route_on_mzi_mesh_per_wavelength():
    """Each group's allocated circuits ride a wavelength *pair* (one λ per
    direction, WDM-style); every joint round's combined demand must route on
    the MZI mesh without two same-λ circuits sharing a waveguide (the
    Alg. 3 signal-integrity invariant)."""
    n, tp, dp = 4, 2, 2
    rounds, _ = _joint_round_allocations(n, tp, dp)
    m = CC.MZIMesh(8, 8)
    # ranks sit at interior nodes (4 incident waveguides each), spread out
    place = [8 * r + c for (r, c) in ((2, 2), (2, 5), (5, 2), (5, 5))]
    for per_group in rounds:
        reqs = [
            CC.CircuitRequest(
                place[u], place[v],
                wavelength=2 * lam + (1 if u > v else 0),
            )
            for lam, circuits in enumerate(per_group)
            for (u, v) in circuits
        ]
        res = CC.route_circuits(m, reqs)
        assert not res.failed, f"unroutable joint allocation: {res.failed}"
        CC.validate_routes(m, res, reqs)


def test_concurrent_allocations_conserve_fiber_ports():
    """Algorithm 4 on each joint round's union circuit set: every demand
    routes with per-node flow conservation, and the per-edge fiber counts
    add up exactly to the routes crossing them (no port double-booking)."""
    n, tp, dp = 16, 4, 4
    rounds, cp = _joint_round_allocations(n, tp, dp)
    topo = F.server_grid(n)  # rank i -> server i on the 4x4 grid
    for per_group in (rounds[0], rounds[-1]):
        demands = sorted(set(e for circuits in per_group for e in circuits))
        routing = F.route_fibers(topo, demands)
        # each route is a path realizing its demand
        recount = {}
        for path, (s, d) in zip(routing.routes, demands):
            assert path[0] == s and path[-1] == d
            assert len(set(path)) == len(path)  # simple: conservation holds
            for a, b in zip(path[:-1], path[1:]):
                assert (a, b) in topo.edges
                recount[(a, b)] = recount.get((a, b), 0) + 1
        # fiber-port conservation: the recorded loads are exactly the route
        # crossings, and z is the worst edge — the fibers to provision
        assert recount == routing.edge_load
        assert routing.z == max(recount.values())
        total_ports = sum(recount.values())
        assert total_ports == sum(len(p) - 1 for p in routing.routes)
    assert cp.total_cost <= cp.sequential_cost * (1 + 1e-12)


def test_concurrent_union_allocation_is_feasible_circuit_set():
    """The arbiter's final fabric state (union of every group's last
    allocation) must itself be a routable circuit set — both layers accept
    it, so threading it into the next plan's G0 is physically meaningful."""
    n, tp, dp = 8, 2, 4
    _, cp = _joint_round_allocations(n, tp, dp)
    if cp.serialized:
        pytest.skip("serialized fallback: no union state to realize")
    union = sorted(cp.final_topology.edges)
    routing = F.route_fibers(F.server_grid(n), union)
    assert routing.z >= 1
    assert len(routing.routes) == len(union)
    m = CC.MZIMesh(6, 6)
    reqs = [CC.CircuitRequest(4 * u, 4 * v) for (u, v) in union]
    res = CC.route_circuits(m, reqs, max_overlap=1)
    CC.validate_routes(m, res, reqs, max_overlap=1)
