"""Runs the 8-device collective equivalence suite in a subprocess.

XLA locks the host device count at first jax init, so multi-device checks
must not share a process with the single-device smoke tests (assignment
rule: only the dry-run and dedicated subprocesses force device_count)."""

import os
import pathlib
import subprocess
import sys

import pytest

ROOT = pathlib.Path(__file__).resolve().parent.parent


@pytest.mark.slow
@pytest.mark.multidevice
def test_multidevice_collectives():
    env = dict(os.environ)
    env["PYTHONPATH"] = str(ROOT / "src")
    proc = subprocess.run(
        [sys.executable, str(ROOT / "tests" / "multidevice_check.py")],
        capture_output=True,
        text=True,
        env=env,
        timeout=600,
    )
    assert proc.returncode == 0, f"stdout:\n{proc.stdout}\nstderr:\n{proc.stderr}"
    assert "ALL-MULTIDEVICE-OK" in proc.stdout
