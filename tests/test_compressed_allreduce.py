"""Int8-compressed all-reduce: quantizer unit tests + device subprocess.

The collective itself needs >1 device, so the psum-agreement and
error-feedback checks run in compressed_allreduce_check.py under 4 host
devices.  The quantizer's per-hop bound — the quantity the documented
error model is built from — is testable on one device here.
"""

import os
import pathlib
import subprocess
import sys

import numpy as np
import pytest

ROOT = pathlib.Path(__file__).resolve().parent.parent


def test_quantize_roundtrip_within_per_hop_bound():
    jnp = pytest.importorskip("jax.numpy")
    from repro.comm.pccl_collectives import _dequantize, _quantize

    rng = np.random.default_rng(0)
    for scale_mag in (1e-3, 1.0, 1e3):
        x = jnp.asarray(rng.normal(size=(256,)).astype(np.float32) * scale_mag)
        q, s = _quantize(x)
        assert q.dtype == jnp.int8
        back = _dequantize(q, s)
        # documented per-hop bound: |round-trip error| <= scale/2,
        # scale = max|x|/127
        bound = float(jnp.max(jnp.abs(x))) / 127.0 / 2.0 + 1e-12
        assert float(jnp.max(jnp.abs(back - x))) <= bound * 1.0001


def test_quantize_handles_zero_buffer():
    jnp = pytest.importorskip("jax.numpy")
    from repro.comm.pccl_collectives import _dequantize, _quantize

    q, s = _quantize(jnp.zeros((8,), jnp.float32))
    assert float(jnp.max(jnp.abs(_dequantize(q, s)))) == 0.0


@pytest.mark.slow
@pytest.mark.multidevice
def test_compressed_allreduce_device_checks():
    env = dict(os.environ)
    env["PYTHONPATH"] = str(ROOT / "src")
    proc = subprocess.run(
        [sys.executable, str(ROOT / "tests" / "compressed_allreduce_check.py")],
        capture_output=True,
        text=True,
        env=env,
        timeout=600,
    )
    assert proc.returncode == 0, f"stdout:\n{proc.stdout}\nstderr:\n{proc.stderr}"
    assert "ALL-COMPRESSED-OK" in proc.stdout
