"""Concurrent-group fabric arbitration (planner.plan_concurrent).

The discipline mirrors the single-group planner tests: the greedy+refinement
solver must agree with the exact product-state DP on n ≤ 8 in every
reconfiguration mode, be bit-reproducible, and never price worse than
sequential independent planning.  On top sit the facade/session/communicator
surfaces and the edge-load primitive the joint cost model is built on.
"""

import numpy as np
import pytest

from repro.core import cost_model as cm
from repro.core import schedules as S
from repro.core import topology as T
from repro.core.cost_model import STRUCTURE_TABLE, edge_loads, pairs_of
from repro.core.pccl import (
    ConcurrentCollectiveRequest,
    default_standard_set,
    plan_concurrent_collectives,
)
from repro.core.planner import (
    plan,
    plan_concurrent,
    plan_concurrent_exact,
)
from repro.core.schedules import mesh_groups, replicate_groups

MB = 1024.0 ** 2

HW_MODES = {
    "serial": cm.H100_DGX,
    "serial_slow": cm.H100_DGX.with_reconfig(1e-3),
    "partial": cm.H100_DGX.with_link_reconfig(1e-6),
    "overlap": cm.H100_DGX.with_link_reconfig(1e-6, overlap=True),
}


def two_axis_schedules(n, tp, dp, c1="all_reduce", a1="rhd",
                       c2="reduce_scatter", a2="rhd", s1=64 * MB, s2=64 * MB):
    tpg, dpg = mesh_groups(tp, dp)
    sch1 = replicate_groups(S.get_schedule(c1, a1, tp, s1), tpg, n)
    sch2 = replicate_groups(S.get_schedule(c2, a2, dp, s2), dpg, n)
    return [sch1, sch2]


# ------------------------------------------------------------ exact oracle

EXACT_CASES = [
    (4, 2, 2, "all_reduce", "rhd", "reduce_scatter", "rhd", 1 * MB, 64 * MB),
    (4, 2, 2, "all_reduce", "ring", "reduce_scatter", "ring", 64 * MB, 64 * MB),
    (8, 2, 4, "all_reduce", "rhd", "reduce_scatter", "rhd", 64 * MB, 64 * MB),
    (8, 4, 2, "all_to_all", "dex", "all_gather", "rhd", 1 * MB, 64 * MB),
    (8, 4, 2, "all_reduce", "ring", "all_gather", "ring", 64 * MB, 4 * MB),
]


@pytest.mark.parametrize("mode", sorted(HW_MODES))
@pytest.mark.parametrize("case", EXACT_CASES, ids=lambda c: f"n{c[0]}_{c[4]}+{c[6]}")
def test_heuristic_matches_exact_product_dp(mode, case):
    """Greedy+refinement == exact product-state DP on n ≤ 8 (all modes)."""
    n, tp, dp, c1, a1, c2, a2, s1, s2 = case
    hw = HW_MODES[mode]
    scheds = two_axis_schedules(n, tp, dp, c1, a1, c2, a2, s1, s2)
    std = default_standard_set(n)
    cp = plan_concurrent(T.ring(n), std, scheds, hw)
    exact = plan_concurrent_exact(T.ring(n), std, scheds, hw)
    assert cp.joint_cost == pytest.approx(exact, rel=1e-12)


def test_exact_solver_guards_state_space():
    scheds = two_axis_schedules(16, 4, 4)
    with pytest.raises(ValueError, match="product state space"):
        plan_concurrent_exact(
            T.ring(16), default_standard_set(16), scheds, cm.H100_DGX,
            max_product_states=4,
        )


# --------------------------------------------------------- reproducibility


def test_bit_reproducible():
    """Two fresh solver runs return the identical plan, state for state."""
    scheds = two_axis_schedules(16, 4, 4)
    std = default_standard_set(16)
    a = plan_concurrent(T.ring(16), std, scheds, cm.H100_DGX)
    b = plan_concurrent(T.ring(16), std, scheds, cm.H100_DGX)
    assert a.joint_cost == b.joint_cost
    assert a.sequential_cost == b.sequential_cost
    assert a.serialized == b.serialized
    assert tuple(g.states for g in a.groups) == tuple(g.states for g in b.groups)
    assert a.final_topology.edges == b.final_topology.edges


# ---------------------------------------------------- never-worse guarantee


@pytest.mark.parametrize("mode", sorted(HW_MODES))
@pytest.mark.parametrize("n,tp,dp", [(4, 2, 2), (8, 2, 4), (16, 4, 4)])
def test_never_worse_than_sequential(mode, n, tp, dp):
    hw = HW_MODES[mode]
    scheds = two_axis_schedules(n, tp, dp)
    cp = plan_concurrent(T.ring(n), default_standard_set(n), scheds, hw)
    assert cp.total_cost <= cp.sequential_cost * (1 + 1e-12)
    # consistency of the serialized fallback bookkeeping
    assert cp.serialized == (cp.joint_cost > cp.sequential_cost)
    expected = cp.sequential_cost if cp.serialized else cp.joint_cost
    assert cp.total_cost == expected
    assert cp.speedup == pytest.approx(cp.sequential_cost / cp.total_cost)


def test_link_disjoint_axes_genuinely_overlap():
    """TP row-rings and DP column-rings allocate disjoint circuits, so the
    joint plan must beat running the two collectives back-to-back."""
    n, tp, dp = 16, 4, 4
    scheds = two_axis_schedules(
        n, tp, dp, "all_reduce", "ring", "reduce_scatter", "ring"
    )
    cp = plan_concurrent(T.ring(n), default_standard_set(n), scheds, cm.H100_DGX)
    assert not cp.serialized
    assert cp.joint_cost < cp.sequential_cost
    assert cp.speedup > 1.2
    # per-group solo plans are the sequential baseline's parts
    assert cp.sequential_cost == pytest.approx(
        sum(g.solo.total_cost for g in cp.groups)
    )


def test_single_group_degenerates_to_solo_plan():
    """With one group the joint cost model collapses to Algorithm 1/2
    arithmetic exactly, so plan_concurrent must reproduce plan()."""
    n = 8
    sched = S.get_schedule("all_reduce", "rhd", n, 64 * MB)
    std = default_standard_set(n)
    for hw in HW_MODES.values():
        solo = plan(T.ring(n), std, sched, hw)
        cp = plan_concurrent(T.ring(n), std, [sched], hw)
        assert cp.joint_cost == pytest.approx(solo.total_cost, rel=1e-12)
        assert not cp.serialized


def test_final_topology_is_union_of_last_allocations():
    n, tp, dp = 8, 2, 4
    scheds = two_axis_schedules(n, tp, dp)
    cp = plan_concurrent(T.ring(n), default_standard_set(n), scheds, cm.H100_DGX)
    if not cp.serialized:
        expect = frozenset()
        for g, grp in enumerate(cp.groups):
            # rebuild each group's last state topology from its plan view
            last = grp.states[-1]
            # states index into the per-group structure; recover via solver
            from repro.core.planner import build_structure

            st = build_structure(
                T.ring(n), default_standard_set(n), grp.schedule, cm.H100_DGX
            )
            expect |= st.states[last].topo.edges
        assert cp.final_topology.edges == expect
    assert cp.final_topology.n == n


# ------------------------------------------------------------- validation


def test_rejects_empty_and_mismatched_inputs():
    n = 8
    std = default_standard_set(n)
    with pytest.raises(ValueError, match="at least one"):
        plan_concurrent(T.ring(n), std, [], cm.H100_DGX)
    wrong_n = S.get_schedule("all_reduce", "ring", 4, MB)
    with pytest.raises(ValueError, match="spans n="):
        plan_concurrent(T.ring(n), std, [wrong_n], cm.H100_DGX)


def test_facade_rejects_bad_groups():
    n = 8
    g0 = T.ring(n)
    uneq = ConcurrentCollectiveRequest(
        "all_reduce", MB, groups=((0, 1, 2), (3, 4), (5, 6, 7))
    )
    with pytest.raises(ValueError, match="unequal group sizes"):
        plan_concurrent_collectives([uneq], n, g0, cm.H100_DGX)
    overlap = ConcurrentCollectiveRequest(
        "all_reduce", MB, groups=((0, 1, 2, 3), (3, 4, 5, 6))
    )
    with pytest.raises(ValueError, match="partition"):
        plan_concurrent_collectives([overlap], n, g0, cm.H100_DGX)


# ------------------------------------------------------------ facade level


def test_facade_arbitrates_algorithms_per_request():
    """`auto` requests pick their input schedule by solo planned cost, the
    same arbitration as plan_collective applied per group."""
    n, tp, dp = 16, 4, 4
    tpg, dpg = mesh_groups(tp, dp)
    cp = plan_concurrent_collectives(
        [
            ConcurrentCollectiveRequest("all_reduce", 64 * MB, groups=tpg,
                                        algorithm="auto"),
            ConcurrentCollectiveRequest("reduce_scatter", 64 * MB, groups=dpg,
                                        algorithm="auto"),
        ],
        n, T.ring(n), cm.H100_DGX,
    )
    assert len(cp.algorithms) == 2
    assert all(isinstance(a, str) for a in cp.algorithms)
    assert len(cp.solo_costs()) == 2
    assert cp.cost <= cp.sequential_cost * (1 + 1e-12)
    # explicit algorithm pins the input schedule
    pinned = plan_concurrent_collectives(
        [
            ConcurrentCollectiveRequest("all_reduce", 64 * MB, groups=tpg,
                                        algorithm="ring"),
            ConcurrentCollectiveRequest("reduce_scatter", 64 * MB, groups=dpg,
                                        algorithm="ring"),
        ],
        n, T.ring(n), cm.H100_DGX,
    )
    assert pinned.algorithms == ("ring", "ring")


# ------------------------------------------------------------ edge loads


@pytest.mark.parametrize("topo_name", ["ring", "torus2d", "grid2d", "hypercube"])
@pytest.mark.parametrize("algo,collective", [
    ("ring", "reduce_scatter"), ("rhd", "all_reduce"), ("dex", "all_to_all"),
])
def test_edge_loads_agree_with_structure_factors(topo_name, algo, collective):
    """max(per-link load) == Algorithm 2's congestion and the dilations
    match — the concurrent per-link pricing degenerates to (D, C) exactly
    when a group has the fabric to itself."""
    n = 8
    topo = T.standard_topologies(n)[topo_name]
    sched = S.get_schedule(collective, algo, n, MB)
    for rnd in sched.rounds:
        pairs = pairs_of(rnd)
        if not pairs:
            continue
        d, c, feas = STRUCTURE_TABLE.factors(topo, pairs)
        loads = edge_loads(topo, pairs)
        if not feas:
            assert loads is None
            continue
        dil, per_edge = loads
        assert dil == d
        assert max(cnt for _, cnt in per_edge) == c
        # conservation: every transfer contributes exactly its hop count
        total_hops = sum(cnt for _, cnt in per_edge)
        assert total_hops >= len(pairs)  # >= 1 hop per transfer
        # loads only on actual circuits of the topology
        assert all(e in topo.edges for e, _ in per_edge)


def test_edge_loads_empty_and_disconnected():
    assert edge_loads(T.ring(4), []) == (0, ())
    two_islands = T.Topology(4, frozenset({(0, 1), (1, 0), (2, 3), (3, 2)}))
    assert edge_loads(two_islands, [(0, 3)]) is None


# ---------------------------------------------------------- session level


def test_session_plan_concurrent_caches_and_threads():
    from repro.api import PcclSession

    n, tp, dp = 16, 4, 4
    tpg, dpg = mesh_groups(tp, dp)
    reqs = [
        ConcurrentCollectiveRequest("all_reduce", 64 * MB, groups=tpg),
        ConcurrentCollectiveRequest("reduce_scatter", 64 * MB, groups=dpg),
    ]
    sess = PcclSession(cm.H100_DGX)
    cp1 = sess.plan_concurrent(reqs)  # n inferred from the groups
    assert sess.stats.misses == 1
    # fabric threaded: the next plan starts from the combined allocation
    assert sess.fabric(n).edges == cp1.final_topology.edges
    # same request over the *threaded* fabric is a different key (warm plan)
    sess.plan_concurrent(reqs)
    # once the fabric reaches a fixed point, lookups hit
    before = sess.stats.hits
    sess.plan_concurrent(reqs)
    sess.plan_concurrent(reqs)
    assert sess.stats.hits >= before + 1

    cold = PcclSession(cm.H100_DGX, thread_fabric=False)
    cp_cold = cold.plan_concurrent(reqs)
    assert cold.fabric(n).edges == T.ring(n).edges  # not threaded
    assert cp_cold.cost == pytest.approx(cp1.cost)  # same cold G0


def test_session_plan_concurrent_requires_domain_size():
    from repro.api import PcclSession

    sess = PcclSession(cm.H100_DGX)
    with pytest.raises(ValueError, match="at least one request"):
        sess.plan_concurrent([])
    # no groups anywhere and no default n → must be told the domain
    with pytest.raises(ValueError, match="no default rank count"):
        sess.plan_concurrent(
            [ConcurrentCollectiveRequest("all_reduce", MB)]
        )
    got = sess.plan_concurrent(
        [ConcurrentCollectiveRequest("all_reduce", MB)], n=8
    )
    assert got.n == 8


def test_communicator_concurrent_request_plumbing():
    from repro.api import PcclSession

    n, tp, dp = 16, 4, 4
    sess = PcclSession(cm.H100_DGX)
    comm = sess.communicator("x", n, backend="sim")
    tp_comm = comm.split([r // tp for r in range(n)])   # rows
    dp_comm = comm.split([r % tp for r in range(n)])    # columns
    r_tp = tp_comm.concurrent_request("all_reduce", 64 * MB)
    r_dp = dp_comm.concurrent_request("reduce_scatter", 64 * MB)
    assert r_tp.groups == mesh_groups(tp, dp)[0]
    assert r_dp.groups == mesh_groups(tp, dp)[1]
    assert r_tp.algorithm == "auto"  # communicator default
    cp = sess.plan_concurrent([r_tp, r_dp])
    assert cp.n == n
    assert cp.cost <= cp.sequential_cost * (1 + 1e-12)
    # full-axis communicator contributes a single domain-spanning group
    full = comm.concurrent_request("all_to_all", MB, algorithm="direct")
    assert full.groups is None and full.algorithm == "direct"


def test_facade_rejects_request_with_no_usable_candidate():
    """A pinned bucket algorithm over a prime group size has only degenerate
    factorizations — the facade must say so, not crash downstream."""
    req = ConcurrentCollectiveRequest(
        "all_reduce", MB, groups=None, algorithm="bucket2d"
    )
    with pytest.raises(ValueError, match="no usable candidate"):
        plan_concurrent_collectives([req], 5, T.ring(5), cm.H100_DGX)


def test_request_groups_normalized_for_cache_keys():
    """List-of-lists group literals must hash (they end up in the session's
    plan-cache key) and compare equal to the tuple form."""
    from repro.api import PcclSession

    as_lists = ConcurrentCollectiveRequest(
        "all_reduce", MB, groups=[[0, 1], [2, 3]]
    )
    as_tuples = ConcurrentCollectiveRequest(
        "all_reduce", MB, groups=((0, 1), (2, 3))
    )
    assert as_lists.groups == as_tuples.groups
    assert hash(as_lists) == hash(as_tuples)
    sess = PcclSession(cm.H100_DGX, thread_fabric=False)
    cp = sess.plan_concurrent([as_lists])
    assert cp.n == 4
    sess.plan_concurrent([as_tuples])
    assert sess.stats.hits == 1  # same key, cache hit


# ------------------------------------------------------- arrival offsets
def test_zero_offsets_bit_identical_to_none():
    """offsets=(0, 0) must be the no-offset plan bit-for-bit: same tables,
    same horizon, same joint DP — staggering only changes anything when an
    offset is nonzero."""
    scheds = two_axis_schedules(8, 2, 4)
    g0 = T.ring(8)
    std = default_standard_set(8)
    base = plan_concurrent(g0, std, scheds, cm.H100_DGX)
    zero = plan_concurrent(g0, std, scheds, cm.H100_DGX, offsets=(0, 0))
    assert zero.joint_cost == base.joint_cost
    assert zero.n_rounds == base.n_rounds
    assert [g.states for g in zero.groups] == [g.states for g in base.groups]
    assert base.offsets == () and zero.offsets == ()


@pytest.mark.parametrize("offsets", [(2, 0), (0, 3), (1, 2)])
def test_offsets_shift_horizon_and_keep_bounds(offsets):
    """A staggered plan spans max(offset + rounds) joint rounds, records its
    offsets, still never prices worse than the (equally staggered)
    sequential baseline, and replays cleanly through the invariant
    checker."""
    from repro.analysis.invariants import check_concurrent_plan

    scheds = two_axis_schedules(8, 2, 4)
    g0 = T.ring(8)
    std = default_standard_set(8)
    cp = plan_concurrent(g0, std, scheds, cm.H100_DGX, offsets=offsets)
    assert cp.offsets == offsets
    assert cp.n_rounds == max(
        o + s.num_rounds for o, s in zip(offsets, scheds)
    )
    assert cp.joint_cost <= cp.sequential_cost * (1 + 1e-12)
    assert check_concurrent_plan(cp, g0, std) == []


@pytest.mark.parametrize("mode", sorted(HW_MODES))
def test_offsets_heuristic_matches_exact(mode):
    """The greedy+refinement solver under offsets stays within its usual
    envelope of the exact product-state DP (never better than exact;
    serialized fallback keeps it bounded above)."""
    hw = HW_MODES[mode]
    scheds = two_axis_schedules(4, 2, 2, s1=1 * MB, s2=64 * MB)
    g0 = T.ring(4)
    std = default_standard_set(4)
    for offsets in ((0, 0), (1, 0), (0, 2)):
        cp = plan_concurrent(g0, std, scheds, hw, offsets=offsets)
        exact = plan_concurrent_exact(g0, std, scheds, hw, offsets=offsets)
        assert cp.joint_cost >= exact - 1e-15
        assert cp.joint_cost <= cp.sequential_cost * (1 + 1e-12)


def test_offsets_idle_prefix_holds_or_prepositions():
    """During its idle prefix a group occupies states enterable at its
    first round — the prefix rows of the padded sequence are valid
    pre-positioning, and the post-offset suffix is a complete execution."""
    scheds = two_axis_schedules(8, 2, 4)
    g0 = T.ring(8)
    std = default_standard_set(8)
    off = (3, 0)
    cp = plan_concurrent(g0, std, scheds, cm.H100_DGX, offsets=off)
    for g, grp in enumerate(cp.groups):
        assert len(grp.states) == cp.n_rounds
        # the group's own rounds occupy the suffix starting at its offset
        assert cp.n_rounds - off[g] >= scheds[g].num_rounds


def test_offsets_validation():
    scheds = two_axis_schedules(8, 2, 4)
    g0 = T.ring(8)
    std = default_standard_set(8)
    with pytest.raises(ValueError, match="offsets"):
        plan_concurrent(g0, std, scheds, cm.H100_DGX, offsets=(1,))
    with pytest.raises(ValueError, match="offsets"):
        plan_concurrent(g0, std, scheds, cm.H100_DGX, offsets=(-1, 0))
    reqs = [
        ConcurrentCollectiveRequest("all_reduce", MB, groups=((0, 1), (2, 3))),
        ConcurrentCollectiveRequest("all_gather", MB, groups=((0, 2), (1, 3))),
    ]
    with pytest.raises(ValueError, match="offsets"):
        plan_concurrent_collectives(reqs, 4, T.ring(4), cm.H100_DGX,
                                    offsets=(1, 2, 3))


def test_facade_offsets_roundtrip():
    """plan_concurrent_collectives forwards offsets and the wrapper exposes
    them; a session caches staggered and aligned variants separately."""
    from repro.api import PcclSession

    reqs = [
        ConcurrentCollectiveRequest("all_reduce", MB, groups=((0, 1), (2, 3))),
        ConcurrentCollectiveRequest("all_gather", MB, groups=((0, 2), (1, 3))),
    ]
    cp = plan_concurrent_collectives(reqs, 4, T.ring(4), cm.H100_DGX,
                                     offsets=(0, 2))
    assert cp.offsets == (0, 2)
    sess = PcclSession(cm.H100_DGX, thread_fabric=False)
    a = sess.plan_concurrent(reqs, n=4)
    b = sess.plan_concurrent(reqs, n=4, offsets=(0, 2))
    c = sess.plan_concurrent(reqs, n=4, offsets=(0, 0))  # aligned == None
    assert b.offsets == (0, 2)
    assert a.joint_cost == c.joint_cost
    assert sess.stats.hits == 1 and sess.stats.misses == 2
