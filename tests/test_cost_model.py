
import pytest

from repro.core import cost_model as C
from repro.core import schedules as S
from repro.core import topology as T

HW = C.H100_DGX


def test_presets():
    assert C.PRESETS["h100_dgx"].alpha == pytest.approx(3e-6)
    assert C.PRESETS["h100_dgx"].beta == pytest.approx(1 / 450e9)
    assert C.PRESETS["h100_dgx"].reconfig_delay == pytest.approx(5e-6)
    assert C.PRESETS["h100_dgx_r0.001"].reconfig_delay == pytest.approx(1e-3)
    assert C.PRESETS["tpu_v5e_photonic"].beta == pytest.approx(1 / 50e9)


def test_ring_rs_on_ring_is_congestion_free():
    n, d = 8, 8 * 1024.0
    topo = T.ring(n)
    sched = S.ring_reduce_scatter(n, d)
    cost = C.schedule_cost_fixed(topo, sched, HW)
    assert cost.dilation_extra == 0.0
    assert cost.congestion_extra == 0.0
    # textbook: (n-1)·(α + β·d/n)
    assert cost.total == pytest.approx((n - 1) * (HW.alpha + HW.beta * d / n))
    assert cost.total == pytest.approx(C.ideal_cost(sched, HW))


def test_rhd_on_ring_suffers_congestion_and_dilation():
    """Paper Fig. 5: RHD AllGather distances 1,2,4 on a ring — later rounds
    overlap on links (congestion) and span multiple hops (dilation)."""
    n, d = 8, 8 * 1024.0
    topo = T.ring(n)
    ag = S.rhd_all_gather(n, d)
    per_round = [C.comm_cost_round(topo, r, None, HW) for r in ag.rounds]
    assert per_round[0].dilation == 1 and per_round[0].congestion == 1
    assert per_round[1].dilation == 2 and per_round[1].congestion == 2
    assert per_round[2].dilation == 4 and per_round[2].congestion == 4
    fixed = C.schedule_cost_fixed(topo, ag, HW)
    assert fixed.total > C.ideal_cost(ag, HW)


def test_rhd_on_hypercube_is_ideal():
    """RHD's partners are exactly hypercube neighbours → no congestion."""
    n, d = 8, 1024.0
    topo = T.hypercube(n)
    rs = S.rhd_reduce_scatter(n, d)
    cost = C.schedule_cost_fixed(topo, rs, HW)
    assert cost.dilation_extra == 0 and cost.congestion_extra == 0
    assert cost.total == pytest.approx(C.ideal_cost(rs, HW))


def test_bucket_on_matching_torus_is_ideal():
    dims = (4, 4)
    d = 4096.0
    topo = T.torus2d(*dims)
    rs = S.bucket_reduce_scatter(dims, d)
    cost = C.schedule_cost_fixed(topo, rs, HW)
    assert cost.dilation_extra == 0 and cost.congestion_extra == 0


def test_bucket_on_grid_pays_wraparound():
    """Grid = torus minus wrap links: the ring's wrap hop dilates (§5)."""
    dims = (4, 4)
    d = 4096.0
    topo = T.grid2d(*dims)
    rs = S.bucket_reduce_scatter(dims, d)
    cost = C.schedule_cost_fixed(topo, rs, HW)
    assert cost.dilation_extra > 0
    # the wrap transfer backtracks on reverse (full-duplex) links, so it adds
    # dilation but no same-direction congestion in a permutation round
    assert cost.total > C.ideal_cost(rs, HW)


def test_disconnected_round_gets_large_penalty():
    topo = T.from_transfers(4, [(0, 1), (1, 0)])
    sched = S.direct_all_to_all(4, 64.0)
    rc = C.comm_cost_round(topo, sched.rounds[0], None, HW)
    assert not rc.feasible
    assert rc.total >= C.LARGE_PENALTY


def test_round_on_own_ideal_topology_is_alpha_beta():
    n, d = 8, 512.0
    sched = S.rhd_reduce_scatter(n, d)
    for rnd in sched.rounds:
        ideal = rnd.ideal_topology(n)
        rc = C.comm_cost_round(ideal, rnd, None, HW)
        assert rc.dilation == 1 and rc.congestion == 1
        assert rc.total == pytest.approx(HW.alpha + HW.beta * rnd.size)


def test_congestion_factor_matches_fig6_model():
    """c overlapping transfers on one link divide bandwidth by c."""
    # 4-node line; transfers 0->3, 1->3, 2->3 share edge (2,3)
    from repro.core.schedules import Round, Transfer

    topo = T.line(4)
    rnd = Round((Transfer(0, 3), Transfer(1, 3), Transfer(2, 3)), 1e6)
    rc = C.comm_cost_round(topo, rnd, None, HW)
    assert rc.congestion == 3
    assert rc.dilation == 3


def test_lower_bound_reduce_scatter():
    n, d = 8, 1e6
    lb = C.lower_bound_reduce_scatter(n, d, HW)
    # RHD on its ideal topologies achieves the bound exactly (power of 2)
    sched = S.rhd_reduce_scatter(n, d)
    assert C.ideal_cost(sched, HW) == pytest.approx(lb)


def test_alltoall_dex_vs_direct_crossover():
    """§2.2: the better algorithm depends on buffer size — DEX (α-optimal)
    wins small buffers, direct exchange (β-optimal) wins large, measured on
    ideal (reconfigured) topologies."""
    n = 64
    small, large = 8 * 1024.0, 1024 ** 3
    dex_small = C.ideal_cost(S.dex_all_to_all(n, small), HW)
    direct_small = C.ideal_cost(S.direct_all_to_all(n, small), HW)
    assert dex_small < direct_small
    dex_large = C.ideal_cost(S.dex_all_to_all(n, large), HW)
    direct_large = C.ideal_cost(S.direct_all_to_all(n, large), HW)
    assert direct_large < dex_large
